"""Algorithm 1 in action: LP-based configuration search for an
SSD-offloaded training run.

    PYTHONPATH=src python examples/lp_config_search.py [--model gpt-65b]

Benchmarks (here: presets for) the machine, then searches micro-batch
count n, delay ratio α, and the CPU/SSD storage split x for checkpoints,
parameters, and optimizer states — printing the throughput landscape and
the chosen configuration, exactly the procedure of paper §4.5.
"""
import argparse

from repro.configs import get_config
from repro.core.lp_search import find_optimal_config, solve_config
from repro.core.perfmodel import MachineParams, Workload, rooflines

MACHINES = {
    "a100-cloud": MachineParams(name="a100-cloud", gpu_flops=140e12,
                                pcie_bw=24e9, ssd_read_bw=4.0e9,
                                ssd_write_bw=2.0e9, cpu_adam_bw=8.0e9,
                                cpu_mem=400e9, gpu_mem=40e9),
    "a5000": MachineParams(name="a5000", gpu_flops=55e12, pcie_bw=24e9,
                           ssd_read_bw=6.9e9, ssd_write_bw=4.1e9,
                           cpu_adam_bw=5.0e9, cpu_mem=256e9, gpu_mem=24e9),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="gpt-65b")
    ap.add_argument("--machine", default="a100-cloud", choices=MACHINES)
    ap.add_argument("--micro-batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=2048)
    args = ap.parse_args()

    cfg = get_config(args.model)
    m = MACHINES[args.machine]
    w = Workload.from_config(cfg, micro_batch=args.micro_batch,
                             seq_len=args.seq)
    print(f"{args.model} on {m.name}: ms={w.ms / 1e9:.0f}GB "
          f"cs={w.cs / 1e9:.2f}GB os={w.os_bytes / 1e9:.0f}GB "
          f"grads={w.grad_bytes / 1e9:.0f}GB\n")

    print("n    alpha*  t_iter(s)  tokens/s   x_ckpt x_param x_opt")
    alphas = [i / 20 for i in range(11)]
    for n in (2, 4, 8, 16, 24, 32, 48, 64):
        best = None
        for a in alphas:
            s = solve_config(m, w, n, a)
            if s and (best is None or s.iteration_time < best[1].iteration_time):
                best = (a, s)
        if best is None:
            print(f"{n:<4d} infeasible")
            continue
        a, s = best
        tp = n * w.tokens_per_mb / s.iteration_time
        print(f"{n:<4d} {a:5.2f} {s.iteration_time:10.1f} {tp:10.1f}"
              f"   {s.x.ckpt:6.2f} {s.x.param:7.2f} {s.x.opt:5.2f}")

    res = find_optimal_config(m, w, alphas=alphas, max_n=256)
    io_roof, comp_roof = rooflines(w, m, res.x)
    print(f"\nAlgorithm 1 selects: n*={res.n} alpha*={res.alpha:.2f} "
          f"x*=(ckpt {res.x.ckpt:.2f}, param {res.x.param:.2f}, "
          f"opt {res.x.opt:.2f})")
    print(f"throughput {res.throughput_tokens_per_s:.1f} tokens/s "
          f"({100 * res.throughput_tokens_per_s / comp_roof:.0f}% of the "
          f"compute roofline)")


if __name__ == "__main__":
    main()
