"""Continuous-batching serving through ``repro.serve.ServeEngine``:
requests stream through admission control, per-step compiled serve
plans (tiered param fetches + KV block spill/fetch at
``IOPriority.KV``), and iteration-level batched decode — with a
preempt-to-SSD / bitwise-resume round trip in the middle.

    PYTHONPATH=src python examples/serve_batched.py --arch gpt-tiny
    PYTHONPATH=src python examples/serve_batched.py \
        --arch gpt-tiny --no-offload      # pure-jit in-memory path

``--no-offload`` runs the seed-era pure-jit B=1 loop — the bitwise f32
reference: with ``--check`` both paths run and every request's greedy
tokens must agree exactly. Non-dense families (SSM/VLM/enc-dec) only
support the ``--no-offload`` path.
"""
import argparse
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.data import SyntheticLM
from repro.models import model as mdl


def reference_decode(cfg, key, prompts, gen, max_len):
    """Pure-jit in-memory decode, one request at a time at B=1 (the
    bitwise f32 reference the offloaded path must match exactly)."""
    params = mdl.init_params(cfg, key, dtype=jnp.float32)
    prefill = jax.jit(lambda p, b, c: mdl.prefill(p, cfg, b, c))
    decode = jax.jit(lambda p, t, pos, c: mdl.decode_step(p, cfg, t, pos, c))
    outs = []
    for pr in prompts:
        caches = mdl.init_caches(cfg, 1, max_len, dtype=jnp.float32)
        batch = {"tokens": jnp.asarray([pr], jnp.int32)}
        if cfg.family == "encdec":
            batch["enc_embeds"] = jnp.zeros(
                (1, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            batch["image_embeds"] = jnp.zeros(
                (1, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
        logits, caches = prefill(params, batch, caches)
        toks = [int(jnp.argmax(logits[0]))]
        pos0 = len(pr) + (cfg.frontend_tokens if cfg.family == "vlm" else 0)
        for i in range(gen - 1):
            logits, caches = decode(
                params, jnp.asarray([[toks[-1]]], jnp.int32),
                jnp.asarray(pos0 + i, jnp.int32), caches)
            toks.append(int(jnp.argmax(logits[0])))
        outs.append(toks)
    return outs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt-tiny")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--no-offload", action="store_true",
                    help="pure-jit in-memory decode (the bitwise ref)")
    ap.add_argument("--check", action="store_true",
                    help="run BOTH paths; assert token-exact agreement")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    max_len = args.prompt_len + args.gen
    key = jax.random.PRNGKey(0)
    data = SyntheticLM(cfg.vocab_size, seed=0)
    prompts = [list(map(int, row)) for row in
               np.asarray(data.batch(args.batch, args.prompt_len))]

    if args.no_offload or cfg.family != "dense":
        if cfg.family != "dense" and not args.no_offload:
            print(f"{cfg.name}: family {cfg.family!r} serves in-memory "
                  "only (ServeEngine is dense-stack)")
        t0 = time.perf_counter()
        outs = reference_decode(cfg, key, prompts, args.gen, max_len)
        dt = time.perf_counter() - t0
        print(f"{cfg.name}: in-memory decode "
              f"{args.batch * args.gen / dt:.1f} tok/s")
        print("first sequence:", outs[0])
        print("OK")
        return

    from repro.serve import ServeConfig, ServeEngine
    with tempfile.TemporaryDirectory(prefix="repro_serve_") as workdir:
        scfg = ServeConfig(max_len=max_len, kv_block_bytes=16 << 10,
                           kv_x_host=0.5, param_x_host=0.5)
        eng = ServeEngine(cfg, scfg, key, workdir)
        rids = [eng.submit(p, args.gen) for p in prompts]
        eng.step()                       # prefill wave
        if args.gen > 2 and len(rids) > 1:
            eng.step()
            eng.preempt(rids[0])         # exercise spill -> bitwise resume
        t0 = time.perf_counter()
        while eng.pending():
            eng.step()
        dt = time.perf_counter() - t0
        snap = eng.metrics_snapshot()
        outs = [eng.result(r) for r in rids]
        print(f"{cfg.name}: served {len(rids)} requests, "
              f"{snap['tokens_decoded']} decode tokens, "
              f"kv hit-rate {snap['kv']['hit_rate']:.2f}, "
              f"{snap['tokens_decoded'] / max(dt, 1e-9):.1f} tok/s")
        print("first sequence:", outs[0])
        eng.close()

    if args.check:
        ref = reference_decode(cfg, key, prompts, args.gen, max_len)
        assert outs == ref, (outs, ref)
        print("offloaded == in-memory (token-exact)")
    print("OK")


if __name__ == "__main__":
    main()
