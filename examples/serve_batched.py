"""Batched serving: prefill a prompt batch, then greedy-decode tokens
with the per-architecture KV / SSM / sliding-window caches — the same
``prefill`` / ``decode_step`` entry points the decode_32k / long_500k
dry-run shapes lower.

    PYTHONPATH=src python examples/serve_batched.py --arch gpt-tiny
    PYTHONPATH=src python examples/serve_batched.py \
        --arch falcon-mamba-7b --smoke     # O(1)-state SSM decode
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.data import SyntheticLM
from repro.models import model as mdl


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt-tiny")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    max_len = args.prompt_len + args.gen
    params = mdl.init_params(cfg, jax.random.PRNGKey(0))
    caches = mdl.init_caches(cfg, args.batch, max_len)
    data = SyntheticLM(cfg.vocab_size, seed=0)
    prompts = jnp.asarray(data.batch(args.batch, args.prompt_len))
    batch = {"tokens": prompts}
    if cfg.family == "encdec":
        batch["enc_embeds"] = jnp.zeros(
            (args.batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.zeros(
            (args.batch, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)

    prefill = jax.jit(lambda p, b, c: mdl.prefill(p, cfg, b, c))
    decode = jax.jit(lambda p, t, pos, c: mdl.decode_step(p, cfg, t, pos, c))

    t0 = time.perf_counter()
    logits, caches = prefill(params, batch, caches)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"{cfg.name}: prefill {args.batch}x{args.prompt_len} "
          f"in {t_prefill:.2f}s  (family={cfg.family})")

    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    pos0 = args.prompt_len + (cfg.frontend_tokens
                              if cfg.family == "vlm" else 0)
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, caches = decode(params, tok,
                                jnp.asarray(pos0 + i, jnp.int32), caches)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    gen = np.concatenate([np.asarray(t) for t in out], axis=1)
    assert gen.shape == (args.batch, args.gen)
    assert not np.isnan(np.asarray(logits)).any()
    print(f"decoded {args.gen} tokens/seq: "
          f"{args.batch * (args.gen - 1) / dt:.1f} tok/s")
    print("first sequence:", gen[0].tolist())
    print("OK")


if __name__ == "__main__":
    main()
