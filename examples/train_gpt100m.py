"""End-to-end driver: train a ~100M-parameter GPT for a few hundred
steps with the GreedySnake vertical schedule + α-delayed optimizer.

    PYTHONPATH=src python examples/train_gpt100m.py [--steps 200]

This is the deliverable-(b) end-to-end example: real data pipeline
(synthetic LM stream), schedule, mixed-precision Adam, checkpointing,
and metrics. Runs on whatever devices JAX sees (CPU here, TPU as-is).
"""
import argparse
import os
import tempfile

import jax

from repro.configs import get_config
from repro.core.schedules import ScheduleConfig
from repro.optim import AdamConfig
from repro.train import Trainer
from repro.train.checkpoint import restore, save


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--alpha", type=float, default=0.25)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config("gpt-100m")
    print(f"training {cfg.name}: {cfg.total_params() / 1e6:.0f}M params, "
          f"{args.steps} steps, batch {args.batch} x seq {args.seq}, "
          f"alpha={args.alpha}")
    sched = ScheduleConfig(schedule="vertical",
                           num_microbatches=args.microbatches,
                           alpha=args.alpha, clip_norm=1.0)
    tr = Trainer(cfg, sched, AdamConfig(lr=6e-4))
    rep = tr.run(args.steps, args.batch, args.seq, log_every=20)

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="gs_ckpt_")
    save(ckpt_dir, tr.params, step=tr.step_num)
    restored, _, step = restore(ckpt_dir, tr.params)
    n = sum(x.size for x in jax.tree.leaves(restored))
    print(f"\nfinal loss {rep.losses[-1]:.4f} "
          f"(start {rep.losses[0]:.4f}); {rep.tokens_per_s:.0f} tok/s")
    print(f"checkpoint: {ckpt_dir} (step {step}, {n / 1e6:.0f}M params)")
    assert rep.losses[-1] < rep.losses[0] - 1.0, "training must make progress"
    print("OK")


if __name__ == "__main__":
    main()
