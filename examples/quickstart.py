"""Quickstart: train a small GPT with GreedySnake's vertical schedule.

    PYTHONPATH=src python examples/quickstart.py [--wave W]

Shows the four core public APIs:
  1. configs      — pick an architecture (any of the 10 assigned archs
                    works via get_smoke)
  2. ScheduleConfig / Trainer — vertical vs horizontal schedules
  3. the schedule-equivalence identity — both produce the same gradients
  4. the offload engine's wave-schedule knob — one compiled
     repro.core.plan per W, interpolating between horizontal (W=1) and
     vertical (W=M) storage traffic
"""
import argparse
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.schedules import ScheduleConfig, grads_fn, init_train_state
from repro.data import make_batch
from repro.optim import AdamConfig
from repro.train import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--wave", type=int, default=2, choices=[1, 2, 4],
                    help="wave size W for the offload-engine demo's M=4 "
                         "(W=1 horizontal ... W=4 vertical)")
    args = ap.parse_args()
    cfg = get_config("gpt-tiny")
    print(f"model: {cfg.name}  layers={cfg.num_layers} d={cfg.d_model} "
          f"params={cfg.total_params() / 1e6:.1f}M")

    # --- 1. the paper's identity: vertical grads == horizontal grads ---
    params, _ = init_train_state(cfg, jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in
             make_batch(cfg, 8, 64, seed=1).items()}
    lv, gv = grads_fn(cfg, ScheduleConfig(schedule="vertical"))(params, batch)
    lh, gh = grads_fn(cfg, ScheduleConfig(
        schedule="horizontal", num_microbatches=4))(params, batch)
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(gv), jax.tree.leaves(gh)))
    print(f"schedule equivalence: loss {float(lv):.4f} vs {float(lh):.4f}, "
          f"max grad diff {err:.2e}")

    # --- 2. train a few steps under each schedule ---
    for sched in ("vertical", "horizontal"):
        tr = Trainer(cfg, ScheduleConfig(schedule=sched, num_microbatches=4),
                     AdamConfig(lr=3e-3))
        rep = tr.run(steps=30, batch_size=8, seq_len=64, log_every=10)
        print(f"{sched:10s}: loss {rep.losses[0]:.3f} -> {rep.losses[-1]:.3f} "
              f"({rep.tokens_per_s:.0f} tok/s)")
        assert rep.losses[-1] < rep.losses[0], "loss must decrease"

    # --- 3. the wave knob on the real offload engine ------------------
    # One compiled plan per W; the measured byte counters show the
    # ckpt-traffic / param-reuse trade-off the §3 analysis predicts
    # (and repro.core.plan.plan_traffic predicts them exactly).
    from repro.core.perfmodel import StorageRatios
    from repro.offload import OffloadConfig, OffloadEngine
    M = 4
    print(f"\nwave knob (M={M}; --wave {args.wave}):")
    for W in sorted({1, args.wave, M}):
        with tempfile.TemporaryDirectory() as d:
            eng = OffloadEngine(cfg, OffloadConfig(
                schedule="wave", wave_size=W, num_microbatches=M,
                micro_batch=1, seq_len=64,
                ratios=StorageRatios(0.0, 0.0, 0.0)),
                jax.random.PRNGKey(0), d)
            tok = make_batch(cfg, M, 64, seed=2)["tokens"]
            loss = eng.train_step(np.asarray(tok))
            eng.finish()
            b = eng.meter.bytes
            param = b.get(("param", "cpu->gpu"), 0)
            reread = b.get(("ckpt", "cpu->gpu"), 0) \
                + b.get(("inter_grad", "cpu->gpu"), 0)
            eng.close()
        name = {1: "horizontal", M: "vertical"}.get(W, "wave")
        print(f"  W={W} ({name:10s}): loss {loss:.3f}  "
              f"param {param / 1e6:6.1f} MB  ckpt+grad reads "
              f"{reread / 1e6:6.1f} MB")
    print("OK")


if __name__ == "__main__":
    sys.exit(main())
