"""Quickstart: train a small GPT with GreedySnake's vertical schedule.

    PYTHONPATH=src python examples/quickstart.py [--wave W]
        [--activation-policy recompute|spill|auto] [--trace out.json]
        [--autotune] [--hetero-paths]

Shows the core public APIs:
  1. configs      — pick an architecture (any of the 10 assigned archs
                    works via get_smoke)
  2. ScheduleConfig / Trainer — vertical vs horizontal schedules
  3. the schedule-equivalence identity — both produce the same gradients
  4. the offload engine's wave-schedule knob — one compiled
     repro.core.plan per W, interpolating between horizontal (W=1) and
     vertical (W=M) storage traffic
  5. the activation-policy knob — "spill" streams each layer's vjp
     residuals through the SSD tier (SPILL_ACT/FETCH_ACT at the
     opportunistic IOPriority.ACT) instead of recomputing backward,
     with BITWISE-identical losses; "auto" asks the perf model
  6. the cross-stream lookahead knob — --prefetch-depth places the
     PREFETCH/PREFETCH_CKPT/PREFETCH_ACT/PREFETCH_OPT hints that many
     fetches ahead (0 disables the hints AND the cross-iteration
     α-tail seam); losses are bitwise-identical at every depth, only
     the prefetch hit rate and stall-seconds move
  7. the observability stack — --trace out.json runs a traced engine,
     exports a Perfetto-loadable Chrome trace (one track per I/O
     channel thread + the executor + the hint streams), and prints the
     ``obs.reconcile`` plan-vs-actual table: every (category, route)
     byte counter measured by the run against the ``plan_traffic``
     prediction, EXACT row by row, plus the stall attribution
  8. the online autotuner — --autotune attaches an
     ``AutotuneController``: every window it measures live route
     rates from the chunk spans (``machine_from_snapshot``), re-runs
     Algorithm 1 per candidate plan, and hot-swaps the engine's plan
     between iterations when the predicted win clears hysteresis
     (gated on the reconcile error), then prints the decision log
  9. dynamic per-path placement — --hetero-paths runs the engine on a
     2-path paced device with a 4:1 per-path rate split: under
     ``path_policy="static"`` the ``i % P`` stripe pays 2x the slow
     cap, under ``"backlog"`` chunk placement drains toward
     sum-of-caps (per-path achieved rates printed from the tracer);
     then the autotuner, fed the static run's LIVE per-path rates,
     prices both policies (``machine_for_path_policy``) and retunes
     ``path_policy`` static -> backlog
 10. the resilient I/O fabric — --chaos installs
     ``repro.io.chaos.ChaosFiles`` on a training engine and injects
     seeded transient faults into every chunk op: with
     ``IOConfig.integrity`` + bounded retries the run stays BITWISE
     identical to its fault-free twin; then a crash-consistent
     checkpoint (``save_checkpoint`` / ``restore_checkpoint``) round
     trips the whole optimizer state through disk into a FRESH engine
     and training resumes bitwise
"""
import argparse
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.schedules import ScheduleConfig, grads_fn, init_train_state
from repro.data import make_batch
from repro.optim import AdamConfig
from repro.train import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--wave", type=int, default=2, choices=[1, 2, 4],
                    help="wave size W for the offload-engine demo's M=4 "
                         "(W=1 horizontal ... W=4 vertical)")
    ap.add_argument("--activation-policy", default="recompute",
                    choices=["recompute", "spill", "auto"],
                    help="backward from recomputed activations (paper) "
                         "or from SSD-streamed vjp residuals (SSDTrain); "
                         "auto prices both with the perf model")
    ap.add_argument("--prefetch-depth", type=int, default=2,
                    help="cross-stream lookahead depth for the adaptive-"
                         "pipeline demo (0 = hints off; the engine "
                         "rejects negative or absurd depths)")
    ap.add_argument("--trace", default="", metavar="OUT.json",
                    help="run the observability demo: export a Chrome "
                         "trace-event JSON here and print the "
                         "plan-vs-actual reconciliation table")
    ap.add_argument("--autotune", action="store_true",
                    help="run the online-autotuner demo: script an SSD "
                         "slowdown into the live-rate feed and watch "
                         "the controller re-solve Algorithm 1 and "
                         "hot-swap the plan mid-training")
    ap.add_argument("--hetero-paths", action="store_true",
                    help="run the dynamic-placement demo: static vs "
                         "backlog chunk placement on a paced 4:1 "
                         "two-path device, then the autotuner's "
                         "path_policy retune off the live per-path "
                         "rates")
    ap.add_argument("--chaos", action="store_true",
                    help="run the resilience demo: transient chunk "
                         "faults absorbed bitwise by integrity+retry, "
                         "then a crash-consistent checkpoint restore "
                         "into a fresh engine")
    args = ap.parse_args()
    cfg = get_config("gpt-tiny")
    print(f"model: {cfg.name}  layers={cfg.num_layers} d={cfg.d_model} "
          f"params={cfg.total_params() / 1e6:.1f}M")

    # --- 1. the paper's identity: vertical grads == horizontal grads ---
    params, _ = init_train_state(cfg, jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in
             make_batch(cfg, 8, 64, seed=1).items()}
    lv, gv = grads_fn(cfg, ScheduleConfig(schedule="vertical"))(params, batch)
    lh, gh = grads_fn(cfg, ScheduleConfig(
        schedule="horizontal", num_microbatches=4))(params, batch)
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(gv), jax.tree.leaves(gh)))
    print(f"schedule equivalence: loss {float(lv):.4f} vs {float(lh):.4f}, "
          f"max grad diff {err:.2e}")

    # --- 2. train a few steps under each schedule ---
    for sched in ("vertical", "horizontal"):
        tr = Trainer(cfg, ScheduleConfig(schedule=sched, num_microbatches=4),
                     AdamConfig(lr=3e-3))
        rep = tr.run(steps=30, batch_size=8, seq_len=64, log_every=10)
        print(f"{sched:10s}: loss {rep.losses[0]:.3f} -> {rep.losses[-1]:.3f} "
              f"({rep.tokens_per_s:.0f} tok/s)")
        assert rep.losses[-1] < rep.losses[0], "loss must decrease"

    # --- 3. the wave knob on the real offload engine ------------------
    # One compiled plan per W; the measured byte counters show the
    # ckpt-traffic / param-reuse trade-off the §3 analysis predicts
    # (and repro.core.plan.plan_traffic predicts them exactly).
    from repro.core.perfmodel import StorageRatios
    from repro.offload import OffloadConfig, OffloadEngine
    M = 4

    def engine_step(W, policy, depth=1, alpha=0.0, steps=1):
        with tempfile.TemporaryDirectory() as d:
            eng = OffloadEngine(cfg, OffloadConfig(
                schedule="wave", wave_size=W, num_microbatches=M,
                micro_batch=1, seq_len=64, alpha=alpha,
                ratios=StorageRatios(0.0, 0.0, 0.0),
                activation_policy=policy, prefetch_depth=depth),
                jax.random.PRNGKey(0), d)
            tok = make_batch(cfg, M, 64, seed=2)["tokens"]
            loss = [eng.train_step(np.asarray(tok))
                    for _ in range(steps)][-1]
            eng.finish()
            b, pol = eng.meter.bytes, eng.act_policy
            look = eng.metrics_snapshot()["lookahead"]
            eng.close()
        return loss, b, pol, look

    print(f"\nwave knob (M={M}; --wave {args.wave}):")
    vertical_cell = None
    for W in sorted({1, args.wave, M}):
        loss, b, _, _ = engine_step(W, "recompute")
        if W == M:
            vertical_cell = (loss, b)    # reused by the policy demo
        param = b.get(("param", "cpu->gpu"), 0)
        reread = b.get(("ckpt", "cpu->gpu"), 0) \
            + b.get(("inter_grad", "cpu->gpu"), 0)
        name = {1: "horizontal", M: "vertical"}.get(W, "wave")
        print(f"  W={W} ({name:10s}): loss {loss:.3f}  "
              f"param {param / 1e6:6.1f} MB  ckpt+grad reads "
              f"{reread / 1e6:6.1f} MB")

    # --- 4. the activation-policy knob on the same engine -------------
    # "spill" trades backward recompute for an opportunistic SSD stream
    # of each layer's vjp residuals; the losses stay bitwise-identical
    # because both policies apply the same saved-residual backward.
    # The W=M recompute cell above IS the reference — no second run.
    print(f"\nactivation policy (vertical, M={M}; "
          f"--activation-policy {args.activation_policy}):")
    l_re, b_re = vertical_cell
    ckpt_rd_re = b_re.get(("ckpt", "ssd->cpu"), 0)
    print(f"  recompute           : loss {l_re:.6f}  act 0.0 MB  "
          f"ckpt ssd re-reads {ckpt_rd_re / 1e6:5.1f} MB")
    if args.activation_policy != "recompute":
        l_pol, b_pol, resolved, _ = engine_step(M, args.activation_policy)
        act = sum(v for (c, _), v in b_pol.items() if c == "act")
        ckpt_rd = b_pol.get(("ckpt", "ssd->cpu"), 0)
        print(f"  {args.activation_policy:8s}->{resolved:9s}: "
              f"loss {l_pol:.6f}  act {act / 1e6:.1f} MB  "
              f"ckpt ssd re-reads {ckpt_rd / 1e6:5.1f} MB")
        assert l_pol == l_re, "policies must agree bitwise"

    # --- 5. the cross-stream lookahead (adaptive prefetch pipeline) ---
    # PREFETCH / PREFETCH_CKPT / PREFETCH_OPT hints stream every SSD
    # read in `--prefetch-depth` fetches ahead of its consumer (and the
    # α-tail optimizer flush rides the plan epilogue, overlapping the
    # next iteration's first fetches); depth 0 turns all of it off.
    # Byte counters and losses are IDENTICAL — only when bytes move
    # changes, which the hit-rate / stall meters make visible.
    print(f"\ncross-stream lookahead (vertical, alpha=0.3; "
          f"--prefetch-depth {args.prefetch_depth}):")
    results = {}
    for depth in sorted({0, args.prefetch_depth}):
        loss, b, _, look = engine_step(M, "recompute", depth=depth,
                                       alpha=0.3, steps=2)
        results[depth] = (loss, b)
        print(f"  depth {depth}: loss {loss:.6f}  "
              f"hit rate {look['hit_rate']:.2f}  "
              f"stall {look['stall_s']:.3f} s  "
              f"hints skipped {look['hint_skips']}")
    l0, b0 = results[0]
    if args.prefetch_depth != 0:
        ld, bd = results[args.prefetch_depth]
        assert l0 == ld, "lookahead must not change the loss"
        assert b0 == bd, "lookahead must not change a single byte counter"

    # --- 6. span tracing + plan-vs-actual reconciliation --------------
    # trace=True turns the engine's always-compiled-in tracer on: plan
    # ops, per-chunk I/O (queue-wait vs transfer, per path), and the
    # hint lifecycle all land on one timeline. metrics_snapshot() is
    # the versioned flat contract; obs.reconcile joins it against the
    # plan's byte predictions — exactly.
    if args.trace:
        from repro.obs import reconcile
        print(f"\nobservability (vertical, alpha=0.3, traced; "
              f"--trace {args.trace}):")
        with tempfile.TemporaryDirectory() as d:
            eng = OffloadEngine(cfg, OffloadConfig(
                schedule="vertical", num_microbatches=M,
                micro_batch=1, seq_len=64, alpha=0.3,
                ratios=StorageRatios(0.0, 0.0, 0.0),
                prefetch_depth=args.prefetch_depth or 1, trace=True),
                jax.random.PRNGKey(0), d)
            tok = make_batch(cfg, M, 64, seed=2)["tokens"]
            for _ in range(2):
                eng.train_step(np.asarray(tok))
            eng.finish()
            snap = eng.metrics_snapshot()
            rec = reconcile(eng.plan, snap)
            path = eng.tracer.export_chrome(args.trace)
            eng.close()
        print(f"  {len(eng.tracer)} spans -> {path} "
              "(open in ui.perfetto.dev)")
        print(rec.format())
        assert rec.ok, "plan-vs-actual byte reconciliation must be exact"

    # --- 7. the online autotuner (measure -> re-solve -> swap) --------
    # The controller measures each window's live route rates from the
    # chunk spans, re-runs Algorithm 1 per candidate plan under that
    # machine, and hot-swaps the engine between iterations when the
    # predicted win clears hysteresis. The demo scripts a device
    # slowdown into the snapshot feed (a 1 MB/s SSD on a compute-bound
    # box — the scenario where the lookahead plan genuinely wins) so
    # the retune is deterministic; on real drifting hardware the same
    # loop runs off the unscripted `metrics_snapshot()`.
    if args.autotune:
        from repro.core.perfmodel import MachineParams
        from repro.offload import AutotuneConfig, AutotuneController
        print("\nonline autotuner (vertical, alpha=0.3, depth 0, "
              "scripted SSD drift; --autotune):")
        with tempfile.TemporaryDirectory() as d:
            eng = OffloadEngine(cfg, OffloadConfig(
                schedule="vertical", num_microbatches=M,
                micro_batch=1, seq_len=64, alpha=0.3,
                ratios=StorageRatios(0.0, 0.0, 0.0),
                prefetch_depth=0),
                jax.random.PRNGKey(0), d)
            real = eng.metrics_snapshot

            def drifted():
                snap = real()
                for r in snap["trace"]["routes"].values():
                    if r.get("bytes"):
                        r["busy_wall_s"] = r["bytes"] / 1e6
                        r["rate_bps"] = 1e6
                return snap

            eng.metrics_snapshot = drifted
            ctl = AutotuneController(eng, AutotuneConfig(
                interval=1, hysteresis=0.0, cooldown=1,
                prefetch_depths=(0, 2),
                machine=MachineParams(name="drift", gpu_flops=1e8,
                                      ssd_read_bw=1e6, ssd_write_bw=1e6,
                                      cpu_mem=2e7)))
            tok = make_batch(cfg, M, 64, seed=2)["tokens"]
            for _ in range(3):
                eng.train_step(np.asarray(tok))
                dec = ctl.post_step()        # interval=1: every step
                reason = dec.get("reason", "")
                print(f"  window {dec['window']}: {dec['action']:8s} "
                      f"{reason}")
            depth = eng.ocfg.resolved_prefetch_depth()
            print(f"  retunes {ctl.retunes}  prefetch depth 0 -> {depth}")
            assert ctl.retunes >= 1 and depth == 2, \
                "the drifted LP must pick the lookahead plan"
            eng.finish()
            eng.close()

    # --- 8. dynamic per-path placement on a heterogeneous device ------
    # Two SSD paths paced 4:1. Static striping alternates chunks
    # i % P, so every transfer waits on the slow path (throughput ->
    # 2x the slow cap); the "backlog" policy asks the engine's
    # idle-level signal per chunk and drains placement toward the fast
    # path (-> sum of caps). The tracer's per-path achieved rates make
    # the split visible, and the same rates drive the autotuner's
    # path_policy candidate axis.
    if args.hetero_paths:
        import time as _time
        from repro.io import IOConfig
        from repro.offload import AutotuneConfig, AutotuneController
        caps = (100e6, 25e6)
        print(f"\nheterogeneous paths (vertical, alpha=0.75, 2 paths "
              f"paced {caps[0] / 1e6:.0f}/{caps[1] / 1e6:.0f} MB/s; "
              "--hetero-paths):")

        def hetero_engine(d, policy):
            return OffloadEngine(cfg, OffloadConfig(
                schedule="vertical", num_microbatches=M,
                micro_batch=1, seq_len=64, alpha=0.75,
                ratios=StorageRatios(0.0, 0.0, 0.0),
                prefetch_depth=2, trace=True,
                io=IOConfig(paths=[f"{d}/p0", f"{d}/p1"],
                            chunk_bytes=256 << 10,
                            path_bandwidth=caps, path_policy=policy)),
                jax.random.PRNGKey(0), d)

        tok = np.asarray(make_batch(cfg, M, 64, seed=2)["tokens"])
        losses, rates = {}, {}
        for policy in ("static", "backlog"):
            with tempfile.TemporaryDirectory() as d:
                eng = hetero_engine(d, policy)
                eng.train_step(tok)              # warm-up (ssd cold)
                t0 = _time.perf_counter()
                losses[policy] = eng.train_step(tok)
                eng.finish()
                dt = _time.perf_counter() - t0
                pp = eng.metrics_snapshot()["trace"]["routes"][
                    "ssd->cpu"]["per_path"]
                eng.close()
            rates[policy] = dt
            split = "  ".join(
                f"path{p}: {pp[p]['rate_bps'] / 1e6:5.1f} MB/s "
                f"({pp[p]['bytes'] / 1e6:.0f} MB)"
                for p in sorted(pp, key=int))
            print(f"  {policy:8s}: {M * 64 / dt:6.0f} tok/s  "
                  f"ssd reads {split}")
        assert losses["static"] == losses["backlog"], \
            "placement must never change what the model computes"
        print(f"  backlog speedup {rates['static'] / rates['backlog']:.2f}x "
              "(placement is byte- and loss-neutral, only WHERE moves)")

        # the autotuner closes the same loop online: measure the static
        # run's per-path rates, price static (P x min) vs backlog
        # (sum of rates) through Algorithm 1, and actuate the flip.
        # The base machine pins cpu_mem below the model's footprint so
        # the LP must place state on the SSD tier (gpt-tiny would fit
        # in DRAM and the path rates would never enter the solve); the
        # measured per-path rates overlay it via machine_from_snapshot.
        # error_gate is relaxed: one cold window on a noisy 2-core
        # container shouldn't block the demo's retune.
        from repro.core.perfmodel import MachineParams
        with tempfile.TemporaryDirectory() as d:
            eng = hetero_engine(d, "static")
            ctl = AutotuneController(eng, AutotuneConfig(
                interval=1, hysteresis=0.0, cooldown=1, error_gate=2.0,
                path_policies=("static", "backlog"),
                machine=MachineParams(name="hetero", cpu_mem=2e7)))
            for _ in range(2):
                eng.train_step(tok)
                dec = ctl.post_step()
                print(f"  window {dec['window']}: {dec['action']:8s} "
                      f"{dec.get('changes', '')} {dec.get('reason', '')}")
                if dec["action"] == "retune":
                    break
            policy_now = eng.ioe.path_policy
            print(f"  path_policy static -> {policy_now}")
            assert policy_now == "backlog", \
                "the live per-path rates must price backlog as the win"
            eng.finish()
            eng.close()

    # --- 9. the resilient I/O fabric (--chaos) ------------------------
    # ChaosFiles sits at the pwrite/pread layer of the stripe backend
    # and injects seeded transient faults into REAL chunk ops. With
    # per-chunk CRC32C (IOConfig.integrity) and bounded in-place
    # retries the trajectory stays bitwise identical to a fault-free
    # twin; a crash-consistent checkpoint — written through the same
    # faulty device — then round trips the whole optimizer state into
    # a fresh engine and training resumes bitwise.
    if args.chaos:
        from repro.io import IOConfig
        from repro.io.chaos import ChaosSpec, install_chaos
        print("\nresilient I/O (vertical, M=4, 5% transient fault "
              "rate; --chaos):")

        def resilient_engine(d):
            return OffloadEngine(cfg, OffloadConfig(
                schedule="vertical", num_microbatches=M,
                micro_batch=1, seq_len=64,
                ratios=StorageRatios(0.0, 0.0, 0.0),
                io=IOConfig(retries=5, integrity=True)),
                jax.random.PRNGKey(0), d)

        tok = np.asarray(make_batch(cfg, M, 64, seed=2)["tokens"])
        with tempfile.TemporaryDirectory() as d_cl, \
                tempfile.TemporaryDirectory() as d_ch, \
                tempfile.TemporaryDirectory() as d_new, \
                tempfile.TemporaryDirectory() as d_ck:
            e_cl, e_ch = resilient_engine(d_cl), resilient_engine(d_ch)
            chaos = install_chaos(e_ch.ssd, ChaosSpec(
                error_rate=0.05, latency_rate=0.05, latency_s=0.0005,
                seed=11))
            for _ in range(2):
                l_cl, l_ch = e_cl.train_step(tok), e_ch.train_step(tok)
                assert l_cl == l_ch, \
                    "absorbed faults must be invisible to the math"
            snap = e_ch.ioe.metrics_snapshot()
            print(f"  2 steps under chaos: loss {l_ch:.6f} == clean "
                  f"twin ({sum(chaos.injected.values())} faults "
                  f"injected, {snap['chunk_retries']} chunk retries)")

            # checkpoint through the faulty device, continue one step
            # on the original engine to pin the reference trajectory,
            # then restore into a FRESH engine and catch up.
            e_ch.save_checkpoint(d_ck)
            l_next = e_ch.train_step(tok)
            for e in (e_cl, e_ch):
                e.finish()
                e.close()
            e_new = resilient_engine(d_new)
            step0 = e_new.restore_checkpoint(d_ck)
            l_resume = e_new.train_step(tok)
            print(f"  checkpoint @ step {step0} -> fresh engine: "
                  f"resumed loss {l_resume:.6f} "
                  f"{'==' if l_resume == l_next else '!='} continued "
                  "trajectory")
            assert l_resume == l_next, \
                "restore must continue the trajectory bitwise"
            e_new.finish()
            e_new.close()
    print("OK")


if __name__ == "__main__":
    sys.exit(main())
