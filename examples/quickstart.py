"""Quickstart: train a small GPT with GreedySnake's vertical schedule.

    PYTHONPATH=src python examples/quickstart.py

Shows the three core public APIs:
  1. configs      — pick an architecture (any of the 10 assigned archs
                    works via get_smoke)
  2. ScheduleConfig / Trainer — vertical vs horizontal schedules
  3. the schedule-equivalence identity — both produce the same gradients
"""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.schedules import ScheduleConfig, grads_fn, init_train_state
from repro.data import make_batch
from repro.optim import AdamConfig
from repro.train import Trainer


def main() -> None:
    cfg = get_config("gpt-tiny")
    print(f"model: {cfg.name}  layers={cfg.num_layers} d={cfg.d_model} "
          f"params={cfg.total_params() / 1e6:.1f}M")

    # --- 1. the paper's identity: vertical grads == horizontal grads ---
    params, _ = init_train_state(cfg, jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in
             make_batch(cfg, 8, 64, seed=1).items()}
    lv, gv = grads_fn(cfg, ScheduleConfig(schedule="vertical"))(params, batch)
    lh, gh = grads_fn(cfg, ScheduleConfig(
        schedule="horizontal", num_microbatches=4))(params, batch)
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(gv), jax.tree.leaves(gh)))
    print(f"schedule equivalence: loss {float(lv):.4f} vs {float(lh):.4f}, "
          f"max grad diff {err:.2e}")

    # --- 2. train a few steps under each schedule ---
    for sched in ("vertical", "horizontal"):
        tr = Trainer(cfg, ScheduleConfig(schedule=sched, num_microbatches=4),
                     AdamConfig(lr=3e-3))
        rep = tr.run(steps=30, batch_size=8, seq_len=64, log_every=10)
        print(f"{sched:10s}: loss {rep.losses[0]:.3f} -> {rep.losses[-1]:.3f} "
              f"({rep.tokens_per_s:.0f} tok/s)")
        assert rep.losses[-1] < rep.losses[0], "loss must decrease"
    print("OK")


if __name__ == "__main__":
    sys.exit(main())
