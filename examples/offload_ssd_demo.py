"""SSD-offloaded training with the REAL three-tier engine: parameters
and optimizer states live in files ("SSD") and host buffers ("CPU"),
moved layer-by-layer through the vertical pipeline with overlapped
CPU-Adam — the runnable counterpart of the paper's system.

    PYTHONPATH=src python examples/offload_ssd_demo.py [--schedule vertical]
        [--io-paths 2] [--cap-ssd-mbs 500]

Every byte flows through the `repro.io` engine: pass ``--io-paths N`` to
stripe the SSD tier across N directories (MLP-Offload-style multi-path)
and ``--cap-ssd-mbs`` to pace the SSD link with the token-bucket
simulator, turning the perf model's rooflines into wall-clock effects.

Prints per-iteration loss, the measured traffic by (category, route) —
which matches the paper's closed-form §3.4 predictions — the I/O-engine
scheduling stats, and the phase wall-times showing optimizer overlap.
"""
import argparse
import os
import tempfile
import time

import jax

from repro.configs import get_config
from repro.core.perfmodel import StorageRatios
from repro.core.traffic import horizontal_traffic, vertical_traffic
from repro.offload import IOConfig, OffloadConfig, OffloadEngine
from repro.data import SyntheticLM


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--schedule", default="vertical",
                    choices=["vertical", "horizontal"])
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--micro-batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--alpha", type=float, default=0.25)
    ap.add_argument("--io-paths", type=int, default=1,
                    help="stripe the SSD tier across this many directories")
    ap.add_argument("--chunk-kb", type=int, default=1024)
    ap.add_argument("--cap-ssd-mbs", type=float, default=0.0,
                    help="simulate an SSD bandwidth cap (MB/s, 0 = off)")
    args = ap.parse_args()

    cfg = get_config("gpt-tiny")
    M, mb = args.microbatches, args.micro_batch
    with tempfile.TemporaryDirectory(prefix="greedysnake_ssd_") as ssd:
        paths = [os.path.join(ssd, f"nvme{i}") for i in range(args.io_paths)]
        bandwidth = {}
        if args.cap_ssd_mbs > 0:
            bandwidth = {"cpu->ssd": args.cap_ssd_mbs * 1e6,
                         "ssd->cpu": args.cap_ssd_mbs * 1e6}
        iocfg = IOConfig(paths=paths, chunk_bytes=args.chunk_kb << 10,
                         bandwidth=bandwidth)
        print(f"SSD tier: {args.io_paths} path(s) under {ssd}"
              + (f", capped at {args.cap_ssd_mbs:.0f} MB/s" if bandwidth
                 else ""))
        eng = OffloadEngine(cfg, OffloadConfig(
            schedule=args.schedule, num_microbatches=M, micro_batch=mb,
            seq_len=args.seq, alpha=args.alpha if args.schedule == "vertical"
            else 0.0, lr=3e-3,
            ratios=StorageRatios(ckpt=0.5, param=0.5, opt=0.0),
            io=iocfg), jax.random.PRNGKey(0), ssd)
        data = SyntheticLM(cfg.vocab_size, seed=0)
        eng.meter.reset()
        t0 = time.perf_counter()
        for i in range(args.steps):
            loss = eng.train_step(data.batch(M * mb, args.seq))
            print(f"step {i + 1:3d}  loss {loss:8.4f}")
        eng.finish()
        dt = time.perf_counter() - t0

        print(f"\n{args.steps} steps, {dt / args.steps:.2f} s/step, "
              f"schedule={args.schedule}, alpha={args.alpha}")
        print("\nmeasured traffic (GB per category:route):")
        for key, v in sorted(eng.meter.snapshot().items()):
            print(f"  {key:20s} {v / 1e9:8.3f}")
        ms = eng.L * eng.P * 4
        cs = cfg.num_layers * mb * args.seq * cfg.d_model * 4
        pred = (vertical_traffic if args.schedule == "vertical"
                else horizontal_traffic)(ms, cs, M)
        print(f"\npaper closed form (params+grads, per step): "
              f"load {pred.param_load / 1e9:.3f} GB + "
              f"grad {pred.grad_swap / 1e9:.3f} GB")
        st = eng.metrics_snapshot()
        io = st["io"][0]                  # per-rank list; single rank here
        print(f"\nio engine: {io['submitted']} requests "
              f"({io['cancelled']} cancelled), {io['chunk_ops']} chunk ops "
              f"over {io['num_paths']} path(s), "
              f"peak in-flight {io['max_inflight_bytes'] / 1e6:.1f} MB")
        print("  bytes by priority:",
              {k: f"{v / 1e9:.3f} GB"
               for k, v in io["bytes_by_priority"].items() if v})
        print(f"host residency peak: "
              f"{st['host_peak_nbytes'][0] / 1e6:.1f} MB")
        print("phase seconds:",
              {k: round(v, 2) for k, v in eng.phase_time.items()})
        eng.close()
    print("OK")


if __name__ == "__main__":
    main()
