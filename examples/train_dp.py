"""Data-parallel SSD-offloaded training: R rank workers × R SSD path
sets, ZeRO-style sharded optimizer state, deterministic collectives.

    PYTHONPATH=src python examples/train_dp.py [--ranks 2] [--steps 6]
        [--paths-per-rank 1] [--cap-ssd-mbs 0] [--verify-single-rank]

Each rank owns a contiguous 1/R element range of every tiered vector
(low-precision params, master, momentum, variance) on its OWN I/O
engine + SSD directory set, all-gathers params per layer boundary and
reduce-scatters layer gradients — see `repro.offload.dp`. With
``--verify-single-rank`` the same seed/batches are replayed on the
single-rank engine and the per-step losses are compared bit-for-bit
(they must be identical in f32, §6.5 extended across the DP axis).

Prints per-step loss, each rank's traffic by (category, route) —
validated against `repro.core.traffic.dp_vertical_traffic` in the test
suite — and the aggregate interconnect volume.
"""
import argparse
import os
import tempfile
import time

import jax

from repro.configs import get_config
from repro.core.perfmodel import StorageRatios
from repro.core.traffic import dp_vertical_traffic
from repro.data import SyntheticLM
from repro.offload import (DataParallelOffloadEngine, IOConfig,
                           OffloadConfig, OffloadEngine)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ranks", type=int, default=2)
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--micro-batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--alpha", type=float, default=0.25)
    ap.add_argument("--paths-per-rank", type=int, default=1)
    ap.add_argument("--cap-ssd-mbs", type=float, default=0.0)
    ap.add_argument("--verify-single-rank", action="store_true")
    args = ap.parse_args()

    cfg = get_config("gpt-tiny")
    M, mb, R = args.microbatches, args.micro_batch, args.ranks
    ocfg_kw = dict(schedule="vertical", num_microbatches=M, micro_batch=mb,
                   seq_len=args.seq, alpha=args.alpha, lr=3e-3,
                   ratios=StorageRatios(ckpt=0.5, param=0.5, opt=0.0))
    bandwidth = {}
    if args.cap_ssd_mbs > 0:
        bandwidth = {"cpu->ssd": args.cap_ssd_mbs * 1e6,
                     "ssd->cpu": args.cap_ssd_mbs * 1e6}

    with tempfile.TemporaryDirectory(prefix="greedysnake_dp_") as root:
        paths = [os.path.join(root, f"nvme{i}")
                 for i in range(R * args.paths_per_rank)]
        eng = DataParallelOffloadEngine(
            cfg, OffloadConfig(io=IOConfig(paths=paths, bandwidth=bandwidth),
                               **ocfg_kw),
            jax.random.PRNGKey(0), root, ranks=R)
        print(f"{R} ranks × {args.paths_per_rank} path(s) each; "
              f"shard bounds {eng.bounds} of P={eng.P} per layer")
        data = SyntheticLM(cfg.vocab_size, seed=0)
        t0 = time.perf_counter()
        losses = []
        for i in range(args.steps):
            loss = eng.train_step(data.batch(M * mb, args.seq))
            losses.append(loss)
            print(f"step {i + 1:3d}  loss {loss:8.4f}")
        eng.finish()
        dt = time.perf_counter() - t0
        print(f"\n{args.steps} steps, {dt / args.steps:.2f} s/step, "
              f"R={R}, alpha={args.alpha}")

        ms = eng.L * eng.P * 4
        cs = cfg.num_layers * mb * args.seq * cfg.d_model * 4
        t = dp_vertical_traffic(ms, cs, M, R, grad_bytes=ms,
                                os_bytes=3 * ms, n_layers=eng.L)
        print(f"closed form per rank/step: param fetch "
              f"{t.param_fetch / 1e9:.3f} GB (2·ms/R), all-gather "
              f"{t.param_allgather / 1e9:.3f} GB, reduce-scatter "
              f"{t.grad_reducescatter / 1e9:.3f} GB")
        for r, snap in enumerate(eng.traffic()):
            print(f"\nrank {r} traffic (GB per category:route):")
            for key, v in sorted(snap.items()):
                if v:
                    print(f"  {key:22s} {v / 1e9:8.3f}")
        agg_ic = sum(v for snap in eng.traffic()
                     for k, v in snap.items() if "net" in k)
        print(f"\naggregate interconnect volume: {agg_ic / 1e9:.3f} GB")
        eng.close()

        if args.verify_single_rank:
            print("\nreplaying on the single-rank engine ...")
            with tempfile.TemporaryDirectory() as d1:
                ref = OffloadEngine(cfg, OffloadConfig(**ocfg_kw),
                                    jax.random.PRNGKey(0), d1)
                data = SyntheticLM(cfg.vocab_size, seed=0)
                ref_losses = [ref.train_step(data.batch(M * mb, args.seq))
                              for _ in range(args.steps)]
                ref.finish()
                ref.close()
            match = losses == ref_losses
            print("bit-identical loss trajectory:", match)
            if not match:
                for i, (a, b) in enumerate(zip(losses, ref_losses)):
                    if a != b:
                        print(f"  step {i + 1}: dp={a!r} single={b!r}")
                raise SystemExit(1)
    print("OK")


if __name__ == "__main__":
    main()
