"""Dynamic chunk-placement battery: the per-tensor chunk-location
table, its persisted sidecar, and the policy axis end-to-end.

* The static policy is a LAYOUT CONSTANT: stripe files bit-for-bit
  identical to the hand-computed ``chunk i -> path i % P`` layout, and
  zero ``.map.json`` sidecars on disk.
* Dynamic placement round-trips: full/partial/short-last-chunk writes
  under "weighted"/"backlog" read back exactly, survive a reopen
  through a FRESH engine (the sidecar is the only carrier), and a
  tensor written static stays readable after a policy flip (and vice
  versa).
* ``IOConfig.shard_for_rank`` slices ``path_bandwidth`` caps along
  with their paths, so a DP rank's placement weights exactly the
  devices it drives.
* Policy neutrality on the REAL engine across the schedule × M × α × R
  acceptance grid: static vs backlog give bitwise-identical losses and
  parameters and byte-identical per-(category, route) traffic —
  placement moves bytes between PATHS only, never between routes.
* Per-path conservation: on a traced 2-path run the per-path chunk
  meters sum exactly to the route totals (``obs.reconcile``'s check),
  and a tampered snapshot is flagged.
* ``machine_for_path_policy`` prices heterogeneous paths (P × min
  under static, sum under backlog) and ``machine_from_snapshot``
  ingests the per-path achieved rates that feed it.
* ``IOEngine.choose_path`` honours rate weights and drains placement
  away from a path with consecutive failures.
"""
import json
import os
import tempfile

import jax
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core.lp_search import solve_config
from repro.core.perfmodel import (MachineParams, StorageRatios,
                                  machine_for_path_policy,
                                  machine_from_snapshot)
from repro.data import SyntheticLM
from repro.io import IOConfig, IOEngine, IOPriority, StripedFiles
from repro.io.engine import PATH_FAIL_DRAIN_THRESHOLD
from repro.obs import reconcile
from repro.offload import (DataParallelOffloadEngine, OffloadConfig,
                           OffloadEngine)

CHUNK = 1000        # odd size: exercises chunk-boundary arithmetic


def _engine(tmp, n_paths=2, **kw):
    paths = [os.path.join(tmp, f"p{i}") for i in range(n_paths)]
    kw.setdefault("chunk_bytes", CHUNK)
    return IOEngine(IOConfig(paths=paths, **kw))


def _payload(nbytes, seed=0):
    return np.random.default_rng(seed).integers(
        0, 255, nbytes, dtype=np.uint8)


def _sidecars(eng):
    return [f for p in eng.paths for f in os.listdir(p)
            if f.endswith(".map.json")]


# ---------------------------------------------------------------------------
# the static layout pin: bit-for-bit i % P, zero placement state
# ---------------------------------------------------------------------------

def test_static_layout_bit_for_bit_and_sidecar_free():
    """Under path_policy="static" the stripe files must equal the
    hand-computed round-robin layout byte for byte — chunk c at slot
    c // P of path c % P — and no sidecar may ever be written."""
    P = 3
    data = _payload(10 * CHUNK + 500)           # 10 full chunks + short
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(d, n_paths=P)
        sf = StripedFiles(eng)
        sf.write("t", data, 0, IOPriority.CKPT_SPILL)
        sf.close()
        eng.shutdown()
        for p in range(P):
            # chunks p, p+P, ... at consecutive slots; all full except
            # a trailing short chunk, so the file is their plain concat
            expected = b"".join(bytes(data[c * CHUNK:(c + 1) * CHUNK])
                                for c in range(p, 11, P))
            with open(os.path.join(eng.paths[p], f"t.s{p}.bin"),
                      "rb") as f:
                assert f.read() == expected, f"path {p}"
        assert _sidecars(eng) == []


def test_static_reproduces_same_bytes_as_before_policy_existed():
    """Two static engines (one default-constructed, one explicit) must
    produce identical stripe files — the policy knob's default changes
    nothing."""
    data = _payload(7 * CHUNK + 123, seed=3)
    blobs = {}
    for tag, kw in (("default", {}), ("explicit", {"path_policy":
                                                   "static"})):
        with tempfile.TemporaryDirectory() as d:
            eng = _engine(d, n_paths=2, **kw)
            sf = StripedFiles(eng)
            sf.write("t", data, 0, IOPriority.CKPT_SPILL)
            sf.close()
            eng.shutdown()
            blobs[tag] = [open(os.path.join(p, "t.s%d.bin" % i),
                               "rb").read()
                          for i, p in enumerate(eng.paths)]
    assert blobs["default"] == blobs["explicit"]


# ---------------------------------------------------------------------------
# dynamic round-trips: table, sidecar, reopen, short last chunk
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["weighted", "backlog"])
def test_dynamic_roundtrip_and_reopen(policy):
    """Write under a dynamic policy, read back; then reopen the same
    paths through a FRESH engine + StripedFiles (placement travels only
    through the sidecar) and read again."""
    data = _payload(9 * CHUNK + 321, seed=1)
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(d, n_paths=3, path_policy=policy,
                      path_bandwidth=(4e9, 1e9, 1e9))
        sf = StripedFiles(eng)
        sf.write("t", data, 0, IOPriority.CKPT_SPILL)
        out = np.empty_like(data)
        sf.readinto("t", out, 0, IOPriority.PARAM_FETCH)
        np.testing.assert_array_equal(out, data)
        assert _sidecars(eng) == ["t.map.json"]  # on paths[0] only
        # ranged partial update sticks to the recorded placement
        patch = _payload(2 * CHUNK, seed=2)
        sf.write("t", patch, 777, IOPriority.CKPT_SPILL)
        ref = data.copy()
        ref[777:777 + patch.nbytes] = patch
        sf.readinto("t", out, 0, IOPriority.PARAM_FETCH)
        np.testing.assert_array_equal(out, ref)
        sf.close()
        eng.shutdown()

        eng2 = _engine(d, n_paths=3, path_policy=policy,
                       path_bandwidth=(4e9, 1e9, 1e9))
        sf2 = StripedFiles(eng2)
        out2 = np.empty_like(ref)
        sf2.readinto("t", out2, 0, IOPriority.PARAM_FETCH)
        np.testing.assert_array_equal(out2, ref)
        # delete removes stripes AND the sidecar
        sf2.delete("t")
        assert _sidecars(eng2) == []
        sf2.close()
        eng2.shutdown()


def test_short_last_chunk_stays_sticky():
    """The short last chunk is never re-placed (a move would need a
    read-modify-write): under backlog it stays on its static path, and
    overwriting just that tail keeps the table unchanged."""
    n_full = 6
    data = _payload(n_full * CHUNK + 77, seed=4)
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(d, n_paths=2, path_policy="backlog")
        sf = StripedFiles(eng)
        sf.write("t", data, 0, IOPriority.CKPT_SPILL)
        p, slot = sf.placement("t", n_full)      # the short chunk
        tail = _payload(77, seed=5)
        sf.write("t", tail, n_full * CHUNK, IOPriority.CKPT_SPILL)
        assert sf.placement("t", n_full) == (p, slot)   # never re-placed
        out = np.empty_like(data)
        sf.readinto("t", out, 0, IOPriority.PARAM_FETCH)
        ref = data.copy()
        ref[n_full * CHUNK:] = tail
        np.testing.assert_array_equal(out, ref)
        sf.close()
        eng.shutdown()


def test_policy_flip_cross_readability():
    """A tensor written static stays readable after flipping the live
    engine to backlog — and chunks rewritten after the flip move while
    the rest keep their static placement."""
    data = _payload(8 * CHUNK, seed=6)
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(d, n_paths=2)              # starts static
        sf = StripedFiles(eng)
        sf.write("t", data, 0, IOPriority.CKPT_SPILL)
        assert _sidecars(eng) == []
        eng.set_path_policy("backlog")
        patch = _payload(3 * CHUNK, seed=7)
        sf.write("t", patch, 2 * CHUNK, IOPriority.CKPT_SPILL)
        ref = data.copy()
        ref[2 * CHUNK:5 * CHUNK] = patch
        out = np.empty_like(ref)
        sf.readinto("t", out, 0, IOPriority.PARAM_FETCH)
        np.testing.assert_array_equal(out, ref)
        # untouched chunks still on their static default
        assert sf.placement("t", 0) == (0, 0)
        assert sf.placement("t", 7) == (1, 3)
        sf.close()
        eng.shutdown()


def test_stale_sidecar_rejected_on_reopen():
    """Reopening a dynamically-placed tensor with a different chunk
    size (or path count) must fail loudly, not read garbage."""
    data = _payload(5 * CHUNK, seed=8)
    with tempfile.TemporaryDirectory() as d:
        # 4:1 weights guarantee at least one chunk leaves its static
        # path, so the sidecar definitely exists to go stale
        eng = _engine(d, n_paths=2, path_policy="backlog",
                      path_bandwidth=(4e9, 1e9))
        sf = StripedFiles(eng)
        sf.write("t", data, 0, IOPriority.CKPT_SPILL)
        assert _sidecars(eng) == ["t.map.json"]
        sf.close()
        eng.shutdown()
        eng2 = _engine(d, n_paths=2, chunk_bytes=CHUNK * 2,
                       path_policy="backlog")
        sf2 = StripedFiles(eng2)
        out = np.empty_like(data)
        with pytest.raises(ValueError, match="stale chunk map"):
            sf2.readinto("t", out, 0, IOPriority.PARAM_FETCH)
        sf2.close()
        eng2.shutdown()


# ---------------------------------------------------------------------------
# DP path sharding carries the caps
# ---------------------------------------------------------------------------

def test_shard_for_rank_slices_caps_with_paths():
    cfg = IOConfig(paths=["/a", "/b", "/c", "/d"],
                   path_bandwidth=(4e9, 1e9, 2e9, 3e9),
                   path_policy="backlog")
    r0 = cfg.shard_for_rank(0, 2)
    r1 = cfg.shard_for_rank(1, 2)
    assert list(r0.paths) == ["/a", "/c"]
    assert r0.path_bandwidth == (4e9, 2e9)
    assert list(r1.paths) == ["/b", "/d"]
    assert r1.path_bandwidth == (1e9, 3e9)
    assert r0.path_policy == r1.path_policy == "backlog"
    # more ranks than paths: the shared device's cap follows the subdir
    r5 = cfg.shard_for_rank(5, 6)
    assert list(r5.paths) == [os.path.join("/b", "rank5")]
    assert r5.path_bandwidth == (1e9,)
    # no caps configured: sharding never invents any
    assert IOConfig(paths=["/a", "/b"]).shard_for_rank(0, 2) \
        .path_bandwidth is None


def test_config_validates_policy_and_caps():
    with pytest.raises(ValueError, match="path_policy"):
        IOConfig(path_policy="roundest-robin")
    with pytest.raises(ValueError, match="> 0"):
        IOConfig(paths=["/a"], path_bandwidth=(0.0,))
    with pytest.raises(ValueError, match="cap"):
        IOConfig(paths=["/a", "/b"], path_bandwidth=(1e9,))


# ---------------------------------------------------------------------------
# choose_path: weights + fault drain
# ---------------------------------------------------------------------------

def test_choose_path_weighted_ratio():
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(d, n_paths=2, path_policy="weighted",
                      path_bandwidth=(3e9, 1e9))
        picks = [eng.choose_path(1000) for _ in range(400)]
        counts = [picks.count(0), picks.count(1)]
        assert counts[0] == 300 and counts[1] == 100  # exact 3:1 argmin
        eng.shutdown()


def test_choose_path_drains_failed_path():
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(d, n_paths=2, path_policy="backlog")
        eng._path_failures[0] = PATH_FAIL_DRAIN_THRESHOLD
        assert all(eng.choose_path(100) == 1 for _ in range(20))
        # every path down: fall back to all (fail loudly downstream
        # rather than deadlocking placement)
        eng._path_failures[1] = PATH_FAIL_DRAIN_THRESHOLD
        assert set(eng.choose_path(100) for _ in range(10)) <= {0, 1}
        eng.shutdown()


# ---------------------------------------------------------------------------
# heterogeneous pricing + live-rate ingestion
# ---------------------------------------------------------------------------

def test_machine_for_path_policy_pricing():
    m = MachineParams(name="het", ssd_path_read_bw=(200e6, 50e6),
                      ssd_path_write_bw=(100e6, 25e6))
    st = machine_for_path_policy(m, "static")
    assert st.ssd_read_bw == pytest.approx(2 * 50e6)
    assert st.ssd_write_bw == pytest.approx(2 * 25e6)
    for pol in ("weighted", "backlog"):
        dy = machine_for_path_policy(m, pol)
        assert dy.ssd_read_bw == pytest.approx(250e6)
        assert dy.ssd_write_bw == pytest.approx(125e6)
    # no per-path evidence: the machine passes through unchanged
    plain = MachineParams(name="plain")
    assert machine_for_path_policy(plain, "backlog") is plain


def test_machine_from_snapshot_ingests_per_path_rates():
    snap = {"trace": {"routes": {
        "ssd->cpu": {"bytes": 300, "busy_s": 2.0, "rate_bps": 150.0,
                     "per_path": {"0": {"bytes": 200, "busy_s": 1.0,
                                        "rate_bps": 200.0},
                                  "1": {"bytes": 100, "busy_s": 1.0,
                                        "rate_bps": 100.0}}},
        "cpu->ssd": {"bytes": 80, "busy_s": 1.0, "rate_bps": 80.0,
                     "per_path": {"0": {"bytes": 80, "busy_s": 1.0,
                                        "rate_bps": 80.0}}},
    }}}
    m = machine_from_snapshot(snap, MachineParams())
    assert m.ssd_path_read_bw == pytest.approx((200.0, 100.0))
    assert m.ssd_path_write_bw == pytest.approx((80.0,))
    # and the LP prices the split policy-dependently from here
    assert machine_for_path_policy(m, "static").ssd_read_bw == \
        pytest.approx(200.0)
    assert machine_for_path_policy(m, "backlog").ssd_read_bw == \
        pytest.approx(300.0)


def test_solve_config_path_policy_pricing_and_tag():
    import dataclasses

    from repro.configs import get_config
    from repro.core.perfmodel import Workload
    m = dataclasses.replace(MachineParams(),
                            ssd_path_read_bw=(4.8e9, 1.2e9),
                            ssd_path_write_bw=(2.4e9, 0.6e9))
    w = Workload.from_config(get_config("gpt-65b"), micro_batch=2,
                             seq_len=2048)
    st = solve_config(m, w, 8, 0.2, path_policy="static")
    bl = solve_config(m, w, 8, 0.2, path_policy="backlog")
    assert st is not None and bl is not None
    assert st.path_policy == "static"
    assert bl.path_policy == "backlog"
    # backlog prices the device at sum-of-rates (6/3 GB/s) vs static's
    # P x min (2.4/1.2 GB/s): never a slower predicted iteration
    assert bl.iteration_time <= st.iteration_time
    with pytest.raises(ValueError, match="path_policy"):
        solve_config(m, w, 8, 0.2, path_policy="fastest")


# ---------------------------------------------------------------------------
# the acceptance grid: policy neutrality on the real engine
# ---------------------------------------------------------------------------

CFG = ArchConfig(name="pp-tiny", family="dense", source="test",
                 num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
                 head_dim=16, d_ff=64, vocab_size=256, act="gelu")
MB, S = 1, 16
X0 = StorageRatios(0.0, 0.0, 0.0)

#: schedule × M × α × R (wave needs M % 2 == 0, DP plans are vertical
#: with M % R == 0) — the same filters as the obs/lookahead batteries
GRID = [(sched, M, alpha, R)
        for sched in ("vertical", "horizontal", "wave")
        for M in (2, 4)
        for alpha in (0.0, 0.5)
        for R in (1, 2)
        if not (sched == "wave" and M % 2)
        and not (R > 1 and (sched != "vertical" or M % R))]


def _run(sched, M, alpha, R, policy, steps=2):
    """One run over a 4-path striped workdir; returns (losses,
    per-rank route bytes, params, sidecar count)."""
    W = {"vertical": 0, "horizontal": 0, "wave": 2}[sched]
    with tempfile.TemporaryDirectory() as d:
        io = IOConfig(paths=[os.path.join(d, f"p{i}") for i in range(4)],
                      chunk_bytes=1 << 10, path_policy=policy,
                      path_bandwidth=(4e9, 1e9, 2e9, 3e9))
        ocfg = OffloadConfig(schedule=sched, num_microbatches=M,
                             micro_batch=MB, seq_len=S, alpha=alpha,
                             wave_size=W, ratios=X0, io=io,
                             prefetch_depth=1)
        if R > 1:
            eng = DataParallelOffloadEngine(CFG, ocfg,
                                            jax.random.PRNGKey(11),
                                            d, ranks=R)
        else:
            eng = OffloadEngine(CFG, ocfg, jax.random.PRNGKey(11), d)
        data = SyntheticLM(CFG.vocab_size, seed=0)
        losses = [eng.train_step(data.batch(M * MB, S))
                  for _ in range(steps)]
        eng.finish()
        if R > 1:
            routes = [dict(rk.meter.bytes) for rk in eng.ranks]
            params = [eng.read_params(l).copy() for l in range(eng.L)]
            n_maps = sum(len(_sidecars(rk.ioe)) for rk in eng.ranks)
        else:
            routes = [dict(eng.meter.bytes)]
            params = [eng.p_vecs[l].read().copy() for l in range(eng.L)]
            n_maps = len(_sidecars(eng.ioe))
        eng.close()
    return losses, routes, params, n_maps


@pytest.mark.parametrize("sched,M,alpha,R", GRID)
def test_policy_neutral_losses_params_and_route_bytes(sched, M, alpha, R):
    """Static vs backlog placement: identical losses, bitwise-identical
    parameters, byte-identical per-(category, route) traffic — and the
    static run leaves zero sidecars while the backlog run places."""
    l_st, r_st, p_st, maps_st = _run(sched, M, alpha, R, "static")
    l_bl, r_bl, p_bl, maps_bl = _run(sched, M, alpha, R, "backlog")
    assert l_st == l_bl
    assert r_st == r_bl
    for a, b in zip(p_st, p_bl):
        assert np.array_equal(a, b)             # bitwise
    assert maps_st == 0
    assert maps_bl > 0


# ---------------------------------------------------------------------------
# per-path conservation through obs.reconcile
# ---------------------------------------------------------------------------

def test_per_path_meters_sum_to_route_totals():
    """A traced 2-path backlog run reconciles byte-exactly, including
    the per-path conservation check; tampering with one per-path meter
    is flagged and flips ``.ok``."""
    with tempfile.TemporaryDirectory() as d:
        io = IOConfig(paths=[os.path.join(d, "p0"), os.path.join(d, "p1")],
                      chunk_bytes=1 << 10, path_policy="backlog")
        eng = OffloadEngine(CFG, OffloadConfig(
            schedule="vertical", num_microbatches=2, micro_batch=MB,
            seq_len=S, alpha=0.5, ratios=X0, io=io, prefetch_depth=1,
            trace=True), jax.random.PRNGKey(11), d)
        data = SyntheticLM(CFG.vocab_size, seed=0)
        for _ in range(2):
            eng.train_step(data.batch(2 * MB, S))
        eng.finish()
        snap = eng.metrics_snapshot()
        plan = eng.plan
        eng.close()
    rec = reconcile(plan, snap)
    assert rec.path_sum_mismatches == []
    assert rec.ok, rec.format()
    # every traced route's per-path split is non-trivial and sums back
    for route, dd in snap["trace"]["routes"].items():
        pp = dd.get("per_path") or {}
        if pp:
            assert sum(v["bytes"] for v in pp.values()) == dd["bytes"]
    # tamper: steal bytes from one path's meter
    snap2 = json.loads(json.dumps(snap))
    for dd in snap2["trace"]["routes"].values():
        if dd.get("per_path"):
            next(iter(dd["per_path"].values()))["bytes"] += 1
            break
    rec2 = reconcile(plan, snap2)
    assert rec2.path_sum_mismatches
    assert not rec2.ok
    assert "per-path conservation VIOLATED" in rec2.format()
