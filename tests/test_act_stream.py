"""Activation-stream battery (the SSDTrain-style spill policy).

The acceptance sweep: the three-way exact cross-check — ``plan_traffic``
== ``traffic.act_spill_traffic`` / ``wave_ckpt_traffic(act_spill=True)``
closed forms == measured engine counters — over vertical / horizontal /
wave × M ∈ {1, 2, 4} × policy ∈ {recompute, spill} × R ∈ {1, 2}, with
spill runs pinned BITWISE-identical (f32) to recompute runs in losses
and parameters. Plus: compiler/lookahead units for the new ops, the
``IOPriority.ACT`` class, the ``ActivationCoordinator`` round-trip, the
"auto" policy resolution, and the ``lp_search`` policy row.
"""
import tempfile

import jax
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core.lp_search import solve_config
from repro.core.perfmodel import (MachineParams, StorageRatios, Workload,
                                  iteration_time_vertical,
                                  pick_activation_policy)
from repro.core.plan import (Op, PlanCosts, PlanSpec, compile_wave,
                             insert_prefetch, plan_traffic)
from repro.core.traffic import (act_spill_traffic, dp_vertical_traffic,
                                wave_ckpt_traffic)
from repro.data import SyntheticLM
from repro.io import CATEGORY_PRIORITY, IOPriority
from repro.offload import (DataParallelOffloadEngine, OffloadConfig,
                           OffloadEngine)

CFG = ArchConfig(name="act-tiny", family="dense", source="test",
                 num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
                 head_dim=16, d_ff=64, vocab_size=256, act="gelu")
MB, S = 1, 16
X0 = StorageRatios(0.0, 0.0, 0.0)


def _run(policy, sched, M, W=0, alpha=0.0, ranks=0, steps=2,
         ratios=X0, seed=7):
    """(losses, per-iter measured routes, plan_traffic prediction,
    full low-precision params, act_nbytes) for one engine run."""
    ocfg = OffloadConfig(schedule=sched, num_microbatches=M,
                         micro_batch=MB, seq_len=S, alpha=alpha,
                         wave_size=W, ratios=ratios,
                         activation_policy=policy)
    with tempfile.TemporaryDirectory() as d:
        if ranks:
            eng = DataParallelOffloadEngine(CFG, ocfg,
                                            jax.random.PRNGKey(seed), d,
                                            ranks=ranks)
            meters = [rk.meter for rk in eng.ranks]
        else:
            eng = OffloadEngine(CFG, ocfg, jax.random.PRNGKey(seed), d)
            meters = [eng.meter]
        data = SyntheticLM(CFG.vocab_size, seed=0)
        losses = [eng.train_step(data.batch(M * MB, S))
                  for _ in range(steps)]
        eng.finish()
        measured = [{k: v / steps for k, v in m.bytes.items()}
                    for m in meters]
        pred = plan_traffic(eng._plan, PlanCosts.from_engine(eng))
        if ranks:
            params = [eng.read_params(l).copy() for l in range(eng.L)]
        else:
            params = [eng.p_vecs[l].read().copy() for l in range(eng.L)]
        A = eng.act_nbytes
        assert eng.act_fallbacks == 0      # clean runs never degrade
        eng.close()
    if not ranks:
        measured, pred = measured[0], pred
    return losses, measured, pred, params, A


def _closed_form_spill(L, P, M, W, A):
    """Exact (category, route) bytes for the f32 spill engine at
    x = (0,0,0,0): the act stream (act_spill_traffic) + the ckpt forms
    with backward re-reads gone + the unchanged param/grad/opt forms."""
    ms = L * P * 4
    u = MB * S * CFG.d_model * 4
    nw = M // W
    ct = wave_ckpt_traffic(L * u, M, W, L, act_spill=True)
    at = act_spill_traffic(A, M, L)
    exp = {
        ("param", "ssd->cpu"): 2 * nw * ms,
        ("param", "cpu->gpu"): 2 * nw * ms,
        ("param", "cpu->ssd"): ms,
        ("grad", "gpu->cpu"): nw * ms,
        ("grad", "cpu->gpu"): (nw - 1) * ms,
        ("opt", "ssd->cpu"): 3 * ms,
        ("opt", "cpu->ssd"): 3 * ms,
        ("ckpt", "gpu->cpu"): ct.write,
        ("ckpt", "cpu->gpu"): ct.read,
        ("ckpt", "cpu->ssd"): ct.ssd_spill,
        ("ckpt", "ssd->cpu"): ct.ssd_reread,
        ("inter_grad", "gpu->cpu"): ct.inter_grad / 2,
        ("inter_grad", "cpu->gpu"): ct.inter_grad / 2,
        ("act", "gpu->cpu"): at.spill,
        ("act", "cpu->gpu"): at.fetch,
        ("act", "cpu->ssd"): at.ssd_spill,
        ("act", "ssd->cpu"): at.ssd_reread,
    }
    return {k: v for k, v in exp.items() if v}


# ---------------------------------------------------------------------------
# IR units: ops, compiler, lookahead
# ---------------------------------------------------------------------------

def test_act_priority_is_opportunistic():
    """ACT is the lowest class — below even deferrable ckpt spills —
    and the "act" meter category maps to it."""
    assert IOPriority.ACT > IOPriority.CKPT_SPILL
    assert max(IOPriority) == IOPriority.ACT
    assert CATEGORY_PRIORITY["act"] is IOPriority.ACT


@pytest.mark.parametrize("W", [1, 2, 4])
def test_spill_compiler_ops(W):
    """Spill plans carry one SPILL_ACT per (layer, micro-batch) right
    after its FWD, FETCH_ACT replaces FETCH_CKPT_BWD one-for-one, and
    recompute plans carry no act ops at all."""
    L, M = 3, 4
    spec = PlanSpec(L=L, M=M, act_spill=True)
    plan = compile_wave(spec, W)
    assert plan.count(Op.SPILL_ACT) == plan.count(Op.FETCH_ACT) == L * M
    assert plan.count(Op.FETCH_CKPT_BWD) == 0
    assert plan.count(Op.FWD) == plan.count(Op.BWD) == L * M
    ops = plan.ops
    for i, op in enumerate(ops):
        if op.op is Op.FWD:
            assert ops[i + 1].op is Op.SPILL_ACT
            assert (ops[i + 1].l, ops[i + 1].m) == (op.l, op.m)
    base = compile_wave(PlanSpec(L=L, M=M), W)
    for kind in (Op.SPILL_ACT, Op.FETCH_ACT, Op.PREFETCH_ACT):
        assert base.count(kind) == 0
    assert base.count(Op.FETCH_CKPT_BWD) == L * M


def test_act_prefetch_hints():
    """insert_prefetch derives exactly one PREFETCH_ACT per FETCH_ACT,
    placed before it and never across a RESET_PARAMS; the param hints
    are unchanged by the act pass."""
    L, M = 3, 4
    spec = PlanSpec(L=L, M=M, act_spill=True)
    plan = insert_prefetch(compile_wave(spec, M))
    assert plan.count(Op.PREFETCH_ACT) == plan.count(Op.FETCH_ACT) == L * M
    assert plan.count(Op.PREFETCH) == plan.count(Op.FETCH_PARAM)
    ops = plan.ops
    resets = {i for i, op in enumerate(ops) if op.op is Op.RESET_PARAMS}
    hints = {}
    for i, op in enumerate(ops):
        if op.op is Op.PREFETCH_ACT:
            assert (op.l, op.m) not in hints, "duplicate hint"
            hints[(op.l, op.m)] = i
        elif op.op is Op.FETCH_ACT:
            h = hints.pop((op.l, op.m))
            assert h < i, "hint after its fetch"
            assert not any(h < r < i for r in resets), \
                "act hint crosses RESET_PARAMS"
    assert not hints, "hints without a fetch"
    # recompute plans gain no act hints
    base = insert_prefetch(compile_wave(PlanSpec(L=L, M=M), M))
    assert base.count(Op.PREFETCH_ACT) == 0


def test_dp_closed_form_includes_act():
    """dp_vertical_traffic(act_bytes=A): per-rank act fields equal the
    per-rank act_spill_traffic closed form, and ckpt backward re-reads
    vanish."""
    ms, cs, M, R, L, A = 4096.0, 1024.0, 4, 2, 2, 300.0
    t = dp_vertical_traffic(ms, cs, M, R, n_layers=L, act_bytes=A)
    at = act_spill_traffic(A, M // R, L)
    assert (t.act.spill, t.act.fetch) == (at.spill, at.fetch)
    assert (t.act.ssd_spill, t.act.ssd_reread) == (at.ssd_spill,
                                                   at.ssd_reread)
    assert t.ckpt.read_bwd == t.ckpt.ssd_reread == 0.0
    assert t.ssd_read == 2 * ms / R + 6 * ms / R + at.ssd_reread
    # recompute form unchanged
    t0 = dp_vertical_traffic(ms, cs, M, R, n_layers=L)
    assert t0.act is None and t0.ckpt.read_bwd > 0


# ---------------------------------------------------------------------------
# the acceptance sweep: three-way cross-check + bitwise policy parity
# ---------------------------------------------------------------------------

SWEEP = [
    # (sched, M, W, alpha, ranks)
    ("vertical", 1, 0, 0.0, 0),
    ("vertical", 2, 0, 0.5, 0),
    ("vertical", 4, 0, 0.0, 0),
    ("horizontal", 1, 0, 0.0, 0),
    ("horizontal", 2, 0, 0.0, 0),
    ("horizontal", 4, 0, 0.5, 0),
    ("wave", 2, 1, 0.0, 0),
    ("wave", 4, 2, 0.5, 0),
    ("vertical", 2, 0, 0.0, 2),
    ("vertical", 4, 0, 0.5, 2),
]


@pytest.mark.parametrize("sched,M,W,alpha,ranks", SWEEP)
def test_spill_three_way_crosscheck_and_bitwise(sched, M, W, alpha, ranks):
    """For every cell: the spill run's measured counters equal the
    static plan_traffic prediction equal the closed forms, the
    recompute run still cross-checks, and the two policies' losses and
    final low-precision parameters are bitwise-identical (f32)."""
    lr, mr, pr, params_r, _ = _run("recompute", sched, M, W, alpha, ranks)
    ls, ms_, ps, params_s, A = _run("spill", sched, M, W, alpha, ranks)
    assert all(np.isfinite(ls))
    assert lr == ls, "spill changed the losses"
    for a, b in zip(params_r, params_s):
        assert (a == b).all(), "spill changed the parameters"
    if ranks:
        for r, (m, p) in enumerate(zip(ms_, ps)):
            assert m == p, f"rank {r} measured != predicted"
        assert mr == pr
    else:
        assert ms_ == ps, "spill measured != predicted"
        assert mr == pr, "recompute measured != predicted"
        Wr = {"vertical": M, "horizontal": 1}.get(sched, W)
        L = CFG.num_layers
        P = params_s[0].size
        assert ps == _closed_form_spill(L, P, M, Wr, A), \
            "plan_traffic != closed forms"


def test_dp_spill_acts_stay_on_owner_rank():
    """R=2: each rank's act counters cover exactly its own M/R
    micro-batches (the per-rank act_spill_traffic form), on its own
    meter — activation shards ride the owner's path set."""
    _, measured, _, _, A = _run("spill", "vertical", 4, ranks=2)
    L, Mr = CFG.num_layers, 2
    at = act_spill_traffic(A, Mr, L)
    for r, m in enumerate(measured):
        assert m[("act", "gpu->cpu")] == at.spill, f"rank {r}"
        assert m[("act", "cpu->ssd")] == at.ssd_spill, f"rank {r}"
        assert m[("act", "ssd->cpu")] == at.ssd_reread, f"rank {r}"
        assert ("ckpt", "ssd->cpu") not in m, "bwd ckpt re-read survived"


def test_spill_nonzero_ratios_crosscheck():
    """Partial CPU residency incl. an act head fraction: the analyzer's
    rounding matches the coordinator's exactly."""
    _, measured, pred, _, _ = _run(
        "spill", "vertical", 4,
        ratios=StorageRatios(0.5, 0.25, 0.5, act=0.3))
    assert measured == pred
    assert ("act", "cpu->ssd") in measured          # tail still spills
    assert measured[("act", "cpu->ssd")] < measured[("act", "gpu->cpu")]


def test_act_fully_host_resident_never_touches_ssd():
    _, measured, pred, _, _ = _run(
        "spill", "vertical", 2, ratios=StorageRatios(0.0, 0.0, 0.0,
                                                     act=1.0))
    assert measured == pred
    assert ("act", "cpu->ssd") not in measured
    assert ("act", "ssd->cpu") not in measured


# ---------------------------------------------------------------------------
# the auto policy: engine knob, perf model, LP row
# ---------------------------------------------------------------------------

# spill wins when compute is the bottleneck (slow GPU, fast storage);
# recompute wins when storage is (fast GPU, slow storage)
SLOW_GPU = MachineParams(gpu_flops=1e8, ssd_read_bw=50e9, ssd_write_bw=50e9,
                         pcie_bw=50e9, cpu_adam_bw=100e9)
FAST_GPU = MachineParams(gpu_flops=1e15, ssd_read_bw=0.5e9,
                         ssd_write_bw=0.25e9)


def _auto_engine_policy(machine):
    ocfg = OffloadConfig(schedule="vertical", num_microbatches=2,
                         micro_batch=MB, seq_len=S, ratios=X0,
                         activation_policy="auto", machine=machine)
    with tempfile.TemporaryDirectory() as d:
        eng = OffloadEngine(CFG, ocfg, jax.random.PRNGKey(0), d)
        pol = eng.act_policy
        n_spill = eng._plan.count(Op.SPILL_ACT)
        eng.close()
    return pol, n_spill


def test_auto_policy_resolves_from_roofline():
    pol, n = _auto_engine_policy(SLOW_GPU)
    assert pol == "spill" and n > 0
    pol, n = _auto_engine_policy(FAST_GPU)
    assert pol == "recompute" and n == 0


def test_pick_activation_policy_directions():
    w = Workload(ms=2e9, cs=0.1e9, os_bytes=12e9, grad_bytes=4e9,
                 flops_per_mb=2e12, tokens_per_mb=4096, n_layers=8,
                 as_bytes=0.2e9)
    assert pick_activation_policy(w, SLOW_GPU, 8, 8, 0.0, X0) == "spill"
    assert pick_activation_policy(w, FAST_GPU, 8, 8, 0.0, X0) == "recompute"
    # pricing is consistent with the chooser
    t_re = iteration_time_vertical(w, SLOW_GPU, 8, 0.0, X0)
    t_sp = iteration_time_vertical(w, SLOW_GPU, 8, 0.0, X0, act="spill")
    assert t_sp < t_re


def test_lp_policy_row():
    """solve_config's activation row: explicit policies tag their
    solutions, and "auto" returns the faster of the two on both
    machine regimes."""
    w = Workload(ms=2e9, cs=0.1e9, os_bytes=12e9, grad_bytes=4e9,
                 flops_per_mb=2e12, tokens_per_mb=4096, n_layers=8,
                 as_bytes=0.2e9)
    for m, want in ((SLOW_GPU, "spill"), (FAST_GPU, "recompute")):
        s_re = solve_config(m, w, 8, 0.2, act_policy="recompute")
        s_sp = solve_config(m, w, 8, 0.2, act_policy="spill")
        s_auto = solve_config(m, w, 8, 0.2, act_policy="auto")
        assert s_re.act_policy == "recompute"
        assert s_sp.act_policy == "spill"
        assert s_auto.act_policy == want
        assert s_auto.iteration_time == min(s_re.iteration_time,
                                            s_sp.iteration_time)
    with pytest.raises(ValueError, match="act_policy"):
        solve_config(SLOW_GPU, w, 8, 0.2, act_policy="stream")


def test_unknown_engine_policy_rejected():
    # eager __post_init__ contract: the typo fails at CONSTRUCTION,
    # before any engine (or even a workdir) exists
    with pytest.raises(ValueError, match="activation_policy"):
        OffloadConfig(schedule="vertical", num_microbatches=2,
                      micro_batch=MB, seq_len=S,
                      activation_policy="nope")


# ---------------------------------------------------------------------------
# coordinator unit: byte-exact round trip (incl. 0-d scalar leaves)
# ---------------------------------------------------------------------------

def test_act_coordinator_roundtrip():
    import os

    from repro.io import IOConfig, IOEngine
    from repro.offload.coordinators import ActivationCoordinator
    from repro.offload.stores import HostStore, SSDStore, TrafficMeter

    with tempfile.TemporaryDirectory() as d:
        meter = TrafficMeter()
        ioe = IOEngine(IOConfig(paths=[os.path.join(d, "p")]), meter=meter)
        ssd = SSDStore(ioe.paths[0], meter, engine=ioe)
        host = HostStore(meter)
        co = ActivationCoordinator(0.25, host, ssd, meter, ioe)
        # a vjp-shaped pytree: mixed dtypes INCLUDING 0-d scalars (the
        # numpy ascontiguousarray 0-d -> (1,) promotion regression)
        tree = {"a": jax.numpy.arange(37, dtype=jax.numpy.float32),
                "idx": jax.numpy.asarray(np.int32(5)),
                "b": (jax.numpy.ones((3, 4), jax.numpy.float32),
                      jax.numpy.asarray(np.float32(2.5)))}
        co.put(1, 0, tree)
        co.prefetch(1, 0)
        got = co.get(1, 0)
        assert got["idx"].shape == () and int(got["idx"]) == 5
        assert float(got["b"][1]) == 2.5
        np.testing.assert_array_equal(np.asarray(got["a"]),
                                      np.asarray(tree["a"]))
        # fully consumed: nothing tracked, host head released
        assert co._n == {} and co._pending == {} and co._prefetched == {}
        assert host.nbytes() == 0
        nbytes = sum(leaf.nbytes for leaf in jax.tree.leaves(tree))
        assert meter.bytes[("act", "gpu->cpu")] == nbytes
        assert meter.bytes[("act", "cpu->gpu")] == nbytes
        tail = nbytes - int(round(0.25 * nbytes))
        assert meter.bytes[("act", "cpu->ssd")] == tail
        assert meter.bytes[("act", "ssd->cpu")] == tail
        ssd.close()
