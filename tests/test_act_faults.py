"""Fault injection for the activation stream: a failed ``SPILL_ACT`` /
``FETCH_ACT`` must release its staging buffer and in-flight budget,
clear the coordinator's tracking for that key, and degrade JUST that
micro-batch to the recompute path — the step completes, and because
both policies run backward from the same residuals the results stay
bitwise-identical to a clean run. A non-act mid-plan fault with live
activation state must clear the whole coordinator (no leaks into the
next step). Faults are aimed at the activation stream with
:class:`repro.io.chaos.ChaosFiles`' name-targeted fuses
(``fail_name_writes["act:"]`` etc. — chunk-level fuses cannot tell an
act tail from a ckpt tail).
"""
import tempfile

import jax
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core.perfmodel import StorageRatios
from repro.data import SyntheticLM
from repro.io import install_chaos
from repro.offload import OffloadConfig, OffloadEngine

CFG = ArchConfig(name="act-fault-tiny", family="dense", source="test",
                 num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
                 head_dim=16, d_ff=64, vocab_size=256, act="gelu")
MB, S, M = 1, 16, 4


def _spill_engine(d):
    eng = OffloadEngine(CFG, OffloadConfig(
        schedule="vertical", num_microbatches=M, micro_batch=MB, seq_len=S,
        ratios=StorageRatios(0.0, 0.0, 0.0), activation_policy="spill"),
        jax.random.PRNGKey(3), d)
    install_chaos(eng.ssd)                    # init writes stay intact
    return eng


def _clean_losses(steps=2):
    """Reference losses from a fault-free spill engine (bitwise equal to
    the recompute engine by the executor's construction)."""
    with tempfile.TemporaryDirectory() as d:
        eng = OffloadEngine(CFG, OffloadConfig(
            schedule="vertical", num_microbatches=M, micro_batch=MB,
            seq_len=S, ratios=StorageRatios(0.0, 0.0, 0.0),
            activation_policy="spill"), jax.random.PRNGKey(3), d)
        data = SyntheticLM(CFG.vocab_size, seed=0)
        losses = [eng.train_step(data.batch(M * MB, S))
                  for _ in range(steps)]
        eng.finish()
        eng.close()
    return losses


def _assert_act_clean(eng):
    co = eng.act_c
    assert co._pending == {}, "leaked in-flight act spills"
    assert co._prefetched == {}, "leaked act prefetch reads"
    assert co._n == {} and co._meta == {}, "leaked act tracking state"
    assert eng.host.nbytes() == 0, "leaked host buffers"


def test_act_write_fault_degrades_to_recompute_bitwise():
    """One act-tail write fault: the step COMPLETES (no exception), that
    micro-batch falls back to recompute, and losses are bitwise equal to
    a clean run — the fallback runs the same residual arithmetic."""
    ref = _clean_losses()
    with tempfile.TemporaryDirectory() as d:
        eng = _spill_engine(d)
        data = SyntheticLM(CFG.vocab_size, seed=0)
        eng.ssd.files.fail_name_writes["act:"] = 1
        losses = [eng.train_step(data.batch(M * MB, S)) for _ in range(2)]
        assert eng.act_fallbacks == 1
        assert losses == ref, "fallback changed the arithmetic"
        eng.finish()
        _assert_act_clean(eng)
        s = eng.ioe.metrics_snapshot()
        assert s["inflight_bytes"] == 0, "fault leaked the byte budget"
        assert s["completed"] + s["cancelled"] == s["submitted"]
        eng.close()


def test_act_read_fault_degrades_to_recompute_bitwise():
    """One act-tail read fault at FETCH_ACT: same contract."""
    ref = _clean_losses()
    with tempfile.TemporaryDirectory() as d:
        eng = _spill_engine(d)
        data = SyntheticLM(CFG.vocab_size, seed=0)
        eng.train_step(data.batch(M * MB, S))     # step 1 clean
        eng.ssd.files.fail_name_reads["act:"] = 1
        losses = [ref[0], eng.train_step(data.batch(M * MB, S))]
        assert eng.act_fallbacks >= 1
        assert losses == ref
        eng.finish()
        _assert_act_clean(eng)
        assert eng.ioe.metrics_snapshot()["inflight_bytes"] == 0
        eng.close()


def test_act_fault_releases_staging_buffers():
    """After an act write fault the staging pool must be fully
    acquirable — the failed spill released its slot."""
    import threading

    with tempfile.TemporaryDirectory() as d:
        eng = _spill_engine(d)
        data = SyntheticLM(CFG.vocab_size, seed=0)
        eng.ssd.files.fail_name_writes["act:"] = 2
        eng.train_step(data.batch(M * MB, S))
        eng.finish()
        nbuf = eng.ioe.config.staging_buffers
        got = threading.Event()

        def drain_pool():
            bufs = [eng.ioe.staging.acquire(64) for _ in range(nbuf)]
            got.set()
            for b in bufs:
                b.release()

        t = threading.Thread(target=drain_pool, daemon=True)
        t.start()
        assert got.wait(5.0), "failed act spill leaked a staging buffer"
        t.join(5.0)
        eng.close()


def test_non_act_fault_clears_act_coordinator():
    """A checkpoint-spill write fault on the HEAD boundary surfaces at
    its DROP_CKPT right after HEAD_BWD — before any FETCH_ACT, with all
    L·M act payloads still tracked: the executor's cleanup must clear
    the activation coordinator too, and the engine must run a clean,
    fallback-free step afterwards."""
    with tempfile.TemporaryDirectory() as d:
        eng = _spill_engine(d)
        data = SyntheticLM(CFG.vocab_size, seed=0)
        eng.ssd.files.fail_prefix = f"c:{CFG.num_layers}:"
        with pytest.raises(OSError, match="injected write fault"):
            eng.train_step(data.batch(M * MB, S))
        _assert_act_clean(eng)
        assert eng.ckpt_c._device_kept == {}
        assert eng.params_c._futures == {}
        before = eng.act_fallbacks
        loss = eng.train_step(data.batch(M * MB, S))
        assert np.isfinite(loss)
        assert eng.act_fallbacks == before, "recovered step degraded"
        eng.finish()
        _assert_act_clean(eng)
        eng.close()
