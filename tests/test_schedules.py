"""The paper's central correctness identity (§3.4): vertical scheduling
computes the same gradients as horizontal micro-batch accumulation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke
from repro.core import ScheduleConfig, grads_fn
from repro.data import make_batch
from repro.models import init_params


def _f32_params(cfg, seed=0):
    p = init_params(cfg, jax.random.PRNGKey(seed))
    return jax.tree.map(lambda x: x.astype(jnp.float32), p)


@pytest.mark.parametrize("arch,mbs", [("gpt-tiny", 4), ("gpt-tiny", 8)])
def test_vertical_equals_horizontal(arch, mbs):
    cfg = get_config(arch)
    params = _f32_params(cfg)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, 8, 64, seed=3).items()}
    lv, gv = jax.jit(grads_fn(cfg, ScheduleConfig("vertical")))(params, batch)
    lh, gh = jax.jit(grads_fn(cfg, ScheduleConfig("horizontal",
                                                  num_microbatches=mbs)))(params, batch)
    assert abs(float(lv) - float(lh)) < 1e-4
    for a, b in zip(jax.tree.leaves(gv), jax.tree.leaves(gh)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=3e-4, rtol=1e-3)


@pytest.mark.parametrize("arch", ["qwen3-4b", "falcon-mamba-7b"])
def test_vertical_equals_horizontal_other_families(arch):
    """The identity holds for GQA+qk-norm and for SSM blocks too."""
    cfg = get_smoke(arch)
    params = _f32_params(cfg, seed=1)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, 4, 32, seed=5).items()}
    lv, gv = jax.jit(grads_fn(cfg, ScheduleConfig("vertical")))(params, batch)
    lh, gh = jax.jit(grads_fn(cfg, ScheduleConfig("horizontal",
                                                  num_microbatches=2)))(params, batch)
    assert abs(float(lv) - float(lh)) < 1e-4
    for a, b in zip(jax.tree.leaves(gv), jax.tree.leaves(gh)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=2e-3)


def test_remat_matches_no_remat():
    """Per-layer rematerialisation must not change gradients."""
    cfg = get_config("gpt-tiny")
    params = _f32_params(cfg)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, 4, 32, seed=7).items()}
    _, g1 = jax.jit(grads_fn(cfg, ScheduleConfig("vertical", remat=True)))(params, batch)
    _, g2 = jax.jit(grads_fn(cfg, ScheduleConfig("vertical", remat=False)))(params, batch)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)
