"""End-to-end integration of the beyond-paper optimized mode (--fsdp):
batch over all axes + activation-spec pin + grad shardings + EP MoE.

Runs a REAL train step on 8 fake devices and checks the loss matches
the unoptimized (paper-faithful) lowering — the sharding scheme must
not change the math.
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke
from repro.core import schedules as sched_lib
from repro.core.schedules import ScheduleConfig, make_train_step
from repro.data import make_batch
from repro.launch import shardings as sh
from repro.models import model as mdl
from repro.models import moe_ep
from repro.optim import AdamConfig, init_state

cfg = get_smoke("qwen3-moe-235b-a22b")   # 4 experts, 2 layers (reduced)
mesh = jax.make_mesh((2, 4), ("data", "model"))
params = mdl.init_params(cfg, jax.random.PRNGKey(0))
opt = init_state(params)
batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, 8, 32).items()}
step = make_train_step(cfg, ScheduleConfig("vertical"), AdamConfig())

# ---- paper-faithful lowering ----
p_sh = sh.shard_params(params, mesh)
o_sh = sh.opt_state_shardings(p_sh, mesh)
b_sh = sh.shard_batch(batch, mesh)
rep = sh.replicated(mesh)
with jax.set_mesh(mesh):
    _, _, m0 = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                       out_shardings=(p_sh, o_sh,
                                      {"loss": rep, "grad_norm": rep})
                       )(params, opt, batch)
loss0 = float(m0["loss"])

# ---- optimized lowering (fsdp + EP) ----
p_sh2 = sh.shard_params(params, mesh, expert_parallel=True, fully_shard=True)
o_sh2 = sh.opt_state_shardings(p_sh2, mesh)
b_sh2 = sh.shard_batch(batch, mesh, include_model=True)
mdl.set_activation_spec(NamedSharding(mesh, P(("data", "model"), None, None)))
sched_lib.set_grad_shardings(p_sh2)
moe_ep.set_ep_mesh(mesh, axis="model", bax=("data", "model"))
step2 = make_train_step(cfg, ScheduleConfig("vertical"), AdamConfig())
with jax.set_mesh(mesh):
    _, _, m1 = jax.jit(step2, in_shardings=(p_sh2, o_sh2, b_sh2),
                       out_shardings=(p_sh2, o_sh2,
                                      {"loss": rep, "grad_norm": rep})
                       )(params, opt, batch)
loss1 = float(m1["loss"])
print(json.dumps({"loss0": loss0, "loss1": loss1,
                  "gn0": float(m0["grad_norm"]),
                  "gn1": float(m1["grad_norm"])}))
"""


@pytest.mark.slow
def test_optimized_mode_matches_baseline_loss():
    env = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    rec = json.loads(r.stdout.strip().splitlines()[-1])
    # EP capacity (1.25x) may drop a few tokens the dense path keeps, so
    # allow a small relative tolerance on the loss.
    assert abs(rec["loss1"] - rec["loss0"]) / rec["loss0"] < 0.02, rec
    assert abs(rec["gn1"] - rec["gn0"]) / rec["gn0"] < 0.1, rec
