"""CI-partition meta-test: the workflow matrix must PARTITION the test
suite. Every ``tests/test_*.py`` file is covered by exactly one suite —
tier1 covers everything it does not ``--ignore``, the battery suites
list their files explicitly — so adding a battery file without updating
the tier1 ignores (or ignoring a file nowhere listed) fails HERE, on
every run, instead of silently dropping tests from CI. Also pins the
required job set and the concurrency group.
"""
import glob
import os

import pytest

yaml = pytest.importorskip("yaml")

HERE = os.path.dirname(__file__)
WORKFLOW = os.path.join(HERE, "..", ".github", "workflows", "ci.yml")


def _doc():
    with open(WORKFLOW) as f:
        return yaml.safe_load(f)


def _suites():
    """suite name -> pytest args (whitespace-split, >- folded)."""
    matrix = _doc()["jobs"]["tests"]["strategy"]["matrix"]["include"]
    return {e["suite"]: e["args"].split() for e in matrix}


def _all_test_files():
    return sorted(os.path.basename(p)
                  for p in glob.glob(os.path.join(HERE, "test_*.py")))


def test_every_test_file_in_exactly_one_suite():
    suites = _suites()
    all_files = _all_test_files()
    assert all_files, "no test files found next to this meta-test?"
    coverage = {f: [] for f in all_files}
    for name, args in suites.items():
        listed = [os.path.basename(a) for a in args
                  if a.startswith("tests/") and a.endswith(".py")]
        ignored = [os.path.basename(a.split("=", 1)[1]) for a in args
                   if a.startswith("--ignore=")]
        if "tests" in args:          # the catch-all suite
            covered = [f for f in all_files if f not in ignored]
        else:
            covered = listed
        for f in covered:
            assert f in coverage, \
                f"suite {name!r} names {f}, which does not exist"
        for f in ignored + listed:
            assert f in coverage, \
                f"suite {name!r} references {f}, which does not exist " \
                f"(stale --ignore / file list)"
        for f in covered:
            coverage[f].append(name)
    problems = {f: names for f, names in coverage.items()
                if len(names) != 1}
    assert not problems, (
        "every tests/test_*.py must be covered by exactly one CI suite; "
        f"violations (file -> suites): {problems}")


def test_required_jobs_present():
    doc = _doc()
    jobs = doc["jobs"]
    assert set(jobs) >= {"tests", "bench-smoke", "lint"}, sorted(jobs)
    suites = set(_suites())
    assert suites >= {"tier1", "io-dp-battery", "plan-battery",
                      "act-battery"}, sorted(suites)
    # >= 5 effective jobs: the four matrix suites + bench-smoke + lint
    assert len(suites) + len(set(jobs) - {"tests"}) >= 5


def test_concurrency_group_cancels_superseded_runs():
    doc = _doc()
    conc = doc.get("concurrency")
    assert conc, "workflow must define a concurrency group"
    cancel = conc.get("cancel-in-progress")
    # either unconditionally true or the guarded expression that keeps
    # main-branch runs (and their bench artifacts) alive
    assert cancel is True or (
        isinstance(cancel, str) and "github.ref" in cancel), cancel


def test_invocation_is_unified():
    """CI and ROADMAP.md run the SAME tier-1 line — the package is
    installed (CI) or pyproject's pythonpath covers src/ (local), so
    neither needs PYTHONPATH juggling."""
    with open(WORKFLOW) as f:
        wf = f.read()
    assert "PYTHONPATH=" not in wf, \
        "CI must use the unified `python -m pytest` invocation"
    with open(os.path.join(HERE, "..", "ROADMAP.md")) as f:
        roadmap = f.read()
    assert "`python -m pytest -x -q`" in roadmap
    assert "PYTHONPATH=src python -m pytest" not in roadmap


def test_bench_smoke_job_shape():
    """The bench job must produce both JSONs, gate against the
    checked-in baseline, and upload the artifacts."""
    steps = _doc()["jobs"]["bench-smoke"]["steps"]
    runs = " ".join(s.get("run", "") for s in steps)
    assert "bench_engine.py --smoke --json" in runs
    assert "bench_io.py" in runs and "--json" in runs
    assert "check_smoke.py" in runs
    assert "baseline_smoke.json" in runs
    uploads = [s for s in steps
               if "upload-artifact" in str(s.get("uses", ""))]
    assert uploads, "bench JSONs must be uploaded as artifacts"
    assert os.path.exists(os.path.join(HERE, "..", "benchmarks",
                                       "baseline_smoke.json"))
