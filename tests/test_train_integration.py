"""Integration: end-to-end training, checkpoint roundtrip, data pipeline."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import ScheduleConfig
from repro.data import SyntheticLM, make_batch, split_microbatches
from repro.optim import AdamConfig
from repro.train import Trainer, checkpoint


def test_loss_decreases_vertical():
    cfg = get_config("gpt-tiny")
    tr = Trainer(cfg, ScheduleConfig(schedule="vertical"), AdamConfig(lr=3e-3))
    rep = tr.run(40, batch_size=16, seq_len=64, log_every=0)
    assert np.mean(rep.losses[-5:]) < rep.losses[0] - 1.0, rep.losses[::8]


def test_delayed_trainer_matches_plain():
    cfg = get_config("gpt-tiny")
    t1 = Trainer(cfg, ScheduleConfig(schedule="vertical"), AdamConfig(lr=1e-3),
                 seed=0)
    r1 = t1.run(6, batch_size=8, seq_len=64, log_every=0)
    t2 = Trainer(cfg, ScheduleConfig(schedule="vertical", alpha=0.4),
                 AdamConfig(lr=1e-3), seed=0)
    r2 = t2.run(6, batch_size=8, seq_len=64, log_every=0)
    np.testing.assert_allclose(r1.losses, r2.losses, atol=2e-3)


def test_checkpoint_roundtrip():
    cfg = get_config("gpt-tiny")
    tr = Trainer(cfg, ScheduleConfig(), AdamConfig())
    tr.run(2, batch_size=4, seq_len=32, log_every=0)
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save(d, tr.params, tr.state, step=2)
        p2, s2, step = checkpoint.restore(d, tr.params, tr.state)
        assert step == 2
        for a, b in zip(jax.tree.leaves(tr.params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(tr.state), jax.tree.leaves(s2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_synthetic_data_learnable_structure():
    d = SyntheticLM(256, seed=0, p_det=0.9)
    b = d.batch(4, 128)
    assert b.shape == (4, 128) and b.dtype == np.int32
    # ~90% of transitions follow the permutation
    nxt = d.perm[b[:, :-1]]
    frac = (nxt == b[:, 1:]).mean()
    assert 0.8 < frac < 0.97
    assert 0 < d.ideal_loss() < 2.0


def test_microbatch_split():
    cfg = get_config("gpt-tiny")
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, 8, 32).items()}
    mb = split_microbatches(batch, 4)
    assert mb["tokens"].shape == (4, 2, 32)
    np.testing.assert_array_equal(
        np.asarray(mb["tokens"]).reshape(8, 32), np.asarray(batch["tokens"]))
