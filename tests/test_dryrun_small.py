"""Distributed-path test: lower + compile the real train/serve steps on a
small forced-device-count mesh in a SUBPROCESS (so the main test process
keeps its single CPU device)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
from repro.configs import get_smoke, INPUT_SHAPES
from repro.configs.base import InputShape
from repro.launch.mesh import make_host_mesh
from repro.launch import shardings as sh
from repro.core.schedules import ScheduleConfig, make_train_step
from repro.optim import AdamConfig, init_state
from repro.models import model as mdl
from repro.data import make_batch

arch = "%ARCH%"
cfg = get_smoke(arch)
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
params = mdl.init_params(cfg, jax.random.PRNGKey(0))
opt = init_state(params)
batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, 4, 32).items()}
p_sh = sh.shard_params(params, mesh)
o_sh = sh.opt_state_shardings(p_sh, mesh)
b_sh = sh.shard_batch(batch, mesh)
rep = sh.replicated(mesh)
step = make_train_step(cfg, ScheduleConfig("vertical"), AdamConfig())
jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                 out_shardings=(p_sh, o_sh, {"loss": rep, "grad_norm": rep}))
with mesh:
    params2, opt2, metrics = jitted(params, opt, batch)
print(json.dumps({"loss": float(metrics["loss"]),
                  "devices": len(jax.devices())}))
"""


@pytest.mark.parametrize("arch", ["gpt-tiny", "qwen3-4b", "falcon-mamba-7b"])
def test_sharded_train_step_runs(arch):
    code = SCRIPT.replace("%ARCH%", arch)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["devices"] == 8
    assert rec["loss"] > 0 and rec["loss"] < 20


def test_dryrun_artifacts_exist_and_fit_schema():
    """If the full dry-run matrix has been produced, validate the records."""
    d = os.path.join(ROOT, "experiments", "dryrun")
    if not os.path.isdir(d) or not os.listdir(d):
        pytest.skip("dry-run artifacts not generated yet")
    for f in os.listdir(d):
        with open(os.path.join(d, f)) as fh:
            rec = json.load(fh)
        assert rec["flops_per_device"] > 0
        assert rec["memory"]["temp_bytes"] >= 0
        assert "collectives" in rec
