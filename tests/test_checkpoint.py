"""Crash-consistent checkpoint battery (`repro.offload.checkpoint`).

The contract under test:

* **Bitwise resume** — save mid-training, restore into a FRESH engine
  built from a different PRNG key: the continued loss trajectory is
  bitwise identical to the uninterrupted run (the plan-swap pin,
  through disk). Saving is non-destructive — the original engine keeps
  training and produces the same reference trajectory.
* **Topology interchange** — vectors are stored assembled, so a
  single-rank checkpoint restores into a DP engine (and the params
  match bitwise): DP sharding is contiguous slicing.
* **Crash consistency** — the manifest commits last (tmp + rename):
  a torn/missing/wrong-version manifest, a torn or corrupt tensor
  file, or mismatched engine meta raise :class:`CheckpointError`
  BEFORE any engine state is touched — a failed restore leaves the
  engine trainable and bit-identical to before the attempt.
* **Generation GC** — re-saving into the same directory keeps only
  the files the committed manifest references.
"""
import dataclasses
import json
import os
import tempfile

import jax
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core.perfmodel import StorageRatios
from repro.data import SyntheticLM
from repro.offload import (CheckpointError, OffloadConfig, load_manifest,
                           make_engine)

CFG = ArchConfig(name="ckpt-tiny", family="dense", source="test",
                 num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
                 head_dim=16, d_ff=64, vocab_size=256, act="gelu")
MB, S, M = 1, 16, 4


def _mk(d, ranks=1, key=0, cfg=CFG):
    oc = OffloadConfig(schedule="vertical", num_microbatches=M,
                       micro_batch=MB, seq_len=S,
                       ratios=StorageRatios(0.5, 0.5, 0.5),
                       alpha=0.5, activation_policy="spill")
    return make_engine(cfg, oc, jax.random.PRNGKey(key), d,
                       num_ranks=ranks)


def _steps(eng, n, data):
    return [eng.train_step(data.batch(M * MB, S)) for _ in range(n)]


def _params(eng):
    if hasattr(eng, "ranks"):
        return [np.asarray(eng.read_params(l)).copy()
                for l in range(eng.L)]
    return [np.asarray(eng.p_vecs[l].read()).copy() for l in range(eng.L)]


def test_save_restore_resumes_bitwise():
    data = SyntheticLM(CFG.vocab_size, seed=0)
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2, \
            tempfile.TemporaryDirectory() as ck:
        a = _mk(d1, key=0)
        _steps(a, 2, data)
        manifest = a.save_checkpoint(ck)
        assert os.path.basename(manifest) == "manifest.json"
        # non-destructive: the SAME engine continues -> reference
        data_a = SyntheticLM(CFG.vocab_size, seed=1)
        ref = _steps(a, 2, data_a)
        a.finish()
        a.close()
        # fresh engine, DIFFERENT init key: restore overwrites it all
        b = _mk(d2, key=99)
        step = b.restore_checkpoint(ck)
        assert step == 2 and b.step_num == 2
        data_b = SyntheticLM(CFG.vocab_size, seed=1)
        got = _steps(b, 2, data_b)
        assert got == ref, "resumed trajectory diverged"
        b.finish()
        b.close()


def test_single_rank_checkpoint_restores_into_dp():
    data = SyntheticLM(CFG.vocab_size, seed=0)
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2, \
            tempfile.TemporaryDirectory() as ck:
        a = _mk(d1, ranks=1, key=0)
        _steps(a, 2, data)
        a.save_checkpoint(ck)
        a.finish()
        want = _params(a)
        a.close()
        b = _mk(d2, ranks=2, key=5)
        assert b.restore_checkpoint(ck) == 2
        for l, (x, y) in enumerate(zip(_params(b), want)):
            np.testing.assert_array_equal(x, y,
                                          err_msg=f"layer {l} params")
        # and it trains
        assert np.isfinite(b.train_step(data.batch(M * MB, S)))
        b.finish()
        b.close()


def test_generation_gc_keeps_only_committed_files():
    data = SyntheticLM(CFG.vocab_size, seed=0)
    with tempfile.TemporaryDirectory() as d, \
            tempfile.TemporaryDirectory() as ck:
        eng = _mk(d)
        _steps(eng, 2, data)
        eng.save_checkpoint(ck)
        assert any(f.endswith(".g2.bin") for f in os.listdir(ck))
        _steps(eng, 2, data)
        eng.save_checkpoint(ck)
        gens = {f.rsplit(".g", 1)[1] for f in os.listdir(ck)
                if f.endswith(".bin")}
        assert gens == {"4.bin"}, "stale generation files survived GC"
        doc = load_manifest(ck)
        assert doc["meta"]["step_num"] == 4
        eng.finish()
        eng.close()


def _saved_engine(d, ck):
    data = SyntheticLM(CFG.vocab_size, seed=0)
    eng = _mk(d)
    _steps(eng, 2, data)
    eng.save_checkpoint(ck)
    return eng, data


def _assert_untouched_and_trainable(eng, before, data):
    for l, (x, y) in enumerate(zip(_params(eng), before)):
        np.testing.assert_array_equal(
            x, y, err_msg=f"failed restore mutated layer {l}")
    assert np.isfinite(eng.train_step(data.batch(M * MB, S)))


def test_torn_manifest_is_rejected_engine_untouched():
    with tempfile.TemporaryDirectory() as d, \
            tempfile.TemporaryDirectory() as ck:
        eng, data = _saved_engine(d, ck)
        before = _params(eng)
        mp = os.path.join(ck, "manifest.json")
        raw = open(mp, "rb").read()
        with open(mp, "wb") as f:                 # simulate a torn write
            f.write(raw[:len(raw) // 2])
        with pytest.raises(CheckpointError, match="torn or corrupt"):
            eng.restore_checkpoint(ck)
        _assert_untouched_and_trainable(eng, before, data)
        eng.finish()
        eng.close()


def test_missing_manifest_is_rejected():
    with tempfile.TemporaryDirectory() as d, \
            tempfile.TemporaryDirectory() as ck:
        eng = _mk(d)
        with pytest.raises(CheckpointError, match="no checkpoint manifest"):
            eng.restore_checkpoint(ck)
        eng.close()


def test_wrong_version_is_rejected():
    with tempfile.TemporaryDirectory() as d, \
            tempfile.TemporaryDirectory() as ck:
        eng, data = _saved_engine(d, ck)
        mp = os.path.join(ck, "manifest.json")
        doc = json.load(open(mp))
        doc["version"] = 999
        json.dump(doc, open(mp, "w"))
        with pytest.raises(CheckpointError, match="version"):
            eng.restore_checkpoint(ck)
        eng.finish()
        eng.close()


def test_corrupt_tensor_is_rejected_engine_untouched():
    """One flipped byte in one tensor file: CRC verification fails the
    whole restore before any state is written."""
    with tempfile.TemporaryDirectory() as d, \
            tempfile.TemporaryDirectory() as ck:
        eng, data = _saved_engine(d, ck)
        before = _params(eng)
        doc = load_manifest(ck)
        fn = doc["tensors"]["master:0"]["file"]
        fp = os.path.join(ck, fn)
        raw = bytearray(open(fp, "rb").read())
        raw[len(raw) // 2] ^= 0xFF
        open(fp, "wb").write(bytes(raw))
        with pytest.raises(CheckpointError, match="CRC32C mismatch"):
            eng.restore_checkpoint(ck)
        _assert_untouched_and_trainable(eng, before, data)
        eng.finish()
        eng.close()


def test_torn_tensor_is_rejected():
    with tempfile.TemporaryDirectory() as d, \
            tempfile.TemporaryDirectory() as ck:
        eng, data = _saved_engine(d, ck)
        doc = load_manifest(ck)
        fn = doc["tensors"]["v:1"]["file"]
        fp = os.path.join(ck, fn)
        raw = open(fp, "rb").read()
        open(fp, "wb").write(raw[: len(raw) // 2])
        with pytest.raises(CheckpointError, match="torn checkpoint tensor"):
            eng.restore_checkpoint(ck)
        eng.finish()
        eng.close()


def test_meta_mismatch_is_rejected():
    """A checkpoint from a 2-layer model must not restore into a
    3-layer engine."""
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2, \
            tempfile.TemporaryDirectory() as ck:
        eng, _ = _saved_engine(d1, ck)
        eng.finish()
        eng.close()
        cfg3 = dataclasses.replace(CFG, name="ckpt-tiny-3", num_layers=3)
        other = _mk(d2, cfg=cfg3)
        with pytest.raises(CheckpointError, match="meta mismatch"):
            other.restore_checkpoint(ck)
        other.close()
