"""Chaos battery for the resilient I/O fabric (`repro.io.chaos`).

Four pins, in order of the fault-discipline ladder:

* **Integrity** — with ``IOConfig.integrity`` on, silent disk
  corruption (torn overwrites, bit flips) is caught at the next read
  as :class:`IntegrityError`; a torn FIRST write surfaces as the
  (permanent) short-read error because the file itself is short.
* **Retry** — probabilistic transient faults (EAGAIN) on every stream
  are absorbed by the engine's bounded retry, and an entire training
  run under transient chaos is BITWISE identical (losses and params)
  to its fault-free twin, across schedules, DP, and α — the
  acceptance grid.
* **Failover** — a path killed mid-run: complete-chunk overwrites
  (the caller's buffer is authoritative) re-place onto survivors and
  round-trip bitwise; placement drains off the dead device.
* **Unwind** — when a fault DOES escalate past retry and kills a
  step, the executor's failure path must leave the engine clean:
  no leaked budget/staging, no stale α gates, futures, or retained
  ``pending_grad`` tails — the next step (and a checkpoint restore)
  runs clean. Exercised as a sweep over error rates × activation
  policies, which drives faults through every priority class
  (PARAM_FETCH, OPTIMIZER_STATE, CKPT_SPILL, ACT).
"""
import os
import tempfile
import threading

import jax
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core.perfmodel import StorageRatios
from repro.data import SyntheticLM
from repro.io import (ChaosFiles, ChaosSpec, IntegrityError, IOConfig,
                      IOEngine, install_chaos)
from repro.offload import (OffloadConfig, OffloadEngine, make_engine)
from repro.offload.stores import SSDStore, TrafficMeter

T = 5.0

CFG = ArchConfig(name="chaos-tiny", family="dense", source="test",
                 num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
                 head_dim=16, d_ff=64, vocab_size=256, act="gelu")
MB, S, M = 1, 16, 4


def _chaos_store(root, n_paths=1, spec=None, **cfg_kw):
    cfg_kw.setdefault("chunk_bytes", 1 << 10)
    if n_paths > 1:
        cfg_kw.setdefault("path_policy", "backlog")
    paths = [os.path.join(root, f"nvme{i}") for i in range(n_paths)]
    eng = IOEngine(IOConfig(paths=paths, **cfg_kw))
    ssd = SSDStore(paths[0], TrafficMeter(), engine=eng)
    files = install_chaos(ssd, spec)
    return eng, ssd, files


def _drainable(eng, nbufs=None):
    """Can the FULL staging pool be acquired (nothing leaked)?"""
    nbufs = nbufs if nbufs is not None else eng.config.staging_buffers
    got = threading.Event()

    def drain():
        bufs = [eng.staging.acquire(64) for _ in range(nbufs)]
        got.set()
        for b in bufs:
            b.release()

    t = threading.Thread(target=drain, daemon=True)
    t.start()
    ok = got.wait(T)
    t.join(T)
    return ok


# ---------------------------------------------------------------------------
# defaults + transient retry
# ---------------------------------------------------------------------------

def test_default_chaosfiles_is_plain_striped():
    """All knobs off: ChaosFiles is bit-for-bit a StripedFiles."""
    with tempfile.TemporaryDirectory() as d:
        eng, ssd, files = _chaos_store(d)
        arr = np.arange(2048, dtype=np.float32)
        ssd.write("t", arr, "opt")
        np.testing.assert_array_equal(ssd.read("t", "opt"), arr)
        assert all(v == 0 for v in files.injected.values())
        s = eng.metrics_snapshot()
        assert s["chunk_retries"] == 0 and s["chunk_failovers"] == 0
        ssd.close()


def test_transient_faults_absorbed_by_retry():
    """EAGAIN chaos on every chunk op: bounded retry absorbs it, the
    data round-trips bitwise, and nothing leaks."""
    with tempfile.TemporaryDirectory() as d:
        eng, ssd, files = _chaos_store(
            d, spec=ChaosSpec(error_rate=0.3, seed=7), retries=5)
        arr = np.arange(4096, dtype=np.float32)
        ssd.write("t", arr, "opt")
        np.testing.assert_array_equal(ssd.read("t", "opt"), arr)
        assert files.injected["transient"] > 0
        s = eng.metrics_snapshot()
        assert s["chunk_retries"] == files.injected["transient"]
        assert s["inflight_bytes"] == 0
        ssd.close()


def test_transient_fault_escalates_without_retry():
    """retries=0: the same transient fault propagates to the caller on
    the first attempt (classification does not imply retry)."""
    with tempfile.TemporaryDirectory() as d:
        eng, ssd, files = _chaos_store(
            d, spec=ChaosSpec(error_rate=1.0, seed=7), retries=0)
        with pytest.raises(OSError, match="injected transient"):
            ssd.write("t", np.zeros(256, np.float32), "opt")
        assert eng.metrics_snapshot()["inflight_bytes"] == 0
        ssd.close()


# ---------------------------------------------------------------------------
# integrity: silent corruption is caught at the next read
# ---------------------------------------------------------------------------

def test_torn_overwrite_detected_by_crc():
    with tempfile.TemporaryDirectory() as d:
        eng, ssd, files = _chaos_store(d, integrity=True)
        arr = np.arange(1024, dtype=np.float32)          # 4 chunks
        ssd.write("t", arr, "opt")
        np.testing.assert_array_equal(ssd.read("t", "opt"), arr)
        files.spec = ChaosSpec(torn_write_rate=1.0, seed=1)
        ssd.write("t", arr + 1.0, "opt")                 # tears land
        files.spec = ChaosSpec()
        with pytest.raises(IntegrityError, match="CRC32C mismatch"):
            ssd.read("t", "opt")
        assert files.injected["torn"] > 0
        assert eng.metrics_snapshot()["integrity_errors"] > 0
        ssd.close()


def test_bit_flip_detected_by_crc():
    with tempfile.TemporaryDirectory() as d:
        eng, ssd, files = _chaos_store(d, integrity=True)
        files.spec = ChaosSpec(bit_flip_rate=1.0, seed=2)
        ssd.write("t", np.arange(512, dtype=np.float32), "opt")
        files.spec = ChaosSpec()
        with pytest.raises(IntegrityError, match="CRC32C mismatch"):
            ssd.read("t", "opt")
        assert files.injected["flip"] > 0
        ssd.close()


def test_torn_first_write_is_a_short_read():
    """A torn FIRST write of a single-chunk tensor leaves the file
    physically short — caught by short-read detection (permanent, no
    CRC needed), not silently zero-padded."""
    with tempfile.TemporaryDirectory() as d:
        eng, ssd, files = _chaos_store(d, integrity=True)
        files.spec = ChaosSpec(torn_write_rate=1.0, seed=3)
        ssd.write("t", np.arange(256, dtype=np.float32), "opt")  # 1 chunk
        files.spec = ChaosSpec()
        with pytest.raises(IOError, match="short read"):
            ssd.read("t", "opt")
        ssd.close()


def test_integrity_off_means_no_verification():
    """Without the opt-in, the same bit flip goes UNDETECTED — the pin
    that verification (and its sidecar cost) is strictly opt-in."""
    with tempfile.TemporaryDirectory() as d:
        eng, ssd, files = _chaos_store(d)                # integrity off
        files.spec = ChaosSpec(bit_flip_rate=1.0, seed=2)
        arr = np.arange(512, dtype=np.float32)
        ssd.write("t", arr, "opt")
        files.spec = ChaosSpec()
        back = ssd.read("t", "opt")                      # no raise
        assert not np.array_equal(back, arr)             # corrupt bytes
        ssd.close()


# ---------------------------------------------------------------------------
# failover: a path killed mid-run
# ---------------------------------------------------------------------------

def test_midrun_path_kill_write_failover():
    """Kill one of two paths while a tensor is spread across both: the
    next full overwrite (caller buffer authoritative) re-places the
    dead path's chunks onto the survivor, round-trips bitwise, and the
    dead path is drained for future placement. No budget leak."""
    with tempfile.TemporaryDirectory() as d:
        eng, ssd, files = _chaos_store(d, n_paths=2, staging_buffers=2)
        arr = np.arange(2048, dtype=np.float32)          # 8 chunks
        ssd.write("t", arr, "opt")
        on1 = [c for c in range(8) if ssd.files.placement("t", c)[0] == 1]
        assert on1, "placement never used path 1"
        files.kill_path(1)
        arr2 = arr * 2.0
        ssd.write("t", arr2, "opt")                      # fails over
        np.testing.assert_array_equal(ssd.read("t", "opt"), arr2)
        assert all(ssd.files.placement("t", c)[0] == 0 for c in range(8))
        s = eng.metrics_snapshot()
        assert s["chunk_failovers"] >= len(on1)
        assert s["paths_drained"] == [False, True]
        assert s["inflight_bytes"] == 0
        assert _drainable(eng, 2)
        # NEW tensors avoid the drained path pre-emptively
        ssd.write("u", arr, "opt")
        assert all(ssd.files.placement("u", c)[0] == 0 for c in range(8))
        np.testing.assert_array_equal(ssd.read("u", "opt"), arr)
        ssd.close()


def test_all_paths_dead_is_loud():
    """When no survivor exists the failure is loud, not a hang or a
    silent success."""
    with tempfile.TemporaryDirectory() as d:
        eng, ssd, files = _chaos_store(d, n_paths=2)
        arr = np.arange(1024, dtype=np.float32)
        ssd.write("t", arr, "opt")
        files.kill_path(0)
        files.kill_path(1)
        with pytest.raises(OSError):
            ssd.write("t", arr + 1.0, "opt")
        assert eng.metrics_snapshot()["inflight_bytes"] == 0
        ssd.close()


# ---------------------------------------------------------------------------
# the acceptance grid: transient chaos on every stream => bitwise training
# ---------------------------------------------------------------------------

GRID = [("vertical", 0.5, 1), ("horizontal", 0.0, 1),
        ("wave", 0.5, 1), ("vertical", 0.5, 2)]


def _train(schedule, alpha, ranks, spec, steps=3):
    """Losses + final assembled params for a short run, chaos-injected
    on every rank's SSD stream when ``spec`` is given."""
    io = IOConfig(retries=5, integrity=True, chunk_bytes=1 << 10)
    kw = {"wave_size": 2} if schedule == "wave" else {}
    oc = OffloadConfig(schedule=schedule, num_microbatches=M,
                       micro_batch=MB, seq_len=S,
                       ratios=StorageRatios(0.5, 0.5, 0.5),
                       alpha=alpha, io=io, activation_policy="spill",
                       **kw)
    data = SyntheticLM(CFG.vocab_size, seed=0)
    with tempfile.TemporaryDirectory() as d:
        eng = make_engine(CFG, oc, jax.random.PRNGKey(0), d,
                          num_ranks=ranks)
        stacks = eng.ranks if hasattr(eng, "ranks") else [eng]
        files = [install_chaos(s.ssd, spec) for s in stacks] \
            if spec is not None else []
        losses = [eng.train_step(data.batch(M * MB, S))
                  for _ in range(steps)]
        eng.finish()
        if hasattr(eng, "ranks"):
            params = [np.asarray(eng.read_params(l)).copy()
                      for l in range(eng.L)]
        else:
            params = [np.asarray(eng.p_vecs[l].read()).copy()
                      for l in range(eng.L)]
        injected = sum(f.injected["transient"] for f in files)
        stats = eng.ioe.metrics_snapshot() if ranks == 1 else \
            stacks[0].ioe.metrics_snapshot()
        eng.close()
    return losses, params, injected, stats


@pytest.mark.parametrize("schedule,alpha,ranks", GRID)
def test_transient_chaos_training_is_bitwise(schedule, alpha, ranks):
    """Transient faults + latency spikes on EVERY SSD stream: training
    is bitwise identical (losses and params) to the fault-free twin —
    a retried chunk op moves the same bytes to the same place, so
    recovery is invisible to the arithmetic."""
    spec = ChaosSpec(error_rate=0.05, latency_rate=0.05,
                     latency_s=0.0005, seed=11)
    ref_losses, ref_params, _, _ = _train(schedule, alpha, ranks, None)
    losses, params, injected, stats = _train(schedule, alpha, ranks, spec)
    assert injected > 0, "chaos never fired — the run proves nothing"
    assert stats["chunk_retries"] > 0
    assert losses == ref_losses, "chaos changed the loss trajectory"
    for l, (a, b) in enumerate(zip(params, ref_params)):
        np.testing.assert_array_equal(a, b,
                                      err_msg=f"layer {l} params diverged")
    assert stats["inflight_bytes"] == 0


# ---------------------------------------------------------------------------
# unwind: an escalated fault kills the step, not the engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rate,policy", [(0.02, "recompute"),
                                         (0.1, "spill")])
def test_failed_step_unwind_leaves_engine_clean(rate, policy):
    """retries=0 so every injected fault escalates and kills its step,
    across several steps (faults land in different plan phases /
    priority classes each time). After chaos is lifted the SAME engine
    must run a clean step: no stale α gates or param futures, no
    retained ``pending_grad`` tails, act coordinator empty, byte
    budget drained, staging pool fully acquirable."""
    io = IOConfig(retries=0, chunk_bytes=1 << 10)
    oc = OffloadConfig(schedule="vertical", num_microbatches=M,
                       micro_batch=MB, seq_len=S,
                       ratios=StorageRatios(0.5, 0.5, 0.5),
                       alpha=0.5, io=io, activation_policy=policy)
    data = SyntheticLM(CFG.vocab_size, seed=0)
    with tempfile.TemporaryDirectory() as d:
        eng = OffloadEngine(CFG, oc, jax.random.PRNGKey(0), d)
        files = install_chaos(eng.ssd, ChaosSpec(error_rate=rate, seed=3))
        failed = 0
        for _ in range(6):
            try:
                eng.train_step(data.batch(M * MB, S))
            except OSError:
                failed += 1
        assert failed > 0, "chaos never killed a step"
        files.spec = ChaosSpec()                 # lift the chaos
        loss = eng.train_step(data.batch(M * MB, S))
        assert np.isfinite(loss)
        eng.finish()
        assert eng.params_c._futures == {}
        # gates left by the clean step are benign: finish() flushed
        # every α tail, so firing them must be a no-op, not a re-raise
        for fn in list(eng.params_c._gate.values()):
            fn()
        assert eng.act_c._pending == {} and eng.act_c._prefetched == {}
        assert not any(f"pending_grad:{l}" in eng.host
                       for l in range(eng.L)), "stale α-tail gradient"
        s = eng.ioe.metrics_snapshot()
        assert s["inflight_bytes"] == 0, "failed steps leaked budget"
        assert _drainable(eng.ioe), "failed steps leaked staging"
        eng.close()
