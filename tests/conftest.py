import os
import sys

# Tests run on the single real CPU device. The dry-run (and ONLY the
# dry-run, spawned as a subprocess) sets the 512-device XLA flag itself.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
