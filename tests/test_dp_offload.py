"""Data-parallel sharded offload: R ranks × R SSD path sets must be a
pure re-layout of the single-rank engine — bit-identical (f32) losses
and parameters — while every per-rank byte counter matches the
``dp_vertical_traffic`` closed forms exactly."""
import os
import tempfile

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.perfmodel import StorageRatios
from repro.core.traffic import dp_vertical_traffic
from repro.data import SyntheticLM
from repro.offload import (DataParallelOffloadEngine, IOConfig,
                           OffloadConfig, OffloadEngine, shard_bounds)

CFG = get_config("gpt-tiny")
M, MB, S = 4, 2, 64


def _ocfg(alpha=0.0, ratios=StorageRatios(0.5, 0.5, 0.0), io=None):
    return OffloadConfig(schedule="vertical", num_microbatches=M,
                         micro_batch=MB, seq_len=S, alpha=alpha,
                         ratios=ratios, io=io)


def _run(alpha, ranks, steps=2, ratios=StorageRatios(0.5, 0.5, 0.0),
         io=None):
    """(losses, per-rank route dicts, final per-layer param arrays,
    (L, P)) for a single-rank (ranks=0) or DP run."""
    with tempfile.TemporaryDirectory() as d:
        if ranks == 0:
            eng = OffloadEngine(CFG, _ocfg(alpha, ratios, io),
                                jax.random.PRNGKey(7), d)
        else:
            eng = DataParallelOffloadEngine(CFG, _ocfg(alpha, ratios, io),
                                            jax.random.PRNGKey(7), d,
                                            ranks=ranks)
        data = SyntheticLM(CFG.vocab_size, seed=0)
        losses = [eng.train_step(data.batch(M * MB, S))
                  for _ in range(steps)]
        eng.finish()
        if ranks == 0:
            routes = [dict(eng.meter.bytes)]
            params = [np.asarray(eng.p_vecs[l].read())
                      for l in range(eng.L)]
        else:
            routes = [dict(rk.meter.bytes) for rk in eng.ranks]
            params = [eng.read_params(l) for l in range(eng.L)]
        shape = (eng.L, eng.P)
        eng.close()
        return losses, routes, params, shape


# ---------------------------------------------------------------------------
# bit-exact parity with the single-rank engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("alpha", [0.0, 0.5])
def test_dp_bit_identical_to_single_rank(alpha):
    """R=2 sharded offload == single rank, bit-for-bit in f32: the
    ordered collectives and elementwise shard updates commute exactly
    with the single-rank fold (§6.5 extended across the DP axis)."""
    l1, _, p1, _ = _run(alpha, ranks=0)
    l2, _, p2, _ = _run(alpha, ranks=2)
    assert l1 == l2, (l1, l2)                    # Python floats: bitwise
    for layer, (a, b) in enumerate(zip(p1, p2)):
        np.testing.assert_array_equal(a, b, err_msg=f"layer {layer}")


def test_dp_four_ranks_losses_match():
    l1, _, p1, _ = _run(0.0, ranks=0, steps=1)
    l4, _, p4, _ = _run(0.0, ranks=4, steps=1)
    assert l1 == l4
    for a, b in zip(p1, p4):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# exact per-rank byte counters vs the closed forms
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("alpha", [0.0, 0.5])
def test_dp_per_rank_counters_match_closed_form(alpha):
    steps, R = 2, 2
    _, per_rank, _, (L, P) = _run(alpha, ranks=R, steps=steps,
                                  ratios=StorageRatios(0.0, 0.0, 0.0))
    ms = L * P * 4                               # f32 engine
    cs = L * MB * S * CFG.d_model * 4
    t = dp_vertical_traffic(ms, cs, M, R, grad_bytes=ms, os_bytes=3 * ms,
                            n_layers=L)
    for r, routes in enumerate(per_rank):
        got = {k: v / steps for k, v in routes.items()}
        want = {
            ("param", "cpu->gpu"): t.param_fetch,
            ("param", "ssd->cpu"): t.param_fetch,      # x_param = 0
            ("param", "net->gpu"): t.param_allgather,
            ("param", "gpu->net"): t.param_allgather,  # even shards
            ("param", "cpu->ssd"): t.param_writeback,
            ("grad", "gpu->cpu"): t.grad_offload,
            ("grad", "net->gpu"): t.grad_reducescatter,
            ("grad", "gpu->net"): t.grad_reducescatter,
            ("opt", "ssd->cpu"): t.opt_read,
            ("opt", "cpu->ssd"): t.opt_write,
            ("ckpt", "gpu->cpu"): t.ckpt.write,
            ("ckpt", "cpu->gpu"): t.ckpt.read,
            ("ckpt", "cpu->ssd"): t.ckpt.ssd_spill,    # x_ckpt = 0
            ("ckpt", "ssd->cpu"): t.ckpt.ssd_reread,
            ("inter_grad", "gpu->cpu"): t.ckpt.inter_grad / 2,
            ("inter_grad", "cpu->gpu"): t.ckpt.inter_grad / 2,
        }
        for key, expect in want.items():
            assert got.get(key, 0) == expect, (
                f"rank {r} {key}: measured {got.get(key, 0)} per step, "
                f"closed form {expect}")


def test_single_rank_counters_match_r1_closed_form():
    """dp_vertical_traffic degenerates to the single-rank engine at R=1
    (no collectives, full shard)."""
    steps = 2
    _, (routes,), _, (L, P) = _run(0.0, ranks=0, steps=steps,
                                   ratios=StorageRatios(0.0, 0.0, 0.0))
    ms = L * P * 4
    cs = L * MB * S * CFG.d_model * 4
    t = dp_vertical_traffic(ms, cs, M, 1, grad_bytes=ms, os_bytes=3 * ms,
                            n_layers=L)
    assert t.param_allgather == t.grad_reducescatter == 0
    assert routes[("param", "cpu->gpu")] / steps == t.param_fetch
    assert routes[("grad", "gpu->cpu")] / steps == t.grad_offload
    assert routes[("opt", "ssd->cpu")] / steps == t.opt_read
    assert routes[("opt", "cpu->ssd")] / steps == t.opt_write
    assert routes[("ckpt", "cpu->gpu")] / steps == t.ckpt.read
    assert routes[("ckpt", "ssd->cpu")] / steps == t.ckpt.ssd_reread


# ---------------------------------------------------------------------------
# rank / path layout
# ---------------------------------------------------------------------------

def test_dp_ranks_drive_disjoint_path_sets():
    """With an explicit path list, IOConfig.shard_for_rank hands rank r
    paths r, r+R, ...: stripes must land only on the owning rank's
    paths, and close() must clean every path."""
    with tempfile.TemporaryDirectory() as d:
        paths = [os.path.join(d, f"nvme{i}") for i in range(4)]
        eng = DataParallelOffloadEngine(
            CFG, _ocfg(io=IOConfig(paths=paths, chunk_bytes=1 << 16)),
            jax.random.PRNGKey(7), d, ranks=2)
        assert [list(rk.ioe.paths) for rk in eng.ranks] == \
            [[paths[0], paths[2]], [paths[1], paths[3]]]
        data = SyntheticLM(CFG.vocab_size, seed=0)
        eng.train_step(data.batch(M * MB, S))
        eng.finish()
        for p in paths:
            assert os.listdir(p), f"no stripes on {p}"
        eng.close()
        for p in paths:
            assert os.listdir(p) == [], f"close() left stripes on {p}"


def test_shard_bounds_cover_contiguously():
    for n, world in [(10, 2), (7, 3), (5, 5), (3, 4)]:
        b = shard_bounds(n, world)
        assert b[0][0] == 0 and b[-1][1] == n
        assert all(b[i][1] == b[i + 1][0] for i in range(world - 1))
        sizes = [hi - lo for lo, hi in b]
        assert max(sizes) - min(sizes) <= 1


def test_dp_rejects_uneven_microbatches():
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(ValueError, match="divide evenly"):
            DataParallelOffloadEngine(CFG, _ocfg(), jax.random.PRNGKey(7),
                                      d, ranks=3)


def test_dp_aggregate_throughput_scales():
    """R=2 rank stacks with per-path SSD-speed pacing must deliver
    >= 1.6x the aggregate throughput of R=1 (the Fig. 10 storage leg;
    see benchmarks/bench_dp.py). Pacing is sleep-based, so the ratio is
    stable even on a loaded CI runner; best-of-3 guards the rest."""
    import time

    from repro.io import IOEngine, IOPriority
    from repro.offload.stores import SSDStore, TrafficMeter

    cap = 150e6
    nbytes = 16 << 20

    def measure(R):
        arr = np.zeros(nbytes, np.uint8)
        bounds = shard_bounds(nbytes, R)
        best = float("inf")
        with tempfile.TemporaryDirectory() as root:
            stacks = []
            for r in range(R):
                p = os.path.join(root, f"rank{r}")
                eng_r = IOEngine(IOConfig(paths=[p], chunk_bytes=1 << 20,
                                          bandwidth={"cpu->ssd": cap}))
                stacks.append(SSDStore(p, TrafficMeter(), engine=eng_r))
            shards = [arr[lo:hi] for lo, hi in bounds]
            for rep in range(3):
                t0 = time.perf_counter()
                reqs = [s.engine.submit(
                            (lambda s=s, sh=sh, rep=rep:
                             s.write(f"x{rep}", sh, "opt")),
                            priority=IOPriority.OPTIMIZER_STATE,
                            nbytes=sh.nbytes)
                        for s, sh in zip(stacks, shards)]
                for q in reqs:
                    q.result()
                best = min(best, time.perf_counter() - t0)
            for s in stacks:
                s.close()
        return nbytes / best

    r1, r2 = measure(1), measure(2)
    assert r2 / r1 >= 1.6, (
        f"aggregate write throughput R=1 {r1 / 1e6:.0f} MB/s -> "
        f"R=2 {r2 / 1e6:.0f} MB/s is only {r2 / r1:.2f}x (>= 1.6x "
        f"expected: the rank engines must drive their paths concurrently)")


# ---------------------------------------------------------------------------
# R-GPU performance model / LP
# ---------------------------------------------------------------------------

def test_dp_perfmodel_and_lp():
    import dataclasses

    from repro.core.lp_search import find_optimal_config, solve_config
    from repro.core.perfmodel import (MachineParams, Workload,
                                      iteration_time_vertical,
                                      iteration_time_vertical_dp,
                                      rooflines_dp)

    m = MachineParams()
    w = Workload(ms=20e9, cs=0.5e9, os_bytes=120e9, grad_bytes=40e9,
                 flops_per_mb=2e9 * 2 * 4096, tokens_per_mb=4096,
                 n_layers=32)
    x = StorageRatios(0.2, 0.2, 0.2)
    t1 = iteration_time_vertical(w, m, 8, 0.2, x)
    assert iteration_time_vertical_dp(w, m, 8, 0.2, x, R=1) == t1
    # storage-bound regime: 2 ranks with their own SSD paths must be
    # faster than 1, but no better than 2x (Amdahl + collectives)
    t2 = iteration_time_vertical_dp(w, m, 8, 0.2, x, R=2)
    assert t2 < t1
    assert t2 >= t1 / 2 - 1e-9
    # an interconnect-starved fabric becomes the binding roofline
    slow = dataclasses.replace(m, interconnect_bw=1e8)
    t2_slow = iteration_time_vertical_dp(w, slow, 8, 0.2, x, R=2)
    assert t2_slow >= 0.5 * (2 * w.ms + w.grad_bytes) / 1e8
    io_r, comp_r, ic_r = rooflines_dp(w, m, x, 4)
    io_1, comp_1, _ = rooflines_dp(w, m, x, 1)
    assert io_r == pytest.approx(io_1 / 4)       # R path sets: R x agg bw
    assert comp_r == pytest.approx(comp_1 * 4)
    # the DP LP: feasible, valid ratios, and it honours the
    # interconnect lower bound rows
    sol = solve_config(m, w, 8, 0.2, num_gpus=2)
    assert sol is not None
    assert sol.t_f >= 0.5 * w.ms / m.interconnect_bw - 1e-9
    assert sol.t_b >= 0.5 * (w.ms + w.grad_bytes) / m.interconnect_bw - 1e-9
    with pytest.raises(ValueError, match="divisible"):
        solve_config(m, w, 7, 0.2, num_gpus=2)   # n % R != 0 is an
    # argument error now — None strictly means LP-infeasible
    best = find_optimal_config(m, w, alphas=[0.0, 0.2], max_n=16,
                               num_gpus=2)
    assert best is not None and best.n % 2 == 0
