"""Hypothesis property tests on the system's invariants (deliverable c)."""
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core.lp_search import solve_config
from repro.core.perfmodel import MachineParams, Workload
from repro.core.traffic import horizontal_traffic, vertical_traffic
from repro.offload.buffers import naive_padded, pack


# ---------------------------------------------------------------------------
# Buffer packing DP (§5)
# ---------------------------------------------------------------------------

def _brute_force(n, size, max_log2=22):
    """Exhaustive search over block multisets for small instances."""
    blocks = []
    b = 1
    while b < size:
        b <<= 1
    while b <= (1 << max_log2):
        blocks.append(b)
        b <<= 1
    best = [float("inf")]

    def rec(remaining, total):
        if total >= best[0]:
            return
        if remaining <= 0:
            best[0] = min(best[0], total)
            return
        for blk in blocks:
            rec(remaining - blk // size, total + blk)

    rec(n, 0)
    return best[0]


@given(n=st.integers(1, 12), size=st.integers(1, 5000))
@settings(max_examples=60, deadline=None)
def test_pack_optimal_vs_bruteforce(n, size):
    total, blks = pack(n, size, max_block_log2=22)
    assert total == _brute_force(n, size)
    # blocks really hold n buffers
    assert sum(b // size for b in blks) >= n
    # and never worse than naive per-buffer padding
    assert total <= naive_padded(n, size)


@given(n=st.integers(1, 64), size=st.integers(1, 10 ** 7))
@settings(max_examples=60, deadline=None)
def test_pack_feasible_and_bounded(n, size):
    total, blks = pack(n, size)
    assert sum(b // size for b in blks) >= n
    assert total >= n * size
    assert all(b & (b - 1) == 0 for b in blks)  # powers of two


# ---------------------------------------------------------------------------
# Traffic model (§1/§3.4)
# ---------------------------------------------------------------------------

@given(ms=st.floats(1e6, 1e12), cs=st.floats(1e4, 1e10),
       M=st.integers(1, 64))
@settings(max_examples=100, deadline=None)
def test_vertical_param_traffic_constant_in_M(ms, cs, M):
    v = vertical_traffic(ms, cs, M)
    h = horizontal_traffic(ms, cs, M)
    assert v.param_load == 2 * ms                  # independent of M
    assert h.param_load == 2 * M * ms
    assert v.grad_swap == 2 * ms
    assert h.grad_swap == (2 * M - 1) * 2 * ms
    # the crossover claim: once M >= 2, vertical moves fewer param+grad bytes
    if M >= 2:
        assert v.param_load + v.grad_swap < h.param_load + h.grad_swap


@given(ms=st.floats(1e8, 1e11), cs_ratio=st.floats(0.01, 0.5),
       M=st.integers(2, 32))
@settings(max_examples=50, deadline=None)
def test_vertical_total_traffic_wins_when_ckpt_small(ms, cs_ratio, M):
    """§3.4: params scale quadratically vs checkpoints linearly => when
    cs < ms/4 the vertical schedule moves fewer total bytes."""
    cs = cs_ratio * ms
    v = vertical_traffic(ms, cs, M)
    h = horizontal_traffic(ms, cs, M)
    if cs <= ms / 4:
        assert v.total < h.total


# ---------------------------------------------------------------------------
# LP configuration search (Alg. 1)
# ---------------------------------------------------------------------------

@given(n=st.integers(1, 16), alpha=st.floats(0.0, 0.5),
       cpu_gb=st.floats(16, 512))
@settings(max_examples=40, deadline=None)
def test_lp_solution_feasible(n, alpha, cpu_gb):
    m = MachineParams(cpu_mem=cpu_gb * 1e9)
    w = Workload(ms=20e9, cs=0.5e9, os_bytes=120e9, grad_bytes=40e9,
                 flops_per_mb=2e9 * 2 * 4096, tokens_per_mb=4096)
    sol = solve_config(m, w, n, alpha)
    if sol is None:
        return  # infeasible is a legal outcome for tiny DRAM
    x = sol.x
    assert -1e-6 <= x.ckpt <= 1 + 1e-6
    assert -1e-6 <= x.param <= 1 + 1e-6
    assert -1e-6 <= x.opt <= 1 + 1e-6
    # CPU memory constraint honored (vertical: only transient layer grads)
    used = (n * w.cs * x.ckpt + w.ms * x.param + w.os_bytes * x.opt
            + w.grad_transient)
    assert used <= 0.95 * m.cpu_mem + 1e6
    # §4.4 reuse constraint: delayed grads fit in reclaimed param/ckpt mem
    assert alpha * w.grad_bytes <= w.ms * x.param + n * w.cs * x.ckpt + 1e6
    # t_f/t_b at least the GPU compute time
    t_f1 = w.flops_per_mb / m.gpu_flops
    assert sol.t_f >= n * t_f1 - 1e-9
    assert sol.t_b >= 3 * n * t_f1 - 1e-9


# ---------------------------------------------------------------------------
# Offload-engine schedule parity (random tiny configs)
# ---------------------------------------------------------------------------

def _engine_run(cfg, M, mb, S, alpha, ratios, seed, steps, ranks=0):
    """Run the (single-rank or DP) offload engine; return (losses,
    final per-layer flat params, initial reference pytree)."""
    import tempfile

    from repro.core.perfmodel import StorageRatios
    from repro.offload import (DataParallelOffloadEngine, OffloadConfig,
                               OffloadEngine)
    from repro.data import SyntheticLM

    ocfg = OffloadConfig(schedule="vertical", num_microbatches=M,
                         micro_batch=mb, seq_len=S, alpha=alpha, lr=1e-3,
                         ratios=StorageRatios(*ratios))
    with tempfile.TemporaryDirectory() as d:
        if ranks:
            eng = DataParallelOffloadEngine(cfg, ocfg,
                                            jax.random.PRNGKey(seed), d,
                                            ranks=ranks)
            read_layer = eng.read_params
        else:
            eng = OffloadEngine(cfg, ocfg, jax.random.PRNGKey(seed), d)
            read_layer = lambda l: np.asarray(eng.p_vecs[l].read())
        layers = [eng._unflatten(jnp.asarray(read_layer(l)))
                  for l in range(eng.L)]
        periods = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
        init_params = {"embed": eng.embed, "prefix": (),
                       "periods": {"sub0": periods}, "suffix": (),
                       "final_norm": eng.final_norm,
                       "unembed": eng.unembed}
        data = SyntheticLM(cfg.vocab_size, seed=0)
        batches = [data.batch(M * mb, S) for _ in range(steps)]
        losses = [eng.train_step(b) for b in batches]
        eng.finish()
        final = [read_layer(l) for l in range(eng.L)]
        eng.close()
    return losses, final, init_params, batches


def check_schedule_parity(L, dm, heads, dff, S, M, mb, alpha, seed,
                          steps=2):
    """The §6.5 reproducibility battery for one random tiny config:

    1. the vertical engine's losses/params are BIT-IDENTICAL (f32)
       across the α-delay and storage-ratio knobs;
    2. when M shards evenly, the R=2 DataParallelOffloadEngine is
       bit-identical too;
    3. the in-memory ``make_delayed_train_step`` reference matches to
       jit-boundary rounding: the engine runs per-layer jitted programs,
       the reference one scanned program, so XLA may legally fuse (and
       round) differently — losses agree to ~1e-3 and the parameter
       ERROR MASS stays tiny (mean |Δ| « lr) even though Adam may flip
       the sign of a few near-zero-gradient updates (max |Δ| ~ lr).
    """
    from repro.configs.base import ArchConfig
    from repro.core.schedules import ScheduleConfig, make_delayed_train_step
    from repro.optim import AdamConfig, flush_late, init_delayed, init_state

    cfg = ArchConfig(name="prop", family="dense", source="test",
                     num_layers=L, d_model=dm, num_heads=heads,
                     num_kv_heads=heads, head_dim=dm // heads, d_ff=dff,
                     vocab_size=256, act="gelu")
    lr = 1e-3
    losses, final, init_params, batches = _engine_run(
        cfg, M, mb, S, alpha, (0.5, 0.5, 0.0), seed, steps)

    # 1. bit-exact across α and storage ratios simultaneously
    losses_b, final_b, _, _ = _engine_run(
        cfg, M, mb, S, 0.0, (0.0, 0.0, 1.0), seed, steps)
    assert losses == losses_b, (losses, losses_b)
    for a, b in zip(final, final_b):
        np.testing.assert_array_equal(a, b)

    # 2. bit-exact across the data-parallel axis
    if M % 2 == 0:
        losses_dp, final_dp, _, _ = _engine_run(
            cfg, M, mb, S, alpha, (0.5, 0.5, 0.0), seed, steps, ranks=2)
        assert losses == losses_dp, (losses, losses_dp)
        for a, b in zip(final, final_dp):
            np.testing.assert_array_equal(a, b)

    # 3. in-memory reference parity (jit-boundary rounding tolerated)
    adam = AdamConfig(lr=lr)
    step_fn = make_delayed_train_step(
        cfg, ScheduleConfig(schedule="vertical", alpha=alpha), adam)
    dst = init_delayed(init_state(init_params),
                       jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                    init_params))
    ref_losses = []
    for b in batches:
        _, dst, metrics = step_fn(dst, {"tokens": jnp.asarray(b)})
        ref_losses.append(float(metrics["loss"]))
    ref_params, _ = flush_late(dst, adam, alpha, compute_dtype=jnp.float32)
    np.testing.assert_allclose(losses, ref_losses, rtol=0, atol=5e-3)
    ref_layers = ref_params["periods"]["sub0"]
    for l, eng_flat in enumerate(final):
        ref_flat = np.concatenate(
            [np.asarray(x[l]).reshape(-1)
             for x in jax.tree.leaves(ref_layers)])
        diff = np.abs(ref_flat - eng_flat)
        assert diff.max() <= 5 * lr * steps, (l, diff.max())
        assert diff.mean() <= 0.1 * lr, (l, diff.mean())


@given(data=st.data())
@settings(max_examples=3, deadline=None)
def test_offload_engine_matches_reference_random_configs(data):
    """Property form of the schedule-parity battery (the fixed-shape
    engine tests cover only gpt-tiny at M=4): random tiny dense configs,
    M in {1,2,4}, alpha in {0, 0.5}."""
    dm = data.draw(st.sampled_from([32, 64]), label="d_model")
    check_schedule_parity(
        L=data.draw(st.sampled_from([2, 3]), label="layers"),
        dm=dm,
        heads=data.draw(st.sampled_from([2, 4]), label="heads"),
        dff=data.draw(st.sampled_from([64, 128]), label="d_ff"),
        S=data.draw(st.sampled_from([8, 16]), label="seq"),
        M=data.draw(st.sampled_from([1, 2, 4]), label="microbatches"),
        mb=data.draw(st.sampled_from([1, 2]), label="micro_batch"),
        alpha=data.draw(st.sampled_from([0.0, 0.5]), label="alpha"),
        seed=data.draw(st.integers(0, 2 ** 10), label="seed"),
    )


@given(seed=st.integers(0, 2 ** 16))
@settings(max_examples=15, deadline=None)
def test_delayed_adam_random_trees(seed):
    """Random shapes/alphas: delayed == plain (f32)."""
    from repro.optim import (AdamConfig, apply_early, apply_update,
                             flush_late, init_delayed, init_state)
    rng = np.random.default_rng(seed)
    alpha = float(rng.uniform(0, 1))
    shapes = [tuple(rng.integers(1, 9, size=rng.integers(1, 3)))
              for _ in range(3)]
    params = {f"p{i}": jnp.asarray(rng.standard_normal(s), jnp.float32)
              for i, s in enumerate(shapes)}
    g = {k: jnp.asarray(rng.standard_normal(v.shape), jnp.float32)
         for k, v in params.items()}
    cfg = AdamConfig(lr=1e-2)
    p1, _ = apply_update(init_state(params), g, cfg, compute_dtype=jnp.float32)
    dst = init_delayed(init_state(params), params)
    _, dst = flush_late(dst, cfg, alpha, compute_dtype=jnp.float32)
    _, dst = apply_early(dst, g, cfg, alpha, compute_dtype=jnp.float32)
    p2, _ = flush_late(dst, cfg, alpha, compute_dtype=jnp.float32)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
