"""Trip-count-aware HLO cost analysis (repro.launch.hlo_cost).

Validation strategy:
* scan-free module: parsed flops == XLA cost_analysis == closed form;
* scan-over-layers module: XLA undercounts (body counted once); the
  parsed value must scale with num_layers and land near the analytic
  6·N·D (train) envelope;
* collective weighting: a collective inside a scan body counts
  trip_count times.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_cost


def test_matmul_exact():
    def f(a, b):
        return (a @ b @ b).sum()

    a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    comp = jax.jit(f).lower(a, b).compile()
    c = hlo_cost.analyze(comp.as_text())
    expect = 2 * 256 * 512 * 512 + 2 * 256 * 512 * 512
    assert abs(c.flops - expect) / expect < 0.01
    ca = comp.cost_analysis()
    assert abs(c.flops - ca["flops"]) / ca["flops"] < 0.05


def test_scan_weighting():
    """flops of scan(matmul, L) must scale ~L, unlike cost_analysis."""
    def make(L):
        def f(x, ws):
            def body(x, w):
                return jnp.tanh(x @ w), None
            y, _ = jax.lax.scan(body, x, ws)
            return y.sum()
        x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        ws = jax.ShapeDtypeStruct((L, 128, 128), jnp.float32)
        return jax.jit(f).lower(x, ws).compile()

    c4 = hlo_cost.analyze(make(4).as_text())
    c16 = hlo_cost.analyze(make(16).as_text())
    per_layer = 2 * 128 * 128 * 128
    assert abs(c4.flops - 4 * per_layer) / (4 * per_layer) < 0.1
    assert abs(c16.flops - 16 * per_layer) / (16 * per_layer) < 0.1
    # XLA's own analysis does NOT scale (documents why hlo_cost exists)
    ca4 = make(4).cost_analysis()["flops"]
    ca16 = make(16).cost_analysis()["flops"]
    assert abs(ca16 - ca4) / ca4 < 0.5  # body counted once in both


def test_train_step_near_model_flops():
    from repro.configs import get_smoke
    from repro.core.schedules import ScheduleConfig, make_train_step
    from repro.models import model as mdl
    from repro.optim import AdamConfig, init_state

    cfg = get_smoke("qwen3-4b")
    params_s = jax.eval_shape(lambda k: mdl.init_params(cfg, k),
                              jax.random.PRNGKey(0))
    opt_s = jax.eval_shape(init_state, params_s)
    batch_s = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
    step = make_train_step(cfg, ScheduleConfig(), AdamConfig())
    comp = jax.jit(step).lower(params_s, opt_s, batch_s).compile()
    c = hlo_cost.analyze(comp.as_text())
    model_flops = 6 * cfg.active_params() * 8 * 64
    # fwd+bwd+remat ~ 8·N·D >= parsed >= 6·N·D-ish (embed/head included
    # in N for smoke models, so allow a wide band)
    assert 0.5 <= model_flops / c.flops <= 1.5
    # bytes must be at least the XLA (loop-undercounted) number
    assert c.bytes_accessed >= 0.9 * comp.cost_analysis()["bytes accessed"]


@pytest.mark.skipif(jax.device_count() > 1, reason="needs single device")
def test_collective_in_scan_weighted():
    """psum inside a scan body counts trip_count times."""
    txt = None
    import subprocess
    import sys
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
mesh = jax.make_mesh((4,), ("d",))
def f(x):
    def body(c, _):
        y = jax.lax.psum(c, "d")
        return c + 0.001 * y, None
    out, _ = jax.lax.scan(body, x, None, length=7)
    return out
sf = jax.shard_map(f, mesh=mesh, in_specs=P("d"), out_specs=P("d"))
comp = jax.jit(sf).lower(jax.ShapeDtypeStruct((8, 128), jnp.float32)).compile()
print(comp.as_text())
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": "src"})
    assert r.returncode == 0, r.stderr[-2000:]
    c = hlo_cost.analyze(r.stdout)
    ar = c.collectives["all-reduce"]
    assert ar["count"] == 7, ar
