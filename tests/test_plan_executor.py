"""Plan-executor battery: the three-way exact cross-check
(plan_traffic == traffic.* closed forms == engine measured counters)
over schedules × M × α, the wave schedule's end-to-end interpolation,
mid-plan fault cleanup, and the measured-bench → LP plumbing.
"""
import os
import sys
import tempfile

import jax
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core.perfmodel import StorageRatios, machine_from_bench
from repro.core.plan import PlanCosts, plan_traffic
from repro.core.traffic import wave_ckpt_traffic
from repro.data import SyntheticLM
from repro.offload import (DataParallelOffloadEngine, OffloadConfig,
                           OffloadEngine)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

CFG = ArchConfig(name="plan-tiny", family="dense", source="test",
                 num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
                 head_dim=16, d_ff=64, vocab_size=256, act="gelu")
MB, S = 1, 16


def _run(schedule, M, alpha, W=0, ranks=0, steps=2,
         ratios=StorageRatios(0.0, 0.0, 0.0), seed=7):
    """(losses, measured per-iter routes, plan_traffic prediction,
    (L, P, plan)) for one engine run with finish() drained."""
    ocfg = OffloadConfig(schedule=schedule, num_microbatches=M,
                         micro_batch=MB, seq_len=S, alpha=alpha,
                         wave_size=W, ratios=ratios)
    with tempfile.TemporaryDirectory() as d:
        if ranks:
            eng = DataParallelOffloadEngine(CFG, ocfg,
                                            jax.random.PRNGKey(seed), d,
                                            ranks=ranks)
            meters = [rk.meter for rk in eng.ranks]
        else:
            eng = OffloadEngine(CFG, ocfg, jax.random.PRNGKey(seed), d)
            meters = [eng.meter]
        data = SyntheticLM(CFG.vocab_size, seed=0)
        losses = [eng.train_step(data.batch(M * MB, S))
                  for _ in range(steps)]
        eng.finish()
        measured = [{k: v / steps for k, v in m.bytes.items()}
                    for m in meters]
        pred = plan_traffic(eng._plan, PlanCosts.from_engine(eng))
        shape = (eng.L, eng.P, eng._plan)
        eng.close()
    if not ranks:
        measured, pred = measured[0], [pred][0]
    return losses, measured, pred, shape


def _closed_form(L, P, M, W):
    """The exact (category, route) byte map for the f32 engine at
    x = (0,0,0): the wave_ckpt_traffic counters plus the param/grad/opt
    schedule forms (ms = L·P·4 here because params are f32, so f32
    grads == ms and optimizer state == 3·ms)."""
    ms = L * P * 4
    u = MB * S * CFG.d_model * 4
    nw = M // W
    ct = wave_ckpt_traffic(L * u, M, W, L)
    exp = {
        ("param", "ssd->cpu"): 2 * nw * ms,
        ("param", "cpu->gpu"): 2 * nw * ms,
        ("param", "cpu->ssd"): ms,
        ("grad", "gpu->cpu"): nw * ms,
        ("grad", "cpu->gpu"): (nw - 1) * ms,
        ("opt", "ssd->cpu"): 3 * ms,
        ("opt", "cpu->ssd"): 3 * ms,
        ("ckpt", "gpu->cpu"): ct.write,
        ("ckpt", "cpu->gpu"): ct.read,
        ("ckpt", "cpu->ssd"): ct.ssd_spill,
        ("ckpt", "ssd->cpu"): ct.ssd_reread,
        ("inter_grad", "gpu->cpu"): ct.inter_grad / 2,
        ("inter_grad", "cpu->gpu"): ct.inter_grad / 2,
    }
    return {k: v for k, v in exp.items() if v}


# ---------------------------------------------------------------------------
# the three-way exact cross-check (satellite: hypothesis-style sweep)
# ---------------------------------------------------------------------------

SWEEP = [(sched, M, alpha)
         for sched in ("vertical", "horizontal", "wave")
         for M in (1, 2, 4)
         for alpha in (0.0, 0.5)
         if not (sched == "wave" and M % 2)]


@pytest.mark.parametrize("sched,M,alpha", SWEEP)
def test_three_way_traffic_crosscheck(sched, M, alpha):
    """plan_traffic(plan) == wave closed forms == measured counters,
    EXACTLY, for every schedule/M/α cell — the IR, the analysis, and
    the running system agree byte-for-byte."""
    W = {"vertical": M, "horizontal": 1, "wave": 2}[sched]
    losses, measured, pred, (L, P, _) = _run(sched, M, alpha, W=W)
    assert all(np.isfinite(losses))
    want = _closed_form(L, P, M, W)
    assert pred == want, ("plan_traffic vs closed form", sched, M, alpha)
    assert measured == want, ("measured vs closed form", sched, M, alpha)


def test_three_way_crosscheck_nonzero_ratios():
    """With partial CPU residency (no closed form pinned at these
    ratios) the static prediction still matches the meters exactly —
    the analyzer replicates TieredVector's rounding."""
    for sched, W in (("vertical", 4), ("wave", 2), ("horizontal", 1)):
        _, measured, pred, _ = _run(sched, 4, 0.5, W=W,
                                    ratios=StorageRatios(0.5, 0.25, 0.5))
        assert measured == pred, sched


@pytest.mark.parametrize("alpha", [0.0, 0.5])
def test_dp_three_way_crosscheck(alpha):
    """R=2: per-rank measured counters == per-rank plan_traffic
    (ALLGATHER / REDUCE_SCATTER / ALLREDUCE_HEAD analyzer paths)."""
    _, measured, pred, _ = _run("vertical", 4, alpha, ranks=2)
    assert len(measured) == len(pred) == 2
    for r, (m, p) in enumerate(zip(measured, pred)):
        assert m == p, f"rank {r}"


# ---------------------------------------------------------------------------
# schedule semantics pinned by the executor
# ---------------------------------------------------------------------------

def test_horizontal_m1_equals_vertical_bitwise():
    """At M=1 the schedules coincide, and the compiled horizontal plan
    now reaches the optimizer: the pre-IR imperative horizontal engine
    parked the single micro-batch's layer gradients in host memory and
    never submitted them (its m==0 branch), silently freezing every
    pipelined layer. Regression-pin the fix as bitwise equality with
    the vertical engine."""
    lv, _, _, _ = _run("vertical", 1, 0.0, W=1, steps=3,
                       ratios=StorageRatios(0.5, 0.5, 0.0))
    lh, _, _, _ = _run("horizontal", 1, 0.0, W=1, steps=3,
                       ratios=StorageRatios(0.5, 0.5, 0.0))
    assert lv == lh, (lv, lh)
    # and training actually progresses: step-3 loss moved from step-1
    assert lh[2] != lh[0]


def test_wave_losses_invariant_across_W():
    """W only re-orders storage traffic, so step-1 losses (forward of
    identical parameters, identical per-micro-batch fold) are
    bit-identical across the whole knob. From step 2 on, equality is
    within jit rounding only: the cross-wave f32 accumulation GROUPS
    differently — vertical folds ((d0+d1)+d2)+d3 where a 2-wave run
    folds (d0+d1)+(d2+d3) via the parked partial — so the optimizer
    sees ulp-level-different sums. This was ALWAYS true (measured: the
    pre-IR fused backward's W=2 accumulators already differed from
    vertical's in ~2.4k elements); the old bitwise-loss pin held only
    because those ulp param deltas happened not to move the loss scalar
    with the fused backward's values. Per-micro-batch gradients ARE
    bitwise-invariant across W, and the spill/recompute policy axis is
    bitwise by construction — ``tests/test_act_stream.py``."""
    ref = None
    for sched, W in (("vertical", 4), ("wave", 2), ("horizontal", 1)):
        losses, _, _, _ = _run(sched, 4, 0.5, W=W)
        if ref is None:
            ref = losses
        else:
            assert losses[0] == ref[0], (sched, losses, ref)
            np.testing.assert_allclose(losses, ref, rtol=1e-5)


def test_wave_interpolates_measured_traffic():
    """The acceptance datapoint, on the live engine: sweeping W trades
    parameter reloads against checkpoint + inter-layer-gradient bytes
    monotonically, with the endpoints being the two paper schedules."""
    rows = {}
    for W in (1, 2, 4):
        _, measured, _, _ = _run("wave", 4, 0.0, W=W, steps=1)
        rows[W] = (
            measured.get(("param", "cpu->gpu"), 0),
            measured.get(("ckpt", "cpu->gpu"), 0)
            + measured.get(("inter_grad", "cpu->gpu"), 0)
            + measured.get(("inter_grad", "gpu->cpu"), 0))
    assert rows[1][0] > rows[2][0] > rows[4][0]
    assert rows[1][1] < rows[2][1] < rows[4][1]


# ---------------------------------------------------------------------------
# mid-plan fault cleanup (satellite bugfix)
# ---------------------------------------------------------------------------

def _faulty_engine(d, M=4):
    from repro.io import install_chaos

    eng = OffloadEngine(CFG, OffloadConfig(
        schedule="vertical", num_microbatches=M, micro_batch=MB, seq_len=S,
        ratios=StorageRatios(0.0, 0.0, 0.0)), jax.random.PRNGKey(3), d)
    install_chaos(eng.ssd)                   # init writes stay intact
    return eng


def _assert_clean(eng):
    assert eng.ckpt_c._device_kept == {}, "leaked device-kept tensors"
    assert eng.ckpt_c._pending == {}, "leaked in-flight spills"
    assert eng.params_c._futures == {}, "leaked param prefetches"
    assert eng.host.nbytes() == 0, "leaked host buffers"


def test_param_fetch_fault_releases_slots_and_recovers():
    """A failing parameter fetch early in the forward pass surfaces as
    the step's exception; the executor must release the already-kept
    embedding boundary tensors and cancel prefetches, and the engine
    must run a clean step afterwards."""
    with tempfile.TemporaryDirectory() as d:
        eng = _faulty_engine(d)
        data = SyntheticLM(CFG.vocab_size, seed=0)
        eng.ssd.files.fail_reads = 1
        with pytest.raises(OSError, match="injected read fault"):
            eng.train_step(data.batch(4 * MB, S))
        _assert_clean(eng)
        loss = eng.train_step(data.batch(4 * MB, S))   # fuse expired
        assert np.isfinite(loss)
        eng.finish()
        _assert_clean(eng)
        eng.close()


def test_mid_backward_spill_fault_releases_slots():
    """A checkpoint-spill write fault surfaces mid-backward (when the
    recompute waits on the spill), with device-kept gradients live —
    exactly the state that used to leak across steps."""
    with tempfile.TemporaryDirectory() as d:
        eng = _faulty_engine(d)
        data = SyntheticLM(CFG.vocab_size, seed=0)
        eng.ssd.files.fail_writes = 1
        with pytest.raises(OSError, match="injected write fault"):
            eng.train_step(data.batch(4 * MB, S))
        _assert_clean(eng)
        loss = eng.train_step(data.batch(4 * MB, S))
        assert np.isfinite(loss)
        eng.close()


# ---------------------------------------------------------------------------
# measured bench rates -> MachineParams -> Algorithm 1 (satellite)
# ---------------------------------------------------------------------------

SAMPLE = os.path.join(os.path.dirname(__file__), "data",
                      "bench_io_sample.json")


def test_machine_from_bench_roundtrip():
    import json

    with open(SAMPLE) as f:
        raw = json.load(f)
    m = machine_from_bench(SAMPLE)
    assert m.ssd_read_bw == max(v["read_bps"] for v in raw["paths"].values())
    assert m.ssd_write_bw == max(v["write_bps"]
                                 for v in raw["paths"].values())
    assert m.name.endswith("-bench")
    # dict input round-trips identically
    assert machine_from_bench(raw) == m
    # Algorithm 1 solves against the measured machine
    from repro.core.lp_search import solve_config
    from repro.core.perfmodel import Workload
    w = Workload(ms=2e9, cs=0.1e9, os_bytes=12e9, grad_bytes=4e9,
                 flops_per_mb=1e12, tokens_per_mb=4096)
    sol = solve_config(m, w, 8, 0.2)
    assert sol is not None and sol.iteration_time > 0
    # slower measured SSDs than the datasheet default => a longer
    # storage-bound iteration (sanity that the rates actually plug in)
    from repro.core.perfmodel import MachineParams
    sol_fast = solve_config(MachineParams(), w, 8, 0.2)
    assert sol.iteration_time >= sol_fast.iteration_time


def test_bench_engine_wave_smoke():
    """The CI plan-battery datapoint: bench_engine --schedule wave
    --smoke must run the three-plan sweep and assert the interpolation
    (under pytest-timeout like everything else here)."""
    from benchmarks import bench_engine

    bench_engine.main(["--schedule", "wave", "--smoke"])
