"""Online autotuner battery (ROADMAP item 3, controller half).

Four pillars:

* scripted-snapshot ``decide()`` tests — every controller branch
  (hold under noise below hysteresis, retune on drift, blocked on a
  reconcile error above the gate, cooldown / budget bounded
  frequency) driven from REAL window snapshots mutated in place, no
  timing dependence;
* the plan-swap seam pin — two measured iterations, a mid-training
  ``apply_plan_config`` wave 2 -> 4 swap, two more iterations must be
  BITWISE identical to an engine compiled with the second plan from
  the same checkpointed state (the swap leaks no per-plan state);
* seam atomicity — an invalid knob raises ``ValueError`` and leaves
  the engine running its current plan;
* trajectory neutrality — autotune ON (live depth retunes) vs OFF
  across the schedule x M x alpha x R grid: bitwise-identical f32
  params and losses, because the default candidate axes are the
  proven bitwise-invariant knobs.
"""
import tempfile

import jax
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core.perfmodel import MachineParams, StorageRatios
from repro.data import SyntheticLM
from repro.offload import (AutotuneConfig, AutotuneController,
                           DataParallelOffloadEngine, OffloadConfig,
                           OffloadEngine, route_seconds_error)

CFG = ArchConfig(name="autotune-tiny", family="dense", source="test",
                 num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
                 head_dim=16, d_ff=64, vocab_size=256, act="gelu")
MB, S = 1, 16
X0 = StorageRatios(0.0, 0.0, 0.0)

#: the acceptance grid: schedule x M x alpha x R (wave needs M % 2 == 0,
#: DP plans are vertical with M % R == 0) — the test_obs grid shape
GRID = [(sched, M, alpha, R)
        for sched in ("vertical", "horizontal", "wave")
        for M in (2, 4)
        for alpha in (0.0, 0.5)
        for R in (1, 2)
        if not (sched == "wave" and M % 2)
        and not (R > 1 and (sched != "vertical" or M % R))]


def _build(sched, M, alpha, R, workdir, depth=1, wave=None):
    W = {"vertical": 0, "horizontal": 0, "wave": 2}[sched] \
        if wave is None else wave
    ocfg = OffloadConfig(schedule=sched, num_microbatches=M,
                         micro_batch=MB, seq_len=S, alpha=alpha,
                         wave_size=W, ratios=X0, prefetch_depth=depth)
    if R > 1:
        return DataParallelOffloadEngine(CFG, ocfg, jax.random.PRNGKey(11),
                                         workdir, ranks=R)
    return OffloadEngine(CFG, ocfg, jax.random.PRNGKey(11), workdir)


def _window(eng, ctl, steps=2, seed=0):
    """Run ``steps`` measured iterations and return the window
    snapshot WITHOUT committing a decision (scripted tests drive
    ``ctl.decide`` by hand)."""
    data = SyntheticLM(CFG.vocab_size, seed=seed)
    M = eng.ocfg.num_microbatches
    for _ in range(steps):
        eng.train_step(data.batch(M * MB, S))
    return eng.metrics_snapshot()


#: A machine where the lookahead LP rows genuinely bind for the tiny
#: test model: compute slow enough to be the stage bound, DRAM too
#: small to cache the optimizer tail, SSD slow enough that the
#: serialized (depth-0) reads cost real fractions of a stage — so
#: depth > 0 wins by several percent and the controller has a true
#: signal to act on. The default A100-node machine caches this whole
#: model in DRAM and every depth ties.
DRIFT_MACHINE = MachineParams(name="drift", gpu_flops=1e7,
                              ssd_read_bw=1e6, ssd_write_bw=1e6,
                              cpu_mem=2e5)
DRIFT_RATE = 1e6


def _script_drift(snap, rate=DRIFT_RATE):
    """Rewrite the window's measured route rates to a slow device,
    keeping bytes and wall seconds self-consistent so the reconcile
    gate stays green: the scripted-drift scenario (the live device got
    slower than the configured machine)."""
    for d in snap["trace"]["routes"].values():
        if d.get("bytes"):
            d["busy_wall_s"] = d["bytes"] / rate
            d["rate_bps"] = rate
    return snap


def _drift_snapshots(eng):
    """Make every window the controller measures look like the drifted
    device (the scripted-snapshot hook for full-loop tests)."""
    real = eng.metrics_snapshot
    eng.metrics_snapshot = lambda: _script_drift(real())


# ---------------------------------------------------------------------------
# the scalar gate
# ---------------------------------------------------------------------------

def test_route_seconds_error_scalar():
    assert route_seconds_error({}, {}) == 0.0
    assert route_seconds_error({"ssd->cpu": 1.0}, {}) == 0.0
    assert route_seconds_error({"ssd->cpu": 1.0},
                               {"ssd->cpu": 1.0}) == 0.0
    assert route_seconds_error({"ssd->cpu": 1.0},
                               {"ssd->cpu": 2.0}) == pytest.approx(0.5)
    # worst route wins
    assert route_seconds_error(
        {"ssd->cpu": 1.0, "cpu->ssd": 1.0},
        {"ssd->cpu": 1.1, "cpu->ssd": 4.0}) == pytest.approx(0.75)
    # both sides under the floor: micro-transfer noise is ignored
    assert route_seconds_error({"ssd->cpu": 1e-5}, {"ssd->cpu": 1e-4},
                               floor_s=1e-3) == 0.0


def test_autotune_config_validates():
    with pytest.raises(ValueError, match="interval"):
        AutotuneConfig(interval=0)
    with pytest.raises(ValueError, match="hysteresis"):
        AutotuneConfig(hysteresis=-0.1)


# ---------------------------------------------------------------------------
# scripted-snapshot decide(): every branch, no timing dependence
# ---------------------------------------------------------------------------

def test_decide_holds_when_current_is_best():
    """Default axes = current knobs only: the controller can only ever
    hold, and the decision is pure w.r.t. engine state."""
    with tempfile.TemporaryDirectory() as d:
        eng = _build("vertical", 2, 0.0, 1, d, depth=1)
        ctl = AutotuneController(eng, AutotuneConfig(interval=2))
        snap = _window(eng, ctl)
        dec = ctl.decide(snap, steps=2)
        assert dec["action"] == "hold"
        assert dec["best"] == dec["current"]
        assert eng.ocfg.resolved_prefetch_depth() == 1   # untouched
        eng.close()


def test_decide_holds_under_noise_below_hysteresis():
    """A real predicted win that does not clear the hysteresis band is
    a hold — meter noise must not thrash the plan."""
    with tempfile.TemporaryDirectory() as d:
        eng = _build("vertical", 2, 0.5, 1, d, depth=0)
        ctl = AutotuneController(
            eng, AutotuneConfig(interval=2, prefetch_depths=(0, 1),
                                hysteresis=1e9, machine=DRIFT_MACHINE))
        snap = _script_drift(_window(eng, ctl))
        dec = ctl.decide(snap, steps=2)
        assert dec["action"] == "hold"
        assert "hysteresis" in dec["reason"]
        # the win was real (depth 1 strictly beats the lookahead-off
        # LP row) — just not big enough for the configured band
        assert dec["predicted_win"] is not None
        assert dec["predicted_win"] > 1.0
        eng.close()


def test_decide_retunes_on_drift():
    """With the band at zero the same predicted win becomes a retune —
    and ``decide`` stays pure: only ``post_step`` commits the swap."""
    with tempfile.TemporaryDirectory() as d:
        eng = _build("vertical", 2, 0.5, 1, d, depth=0)
        ctl = AutotuneController(
            eng, AutotuneConfig(interval=2, prefetch_depths=(0, 1),
                                hysteresis=0.0, machine=DRIFT_MACHINE))
        snap = _script_drift(_window(eng, ctl))
        dec = ctl.decide(snap, steps=2)
        assert dec["action"] == "retune"
        assert dec["changes"] == {"prefetch_depth": 1}
        assert dec["best"]["pred_s"] < dec["current"]["pred_s"]
        assert eng.ocfg.resolved_prefetch_depth() == 0   # decide is pure
        # candidates always lead with the current knobs
        assert dec["candidates"][0]["depth"] == 0
        eng.close()


def test_decide_blocked_on_reconcile_error():
    """A model that cannot explain the current plan's route seconds is
    not allowed to pick the next plan."""
    with tempfile.TemporaryDirectory() as d:
        eng = _build("vertical", 2, 0.5, 1, d, depth=0)
        ctl = AutotuneController(
            eng, AutotuneConfig(interval=2, prefetch_depths=(0, 1),
                                hysteresis=0.0, error_gate=0.5,
                                machine=DRIFT_MACHINE))
        snap = _script_drift(_window(eng, ctl))
        # script a measured wall-clock envelope the model cannot
        # explain: 1000 s on a route the plan predicts in micro-seconds
        snap["trace"]["routes"]["cpu->ssd"]["busy_wall_s"] = 1000.0
        dec = ctl.decide(snap, steps=2)
        assert dec["action"] == "blocked"
        assert dec["route_error"] > 0.5
        assert "candidates" not in dec          # never got to scoring
        eng.close()


def test_decide_bounded_frequency_cooldown_and_budget():
    with tempfile.TemporaryDirectory() as d:
        eng = _build("vertical", 2, 0.5, 1, d, depth=0)
        ctl = AutotuneController(
            eng, AutotuneConfig(interval=2, prefetch_depths=(0, 1),
                                hysteresis=0.0, cooldown=2,
                                max_retunes=0))
        snap = _window(eng, ctl)
        # a pending cooldown short-circuits everything
        ctl._cooldown = 2
        dec = ctl.decide(snap, steps=2)
        assert dec["action"] == "cooldown"
        # budget spent: measured forever, swapped never
        ctl._cooldown = 0
        dec = ctl.decide(snap, steps=2)
        assert dec["action"] == "hold"
        assert "budget" in dec["reason"]
        eng.close()


def test_post_step_loop_swaps_once_then_cools_down():
    """The committed loop end-to-end: one retune fires, the cooldown
    window follows, the swap actually landed on the engine, and the
    decision log rides in the next metrics snapshot."""
    with tempfile.TemporaryDirectory() as d:
        eng = _build("vertical", 2, 0.5, 1, d, depth=0)
        _drift_snapshots(eng)
        ctl = AutotuneController(
            eng, AutotuneConfig(interval=1, prefetch_depths=(0, 1),
                                hysteresis=0.0, cooldown=1,
                                max_retunes=1, machine=DRIFT_MACHINE))
        data = SyntheticLM(CFG.vocab_size, seed=0)
        decisions = []
        for _ in range(4):
            eng.train_step(data.batch(2 * MB, S))
            dec = ctl.post_step()
            assert dec is not None              # interval=1
            decisions.append(dec)
        actions = [dc["action"] for dc in decisions]
        assert actions[0] == "retune"
        assert actions[1] == "cooldown"
        assert set(actions[2:]) <= {"hold", "blocked"}
        assert ctl.retunes == 1
        assert eng.ocfg.resolved_prefetch_depth() == 1   # swap landed
        # per-path steering signal is advisory but always logged
        assert decisions[0]["paths"][0]["least_loaded_path"] >= 0
        assert decisions[0]["paths"][0]["imbalance"] >= 0.0
        eng.finish()
        snap = eng.metrics_snapshot()
        assert [dc["action"] for dc in snap["autotune"]] == actions
        eng.close()


# ---------------------------------------------------------------------------
# the plan-swap seam: bitwise pin + atomicity
# ---------------------------------------------------------------------------

def test_wave_swap_bitwise_equals_recompile_from_checkpoint():
    """2 iters -> apply_plan_config(wave 2 -> 4) -> 2 iters must equal,
    bitwise, an engine COMPILED with the second plan from the same
    checkpointed state: the swap leaks no per-plan state (alpha gates,
    pinned fetches, spill queues, stale plan closures). The checkpoint
    goes through the engine's durable ``save_checkpoint`` /
    ``restore_checkpoint`` (``repro.offload.checkpoint``) — the
    promotion of the ad-hoc state dict this test originally grew."""
    data = SyntheticLM(CFG.vocab_size, seed=0)
    batches = [data.batch(4 * MB, S) for _ in range(4)]
    with tempfile.TemporaryDirectory() as da, \
            tempfile.TemporaryDirectory() as db, \
            tempfile.TemporaryDirectory() as dc, \
            tempfile.TemporaryDirectory() as ck:
        # the swapped engine
        a = _build("wave", 4, 0.5, 1, da, depth=1, wave=2)
        losses_a = [a.train_step(b) for b in batches[:2]]
        a.apply_plan_config(wave_size=4)
        assert not a.params_c._gate              # seam cleared the gates
        assert a.ocfg.resolved_wave_size() == 4
        losses_a += [a.train_step(b) for b in batches[2:]]
        a.finish()
        params_a = [a.p_vecs[l].read().copy() for l in range(a.L)]
        a.close()

        # the reference: same first half on a twin, checkpoint...
        b_eng = _build("wave", 4, 0.5, 1, db, depth=1, wave=2)
        losses_b = [b_eng.train_step(b) for b in batches[:2]]
        assert losses_b == losses_a[:2]          # determinism baseline
        b_eng.save_checkpoint(ck)    # finish() == the seam's quiesce
        b_eng.close()

        # ...restored into an engine BORN with the second plan
        c = _build("wave", 4, 0.5, 1, dc, depth=1, wave=4)
        assert c.restore_checkpoint(ck) == b_eng.step_num
        losses_c = [c.train_step(b) for b in batches[2:]]
        c.finish()
        params_c = [c.p_vecs[l].read().copy() for l in range(c.L)]
        c.close()

    assert losses_a[2:] == losses_c              # float-exact
    for pa, pc in zip(params_a, params_c):
        assert np.array_equal(pa, pc)            # bitwise


def test_apply_plan_config_invalid_knob_is_atomic():
    """Validate-then-commit: a bad knob raises and the engine keeps
    training on its current plan with its current config."""
    with tempfile.TemporaryDirectory() as d:
        eng = _build("wave", 4, 0.0, 1, d, depth=1, wave=2)
        data = SyntheticLM(CFG.vocab_size, seed=0)
        eng.train_step(data.batch(4 * MB, S))
        with pytest.raises(ValueError):
            eng.apply_plan_config(wave_size=3)           # 3 does not divide 4
        with pytest.raises(ValueError):
            eng.apply_plan_config(activation_policy="levitate")
        assert eng.ocfg.resolved_wave_size() == 2        # untouched
        assert eng.act_policy == "recompute"
        loss = eng.train_step(data.batch(4 * MB, S))     # still alive
        assert np.isfinite(loss)
        eng.close()


# ---------------------------------------------------------------------------
# acceptance: retuning is trajectory-neutral (autotune on vs off)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sched,M,alpha,R", GRID)
def test_autotune_on_vs_off_bitwise(sched, M, alpha, R):
    """Autotune ON (live depth retunes from measured windows) vs OFF:
    identical f32 losses and bitwise-identical params on every grid
    cell — a retune changes when bytes move, never what is learned."""
    steps = 3

    def run(autotune):
        with tempfile.TemporaryDirectory() as d:
            eng = _build(sched, M, alpha, R, d, depth=0)
            ctl = None
            if autotune:
                _drift_snapshots(eng)
                ctl = AutotuneController(
                    eng, AutotuneConfig(interval=1, hysteresis=0.0,
                                        cooldown=0, machine=DRIFT_MACHINE,
                                        prefetch_depths=(0, 1, 2)))
            data = SyntheticLM(CFG.vocab_size, seed=0)
            losses = []
            for _ in range(steps):
                losses.append(eng.train_step(data.batch(M * MB, S)))
                if ctl is not None:
                    ctl.post_step()
            eng.finish()
            if R > 1:
                params = [eng.read_params(l).copy() for l in range(eng.L)]
            else:
                params = [eng.p_vecs[l].read().copy() for l in range(eng.L)]
            retunes = ctl.retunes if ctl is not None else 0
            eng.close()
        return losses, params, retunes

    l_off, p_off, _ = run(autotune=False)
    l_on, p_on, retunes = run(autotune=True)
    assert l_off == l_on
    for a, b in zip(p_off, p_on):
        assert np.array_equal(a, b)              # bitwise
    # under the drifted machine the lookahead win is only guaranteed
    # on the cells where the serialized depth-0 reads carry an α tail
    # (the fwd stall term is α-scaled; DP halves every per-rank I/O
    # term, so the R=2 LP can tie and legitimately hold) — there the
    # swap MUST have run, so the bitwise check above really covers a
    # mid-training retune
    if sched == "vertical" and alpha > 0.0 and R == 1:
        assert retunes >= 1
