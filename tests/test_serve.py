"""repro.serve tests: the KV plan lint (meta-test of the serve hint
contract), the three-way KV byte-exactness sweep (plan prediction ==
measured meters == ``traffic.kv_traffic`` closed form), admission
control (eager budget refusal, preempt-to-SSD-and-bitwise-resume), the
``stats()`` -> ``metrics_snapshot()`` deprecation shims, and the eager
config-validation parity contract."""
import json
import tempfile
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.plan import Op, Plan, PlanOp, PlanSpec
from repro.core.traffic import kv_blocks, kv_traffic
from repro.io import IOConfig
from repro.models import model as mdl
from repro.offload import (DataParallelOffloadEngine, OffloadConfig,
                           OffloadEngine, make_engine)
from repro.serve import (ServeConfig, ServeEngine, compile_serve_step,
                         lint_kv_plan)

CFG = get_config("gpt-tiny")
MAX_LEN = 12            # engine-wide: fixed so jit caches stay warm
PROMPT_LEN = 4
BB = 4096               # kv block size for every serve test


def _blocks_per_request(max_len=MAX_LEN, bb=BB):
    template = mdl.init_caches(CFG, 1, max_len, dtype=jnp.float32)
    return sum(kv_blocks(nb, bb)
               for nb in mdl.cache_unit_nbytes(CFG, template))


def _engine(workdir, *, capacity_requests=8, **kw):
    """ServeEngine with a KV budget of exactly ``capacity_requests``
    requests' worth of blocks."""
    budget = capacity_requests * _blocks_per_request() * BB
    scfg = ServeConfig(max_len=MAX_LEN, kv_block_bytes=BB,
                       kv_budget_bytes=budget, **kw)
    return ServeEngine(CFG, scfg, jax.random.PRNGKey(0), workdir)


def _prompts(n, seed=0):
    rng = np.random.default_rng(seed)
    return [[int(t) for t in rng.integers(0, CFG.vocab_size, PROMPT_LEN)]
            for _ in range(n)]


def _drain(eng, preempt_rid=None, preempt_after=2):
    """Step to completion, optionally preempting one request once."""
    steps = 0
    while eng.pending():
        eng.step()
        steps += 1
        if preempt_rid is not None and steps == preempt_after and \
                eng.requests[preempt_rid].state == "running":
            eng.preempt(preempt_rid)
        assert steps < 200, "serve loop did not converge"


# ---------------------------------------------------------------------------
# KV plan lint (meta-test)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("depth", [1, 2, 3])
@pytest.mark.parametrize("evict,resume,prefill,decode", [
    ((), (), (0, 1), ()),
    ((), (), (2,), (0, 1)),
    ((0,), (), (), (1, 2)),
    ((0,), (0,), (), (1,)),          # same-step evict + resume
    ((0, 1), (2, 3), (4,), (5,)),
])
def test_compiled_plans_pass_lint(depth, evict, resume, prefill, decode):
    plan = compile_serve_step(4, evict=evict, resume=resume,
                              prefill=prefill, decode=decode,
                              prefetch_depth=depth)
    assert lint_kv_plan(plan) == []


def test_every_fetch_kv_has_exactly_one_hint():
    plan = compile_serve_step(4, evict=(0,), resume=(0, 1), decode=(2,),
                              prefetch_depth=2)
    hints, fetches = {}, {}
    for op in plan.ops:
        if op.op is Op.PREFETCH_KV:
            hints[(op.l, op.m)] = hints.get((op.l, op.m), 0) + 1
        elif op.op is Op.FETCH_KV:
            fetches[(op.l, op.m)] = fetches.get((op.l, op.m), 0) + 1
    assert fetches and set(hints) == set(fetches)
    assert all(n == 1 for n in hints.values())
    assert all(n == 1 for n in fetches.values())


def test_depth_zero_plan_is_hint_free_and_legal():
    plan = compile_serve_step(3, evict=(0,), resume=(0,), decode=(1,),
                              prefetch_depth=0)
    assert plan.count(Op.PREFETCH_KV) == 0
    assert plan.count(Op.FETCH_KV) == 3
    assert lint_kv_plan(plan) == []


def _raw(ops):
    return Plan(schedule="serve", spec=PlanSpec(L=2, M=1), W=1,
                ops=tuple(ops))


def test_lint_catches_hint_crossing_eviction():
    # hint issued BEFORE a SPILL_KV that its fetch then reads past
    plan = _raw([PlanOp(Op.PREFETCH_KV, l=0, m=1),
                 PlanOp(Op.SPILL_KV, l=0, m=2),
                 PlanOp(Op.FETCH_KV, l=0, m=1)])
    assert any("crosses" in e for e in lint_kv_plan(plan))


def test_lint_catches_orphan_and_missing_hints():
    orphan = _raw([PlanOp(Op.PREFETCH_KV, l=0, m=1)])
    assert any("orphan" in e for e in lint_kv_plan(orphan))
    # a hinted plan where one fetch has no hint
    missing = _raw([PlanOp(Op.PREFETCH_KV, l=0, m=1),
                    PlanOp(Op.FETCH_KV, l=0, m=1),
                    PlanOp(Op.FETCH_KV, l=1, m=1)])
    assert any("0 hint" in e for e in lint_kv_plan(missing))


def test_lint_catches_hint_after_fetch():
    plan = _raw([PlanOp(Op.FETCH_KV, l=0, m=1),
                 PlanOp(Op.PREFETCH_KV, l=0, m=1)])
    errs = lint_kv_plan(plan)
    assert any("not before" in e for e in errs)


# ---------------------------------------------------------------------------
# three-way byte exactness: plan == meter == closed form
# ---------------------------------------------------------------------------
def _assert_three_way(eng):
    measured = {k: int(v) for k, v in eng.meter.bytes.items()}
    predicted = {k: int(v) for k, v in eng.predicted_traffic.items()}
    for k in set(measured) | set(predicted):
        assert measured.get(k, 0) == predicted.get(k, 0), \
            (k, measured, predicted)
    kt = kv_traffic(eng.kv_unit_nbytes, eng.scfg.kv_block_bytes,
                    eng.scfg.kv_x_host, eng.kv_spills, eng.kv_fetches)
    assert measured.get(("kv", "gpu->cpu"), 0) == kt.spill
    assert measured.get(("kv", "cpu->ssd"), 0) == kt.ssd_spill
    assert measured.get(("kv", "cpu->gpu"), 0) == kt.fetch
    assert measured.get(("kv", "ssd->cpu"), 0) == kt.ssd_fetch
    # param closed form: every executed step fetches every unit once
    steps = eng.step_num
    assert measured.get(("param", "cpu->gpu"), 0) == \
        steps * sum(eng.param_unit_nbytes)
    assert measured.get(("param", "ssd->cpu"), 0) == \
        steps * sum(nb - int(round(eng.scfg.param_x_host * nb))
                    for nb in eng.param_unit_nbytes)


@pytest.mark.parametrize("kv_x,p_x", [(0.0, 1.0), (0.5, 0.5), (1.0, 0.0)])
@pytest.mark.parametrize("batch,gen", [(1, 2), (3, 3)])
def test_three_way_exactness_sweep(batch, gen, kv_x, p_x):
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(d, capacity_requests=max(1, batch - 1),
                      kv_x_host=kv_x, param_x_host=p_x)
        rids = [eng.submit(p, gen) for p in _prompts(batch)]
        _drain(eng, preempt_rid=rids[0] if batch > 1 and gen > 2 else None)
        assert all(len(eng.result(r)) == gen for r in rids)
        _assert_three_way(eng)
        if batch > 1 and gen > 2:        # the preempt really happened
            assert eng.preempted >= 1 and sum(eng.kv_fetches) > 0
        eng.close()


def test_three_way_exactness_under_queueing_and_preempt():
    """Capacity 2 < 3 requests: head-of-line queueing + an explicit
    preempt round-trip, still byte-exact on every (category, route)."""
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(d, capacity_requests=2)
        rids = [eng.submit(p, 4) for p in _prompts(3)]
        eng.step()
        assert eng.requests[rids[2]].state == "waiting"
        eng.preempt(rids[1])
        _drain(eng)
        assert all(len(eng.result(r)) == 4 for r in rids)
        assert eng.preempted >= 1
        _assert_three_way(eng)
        eng.close()


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------
def test_submit_refuses_oversized_request():
    """A request whose block footprint alone exceeds the KV budget is
    refused eagerly — before any I/O happens."""
    with tempfile.TemporaryDirectory() as d:
        budget = (_blocks_per_request() - 1) * BB
        scfg = ServeConfig(max_len=MAX_LEN, kv_block_bytes=BB,
                           kv_budget_bytes=budget)
        eng = ServeEngine(CFG, scfg, jax.random.PRNGKey(0), d)
        with pytest.raises(ValueError, match="budget"):
            eng.submit(_prompts(1)[0], 2)
        eng.close()


def test_submit_validates_length_and_prompt():
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(d)
        with pytest.raises(ValueError, match="max_len"):
            eng.submit(list(range(PROMPT_LEN)), MAX_LEN)
        with pytest.raises(ValueError):
            eng.submit([], 2)
        with pytest.raises(ValueError):
            eng.submit([1, 2], 0)
        eng.close()


def test_preempt_requires_running_request():
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(d)
        rid = eng.submit(_prompts(1)[0], 2)
        with pytest.raises(ValueError):
            eng.preempt(rid)            # still waiting
        _drain(eng)
        with pytest.raises(ValueError):
            eng.preempt(rid)            # finished
        eng.close()


def test_two_concurrent_under_partial_budget():
    """>= 2 requests run concurrently under a KV budget smaller than
    the total KV footprint of all admitted requests."""
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(d, capacity_requests=2)
        rids = [eng.submit(p, 3) for p in _prompts(3)]
        total_blocks = 3 * _blocks_per_request()
        assert eng.capacity_blocks < total_blocks
        eng.step()
        running = [r for r in rids
                   if eng.requests[r].state == "running"]
        assert len(running) == 2
        assert eng.used_blocks == 2 * _blocks_per_request()
        _drain(eng)
        assert all(len(eng.result(r)) == 3 for r in rids)
        assert eng.used_blocks == 0
        eng.close()


def _reference_logits(prompt, gen):
    """Pure-jit in-memory B=1 decode — the bitwise f32 reference."""
    params = mdl.init_params(CFG, jax.random.PRNGKey(0), dtype=jnp.float32)
    prefill = jax.jit(lambda p, b, c: mdl.prefill(p, CFG, b, c))
    decode = jax.jit(lambda p, t, pos, c: mdl.decode_step(p, CFG, t, pos, c))
    caches = mdl.init_caches(CFG, 1, MAX_LEN, dtype=jnp.float32)
    logits, caches = prefill(
        params, {"tokens": jnp.asarray([prompt], jnp.int32)}, caches)
    out, toks = [np.asarray(logits)], [int(jnp.argmax(logits[0]))]
    for i in range(gen - 1):
        logits, caches = decode(
            params, jnp.asarray([[toks[-1]]], jnp.int32),
            jnp.asarray(len(prompt) + i, jnp.int32), caches)
        out.append(np.asarray(logits))
        toks.append(int(jnp.argmax(logits[0])))
    return out, toks


def test_preempt_to_ssd_and_resume_is_bitwise():
    """The acceptance invariant: a request preempted to the tiers and
    resumed produces BITWISE-identical f32 logits (and tokens) to an
    uninterrupted in-memory decode."""
    prompts = _prompts(2)
    gen = 5
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(d, record_logits=True)
        rids = [eng.submit(p, gen) for p in prompts]
        eng.step()
        eng.step()
        eng.preempt(rids[0])             # spill mid-generation
        _drain(eng)
        assert eng.requests[rids[0]].evictions >= 1
        for rid, prompt in zip(rids, prompts):
            ref_logits, ref_toks = _reference_logits(prompt, gen)
            assert eng.result(rid) == ref_toks
            got = eng.requests[rid].logits
            assert len(got) == len(ref_logits) == gen
            for g, r in zip(got, ref_logits):
                np.testing.assert_array_equal(g, r)
        _assert_three_way(eng)
        eng.close()


def test_serve_snapshot_round_trips_json():
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(d, capacity_requests=1)
        rids = [eng.submit(p, 3) for p in _prompts(2)]
        _drain(eng, preempt_rid=rids[0])
        snap = eng.metrics_snapshot()
        again = json.loads(json.dumps(snap))
        assert again["version"] == snap["version"] >= 1
        assert again["schedule"] == "serve"
        assert again["kv"]["capacity_blocks"] == _blocks_per_request()
        assert 0.0 <= again["kv"]["hit_rate"] <= 1.0
        assert again["tokens_decoded"] == eng.tokens_decoded > 0
        # predicted side rides along for offline reconciliation
        meas = {k: int(v) for k, v in again["traffic"][0].items()}
        assert meas == {k: int(v) for k, v in again["predicted"].items()}
        eng.close()


# ---------------------------------------------------------------------------
# eager config validation parity (OffloadConfig / IOConfig / ServeConfig)
# ---------------------------------------------------------------------------
def test_offload_config_rejects_unknown_schedule_eagerly():
    with pytest.raises(ValueError, match="schedule"):
        OffloadConfig(schedule="diagonal")


def test_offload_config_rejects_unknown_activation_policy_eagerly():
    with pytest.raises(ValueError, match="activation_policy"):
        OffloadConfig(activation_policy="teleport")


def test_io_config_rejects_unknown_path_policy_eagerly():
    with pytest.raises(ValueError, match="path_policy"):
        IOConfig(paths=["/tmp/x"], path_policy="psychic")


@pytest.mark.parametrize("kw", [
    {"kv_block_bytes": 0}, {"kv_budget_bytes": -1}, {"kv_x_host": 1.5},
    {"param_x_host": -0.1}, {"prefetch_depth": -1}, {"max_len": 1},
])
def test_serve_config_rejects_bad_values_eagerly(kw):
    with pytest.raises(ValueError):
        ServeConfig(**kw)


# ---------------------------------------------------------------------------
# make_engine factory + stats() deprecation shims
# ---------------------------------------------------------------------------
_OCFG = dict(num_microbatches=2, micro_batch=1, seq_len=32)


def test_make_engine_dispatch_and_io_override():
    with tempfile.TemporaryDirectory() as d:
        io_cfg = IOConfig(paths=[d], chunk_bytes=128 << 10)
        eng = make_engine(CFG, OffloadConfig(**_OCFG), jax.random.PRNGKey(0),
                          d, io_cfg=io_cfg)
        assert isinstance(eng, OffloadEngine)
        assert eng.ocfg.io is io_cfg
        eng.close()
    with pytest.raises(ValueError, match="num_ranks"):
        make_engine(CFG, OffloadConfig(**_OCFG), jax.random.PRNGKey(0),
                    "/tmp/x", num_ranks=0)


def test_make_engine_builds_dp():
    with tempfile.TemporaryDirectory() as d:
        eng = make_engine(CFG, OffloadConfig(**_OCFG), jax.random.PRNGKey(0),
                          d, num_ranks=2)
        assert isinstance(eng, DataParallelOffloadEngine)
        assert eng.R == 2
        eng.close()


def test_stats_is_deprecated_and_metrics_snapshot_is_not():
    with tempfile.TemporaryDirectory() as d:
        eng = OffloadEngine(CFG, OffloadConfig(**_OCFG),
                            jax.random.PRNGKey(0), d)
        with pytest.warns(DeprecationWarning, match="metrics_snapshot"):
            legacy = eng.stats()
        with pytest.warns(DeprecationWarning, match="metrics_snapshot"):
            eng.ioe.stats()
        # the replacement is warning-free and subsumes the legacy shape
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            snap = eng.metrics_snapshot()
            eng.ioe.metrics_snapshot()
        assert snap["version"] >= 1
        assert set(legacy) <= set(snap) | {"io"}
        eng.close()


def test_dp_stats_is_deprecated():
    with tempfile.TemporaryDirectory() as d:
        eng = make_engine(CFG, OffloadConfig(**_OCFG), jax.random.PRNGKey(0),
                          d, num_ranks=2)
        with pytest.warns(DeprecationWarning, match="metrics_snapshot"):
            eng.stats()
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            eng.metrics_snapshot()
        eng.close()
