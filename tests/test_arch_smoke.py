"""Per-architecture smoke tests (assigned deliverable f).

Each assigned architecture instantiates its REDUCED variant (<=2 layers,
d_model<=512, <=4 experts) and runs one forward/train step plus a
prefill+decode step on CPU, asserting output shapes and no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_smoke
from repro.data import make_batch
from repro.models import (decode_step, init_caches, init_params, loss_fn,
                          prefill)
from repro.models.blocks import build_plan, layer_kind


def _batch(cfg, B, S, seed=0):
    return {k: jnp.asarray(v) for k, v in make_batch(cfg, B, S, seed=seed).items()}


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_smoke(arch):
    cfg = get_smoke(arch)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512 and cfg.num_experts <= 4
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, S = 2, 32
    batch = _batch(cfg, B, S)
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p, b: loss_fn(p, cfg, b)))(params, batch)
    assert np.isfinite(float(loss)), arch
    gn = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_serve_smoke(arch):
    cfg = get_smoke(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    B, S = 2, 32
    batch = _batch(cfg, B, S, seed=1)
    caches = init_caches(cfg, B, S)
    logits, caches = jax.jit(
        lambda p, b, c: prefill(p, cfg, b, c))(params, batch, caches)
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits))), arch
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, caches = jax.jit(
        lambda p, t, pos, c: decode_step(p, cfg, t, pos, c))(
        params, tok, jnp.int32(min(S, 31)), caches)
    assert logits2.shape == (B, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits2))), arch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_layer_plan_exact(arch):
    """The periodic plan must reproduce the exact layer order."""
    from repro.configs import get_config
    cfg = get_config(arch)
    plan = build_plan(cfg)
    assert plan.num_layers == cfg.num_layers
    assert plan.all_kinds() == [layer_kind(cfg, i)
                                for i in range(cfg.num_layers)]


def test_param_counts_match_published():
    """Total parameter counts should be within 10% of the model names."""
    from repro.configs import get_config
    expect = {"deepseek-v2-lite-16b": 15.7e9, "falcon-mamba-7b": 7.3e9,
              "phi3-medium-14b": 14e9, "qwen3-moe-235b-a22b": 235e9,
              "jamba-v0.1-52b": 52e9, "starcoder2-7b": 7.2e9,
              "internvl2-76b": 70e9, "gpt-65b": 65e9, "gpt-175b": 175e9}
    for arch, want in expect.items():
        got = get_config(arch).total_params()
        assert abs(got - want) / want < 0.12, (arch, got, want)


def test_decode_matches_prefill_logits():
    """Decoding token t with a cache filled by prefill over [0..t) must
    equal the full-sequence forward's logits at position t."""
    from repro.configs import get_config
    cfg = get_config("gpt-tiny")
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    # full forward logits at position S-1 predict token S
    caches = init_caches(cfg, B, S + 1)
    logits_pre, caches = prefill(params, cfg, {"tokens": toks[:, :S]}, caches)
    # now decode position S with token toks[:, S]
    logits_dec, _ = decode_step(params, cfg, toks[:, S:S + 1],
                                jnp.int32(S), caches)
    # reference: prefill over S+1 tokens, last logits
    caches2 = init_caches(cfg, B, S + 1)
    logits_ref, _ = prefill(params, cfg, {"tokens": toks}, caches2)
    np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(logits_ref),
                               atol=2e-2, rtol=2e-2)
