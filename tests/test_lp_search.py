"""Algorithm 1 behaviour tests."""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.lp_search import find_optimal_config, solve_config
from repro.core.perfmodel import (MachineParams, StorageRatios, Workload,
                                  cpu_mem_vertical, delayed_grads_fit,
                                  iteration_time_horizontal,
                                  iteration_time_vertical, rooflines)


M65 = MachineParams()


def _w65(mb=2):
    return Workload.from_config(get_config("gpt-65b"), micro_batch=mb,
                                seq_len=2048)


def test_lp_matches_bruteforce_grid():
    """The LP optimum must match a dense grid search over x."""
    w = _w65()
    n, alpha = 8, 0.2
    sol = solve_config(M65, w, n, alpha)
    best = np.inf
    grid = np.linspace(0, 1, 21)
    for xc in grid:
        for xp in grid:
            for xo in grid:
                x = StorageRatios(xc, xp, xo)
                if cpu_mem_vertical(w, n, x, alpha) > 0.95 * M65.cpu_mem:
                    continue
                if not delayed_grads_fit(w, n, x, alpha):
                    continue
                t = iteration_time_vertical(w, M65, n, alpha, x)
                best = min(best, t)
    assert sol is not None
    # grid is coarse; LP must be at least as good (within tolerance)
    assert sol.iteration_time <= best * 1.02


def test_throughput_monotone_then_saturates():
    w = _w65()
    res = find_optimal_config(M65, w, alphas=[0.0, 0.2, 0.4], max_n=64)
    assert res is not None
    assert res.n >= 2
    # saturated throughput below compute roofline
    _, comp_roof = rooflines(w, M65, res.x)
    assert res.throughput_tokens_per_s <= comp_roof * 1.001


def test_vertical_beats_horizontal_at_saturation():
    """The headline claim: saturated vertical throughput exceeds the
    horizontal schedule's by a wide margin for GPT-65B-scale models."""
    w = _w65()
    res = find_optimal_config(M65, w, alphas=[0.0, 0.2, 0.4], max_n=64)
    tv = res.iteration_time / res.n
    # horizontal gets its own best CPU-cache config (generous baseline)
    th_best = np.inf
    for M in (4, 8, 16, 32, 64):
        th = iteration_time_horizontal(w, M65, M,
                                       StorageRatios(0.0, 1.0, 0.0)) / M
        th_best = min(th_best, th)
    assert tv < th_best, (tv, th_best)
    assert th_best / tv > 1.4   # paper: 1.9-2.5x on A100s


def test_delay_ratio_helps_small_batch_and_converges():
    """Fig. 11: delaying α of the optimizer step lifts the I/O-bound
    (small-n) region toward the roofline; both curves converge to the
    same saturated throughput at large n."""
    w = _w65()

    def tp(n, alpha):
        sol = solve_config(M65, w, n, alpha)
        return n * w.tokens_per_mb / sol.iteration_time

    # The benefit window is the "knee" of the roofline (Fig. 11): once the
    # forward stage turns compute-bound but the backward stage is still
    # I/O-bound, delaying α of the optimizer step moves opt-state I/O into
    # the forward stage's compute slack. Deep in the I/O-bound regime
    # (tiny n: BOTH stages I/O-bound) moving I/O between stages cannot
    # reduce the total — and the §4.4 reuse constraint can even make a
    # FORCED α slightly harmful there (delayed grads displace opt-state
    # caching). Algorithm 1's per-n argmax over α (which includes 0)
    # therefore never loses.
    knee_n = 16
    assert tp(knee_n, 0.3) > tp(knee_n, 0.0) * 1.02
    tiny_n = 4
    assert tp(tiny_n, 0.3) <= tp(tiny_n, 0.0) * 1.01
    big_n = 48
    assert abs(tp(big_n, 0.3) - tp(big_n, 0.0)) / tp(big_n, 0.0) < 0.05


# ---------------------------------------------------------------------------
# the solve_config return contract: None is STRICTLY "LP-infeasible";
# caller bugs (malformed arguments) raise ValueError instead of being
# silently swallowed as "no plan" — the autotuner holds on None, so a
# silent None would mask a bug forever
# ---------------------------------------------------------------------------

def test_solve_config_invalid_args_raise_value_error():
    w = _w65()
    with pytest.raises(ValueError, match="divisible"):
        solve_config(M65, w, 9, 0.2, num_gpus=2)
    with pytest.raises(ValueError, match="wave"):
        solve_config(M65, w, 8, 0.2, num_gpus=2, wave=4)
    with pytest.raises(ValueError, match="divisor"):
        solve_config(M65, w, 8, 0.2, wave=3)
    with pytest.raises(ValueError, match="act_policy"):
        solve_config(M65, w, 8, 0.2, act_policy="levitate")


def test_solve_config_none_means_infeasible_only():
    w = _w65()
    # valid args, valid workload, but a host too small to cache anything
    # AND too little headroom for the delayed-grad buffers: the LP has
    # no feasible point — that (and only that) returns None
    tiny = dataclasses.replace(M65, cpu_mem=1e6)
    assert solve_config(tiny, w, 8, 0.5) is None
    # same args on the real machine solve fine (guards the test against
    # drifting into the ValueError regime)
    assert solve_config(M65, w, 8, 0.5) is not None
    # act_policy="auto" recurses over the concrete policies, so it
    # composes with the strict contract: feasible machine -> solution
    # (never an exception), infeasible machine -> None (min over an
    # empty candidate set), and its inner calls pass valid args only
    assert solve_config(M65, w, 8, 0.2, act_policy="auto") is not None
    assert solve_config(tiny, w, 8, 0.5, act_policy="auto") is None
