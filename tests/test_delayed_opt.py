"""α-delayed optimizer (§4.4): deferring α of each update to the next
iteration must be mathematically equivalent to standard Adam."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (AdamConfig, apply_early, apply_update,
                         clip_by_global_norm, flush_late, global_norm,
                         init_delayed, init_state)


def _tree(key, n=3):
    ks = jax.random.split(key, 2 * n)
    return {f"w{i}": jax.random.normal(ks[2 * i], (7, 11), jnp.float32)
            for i in range(n)}


@pytest.mark.parametrize("alpha", [0.0, 0.01, 0.25, 0.5, 0.99, 1.0])
def test_delayed_equals_plain_adam(alpha):
    """N delayed steps + final flush == N plain Adam steps (f32 exact)."""
    key = jax.random.PRNGKey(0)
    params = _tree(key)
    cfg = AdamConfig(lr=1e-2)
    grads_seq = [_tree(jax.random.PRNGKey(100 + i)) for i in range(4)]

    # plain
    st = init_state(params)
    p_plain = params
    for g in grads_seq:
        p_plain, st = apply_update(st, g, cfg, compute_dtype=jnp.float32)

    # delayed
    dst = init_delayed(init_state(params), params)
    p_del = params
    for g in grads_seq:
        p_del, dst = flush_late(dst, cfg, alpha, compute_dtype=jnp.float32)
        p_del, dst = apply_early(dst, g, cfg, alpha, compute_dtype=jnp.float32)
    p_del, dst = flush_late(dst, cfg, alpha, compute_dtype=jnp.float32)

    for a, b in zip(jax.tree.leaves(p_plain), jax.tree.leaves(p_del)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6, rtol=1e-6)
    for a, b in zip(jax.tree.leaves(st.m), jax.tree.leaves(dst.adam.m)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-7, rtol=1e-6)


def test_forward_params_fully_updated():
    """After flush_late, every element equals the plain-Adam params —
    the §4.4 invariant 'each layer is updated before it executes'."""
    key = jax.random.PRNGKey(1)
    params = _tree(key, n=2)
    cfg = AdamConfig(lr=5e-3)
    g = _tree(jax.random.PRNGKey(9), n=2)

    st = init_state(params)
    p_plain, _ = apply_update(st, g, cfg, compute_dtype=jnp.float32)

    dst = init_delayed(init_state(params), params)
    _, dst = flush_late(dst, cfg, 0.4, compute_dtype=jnp.float32)
    p_mid, dst = apply_early(dst, g, cfg, 0.4, compute_dtype=jnp.float32)
    # p_mid is PARTIALLY updated (early fraction only)
    p_full, _ = flush_late(dst, cfg, 0.4, compute_dtype=jnp.float32)
    for a, b in zip(jax.tree.leaves(p_plain), jax.tree.leaves(p_full)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)
    # and the partial params differ from full exactly on the late fraction
    for pm, pf, p0 in zip(jax.tree.leaves(p_mid), jax.tree.leaves(p_full),
                          jax.tree.leaves(params)):
        pm, pf, p0 = map(np.asarray, (pm, pf, p0))
        k = int(round(0.6 * pm.size))
        assert np.allclose(pm.reshape(-1)[:k], pf.reshape(-1)[:k])
        assert np.allclose(pm.reshape(-1)[k:], p0.reshape(-1)[k:])


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((6,), 4.0)}
    n = float(global_norm(g))
    clipped, coef, raw = clip_by_global_norm(g, n / 2)
    assert abs(float(coef) - 0.5) < 1e-6
    assert abs(float(global_norm(clipped)) - n / 2) < 1e-5
