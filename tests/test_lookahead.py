"""Cross-stream lookahead battery.

* The plan-lint meta-test (the PR's structural acceptance): in EVERY
  compiled plan across schedules × M × α × R × activation policy, every
  fetch-class op that can touch the SSD carries exactly one matching
  hint (``PREFETCH`` per param fetch / all-gather, ``PREFETCH_CKPT``
  per backward checkpoint re-read, ``PREFETCH_ACT`` per activation
  fetch, ``PREFETCH_OPT`` per α-tail flush), placed before its fetch
  and never across a ``RESET_PARAMS``; ops whose payloads are provably
  device-kept or CPU-resident (``FETCH_CKPT``, ``FETCH_GRAD``) carry
  none.
* Hints move *when* bytes flow, never *how many*: ``plan_traffic`` is
  invariant under ``insert_prefetch`` at any depth, and live engines
  are bitwise-identical (f32, losses AND parameters) and byte-identical
  (every meter counter) with lookahead on vs off across the acceptance
  grid — single-rank and data-parallel.
* The backpressure-adaptive loop: ``IOEngine.depth()`` introspection,
  hint skipping under a saturated budget (still bitwise/byte-clean),
  and the per-(layer, micro-batch) "auto" spill degradation.
* The perf model's reduced stall terms: ``lookahead=False`` prices the
  hint-free executor at or above the hinted one, in ``perfmodel`` and
  in the LP rows.
"""
import dataclasses
import tempfile
import threading
from collections import defaultdict, deque

import jax
import pytest

from repro.configs.base import ArchConfig
from repro.core.perfmodel import (MachineParams, StorageRatios, Workload,
                                  iteration_time_vertical,
                                  iteration_time_vertical_dp,
                                  iteration_time_wave)
from repro.core.plan import (HINT_FOR_FETCH, HINT_KINDS, Op, PlanCosts,
                             PlanSpec, compile_wave, insert_prefetch,
                             plan_traffic)
from repro.data import SyntheticLM
from repro.io import IOConfig, IOEngine, IOPriority
from repro.offload import (DataParallelOffloadEngine, OffloadConfig,
                           OffloadEngine)

CFG = ArchConfig(name="look-tiny", family="dense", source="test",
                 num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
                 head_dim=16, d_ff=64, vocab_size=256, act="gelu")
MB, S = 1, 16
X0 = StorageRatios(0.0, 0.0, 0.0)

#: fetch-class ops whose payloads are PROVABLY device-kept or
#: CPU-resident (forward ckpt cache, inter-layer grads) — the lint
#: asserts these carry no hints
UNHINTED_FETCHES = (Op.FETCH_CKPT, Op.FETCH_GRAD)

#: the acceptance grid: schedule × M × α × R (wave needs M % 2 == 0,
#: DP plans are vertical with M % R == 0)
GRID = [(sched, M, alpha, R)
        for sched in ("vertical", "horizontal", "wave")
        for M in (1, 2, 4)
        for alpha in (0.0, 0.5)
        for R in (1, 2)
        if not (sched == "wave" and M % 2)
        and not (R > 1 and (sched != "vertical" or M % R))]


def _compiled(sched, M, alpha, R, act_spill=False, depth=1):
    W = {"vertical": M, "horizontal": 1, "wave": 2}[sched]
    spec = PlanSpec(L=3, M=M, alpha=alpha, ranks=R, act_spill=act_spill)
    return insert_prefetch(compile_wave(spec, W), depth=depth)


def _hint_key(op):
    return (op.op, op.l, op.m)


def lint_plan(plan):
    """Assert the hint discipline over one compiled plan (see module
    docstring). Returns the number of (hint, fetch) pairs checked."""
    hints = defaultdict(deque)        # (hint_kind, l, m) -> hint indices
    resets = []
    pairs = 0
    for i, op in enumerate(plan.ops):
        if op.op is Op.RESET_PARAMS:
            resets.append(i)
        elif op.op in HINT_KINDS:
            hints[(op.op, op.l, op.m)].append(i)
        elif op.op in HINT_FOR_FETCH:
            kind = HINT_FOR_FETCH[op.op]
            q = hints[(kind, op.l, op.m)]
            assert q, (f"{op!r} at {i} has no pending {kind.name} hint "
                       f"({plan.schedule}, M={plan.spec.M})")
            h = q.popleft()
            crossed = [r for r in resets if h < r < i]
            assert not crossed, \
                f"hint at {h} for {op!r} at {i} crosses RESET_PARAMS"
            pairs += 1
        elif op.op in UNHINTED_FETCHES:
            pass                      # checked globally below
    leftovers = {k: list(v) for k, v in hints.items() if v}
    assert not leftovers, f"hints without a consumer: {leftovers}"
    for kind in HINT_KINDS:
        wanted = [f for f, h in HINT_FOR_FETCH.items() if h is kind]
        assert plan.count(kind) == sum(plan.count(f) for f in wanted)
    return pairs


# ---------------------------------------------------------------------------
# the plan-lint meta-test (every compiled plan, both activation policies)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sched,M,alpha,R", GRID)
@pytest.mark.parametrize("act_spill", [False, True])
def test_plan_lint_every_fetch_has_exactly_one_hint(sched, M, alpha, R,
                                                    act_spill):
    plan = _compiled(sched, M, alpha, R, act_spill=act_spill)
    pairs = lint_plan(plan)
    assert pairs > 0
    # provably-resident payloads carry no hints: no hint kind targets
    # FETCH_CKPT / FETCH_GRAD (structural, from the op->hint table)
    for f in UNHINTED_FETCHES:
        assert f not in HINT_FOR_FETCH
    # spill plans hint the act stream, recompute plans the ckpt tails
    if act_spill:
        assert plan.count(Op.PREFETCH_ACT) == plan.count(Op.FETCH_ACT) > 0
        assert plan.count(Op.PREFETCH_CKPT) == 0
    else:
        assert plan.count(Op.PREFETCH_CKPT) \
            == plan.count(Op.FETCH_CKPT_BWD) > 0
        assert plan.count(Op.PREFETCH_ACT) == 0
    if alpha > 0:
        assert plan.count(Op.PREFETCH_OPT) == plan.count(Op.OPT_LATE) > 0


def test_prologue_plans_keep_hints_behind_the_alpha_gates():
    """Hinting a prologue-ordered plan (a public, if unusual,
    combination) must never hoist a param hint above the OPT_LATE ops
    that arm the fetch gates — the old pre-seam invariant."""
    from repro.core.plan import compile_vertical

    spec = PlanSpec(L=3, M=4, alpha=0.4)
    for depth in (1, 3):
        plan = insert_prefetch(compile_vertical(spec, opt_epilogue=False),
                               depth=depth)
        lint_plan(plan)
        kinds = [op.op for op in plan.ops]
        last_pro = max(i for i, op in enumerate(plan.ops)
                       if op.op is Op.OPT_LATE and op.tag == "pro")
        assert kinds.index(Op.PREFETCH) > last_pro, depth


def test_plan_lint_holds_at_greater_depths():
    for depth in (2, 5):
        for sched in ("vertical", "horizontal", "wave"):
            lint_plan(_compiled(sched, 4, 0.5, 1, depth=depth))
            lint_plan(_compiled(sched, 4, 0.5, 1, act_spill=True,
                                depth=depth))


def test_depth_zero_is_the_prologue_baseline():
    """depth 0 compiles the full lookahead-off plan: no hint ops at
    all, and the α-tail flushes back in the PROLOGUE (tag "pro") —
    the pre-lookahead executor ordering."""
    from repro.core.plan import compile_vertical

    spec = PlanSpec(L=3, M=4, alpha=0.5)
    bare = insert_prefetch(compile_vertical(spec, opt_epilogue=False),
                           depth=0)
    for kind in HINT_KINDS:
        assert bare.count(kind) == 0
    lates = [op for op in bare.ops if op.op is Op.OPT_LATE]
    assert [op.tag for op in lates] == ["pro"] * 3
    kinds = [op.op for op in bare.ops]
    assert kinds.index(Op.OPT_LATE) < kinds.index(Op.EMBED_FWD)
    with pytest.raises(ValueError, match="depth"):
        insert_prefetch(compile_vertical(spec), depth=-1)


# ---------------------------------------------------------------------------
# byte parity: hints move when bytes flow, never how many
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("act_spill", [False, True])
def test_plan_traffic_invariant_under_hints(act_spill):
    costs = PlanCosts(P=1000, param_itemsize=4, ckpt_elems=64,
                      act_itemsize=4, ratios=X0, alpha=0.5,
                      act_res_bytes=512)
    for sched in ("vertical", "horizontal", "wave"):
        W = {"vertical": 4, "horizontal": 1, "wave": 2}[sched]
        spec = PlanSpec(L=3, M=4, alpha=0.5, act_spill=act_spill)
        bare = compile_wave(spec, W)
        pro = compile_wave(spec, W, opt_epilogue=False)
        t0 = plan_traffic(bare, costs)
        assert plan_traffic(insert_prefetch(bare, depth=1), costs) == t0
        assert plan_traffic(insert_prefetch(bare, depth=3), costs) == t0
        # the prologue (lookahead-off) seam moves the same bytes too
        assert plan_traffic(pro, costs) == t0


# ---------------------------------------------------------------------------
# the live acceptance grid: bitwise + byte identity, lookahead on vs off
# ---------------------------------------------------------------------------

def _run(sched, M, alpha, R, depth, steps=2, io=None, policy="recompute",
         machine=None):
    W = {"vertical": 0, "horizontal": 0, "wave": 2}[sched]
    ocfg = OffloadConfig(schedule=sched, num_microbatches=M,
                         micro_batch=MB, seq_len=S, alpha=alpha,
                         wave_size=W, ratios=X0, prefetch_depth=depth,
                         io=io, activation_policy=policy, machine=machine)
    with tempfile.TemporaryDirectory() as d:
        if R > 1:
            eng = DataParallelOffloadEngine(CFG, ocfg,
                                            jax.random.PRNGKey(11), d,
                                            ranks=R)
            meters = [rk.meter for rk in eng.ranks]
        else:
            eng = OffloadEngine(CFG, ocfg, jax.random.PRNGKey(11), d)
            meters = [eng.meter]
        data = SyntheticLM(CFG.vocab_size, seed=0)
        losses = [eng.train_step(data.batch(M * MB, S))
                  for _ in range(steps)]
        eng.finish()
        routes = [dict(m.bytes) for m in meters]
        if R > 1:
            params = [eng.read_params(l).copy() for l in range(eng.L)]
        else:
            params = [eng.p_vecs[l].read().copy() for l in range(eng.L)]
        look = eng.metrics_snapshot()["lookahead"]
        skips = (eng.hint_skips, eng.act_skips, eng.act_fallbacks)
        eng.close()
    return losses, routes, params, look, skips


@pytest.mark.parametrize("sched,M,alpha,R", GRID)
def test_lookahead_on_off_bitwise_and_byte_identical(sched, M, alpha, R):
    """The acceptance sweep: losses, final parameters, and every
    (category, route) byte counter are identical with the cross-stream
    lookahead on (depth 1) vs off (depth 0, prologue seam) — and the
    hinted run actually prefetches."""
    l0, r0, p0, _, _ = _run(sched, M, alpha, R, depth=0)
    l1, r1, p1, look, _ = _run(sched, M, alpha, R, depth=1)
    assert l0 == l1, "lookahead changed the losses"
    assert r0 == r1, "lookahead changed a byte counter"
    for a, b in zip(p0, p1):
        assert (a == b).all(), "lookahead changed the parameters"
    assert look["hits"] > 0, "the hinted run never prefetched"


def test_deeper_lookahead_still_bitwise():
    l1, r1, p1, _, _ = _run("vertical", 4, 0.5, 1, depth=1)
    l3, r3, p3, look, _ = _run("vertical", 4, 0.5, 1, depth=3)
    assert l1 == l3 and r1 == r3
    for a, b in zip(p1, p3):
        assert (a == b).all()
    assert look["hit_rate"] > 0.5


def test_prefetch_depth_validation():
    # malformed knobs fail at CONSTRUCTION, with a clear error
    for bad in (-1, 99):
        with pytest.raises(ValueError, match="prefetch_depth"):
            OffloadConfig(num_microbatches=2, micro_batch=MB,
                          seq_len=S, prefetch_depth=bad)
    with pytest.raises(ValueError, match="backpressure"):
        OffloadConfig(num_microbatches=2, micro_batch=MB, seq_len=S,
                      backpressure=0.0)
    # a config mutated after construction is re-checked at compile time
    ocfg = OffloadConfig(num_microbatches=2, micro_batch=MB, seq_len=S)
    ocfg.prefetch_depth = -3
    with pytest.raises(ValueError, match="prefetch_depth"):
        ocfg.resolved_prefetch_depth()
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(ValueError, match="prefetch_depth"):
            OffloadEngine(CFG, OffloadConfig(
                num_microbatches=2, micro_batch=MB, seq_len=S,
                prefetch_depth=-3), jax.random.PRNGKey(0), d)


# ---------------------------------------------------------------------------
# the backpressure-adaptive loop
# ---------------------------------------------------------------------------

def test_io_engine_depth_introspection():
    with tempfile.TemporaryDirectory() as d:
        ioe = IOEngine(IOConfig(workers=1, inflight_bytes=1 << 30),
                       default_root=d)
        try:
            gate = threading.Event()
            started = threading.Event()

            def blocker():
                started.set()
                gate.wait(10)

            r1 = ioe.submit(blocker, priority=IOPriority.PARAM_FETCH,
                            category="param", route="ssd->cpu",
                            nbytes=1000)
            assert started.wait(5)
            r2 = ioe.submit(lambda: None,
                            priority=IOPriority.OPTIMIZER_STATE,
                            category="opt", route="ssd->cpu", nbytes=500)
            d0 = ioe.depth()
            assert d0["running"] == 1
            assert d0["queued"] == 1
            assert d0["queued_by_priority"]["OPTIMIZER_STATE"] == 1
            assert d0["queued_bytes_by_route"]["ssd->cpu"] == 500
            assert d0["inflight_bytes"] == 1500
            assert d0["budget_bytes"] == 1 << 30
            assert 0 < d0["utilization"] < 1
            gate.set()
            r1.result()
            r2.result()
            d1 = ioe.depth()
            assert d1["queued"] == 0 and d1["inflight_bytes"] == 0
            assert d1["channel_queued"] == 0
        finally:
            ioe.shutdown()


def test_saturation_signal_reads_live_depth():
    """``_saturated`` fires on either live condition — in-flight bytes
    past the budget fraction, or a standing channel backlog on the
    route — and stays quiet on an idle engine."""
    from repro.offload.executor import _saturated

    with tempfile.TemporaryDirectory() as d:
        ioe = IOEngine(IOConfig(workers=1, inflight_bytes=10_000),
                       default_root=d)
        try:
            assert not _saturated(ioe, 0.5, "cpu->ssd")
            gate = threading.Event()
            started = threading.Event()

            def blocker():
                started.set()
                gate.wait(10)

            req = ioe.submit(blocker, priority=IOPriority.CKPT_SPILL,
                             category="ckpt", route="cpu->ssd",
                             nbytes=6_000)
            assert started.wait(5)
            assert _saturated(ioe, 0.5, "cpu->ssd")     # 6000 > 5000
            assert not _saturated(ioe, 0.7, "cpu->ssd")  # 6000 < 7000
            gate.set()
            req.result()
            assert not _saturated(ioe, 0.5, "cpu->ssd")
        finally:
            ioe.shutdown()


def test_hints_skipped_under_saturation_stay_bitwise(monkeypatch):
    """With the saturation signal pinned high, every hint is SKIPPED
    (counted, byte-neutral): losses, params, and every byte counter
    still equal both the hint-free run and the freely-prefetching
    run — the executor guarantee that makes adaptivity always legal."""
    import repro.offload.executor as ex

    l0, r0, p0, _, _ = _run("vertical", 2, 0.5, 1, depth=0)
    l1, r1, p1, _, _ = _run("vertical", 2, 0.5, 1, depth=1)
    monkeypatch.setattr(ex, "_saturated", lambda *a: True)
    ls, rs, ps, look, (skips, _, _) = _run("vertical", 2, 0.5, 1, depth=1)
    assert skips > 0, "a pinned-high signal must skip every hint"
    assert look["hits"] == 0, "skipped hints cannot produce hits"
    assert l0 == l1 == ls, "adaptive skipping changed the losses"
    for a, b, c in zip(p0, p1, ps):
        assert (a == b).all() and (a == c).all()
    assert r0 == r1 == rs, "a skipped hint changed a byte counter"


def test_auto_policy_degrades_spills_under_backpressure(monkeypatch):
    """activation_policy="auto" resolved to spill: a saturated write
    queue degrades individual (layer, micro-batch) spills to the
    recompute path — still bitwise-identical to the recompute run."""
    import repro.offload.executor as ex

    slow_gpu = MachineParams(gpu_flops=1e8, ssd_read_bw=50e9,
                             ssd_write_bw=50e9, pcie_bw=50e9,
                             cpu_adam_bw=100e9)
    l_re, _, p_re, _, _ = _run("vertical", 2, 0.0, 1, depth=1)
    # saturate ONLY the write side: spills skip, read hints still flow
    monkeypatch.setattr(ex, "_saturated",
                        lambda ioe, frac, route: route == "cpu->ssd")
    l_ad, _, p_ad, _, (_, act_skips, fallbacks) = _run(
        "vertical", 2, 0.0, 1, depth=1, policy="auto", machine=slow_gpu)
    # 2 steps x L layers x M=2 micro-batches, every spill degraded
    assert act_skips == 2 * CFG.num_layers * 2, \
        "every (layer, micro-batch) spill must degrade"
    assert fallbacks == act_skips, "skipped spills must recompute"
    assert l_re == l_ad, "adaptive spill skipping changed the losses"
    for a, b in zip(p_re, p_ad):
        assert (a == b).all()


def test_explicit_spill_policy_is_never_adaptive(monkeypatch):
    """Only "auto" adapts: an explicit "spill" run under a pinned-high
    saturation signal keeps its exact deterministic byte counters
    (hints skip — byte-neutral — but no spill ever degrades)."""
    import repro.offload.executor as ex

    monkeypatch.setattr(ex, "_saturated", lambda *a: True)
    _, _, _, _, (_, act_skips, fallbacks) = _run(
        "vertical", 2, 0.0, 1, depth=1, policy="spill")
    assert act_skips == 0 and fallbacks == 0


def test_hinted_prefetch_refused_while_gate_unready():
    """The deadlock guard: a HINT must not submit a fetch whose α gate
    is not ready (its flush still queued) — a burst of prefetch_depth
    gated bodies outranking the queued flushes could otherwise occupy
    every request worker; the consumer path always submits (it blocks
    the executor, not a worker)."""
    from repro.offload.coordinators import ParameterCoordinator
    from repro.offload.stores import HostStore, SSDStore, TieredVector, \
        TrafficMeter

    with tempfile.TemporaryDirectory() as d:
        meter = TrafficMeter()
        ioe = IOEngine(IOConfig(workers=3), default_root=d)
        ssd = SSDStore(d, meter, engine=ioe)
        host = HostStore(meter)
        vec = TieredVector("param:0", 64, "float32", 0.0, host, ssd,
                          "param")
        import numpy as np
        vec.write_full(np.arange(64, dtype=np.float32))
        co = ParameterCoordinator([vec], meter, ioe)
        ready = {"ok": False}
        fired = []
        co.set_gate(0, lambda: fired.append(True),
                    ready=lambda: ready["ok"])
        co.prefetch(0)
        assert co._futures == {}, "hint submitted past an unready gate"
        ready["ok"] = True
        co.prefetch(0)
        assert 0 in co._futures, "ready gate must admit the hint"
        out = co.get(0)
        assert fired == [True] and float(out[5]) == 5.0
        # unready gate + consumer get(): still submits and completes
        co.set_gate(0, lambda: fired.append(True), ready=lambda: False)
        co.prefetch(0)
        assert co._futures == {}
        out = co.get(0)
        assert len(fired) == 2 and float(out[7]) == 7.0
        ssd.close()


def test_deep_lookahead_with_gates_completes_and_stays_bitwise():
    """Integration pin for the same guard: prefetch_depth far above the
    worker count, α>0, L > workers — every plan-start hint burst hits
    freshly-submitted epilogue flushes, and the run must neither hang
    nor change a bit."""
    deep_cfg = ArchConfig(name="deep-tiny", family="dense", source="test",
                          num_layers=4, d_model=32, num_heads=2,
                          num_kv_heads=2, head_dim=16, d_ff=64,
                          vocab_size=256, act="gelu")

    def run(depth):
        ocfg = OffloadConfig(schedule="vertical", num_microbatches=2,
                             micro_batch=MB, seq_len=S, alpha=0.5,
                             ratios=X0, prefetch_depth=depth,
                             io=IOConfig(workers=3))
        with tempfile.TemporaryDirectory() as d:
            eng = OffloadEngine(deep_cfg, ocfg, jax.random.PRNGKey(3), d)
            data = SyntheticLM(deep_cfg.vocab_size, seed=0)
            losses = [eng.train_step(data.batch(2 * MB, S))
                      for _ in range(3)]
            eng.finish()
            routes = dict(eng.meter.bytes)
            eng.close()
        return losses, routes

    l0, r0 = run(0)
    l8, r8 = run(8)
    assert l0 == l8 and r0 == r8


def test_stall_meters_and_stats_plumbing():
    _, _, _, look, _ = _run("vertical", 2, 0.5, 1, depth=1)
    assert look["stall_s"] > 0
    assert set(look) >= {"hits", "misses", "hit_rate", "hint_skips",
                         "act_skips", "stall_s", "op_seconds"}
    assert look["op_seconds"]["FETCH_PARAM"] >= 0
    from repro.offload.executor import STALL_OPS, stall_seconds
    assert "FETCH_PARAM" in STALL_OPS and "FWD" not in STALL_OPS
    assert stall_seconds({"FETCH_PARAM": 1.0, "FWD": 5.0}) == 1.0


# ---------------------------------------------------------------------------
# the perf model's reduced stall terms
# ---------------------------------------------------------------------------

# checkpoint-heavy workload, optimizer state CPU-resident: the
# backward tail re-reads (recompute) / residual fetches (spill) are
# the serialized reads the lookahead hides, and compute + stall
# exceeds the pure SSD stage bound, so the hint-free pricing binds
STALL_M = MachineParams(gpu_flops=100e12, ssd_read_bw=2e9,
                        ssd_write_bw=2e9, pcie_bw=200e9,
                        cpu_adam_bw=500e9)
STALL_W = Workload(ms=1e9, cs=2e9, os_bytes=6e9, grad_bytes=2e9,
                   flops_per_mb=15e12, tokens_per_mb=4096, n_layers=8,
                   as_bytes=1.5e9)
STALL_X = StorageRatios(0.0, 0.0, 1.0)


def test_perfmodel_lookahead_reduces_stall_terms():
    for act in ("recompute", "spill"):
        t_on = iteration_time_vertical(STALL_W, STALL_M, 8, 0.4, STALL_X,
                                       act=act)
        t_off = iteration_time_vertical(STALL_W, STALL_M, 8, 0.4, STALL_X,
                                        act=act, lookahead=False)
        assert t_off > t_on, act
    t_on = iteration_time_wave(STALL_W, STALL_M, 8, 2, 0.4, STALL_X)
    t_off = iteration_time_wave(STALL_W, STALL_M, 8, 2, 0.4, STALL_X,
                                lookahead=False)
    assert t_off > t_on
    t_on = iteration_time_vertical_dp(STALL_W, STALL_M, 8, 0.4, STALL_X,
                                      R=2)
    t_off = iteration_time_vertical_dp(STALL_W, STALL_M, 8, 0.4, STALL_X,
                                       R=2, lookahead=False)
    assert t_off > t_on
    # fully CPU-resident storage has nothing to stall on
    x1 = StorageRatios(1.0, 1.0, 1.0, act=1.0)
    assert iteration_time_vertical(STALL_W, STALL_M, 8, 0.4, x1) == \
        iteration_time_vertical(STALL_W, STALL_M, 8, 0.4, x1,
                                lookahead=False)


def test_lp_rows_price_the_hint_free_executor():
    from repro.core.lp_search import solve_config

    s_on = solve_config(STALL_M, STALL_W, 8, 0.4)
    s_off = solve_config(STALL_M, STALL_W, 8, 0.4, lookahead=False)
    assert s_on is not None and s_off is not None
    assert s_off.iteration_time >= s_on.iteration_time
    # the hint-free spill row carries the residual-fetch stall too
    s_sp_off = solve_config(STALL_M, STALL_W, 8, 0.4, act_policy="spill",
                            lookahead=False)
    s_sp_on = solve_config(STALL_M, STALL_W, 8, 0.4, act_policy="spill")
    assert s_sp_off.iteration_time >= s_sp_on.iteration_time
    # auto still resolves under both pricings
    s_auto = solve_config(STALL_M, STALL_W, 8, 0.4, act_policy="auto",
                          lookahead=False)
    assert s_auto is not None


def test_workload_grid_monotone_under_stall_pricing():
    """More CPU residency can only shrink the hint-free stall terms."""
    t = [iteration_time_vertical(
            STALL_W, STALL_M, 8, 0.4,
            dataclasses.replace(X0, ckpt=c, opt=c), lookahead=False)
         for c in (0.0, 0.5, 1.0)]
    assert t[0] >= t[1] >= t[2]


def test_engine_stats_reset():
    with tempfile.TemporaryDirectory() as d:
        eng = OffloadEngine(CFG, OffloadConfig(
            num_microbatches=2, micro_batch=MB, seq_len=S, alpha=0.5,
            ratios=X0), jax.random.PRNGKey(0), d)
        data = SyntheticLM(CFG.vocab_size, seed=0)
        eng.train_step(data.batch(2 * MB, S))
        assert eng.metrics_snapshot()["lookahead"]["stall_s"] > 0
        eng.reset_stats()
        look = eng.metrics_snapshot()["lookahead"]
        assert look["stall_s"] == 0 and look["hits"] == 0
        assert look["hit_rate"] == 1.0
        eng.finish()
        eng.close()
