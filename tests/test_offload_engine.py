"""End-to-end tests of the SSD-offload engine against the paper's
traffic model and the schedule-equivalence identity."""
import tempfile

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.perfmodel import StorageRatios
from repro.data import SyntheticLM
from repro.offload import OffloadConfig, OffloadEngine

CFG = get_config("gpt-tiny")
M, MB, S = 4, 2, 64


def _run(schedule, alpha=0.0, ratios=StorageRatios(0.5, 0.5, 0.0), steps=2,
         seed=0):
    with tempfile.TemporaryDirectory() as d:
        eng = OffloadEngine(CFG, OffloadConfig(
            schedule=schedule, num_microbatches=M, micro_batch=MB, seq_len=S,
            alpha=alpha, ratios=ratios), jax.random.PRNGKey(7), d)
        data = SyntheticLM(CFG.vocab_size, seed=seed)
        eng.meter.reset()
        losses = [eng.train_step(data.batch(M * MB, S)) for _ in range(steps)]
        eng.finish()
        routes = dict(eng.meter.bytes)
        eng.close()
        return losses, routes, eng


def test_vertical_equals_horizontal_loss():
    lv, _, _ = _run("vertical")
    lh, _, _ = _run("horizontal")
    np.testing.assert_allclose(lv, lh, atol=1e-4)


@pytest.mark.parametrize("alpha", [0.2, 0.5])
def test_alpha_delay_loss_identical(alpha):
    l0, _, _ = _run("vertical", alpha=0.0)
    la, _, _ = _run("vertical", alpha=alpha)
    np.testing.assert_allclose(l0, la, atol=1e-4)


def test_vertical_traffic_matches_formula():
    """§3.4: params loaded 2x per iteration (GPU loads), grads moved once."""
    _, routes, eng = _run("vertical", steps=3)
    ms = eng.L * eng.P * 4          # f32 params bytes
    # params: cpu->gpu == 2 * ms per iteration
    assert routes[("param", "cpu->gpu")] == 3 * 2 * ms
    # grads: gpu->cpu == 1 * ms (f32) per iteration, never fetched back
    assert routes[("grad", "gpu->cpu")] == 3 * ms
    assert ("grad", "cpu->gpu") not in routes


def test_horizontal_traffic_matches_formula():
    """§1: params 2M x ms; grad buffer (2M-1) x ms_f32."""
    _, routes, eng = _run("horizontal", steps=3)
    ms = eng.L * eng.P * 4
    assert routes[("param", "cpu->gpu")] == 3 * 2 * M * ms
    grad_total = routes[("grad", "gpu->cpu")] + routes[("grad", "cpu->gpu")]
    assert grad_total == 3 * (2 * M - 1) * ms


def test_vertical_param_traffic_independent_of_M():
    """The core §3.4 claim: vertical parameter traffic does not scale
    with the number of micro-batches."""
    with tempfile.TemporaryDirectory() as d:
        eng = OffloadEngine(CFG, OffloadConfig(
            schedule="vertical", num_microbatches=8, micro_batch=1,
            seq_len=S), jax.random.PRNGKey(7), d)
        data = SyntheticLM(CFG.vocab_size, seed=0)
        eng.meter.reset()
        eng.train_step(data.batch(8, S))
        eng.finish()
        p8 = eng.meter.bytes[("param", "cpu->gpu")]
        eng.close()
    _, routes, eng2 = _run("vertical", steps=1)
    assert p8 == routes[("param", "cpu->gpu")] == 2 * eng2.L * eng2.P * 4


def test_ssd_files_actually_used():
    """With x=0 everything lives on SSD: files must be read and written."""
    _, routes, _ = _run("vertical", ratios=StorageRatios(0.0, 0.0, 0.0),
                        steps=1)
    assert routes[("param", "ssd->cpu")] > 0
    assert routes[("opt", "ssd->cpu")] > 0
    assert routes[("opt", "cpu->ssd")] > 0
    assert routes[("ckpt", "cpu->ssd")] > 0


def test_loss_decreases_offloaded():
    with tempfile.TemporaryDirectory() as d:
        eng = OffloadEngine(CFG, OffloadConfig(
            schedule="vertical", num_microbatches=M, micro_batch=4,
            seq_len=S, alpha=0.3, lr=3e-3), jax.random.PRNGKey(7), d)
        data = SyntheticLM(CFG.vocab_size, seed=0)
        losses = [eng.train_step(data.batch(M * 4, S)) for _ in range(25)]
        eng.finish()
        eng.close()
    assert np.mean(losses[-5:]) < losses[0] - 0.5, losses
