"""End-to-end tests of the SSD-offload engine against the paper's
traffic model and the schedule-equivalence identity."""
import dataclasses
import os
import tempfile

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.lp_search import solve_config
from repro.core.perfmodel import MachineParams, StorageRatios, Workload
from repro.data import SyntheticLM
from repro.offload import IOConfig, OffloadConfig, OffloadEngine

CFG = get_config("gpt-tiny")
M, MB, S = 4, 2, 64


def _run(schedule, alpha=0.0, ratios=StorageRatios(0.5, 0.5, 0.0), steps=2,
         seed=0):
    with tempfile.TemporaryDirectory() as d:
        eng = OffloadEngine(CFG, OffloadConfig(
            schedule=schedule, num_microbatches=M, micro_batch=MB, seq_len=S,
            alpha=alpha, ratios=ratios), jax.random.PRNGKey(7), d)
        data = SyntheticLM(CFG.vocab_size, seed=seed)
        eng.meter.reset()
        losses = [eng.train_step(data.batch(M * MB, S)) for _ in range(steps)]
        eng.finish()
        routes = dict(eng.meter.bytes)
        eng.close()
        return losses, routes, eng


def test_vertical_equals_horizontal_loss():
    lv, _, _ = _run("vertical")
    lh, _, _ = _run("horizontal")
    np.testing.assert_allclose(lv, lh, atol=1e-4)


@pytest.mark.parametrize("alpha", [0.2, 0.5])
def test_alpha_delay_loss_identical(alpha):
    l0, _, _ = _run("vertical", alpha=0.0)
    la, _, _ = _run("vertical", alpha=alpha)
    np.testing.assert_allclose(l0, la, atol=1e-4)


def test_vertical_traffic_matches_formula():
    """§3.4: params loaded 2x per iteration (GPU loads), grads moved once."""
    _, routes, eng = _run("vertical", steps=3)
    ms = eng.L * eng.P * 4          # f32 params bytes
    # params: cpu->gpu == 2 * ms per iteration
    assert routes[("param", "cpu->gpu")] == 3 * 2 * ms
    # grads: gpu->cpu == 1 * ms (f32) per iteration, never fetched back
    assert routes[("grad", "gpu->cpu")] == 3 * ms
    assert ("grad", "cpu->gpu") not in routes


def test_horizontal_traffic_matches_formula():
    """§1: params 2M x ms; grad buffer (2M-1) x ms_f32."""
    _, routes, eng = _run("horizontal", steps=3)
    ms = eng.L * eng.P * 4
    assert routes[("param", "cpu->gpu")] == 3 * 2 * M * ms
    grad_total = routes[("grad", "gpu->cpu")] + routes[("grad", "cpu->gpu")]
    assert grad_total == 3 * (2 * M - 1) * ms


def test_vertical_param_traffic_independent_of_M():
    """The core §3.4 claim: vertical parameter traffic does not scale
    with the number of micro-batches."""
    with tempfile.TemporaryDirectory() as d:
        eng = OffloadEngine(CFG, OffloadConfig(
            schedule="vertical", num_microbatches=8, micro_batch=1,
            seq_len=S), jax.random.PRNGKey(7), d)
        data = SyntheticLM(CFG.vocab_size, seed=0)
        eng.meter.reset()
        eng.train_step(data.batch(8, S))
        eng.finish()
        p8 = eng.meter.bytes[("param", "cpu->gpu")]
        eng.close()
    _, routes, eng2 = _run("vertical", steps=1)
    assert p8 == routes[("param", "cpu->gpu")] == 2 * eng2.L * eng2.P * 4


def test_boundary_microbatch_ckpt_stays_on_device(monkeypatch):
    """§4.2: the alternating micro-batch order keeps each boundary's
    last-produced checkpoint (and inter-layer gradient) on device, so the
    measured ckpt bytes equal the exact closed form "read twice minus the
    on-device boundary micro-batch" — and perturbing the order evicts
    exactly one micro-batch per interior boundary."""
    from repro.core.traffic import vertical_ckpt_traffic
    from repro.offload import OffloadEngine

    _, routes, eng = _run("vertical", steps=1)
    u = MB * S * CFG.d_model * 4          # one boundary tensor, f32
    ct = vertical_ckpt_traffic(eng.L * u, M, eng.L)
    assert routes[("ckpt", "gpu->cpu")] == ct.write
    assert routes[("ckpt", "cpu->gpu")] == ct.read
    ig = routes[("inter_grad", "gpu->cpu")] \
        + routes[("inter_grad", "cpu->gpu")]
    assert ig == ct.inter_grad

    # Perturb the order (always ascending): every interior boundary's
    # kept micro-batch is now consumed LAST, so the device slot is lost
    # and the engine pays the re-read / spill the §4.2 order avoids.
    monkeypatch.setattr(OffloadEngine, "_mb_order",
                        lambda self, l: list(range(M)))
    _, bad, _ = _run("vertical", steps=1)
    extra_read = bad[("ckpt", "cpu->gpu")] - ct.read
    extra_ig = (bad[("inter_grad", "gpu->cpu")]
                + bad[("inter_grad", "cpu->gpu")]) - ct.inter_grad
    assert (extra_read, extra_ig) == (eng.L * u, 2 * eng.L * u), (
        f"perturbed _mb_order: expected exactly {eng.L} evicted boundary "
        f"checkpoints (+{eng.L * u} read bytes) and {eng.L} spilled "
        f"inter-layer gradients (+{2 * eng.L * u} bytes); measured "
        f"+{extra_read} ckpt-read and +{extra_ig} inter-grad bytes")


def test_ssd_files_actually_used():
    """With x=0 everything lives on SSD: files must be read and written."""
    _, routes, _ = _run("vertical", ratios=StorageRatios(0.0, 0.0, 0.0),
                        steps=1)
    assert routes[("param", "ssd->cpu")] > 0
    assert routes[("opt", "ssd->cpu")] > 0
    assert routes[("opt", "cpu->ssd")] > 0
    assert routes[("ckpt", "cpu->ssd")] > 0


def test_striped_multipath_loss_and_traffic_identical():
    """Striping the SSD tier over several paths is a pure I/O-layout
    change: losses and byte counters must match the single-path run."""
    l1, r1, _ = _run("vertical")
    with tempfile.TemporaryDirectory() as d:
        paths = [os.path.join(d, f"nvme{i}") for i in range(3)]
        eng = OffloadEngine(CFG, OffloadConfig(
            schedule="vertical", num_microbatches=M, micro_batch=MB,
            seq_len=S, ratios=StorageRatios(0.5, 0.5, 0.0),
            io=IOConfig(paths=paths, chunk_bytes=1 << 16)),
            jax.random.PRNGKey(7), d)
        data = SyntheticLM(CFG.vocab_size, seed=0)
        eng.meter.reset()
        l3 = [eng.train_step(data.batch(M * MB, S)) for _ in range(2)]
        eng.finish()
        r3 = dict(eng.meter.bytes)
        eng.close()
        for p in paths:
            assert os.listdir(p) == []       # close() cleaned every path
    np.testing.assert_allclose(l1, l3, atol=1e-5)
    assert r1 == r3


def test_host_peak_within_lp_budget():
    """Algorithm 1's LP sizes the CPU tier; the vertical schedule's
    measured peak host residency must respect the LP's memory cap."""
    with tempfile.TemporaryDirectory() as d:
        probe = OffloadEngine(CFG, OffloadConfig(
            num_microbatches=M, micro_batch=MB, seq_len=S),
            jax.random.PRNGKey(7), d)
        L, P = probe.L, probe.P
        probe.close()
    # engine quantities: params/ckpts are f32 on this container
    w = Workload(ms=L * P * 4, cs=L * MB * S * CFG.d_model * 4,
                 os_bytes=3 * L * P * 4, grad_bytes=L * P * 4,
                 flops_per_mb=1e9, tokens_per_mb=MB * S, n_layers=L)
    full = M * w.cs + w.ms + w.os_bytes + w.grad_transient
    m = dataclasses.replace(MachineParams(), cpu_mem=0.6 * full / 0.95)
    sol = solve_config(m, w, M, 0.0)
    assert sol is not None
    with tempfile.TemporaryDirectory() as d:
        eng = OffloadEngine(CFG, OffloadConfig(
            schedule="vertical", num_microbatches=M, micro_batch=MB,
            seq_len=S, ratios=sol.x), jax.random.PRNGKey(7), d)
        data = SyntheticLM(CFG.vocab_size, seed=0)
        for _ in range(2):
            eng.train_step(data.batch(M * MB, S))
        eng.finish()
        peak = eng.host.peak_nbytes
        assert eng.traffic()["host:peak_nbytes"] == peak
        eng.close()
    # allowance: per-boundary transients (current-layer full tails,
    # inter-layer grads) the LP's steady-state model excludes
    budget = 0.95 * m.cpu_mem + w.cs
    assert 0 < peak <= budget, (peak / 1e6, budget / 1e6)


def test_loss_decreases_offloaded():
    with tempfile.TemporaryDirectory() as d:
        eng = OffloadEngine(CFG, OffloadConfig(
            schedule="vertical", num_microbatches=M, micro_batch=4,
            seq_len=S, alpha=0.3, lr=3e-3), jax.random.PRNGKey(7), d)
        data = SyntheticLM(CFG.vocab_size, seed=0)
        losses = [eng.train_step(data.batch(M * 4, S)) for _ in range(25)]
        eng.finish()
        eng.close()
    assert np.mean(losses[-5:]) < losses[0] - 0.5, losses
