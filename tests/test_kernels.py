"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret
mode (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.fused_adam import fused_adam
from repro.kernels.selective_scan import selective_scan_fwd


@pytest.mark.parametrize("B,H,S,hd", [
    (1, 1, 128, 64), (2, 4, 256, 64), (1, 2, 512, 128), (2, 1, 384, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, H, S, hd, dtype, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), dtype)
    k = jax.random.normal(ks[1], (B, H, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, H, S, hd), dtype)
    out = flash_attention_fwd(q, k, v, causal=causal, block_q=128, block_k=128)
    want = ref.ref_attention(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("blocks", [(64, 64), (128, 32), (256, 128)])
def test_flash_attention_block_shapes(blocks):
    """Result must be independent of the BlockSpec tiling."""
    bq, bk = blocks
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    out = flash_attention_fwd(q, k, v, block_q=bq, block_k=bk)
    want = ref.ref_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("B,S,di,st", [
    (1, 64, 128, 8), (2, 64, 256, 16), (1, 128, 512, 16), (2, 96, 384, 4),
])
def test_selective_scan_sweep(B, S, di, st):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (B, S, di)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, di)) * 0.2)
    A = -jnp.exp(jax.random.normal(ks[2], (di, st)) * 0.3)
    Bc = jax.random.normal(ks[3], (B, S, st))
    Cc = jax.random.normal(ks[4], (B, S, st))
    D = jnp.ones((di,))
    y, h = selective_scan_fwd(x, dt, A, Bc, Cc, D, block_d=128, block_t=32)
    yr, hr = ref.ref_selective_scan(x, dt, A, Bc, Cc, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=1e-4)


def test_selective_scan_matches_model_scan():
    """Kernel agrees with the model's chunked lax.scan implementation."""
    from repro.models.mamba import selective_scan as model_scan
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    B, S, di, st = 2, 64, 256, 16
    x = jax.random.normal(ks[0], (B, S, di)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, di)) * 0.2)
    A = -jnp.exp(jax.random.normal(ks[2], (di, st)) * 0.3)
    Bc = jax.random.normal(ks[3], (B, S, st))
    Cc = jax.random.normal(ks[4], (B, S, st))
    D = jnp.ones((di,))
    y1, h1 = selective_scan_fwd(x, dt, A, Bc, Cc, D)
    y2, h2 = model_scan(x, dt, A, Bc, Cc, D)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4)


@pytest.mark.parametrize("n", [100, 1024, 4097, 65536])
@pytest.mark.parametrize("step", [1, 10])
def test_fused_adam_sweep(n, step):
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    p = jax.random.normal(ks[0], (n,), jnp.float32)
    m = jax.random.normal(ks[1], (n,)) * 0.1
    v = jnp.abs(jax.random.normal(ks[2], (n,))) * 0.01
    g = jax.random.normal(ks[3], (n,))
    p2, m2, v2, lp = fused_adam(p, m, v, g, step, lr=1e-2)
    pr, mr, vr = ref.ref_adam(p, m, v, g, step, lr=1e-2)
    np.testing.assert_allclose(np.asarray(p2), np.asarray(pr), atol=1e-6)
    np.testing.assert_allclose(np.asarray(m2), np.asarray(mr), atol=1e-7)
    np.testing.assert_allclose(np.asarray(v2), np.asarray(vr), atol=1e-7)
    np.testing.assert_allclose(np.asarray(lp, np.float32), np.asarray(pr),
                               atol=2e-2)  # bf16 low-precision copy


def test_fused_adam_partial_matches_two_stage():
    """Early [0,k) + late [k,n) kernel launches == one full launch —
    the α-delayed optimizer identity at kernel level."""
    n, k, step = 10_000, 6_000, 5
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    p = jax.random.normal(ks[0], (n,), jnp.float32)
    m = jnp.zeros((n,))
    v = jnp.zeros((n,))
    g = jax.random.normal(ks[3], (n,))
    pf, mf, vf, _ = fused_adam(p, m, v, g, step, lr=1e-2)
    p1, m1, v1, _ = fused_adam(p, m, v, g, step, lo=0, hi=k, lr=1e-2)
    p2, m2, v2, _ = fused_adam(p1, m1, v1, g, step, lo=k, hi=n, lr=1e-2)
    np.testing.assert_allclose(np.asarray(p2), np.asarray(pf), atol=1e-7)
    np.testing.assert_allclose(np.asarray(m2), np.asarray(mf), atol=1e-7)
    np.testing.assert_allclose(np.asarray(v2), np.asarray(vf), atol=1e-7)
