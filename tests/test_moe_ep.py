"""Expert-parallel MoE (shard_map + all_to_all) vs the dense-jit oracle.

Runs in a subprocess with 4 fake devices (2 data x 2 model) so the main
test process keeps its single real device.
"""
import os
import subprocess
import sys

import pytest

CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import math
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_smoke
from repro.models import moe as moe_lib
from repro.models import moe_ep

cfg = get_smoke("qwen3-moe-235b-a22b")   # 4 experts top-2 (reduced)
mesh = jax.make_mesh((2, 2), ("data", "model"))
key = jax.random.PRNGKey(0)
params = moe_lib.moe_init(key, cfg, dtype=jnp.float32)
B, S, d = 4, 16, cfg.d_model
x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d), jnp.float32) * 0.3

# oracle (single device semantics, generous capacity => no drops)
y_ref, aux_ref = moe_lib.moe_apply(params, x, cfg, capacity_factor=8.0)

moe_ep.set_ep_mesh(mesh, axis="model", bax=("data", "model"))
xs = jax.device_put(x, NamedSharding(mesh, P(("data", "model"), None, None)))
ps = jax.device_put(params, jax.tree_util.tree_map_with_path(
    lambda p, l: NamedSharding(mesh, P("model", None, None)
                 if "/".join(str(getattr(q, "key", q)) for q in p).startswith("w_")
                 else P()), params))
with jax.set_mesh(mesh):
    y_ep, aux_ep = jax.jit(
        lambda pp, xx: moe_ep.moe_apply_ep(pp, xx, cfg, capacity_factor=8.0)
    )(ps, xs)

err = float(jnp.max(jnp.abs(y_ep - y_ref)))
aerr = abs(float(aux_ep) - float(aux_ref))
print("MAXERR", err, "AUXERR", aerr)
assert err < 2e-4, err
assert aerr < 1e-5, (float(aux_ep), float(aux_ref))

# gradients: d loss / d expert weights must also agree
def loss_ref(pp, xx):
    y, aux = moe_lib.moe_apply(pp, xx, cfg, capacity_factor=8.0)
    return jnp.sum(y ** 2) + aux

def loss_ep(pp, xx):
    y, aux = moe_ep.moe_apply_ep(pp, xx, cfg, capacity_factor=8.0)
    return jnp.sum(y ** 2) + aux

g_ref = jax.grad(loss_ref)(params, x)
with jax.set_mesh(mesh):
    g_ep = jax.jit(jax.grad(loss_ep))(ps, xs)
for kref, kep in zip(jax.tree_util.tree_leaves_with_path(g_ref),
                     jax.tree_util.tree_leaves_with_path(g_ep)):
    name = "/".join(str(getattr(p, "key", p)) for p in kref[0])
    e = float(jnp.max(jnp.abs(kref[1] - kep[1])))
    rel = e / (float(jnp.max(jnp.abs(kref[1]))) + 1e-9)
    assert rel < 5e-4, (name, e, rel)
print("GRADS OK")
"""


@pytest.mark.slow
def test_moe_ep_matches_dense_oracle():
    env = {**os.environ, "PYTHONPATH": "src"}
    r = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                       text=True, env=env, cwd=os.path.join(
                           os.path.dirname(__file__), ".."))
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-4000:])
    assert "GRADS OK" in r.stdout
