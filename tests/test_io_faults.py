"""Fault-injection battery for `repro.io`: chunk ops that fail on demand
must propagate errors to `IORequest.result()`, release the in-flight
byte budget (no backpressure leak), leave worker/channel threads alive,
and honour the `IORequest.cancel` contract for queued vs in-flight
requests — all without deadlocking (every wait below is bounded).

The injector is the library's own :class:`repro.io.chaos.ChaosFiles`
(this battery grew it locally as ``FaultyFiles``/``DeadPathFiles``
before its promotion). Everything here uses the DETERMINISTIC knobs —
countdown fuses raising permanent EIO (so one fault propagates on the
first attempt, retries notwithstanding) and scripted path death; the
probabilistic chaos and integrity pins live in ``tests/test_chaos.py``.
"""
import errno
import os
import tempfile
import threading
from concurrent.futures import CancelledError

import numpy as np
import pytest

from repro.io import IOConfig, IOEngine, IOPriority, install_chaos
from repro.io.engine import PATH_FAIL_DRAIN_THRESHOLD
from repro.offload.stores import SSDStore, TrafficMeter

T = 5.0  # every blocking call in this file is bounded by this


def _faulty_store(root, **cfg_kw):
    cfg_kw.setdefault("chunk_bytes", 1 << 10)
    eng = IOEngine(IOConfig(paths=[os.path.join(root, "nvme0")], **cfg_kw))
    ssd = SSDStore(eng.paths[0], TrafficMeter(), engine=eng)
    install_chaos(ssd)                    # swap in the faulting backend
    return eng, ssd


def _dead_path_store(root, n_paths=2, **cfg_kw):
    cfg_kw.setdefault("chunk_bytes", 1 << 10)
    cfg_kw.setdefault("path_policy", "backlog")
    paths = [os.path.join(root, f"nvme{i}") for i in range(n_paths)]
    eng = IOEngine(IOConfig(paths=paths, **cfg_kw))
    ssd = SSDStore(paths[0], TrafficMeter(), engine=eng)
    install_chaos(ssd)
    return eng, ssd


# ---------------------------------------------------------------------------
# error propagation + budget release
# ---------------------------------------------------------------------------

def test_async_write_fault_propagates_to_result():
    with tempfile.TemporaryDirectory() as d:
        eng, ssd = _faulty_store(d)
        ssd.files.fail_writes = 1
        req = ssd.write_async("t", np.arange(256, dtype=np.float32), "ckpt")
        with pytest.raises(OSError, match="injected write fault"):
            req.result(timeout=T)
        assert req.done() and not req.cancelled()
        ssd.close()


def test_sync_read_write_faults_propagate():
    with tempfile.TemporaryDirectory() as d:
        eng, ssd = _faulty_store(d)
        arr = np.arange(4096, dtype=np.float32)
        ssd.write("t", arr, "opt")                      # clean write
        ssd.files.fail_reads = 1
        with pytest.raises(OSError, match="injected read fault"):
            ssd.read("t", "opt")
        # clean read: drains the failed read's leftover chunk ops (the
        # single channel thread is FIFO) and proves the data is intact
        np.testing.assert_array_equal(ssd.read("t", "opt"), arr)
        ssd.files.short_reads = 1
        with pytest.raises(IOError, match="short read"):
            ssd.read("t", "opt")
        ssd.files.short_reads = 0
        np.testing.assert_array_equal(ssd.read("t", "opt"), arr)
        ssd.close()


def test_failed_request_releases_inflight_budget():
    """A failed request must not leak its bytes from the backpressure
    budget: a follow-up request that needs the whole budget is admitted
    promptly instead of blocking forever."""
    with tempfile.TemporaryDirectory() as d:
        eng, ssd = _faulty_store(d, inflight_bytes=4096)
        ssd.files.fail_writes = 1
        big = np.zeros(1024, np.uint8)                  # budget / 4
        req = ssd.write_async("t", big, "ckpt")
        with pytest.raises(OSError):
            req.result(timeout=T)
        s = eng.metrics_snapshot()
        assert s["inflight_bytes"] == 0, "failed request leaked its bytes"
        assert s["completed"] == s["submitted"]
        # a request that needs the ENTIRE budget must get through
        admitted = threading.Event()

        def whole_budget():
            eng.submit(lambda: None, priority=IOPriority.CKPT_SPILL,
                       nbytes=4096).result(timeout=T)
            admitted.set()

        t = threading.Thread(target=whole_budget, daemon=True)
        t.start()
        assert admitted.wait(T), "budget was leaked by the failed request"
        t.join(T)
        ssd.close()


def test_failed_async_spill_releases_staging_buffer():
    """write_async stages through the double-buffered pool; a failing
    write must still release its staging slot (checked by acquiring the
    full pool afterwards without blocking)."""
    with tempfile.TemporaryDirectory() as d:
        eng, ssd = _faulty_store(d, staging_buffers=2)
        ssd.files.fail_writes = 2
        for i in range(2):
            with pytest.raises(OSError):
                ssd.write_async(f"t{i}", np.zeros(64, np.uint8),
                                "ckpt").result(timeout=T)
        got = threading.Event()

        def drain_pool():
            a = eng.staging.acquire(64)
            b = eng.staging.acquire(64)
            got.set()
            a.release()
            b.release()

        t = threading.Thread(target=drain_pool, daemon=True)
        t.start()
        assert got.wait(T), "failed spill leaked a staging buffer"
        t.join(T)
        ssd.close()


# ---------------------------------------------------------------------------
# worker survival
# ---------------------------------------------------------------------------

def test_worker_threads_survive_fault_storm():
    """20 consecutive failing requests must not kill the request workers
    or the path channel threads: a clean write afterwards round-trips."""
    with tempfile.TemporaryDirectory() as d:
        eng, ssd = _faulty_store(d, workers=2)
        ssd.files.fail_writes = 20
        reqs = [ssd.write_async(f"t{i}", np.zeros(32, np.uint8), "ckpt")
                for i in range(20)]
        for r in reqs:
            with pytest.raises(OSError):
                r.result(timeout=T)
        arr = np.arange(2048, dtype=np.float32)
        ssd.write("ok", arr, "opt")
        np.testing.assert_array_equal(ssd.read("ok", "opt"), arr)
        s = eng.metrics_snapshot()
        assert s["completed"] == s["submitted"]
        assert s["inflight_bytes"] == 0
        ssd.close()


# ---------------------------------------------------------------------------
# per-path fault isolation under dynamic placement
# ---------------------------------------------------------------------------

def test_dead_path_drains_placement_to_survivors():
    """One persistently failing path under ``path_policy="backlog"``:
    after PATH_FAIL_DRAIN_THRESHOLD consecutive chunk failures the
    policy stops choosing the path for NEW chunks, so writes drain to
    the survivors and round-trip cleanly — while reads of chunks
    already placed on the dead path keep failing loudly, and none of
    the failures leak backpressure budget or staging slots."""
    with tempfile.TemporaryDirectory() as d:
        eng, ssd = _dead_path_store(d, staging_buffers=2)
        pre = np.arange(2048, dtype=np.float32)       # 8 chunks, spread
        ssd.write("pre", pre, "opt")                  # over both paths
        assert any(ssd.files.placement("pre", c)[0] == 1 for c in range(8))
        ssd.files.dead_path = 1

        # already-placed chunks on the dead path: reads fail loudly,
        # they are NOT silently rerouted
        with pytest.raises(OSError, match="dead-path read fault"):
            ssd.read("pre", "opt")

        # keep writing; every chunk still sent to the dead path fails
        # the whole write, until the drain threshold excludes the path
        survivor = None
        for i in range(4 * PATH_FAIL_DRAIN_THRESHOLD):
            arr = np.full(1024, i, dtype=np.float32)  # 4 full chunks
            try:
                ssd.write(f"t{i}", arr, "opt")
                survivor = (f"t{i}", arr)
                break
            except OSError:
                pass
        assert survivor is not None, \
            "placement never drained off the dead path"
        assert eng.metrics_snapshot()["path_failures"][1] >= PATH_FAIL_DRAIN_THRESHOLD

        # the surviving write landed wholly on path 0 and round-trips;
        # so does everything written afterwards (sync and async)
        name, arr = survivor
        assert all(ssd.files.placement(name, c)[0] == 0 for c in range(4))
        np.testing.assert_array_equal(ssd.read(name, "opt"), arr)
        after = np.arange(1024, dtype=np.float32)
        ssd.write_async("after", after, "ckpt").result(timeout=T)
        np.testing.assert_array_equal(ssd.read("after", "ckpt"), after)

        # no leaks from the failure storm: budget drained and the full
        # staging pool is still acquirable
        s = eng.metrics_snapshot()
        assert s["inflight_bytes"] == 0
        assert s["completed"] == s["submitted"]
        got = threading.Event()

        def drain_pool():
            a = eng.staging.acquire(64)
            b = eng.staging.acquire(64)
            got.set()
            a.release()
            b.release()

        t = threading.Thread(target=drain_pool, daemon=True)
        t.start()
        assert got.wait(T), "dead-path failures leaked a staging buffer"
        t.join(T)
        ssd.close()


# ---------------------------------------------------------------------------
# cancellation contract (queued vs in-flight), bounded waits throughout
# ---------------------------------------------------------------------------

def test_cancel_queued_request_contract():
    with tempfile.TemporaryDirectory() as d:
        eng = IOEngine(IOConfig(paths=[os.path.join(d, "p")], workers=1))
        gate, started = threading.Event(), threading.Event()

        def block():
            started.set()
            gate.wait(T)

        blocker = eng.submit(block, priority=IOPriority.PARAM_FETCH,
                             nbytes=10)
        assert started.wait(T)
        victim = eng.submit(lambda: None, priority=IOPriority.CKPT_SPILL,
                            nbytes=77)
        assert victim.cancel() is True        # queued: cancel succeeds
        assert victim.cancel() is True        # idempotent per Future
        assert victim.cancelled() and victim.done()
        with pytest.raises(CancelledError):
            victim.result(timeout=T)
        gate.set()
        blocker.result(timeout=T)
        s = eng.metrics_snapshot()
        assert s["cancelled"] == 1            # settled exactly once
        assert s["inflight_bytes"] == 0       # victim's 77 bytes released
        eng.shutdown()


def test_cancel_inflight_request_contract():
    """A running request cannot be cancelled; cancel() returns False and
    the request is drained to completion (or failure) normally."""
    with tempfile.TemporaryDirectory() as d:
        eng, ssd = _faulty_store(d, workers=1)
        gate, started = threading.Event(), threading.Event()

        def block():
            started.set()
            gate.wait(T)
            raise OSError(errno.EIO, "late fault")

        req = eng.submit(block, priority=IOPriority.OPTIMIZER_STATE,
                         nbytes=123)
        assert started.wait(T)
        assert req.cancel() is False          # in-flight: best-effort only
        assert not req.cancelled()
        gate.set()
        with pytest.raises(OSError, match="late fault"):
            req.result(timeout=T)
        assert req.cancel() is False          # done: still not cancellable
        s = eng.metrics_snapshot()
        assert s["cancelled"] == 0
        assert s["inflight_bytes"] == 0       # failure released the bytes
        ssd.close()
