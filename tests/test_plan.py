"""Schedule-IR unit battery: the canonical micro-batch order, the
vertical/horizontal/wave compilers, the PREFETCH lookahead pass, and the
static ``plan_traffic`` analyzer cross-checked against the closed forms
in ``repro.core.traffic`` — all without constructing an engine (the
engine-level three-way cross-check lives in ``test_plan_executor.py``).
"""
import types

import pytest

from repro.core.plan import (Op, PlanCosts, PlanSpec, compile_horizontal,
                             compile_vertical, compile_wave, insert_prefetch,
                             mb_order, plan_traffic, shard_bounds)
from repro.core.perfmodel import StorageRatios
from repro.core.traffic import dp_vertical_traffic, wave_ckpt_traffic

L, M = 3, 4
SPEC = PlanSpec(L=L, M=M)


# ---------------------------------------------------------------------------
# canonical micro-batch order (satellite: ONE implementation, pinned)
# ---------------------------------------------------------------------------

def test_mb_order_alternates():
    """§4.2 regression pin: even layers consume ascending, odd layers
    descending, so each boundary's producer emits in the reverse of its
    consumer's order."""
    assert mb_order(4, 0) == [0, 1, 2, 3]
    assert mb_order(4, 1) == [3, 2, 1, 0]
    assert mb_order(4, 2) == [0, 1, 2, 3]
    assert mb_order(1, 0) == [0] == mb_order(1, 1)
    for l in range(5):
        assert mb_order(6, l) == list(reversed(mb_order(6, l + 1)))
        assert sorted(mb_order(6, l)) == list(range(6))


def test_engines_delegate_to_canonical_order():
    """Both engines' ``_mb_order`` is the canonical repro.core.plan one
    (the duplicate module-level copy in offload.engine re-exports it)."""
    from repro.offload import engine as eng_mod
    from repro.offload.dp import DataParallelOffloadEngine
    from repro.offload.engine import OffloadEngine

    assert eng_mod.mb_order is mb_order
    stub = types.SimpleNamespace(
        ocfg=types.SimpleNamespace(num_microbatches=6))
    for l in range(4):
        assert OffloadEngine._mb_order(stub, l) == mb_order(6, l)
        assert DataParallelOffloadEngine._mb_order(stub, l) == mb_order(6, l)


# ---------------------------------------------------------------------------
# compilers
# ---------------------------------------------------------------------------

def test_wave_specializations():
    v = compile_vertical(SPEC)
    h = compile_horizontal(SPEC)
    w = compile_wave(SPEC, 2)
    assert (v.schedule, h.schedule, w.schedule) == \
        ("vertical", "horizontal", "wave")
    assert v.ops == compile_wave(SPEC, M).ops
    assert h.ops == compile_wave(SPEC, 1).ops
    # params fetched twice per wave: 2·L·nw fetches
    for plan, nw in ((v, 1), (h, M), (w, 2)):
        assert plan.num_waves == nw
        assert plan.count(Op.FETCH_PARAM) == 2 * L * nw
        # every boundary (0..L) spilled for every micro-batch
        assert plan.count(Op.SPILL_CKPT) == (L + 1) * M
        assert plan.count(Op.FWD) == plan.count(Op.BWD) == L * M
        assert plan.count(Op.HEAD_BWD) == plan.count(Op.EMBED_FWD) == M
        assert plan.count(Op.WRITEBACK_GRAD) == L
        assert plan.count(Op.RESET_PARAMS) == nw
    # cross-wave f32 buffer swap: (nw-1) spills + (nw-1) fetches per layer
    assert v.count(Op.GRAD_SPILL) == v.count(Op.GRAD_FETCH_ACC) == 0
    assert h.count(Op.GRAD_SPILL) == h.count(Op.GRAD_FETCH_ACC) == L * (M - 1)
    assert w.count(Op.GRAD_SPILL) == w.count(Op.GRAD_FETCH_ACC) == L


def test_keep_flags_one_per_boundary_per_wave():
    for W in (1, 2, M):
        plan = compile_wave(SPEC, W)
        nw = M // W
        kept = [op for op in plan.ops if op.op is Op.SPILL_CKPT and op.keep]
        # one kept checkpoint per boundary per wave
        assert len(kept) == (L + 1) * nw
        kept_g = [op for op in plan.ops if op.op is Op.SPILL_GRAD and op.keep]
        assert len(kept_g) == (L + 1) * nw


def test_compile_validation():
    with pytest.raises(ValueError, match="divide"):
        compile_wave(SPEC, 3)
    with pytest.raises(ValueError, match="divide"):
        compile_wave(SPEC, 0)
    with pytest.raises(ValueError, match="vertical"):
        compile_wave(PlanSpec(L=2, M=4, ranks=2), 2)
    with pytest.raises(ValueError, match="ranks"):
        compile_vertical(PlanSpec(L=2, M=3, ranks=2))


def test_alpha_emits_gates_and_skips_wait():
    a = compile_vertical(PlanSpec(L=L, M=M, alpha=0.5))
    z = compile_vertical(SPEC)
    assert a.count(Op.OPT_LATE) == L and z.count(Op.OPT_LATE) == 0
    assert a.count(Op.WAIT_OPT) == 0 and z.count(Op.WAIT_OPT) == 1


def test_dp_plan_uses_collective_ops():
    plan = compile_vertical(PlanSpec(L=L, M=M, ranks=2))
    assert plan.count(Op.ALLGATHER) == 2 * L
    assert plan.count(Op.FETCH_PARAM) == 0
    assert plan.count(Op.REDUCE_SCATTER) == L
    assert plan.count(Op.WRITEBACK_GRAD) == 0
    assert plan.count(Op.FOLD_HEAD) == plan.count(Op.FOLD_EMBED) == 1
    assert plan.count(Op.ALLREDUCE_HEAD) == 1
    # rank-major emission: each layer's FWD micro-batches are the global
    # alternating order restricted to each rank's contiguous block
    fwd_l0 = [op.m for op in plan.ops if op.op is Op.FWD and op.l == 0]
    assert fwd_l0 == [0, 1, 2, 3]
    fwd_l1 = [op.m for op in plan.ops if op.op is Op.FWD and op.l == 1]
    assert fwd_l1 == [1, 0, 3, 2]      # descending within each rank block


# ---------------------------------------------------------------------------
# the PREFETCH lookahead pass
# ---------------------------------------------------------------------------

def _prefetched(plan):
    return [op.l for op in plan.ops if op.op is Op.PREFETCH]


def test_prefetch_one_hint_per_fetch_never_across_reset():
    for W in (1, 2, M):
        plan = insert_prefetch(compile_wave(SPEC, W))
        assert plan.count(Op.PREFETCH) == plan.count(Op.FETCH_PARAM)
        # a hint between a RESET_PARAMS and the next fetch must target
        # that next fetch's layer (no hint survives a reset)
        ops = plan.ops
        for i, op in enumerate(ops):
            if op.op is not Op.RESET_PARAMS:
                continue
            tail = ops[i + 1:]
            hint = next(o for o in tail if o.op is Op.PREFETCH)
            fetch = next(o for o in tail if o.op is Op.FETCH_PARAM)
            assert hint.l == fetch.l == L - 1


def test_prefetch_two_stage_pipeline_order():
    plan = insert_prefetch(compile_vertical(SPEC))
    ops = plan.ops
    # opening: PREFETCH(0) before any compute op
    assert ops[0].op is Op.PREFETCH and ops[0].l == 0
    # after FETCH_PARAM(l) the very next op is the NEXT fetch's hint,
    # for every fetch that still has a successor in its segment
    # (forward: l+1; backward: l-1; the plan's last fetch has none)
    fetches = [(i, op) for i, op in enumerate(ops)
               if op.op is Op.FETCH_PARAM]
    reset_at = next(i for i, op in enumerate(ops)
                    if op.op is Op.RESET_PARAMS)
    for i, op in fetches:
        expect = op.l + 1 if i < reset_at else op.l - 1
        if 0 <= expect < L:
            nxt = ops[i + 1]
            assert nxt.op is Op.PREFETCH and nxt.l == expect, (i, nxt)


def test_alpha_tail_epilogue_seam():
    """The cross-iteration seam: the α-tail OPT_LATE flushes are
    emitted in the plan EPILOGUE (after the last backward writeback),
    each preceded by exactly one PREFETCH_OPT hint placed at its
    layer's WRITEBACK_GRAD — so iteration i's tail flush and state
    reads are in flight together with iteration i+1's first param
    fetches, whose hints sit at plan START (the fetch gate, runtime
    state re-armed by each OPT_LATE, enforces flush-before-fetch)."""
    plan = insert_prefetch(compile_vertical(PlanSpec(L=L, M=M, alpha=0.3)))
    kinds = [op.op for op in plan.ops]
    assert plan.count(Op.OPT_LATE) == plan.count(Op.PREFETCH_OPT) == L
    # next iteration's first param hint is the very first op
    assert kinds.index(Op.PREFETCH) == 0
    # every OPT_LATE sits after the last WRITEBACK_GRAD (the epilogue)
    last_wb = max(i for i, k in enumerate(kinds)
                  if k is Op.WRITEBACK_GRAD)
    assert min(i for i, k in enumerate(kinds) if k is Op.OPT_LATE) > last_wb
    # each PREFETCH_OPT(l) follows its layer's WRITEBACK_GRAD(l) and
    # precedes its OPT_LATE(l)
    for l in range(L):
        wb = next(i for i, op in enumerate(plan.ops)
                  if op.op is Op.WRITEBACK_GRAD and op.l == l)
        hint = next(i for i, op in enumerate(plan.ops)
                    if op.op is Op.PREFETCH_OPT and op.l == l)
        late = next(i for i, op in enumerate(plan.ops)
                    if op.op is Op.OPT_LATE and op.l == l)
        assert wb < hint < late, (l, wb, hint, late)
    # α = 0 plans carry neither
    z = insert_prefetch(compile_vertical(SPEC))
    assert z.count(Op.OPT_LATE) == z.count(Op.PREFETCH_OPT) == 0


# ---------------------------------------------------------------------------
# static traffic analyzer vs closed forms (no engine, exact)
# ---------------------------------------------------------------------------

P, E = 1000, 64            # per-layer param elements / boundary elements
COSTS = PlanCosts(P=P, param_itemsize=4, ckpt_elems=E, act_itemsize=4,
                  ratios=StorageRatios(0.0, 0.0, 0.0), alpha=0.0)


def _expected(W, alpha=0.0):
    """The closed-form (category, route) map for the f32 engine at
    x = (0, 0, 0): ms = L·P·4 (params are f32 here), grads f32 = ms,
    optimizer state = 3·ms, ckpt unit u = E·4."""
    ms = L * P * 4
    u = E * 4
    nw = M // W
    ct = wave_ckpt_traffic(L * u, M, W, L)
    exp = {
        ("param", "ssd->cpu"): 2 * nw * ms,
        ("param", "cpu->gpu"): 2 * nw * ms,
        ("param", "cpu->ssd"): ms,
        ("grad", "gpu->cpu"): nw * ms,
        ("opt", "ssd->cpu"): 3 * ms,
        ("opt", "cpu->ssd"): 3 * ms,
        ("ckpt", "gpu->cpu"): ct.write,
        ("ckpt", "cpu->gpu"): ct.read,
        ("ckpt", "cpu->ssd"): ct.ssd_spill,
        ("ckpt", "ssd->cpu"): ct.ssd_reread,
        ("inter_grad", "gpu->cpu"): ct.inter_grad / 2,
        ("inter_grad", "cpu->gpu"): ct.inter_grad / 2,
    }
    if nw > 1:
        exp[("grad", "cpu->gpu")] = (nw - 1) * ms
    return {k: v for k, v in exp.items() if v}


@pytest.mark.parametrize("W", [1, 2, 4])
@pytest.mark.parametrize("alpha", [0.0, 0.5])
def test_plan_traffic_matches_closed_forms(W, alpha):
    spec = PlanSpec(L=L, M=M, alpha=alpha)
    costs = PlanCosts(P=P, param_itemsize=4, ckpt_elems=E, act_itemsize=4,
                      ratios=StorageRatios(0.0, 0.0, 0.0), alpha=alpha)
    got = plan_traffic(insert_prefetch(compile_wave(spec, W)), costs)
    assert got == _expected(W, alpha)


def test_plan_traffic_wave_interpolates():
    """The wave knob's trade: ckpt re-reads + inter-layer gradients grow
    with W while parameter (re)loads shrink — wave W=2 sits strictly
    between horizontal and vertical on both axes."""
    t = {W: plan_traffic(compile_wave(SPEC, W), COSTS) for W in (1, 2, 4)}

    def g(W, key):
        return t[W].get(key, 0)

    assert g(1, ("param", "cpu->gpu")) > g(2, ("param", "cpu->gpu")) \
        > g(4, ("param", "cpu->gpu"))
    assert g(1, ("ckpt", "cpu->gpu")) < g(2, ("ckpt", "cpu->gpu")) \
        < g(4, ("ckpt", "cpu->gpu"))
    assert g(1, ("inter_grad", "cpu->gpu")) == 0
    assert g(2, ("inter_grad", "cpu->gpu")) \
        < g(4, ("inter_grad", "cpu->gpu"))


def test_plan_traffic_predicts_eviction_penalty():
    """Compiling from a PERTURBED order (always ascending) costs exactly
    one evicted checkpoint re-read per interior boundary and one spilled
    inter-layer gradient round trip — the §4.2 closed-form penalty the
    engine-level boundary test measures."""
    good = plan_traffic(compile_vertical(SPEC), COSTS)
    bad = plan_traffic(
        compile_vertical(SPEC, order=lambda l: list(range(M))), COSTS)
    u = E * 4
    assert bad[("ckpt", "cpu->gpu")] - good[("ckpt", "cpu->gpu")] == L * u
    ig_good = good[("inter_grad", "gpu->cpu")] \
        + good[("inter_grad", "cpu->gpu")]
    ig_bad = bad[("inter_grad", "gpu->cpu")] \
        + bad[("inter_grad", "cpu->gpu")]
    assert ig_bad - ig_good == 2 * L * u


@pytest.mark.parametrize("alpha", [0.0, 0.5])
def test_plan_traffic_dp_matches_closed_form(alpha):
    """The DP plan's per-rank prediction equals dp_vertical_traffic —
    statically, without building a 2-rank engine."""
    R = 2
    spec = PlanSpec(L=L, M=M, alpha=alpha, ranks=R)
    costs = PlanCosts(P=P, param_itemsize=4, ckpt_elems=E, act_itemsize=4,
                      ratios=StorageRatios(0.0, 0.0, 0.0), alpha=alpha,
                      ranks=R, head_nbytes=1 << 20)
    per_rank = plan_traffic(insert_prefetch(compile_vertical(spec)), costs)
    assert len(per_rank) == R
    ms = L * P * 4
    u = E * 4
    t = dp_vertical_traffic(ms, L * u, M, R, grad_bytes=ms, os_bytes=3 * ms,
                            n_layers=L)
    ring_head = 2 * (R - 1) * costs.head_nbytes // R
    for got in per_rank:
        want = {
            ("param", "cpu->gpu"): t.param_fetch,
            ("param", "ssd->cpu"): t.param_fetch,
            ("param", "net->gpu"): t.param_allgather,
            ("param", "gpu->net"): t.param_allgather,
            ("param", "cpu->ssd"): t.param_writeback,
            ("grad", "gpu->cpu"): t.grad_offload,
            ("grad", "net->gpu"): t.grad_reducescatter,
            ("grad", "gpu->net"): t.grad_reducescatter,
            ("opt", "ssd->cpu"): t.opt_read,
            ("opt", "cpu->ssd"): t.opt_write,
            ("ckpt", "gpu->cpu"): t.ckpt.write,
            ("ckpt", "cpu->gpu"): t.ckpt.read,
            ("ckpt", "cpu->ssd"): t.ckpt.ssd_spill,
            ("ckpt", "ssd->cpu"): t.ckpt.ssd_reread,
            ("inter_grad", "gpu->cpu"): t.ckpt.inter_grad / 2,
            ("inter_grad", "cpu->gpu"): t.ckpt.inter_grad / 2,
            ("head_grad", "gpu->net"): ring_head,
            ("head_grad", "net->gpu"): ring_head,
        }
        for key, expect in want.items():
            assert got.get(key, 0) == expect, (key, got.get(key, 0), expect)


def test_wave_traffic_endpoints_match_paper_schedules():
    """The smooth wave form's endpoints ARE the paper forms: W=M is
    vertical_traffic, W=1 is horizontal_traffic (in particular the
    backward recompute reads are never cancelled by the keep saving),
    and the wave LP accepts wave=n as vertical under data parallelism."""
    from repro.core.traffic import (horizontal_traffic, vertical_traffic,
                                    wave_traffic)
    ms, cs = 100.0, 10.0
    assert wave_traffic(ms, cs, 8, 8) == vertical_traffic(ms, cs, 8)
    assert wave_traffic(ms, cs, 8, 1) == horizontal_traffic(ms, cs, 8)
    w2 = wave_traffic(ms, cs, 8, 2)
    assert w2.ckpt_read == (2 * 8 - 4) * cs      # bwd reads all M mbs
    assert w2.inter_grad == 2 * (8 - 4) * cs

    from repro.core.lp_search import solve_config
    from repro.core.perfmodel import MachineParams, Workload
    w = Workload(ms=20e9, cs=0.5e9, os_bytes=120e9, grad_bytes=40e9,
                 flops_per_mb=2e9 * 2 * 4096, tokens_per_mb=4096)
    m = MachineParams()
    dp_none = solve_config(m, w, 8, 0.2, num_gpus=2)
    dp_wave = solve_config(m, w, 8, 0.2, num_gpus=2, wave=8)
    assert dp_none is not None and dp_wave == dp_none
    # a true wave under DP is an argument error, not infeasibility
    # (None strictly means the LP has no feasible point)
    with pytest.raises(ValueError, match="wave"):
        solve_config(m, w, 8, 0.2, num_gpus=2, wave=2)


def test_shard_bounds_cover_contiguously():
    for n, world in [(10, 2), (7, 3), (5, 5), (3, 4)]:
        b = shard_bounds(n, world)
        assert b[0][0] == 0 and b[-1][1] == n
        assert all(b[i][1] == b[i + 1][0] for i in range(world - 1))
        sizes = [hi - lo for lo, hi in b]
        assert max(sizes) - min(sizes) <= 1
