"""Unit tests for the `repro.io` subsystem: priority ordering, chunked
striping round-trips, cancellation, backpressure, bandwidth pacing, the
staging pool, and the store-level API built on top of it."""
import os
import tempfile
import threading
import time

import numpy as np
import pytest

from repro.io import (IOConfig, IOEngine, IOPriority, StagingPool,
                      TokenBucket)
from repro.offload.coordinators import ParameterCoordinator
from repro.offload.stores import HostStore, SSDStore, TieredVector, TrafficMeter


def _engine(tmp, n_paths=1, **kw):
    paths = []
    for i in range(n_paths):
        p = os.path.join(tmp, f"path{i}")
        paths.append(p)
    kw.setdefault("chunk_bytes", 1000)   # odd size: exercises boundaries
    return IOEngine(IOConfig(paths=paths, **kw))


# ---------------------------------------------------------------------------
# request scheduling
# ---------------------------------------------------------------------------

def test_priority_ordering():
    """With one worker pinned by a blocker, queued requests must drain
    param-fetch first and ckpt-spill last regardless of submit order."""
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(d, workers=1)
        gate = threading.Event()
        ran = []
        blocker = eng.submit(gate.wait, priority=IOPriority.PARAM_FETCH)
        reqs = [eng.submit((lambda p=p: ran.append(p)), priority=p)
                for p in (IOPriority.CKPT_SPILL, IOPriority.OPTIMIZER_STATE,
                          IOPriority.INTER_LAYER_GRAD, IOPriority.PARAM_FETCH)]
        gate.set()
        blocker.result()
        for r in reqs:
            r.result()
        eng.shutdown()
        assert ran == [IOPriority.PARAM_FETCH, IOPriority.INTER_LAYER_GRAD,
                       IOPriority.OPTIMIZER_STATE, IOPriority.CKPT_SPILL]


def test_fifo_within_priority():
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(d, workers=1)
        gate = threading.Event()
        ran = []
        eng.submit(gate.wait, priority=IOPriority.PARAM_FETCH)
        reqs = [eng.submit((lambda i=i: ran.append(i)),
                           priority=IOPriority.OPTIMIZER_STATE)
                for i in range(5)]
        gate.set()
        for r in reqs:
            r.result()
        eng.shutdown()
        assert ran == [0, 1, 2, 3, 4]


def test_cancellation_before_start():
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(d, workers=1)
        gate, started = threading.Event(), threading.Event()

        def block():
            started.set()
            gate.wait()

        ran = []
        blocker = eng.submit(block, priority=IOPriority.PARAM_FETCH,
                             nbytes=100)
        assert started.wait(5.0)
        victim = eng.submit(lambda: ran.append("victim"),
                            priority=IOPriority.CKPT_SPILL, nbytes=50)
        assert victim.cancel()
        assert victim.cancelled()
        assert not blocker.cancel()          # already running
        gate.set()
        blocker.result()
        eng.shutdown()
        assert ran == []
        s = eng.metrics_snapshot()
        assert s["cancelled"] == 1
        assert s["inflight_bytes"] == 0      # cancelled bytes released


def test_exception_propagates():
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(d)

        def boom():
            raise ValueError("kaput")

        req = eng.submit(boom, priority=IOPriority.OPTIMIZER_STATE)
        with pytest.raises(ValueError, match="kaput"):
            req.result()
        eng.shutdown()


def test_backpressure_budget():
    """submit() must block while in-flight bytes would exceed the budget
    and resume as soon as the holder completes."""
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(d, workers=1, inflight_bytes=1000)
        gate = threading.Event()
        eng.submit(gate.wait, priority=IOPriority.PARAM_FETCH, nbytes=900)
        admitted = threading.Event()

        def try_submit():
            eng.submit(lambda: None, priority=IOPriority.CKPT_SPILL,
                       nbytes=500)
            admitted.set()

        t = threading.Thread(target=try_submit, daemon=True)
        t.start()
        assert not admitted.wait(0.3), "submit should have blocked"
        gate.set()
        assert admitted.wait(5.0), "submit should unblock on release"
        t.join()
        eng.shutdown()
        assert eng.metrics_snapshot()["max_inflight_bytes"] <= 1000


def test_default_config_not_shared_between_engines():
    """Regression: the default IOConfig used to be created once at
    class-definition time, so every default-constructed engine aliased
    the same config object (and the same mutable ``bandwidth`` dict)."""
    with tempfile.TemporaryDirectory() as d:
        e1 = IOEngine(default_root=os.path.join(d, "a"))
        e2 = IOEngine(default_root=os.path.join(d, "b"))
        try:
            assert e1.config is not e2.config
            assert e1.config.bandwidth is not e2.config.bandwidth
            # mutating one engine's bandwidth map must not leak into the
            # other engine's config or pacing
            e1.config.bandwidth["cpu->ssd"] = 1.0
            assert "cpu->ssd" not in e2.config.bandwidth
            assert e2.simulator.cap("cpu->ssd") is None
            # and per-engine state is per-engine
            assert e1.staging is not e2.staging
            assert e1.simulator is not e2.simulator
        finally:
            e1.shutdown()
            e2.shutdown()


# ---------------------------------------------------------------------------
# chunked striped storage
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_paths", [1, 3])
def test_striped_roundtrip_bit_exact(n_paths):
    rng = np.random.default_rng(0)
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(d, n_paths=n_paths)
        meter = TrafficMeter()
        ssd = SSDStore(os.path.join(d, "path0"), meter, engine=eng)
        arrays = {}
        for i, n in enumerate([1, 249, 250, 251, 3000, 25000]):
            arr = rng.standard_normal(n).astype(np.float32)
            ssd.write(f"t{i}", arr, "opt")
            arrays[f"t{i}"] = arr
        for name, arr in arrays.items():
            np.testing.assert_array_equal(ssd.read(name, "opt"), arr)
        # partial reads/writes against a numpy reference
        ref = arrays["t5"].copy()
        got = ssd.read_range("t5", 123, 7777, "opt")
        np.testing.assert_array_equal(got, ref[123:7777])
        patch = rng.standard_normal(5000).astype(np.float32)
        ssd.write_range("t5", patch, 1111, "opt")
        ref[1111:6111] = patch
        np.testing.assert_array_equal(ssd.read("t5", "opt"), ref)
        # byte counters: metered once per call, chunking invisible
        assert meter.bytes[("opt", "cpu->ssd")] == \
            sum(a.nbytes for a in arrays.values()) + patch.nbytes
        ssd.close()


def test_stripes_land_on_every_path():
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(d, n_paths=3)
        ssd = SSDStore(os.path.join(d, "path0"), TrafficMeter(), engine=eng)
        ssd.write("big", np.zeros(25000, np.float32), "opt")  # 100 chunks
        for p in eng.paths:
            files = os.listdir(p)
            assert any(f.startswith("big") for f in files), (p, files)
        ssd.close()
        for p in eng.paths:
            assert os.listdir(p) == []       # close() removed all stripes


def test_delete_and_keyerror():
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(d)
        ssd = SSDStore(os.path.join(d, "path0"), TrafficMeter(), engine=eng)
        ssd.write("x", np.arange(10, dtype=np.float32), "opt")
        assert ssd.exists("x")
        ssd.delete("x")
        assert not ssd.exists("x")
        assert os.listdir(eng.paths[0]) == []
        with pytest.raises(KeyError, match="'x'"):
            ssd.read("x", "opt")
        with pytest.raises(KeyError, match="'nope'"):
            ssd.delete("nope")
        with pytest.raises(KeyError, match="'nope'"):
            ssd.read_range("nope", 0, 1, "opt")
        ssd.close()


def test_close_drains_queued_async_spills():
    """A spill still queued when close() runs must not recreate its
    stripe files after the cleanup pass."""
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(d, workers=1)
        ssd = SSDStore(eng.paths[0], TrafficMeter(), engine=eng)
        gate = threading.Event()
        eng.submit(gate.wait, priority=IOPriority.PARAM_FETCH)  # jam worker
        req = ssd.write_async("spill", np.arange(100, dtype=np.float32),
                              "ckpt")
        t = threading.Thread(
            target=lambda: (time.sleep(0.2), gate.set()), daemon=True)
        t.start()
        ssd.close()                          # must drain req, then clean
        t.join()
        assert req.done()
        assert os.listdir(eng.paths[0]) == []


def test_tiered_vector_through_engine():
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(d, n_paths=2)
        meter = TrafficMeter()
        host, ssd = HostStore(meter), SSDStore(eng.paths[0], meter, engine=eng)
        tv = TieredVector("tv", 5000, np.float32, 0.4, host, ssd, "opt")
        full = np.arange(5000, dtype=np.float32)
        tv.write_full(full)
        np.testing.assert_array_equal(tv.read(), full)
        seg = -np.arange(1000, dtype=np.float32)
        tv.write_seg(seg, 1500)          # straddles the host/SSD split
        full[1500:2500] = seg
        np.testing.assert_array_equal(tv.read(), full)
        np.testing.assert_array_equal(tv.read_range(1900, 2600),
                                      full[1900:2600])
        # out= lands the SSD chunks straight in the caller's buffer
        out = np.empty(700, np.float32)
        assert tv.read_range(1900, 2600, out=out) is out
        np.testing.assert_array_equal(out, full[1900:2600])
        ssd.close()


# ---------------------------------------------------------------------------
# bandwidth simulation
# ---------------------------------------------------------------------------

def test_token_bucket_rate():
    # best-of-3: wall-clock timing on a loaded CI runner can stall one
    # attempt, but the bucket's self-correcting refill makes a clean
    # attempt land within the +-20/25% band.
    rates = []
    for _ in range(3):
        tb = TokenBucket(10e6, burst=1e5)
        t0 = time.perf_counter()
        total = 0
        while total < 2_000_000:
            tb.consume(100_000)
            total += 100_000
        rates.append(total / (time.perf_counter() - t0))
        if 0.8 * 10e6 <= rates[-1] <= 1.25 * 10e6:
            break
    assert any(0.8 * 10e6 <= r <= 1.25 * 10e6 for r in rates), rates


def test_bandwidth_cap_reproduced_within_20pct():
    """A configured cpu->ssd cap must show up in wall-clock throughput
    (the perfmodel-validation path)."""
    cap = 100e6
    measured = []
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(d, chunk_bytes=1 << 20, bandwidth={"cpu->ssd": cap})
        ssd = SSDStore(eng.paths[0], TrafficMeter(), engine=eng)
        ssd.write("warm", np.zeros(6 << 20, np.uint8), "opt")  # settle fds
        big = np.zeros(24 << 20, np.uint8)
        for r in range(3):                   # best-of-3 against CI noise
            t0 = time.perf_counter()
            ssd.write(f"big{r}", big, "opt")
            measured.append(big.nbytes / (time.perf_counter() - t0))
            if 0.8 * cap <= measured[-1] <= 1.2 * cap:
                break
        ssd.close()
    assert any(0.8 * cap <= m <= 1.2 * cap for m in measured), \
        [f"{m / 1e6:.1f} MB/s" for m in measured]


# ---------------------------------------------------------------------------
# staging pool
# ---------------------------------------------------------------------------

def test_staging_pool_double_buffer_blocks():
    pool = StagingPool(nbuf=2, buf_bytes=1000)
    a, b = pool.acquire(500), pool.acquire(700)
    got_third = threading.Event()

    def third():
        c = pool.acquire(100)
        got_third.set()
        c.release()

    t = threading.Thread(target=third, daemon=True)
    t.start()
    assert not got_third.wait(0.2), "third acquire should block"
    a.release()
    assert got_third.wait(5.0)
    t.join()
    b.release()
    big = pool.acquire(5000)                 # oversized: one-off allocation
    assert big.view.nbytes == 5000
    big.release()
    assert pool.oversized_allocs == 1


def test_staging_release_idempotent():
    pool = StagingPool(nbuf=1, buf_bytes=100)
    a = pool.acquire(10)
    a.release()
    a.release()
    b = pool.acquire(10)                     # double release didn't corrupt
    b.release()
    assert len(pool._free) == 1


# ---------------------------------------------------------------------------
# host residency + coordinator reset
# ---------------------------------------------------------------------------

def test_host_store_peak_tracking():
    h = HostStore(TrafficMeter())
    h.put("a", np.zeros(100, np.uint8))
    h.put("b", np.zeros(300, np.uint8))
    assert h.nbytes() == 400 and h.peak_nbytes == 400
    h.pop("a")
    assert h.nbytes() == 300 and h.peak_nbytes == 400
    h.put("b", np.zeros(50, np.uint8))       # replace shrinks residency
    assert h.nbytes() == 50 and h.peak_nbytes == 400
    h.put("c", np.zeros(600, np.uint8))
    assert h.nbytes() == 650 and h.peak_nbytes == 650


def test_parameter_coordinator_reset_cancels_prefetches():
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(d, workers=1)
        meter = TrafficMeter()
        host, ssd = HostStore(meter), SSDStore(eng.paths[0], meter, engine=eng)
        vecs = []
        for l in range(3):
            tv = TieredVector(f"param:{l}", 100, np.float32, 0.0, host, ssd,
                              "param")
            tv.write_full(np.full(100, float(l), np.float32))
            vecs.append(tv)
        pc = ParameterCoordinator(vecs, meter, eng)
        gate = threading.Event()
        blocker = eng.submit(gate.wait, priority=IOPriority.PARAM_FETCH)
        for l in range(3):
            pc.prefetch(l)
        pc.reset()                           # cancels all queued fetches
        gate.set()
        blocker.result()
        eng.shutdown()
        assert pc._futures == {}
        assert ("param", "ssd->cpu") not in meter.bytes  # nothing was read
        assert eng.metrics_snapshot()["cancelled"] == 3
