"""repro.obs battery: span tracing, the metrics registry, and
plan-vs-actual reconciliation.

* Tracing-off is byte- AND bitwise-neutral across the full schedule ×
  M × α × R acceptance grid, and on the SAME traced runs
  ``obs.reconcile`` byte columns match ``plan_traffic`` exactly (the
  three-way cross-check discipline extended to the snapshot path).
* ``Tracer.export_chrome`` emits valid Chrome trace-event JSON
  (schema-checked field by field) with the executor / channel / hint
  tracks present.
* Reconciliation stays byte-exact on the paced-SSD smoke (bandwidth
  caps + activation spill + α-tail), and the snapshot feeds
  ``perfmodel.machine_from_snapshot``.
* Satellite regressions: ``reset_stats()`` clears EVERY meter (a
  second measured iteration matches the first), and ``IOEngine``
  reports per-path chunk backlog / cumulative bytes without disturbing
  the aggregate keys.
"""
import json
import tempfile
import threading

import jax
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core.perfmodel import (MachineParams, StorageRatios,
                                  machine_from_snapshot)
from repro.data import SyntheticLM
from repro.io import IOConfig, IOEngine, IOPriority, StripedFiles
from repro.obs import (SNAPSHOT_VERSION, Tracer, reconcile, stall_by_stream,
                       top_stall_stream)
from repro.offload import (DataParallelOffloadEngine, OffloadConfig,
                           OffloadEngine)

CFG = ArchConfig(name="obs-tiny", family="dense", source="test",
                 num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
                 head_dim=16, d_ff=64, vocab_size=256, act="gelu")
MB, S = 1, 16
X0 = StorageRatios(0.0, 0.0, 0.0)

#: the acceptance grid: schedule × M × α × R (wave needs M % 2 == 0,
#: DP plans are vertical with M % R == 0) — same grid as the
#: lookahead battery
GRID = [(sched, M, alpha, R)
        for sched in ("vertical", "horizontal", "wave")
        for M in (1, 2, 4)
        for alpha in (0.0, 0.5)
        for R in (1, 2)
        if not (sched == "wave" and M % 2)
        and not (R > 1 and (sched != "vertical" or M % R))]


def _build(sched, M, alpha, R, workdir, trace, io=None, policy="recompute",
           depth=1):
    W = {"vertical": 0, "horizontal": 0, "wave": 2}[sched]
    ocfg = OffloadConfig(schedule=sched, num_microbatches=M,
                         micro_batch=MB, seq_len=S, alpha=alpha,
                         wave_size=W, ratios=X0, prefetch_depth=depth,
                         io=io, activation_policy=policy, trace=trace)
    if R > 1:
        return DataParallelOffloadEngine(CFG, ocfg, jax.random.PRNGKey(11),
                                         workdir, ranks=R)
    return OffloadEngine(CFG, ocfg, jax.random.PRNGKey(11), workdir)


def _run(sched, M, alpha, R, trace, steps=2, **kw):
    """One measured run; returns (losses, per-rank route bytes, params,
    snapshot, plan, span count)."""
    with tempfile.TemporaryDirectory() as d:
        eng = _build(sched, M, alpha, R, d, trace, **kw)
        data = SyntheticLM(CFG.vocab_size, seed=0)
        losses = [eng.train_step(data.batch(M * MB, S))
                  for _ in range(steps)]
        eng.finish()
        # snapshot FIRST: the param readback below is a debug fetch
        # outside the plan, and reconciliation must not see its bytes
        snap = eng.metrics_snapshot()
        plan = eng.plan
        n_spans = len(eng.tracer)
        if R > 1:
            routes = [dict(rk.meter.bytes) for rk in eng.ranks]
            params = [eng.read_params(l).copy() for l in range(eng.L)]
        else:
            routes = [dict(eng.meter.bytes)]
            params = [eng.p_vecs[l].read().copy() for l in range(eng.L)]
        eng.close()
    return losses, routes, params, snap, plan, n_spans


# ---------------------------------------------------------------------------
# tracer unit behavior
# ---------------------------------------------------------------------------

def test_tracer_ring_capacity_and_drop_count():
    tr = Tracer(capacity=4)
    tr.enable()
    for i in range(6):
        tr.record("t", f"s{i}", "c", float(i), float(i) + 0.5, n=i)
    assert len(tr) == 4
    assert tr.dropped == 2
    names = [s[1] for s in tr.spans()]
    assert names == ["s2", "s3", "s4", "s5"]    # oldest evicted first
    tr.clear()
    assert len(tr) == 0 and tr.dropped == 0


def test_tracer_disabled_by_default_and_flag_gated():
    tr = Tracer()
    assert not tr.enabled
    tr.enable()
    assert tr.enabled
    tr.disable()
    assert not tr.enabled


def test_tracer_summary_aggregates_chunk_spans():
    tr = Tracer()
    tr.enable()
    tr.record("p0", "ssd->cpu", "io.chunk", 0.0, 2.0,
              route="ssd->cpu", nbytes=100)
    tr.record("p0", "ssd->cpu:wait", "io.queue", 0.0, 1.0,
              route="ssd->cpu", nbytes=100)
    tr.record("exec", "FWD", "plan", 0.0, 1.0)      # not an io span
    s = tr.summary()
    assert s["spans"] == 3
    d = s["routes"]["ssd->cpu"]
    assert d["bytes"] == 100 and d["ops"] == 1
    assert d["busy_s"] == pytest.approx(2.0)
    assert d["queue_s"] == pytest.approx(1.0)
    # single channel: the wall-clock envelope IS the busy sum
    assert d["channels"] == 1
    assert d["busy_wall_s"] == pytest.approx(2.0)
    assert d["rate_bps"] == pytest.approx(50.0)


def test_tracer_summary_concurrent_channels_union_rate():
    """The concurrency-blindness regression: two path channels moving
    chunks in the SAME wall-clock window must report the device's
    aggregate rate (bytes / union-of-intervals), not the ~1/P figure
    that dividing by the summed per-channel busy seconds yields."""
    tr = Tracer()
    tr.enable()
    # two channels, fully overlapped: each moves 100 B over [0, 2]
    tr.record("p0", "ssd->cpu", "io.chunk", 0.0, 2.0,
              route="ssd->cpu", nbytes=100)
    tr.record("p1", "ssd->cpu", "io.chunk", 0.0, 2.0,
              route="ssd->cpu", nbytes=100)
    d = tr.summary()["routes"]["ssd->cpu"]
    assert d["channels"] == 2
    assert d["busy_s"] == pytest.approx(4.0)         # per-thread sum
    assert d["busy_wall_s"] == pytest.approx(2.0)    # union
    # aggregate device rate: 200 B / 2 s — exactly 2x the single-path
    # rate, where bytes/busy_s would have read half of it
    assert d["rate_bps"] == pytest.approx(100.0)
    assert d["bytes"] / d["busy_s"] == pytest.approx(50.0)

    # serialized channels (no overlap): union degrades to the sum, so
    # the estimator is exact for devices that don't really parallelize
    tr.clear()
    tr.record("p0", "cpu->ssd", "io.chunk", 0.0, 1.0,
              route="cpu->ssd", nbytes=50)
    tr.record("p1", "cpu->ssd", "io.chunk", 1.0, 2.0,
              route="cpu->ssd", nbytes=50)
    d = tr.summary()["routes"]["cpu->ssd"]
    assert d["channels"] == 2
    assert d["busy_wall_s"] == pytest.approx(d["busy_s"]) == pytest.approx(2.0)
    assert d["rate_bps"] == pytest.approx(50.0)


def test_machine_from_snapshot_recovers_paced_two_path_rate(tmp_path):
    """Live-rate ingestion end-to-end on a token-bucket paced 2-path
    device: ``machine_from_snapshot`` must recover approximately the
    configured aggregate cap. Before the union fix it read ~1/2 of it
    (both path channels sleep against the shared bucket, so their busy
    seconds double-count the same pacing window)."""
    cap = 16e6          # small enough that burst (= cap/64) << payload
    tr = Tracer()
    tr.enable()
    cfg = IOConfig(paths=[str(tmp_path / "p0"), str(tmp_path / "p1")],
                   bandwidth={"cpu->ssd": cap, "ssd->cpu": cap},
                   chunk_bytes=1 << 16)
    eng = IOEngine(cfg, tracer=tr)
    sf = StripedFiles(eng)
    data = np.random.default_rng(0).integers(
        0, 255, size=2_000_000, dtype=np.uint8)
    sf.write("x", data, 0, IOPriority.CKPT_SPILL)
    out = np.empty_like(data)
    sf.readinto("x", out, 0, IOPriority.PARAM_FETCH)
    sf.close()
    eng.shutdown()
    assert np.array_equal(out, data)
    snap = {"trace": tr.summary()}
    routes = snap["trace"]["routes"]
    for route in ("cpu->ssd", "ssd->cpu"):
        d = routes[route]
        assert d["channels"] == 2
        # the paced aggregate: within a band of the cap (burst credit
        # lets it land slightly above; scheduling jitter slightly below)
        assert 0.6 * cap < d["rate_bps"] < 2.0 * cap, (route, d)
        # and strictly above the concurrency-blind estimate
        assert d["rate_bps"] > d["bytes"] / d["busy_s"]
    m = machine_from_snapshot(snap, MachineParams())
    assert m.ssd_write_bw == pytest.approx(routes["cpu->ssd"]["rate_bps"])
    assert m.ssd_read_bw == pytest.approx(routes["ssd->cpu"]["rate_bps"])


# ---------------------------------------------------------------------------
# the acceptance grid: tracing-off neutrality + byte-exact reconcile
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sched,M,alpha,R", GRID)
def test_trace_neutral_and_reconcile_exact(sched, M, alpha, R):
    """Tracing on vs off: identical losses, identical byte counters,
    bitwise-identical parameters — and the traced run's snapshot
    reconciles byte-exactly against the plan."""
    l_off, r_off, p_off, snap_off, plan_off, n_off = _run(
        sched, M, alpha, R, trace=False)
    l_on, r_on, p_on, snap_on, plan_on, n_on = _run(
        sched, M, alpha, R, trace=True)
    assert l_off == l_on
    assert r_off == r_on
    for a, b in zip(p_off, p_on):
        assert np.array_equal(a, b)             # bitwise
    assert n_off == 0                           # off path records nothing
    assert n_on > 0
    assert snap_off["trace"]["spans"] == 0
    # the load-bearing invariant: measured == plan_traffic, per rank,
    # per (category, route), exactly — from the snapshot alone
    rec = reconcile(plan_on, snap_on)
    assert rec.rows and rec.ok, [r for r in rec.rows if not r.match]
    assert {r.rank for r in rec.rows} == set(range(R))
    # the untraced snapshot reconciles identically (bytes don't care)
    rec_off = reconcile(plan_off, snap_off)
    assert rec_off.ok and not rec_off.route_seconds_measured


# ---------------------------------------------------------------------------
# Chrome trace-event export schema
# ---------------------------------------------------------------------------

def test_chrome_export_is_valid_trace_event_json(tmp_path):
    with tempfile.TemporaryDirectory() as d:
        eng = _build("vertical", 2, 0.5, 1, d, trace=True)
        data = SyntheticLM(CFG.vocab_size, seed=0)
        eng.train_step(data.batch(2 * MB, S))
        eng.finish()
        path = eng.tracer.export_chrome(str(tmp_path / "trace.json"))
        eng.close()
    with open(path) as f:
        doc = json.load(f)
    assert isinstance(doc, dict) and isinstance(doc["traceEvents"], list)
    evs = doc["traceEvents"]
    assert evs
    meta = [e for e in evs if e["ph"] == "M"]
    spans = [e for e in evs if e["ph"] == "X"]
    instants = [e for e in evs if e["ph"] == "i"]
    assert set(e["ph"] for e in evs) <= {"M", "X", "i"}
    for e in evs:
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
        assert isinstance(e["name"], str)
        if e["ph"] == "X":
            assert e["ts"] >= 0 and e["dur"] >= 0
        if e["ph"] == "i":
            assert e["s"] in ("t", "p", "g")
    # one thread_name metadata row per track, tids unique
    tracks = {e["args"]["name"]: e["tid"] for e in meta
              if e["name"] == "thread_name"}
    assert len(set(tracks.values())) == len(tracks)
    # the three instrumentation layers all present
    assert "exec" in tracks                          # plan-op track
    assert any(t.startswith("io-path") for t in tracks)   # channel tracks
    assert any(t.startswith("hints/") for t in tracks)    # hint lifecycle
    by_cat = {}
    for e in spans:
        by_cat.setdefault(e.get("cat"), []).append(e)
    # plan-op spans carry the full identity tuple
    for e in by_cat["plan"][:5]:
        a = e["args"]
        assert {"l", "m", "wave", "rank", "step"} <= set(a)
    # chunk spans carry route / priority / nbytes / path index
    chunk = by_cat["io.chunk"][0]["args"]
    assert {"route", "priority", "nbytes", "path"} <= set(chunk)
    assert chunk["priority"] in {p.name for p in IOPriority}
    # queue-wait spans pair with execution spans (same categories' count)
    assert len(by_cat["io.queue"]) == len(by_cat["io.chunk"])
    # hint lifecycle spans carry their outcome
    hint = by_cat["hint"][0]["args"]
    assert hint["outcome"] in ("hit", "late", "cancelled", "unused")
    assert instants is not None      # instants are optional but well-formed


def test_dp_ranks_get_distinct_channel_tracks(tmp_path):
    with tempfile.TemporaryDirectory() as d:
        eng = _build("vertical", 2, 0.0, 2, d, trace=True)
        data = SyntheticLM(CFG.vocab_size, seed=0)
        eng.train_step(data.batch(2 * MB, S))
        eng.finish()
        path = eng.tracer.export_chrome(str(tmp_path / "dp.json"))
        eng.close()
    with open(path) as f:
        doc = json.load(f)
    tracks = {e["args"]["name"] for e in doc["traceEvents"]
              if e["ph"] == "M" and e["name"] == "thread_name"}
    assert any(t.startswith("rank0-io-path") for t in tracks)
    assert any(t.startswith("rank1-io-path") for t in tracks)


# ---------------------------------------------------------------------------
# reconciliation on the paced-SSD smoke + machine ingestion
# ---------------------------------------------------------------------------

def test_reconcile_byte_exact_on_paced_ssd_smoke(tmp_path):
    io = IOConfig(bandwidth={"ssd->cpu": 2e9, "cpu->ssd": 2e9},
                  chunk_bytes=1 << 16)
    with tempfile.TemporaryDirectory() as d:
        eng = _build("vertical", 2, 0.5, 1, d, trace=True, io=io,
                     policy="spill", depth=2)
        data = SyntheticLM(CFG.vocab_size, seed=0)
        for _ in range(2):
            eng.train_step(data.batch(2 * MB, S))
        eng.finish()
        snap = eng.metrics_snapshot()
        plan = eng.plan
        eng.close()
    rec = reconcile(plan, snap, machine=MachineParams())
    assert rec.ok and rec.steps == 2
    cats = {r.category for r in rec.rows}
    assert "act" in cats                        # the spill stream showed up
    # predicted seconds exist for every route that moved bytes;
    # measured seconds exist for the SSD routes the channels executed
    assert set(rec.route_seconds_predicted) >= {"ssd->cpu", "cpu->ssd"}
    assert rec.route_seconds_measured.get("cpu->ssd", 0) > 0
    # stall attribution: a sorted, non-negative stream table
    assert rec.stalls == sorted(rec.stalls, key=lambda kv: -kv[1])
    streams = dict(rec.stalls)
    assert all(v >= 0 for v in streams.values())
    assert top_stall_stream(snap["op_seconds"]) in (*streams, "none")
    # the report renders
    table = rec.format()
    assert "exact" in table and "MISMATCH" not in table
    # live machine ingestion: measured chunk rates replace SSD params
    m = machine_from_snapshot(snap)
    assert m.name.endswith("-live")
    assert m.ssd_write_bw > 0
    base = MachineParams()
    empty = machine_from_snapshot({"trace": {"routes": {}}}, base)
    assert empty.ssd_read_bw == base.ssd_read_bw
    assert empty.ssd_write_bw == base.ssd_write_bw


def test_stall_by_stream_fold():
    op_s = {"FETCH_PARAM": 1.0, "ALLGATHER": 0.5, "WAIT_OPT": 0.25,
            "FWD": 99.0}                        # FWD is not a stall kind
    streams = stall_by_stream(op_s)
    assert streams == {"param": 1.5, "opt": 0.25}
    assert top_stall_stream(op_s) == "param"
    assert top_stall_stream({}) == "none"
    assert top_stall_stream({"FWD": 9.0}) == "none"


def test_reconcile_rejects_rank_mismatch():
    _, _, _, snap, plan, _ = _run("vertical", 2, 0.0, 1, trace=False,
                                  steps=1)
    snap["traffic"] = snap["traffic"] * 2       # pretend two ranks
    with pytest.raises(ValueError, match="rank"):
        reconcile(plan, snap)


# ---------------------------------------------------------------------------
# the metrics registry schema (the autotuner ingestion contract)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("R", [1, 2])
def test_metrics_snapshot_schema_and_json_roundtrip(R):
    _, _, _, snap, _, _ = _run("vertical", 2, 0.5, R, trace=True, steps=1)
    assert snap["version"] == SNAPSHOT_VERSION
    required = {"version", "schedule", "ranks", "steps", "act_policy",
                "traffic", "io", "io_depth", "host_peak_nbytes",
                "host_nbytes", "bounds", "op_seconds", "stall_s",
                "phase_time", "lookahead", "hint_skips", "act_skips",
                "act_fallbacks", "plan_costs", "trace"}
    assert required <= set(snap)
    assert snap["ranks"] == R and snap["steps"] == 1
    # per-rank fields are rank-indexed lists in BOTH engines' snapshots
    for key in ("traffic", "io", "io_depth", "host_peak_nbytes",
                "host_nbytes"):
        assert isinstance(snap[key], list) and len(snap[key]) == R
    # subsumes stats(): the io shape and lookahead shape are embedded
    io0 = snap["io"][0]
    assert {"submitted", "completed", "chunk_ops",
            "chunk_bytes_per_path", "chunk_ops_per_path"} <= set(io0)
    assert {"hits", "misses", "hit_rate",
            "hint_skips"} <= set(snap["lookahead"])
    assert {"fwd", "bwd", "opt_wait"} <= set(snap["phase_time"])
    # plan_costs is enough to re-derive predictions (reconcile uses it)
    pc = snap["plan_costs"]
    assert {"P", "param_itemsize", "ckpt_elems", "ratios",
            "alpha", "ranks"} <= set(pc)
    assert pc["ranks"] == R
    # the whole contract is JSON-serializable, by construction
    again = json.loads(json.dumps(snap))
    assert again["version"] == SNAPSHOT_VERSION
    assert (snap["bounds"] is None) == (R == 1)


# ---------------------------------------------------------------------------
# satellite 1: reset_stats clears EVERY meter
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("R", [1, 2])
def test_second_measured_iteration_matches_first_after_reset(R):
    """The warm-up-boundary regression: meter.reset + reset_stats
    between two identical measured iterations must make the second
    report EXACTLY like the first — byte counters byte-for-byte, the
    deterministic lookahead totals equal, every PR-4/5 meter back to
    zero at the boundary."""
    with tempfile.TemporaryDirectory() as d:
        eng = _build("vertical", 2, 0.5, R, d, trace=False)
        data = SyntheticLM(CFG.vocab_size, seed=0)
        meters = [rk.meter for rk in eng.ranks] if R > 1 else [eng.meter]

        def measured_iteration():
            loss = eng.train_step(data.batch(2 * MB, S))
            eng.finish()
            look = eng.metrics_snapshot()["lookahead"]
            return (loss, [dict(m.snapshot()) for m in meters],
                    look["hits"] + look["misses"])

        first = measured_iteration()
        # poison every resettable meter, including the PR-4/5 ones the
        # old reset missed, then reset
        eng.act_fallbacks = 7
        eng.hint_skips += 3
        eng.act_skips += 2
        for m in meters:
            m.reset()
        eng.reset_stats()
        look = eng.metrics_snapshot()["lookahead"]
        assert look["hits"] == look["misses"] == 0
        assert look["hint_skips"] == 0 and look["act_skips"] == 0
        assert look["stall_s"] == 0 and not look["op_seconds"]
        assert eng.act_fallbacks == 0
        assert all(v == 0.0 for v in eng.phase_time.values())
        second = measured_iteration()
        eng.close()
    # identical byte counters and total fetch count (the hit/miss SPLIT
    # is timing-dependent; the total per iteration is not)
    assert first[1] == second[1]
    assert first[2] == second[2]


# ---------------------------------------------------------------------------
# satellite 2: per-path IOEngine counters
# ---------------------------------------------------------------------------

def test_io_engine_per_path_counters(tmp_path):
    cfg = IOConfig(paths=[str(tmp_path / "p0"), str(tmp_path / "p1")])
    eng = IOEngine(cfg)
    try:
        release = threading.Event()
        f0 = eng.submit_chunk(0, release.wait, IOPriority.CKPT_SPILL,
                              route="cpu->ssd", nbytes=100)
        f1 = eng.submit_chunk(0, lambda: None, IOPriority.CKPT_SPILL,
                              route="cpu->ssd", nbytes=50)
        d = eng.depth()
        # path 0 holds one running + one queued chunk; path 1 is idle
        assert d["channel_backlog_per_path"] == [2, 0]
        assert d["channel_backlog_bytes_per_path"] == [150, 0]
        release.set()
        f0.result(); f1.result()
        f2 = eng.submit_chunk(1, lambda: None, IOPriority.ACT,
                              route="ssd->cpu", nbytes=30)
        f2.result()
        d = eng.depth()
        assert d["channel_backlog_per_path"] == [0, 0]
        assert d["channel_backlog_bytes_per_path"] == [0, 0]
        s = eng.metrics_snapshot()
        # cumulative per-path meters survive completion...
        assert s["chunk_bytes_per_path"] == [150, 30]
        assert s["chunk_ops_per_path"] == [2, 1]
        # ...and the aggregate keys are unchanged in shape and value
        assert s["chunk_ops"] == 3
        assert s["num_paths"] == 2
        assert {"submitted", "completed", "cancelled",
                "max_inflight_bytes", "bytes_by_priority",
                "inflight_bytes",
                "staging_oversized_allocs"} <= set(s)
    finally:
        eng.shutdown()


def test_io_engine_chunk_spans_split_queue_wait_from_transfer(tmp_path):
    tr = Tracer()
    tr.enable()
    cfg = IOConfig(paths=[str(tmp_path / "p0")])
    eng = IOEngine(cfg, tracer=tr)
    try:
        eng.submit_chunk(0, lambda: None, IOPriority.PARAM_FETCH,
                         route="ssd->cpu", nbytes=64).result()
    finally:
        eng.shutdown()
    spans = tr.spans()
    waits = [s for s in spans if s[2] == "io.queue"]
    runs = [s for s in spans if s[2] == "io.chunk"]
    assert len(waits) == 1 and len(runs) == 1
    (_, _, _, w0, w1, wargs) = waits[0]
    (_, _, _, r0, r1, rargs) = runs[0]
    assert w1 <= r0 or w1 == pytest.approx(r0)   # wait ends where run starts
    assert wargs["nbytes"] == rargs["nbytes"] == 64
    assert rargs["path"] == 0
    assert rargs["priority"] == "PARAM_FETCH"
