"""repro.obs — span tracing, the unified metrics registry, and
plan-vs-actual reconciliation for the offload stack.

Three pieces, layered bottom-up:

* :class:`Tracer` (``obs.tracer``) — the thread-safe, ring-buffered
  flight recorder every instrumented layer shares. Off by default; the
  disabled path is one flag test per site. Spans carry plan-op identity
  from the executor, queue-wait/transfer splits from the ``IOEngine``
  channel threads, and hint lifecycles from the coordinators.
  ``export_chrome(path)`` writes Perfetto-loadable Chrome trace-event
  JSON.
* ``build_snapshot`` (``obs.registry``) — the versioned flat
  ``metrics_snapshot()`` both engines expose: one JSON-serializable
  dict subsuming ``stats()``, embedding ``plan_costs`` and the trace's
  per-route aggregates. This schema is the ingestion contract for the
  ROADMAP item-3 autotuner.
* :func:`reconcile` (``obs.reconcile``) — joins a snapshot against
  ``plan_traffic`` byte predictions (must be exact) and
  ``perfmodel.route_seconds`` time predictions, plus the
  stall-attribution fold (:func:`top_stall_stream`).
"""
from repro.obs.reconcile import (Reconciliation, ReconRow, STALL_STREAM,
                                 reconcile, stall_by_stream,
                                 top_stall_stream)
from repro.obs.registry import (SNAPSHOT_VERSION, build_serve_snapshot,
                                build_snapshot, traffic_maps)
from repro.obs.tracer import (CAT_HINT, CAT_IO_CHUNK, CAT_IO_QUEUE,
                              CAT_IO_REQ, CAT_IO_REQ_QUEUE, CAT_PLAN,
                              Tracer)

__all__ = [
    "Tracer", "CAT_PLAN", "CAT_HINT", "CAT_IO_CHUNK", "CAT_IO_QUEUE",
    "CAT_IO_REQ", "CAT_IO_REQ_QUEUE",
    "SNAPSHOT_VERSION", "build_snapshot", "build_serve_snapshot",
    "traffic_maps",
    "Reconciliation", "ReconRow", "STALL_STREAM", "reconcile",
    "stall_by_stream", "top_stall_stream",
]
