"""Plan-vs-actual reconciliation: join a ``metrics_snapshot()`` against
the schedule IR's static predictions.

Three joins, one report:

* **bytes** — ``plan_traffic(plan, costs)`` per (category, route) per
  rank, scaled by the snapshot's step count, against the measured
  traffic meters. These must match EXACTLY (the load-bearing invariant:
  hints, adaptive skips, and tracing move *when* bytes flow, never
  *how many*); any mismatch flips the row's verdict and ``ok``.
* **seconds** — ``perfmodel.route_seconds`` over the predicted bytes
  against the measured per-route transfer busy time from the trace's
  channel-thread spans (empty when tracing was off; the predictions
  still print).
* **stalls** — the per-op stall meters folded through
  :data:`STALL_STREAM` into "which stream blocked the executor, how
  long", sorted worst-first.

The snapshot carries everything but the plan (``plan_costs`` is
embedded), so reconciliation needs no live engine — the bench artifacts
alone reproduce the report.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

#: stall op kind -> the stream whose latency the executor was exposed
#: to (the attribution key of the stall report). BARRIER waits on the
#: device, not storage, hence "compute".
STALL_STREAM: Dict[str, str] = {
    "FETCH_PARAM": "param", "ALLGATHER": "param",
    "FETCH_CKPT": "ckpt", "FETCH_CKPT_BWD": "ckpt",
    "FETCH_ACT": "act", "FETCH_GRAD": "inter_grad",
    "GRAD_FETCH_ACC": "grad", "WAIT_OPT": "opt",
    "BARRIER": "compute",
}


def stall_by_stream(op_seconds: Dict[str, float]) -> Dict[str, float]:
    """Fold ``eng.op_seconds`` into per-stream blocked seconds."""
    out: Dict[str, float] = {}
    for op, s in op_seconds.items():
        stream = STALL_STREAM.get(op)
        if stream is not None:
            out[stream] = out.get(stream, 0.0) + float(s)
    return out


def top_stall_stream(op_seconds: Dict[str, float]) -> str:
    """The stream that blocked the executor longest ("none" when
    nothing stalled) — the one-word diagnosis column of the bench
    artifacts."""
    streams = {k: v for k, v in stall_by_stream(op_seconds).items() if v > 0}
    if not streams:
        return "none"
    return max(streams.items(), key=lambda kv: kv[1])[0]


@dataclasses.dataclass(frozen=True)
class ReconRow:
    """One (rank, category, route) byte comparison."""
    rank: int
    category: str
    route: str
    predicted_bytes: int
    measured_bytes: int

    @property
    def match(self) -> bool:
        return self.predicted_bytes == self.measured_bytes


@dataclasses.dataclass
class Reconciliation:
    """The joined report — see :func:`reconcile`."""
    rows: List[ReconRow]
    route_seconds_predicted: Dict[str, float]
    route_seconds_measured: Dict[str, float]   # {} when tracing was off
    stalls: List[Tuple[str, float]]            # worst-first
    steps: int
    #: per-path conservation violations (chunk placement moves bytes
    #: between paths, never between routes, so every per-path split in
    #: the snapshot must sum EXACTLY to its route total); one
    #: human-readable line per violation, empty when exact
    path_sum_mismatches: List[str] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        """Every byte row exact (the plan_traffic invariant) and every
        per-path split summing exactly to its route total."""
        return all(r.match for r in self.rows) \
            and not self.path_sum_mismatches

    def format(self) -> str:
        """The human-readable table ``quickstart.py --trace`` prints."""
        lines = [f"plan-vs-actual over {self.steps} step(s)",
                 f"{'rk':>2} {'category':<10} {'route':<10} "
                 f"{'predicted_B':>14} {'measured_B':>14}  verdict"]
        for r in self.rows:
            lines.append(
                f"{r.rank:>2} {r.category:<10} {r.route:<10} "
                f"{r.predicted_bytes:>14} {r.measured_bytes:>14}  "
                f"{'exact' if r.match else 'MISMATCH'}")
        lines.append("")
        lines.append(f"{'route':<10} {'predicted_s':>12} {'measured_s':>12}")
        for route in sorted(set(self.route_seconds_predicted)
                            | set(self.route_seconds_measured)):
            p = self.route_seconds_predicted.get(route)
            m = self.route_seconds_measured.get(route)
            lines.append(f"{route:<10} "
                         f"{p if p is not None else float('nan'):>12.4f} "
                         + (f"{m:>12.4f}" if m is not None
                            else f"{'(no trace)':>12}"))
        lines.append("")
        if self.stalls:
            lines.append("stall attribution (stream -> executor-blocked s):")
            for stream, s in self.stalls:
                lines.append(f"  {stream:<10} {s:.4f}")
        else:
            lines.append("stall attribution: no stalls metered")
        if self.path_sum_mismatches:
            lines.append("")
            lines.append("per-path conservation VIOLATED:")
            for msg in self.path_sum_mismatches:
                lines.append(f"  {msg}")
        return "\n".join(lines)


def reconcile(plan, snapshot: dict, machine=None,
              steps: Optional[int] = None) -> Reconciliation:
    """Join ``plan``'s static predictions against a live
    ``metrics_snapshot()`` (see module docstring).

    ``steps`` defaults to the snapshot's completed-step count; the
    per-iteration ``plan_traffic`` prediction is scaled by it, which is
    exact for a run measured from a fresh meter through ``finish()``
    (each iteration flushes its own α-tail at the plan epilogue).
    ``machine`` prices the predicted route seconds
    (:class:`repro.core.perfmodel.MachineParams`; default machine when
    omitted)."""
    from repro.core.perfmodel import (MachineParams, StorageRatios,
                                      route_seconds)
    from repro.core.plan import PlanCosts, plan_traffic
    from repro.obs.registry import traffic_maps

    pc = dict(snapshot["plan_costs"])
    pc["ratios"] = StorageRatios(**pc["ratios"])
    costs = PlanCosts(**pc)
    pred = plan_traffic(plan, costs)
    preds = pred if isinstance(pred, list) else [pred]
    n_steps = int(snapshot.get("steps", 1) if steps is None else steps) or 1
    measured = traffic_maps(snapshot)
    if len(measured) != len(preds):
        raise ValueError(
            f"snapshot has {len(measured)} rank meter(s) but the plan "
            f"predicts {len(preds)} — wrong plan for this snapshot?")

    rows: List[ReconRow] = []
    agg: Dict[tuple, int] = {}
    for r, (p, m) in enumerate(zip(preds, measured)):
        for key in sorted(set(p) | set(m)):
            pb = int(p.get(key, 0)) * n_steps
            rows.append(ReconRow(r, key[0], key[1], pb, int(m.get(key, 0))))
            agg[key] = agg.get(key, 0) + pb

    machine = machine if machine is not None else MachineParams()
    pred_s = route_seconds(machine, agg)
    # measured route-seconds are the WALL-clock envelope of the chunk
    # spans (union across the concurrent path channels), comparable to
    # route_seconds' aggregate-bandwidth prediction; the per-channel
    # busy_s sum would over-count a P-path device by up to P×
    meas_s = {route: float(d.get("busy_wall_s", d.get("busy_s", 0.0)))
              for route, d in (snapshot.get("trace") or {})
              .get("routes", {}).items()}
    stalls = sorted(stall_by_stream(snapshot.get("op_seconds", {})).items(),
                    key=lambda kv: -kv[1])
    return Reconciliation(rows=rows, route_seconds_predicted=pred_s,
                          route_seconds_measured=meas_s, stalls=stalls,
                          steps=n_steps,
                          path_sum_mismatches=_check_path_sums(snapshot))


def _check_path_sums(snapshot: dict) -> List[str]:
    """Byte-exact conservation of the per-path splits (see
    ``Reconciliation.path_sum_mismatches``). Two independent sources:

    * the trace summary's per-route ``per_path`` bytes must sum to the
      route's traced ``bytes``;
    * each rank's engine ``chunk_bytes_by_route_per_path`` split must
      sum to the engine's own ``chunk_bytes_by_route`` total.

    Both pairs are incremented at different aggregation levels, so an
    inexact sum means chunk placement created or lost bytes between
    paths — the invariant the dynamic ``path_policy`` must preserve."""
    out: List[str] = []
    for route, d in (snapshot.get("trace") or {}).get("routes", {}).items():
        per_path = d.get("per_path") or {}
        if per_path:
            s = sum(int(pp.get("bytes", 0)) for pp in per_path.values())
            if s != int(d.get("bytes", 0)):
                out.append(f"trace {route}: per-path bytes {s} != "
                           f"route bytes {d.get('bytes')}")
    io = snapshot.get("io") or []
    for rank, st in enumerate(io if isinstance(io, list) else [io]):
        by_route = (st or {}).get("chunk_bytes_by_route_per_path") or {}
        totals = (st or {}).get("chunk_bytes_by_route") or {}
        for route, per_path in by_route.items():
            s = sum(int(b) for b in per_path)
            total = int(totals.get(route, 0))
            if s != total:
                out.append(f"rank {rank} {route}: per-path chunk bytes "
                           f"{s} != route chunk bytes {total}")
    return out
