"""The unified metrics registry: one versioned, flat, JSON-serializable
snapshot of everything the offload stack measures.

``build_snapshot(eng)`` works on both :class:`OffloadEngine` and
:class:`DataParallelOffloadEngine` (both expose it as
``metrics_snapshot()``) and SUBSUMES their ``stats()`` shapes — every
``stats()`` field appears here, normalized to per-rank lists so the
single-rank and DP schemas are the same shape. The dict round-trips
through ``json.dumps`` by construction (numpy ints coerced, tuples
listed): it is the artifact the bench-smoke job persists and the
ingestion contract for the ROADMAP item-3 autotuner, which is why the
schema carries ``version`` (bump ``SNAPSHOT_VERSION`` on any breaking
shape change) and embeds ``plan_costs`` — enough to re-run
``plan_traffic`` from the snapshot alone, so ``obs.reconcile`` needs no
live engine.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

#: Bump on any breaking change to the snapshot shape. Consumers
#: (``obs.reconcile``, ``check_smoke.py``, the future autotuner) must
#: check this before reading.
SNAPSHOT_VERSION = 1


def _rank_stacks(eng) -> list:
    """Per-rank stacks: the DP engine's ``ranks`` list, or the
    single-rank engine itself (same attribute surface)."""
    rks = getattr(eng, "ranks", None)
    return list(rks) if rks is not None else [eng]


def _jsonable(obj):
    """Coerce meter/stat values to plain JSON types (numpy ints from
    ``arr.nbytes`` arithmetic, tuples from ``shard_bounds``)."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, bool) or obj is None or isinstance(obj, str):
        return obj
    if isinstance(obj, float):
        return float(obj)
    try:
        return int(obj)          # numpy integer scalars
    except (TypeError, ValueError):
        return obj


def build_snapshot(eng) -> Dict[str, object]:
    """The versioned flat metrics snapshot (see module docstring).

    Keys:

    * identity — ``version``, ``schedule``, ``ranks``, ``steps``
      (completed train steps), ``act_policy``
    * bytes — ``traffic`` (per-rank list of ``"category:route" ->
      bytes`` meter snapshots, the measured side of the reconciliation)
    * storage — ``io`` / ``io_depth`` (per-rank ``IOEngine.metrics_snapshot()`` /
      ``depth()``, including the per-path counters),
      ``host_peak_nbytes`` / ``host_nbytes``, ``bounds`` (DP shard
      ranges, ``None`` single-rank)
    * time — ``op_seconds``, ``stall_s``, ``phase_time``
    * lookahead — ``lookahead`` (the ``lookahead_stats`` shape),
      ``hint_skips`` / ``act_skips`` / ``act_fallbacks``
    * prediction inputs — ``plan_costs`` (``PlanCosts.from_engine``
      as a dict; ``ratios`` nested)
    * spans — ``trace`` (``Tracer.summary()``: enabled flag, span
      count, per-route measured bytes/busy/queue seconds)
    """
    from repro.core.plan import PlanCosts
    from repro.offload.executor import stall_seconds

    rks = _rank_stacks(eng)
    costs = dataclasses.asdict(PlanCosts.from_engine(eng))
    tracer = getattr(eng, "tracer", None)
    trace = tracer.summary() if tracer is not None else \
        {"enabled": False, "spans": 0, "dropped": 0, "routes": {}}
    lookahead = eng._lookahead_stats()
    snap = {
        "version": SNAPSHOT_VERSION,
        "schedule": eng.ocfg.schedule,
        "ranks": int(getattr(eng, "R", 1)),
        "steps": int(eng.step_num),
        "act_policy": eng.act_policy,
        "traffic": [dict(rk.meter.snapshot()) for rk in rks],
        "io": [rk.ioe._collect_stats() for rk in rks],
        "io_depth": [rk.ioe.depth() for rk in rks],
        "host_peak_nbytes": [rk.host.peak_nbytes for rk in rks],
        "host_nbytes": [rk.host.nbytes() for rk in rks],
        "bounds": getattr(eng, "bounds", None),
        "op_seconds": dict(eng.op_seconds),
        "stall_s": stall_seconds(eng.op_seconds),
        "phase_time": dict(eng.phase_time),
        "lookahead": lookahead,
        "hint_skips": int(eng.hint_skips),
        "act_skips": int(eng.act_skips),
        "act_fallbacks": int(eng.act_fallbacks),
        "plan_costs": costs,
        "trace": trace,
    }
    # additive: the online autotuner's decision log (attached by
    # repro.offload.autotune.AutotuneController) rides along so a
    # snapshot archives WHY the plan changed mid-run
    log = getattr(eng, "autotune_log", None)
    if log is not None:
        snap["autotune"] = list(log)
    return _jsonable(snap)


def build_serve_snapshot(eng) -> Dict[str, object]:
    """The serve-engine counterpart of :func:`build_snapshot` — same
    versioning and JSON discipline, serve-shaped keys:

    * identity — ``version``, ``schedule`` (``"serve"``), ``steps``
    * bytes — ``traffic`` (per-rank list, single rank), ``predicted``
      (the accumulated per-step ``plan_traffic`` predictions — the
      plan side of the three-way KV invariant), ``plan_costs``
    * kv — block table state (``block_bytes``, ``capacity_blocks``,
      ``used_blocks``, ``x_host``), lifecycle counters (``admitted``
      / ``preempted`` / ``finished`` / ``appends``), per-unit
      ``spills`` / ``fetches`` (the ``traffic.kv_traffic`` closed-form
      inputs), and ``hit_rate`` — the warm-tier fraction of fetched KV
      bytes (1 - ssd->cpu / cpu->gpu; 1.0 when nothing was fetched)
    * serving — ``tokens_decoded``, ``phase_time``, ``waiting`` /
      ``running`` request counts
    * storage/time/spans — ``io``, ``io_depth``, ``host_peak_nbytes``,
      ``host_nbytes``, ``lookahead``, ``trace`` (as in training)
    """
    import dataclasses as _dc

    traffic = dict(eng.meter.snapshot())
    kv_fetch = traffic.get("kv:cpu->gpu", 0)
    kv_ssd = traffic.get("kv:ssd->cpu", 0)
    snap = {
        "version": SNAPSHOT_VERSION,
        "schedule": "serve",
        "ranks": 1,
        "steps": int(eng.step_num),
        "traffic": [traffic],
        "predicted": {f"{c}:{r}": v
                      for (c, r), v in eng.predicted_traffic.items()},
        "plan_costs": _dc.asdict(eng.plan_costs()),
        "kv": {
            "block_bytes": int(eng.scfg.kv_block_bytes),
            "capacity_blocks": int(eng.capacity_blocks),
            "used_blocks": int(eng.used_blocks),
            "x_host": float(eng.scfg.kv_x_host),
            "blocks_per_request": int(eng.blocks_per_request),
            "admitted": int(eng.admitted),
            "preempted": int(eng.preempted),
            "finished": int(eng.finished),
            "appends": int(eng.appends),
            "spills": list(eng.kv_spills),
            "fetches": list(eng.kv_fetches),
            "hit_rate": 1.0 - kv_ssd / kv_fetch if kv_fetch else 1.0,
        },
        "tokens_decoded": int(eng.tokens_decoded),
        "phase_time": dict(eng.phase_time),
        "waiting": sum(1 for r in eng.requests.values()
                       if r.state == "waiting" or r.state == "evicted"),
        "running": sum(1 for r in eng.requests.values()
                       if r.state == "running"),
        "io": [eng.ioe._collect_stats()],
        "io_depth": [eng.ioe.depth()],
        "host_peak_nbytes": [eng.host.peak_nbytes],
        "host_nbytes": [eng.host.nbytes()],
        "lookahead": eng._lookahead_stats(),
        "trace": eng.tracer.summary(),
    }
    return _jsonable(snap)


def traffic_maps(snapshot: dict) -> List[Dict[tuple, int]]:
    """The snapshot's per-rank measured byte counters re-keyed as
    ``(category, route)`` tuples — the join key ``plan_traffic``
    predictions use."""
    out = []
    for rank_map in snapshot["traffic"]:
        m: Dict[tuple, int] = {}
        for key, v in rank_map.items():
            cat, _, route = key.partition(":")
            m[(cat, route)] = int(v)
        out.append(m)
    return out
