"""Span-based flight recorder for the offload stack.

One ``Tracer`` instance is shared by every layer that touches bytes —
the plan executor, the ``IOEngine`` channel threads, and the hint
coordinators. It is **off by default**: recording is gated by the
single ``enabled`` flag, and every instrumentation site tests that flag
*before* taking timestamps or building args, so the disabled path is
one attribute read per site (nothing measurable; acceptance-gated by
the paced-SSD smoke in ``check_smoke.py``).

Spans live in a bounded ring (``collections.deque(maxlen=...)``) under
one lock — a long traced run degrades to "most recent N spans" instead
of unbounded memory, and ``dropped`` counts the evictions so exports
are honest about truncation. Each span is a flat tuple
``(track, name, cat, t0, t1, args)``; ``t1 is None`` marks an instant
event. Tracks map 1:1 onto Chrome trace ``tid``s: one per I/O channel
thread (queue-wait + transfer slices), one for the plan executor, and
one per hint stream.

``export_chrome(path)`` writes the Chrome trace-event JSON format
(``{"traceEvents": [...]}`` with ``ph="X"`` complete events, ``ph="i"``
instants and ``ph="M"`` thread-name metadata) — loadable directly in
Perfetto / ``chrome://tracing``. ``summary()`` reduces the ring to the
per-route byte/seconds aggregates that ``metrics_snapshot()`` embeds
and ``obs.reconcile`` / ``perfmodel.machine_from_snapshot`` consume.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

Span = Tuple[str, str, str, float, Optional[float], Optional[dict]]

#: Span categories (the ``cat`` field). Queue-wait and execution are
#: separate categories so aggregation never conflates the two.
CAT_IO_CHUNK = "io.chunk"      # chunk execution on a path channel
CAT_IO_QUEUE = "io.queue"      # chunk queue-wait (submit -> start)
CAT_IO_REQ = "io.req"          # request-body execution (front pool)
CAT_IO_REQ_QUEUE = "io.req.queue"
CAT_PLAN = "plan"              # one span per executed plan op
CAT_HINT = "hint"              # hint lifecycle (issued -> outcome)
CAT_FAULT = "io.fault"         # instants: retries, failovers, CRC errors


def _union_seconds(intervals: List[Tuple[float, float]]) -> float:
    """Total length of the union of ``(t0, t1)`` intervals — the
    wall-clock envelope a set of (possibly concurrent) chunk transfers
    actually occupied. Disjoint intervals sum; overlapping ones count
    once."""
    if not intervals:
        return 0.0
    total = 0.0
    cur_lo = cur_hi = None
    for lo, hi in sorted(intervals):
        if cur_hi is None or lo > cur_hi:
            if cur_hi is not None:
                total += cur_hi - cur_lo
            cur_lo, cur_hi = lo, hi
        elif hi > cur_hi:
            cur_hi = hi
    total += cur_hi - cur_lo
    return total


class Tracer:
    """Thread-safe ring-buffered span recorder (see module docstring).

    Callers must gate on ``tracer.enabled`` BEFORE computing timestamps;
    ``record`` itself does not re-check, so the off path never reaches
    it."""

    def __init__(self, capacity: int = 1 << 16):
        self.enabled = False
        self._capacity = int(capacity)
        self._lock = threading.Lock()
        self._spans: deque = deque(maxlen=self._capacity)
        self._dropped = 0
        # all exported timestamps are relative to this epoch
        self._epoch = time.perf_counter()

    # ---------------- control ----------------
    def enable(self):
        self.enabled = True

    def disable(self):
        self.enabled = False

    def clear(self):
        with self._lock:
            self._spans.clear()
            self._dropped = 0
            self._epoch = time.perf_counter()

    @property
    def dropped(self) -> int:
        with self._lock:
            return self._dropped

    def __len__(self) -> int:
        with self._lock:
            return len(self._spans)

    # ---------------- recording ----------------
    def record(self, track: str, name: str, cat: str, t0: float,
               t1: Optional[float], **args):
        """Append one complete span (or instant when ``t1 is None``).
        ``args`` values must be JSON-serializable scalars."""
        with self._lock:
            if len(self._spans) == self._capacity:
                self._dropped += 1
            self._spans.append((track, name, cat, t0, t1, args or None))

    def instant(self, track: str, name: str, cat: str, **args):
        self.record(track, name, cat, time.perf_counter(), None, **args)

    def spans(self) -> List[Span]:
        with self._lock:
            return list(self._spans)

    # ---------------- reduction ----------------
    def summary(self) -> dict:
        """Flat aggregates for ``metrics_snapshot()``: per-route chunk
        transfer time/bytes and queue-wait time, measured from the
        channel-thread spans.

        Concurrency semantics (the live-rate feed contract): a striped
        device runs P path-channel threads CONCURRENTLY, so per-route
        ``busy_s`` (the plain sum of chunk-span durations across all
        channels) over-counts wall time by up to P× — ``bytes /
        busy_s`` would read ~1/P of the aggregate rate the device
        actually delivered. ``busy_wall_s`` is therefore the measure
        rates must divide by: the UNION of the chunk-span intervals per
        route, which equals the summed durations when channels run
        serially and the wall-clock envelope when they overlap (a
        single channel reduces to ``busy_s`` exactly). ``rate_bps =
        bytes / busy_wall_s`` is the aggregate effective rate —
        the feed for ``perfmodel.machine_from_snapshot``. ``channels``
        counts the distinct threads that carried the route.

        Per-path splits: chunk spans on channel threads carry the SSD
        path index, so each route also reports ``per_path`` (path ->
        bytes/busy_s/ops/rate_bps, keys stringified for JSON
        round-trip). A path channel is a single thread, so its spans
        never overlap and per-(route, path) ``busy_s`` IS that path's
        wall occupancy — ``rate_bps = bytes / busy_s`` measures the
        DEVICE's achieved rate no matter how few chunks placement sent
        it. Per-path bytes sum exactly to the route's ``bytes``
        (placement moves bytes between paths, never between routes);
        ``obs.reconcile`` asserts that invariant."""
        routes: Dict[str, dict] = {}
        intervals: Dict[str, list] = {}
        tracks: Dict[str, set] = {}
        n_spans = 0
        for track, _name, cat, t0, t1, args in self.spans():
            n_spans += 1
            if t1 is None or cat not in (CAT_IO_CHUNK, CAT_IO_QUEUE):
                continue
            route = (args or {}).get("route") or "?"
            d = routes.setdefault(route, {"bytes": 0, "busy_s": 0.0,
                                          "queue_s": 0.0, "ops": 0,
                                          "per_path": {}})
            if cat == CAT_IO_QUEUE:
                d["queue_s"] += t1 - t0
            else:
                d["busy_s"] += t1 - t0
                d["bytes"] += int((args or {}).get("nbytes", 0))
                d["ops"] += 1
                intervals.setdefault(route, []).append((t0, t1))
                tracks.setdefault(route, set()).add(track)
                path = (args or {}).get("path")
                if path is not None:
                    pp = d["per_path"].setdefault(
                        str(path), {"bytes": 0, "busy_s": 0.0, "ops": 0})
                    pp["bytes"] += int((args or {}).get("nbytes", 0))
                    pp["busy_s"] += t1 - t0
                    pp["ops"] += 1
        for route, d in routes.items():
            wall = _union_seconds(intervals.get(route, []))
            d["busy_wall_s"] = wall
            d["channels"] = len(tracks.get(route, ()))
            d["rate_bps"] = d["bytes"] / wall if wall > 0 else 0.0
            for pp in d["per_path"].values():
                pp["rate_bps"] = (pp["bytes"] / pp["busy_s"]
                                  if pp["busy_s"] > 0 else 0.0)
        return {"enabled": self.enabled, "spans": n_spans,
                "dropped": self.dropped, "routes": routes}

    # ---------------- export ----------------
    def export_chrome(self, path: str) -> str:
        """Write the ring as Chrome trace-event JSON and return ``path``.
        One ``tid`` (track) per channel thread / executor / hint stream,
        named via ``ph="M"`` thread_name metadata."""
        tids: Dict[str, int] = {}
        events: List[dict] = []

        def tid_of(track: str) -> int:
            tid = tids.get(track)
            if tid is None:
                tid = tids[track] = len(tids) + 1
                events.append({"ph": "M", "pid": 1, "tid": tid,
                               "name": "thread_name",
                               "args": {"name": track}})
            return tid

        for track, name, cat, t0, t1, args in self.spans():
            ev = {"pid": 1, "tid": tid_of(track), "name": name, "cat": cat,
                  "ts": (t0 - self._epoch) * 1e6}
            if t1 is None:
                ev["ph"] = "i"
                ev["s"] = "t"                    # thread-scoped instant
            else:
                ev["ph"] = "X"
                ev["dur"] = max(0.0, (t1 - t0) * 1e6)
            if args:
                ev["args"] = args
            events.append(ev)
        doc = {"traceEvents": events, "displayTimeUnit": "ms",
               "otherData": {"dropped": self.dropped,
                             "capacity": self._capacity}}
        with open(path, "w") as f:
            json.dump(doc, f)
        return path
