"""α-delayed partial optimizer step (GreedySnake §4.4) as a JAX transform.

Adam is element-wise, so each tensor can be partitioned into an "early"
fraction (1-α), updated right after its layer's backward pass, and a
"late" fraction α, deferred to just before the layer's forward pass in
the NEXT iteration. Both fractions use the same gradients and the same
step counter, so the composition is EXACTLY one standard Adam step —
split in time, not in math (tests assert bit-equality in f32).

The partition is a static flat-index split at k = round((1-α)·size) per
leaf, mirroring the paper's chunk-granularity CPU optimizer (chunks need
not align with layer boundaries, §2.2).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.adam import AdamConfig, AdamState, _adam_update


class DelayedAdamState(NamedTuple):
    adam: AdamState
    pending: Any          # f32 grads retained for the late fraction
    has_pending: jax.Array  # bool scalar (first iteration has none)


def init_delayed(adam_state: AdamState, grads_like) -> DelayedAdamState:
    zeros = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
    return DelayedAdamState(adam_state, zeros, jnp.zeros((), bool))


def _split_k(x, alpha: float) -> int:
    return int(round((1.0 - alpha) * x.size))


def _partial_leaf(p, g, m, v, step, cfg, lo: int, hi: int):
    """Update flat elements [lo, hi) of one leaf; leave the rest."""
    shape = p.shape
    pf, gf = p.reshape(-1), g.reshape(-1)
    mf, vf = m.reshape(-1), v.reshape(-1)
    n = hi - lo
    if n <= 0:
        return p, m, v
    ps = jax.lax.dynamic_slice_in_dim(pf, lo, n, 0)
    gs = jax.lax.dynamic_slice_in_dim(gf, lo, n, 0)
    ms = jax.lax.dynamic_slice_in_dim(mf, lo, n, 0)
    vs = jax.lax.dynamic_slice_in_dim(vf, lo, n, 0)
    p2, m2, v2 = _adam_update(ps, gs, ms, vs, step, cfg)
    pf = jax.lax.dynamic_update_slice_in_dim(pf, p2, lo, 0)
    mf = jax.lax.dynamic_update_slice_in_dim(mf, m2, lo, 0)
    vf = jax.lax.dynamic_update_slice_in_dim(vf, v2, lo, 0)
    return pf.reshape(shape), mf.reshape(shape), vf.reshape(shape)


def _apply_fraction(state: AdamState, grads, cfg: AdamConfig, alpha: float,
                    which: str, step) -> AdamState:
    """Update the early [0,k) or late [k,size) fraction of every leaf."""
    leaves_p, treedef = jax.tree.flatten(state.master)
    leaves_g = treedef.flatten_up_to(grads)
    leaves_m = treedef.flatten_up_to(state.m)
    leaves_v = treedef.flatten_up_to(state.v)
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(leaves_p, leaves_g, leaves_m, leaves_v):
        k = _split_k(p, alpha)
        lo, hi = (0, k) if which == "early" else (k, p.size)
        p2, m2, v2 = _partial_leaf(p, g, m, v, step, cfg, lo, hi)
        new_p.append(p2)
        new_m.append(m2)
        new_v.append(v2)
    return AdamState(treedef.unflatten(new_p), treedef.unflatten(new_m),
                     treedef.unflatten(new_v), state.step)


def flush_late(state: DelayedAdamState, cfg: AdamConfig, alpha: float,
               compute_dtype=jnp.bfloat16):
    """Apply the deferred α fraction (start of next iteration's forward).

    Returns (fully-updated low-precision params, DelayedAdamState)."""
    def do(adam: AdamState) -> AdamState:
        return _apply_fraction(adam, state.pending, cfg, alpha, "late",
                               adam.step)

    adam = jax.lax.cond(state.has_pending, do, lambda a: a, state.adam)
    params = jax.tree.map(lambda p: p.astype(compute_dtype), adam.master)
    return params, DelayedAdamState(adam, state.pending, jnp.zeros((), bool))


def apply_early(state: DelayedAdamState, grads, cfg: AdamConfig, alpha: float,
                compute_dtype=jnp.bfloat16):
    """Apply the (1-α) fraction right after backward; retain grads for the
    late fraction. Returns (partially-updated params, DelayedAdamState)."""
    step = state.adam.step + 1
    adam = _apply_fraction(state.adam._replace(step=step), grads, cfg,
                           alpha, "early", step)
    pending = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    params = jax.tree.map(lambda p: p.astype(compute_dtype), adam.master)
    return params, DelayedAdamState(adam, pending, jnp.ones((), bool))
