"""Host (numpy) Adam for the offload engine — the analogue of
ZeRO-Infinity's ``cpu_adam`` that GreedySnake reuses.

All computation is uniformly vectorised (no scalar tail handling), which
is the paper's §6.5 reproducibility point: loss is bit-identical across
different chunk/partition ratios because every element goes through the
same vectorised code path. Supports partial (chunk-range) updates for the
α-delayed optimizer step.
"""
from __future__ import annotations

import numpy as np


class CpuAdam:
    def __init__(self, lr=3e-4, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0):
        self.lr, self.b1, self.b2, self.eps, self.wd = lr, b1, b2, eps, weight_decay

    def update(self, master: np.ndarray, m: np.ndarray, v: np.ndarray,
               grad: np.ndarray, step: int,
               lo: int = 0, hi: int | None = None) -> None:
        """In-place Adam on flat f32 arrays, elements [lo, hi)."""
        hi = master.size if hi is None else hi
        if hi <= lo:
            return
        p = master[lo:hi]
        g = grad[lo:hi].astype(np.float32)
        m_ = m[lo:hi]
        v_ = v[lo:hi]
        np.multiply(m_, self.b1, out=m_)
        m_ += (1 - self.b1) * g
        np.multiply(v_, self.b2, out=v_)
        v_ += (1 - self.b2) * (g * g)
        bc1 = 1 - self.b1 ** step
        bc2 = 1 - self.b2 ** step
        denom = np.sqrt(v_ / bc2) + self.eps
        upd = (m_ / bc1) / denom
        if self.wd:
            upd = upd + self.wd * p
        p -= self.lr * upd
