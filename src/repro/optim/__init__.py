from repro.optim.adam import (  # noqa: F401
    AdamConfig,
    AdamState,
    apply_update,
    clip_by_global_norm,
    global_norm,
    init_state,
)
from repro.optim.partial import (  # noqa: F401
    DelayedAdamState,
    apply_early,
    flush_late,
    init_delayed,
)
from repro.optim.cpu_adam import CpuAdam  # noqa: F401
