"""Mixed-precision Adam (paper §2.1/§2.2 conventions).

Each weight element carries three full-precision optimizer states —
master parameter, momentum, variance (the paper folds master params into
"optimizer states"; so do we). Forward/backward use the low-precision
(bf16) parameters; gradients are accumulated in f32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    master: Any   # f32 pytree (master parameters)
    m: Any        # f32 pytree
    v: Any        # f32 pytree
    step: jax.Array  # int32 scalar


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0


def init_state(params) -> AdamState:
    f32 = lambda x: x.astype(jnp.float32)
    zeros = lambda x: jnp.zeros(x.shape, jnp.float32)
    return AdamState(
        master=jax.tree.map(f32, params),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def _adam_update(p, g, m, v, step, cfg: AdamConfig):
    g = g.astype(jnp.float32)
    m2 = cfg.b1 * m + (1 - cfg.b1) * g
    v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
    t = step.astype(jnp.float32)
    mhat = m2 / (1 - cfg.b1 ** t)
    vhat = v2 / (1 - cfg.b2 ** t)
    p2 = p - cfg.lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p)
    return p2, m2, v2


def apply_update(state: AdamState, grads, cfg: AdamConfig,
                 compute_dtype=jnp.bfloat16):
    """Full optimizer step. Returns (new low-precision params, new state)."""
    step = state.step + 1
    flat_p, treedef = jax.tree.flatten(state.master)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [_adam_update(p, g, m, v, step, cfg)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    master = treedef.unflatten([o[0] for o in out])
    m = treedef.unflatten([o[1] for o in out])
    v = treedef.unflatten([o[2] for o in out])
    params = jax.tree.map(lambda p: p.astype(compute_dtype), master)
    return params, AdamState(master, m, v, step)


def global_norm(grads) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                        for g in jax.tree.leaves(grads)))


def clip_by_global_norm(grads, max_norm: float):
    """Returns (clipped grads, clip_coef<=1, raw norm)."""
    n = global_norm(grads)
    coef = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-12))
    return jax.tree.map(lambda g: g.astype(jnp.float32) * coef, grads), coef, n
