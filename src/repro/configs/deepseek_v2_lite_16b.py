"""DeepSeek-V2-Lite 16B [arXiv:2405.04434].

Assignment line lists both "MoE 64e top-6" and "2 shared+160 routed"; the
160-routed figure belongs to full V2 — V2-Lite is 64 routed + 2 shared,
top-6 (paper Tab. 1). We use 64 routed + 2 shared, top-6, MLA kv_lora=512.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    source="arXiv:2405.04434",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=10944,           # dense FFN used by the first layer
    vocab_size=102_400,
    use_mla=True,
    kv_lora_rank=512,
    q_lora_rank=0,        # V2-Lite has no q compression
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    num_experts=64,
    num_shared_experts=2,
    moe_top_k=6,
    moe_d_ff=1408,
    first_dense_layers=1,
    rope_theta=10_000.0,
    act="swiglu",
)

SMOKE = CONFIG.reduced()
