"""Qwen3-MoE 235B-A22B [hf:Qwen/Qwen3-30B-A3B family] — 128 experts, top-8."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    source="hf:Qwen/Qwen3-30B-A3B",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,             # (unused — every layer is MoE)
    vocab_size=151_936,
    use_qk_norm=True,
    num_experts=128,
    num_shared_experts=0,
    moe_top_k=8,
    moe_d_ff=1536,
    rope_theta=1_000_000.0,
    act="swiglu",
)

SMOKE = CONFIG.reduced()
