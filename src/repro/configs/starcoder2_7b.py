"""StarCoder2-7B [arXiv:2402.19173] — dense, GQA + RoPE (GELU MLP)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b",
    family="dense",
    source="arXiv:2402.19173",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18_432,
    vocab_size=49_152,
    rope_theta=1_000_000.0,
    act="gelu",
)

SMOKE = CONFIG.reduced()
