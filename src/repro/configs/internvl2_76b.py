"""InternVL2-76B [arXiv:2404.16821] — VLM; language backbone only.

The InternViT-6B vision tower + MLP projector are STUBBED per the
assignment carve-out: ``input_specs`` supplies precomputed patch
embeddings (256 tokens/image after pixel-shuffle) of shape
(batch, frontend_tokens, d_model) which are prepended to the text tokens.
The backbone below is the Llama-3-70B-shaped decoder used by InternVL2-76B.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    source="arXiv:2404.16821",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28_672,
    vocab_size=128_256,
    frontend_tokens=256,
    rope_theta=500_000.0,
    act="swiglu",
)

SMOKE = CONFIG.reduced()
