"""Phi-3-medium 14B [arXiv:2404.14219] — dense, RoPE + SwiGLU + GQA."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3-medium-14b",
    family="dense",
    source="arXiv:2404.14219",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=10,
    head_dim=128,
    d_ff=17_920,
    vocab_size=100_352,
    rope_theta=10_000.0,
    act="swiglu",
)

SMOKE = CONFIG.reduced()
