"""Architecture + input-shape configuration for the repro framework.

Every assigned architecture gets one module in this package defining a
``CONFIG`` (exact published dims, source cited) plus a reduced ``SMOKE``
variant (<=2 layers, d_model<=512, <=4 experts) used by CPU smoke tests.

The FULL configs are only ever *lowered* (ShapeDtypeStruct dry-run); the
SMOKE configs actually run.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional


# ---------------------------------------------------------------------------
# Input shapes (assigned; fixed across architectures)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One transformer-family architecture.

    Families: dense | moe | ssm | hybrid | encdec | vlm
    (vlm/audio frontends are precomputed-embedding stubs per assignment.)
    """

    name: str
    family: str
    source: str  # citation from the assignment line

    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0

    # --- attention details ---
    rope_theta: float = 10_000.0
    use_qk_norm: bool = False
    sliding_window: Optional[int] = None   # window for "local" layers
    global_attn_every: Optional[int] = None  # e.g. 6 => 5 local : 1 global
    causal: bool = True

    # --- MLA (DeepSeek-V2) ---
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0          # 0 => no q compression
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ---
    num_experts: int = 0          # routed experts (0 => dense MLP)
    num_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0             # per-expert FFN width
    first_dense_layers: int = 0   # leading layers that use a dense MLP
    moe_every: int = 1            # MoE in layers where i % moe_every == moe_offset
    moe_offset: int = 0

    # --- SSM (Mamba-1) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    attn_every: Optional[int] = None   # hybrid: attention where i % attn_every == attn_offset
    attn_offset: int = 0

    # --- enc-dec / multimodal frontends ---
    encoder_layers: int = 0
    encoder_seq: int = 0          # stubbed frame/patch embedding count
    frontend_tokens: int = 0      # vlm: image patch embeddings prepended

    # --- misc ---
    norm_eps: float = 1e-6
    act: str = "swiglu"           # "swiglu" | "gelu"
    tie_embeddings: bool = False
    scale_embed: bool = False     # gemma-style sqrt(d) embedding scaling
    local_rope_theta: float = 10_000.0  # rope theta for sliding-window layers
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab padded so the 16-way model axis divides it."""
        mult = 128
        return int(math.ceil(self.vocab_size / mult) * mult)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def is_attn_layer(self, i: int) -> bool:
        if self.family == "ssm":
            return False
        if self.attn_every is None:
            return True
        return (i % self.attn_every) == self.attn_offset

    def is_global_attn_layer(self, i: int) -> bool:
        if self.global_attn_every is None:
            return True
        return (i % self.global_attn_every) == (self.global_attn_every - 1)

    def is_moe_layer(self, i: int) -> bool:
        if self.num_experts == 0:
            return False
        if i < self.first_dense_layers:
            return False
        return (i % self.moe_every) == self.moe_offset

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(1, math.ceil(self.d_model / 16))

    # --- parameter counting (used by traffic/perf models & roofline) -----
    def _attn_params(self) -> int:
        d = self.d_model
        if self.use_mla:
            q = (d * self.q_lora_rank + self.q_lora_rank *
                 self.num_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)) \
                if self.q_lora_rank else \
                d * self.num_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
            kv = d * (self.kv_lora_rank + self.qk_rope_head_dim)
            kv += self.kv_lora_rank * self.num_heads * (self.qk_nope_head_dim + self.v_head_dim)
            o = self.num_heads * self.v_head_dim * d
            return q + kv + o
        hd = self.head_dim
        return (d * self.num_heads * hd          # q
                + 2 * d * self.num_kv_heads * hd  # k,v
                + self.num_heads * hd * d)        # o

    def _mlp_params(self, i: int) -> int:
        d = self.d_model
        mult = 3 if self.act == "swiglu" else 2
        if self.is_moe_layer(i):
            per = mult * d * self.moe_d_ff
            return ((self.num_experts + self.num_shared_experts) * per
                    + d * self.num_experts)  # router
        return mult * d * self.d_ff

    def _mamba_params(self) -> int:
        d, di, st = self.d_model, self.d_inner, self.ssm_state
        return (d * 2 * di                 # in_proj
                + di * self.ssm_conv       # conv1d
                + di * (self.dt_rank + 2 * st)  # x_proj
                + self.dt_rank * di        # dt_proj
                + di * st + di             # A_log, D
                + di * d)                  # out_proj

    def layer_params(self, i: int) -> int:
        """Parameter count of block i (decoder side for enc-dec)."""
        if self.family == "ssm":
            return self._mamba_params() + self.d_model  # + norm
        if self.family == "hybrid":
            mixer = self._attn_params() if self.is_attn_layer(i) else self._mamba_params()
            return mixer + self._mlp_params(i) + 2 * self.d_model
        return self._attn_params() + self._mlp_params(i) + 2 * self.d_model

    def total_params(self) -> int:
        n = sum(self.layer_params(i) for i in range(self.num_layers))
        n += self.padded_vocab * self.d_model * (1 if self.tie_embeddings else 2)
        n += self.d_model  # final norm
        if self.family == "encdec":
            enc_layer = self._attn_params() + self._mlp_params(0) + 2 * self.d_model
            cross = self._attn_params() + self.d_model
            n += self.encoder_layers * enc_layer + self.num_layers * cross
        return n

    def active_params(self) -> int:
        """Params touched per token (MoE: top-k + shared only)."""
        if self.num_experts == 0:
            return self.total_params()
        n = self.padded_vocab * self.d_model * (1 if self.tie_embeddings else 2)
        mult = 3 if self.act == "swiglu" else 2
        for i in range(self.num_layers):
            if self.family == "hybrid":
                mixer = self._attn_params() if self.is_attn_layer(i) else self._mamba_params()
            elif self.family == "ssm":
                mixer = self._mamba_params()
            else:
                mixer = self._attn_params()
            if self.is_moe_layer(i):
                per = mult * self.d_model * self.moe_d_ff
                mlp = (self.moe_top_k + self.num_shared_experts) * per
            else:
                mlp = self._mlp_params(i)
            n += mixer + mlp + 2 * self.d_model
        return n

    # --- reduced smoke variant ---------------------------------------
    def reduced(self) -> "ArchConfig":
        """<=2 layers, d_model<=512, <=4 experts — runnable on CPU."""
        d = min(self.d_model, 256)
        heads = min(self.num_heads, 4)
        kv = max(1, min(self.num_kv_heads, heads))
        hd = 32
        layers = min(self.num_layers, 2)
        if self.family == "hybrid":
            layers = 2  # 1 mamba + 1 attn below
        kw = dict(
            name=self.name + "-smoke",
            num_layers=layers,
            d_model=d,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 4 * d) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 1024),
            num_experts=min(self.num_experts, 4),
            moe_top_k=min(self.moe_top_k, 2),
            moe_d_ff=min(self.moe_d_ff, 2 * d) if self.moe_d_ff else 0,
            num_shared_experts=min(self.num_shared_experts, 1),
            first_dense_layers=min(self.first_dense_layers, 1),
            kv_lora_rank=min(self.kv_lora_rank, 64),
            q_lora_rank=min(self.q_lora_rank, 64),
            qk_nope_head_dim=hd if self.use_mla else 0,
            qk_rope_head_dim=hd // 2 if self.use_mla else 0,
            v_head_dim=hd if self.use_mla else 0,
            ssm_state=min(self.ssm_state, 8),
            attn_every=2 if self.attn_every else None,
            attn_offset=1 if self.attn_every else 0,
            global_attn_every=2 if self.global_attn_every else None,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else None,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 32) if self.encoder_seq else 0,
            frontend_tokens=min(self.frontend_tokens, 16) if self.frontend_tokens else 0,
        )
        return dataclasses.replace(self, **kw)
