"""Gemma3-1B [hf:google/gemma-3-1b-pt] — 5:1 local:global attention, 128k.

Local layers use a 512-token sliding window; every 6th layer is global.
head_dim=256 explicit (heads*hd != d_model). qk-norm per gemma3.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b",
    family="dense",
    source="hf:google/gemma-3-1b-pt",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262_144,
    use_qk_norm=True,
    sliding_window=512,
    global_attn_every=6,   # layers 5, 11, 17, 23 are global
    rope_theta=1_000_000.0,   # global layers
    local_rope_theta=10_000.0,  # sliding-window layers
    act="gelu",            # gemma uses gelu-gated (geglu); we model gated gelu via swiglu-shape
    tie_embeddings=True,
    scale_embed=True,
)

SMOKE = CONFIG.reduced()
