"""Qwen3-4B [hf:Qwen/Qwen3-8B family] — dense, GQA + qk-norm."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-4b",
    family="dense",
    source="hf:Qwen/Qwen3-8B",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,          # qwen3 uses explicit head_dim 128 (heads*hd != d_model)
    d_ff=9728,
    vocab_size=151_936,
    use_qk_norm=True,
    rope_theta=1_000_000.0,
    act="swiglu",
)

SMOKE = CONFIG.reduced()
