"""Small runnable configs for examples/tests on this CPU container."""
from repro.configs.base import ArchConfig

# ~124M GPT-2-small-shaped model: the end-to-end training driver target.
GPT_100M = ArchConfig(
    name="gpt-100m",
    family="dense",
    source="examples (GPT-2-small shaped)",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=32_768,
    rope_theta=10_000.0,
    act="gelu",
)

# ~10M model for fast integration tests / quickstart.
GPT_TINY = ArchConfig(
    name="gpt-tiny",
    family="dense",
    source="tests",
    num_layers=4,
    d_model=256,
    num_heads=4,
    num_kv_heads=4,
    head_dim=64,
    d_ff=1024,
    vocab_size=2048,
    rope_theta=10_000.0,
    act="gelu",
)

CONFIG = GPT_100M
SMOKE = GPT_TINY
