"""Config registry: ``get_config(name)`` / ``get_smoke(name)``.

The 10 assigned architectures + the paper's own GPT models + small runnable
configs. ``--arch <id>`` in the launchers resolves through REGISTRY.
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ArchConfig, InputShape, INPUT_SHAPES  # noqa: F401

# arch-id -> module (one module per assigned architecture, per the brief)
_MODULES = {
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "whisper-base": "repro.configs.whisper_base",
    "falcon-mamba-7b": "repro.configs.falcon_mamba_7b",
    "phi3-medium-14b": "repro.configs.phi3_medium_14b",
    "qwen3-4b": "repro.configs.qwen3_4b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "jamba-v0.1-52b": "repro.configs.jamba_v01_52b",
    "starcoder2-7b": "repro.configs.starcoder2_7b",
    "gemma3-1b": "repro.configs.gemma3_1b",
    "internvl2-76b": "repro.configs.internvl2_76b",
    # the paper's own evaluation models
    "gpt-30b": "repro.configs.gpt_paper",
    "gpt-65b": "repro.configs.gpt_paper",
    "gpt-175b": "repro.configs.gpt_paper",
    # small runnable configs
    "gpt-100m": "repro.configs.tiny",
    "gpt-tiny": "repro.configs.tiny",
}

ASSIGNED_ARCHS = [
    "deepseek-v2-lite-16b",
    "whisper-base",
    "falcon-mamba-7b",
    "phi3-medium-14b",
    "qwen3-4b",
    "qwen3-moe-235b-a22b",
    "jamba-v0.1-52b",
    "starcoder2-7b",
    "gemma3-1b",
    "internvl2-76b",
]

# archs eligible for the long_500k decode shape (sub-quadratic context):
# SSM (O(1) state), hybrid (only 4/32 layers hold full cache), and the one
# dense arch with a native sliding-window pattern (gemma3: only ~4 global
# layers hold full cache). Pure full-attention archs skip it (DESIGN.md §4).
LONG_CONTEXT_ARCHS = ["falcon-mamba-7b", "jamba-v0.1-52b", "gemma3-1b"]


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(_MODULES[name])
    if name == "gpt-30b":
        return mod.GPT_30B
    if name == "gpt-65b":
        return mod.GPT_65B
    if name == "gpt-175b":
        return mod.GPT_175B
    if name == "gpt-100m":
        return mod.GPT_100M
    if name == "gpt-tiny":
        return mod.GPT_TINY
    return mod.CONFIG


def get_smoke(name: str) -> ArchConfig:
    mod = importlib.import_module(_MODULES[name])
    return mod.SMOKE


def list_archs() -> Dict[str, str]:
    return dict(_MODULES)


def supports_shape(arch: str, shape: str) -> bool:
    """Whether (arch, shape) is part of the dry-run/roofline matrix."""
    cfg = get_config(arch)
    if shape == "long_500k":
        return arch in LONG_CONTEXT_ARCHS
    return True
