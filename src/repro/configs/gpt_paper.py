"""The paper's own evaluation models (GreedySnake Tab. 2, Megatron GPT-style).

These drive the paper-claim reproductions (Fig. 4/5/10/11/12): traffic
formulas, perf model, LP search. GPT-style: MHA (kv=heads), GELU 4x MLP,
vocab 50257 (padded), seq 2048 in the paper's experiments.
"""
from repro.configs.base import ArchConfig


def _gpt(name, layers, heads, hidden):
    return ArchConfig(
        name=name,
        family="dense",
        source="GreedySnake Tab.2 / Megatron-LM",
        num_layers=layers,
        d_model=hidden,
        num_heads=heads,
        num_kv_heads=heads,
        head_dim=hidden // heads,
        d_ff=4 * hidden,
        vocab_size=50_257,
        rope_theta=10_000.0,
        act="gelu",
    )


GPT_30B = _gpt("gpt-30b", 48, 56, 7168)
GPT_65B = _gpt("gpt-65b", 80, 64, 8192)
GPT_175B = _gpt("gpt-175b", 96, 96, 12288)

CONFIG = GPT_65B
SMOKE = GPT_65B.reduced()
