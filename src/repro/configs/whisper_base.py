"""Whisper-base [arXiv:2212.04356] — enc-dec transformer backbone.

Conv/mel frontend is a stub per the assignment carve-out: ``input_specs``
provides precomputed frame embeddings (1500 frames for the 30 s window) of
shape (batch, frames, d_model) directly to the encoder.

vocab 51865 padded to 51968 for 16-way sharding (see ArchConfig.padded_vocab).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="encdec",
    source="arXiv:2212.04356",
    num_layers=6,          # decoder layers
    encoder_layers=6,
    encoder_seq=1500,      # stubbed conv-frontend output frames
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51_865,
    rope_theta=10_000.0,   # (whisper uses learned pos-emb; we use RoPE-free sinusoidal)
    act="gelu",
)

SMOKE = CONFIG.reduced()
