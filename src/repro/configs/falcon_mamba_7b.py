"""Falcon-Mamba 7B [arXiv:2410.05355] — pure Mamba-1, attention-free."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    source="arXiv:2410.05355",
    num_layers=64,
    d_model=4096,
    d_ff=0,
    vocab_size=65_024,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    act="swiglu",  # unused (no FFN); mamba block has its own gating
)

SMOKE = CONFIG.reduced()
