"""Jamba v0.1 52B [arXiv:2403.19887] — Mamba+attention 1:7, MoE 16e top-2.

Jamba block structure: 8 layers per block, 1 attention : 7 mamba
(attention at in-block index 3 per the paper figure), MoE replacing the
MLP every other layer (e=2).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    source="arXiv:2403.19887",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=65_536,
    num_experts=16,
    moe_top_k=2,
    moe_d_ff=14_336,
    moe_every=2,
    moe_offset=1,
    attn_every=8,
    attn_offset=3,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    act="swiglu",
)

SMOKE = CONFIG.reduced()
