from repro.data.synthetic import SyntheticLM, make_batch  # noqa: F401
from repro.data.microbatch import split_microbatches  # noqa: F401
