"""Synthetic LM data with learnable structure.

A noisy Markov chain over the vocab: with probability ``p_det`` the next
token is a fixed permutation of the current one, else uniform. A model
that learns the permutation reaches loss ≈ -[p ln p + (1-p) ln((1-p)/V)],
so integration tests can assert a concrete loss drop (not just "finite").
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np


class SyntheticLM:
    def __init__(self, vocab: int, seed: int = 0, p_det: float = 0.9):
        self.vocab = vocab
        self.p_det = p_det
        rng = np.random.default_rng(seed)
        self.perm = rng.permutation(vocab).astype(np.int32)
        self._rng = np.random.default_rng(seed + 1)

    def batch(self, batch_size: int, seq_len: int) -> np.ndarray:
        rng = self._rng
        out = np.empty((batch_size, seq_len), np.int32)
        cur = rng.integers(0, self.vocab, batch_size, dtype=np.int32)
        for t in range(seq_len):
            out[:, t] = cur
            det = rng.random(batch_size) < self.p_det
            rnd = rng.integers(0, self.vocab, batch_size, dtype=np.int32)
            cur = np.where(det, self.perm[cur], rnd)
        return out

    def ideal_loss(self) -> float:
        p, v = self.p_det, self.vocab
        return float(-(p * np.log(p + (1 - p) / v)
                       + (1 - p) * (v - 1) / v * np.log((1 - p) / v)))


def make_batch(cfg, batch_size: int, seq_len: int, *, seed: int = 0,
               data: Optional[SyntheticLM] = None) -> Dict[str, np.ndarray]:
    """Assemble the per-family batch dict (tokens + stub frontends)."""
    rng = np.random.default_rng(seed)
    if cfg.family == "vlm":
        text = seq_len - cfg.frontend_tokens
        tokens = (data.batch(batch_size, text) if data
                  else rng.integers(0, cfg.vocab_size, (batch_size, text), dtype=np.int32))
        img = rng.standard_normal((batch_size, cfg.frontend_tokens,
                                   cfg.d_model)).astype(np.float32) * (cfg.d_model ** -0.5)
        return {"tokens": tokens, "image_embeds": img}
    tokens = (data.batch(batch_size, seq_len) if data
              else rng.integers(0, cfg.vocab_size, (batch_size, seq_len), dtype=np.int32))
    out = {"tokens": tokens}
    if cfg.family == "encdec":
        out["enc_embeds"] = rng.standard_normal(
            (batch_size, cfg.encoder_seq, cfg.d_model)).astype(np.float32) \
            * (cfg.d_model ** -0.5)
    return out
