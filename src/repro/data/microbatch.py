"""Micro-batch splitting for gradient accumulation (paper §2.1)."""
from __future__ import annotations

import jax


def split_microbatches(batch, num_microbatches: int):
    """dict of (B, ...) -> dict of (M, B/M, ...). B must divide evenly."""
    def split(x):
        b = x.shape[0]
        assert b % num_microbatches == 0, (b, num_microbatches)
        return x.reshape(num_microbatches, b // num_microbatches, *x.shape[1:])
    return jax.tree.map(split, batch)
