"""Pallas TPU flash-attention forward kernel.

TPU adaptation (not a CUDA port): the kernel tiles Q into (block_q, hd)
VMEM blocks and streams K/V through VMEM in (block_k, hd) tiles on the
innermost (sequential) grid axis, keeping the running max/denominator/
accumulator in VMEM scratch across those grid steps — the MXU sees
(block_q x hd) @ (hd x block_k) matmuls with both dims multiples of 128.
Grid: (B, H, num_q_blocks, num_k_blocks); the kv axis is the
fastest-varying (sequential on TPU), so scratch carries are legal.

Validated in interpret mode against repro.kernels.ref.ref_attention.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  causal: bool, scale: float, block_q: int, block_k: int,
                  num_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)            # (bk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                        (block_q, block_k), 0)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                        (block_q, block_k), 1)
        s = jnp.where(k_pos <= q_pos, s, _NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_cur)
    corr = jnp.exp(m_prev - m_cur)
    l_cur = corr * l_prev + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_cur
    l_ref[...] = l_cur

    @pl.when(ki == num_k - 1)
    def _finalize():
        o_ref[0, 0, :, :] = (acc_ref[...]
                             / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True,
                        scale: Optional[float] = None,
                        block_q: int = 256, block_k: int = 512,
                        interpret: bool = True) -> jax.Array:
    """q, k, v: (B, H, S, hd) (pre-grouped; GQA callers repeat or group
    outside). Returns (B, H, S, hd) in q.dtype."""
    B, H, Sq, hd = q.shape
    Skv = k.shape[2]
    bq = min(block_q, Sq)
    while Sq % bq:
        bq //= 2
    bk = min(block_k, Skv)
    while Skv % bk:
        bk //= 2
    nq, nk = Sq // bq, Skv // bk
    sc = scale if scale is not None else 1.0 / math.sqrt(hd)

    kernel = functools.partial(_flash_kernel, causal=causal, scale=sc,
                               block_q=bq, block_k=bk, num_k=nk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, qi, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, qi, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
