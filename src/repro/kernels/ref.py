"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def ref_attention(q, k, v, *, causal: bool = True,
                  scale: Optional[float] = None) -> jax.Array:
    """Exact softmax attention. q: (B,H,Sq,hd); k,v: (B,H,Skv,hd)."""
    B, H, Sq, hd = q.shape
    Skv = k.shape[2]
    sc = scale if scale is not None else 1.0 / math.sqrt(hd)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sc
    if causal:
        msk = jnp.tril(jnp.ones((Sq, Skv), bool), k=Skv - Sq)
        s = jnp.where(msk, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def ref_selective_scan(x, dt, A, Bc, Cc, D) -> Tuple[jax.Array, jax.Array]:
    """Naive sequential selective scan.
    x, dt: (B,S,di); Bc,Cc: (B,S,st); A: (di,st); D: (di,)."""
    B, S, di = x.shape
    st = A.shape[-1]
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf = Bc.astype(jnp.float32), Cc.astype(jnp.float32)
    h = jnp.zeros((B, di, st), jnp.float32)
    ys = []
    for t in range(S):
        da = jnp.exp(dtf[:, t, :, None] * A)
        h = da * h + (dtf[:, t] * xf[:, t])[..., None] * Bf[:, t][:, None, :]
        ys.append(jnp.einsum("bds,bs->bd", h, Cf[:, t]))
    y = jnp.stack(ys, axis=1) + xf * D
    return y.astype(x.dtype), h


def ref_adam(p, m, v, g, step: int, *, lr=1e-3, b1=0.9, b2=0.95, eps=1e-8,
             wd=0.0):
    """Element-wise Adam; all f32. Returns (p2, m2, v2)."""
    g = g.astype(jnp.float32)
    m2 = b1 * m + (1 - b1) * g
    v2 = b2 * v + (1 - b2) * g * g
    mhat = m2 / (1 - b1 ** step)
    vhat = v2 / (1 - b2 ** step)
    p2 = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
    return p2, m2, v2
