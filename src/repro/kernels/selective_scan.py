"""Pallas TPU selective-scan (Mamba-1) kernel.

TPU adaptation: the recurrence h_t = exp(dt_t A) h_{t-1} + (dt_t x_t) B_t
is element-wise in the d_inner dimension, so we tile d_inner into
(block_d) VMEM lanes (multiples of 128 for the VPU) and keep the hidden
state h (block_d, st) resident in VMEM scratch while streaming the time
axis in (block_t) chunks on the innermost sequential grid axis. No
inter-chip traffic: d_inner is the natural shard dim.

Grid: (B, num_d_blocks, num_t_chunks); within a chunk the kernel runs a
fori_loop over time steps (VPU element-wise ops + a (block_d x st) @ (st)
contraction folded into an elementwise-multiply-reduce).

Validated in interpret mode against repro.kernels.ref.ref_selective_scan.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, hout_ref,
                 h_ref, *, block_t: int, num_t: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = a_ref[...].astype(jnp.float32)             # (bd, st)
    D = d_ref[...].astype(jnp.float32)             # (1, bd)

    def step(t, h):
        xt = x_ref[0, t, :].astype(jnp.float32)    # (bd,)
        dtt = dt_ref[0, t, :].astype(jnp.float32)  # (bd,)
        bt = b_ref[0, t, :].astype(jnp.float32)    # (st,)
        ct = c_ref[0, t, :].astype(jnp.float32)    # (st,)
        da = jnp.exp(dtt[:, None] * A)             # (bd, st)
        h = da * h + (dtt * xt)[:, None] * bt[None, :]
        y = jnp.sum(h * ct[None, :], axis=-1) + xt * D[0]
        y_ref[0, t, :] = y.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_t, step, h_ref[...])
    h_ref[...] = h

    @pl.when(ti == num_t - 1)
    def _final():
        hout_ref[0] = h


def selective_scan_fwd(x, dt, A, Bc, Cc, D, *, block_d: int = 256,
                       block_t: int = 128, interpret: bool = True
                       ) -> Tuple[jax.Array, jax.Array]:
    """x, dt: (B,S,di); Bc,Cc: (B,S,st); A: (di,st); D: (di,).
    Returns (y: (B,S,di), h_final: (B,di,st) f32)."""
    B, S, di = x.shape
    st = A.shape[-1]
    bd = min(block_d, di)
    while di % bd:
        bd //= 2
    bt = min(block_t, S)
    while S % bt:
        bt //= 2
    nd, nt = di // bd, S // bt

    kernel = functools.partial(_scan_kernel, block_t=bt, num_t=nt)
    d2 = D.reshape(1, di)
    y, h = pl.pallas_call(
        kernel,
        grid=(B, nd, nt),
        in_specs=[
            pl.BlockSpec((1, bt, bd), lambda b, d, t: (b, t, d)),   # x
            pl.BlockSpec((1, bt, bd), lambda b, d, t: (b, t, d)),   # dt
            pl.BlockSpec((bd, st), lambda b, d, t: (d, 0)),         # A
            pl.BlockSpec((1, bt, st), lambda b, d, t: (b, t, 0)),   # B
            pl.BlockSpec((1, bt, st), lambda b, d, t: (b, t, 0)),   # C
            pl.BlockSpec((1, bd), lambda b, d, t: (0, d)),          # D
        ],
        out_specs=[
            pl.BlockSpec((1, bt, bd), lambda b, d, t: (b, t, d)),   # y
            pl.BlockSpec((1, bd, st), lambda b, d, t: (b, d, 0)),   # h_final
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, di), x.dtype),
            jax.ShapeDtypeStruct((B, di, st), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, st), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bc, Cc, d2)
    return y, h
