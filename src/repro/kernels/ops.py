"""Jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels execute in interpret mode (the kernel
body runs as traced jnp on CPU); on a real TPU set REPRO_PALLAS_COMPILE=1
to compile them natively.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.fused_adam import fused_adam
from repro.kernels.selective_scan import selective_scan_fwd


def _interpret() -> bool:
    if os.environ.get("REPRO_PALLAS_COMPILE"):
        return False
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal",))
def flash_attention_op(q, k, v, *, causal: bool = True):
    return flash_attention_fwd(q, k, v, causal=causal,
                               interpret=_interpret())


@jax.jit
def selective_scan_op(x, dt, A, Bc, Cc, D):
    return selective_scan_fwd(x, dt, A, Bc, Cc, D, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("lo", "hi", "lr"))
def fused_adam_op(p, m, v, g, step, *, lo: int = 0, hi: int = -1,
                  lr: float = 1e-3):
    return fused_adam(p, m, v, g, step, lo=lo, hi=hi, lr=lr,
                      interpret=_interpret())
