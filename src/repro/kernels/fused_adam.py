"""Pallas fused-Adam kernel — the optimizer-step hot spot GreedySnake
offloads to the CPU (cpu_adam). On a TPU host-offload design the same
fused update runs as a single element-wise kernel over (8,128)-tiled
f32 vectors: one pass reads (p, m, v, g) and writes (p', m', v', lowp')
— 16 bytes in / 14 out per element, exactly the stream the paper's SSD
bandwidth bound models.

Supports the α-partial update (§4.4) via [lo, hi) masking on the global
element index, so the early/late fractions are single kernel launches.

Validated in interpret mode against repro.kernels.ref.ref_adam.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANES = 128
_SUBLANES = 8
_TILE = _LANES * _SUBLANES


def _adam_kernel(p_ref, m_ref, v_ref, g_ref, step_ref, lim_ref,
                 p_out, m_out, v_out, lp_out, *,
                 lr: float, b1: float, b2: float, eps: float, wd: float,
                 block: int):
    i = pl.program_id(0)
    p = p_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    t = step_ref[0, 0].astype(jnp.float32)
    lo = lim_ref[0, 0]
    hi = lim_ref[0, 1]

    m2 = b1 * m + (1 - b1) * g
    v2 = b2 * v + (1 - b2) * g * g
    mhat = m2 / (1 - b1 ** t)
    vhat = v2 / (1 - b2 ** t)
    p2 = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)

    # α-partial masking on the global flat index
    rows = jax.lax.broadcasted_iota(jnp.int32, p.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, p.shape, 1)
    idx = i * block + rows * _LANES + cols
    sel = (idx >= lo) & (idx < hi)
    p_out[...] = jnp.where(sel, p2, p).astype(p_out.dtype)
    m_out[...] = jnp.where(sel, m2, m).astype(m_out.dtype)
    v_out[...] = jnp.where(sel, v2, v).astype(v_out.dtype)
    lp_out[...] = jnp.where(sel, p2, p).astype(lp_out.dtype)


def fused_adam(p, m, v, g, step, *, lo: int = 0, hi: int = -1,
               lr: float = 1e-3, b1: float = 0.9, b2: float = 0.95,
               eps: float = 1e-8, wd: float = 0.0,
               lowp_dtype=jnp.bfloat16, block_rows: int = 64,
               interpret: bool = True
               ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Flat f32 vectors p, m, v, g of length n. Updates elements [lo, hi)
    (hi=-1 => n), returning (p', m', v', lowp'). Padding to (8,128) tiles
    is handled here."""
    n = p.size
    hi = n if hi < 0 else hi
    block = block_rows * _LANES
    pad = (-n) % block
    npad = n + pad

    def prep(x):
        return jnp.pad(x.reshape(-1), (0, pad)).reshape(npad // _LANES, _LANES)

    rows_per_block = block // _LANES
    grid = (npad // block,)
    step_arr = jnp.asarray(step, jnp.int32).reshape(1, 1)
    lim = jnp.asarray([lo, hi], jnp.int32).reshape(1, 2)

    kernel = functools.partial(_adam_kernel, lr=lr, b1=b1, b2=b2, eps=eps,
                               wd=wd, block=block)
    vec_spec = pl.BlockSpec((rows_per_block, _LANES), lambda i: (i, 0))
    scal_spec = pl.BlockSpec(lambda i: (0, 0))
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[vec_spec] * 4 + [
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
        ],
        out_specs=[vec_spec] * 4,
        out_shape=[
            jax.ShapeDtypeStruct((npad // _LANES, _LANES), jnp.float32),
            jax.ShapeDtypeStruct((npad // _LANES, _LANES), jnp.float32),
            jax.ShapeDtypeStruct((npad // _LANES, _LANES), jnp.float32),
            jax.ShapeDtypeStruct((npad // _LANES, _LANES), lowp_dtype),
        ],
        interpret=interpret,
    )(prep(p), prep(m), prep(v), prep(g), step_arr, lim)
    return tuple(o.reshape(-1)[:n] for o in outs)
