"""Serve-step plan compiler + KV plan lint.

One engine step = one plan in the schedule IR (``schedule="serve"``),
so the step's byte movement is priced by the SAME ``plan_traffic``
abstract interpreter that prices training plans, and the lookahead
pass (``insert_prefetch``) derives the hints. Op order within a step:

1. ``SPILL_KV(l=unit, m=rid)`` — evictions (finished/preempted), all
   units of each evicted request;
2. ``FETCH_KV(l=unit, m=rid)`` — resumes, all units of each resumed
   request (bitwise restore from the tiers);
3. ``FETCH_PARAM(l=unit)`` — the per-unit tiered param fetches the
   step's compute consumes (dropped after use, like training);
4. per new request: ``PHASE(tag="prefill", m=rid)`` then one
   ``APPEND_KV(l=unit, m=rid)`` per unit;
5. per running request: ``PHASE(tag="decode", m=rid)`` then one
   ``APPEND_KV(l=unit, m=rid)`` per unit.

Evictions compile FIRST so a ``PREFETCH_KV`` hint can never be hoisted
above the ``SPILL_KV`` whose blocks it would read — ``insert_prefetch``
additionally treats every ``SPILL_KV`` as a hint barrier (the lint
below is the meta-test for both properties).
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

from repro.core.plan import Op, Plan, PlanOp, PlanSpec, insert_prefetch


def compile_serve_step(n_units: int, *,
                       evict: Sequence[int] = (),
                       resume: Sequence[int] = (),
                       prefill: Sequence[int] = (),
                       decode: Sequence[int] = (),
                       prefetch_depth: int = 1) -> Plan:
    """Compile one continuous-batching step (see module docstring).

    ``evict``/``resume``/``prefill``/``decode`` are request-id lists;
    ``n_units`` is the model's cache-unit count. ``prefetch_depth``
    runs the unified lookahead pass (0 = hints off — bytes identical,
    every fetch synchronous)."""
    ops: List[PlanOp] = []
    for rid in evict:
        for u in range(n_units):
            ops.append(PlanOp(Op.SPILL_KV, l=u, m=rid))
    for rid in resume:
        for u in range(n_units):
            ops.append(PlanOp(Op.FETCH_KV, l=u, m=rid))
    for u in range(n_units):
        ops.append(PlanOp(Op.FETCH_PARAM, l=u))
    for rid in prefill:
        ops.append(PlanOp(Op.PHASE, m=rid, tag="prefill"))
        for u in range(n_units):
            ops.append(PlanOp(Op.APPEND_KV, l=u, m=rid))
    for rid in decode:
        ops.append(PlanOp(Op.PHASE, m=rid, tag="decode"))
        for u in range(n_units):
            ops.append(PlanOp(Op.APPEND_KV, l=u, m=rid))
    plan = Plan(schedule="serve", spec=PlanSpec(L=n_units, M=1), W=1,
                ops=tuple(ops))
    return insert_prefetch(plan, prefetch_depth)


def lint_kv_plan(plan: Plan) -> List[str]:
    """KV-stream hint lint: returns a list of violations (empty = ok).

    Checked invariants (the serve analogue of the training hint
    contract):

    * every ``FETCH_KV`` has EXACTLY one ``PREFETCH_KV`` hint with its
      ``(l, m)`` key, placed before it — when the plan is hinted at
      all (a ``prefetch_depth=0`` plan legally has zero hints);
    * no hint is orphaned (a ``PREFETCH_KV`` without a later matching
      ``FETCH_KV`` would leak a queued read);
    * no hint crosses a request eviction: between a hint and its fetch
      there is no ``SPILL_KV`` (any key — an eviction makes the tiers
      the source of truth, so a read started earlier could race the
      spill's write).
    """
    errs: List[str] = []
    hints: dict = {}
    fetches: dict = {}
    spill_idx: List[int] = []
    for i, op in enumerate(plan.ops):
        key = (op.l, op.m)
        if op.op is Op.PREFETCH_KV:
            hints.setdefault(key, []).append(i)
        elif op.op is Op.FETCH_KV:
            fetches.setdefault(key, []).append(i)
        elif op.op is Op.SPILL_KV:
            spill_idx.append(i)
    hinted = bool(hints)
    for key, fs in fetches.items():
        hs = hints.pop(key, [])
        if hinted and len(hs) != len(fs):
            errs.append(f"FETCH_KV{key}: {len(fs)} fetch(es) but "
                        f"{len(hs)} hint(s)")
            continue
        for h, f in zip(hs, fs):
            if h >= f:
                errs.append(f"PREFETCH_KV{key} at {h} not before its "
                            f"FETCH_KV at {f}")
            crossed = [s for s in spill_idx if h < s < f]
            if crossed:
                errs.append(f"PREFETCH_KV{key} at {h} crosses "
                            f"SPILL_KV at {crossed} before its fetch "
                            f"at {f}")
    for key, hs in hints.items():
        errs.append(f"orphan PREFETCH_KV{key} at {hs} (no FETCH_KV)")
    return errs


def serve_phase_requests(plan: Plan) -> List[Tuple[str, int]]:
    """The step's compute order: ``(phase_tag, rid)`` per PHASE op."""
    return [(op.tag, op.m) for op in plan.ops if op.op is Op.PHASE]
