"""Serving as a first-class workload on the GreedySnake substrate.

The training stack's core bet — every tensor movement is schedulable
I/O under a plan an abstract interpreter can price exactly — cashed in
for inference. Three layers, all reusing proven machinery:

**Block tables.** Each request's KV cache is addressed per CACHE UNIT
(``models.model.cache_units``: one unit per prefix block / scanned
period sub-block / suffix block — one per layer for a plain dense
stack). A unit's payload is padded to fixed-size blocks
(``core.traffic.kv_blocks`` — the ONE ceil the coordinator, the plan
interpreter, and the closed form all share). Hot blocks are
device-resident (the request's live cache pytree); on eviction the
``round(kv_x_host * blocks)`` head blocks go warm to host DRAM and the
cold tail to SSD — a TieredVector-style split at block granularity,
streamed through ``repro.io`` at ``IOPriority.KV`` (above ckpt spills:
a late fetch is user-visible decode latency; below the training
critical path) with PR-8 backlog-aware path placement for free.

**Tier lifecycle.** Every step compiles a plan in the schedule IR
(``schedule="serve"``): ``SPILL_KV`` evictions first (all of a unit's
blocks off device, cold tail written async), then ``FETCH_KV`` resumes
(bitwise restore — true payload length is tracked so block padding
never leaks into the rebuilt pytree), per-unit ``FETCH_PARAM`` ops
through the SAME tiered-param + lookahead machinery training uses
(``insert_prefetch`` places one ``PREFETCH_KV``/``PREFETCH`` hint per
fetch; KV hints never cross a ``SPILL_KV`` — an eviction is the
barrier that makes the tiers the source of truth), then ``PHASE`` ops
tagged ``prefill``/``decode`` carrying the request id, with
``APPEND_KV`` occupancy marks (device-HBM block-table writes — zero
offload bytes). ``plan_traffic`` prices the plan exactly; the
three-way invariant (plan == ``traffic.kv_traffic`` == measured
meters) is pinned the same way training streams are.

**Admission control.** ``ServeEngine.submit`` refuses any request
whose block footprint alone exceeds the KV byte budget
(``ValueError``, eager); admitted requests wait FIFO until enough
blocks are free. ``step()`` runs iteration-level continuous batching:
evict (finished/preempted -> tiers), admit (new -> prefill, evicted ->
resume), decode one token per running request. ``preempt``/resume
round-trips are bitwise — decode logits after a resume equal the
never-evicted run exactly (f32).
"""
from repro.serve.engine import Request, ServeConfig, ServeEngine  # noqa: F401
from repro.serve.plan import compile_serve_step, lint_kv_plan  # noqa: F401
