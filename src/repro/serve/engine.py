"""ServeEngine: continuous batching over tiered KV blocks + params.

See ``repro.serve.__init__`` for the design header. The engine owns
the same storage stack an ``OffloadEngine`` does — ``TrafficMeter``,
``IOEngine`` (with the PR-8 path placement policies), ``SSDStore``,
``HostStore``, ``Tracer`` — plus two coordinators:

* a :class:`~repro.offload.coordinators.KVBlockCoordinator` for the
  request KV-block stream (``IOPriority.KV``);
* a param coordinator over per-unit uint8 TieredVector blobs (the
  ``param_x_host`` byte split), reusing the training lookahead
  machinery: ``PREFETCH`` hints start the SSD->host stage early, the
  host->device copy happens at consumption.

Byte exactness: every step executes exactly the ops its compiled plan
lists, the coordinators meter exactly what ``plan_traffic`` prices,
and the engine accumulates the per-step predictions
(``predicted_traffic``) plus per-unit spill/fetch event counts
(``kv_events`` — the input to the ``traffic.kv_traffic`` closed form)
so all three sides of the invariant are available from one object.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import Op, Plan, PlanCosts, plan_traffic
from repro.core.traffic import kv_blocks
from repro.io import IOConfig, IOEngine
from repro.models import model as mdl
from repro.obs.tracer import Tracer
from repro.offload.coordinators import (KVBlockCoordinator,
                                        ParameterCoordinator, _xfer)
from repro.offload.stores import (HostStore, SSDStore, TieredVector,
                                  TrafficMeter)
from repro.serve.plan import compile_serve_step


@dataclasses.dataclass
class ServeConfig:
    """Knobs of the serving engine. Validation is EAGER
    (``__post_init__``, same ``ValueError`` contract as
    ``OffloadConfig``/``IOConfig``): a typo fails where it was
    written."""
    max_len: int = 64               # engine-wide cache length (every
                                    # request's prompt+gen must fit)
    kv_block_bytes: int = 4096      # fixed KV block size (padding unit)
    kv_budget_bytes: int = 1 << 30  # device KV budget -> admission
                                    # capacity in whole blocks
    kv_x_host: float = 0.5          # warm (host) fraction of evicted
                                    # KV blocks; rest go cold to SSD
    param_x_host: float = 0.5       # host byte fraction of each unit's
                                    # tiered param blob
    prefetch_depth: int = 1         # unified lookahead depth (0 = off)
    io: Optional[IOConfig] = None   # paths/pacing/placement (None:
                                    # single path = the workdir)
    param_dtype: str = "float32"    # f32 => bitwise vs in-memory ref
    trace: bool = False             # repro.obs span tracer on
    record_logits: bool = False     # keep every step's f32 logits on
                                    # each Request (bitwise-parity
                                    # tests; off for real serving)

    MAX_PREFETCH_DEPTH = 16

    def __post_init__(self):
        if self.kv_block_bytes <= 0:
            raise ValueError(
                f"kv_block_bytes={self.kv_block_bytes} must be > 0")
        if self.kv_budget_bytes <= 0:
            raise ValueError(
                f"kv_budget_bytes={self.kv_budget_bytes} must be > 0")
        for nm in ("kv_x_host", "param_x_host"):
            v = float(getattr(self, nm))
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{nm}={v} must be in [0, 1]")
        d = int(self.prefetch_depth)
        if not 0 <= d <= self.MAX_PREFETCH_DEPTH:
            raise ValueError(
                f"prefetch_depth={self.prefetch_depth} is outside "
                f"[0, {self.MAX_PREFETCH_DEPTH}]")
        if self.max_len < 2:
            raise ValueError(f"max_len={self.max_len} must be >= 2")


# request lifecycle states
WAITING, RUNNING, EVICTED, FINISHED = \
    "waiting", "running", "evicted", "finished"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    blocks: int                     # total KV blocks (all units)
    state: str = WAITING
    generated: List[int] = dataclasses.field(default_factory=list)
    caches: Any = None              # device cache pytree while RUNNING
    evictions: int = 0              # times this request was preempted
    logits: List[np.ndarray] = dataclasses.field(default_factory=list)

    @property
    def pos(self) -> int:
        """Position of the NEXT token to decode."""
        return len(self.prompt) + len(self.generated) - 1

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


def _flatten_unit(tree) -> Tuple[np.ndarray, object, list]:
    """One unit's pytree as (uint8 blob, treedef, leaf metas) — the
    true shape is recorded BEFORE ascontiguousarray (which promotes 0-d
    scalars to (1,))."""
    leaves, treedef = jax.tree.flatten(tree)
    metas, chunks = [], []
    for leaf in leaves:
        arr = np.asarray(leaf)
        metas.append((arr.dtype, arr.shape))
        chunks.append(np.ascontiguousarray(arr).reshape(-1).view(np.uint8))
    buf = np.concatenate(chunks) if chunks else np.zeros(0, np.uint8)
    return buf, treedef, metas


def _unflatten_unit(buf: np.ndarray, treedef, metas):
    leaves, off = [], 0
    for dt, shp in metas:
        nb = int(np.prod(shp)) * dt.itemsize
        leaves.append(jnp.asarray(
            np.frombuffer(buf[off:off + nb].tobytes(), dtype=dt)
            .reshape(shp)))
        off += nb
    return jax.tree.unflatten(treedef, leaves)


class _HostBlobParamCoordinator(ParameterCoordinator):
    """ParameterCoordinator whose ``get`` returns the HOST byte blob:
    the serve engine rebuilds the unit's param pytree leaf-wise, so the
    host->device copy happens per leaf at consumption (same bytes, same
    meter line as the base class)."""

    def get(self, l: int) -> np.ndarray:
        from repro.offload.coordinators import _hint_settle
        if l not in self._futures:
            self.prefetch(l, consumer=True)
            self.la_misses += 1
        elif self._futures[l].done():
            self.la_hits += 1
            _hint_settle(self, "param", l, "hit")
        else:
            self.la_misses += 1
            _hint_settle(self, "param", l, "late")
        host_arr = self._futures.pop(l).result()
        _xfer(self.meter, self.engine, "param", "cpu->gpu",
              host_arr.nbytes)
        return host_arr


class ServeEngine:
    """Continuous-batching inference over the tiered storage stack.

    ``submit()`` enqueues a request (eager budget refusal), ``step()``
    runs one compiled serve plan (evict -> resume -> param fetch ->
    prefill/decode), ``preempt()`` flags a running request for
    spill-to-tiers at the next step (resume is bitwise). Construction
    mirrors ``repro.offload.make_engine``: model config, serve config,
    PRNG key, SSD workdir.
    """

    def __init__(self, cfg, scfg: ServeConfig, key, workdir: str):
        assert cfg.family == "dense", \
            f"ServeEngine supports dense stacks (got {cfg.family!r})"
        self.cfg = cfg
        self.scfg = scfg
        self.dtype = jnp.dtype(scfg.param_dtype)
        self.meter = TrafficMeter()
        self.tracer = Tracer()
        if scfg.trace:
            self.tracer.enable()
        iocfg = scfg.io if scfg.io is not None else IOConfig(paths=[workdir])
        self.ioe = IOEngine(iocfg, meter=self.meter, default_root=workdir,
                            tracer=self.tracer)
        self.ssd = SSDStore(workdir, self.meter, engine=self.ioe)
        self.host = HostStore(self.meter)

        # ---- model: cache-unit layout + per-unit tiered params ----
        self.units = mdl.cache_units(cfg)
        self.n_units = len(self.units)
        params = mdl.init_params(cfg, key, dtype=self.dtype)
        template = mdl.init_caches(cfg, 1, scfg.max_len, dtype=self.dtype)
        self.kv_unit_nbytes = tuple(mdl.cache_unit_nbytes(cfg, template))
        self.blocks_per_unit = [kv_blocks(nb, scfg.kv_block_bytes)
                                for nb in self.kv_unit_nbytes]
        self.blocks_per_request = sum(self.blocks_per_unit)
        self.capacity_blocks = scfg.kv_budget_bytes // scfg.kv_block_bytes

        self._p_meta: List[Tuple[object, list]] = []
        vecs = []
        unit_nb = []
        for u, unit in enumerate(self.units):
            buf, treedef, metas = _flatten_unit(
                mdl.get_cache_unit(params, unit))
            self._p_meta.append((treedef, metas))
            unit_nb.append(buf.size)
            v = TieredVector(f"punit:{u}", buf.size, np.uint8,
                             scfg.param_x_host, self.host, self.ssd,
                             "param")
            v.write_full(buf)       # initial population: unmetered
            vecs.append(v)
        self.param_unit_nbytes = tuple(unit_nb)
        # the resident skeleton holds everything OUTSIDE the tiered
        # units (embed/norm/unembed); unit slots are zeroed so a missed
        # fetch produces visibly wrong logits, not silently stale ones
        resident = params
        for unit in self.units:
            zero = jax.tree.map(jnp.zeros_like,
                                mdl.get_cache_unit(params, unit))
            resident = mdl.set_cache_unit(resident, unit, zero)
        self._resident = resident

        self.p_coord = _HostBlobParamCoordinator(
            vecs, self.meter, self.ioe, dtype=np.uint8)
        self.kv_coord = KVBlockCoordinator(
            scfg.kv_block_bytes, scfg.kv_x_host, self.host, self.ssd,
            self.meter, self.ioe)
        self.p_coord.tracer = self.tracer
        self.kv_coord.tracer = self.tracer

        # ---- jitted compute (whole model, B=1, shared max_len) ----
        self._prefill_fn = jax.jit(
            lambda p, b, c: mdl.prefill(p, cfg, b, c))
        self._decode_fn = jax.jit(
            lambda p, t, pos, c: mdl.decode_step(p, cfg, t, pos, c))

        # ---- scheduler state ----
        self._next_rid = 0
        self.requests: Dict[int, Request] = {}
        self._waiting: deque = deque()      # rids awaiting admission
        self._evict_next: List[int] = []    # rids to SPILL_KV next step
        self._drop_next: List[int] = []     # finished rids: spill+free
        self.used_blocks = 0

        # ---- counters / invariant bookkeeping ----
        self.step_num = 0
        self.tokens_decoded = 0
        self.admitted = self.preempted = self.resumed = 0
        self.finished = self.appends = 0
        self.phase_time: Dict[str, float] = defaultdict(float)
        self.predicted_traffic: Dict[Tuple[str, str], int] = defaultdict(int)
        #: per-unit (spill_count, fetch_count) — ``traffic.kv_traffic``
        #: closed-form inputs
        self.kv_spills = [0] * self.n_units
        self.kv_fetches = [0] * self.n_units
        self._plan: Optional[Plan] = None
        self._closed = False

    # ------------------------------------------------------------------
    # request API
    # ------------------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int) -> int:
        """Enqueue a request. Eager admission checks: a request whose
        block footprint alone exceeds the KV budget, or whose
        prompt+gen exceeds ``max_len``, is REFUSED with ValueError."""
        prompt = [int(t) for t in prompt]
        if not prompt or max_new_tokens < 1:
            raise ValueError("need a non-empty prompt and "
                             "max_new_tokens >= 1")
        if len(prompt) + max_new_tokens > self.scfg.max_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens "
                f"({max_new_tokens}) exceeds max_len={self.scfg.max_len}")
        if self.blocks_per_request > self.capacity_blocks:
            raise ValueError(
                f"request needs {self.blocks_per_request} KV blocks but "
                f"the budget ({self.scfg.kv_budget_bytes} B) only holds "
                f"{self.capacity_blocks}")
        rid = self._next_rid
        self._next_rid += 1
        self.requests[rid] = Request(rid, prompt, int(max_new_tokens),
                                     self.blocks_per_request)
        self._waiting.append(rid)
        return rid

    def preempt(self, rid: int):
        """Flag a RUNNING request for eviction at the next step: its KV
        blocks spill to the tiers (warm head to host, cold tail to SSD)
        and it re-queues for a bitwise resume."""
        req = self.requests[rid]
        if req.state != RUNNING or rid in self._drop_next:
            raise ValueError(f"request {rid} is not running "
                             f"(state={req.state!r})")
        if rid not in self._evict_next:
            self._evict_next.append(rid)

    def pending(self) -> bool:
        """Any work left (waiting, running, or evictions due)?"""
        return bool(self._waiting or self._evict_next or self._drop_next
                    or any(r.state == RUNNING for r in
                           self.requests.values()))

    def result(self, rid: int) -> List[int]:
        return list(self.requests[rid].generated)

    # ------------------------------------------------------------------
    # the step
    # ------------------------------------------------------------------
    def step(self) -> Dict[str, list]:
        """One continuous-batching iteration; returns the step's
        scheduling decisions (rid lists)."""
        if not self.pending():
            return {"evicted": [], "admitted": [], "resumed": [],
                    "decoded": []}
        # 1. decide: evictions (preempted + finished), then admission
        evict = list(self._evict_next) + list(self._drop_next)
        for rid in self._evict_next:
            self.used_blocks -= self.requests[rid].blocks
            self.requests[rid].state = EVICTED
            self.requests[rid].evictions += 1
            self.preempted += 1
            self._waiting.append(rid)
        for rid in self._drop_next:
            self.used_blocks -= self.requests[rid].blocks
            self.requests[rid].state = FINISHED
            self.finished += 1
        self._evict_next, self._drop_next = [], []

        prefill_r, resume_r = [], []
        while self._waiting:
            req = self.requests[self._waiting[0]]
            if self.used_blocks + req.blocks > self.capacity_blocks:
                break
            self._waiting.popleft()
            self.used_blocks += req.blocks
            self.admitted += 1
            (resume_r if req.state is EVICTED else prefill_r).append(req.rid)
            req.state = RUNNING
        decode_r = [r.rid for r in self.requests.values()
                    if r.state == RUNNING and r.generated
                    and not r.done and r.rid not in prefill_r]

        # 2. compile + price the step's plan
        plan = compile_serve_step(
            self.n_units, evict=evict, resume=resume_r,
            prefill=prefill_r, decode=decode_r,
            prefetch_depth=self.scfg.prefetch_depth)
        self._plan = plan
        for (cat, route), nb in plan_traffic(plan, self.plan_costs()).items():
            self.predicted_traffic[(cat, route)] += nb

        # 3. execute the ops in plan order
        evict_caches = {rid: self.requests[rid].caches for rid in evict}
        restored: Dict[int, Any] = {}
        for op in plan.ops:
            if op.op is Op.SPILL_KV:
                req = self.requests[op.m]
                self.kv_coord.put(op.m, op.l, mdl.get_cache_unit(
                    evict_caches[op.m], self.units[op.l]))
                self.kv_spills[op.l] += 1
                req.caches = None
            elif op.op is Op.PREFETCH_KV:
                self.kv_coord.prefetch(op.m, op.l)
            elif op.op is Op.FETCH_KV:
                unit_val = self.kv_coord.get(op.m, op.l)
                self.kv_fetches[op.l] += 1
                base = restored.get(op.m)
                if base is None:
                    base = mdl.init_caches(self.cfg, 1, self.scfg.max_len,
                                           dtype=self.dtype)
                restored[op.m] = mdl.set_cache_unit(
                    base, self.units[op.l], unit_val)
            elif op.op is Op.PREFETCH:
                self.p_coord.prefetch(op.l)
            elif op.op is Op.FETCH_PARAM:
                blob = self.p_coord.get(op.l)
                treedef, metas = self._p_meta[op.l]
                self._resident = mdl.set_cache_unit(
                    self._resident, self.units[op.l],
                    _unflatten_unit(blob, treedef, metas))
            elif op.op is Op.APPEND_KV:
                self.appends += 1            # block-table write: 0 bytes
            elif op.op is Op.PHASE:
                self._run_phase(op.tag, op.m, restored)
        # drop the fetched unit params (consumed; next step re-fetches)
        for unit in self.units:
            zero = jax.tree.map(jnp.zeros_like,
                                mdl.get_cache_unit(self._resident, unit))
            self._resident = mdl.set_cache_unit(self._resident, unit, zero)
        self.step_num += 1
        return {"evicted": evict, "admitted": prefill_r,
                "resumed": resume_r, "decoded": decode_r}

    def _run_phase(self, tag: str, rid: int, restored: Dict[int, Any]):
        req = self.requests[rid]
        t0 = time.perf_counter()
        if tag == "prefill":
            caches = mdl.init_caches(self.cfg, 1, self.scfg.max_len,
                                     dtype=self.dtype)
            batch = {"tokens": jnp.asarray([req.prompt], jnp.int32)}
            logits, caches = self._prefill_fn(self._resident, batch, caches)
        else:
            caches = restored.pop(rid, None) or req.caches
            tok = jnp.asarray([[req.generated[-1]]], jnp.int32)
            logits, caches = self._decode_fn(
                self._resident, tok, jnp.int32(req.pos), caches)
            self.tokens_decoded += 1
        req.caches = caches
        if self.scfg.record_logits:
            req.logits.append(np.asarray(logits))
        req.generated.append(int(jnp.argmax(logits[0])))
        if req.done:
            self._drop_next.append(rid)
        self.phase_time[tag] += time.perf_counter() - t0

    # ------------------------------------------------------------------
    # metrics / pricing
    # ------------------------------------------------------------------
    def plan_costs(self) -> PlanCosts:
        """The serve-side ``PlanCosts`` (KV + per-unit param pricing)."""
        return PlanCosts(
            P=0, param_itemsize=1, ckpt_elems=0, act_itemsize=1,
            kv_block_bytes=self.scfg.kv_block_bytes,
            kv_x_host=self.scfg.kv_x_host,
            kv_unit_nbytes=self.kv_unit_nbytes,
            param_unit_nbytes=self.param_unit_nbytes,
            param_x_host=self.scfg.param_x_host)

    @property
    def plan(self) -> Optional[Plan]:
        """The last executed step's compiled plan (lint target)."""
        return self._plan

    def _lookahead_stats(self) -> Dict[str, object]:
        return {"param": {"hits": self.p_coord.la_hits,
                          "misses": self.p_coord.la_misses},
                "kv": {"hits": self.kv_coord.la_hits,
                       "misses": self.kv_coord.la_misses}}

    def metrics_snapshot(self) -> Dict[str, object]:
        """The versioned serve metrics snapshot; see
        :func:`repro.obs.build_serve_snapshot`."""
        from repro.obs import build_serve_snapshot
        return build_serve_snapshot(self)

    def close(self):
        if self._closed:
            return
        self._closed = True
        self.p_coord.reset()
        self.kv_coord.wait_pending()
        self.ssd.close()
        self.ioe.shutdown(wait=True)
