"""Chunked, striped flat-tensor files across N SSD paths, with a
per-tensor chunk-location table so chunk->path assignment is a
scheduled decision, not a layout constant.

Baseline layout (MLP-Offload-style round robin): a tensor of
``nbytes`` is cut into chunks of ``chunk_bytes``; chunk ``i`` DEFAULTS
to path ``i % P`` at slot ``i // P`` of that path's stripe file
(``<path>/<name>.s<p>.bin``, file offset = slot * chunk_bytes). Under
``path_policy="static"`` that default is the whole story — the layout
is bit-for-bit the classic static striping and no placement state is
ever created.

Under the dynamic policies ("weighted"/"backlog") every FULL-chunk
write asks :meth:`IOEngine.choose_path` where the chunk should land
*now* (rate-weighted / least-backlogged path) and records the decision
in the tensor's chunk-location table: ``chunk -> (path, slot)``. Reads
and partial writes follow the recorded map, falling back to the static
default for chunks never dynamically placed — so a tensor written
under "static" stays readable after a policy flip and vice versa.
Slots for re-placed chunks come from a per-(tensor, path) allocation
cursor that starts past the stripe file's current end and only ever
moves forward, and a claims map tracks slot ownership so a dynamic
allocation can never collide with a chunk still on its static slot.
Slots vacated by a re-placement are deliberately NEVER reused: an op
targeting the old slot may still be in flight (chunk ops from
overlapping writes of one tensor interleave on the path channels), so
handing the slot to another chunk would let that stale op corrupt the
new tenant after the fact. Orphaning the slot instead means a stale op
can only ever touch bytes its own chunk used to own — the worst case
degrades to the same-offset version race static striping always had,
at the cost of stripe-file growth when placement flips a chunk between
paths. Only full-chunk writes re-place: a short last chunk or a ranged
partial write sticks to wherever the chunk already lives (moving it
would require a read-modify-write of bytes the caller didn't provide).

The table is persisted as a JSON sidecar next to the first path's
stripe file (``<paths[0]>/<name>.map.json``, written atomically via
temp + rename after the chunk writes it describes have completed) and
lazily reloaded on reopen, so placement survives process restarts.
Static-only runs produce zero sidecars.

All byte movement is positioned I/O (``pread``/``pwritev`` on cached
fds), submitted as one chunk op per chunk on the owning path's channel
— so a P-path store keeps P threads busy in parallel, and a
higher-priority tensor's chunks overtake a lower-priority one's in
each channel's heap. Bandwidth pacing applies per chunk before the
syscall: the route cap (``cpu->ssd`` / ``ssd->cpu``) and the owning
path's device cap (``IOConfig.path_bandwidth``) both, when configured.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.io.engine import IOEngine, IOPriority


def _mangle(name: str) -> str:
    return name.replace("/", "_")


class StripedFiles:
    def __init__(self, engine: IOEngine):
        self.engine = engine
        self.paths = engine.paths
        self.chunk = int(engine.chunk_bytes)
        self._fds: Dict[Tuple[str, int], int] = {}
        self._fd_lock = threading.Lock()
        # placement state, all guarded by _map_lock:
        #   _tables[name]: chunk -> (path, slot) overrides (absent chunk
        #       = static default); _claims[name]: (path, slot) -> chunk
        #       for every slot a write has claimed THIS process — the
        #       collision guard between static-default slots and
        #       allocated ones; _cursors[name][p]: next never-used slot
        #       (init lazily from stripe file size + live claims).
        #       Slots vacated by re-placement are orphaned, never
        #       recycled (see the module docstring).
        self._map_lock = threading.Lock()
        self._tables: Dict[str, Dict[int, Tuple[int, int]]] = {}
        self._claims: Dict[str, Dict[Tuple[int, int], int]] = {}
        self._cursors: Dict[str, List[Optional[int]]] = {}
        self._map_checked: Set[str] = set()

    # ---------------- fd cache ----------------
    def _fd(self, name: str, p: int) -> int:
        key = (name, p)
        with self._fd_lock:
            fd = self._fds.get(key)
            if fd is None:
                path = os.path.join(self.paths[p],
                                    _mangle(name) + f".s{p}.bin")
                fd = os.open(path, os.O_RDWR | os.O_CREAT, 0o644)
                self._fds[key] = fd
            return fd

    # ---------------- raw chunk ops ----------------
    # The single choke point every chunk's bytes pass through. Kept as
    # overridable methods so a harness can inject faults (short reads,
    # EIO, stalls) under the full engine stack — the fault-injection
    # test battery subclasses StripedFiles and flips these.
    def _pwrite(self, fd: int, mv: memoryview, off: int) -> None:
        os.pwritev(fd, [mv], off)

    def _pread(self, fd: int, mv: memoryview, off: int) -> int:
        return os.preadv(fd, [mv], off)

    # ---------------- chunk-location table ----------------
    def _map_path(self, name: str) -> str:
        return os.path.join(self.paths[0], _mangle(name) + ".map.json")

    def _table(self, name: str) -> Optional[Dict[int, Tuple[int, int]]]:
        """The tensor's placement table, lazily loading the sidecar the
        first time the tensor is touched. Caller holds _map_lock."""
        t = self._tables.get(name)
        if t is None and name not in self._map_checked:
            self._map_checked.add(name)
            try:
                with open(self._map_path(name)) as f:
                    doc = json.load(f)
            except FileNotFoundError:
                return None
            if (doc.get("chunk_bytes") != self.chunk
                    or doc.get("n_paths") != len(self.paths)):
                raise ValueError(
                    f"stale chunk map for {name!r}: written with "
                    f"chunk_bytes={doc.get('chunk_bytes')} over "
                    f"{doc.get('n_paths')} path(s), reopened with "
                    f"chunk_bytes={self.chunk} over "
                    f"{len(self.paths)} path(s)")
            t = {int(c): (int(p), int(s))
                 for c, (p, s) in doc["map"].items()}
            self._tables[name] = t
            self._claims[name] = {ps: c for c, ps in t.items()}
        return t

    def _persist(self, name: str):
        """Atomically write the sidecar (temp + rename). Called after
        the chunk writes a table mutation describes have completed, so
        a persisted slot always has its bytes on disk."""
        with self._map_lock:
            t = self._tables.get(name)
            if not t:
                return
            doc = {"chunk_bytes": self.chunk, "n_paths": len(self.paths),
                   "map": {str(c): list(ps) for c, ps in sorted(t.items())}}
        target = self._map_path(name)
        tmp = target + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f)
        os.replace(tmp, target)

    def _alloc_slot(self, name: str, p: int) -> int:
        """An unclaimed, never-dynamically-used slot on path ``p`` from
        the monotonic allocation cursor. The cursor initializes past
        the stripe file's current end (data from earlier processes /
        completed writes; in-flight slots are covered by the claims
        skip below) and never moves backward — vacated slots are never
        recycled. At EVERY allocation the cursor additionally skips
        slots claimed since it was initialized: a chunk that kept its
        static default ``(c % P, c // P)`` after the cursor passed that
        slot would otherwise be silently overwritten by the next
        dynamic placement on its path. Caller holds _map_lock."""
        cur = self._cursors.setdefault(name, [None] * len(self.paths))
        if cur[p] is None:
            fd = self._fd(name, p)
            cur[p] = (os.fstat(fd).st_size + self.chunk - 1) // self.chunk
        claims = self._claims.get(name) or {}
        slot = cur[p]
        while (p, slot) in claims:
            slot += 1
        cur[p] = slot + 1
        return slot

    def placement(self, name: str, c: int) -> Tuple[int, int]:
        """Where chunk ``c`` of tensor ``name`` lives: the recorded
        table entry, else the static default ``(c % P, c // P)``."""
        with self._map_lock:
            t = self._table(name)
            if t is not None:
                e = t.get(c)
                if e is not None:
                    return e
        P = len(self.paths)
        return c % P, c // P

    def _place_for_write(self, name: str, c: int, full: bool
                         ) -> Tuple[int, int, bool]:
        """Placement decision for one chunk about to be WRITTEN.
        Returns (path, slot, table_mutated).

        A full chunk under a dynamic policy is re-placed via
        :meth:`IOEngine.choose_path`; anything else sticks to its
        recorded/static location. Either way, a static-default slot
        already owned by a re-placed chunk forces a fresh allocation
        (the collision guard: the cursor starts from the file size, so
        a first-ever dynamic write can hand out slots the tensor's
        *later* chunks would map to statically)."""
        eng = self.engine
        P, C = len(self.paths), self.chunk
        dynamic = full and P > 1 and eng.path_policy != "static"
        new_p = eng.choose_path(C) if dynamic else None
        with self._map_lock:
            t = self._table(name)
            entry = t.get(c) if t is not None else None
            old = entry if entry is not None else (c % P, c // P)
            claims = self._claims.setdefault(name, {})
            # "ours": unclaimed, or claimed by this very chunk
            ours = claims.get(old, c) == c
            if new_p is None or (new_p == old[0] and ours):
                if ours:
                    if claims.get(old) != c:
                        # record the static claim so the allocation
                        # cursor can never hand this slot out
                        claims[old] = c
                    return old[0], old[1], False
                # static slot stolen by a re-placed chunk: convert this
                # chunk to a fresh slot on its own (static) path
                new_p = old[0]
            slot = self._alloc_slot(name, new_p)
            if t is None:
                t = self._tables[name] = {}
            t[c] = (new_p, slot)
            claims[(new_p, slot)] = c
            if ours:
                # the old slot is orphaned, never recycled: a stale op
                # from an overlapping write may still land there
                claims.pop(old, None)
            return new_p, slot, True

    # ---------------- bulk ops ----------------
    def _chunk_spans(self, byte_lo: int, byte_hi: int):
        """Yield (chunk_index, lo, hi) per chunk overlapping
        [byte_lo, byte_hi) — lo/hi are tensor-relative byte offsets."""
        C = self.chunk
        for c in range(byte_lo // C, (byte_hi + C - 1) // C):
            lo = max(byte_lo, c * C)
            hi = min(byte_hi, (c + 1) * C)
            if lo < hi:
                yield c, lo, hi

    def _positioned(self, name: str, data_u8: np.ndarray, byte_lo: int,
                    write: bool, route: str, priority: IOPriority):
        """Chunked read into / write from ``data_u8`` (a uint8 view) that
        occupies tensor bytes [byte_lo, byte_lo + data_u8.nbytes).
        One channel op per chunk, so a higher-priority transfer's chunks
        can overtake this one's mid-flight. Placement is resolved here,
        in the submitting thread (deterministic decision order), before
        the ops fan out to the path channels."""
        nbytes = data_u8.nbytes
        if nbytes == 0:
            self._fd(name, 0)        # ensure the tensor exists on disk
            return
        byte_hi = byte_lo + nbytes
        eng = self.engine
        C = self.chunk
        futs: List = []
        mutated = False
        for c, lo, hi in self._chunk_spans(byte_lo, byte_hi):
            n = hi - lo
            if write:
                p, slot, changed = self._place_for_write(name, c,
                                                         full=(n == C))
                mutated = mutated or changed
            else:
                p, slot = self.placement(name, c)
            off = slot * C + (lo - c * C)
            mv = memoryview(data_u8[lo - byte_lo:hi - byte_lo])

            def op(p=p, off=off, mv=mv, n=n):
                fd = self._fd(name, p)
                eng.throttle(route, n)
                eng.throttle_path(p, n)
                if write:
                    self._pwrite(fd, mv, off)
                else:
                    got = self._pread(fd, mv, off)
                    if got != n:
                        raise IOError(
                            f"short read on {name!r} path {p}: "
                            f"{got}/{n} bytes at offset {off}")
            futs.append(eng.submit_chunk(p, op, priority, route=route,
                                         nbytes=n))
        err = None
        for f in futs:
            try:
                f.result()
            except BaseException as e:
                err = err or e
        if mutated:
            # persist even on partial failure: the table describes where
            # the bytes were SENT, and surviving chunks did land there
            self._persist(name)
        if err is not None:
            raise err

    def write(self, name: str, data_u8: np.ndarray, byte_lo: int,
              priority: IOPriority):
        self._positioned(name, data_u8, byte_lo, write=True,
                         route="cpu->ssd", priority=priority)

    def readinto(self, name: str, out_u8: np.ndarray, byte_lo: int,
                 priority: IOPriority):
        self._positioned(name, out_u8, byte_lo, write=False,
                         route="ssd->cpu", priority=priority)

    def delete(self, name: str):
        for p in range(len(self.paths)):
            with self._fd_lock:
                fd = self._fds.pop((name, p), None)
            if fd is not None:
                os.close(fd)
            path = os.path.join(self.paths[p], _mangle(name) + f".s{p}.bin")
            try:
                os.unlink(path)
            except FileNotFoundError:
                pass
        with self._map_lock:
            self._tables.pop(name, None)
            self._claims.pop(name, None)
            self._cursors.pop(name, None)
            self._map_checked.discard(name)
        try:
            os.unlink(self._map_path(name))
        except FileNotFoundError:
            pass

    def close(self):
        with self._fd_lock:
            fds, self._fds = list(self._fds.values()), {}
        for fd in fds:
            os.close(fd)
