"""Chunked, striped flat-tensor files across N SSD paths.

Layout (MLP-Offload-style round robin): a tensor of ``nbytes`` is cut
into chunks of ``chunk_bytes``; chunk ``i`` lives on path ``i % P`` at
file offset ``(i // P) * chunk_bytes`` of that path's stripe file
(``<path>/<name>.s<p>.bin``). Only the globally-last chunk may be short,
and it is the last chunk of its stripe file, so offsets never shift.

All byte movement is positioned I/O (``pread``/``pwritev`` on cached
fds), submitted as one chunk op per chunk on the owning path's channel —
so a P-path store keeps P threads busy in parallel, and a
higher-priority tensor's chunks overtake a lower-priority one's in each
channel's heap. Bandwidth pacing (``cpu->ssd`` / ``ssd->cpu``) applies
per chunk before the syscall.
"""
from __future__ import annotations

import os
import threading
from typing import Dict, List, Tuple

import numpy as np

from repro.io.engine import IOEngine, IOPriority


def _mangle(name: str) -> str:
    return name.replace("/", "_")


class StripedFiles:
    def __init__(self, engine: IOEngine):
        self.engine = engine
        self.paths = engine.paths
        self.chunk = int(engine.chunk_bytes)
        self._fds: Dict[Tuple[str, int], int] = {}
        self._fd_lock = threading.Lock()

    # ---------------- fd cache ----------------
    def _fd(self, name: str, p: int) -> int:
        key = (name, p)
        with self._fd_lock:
            fd = self._fds.get(key)
            if fd is None:
                path = os.path.join(self.paths[p],
                                    _mangle(name) + f".s{p}.bin")
                fd = os.open(path, os.O_RDWR | os.O_CREAT, 0o644)
                self._fds[key] = fd
            return fd

    # ---------------- raw chunk ops ----------------
    # The single choke point every chunk's bytes pass through. Kept as
    # overridable methods so a harness can inject faults (short reads,
    # EIO, stalls) under the full engine stack — the fault-injection
    # test battery subclasses StripedFiles and flips these.
    def _pwrite(self, fd: int, mv: memoryview, off: int) -> None:
        os.pwritev(fd, [mv], off)

    def _pread(self, fd: int, mv: memoryview, off: int) -> int:
        return os.preadv(fd, [mv], off)

    def _chunk_spans(self, byte_lo: int, byte_hi: int):
        """Yield (path, file_offset, lo, hi) per chunk overlapping
        [byte_lo, byte_hi) — lo/hi are tensor-relative byte offsets."""
        P, C = len(self.paths), self.chunk
        for c in range(byte_lo // C, (byte_hi + C - 1) // C):
            lo = max(byte_lo, c * C)
            hi = min(byte_hi, (c + 1) * C)
            if lo < hi:
                yield c % P, (c // P) * C + (lo - c * C), lo, hi

    # ---------------- bulk ops ----------------
    def _positioned(self, name: str, data_u8: np.ndarray, byte_lo: int,
                    write: bool, route: str, priority: IOPriority):
        """Chunked read into / write from ``data_u8`` (a uint8 view) that
        occupies tensor bytes [byte_lo, byte_lo + data_u8.nbytes).
        One channel op per chunk, so a higher-priority transfer's chunks
        can overtake this one's mid-flight."""
        nbytes = data_u8.nbytes
        if nbytes == 0:
            self._fd(name, 0)        # ensure the tensor exists on disk
            return
        byte_hi = byte_lo + nbytes
        eng = self.engine
        futs: List = []
        for p, off, lo, hi in self._chunk_spans(byte_lo, byte_hi):
            mv = memoryview(data_u8[lo - byte_lo:hi - byte_lo])

            def op(p=p, off=off, mv=mv, n=hi - lo):
                fd = self._fd(name, p)
                eng.throttle(route, n)
                if write:
                    self._pwrite(fd, mv, off)
                else:
                    got = self._pread(fd, mv, off)
                    if got != n:
                        raise IOError(
                            f"short read on {name!r} path {p}: "
                            f"{got}/{n} bytes at offset {off}")
            futs.append(eng.submit_chunk(p, op, priority, route=route,
                                         nbytes=hi - lo))
        for f in futs:
            f.result()

    def write(self, name: str, data_u8: np.ndarray, byte_lo: int,
              priority: IOPriority):
        self._positioned(name, data_u8, byte_lo, write=True,
                         route="cpu->ssd", priority=priority)

    def readinto(self, name: str, out_u8: np.ndarray, byte_lo: int,
                 priority: IOPriority):
        self._positioned(name, out_u8, byte_lo, write=False,
                         route="ssd->cpu", priority=priority)

    def delete(self, name: str):
        for p in range(len(self.paths)):
            with self._fd_lock:
                fd = self._fds.pop((name, p), None)
            if fd is not None:
                os.close(fd)
            path = os.path.join(self.paths[p], _mangle(name) + f".s{p}.bin")
            try:
                os.unlink(path)
            except FileNotFoundError:
                pass

    def close(self):
        with self._fd_lock:
            fds, self._fds = list(self._fds.values()), {}
        for fd in fds:
            os.close(fd)
