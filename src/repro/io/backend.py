"""Chunked, striped flat-tensor files across N SSD paths, with a
per-tensor chunk-location table so chunk->path assignment is a
scheduled decision, not a layout constant.

Baseline layout (MLP-Offload-style round robin): a tensor of
``nbytes`` is cut into chunks of ``chunk_bytes``; chunk ``i`` DEFAULTS
to path ``i % P`` at slot ``i // P`` of that path's stripe file
(``<path>/<name>.s<p>.bin``, file offset = slot * chunk_bytes). Under
``path_policy="static"`` that default is the whole story — the layout
is bit-for-bit the classic static striping and no placement state is
ever created.

Under the dynamic policies ("weighted"/"backlog") every FULL-chunk
write asks :meth:`IOEngine.choose_path` where the chunk should land
*now* (rate-weighted / least-backlogged path) and records the decision
in the tensor's chunk-location table: ``chunk -> (path, slot)``. Reads
and partial writes follow the recorded map, falling back to the static
default for chunks never dynamically placed — so a tensor written
under "static" stays readable after a policy flip and vice versa.
Slots for re-placed chunks come from a per-(tensor, path) allocation
cursor that starts past the stripe file's current end and only ever
moves forward, and a claims map tracks slot ownership so a dynamic
allocation can never collide with a chunk still on its static slot.
Slots vacated by a re-placement are deliberately NEVER reused: an op
targeting the old slot may still be in flight (chunk ops from
overlapping writes of one tensor interleave on the path channels), so
handing the slot to another chunk would let that stale op corrupt the
new tenant after the fact. Orphaning the slot instead means a stale op
can only ever touch bytes its own chunk used to own — the worst case
degrades to the same-offset version race static striping always had,
at the cost of stripe-file growth when placement flips a chunk between
paths. Only full-chunk writes re-place: a short last chunk or a ranged
partial write sticks to wherever the chunk already lives (moving it
would require a read-modify-write of bytes the caller didn't provide).

The table is persisted as a JSON sidecar next to the first path's
stripe file (``<paths[0]>/<name>.map.json``, written atomically via
temp + rename after the chunk writes it describes have completed) and
lazily reloaded on reopen, so placement survives process restarts.
Static-only, integrity-off runs produce zero sidecars.

Integrity (``IOConfig.integrity``): every COMPLETE-chunk write — one
whose buffer is authoritative for every byte the chunk will hold
(``lo == c*C`` and the span reaches the chunk boundary or the tensor's
known end) — records the CRC32C of the intended bytes in the sidecar,
and every complete-chunk read verifies the stored bytes against it,
raising :class:`repro.io.integrity.IntegrityError` on mismatch. The
checksum is computed from the WRITE buffer, not read back from disk, so
a torn write (device persisted only a prefix) or a flipped bit is
caught at the next read instead of training on garbage. Partial writes
drop the chunk's recorded CRC (the buffer can't vouch for bytes it
doesn't carry); partial reads skip verification.

Fault recovery on the write path: a chunk op error that survives the
engine's transient-retry loop surfaces here, and — when the chunk is
complete and another path exists — the chunk is re-placed on a
surviving path (``IOEngine.failover_path``) and re-written from the
caller's authoritative buffer, recording the move in the location
table. A path at ``PATH_FAIL_DRAIN_THRESHOLD`` consecutive failures is
additionally avoided PRE-emptively for new complete-chunk writes under
EVERY policy, static included (a dead device is a fault condition, not
a layout choice). Reads are never rerouted: a chunk's only copy lives
where the table says, so a dead-path read fails loudly — data is
declared irrecoverable rather than silently substituted.

All byte movement is positioned I/O (``pread``/``pwritev`` on cached
fds), submitted as one chunk op per chunk on the owning path's channel
— so a P-path store keeps P threads busy in parallel, and a
higher-priority tensor's chunks overtake a lower-priority one's in
each channel's heap. Bandwidth pacing applies per chunk before the
syscall: the route cap (``cpu->ssd`` / ``ssd->cpu``) and the owning
path's device cap (``IOConfig.path_bandwidth``) both, when configured.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.io.engine import IOEngine, IOPriority
from repro.io.integrity import IntegrityError, crc32c


def _mangle(name: str) -> str:
    return name.replace("/", "_")


class StripedFiles:
    def __init__(self, engine: IOEngine):
        self.engine = engine
        self.paths = engine.paths
        self.chunk = int(engine.chunk_bytes)
        self._fds: Dict[Tuple[str, int], int] = {}
        self._fd_lock = threading.Lock()
        # placement state, all guarded by _map_lock:
        #   _tables[name]: chunk -> (path, slot) overrides (absent chunk
        #       = static default); _claims[name]: (path, slot) -> chunk
        #       for every slot a write has claimed THIS process — the
        #       collision guard between static-default slots and
        #       allocated ones; _cursors[name][p]: next never-used slot
        #       (init lazily from stripe file size + live claims).
        #       Slots vacated by re-placement are orphaned, never
        #       recycled (see the module docstring).
        self._map_lock = threading.Lock()
        self._tables: Dict[str, Dict[int, Tuple[int, int]]] = {}
        self._claims: Dict[str, Dict[Tuple[int, int], int]] = {}
        self._cursors: Dict[str, List[Optional[int]]] = {}
        self._map_checked: Set[str] = set()
        # integrity state (also under _map_lock): _crcs[name][chunk] is
        # the CRC32C of the chunk's intended bytes, recorded at write;
        # _hiwater[name] is the highest byte offset ever written (or
        # loaded from the sidecar) — the "known end" that makes a short
        # last chunk count as COMPLETE for checksum purposes.
        self.integrity = bool(getattr(engine.config, "integrity", False))
        self._crcs: Dict[str, Dict[int, int]] = {}
        self._hiwater: Dict[str, int] = {}

    # ---------------- fd cache ----------------
    def _fd(self, name: str, p: int) -> int:
        key = (name, p)
        with self._fd_lock:
            fd = self._fds.get(key)
            if fd is None:
                path = os.path.join(self.paths[p],
                                    _mangle(name) + f".s{p}.bin")
                fd = os.open(path, os.O_RDWR | os.O_CREAT, 0o644)
                self._fds[key] = fd
            return fd

    # ---------------- raw chunk ops ----------------
    # The single choke point every chunk's bytes pass through. Kept as
    # overridable methods so a harness can inject faults (short reads,
    # EIO, stalls) under the full engine stack — the fault-injection
    # test battery subclasses StripedFiles and flips these.
    def _pwrite(self, fd: int, mv: memoryview, off: int) -> None:
        os.pwritev(fd, [mv], off)

    def _pread(self, fd: int, mv: memoryview, off: int) -> int:
        return os.preadv(fd, [mv], off)

    # ---------------- chunk-location table ----------------
    def _map_path(self, name: str) -> str:
        return os.path.join(self.paths[0], _mangle(name) + ".map.json")

    def _load_sidecar(self, name: str):
        """Load the sidecar once per tensor: the placement table plus,
        when present, the per-chunk CRCs and the byte high-water mark.
        Caller holds _map_lock."""
        if name in self._map_checked:
            return
        self._map_checked.add(name)
        try:
            with open(self._map_path(name)) as f:
                doc = json.load(f)
        except FileNotFoundError:
            return
        if (doc.get("chunk_bytes") != self.chunk
                or doc.get("n_paths") != len(self.paths)):
            raise ValueError(
                f"stale chunk map for {name!r}: written with "
                f"chunk_bytes={doc.get('chunk_bytes')} over "
                f"{doc.get('n_paths')} path(s), reopened with "
                f"chunk_bytes={self.chunk} over "
                f"{len(self.paths)} path(s)")
        m = doc.get("map") or {}
        if m:
            t = {int(c): (int(p), int(s)) for c, (p, s) in m.items()}
            self._tables[name] = t
            self._claims[name] = {ps: c for c, ps in t.items()}
        crcs = doc.get("crc") or {}
        if crcs:
            self._crcs[name] = {int(c): int(v) for c, v in crcs.items()}
        nb = doc.get("nbytes")
        if nb is not None:
            self._hiwater[name] = max(self._hiwater.get(name, 0), int(nb))

    def _table(self, name: str) -> Optional[Dict[int, Tuple[int, int]]]:
        """The tensor's placement table, lazily loading the sidecar the
        first time the tensor is touched. Caller holds _map_lock."""
        t = self._tables.get(name)
        if t is None:
            self._load_sidecar(name)
            t = self._tables.get(name)
        return t

    def _persist(self, name: str):
        """Atomically write the sidecar (temp + rename). Called after
        the chunk writes a table/CRC mutation describes have completed,
        so a persisted slot always has its bytes on disk and a persisted
        checksum always covers bytes that were sent."""
        with self._map_lock:
            t = self._tables.get(name)
            crcs = self._crcs.get(name) if self.integrity else None
            if not t and not crcs:
                return
            doc = {"chunk_bytes": self.chunk, "n_paths": len(self.paths),
                   "map": {str(c): list(ps)
                           for c, ps in sorted((t or {}).items())}}
            if self.integrity:
                doc["crc"] = {str(c): v
                              for c, v in sorted((crcs or {}).items())}
                doc["nbytes"] = self._hiwater.get(name, 0)
        target = self._map_path(name)
        tmp = target + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f)
        os.replace(tmp, target)

    def _alloc_slot(self, name: str, p: int) -> int:
        """An unclaimed, never-dynamically-used slot on path ``p`` from
        the monotonic allocation cursor. The cursor initializes past
        the stripe file's current end (data from earlier processes /
        completed writes; in-flight slots are covered by the claims
        skip below) and never moves backward — vacated slots are never
        recycled. At EVERY allocation the cursor additionally skips
        slots claimed since it was initialized: a chunk that kept its
        static default ``(c % P, c // P)`` after the cursor passed that
        slot would otherwise be silently overwritten by the next
        dynamic placement on its path. Caller holds _map_lock."""
        cur = self._cursors.setdefault(name, [None] * len(self.paths))
        if cur[p] is None:
            fd = self._fd(name, p)
            cur[p] = (os.fstat(fd).st_size + self.chunk - 1) // self.chunk
        claims = self._claims.get(name) or {}
        slot = cur[p]
        while (p, slot) in claims:
            slot += 1
        cur[p] = slot + 1
        return slot

    def placement(self, name: str, c: int) -> Tuple[int, int]:
        """Where chunk ``c`` of tensor ``name`` lives: the recorded
        table entry, else the static default ``(c % P, c // P)``."""
        with self._map_lock:
            t = self._table(name)
            if t is not None:
                e = t.get(c)
                if e is not None:
                    return e
        P = len(self.paths)
        return c % P, c // P

    # ---------------- per-chunk CRCs (integrity) ----------------
    def _set_crc(self, name: str, c: int, crc: int):
        with self._map_lock:
            self._crcs.setdefault(name, {})[c] = crc

    def _drop_crc(self, name: str, c: int):
        """A partial write touched chunk ``c``: its recorded checksum no
        longer describes the full chunk, so verification must skip it."""
        with self._map_lock:
            crcs = self._crcs.get(name)
            if crcs:
                crcs.pop(c, None)

    def _crc_of(self, name: str, c: int) -> Optional[int]:
        with self._map_lock:
            self._load_sidecar(name)
            crcs = self._crcs.get(name)
            return crcs.get(c) if crcs else None

    def _place_for_write(self, name: str, c: int, full: bool,
                         complete: bool = False) -> Tuple[int, int, bool]:
        """Placement decision for one chunk about to be WRITTEN.
        Returns (path, slot, table_mutated).

        A full chunk under a dynamic policy is re-placed via
        :meth:`IOEngine.choose_path`; anything else sticks to its
        recorded/static location. Either way, a static-default slot
        already owned by a re-placed chunk forces a fresh allocation
        (the collision guard: the cursor starts from the file size, so
        a first-ever dynamic write can hand out slots the tensor's
        *later* chunks would map to statically).

        ``complete`` marks a chunk whose buffer carries every byte the
        chunk will hold; such a chunk headed for a DRAINED path (at the
        consecutive-failure threshold) is rerouted to a survivor
        pre-emptively under every policy — ``full``/dynamic placement
        governs load balancing, ``complete``/drain governs fault
        avoidance, and the two stay separate so partial writes never
        move (the caller's buffer can't re-create bytes it lacks)."""
        eng = self.engine
        P, C = len(self.paths), self.chunk
        dynamic = full and P > 1 and eng.path_policy != "static"
        new_p = eng.choose_path(C) if dynamic else None
        moved_off = None
        with self._map_lock:
            t = self._table(name)
            entry = t.get(c) if t is not None else None
            old = entry if entry is not None else (c % P, c // P)
            claims = self._claims.setdefault(name, {})
            # "ours": unclaimed, or claimed by this very chunk
            ours = claims.get(old, c) == c
            if ((new_p is None or new_p == old[0]) and complete and P > 1
                    and eng.path_drained(old[0])):
                survivor = eng.failover_path({old[0]}, C)
                if survivor is not None:
                    moved_off, new_p = old[0], survivor
            if new_p is None or (new_p == old[0] and ours):
                if ours:
                    if claims.get(old) != c:
                        # record the static claim so the allocation
                        # cursor can never hand this slot out
                        claims[old] = c
                    return old[0], old[1], False
                # static slot stolen by a re-placed chunk: convert this
                # chunk to a fresh slot on its own (static) path
                new_p = old[0]
            slot = self._alloc_slot(name, new_p)
            if t is None:
                t = self._tables[name] = {}
            t[c] = (new_p, slot)
            claims[(new_p, slot)] = c
            if ours:
                # the old slot is orphaned, never recycled: a stale op
                # from an overlapping write may still land there
                claims.pop(old, None)
        if moved_off is not None:
            eng.note_failover(moved_off, new_p, name, c)
        return new_p, slot, True

    # ---------------- bulk ops ----------------
    def _chunk_spans(self, byte_lo: int, byte_hi: int):
        """Yield (chunk_index, lo, hi) per chunk overlapping
        [byte_lo, byte_hi) — lo/hi are tensor-relative byte offsets."""
        C = self.chunk
        for c in range(byte_lo // C, (byte_hi + C - 1) // C):
            lo = max(byte_lo, c * C)
            hi = min(byte_hi, (c + 1) * C)
            if lo < hi:
                yield c, lo, hi

    def _chunk_op(self, name: str, p: int, off: int, mv: memoryview,
                  n: int, c: int, complete: bool, write: bool,
                  route: str):
        """One chunk's channel op: pace, move the bytes, and maintain /
        verify the chunk's CRC when integrity is on. The checksum is
        computed from ``mv`` — the INTENDED bytes — after the pwrite, so
        a torn or corrupted landing mismatches at the next read."""
        eng = self.engine

        def op():
            fd = self._fd(name, p)
            eng.throttle(route, n)
            eng.throttle_path(p, n)
            if write:
                self._pwrite(fd, mv, off)
                if self.integrity:
                    if complete:
                        self._set_crc(name, c, crc32c(mv))
                    else:
                        self._drop_crc(name, c)
            else:
                got = self._pread(fd, mv, off)
                if got != n:
                    raise IOError(
                        f"short read on {name!r} path {p}: "
                        f"{got}/{n} bytes at offset {off}")
                if self.integrity and complete:
                    want = self._crc_of(name, c)
                    if want is not None and crc32c(mv) != want:
                        eng.note_integrity_error(p, name, c)
                        raise IntegrityError(
                            f"CRC32C mismatch on {name!r} chunk {c} "
                            f"(path {p}): stored bytes do not match "
                            f"the recorded checksum")
        return op

    def _failover_write(self, name: str, c: int, lo: int,
                        mv: memoryview, n: int, failed: int, route: str,
                        priority: IOPriority):
        """Re-home one COMPLETE chunk whose write just failed
        permanently: allocate a slot on a surviving path, point the
        table there, and re-write from the caller's authoritative
        buffer. Tries every survivor in turn; raises the last error when
        none accepts the bytes (table then points at the last attempt —
        the same bytes-were-SENT discipline as partial-failure
        persists)."""
        eng = self.engine
        C = self.chunk
        exclude = {failed}
        last: Optional[BaseException] = None
        while True:
            q = eng.failover_path(exclude, n)
            if q is None:
                if last is not None:
                    raise last
                raise IOError(
                    f"no surviving path for {name!r} chunk {c}: all "
                    f"{len(self.paths)} path(s) failed")
            with self._map_lock:
                t = self._table(name)
                entry = t.get(c) if t is not None else None
                old = (entry if entry is not None
                       else (c % len(self.paths), c // len(self.paths)))
                claims = self._claims.setdefault(name, {})
                ours = claims.get(old, c) == c
                slot = self._alloc_slot(name, q)
                if t is None:
                    t = self._tables[name] = {}
                t[c] = (q, slot)
                claims[(q, slot)] = c
                if ours:
                    claims.pop(old, None)
            off = slot * C + (lo - c * C)
            fut = eng.submit_chunk(
                q, self._chunk_op(name, q, off, mv, n, c, True, True,
                                  route),
                priority, route=route, nbytes=n)
            try:
                fut.result()
            except BaseException as e:
                last = e
                exclude.add(q)
                continue
            eng.note_failover(failed, q, name, c)
            return

    def _positioned(self, name: str, data_u8: np.ndarray, byte_lo: int,
                    write: bool, route: str, priority: IOPriority):
        """Chunked read into / write from ``data_u8`` (a uint8 view) that
        occupies tensor bytes [byte_lo, byte_lo + data_u8.nbytes).
        One channel op per chunk, so a higher-priority transfer's chunks
        can overtake this one's mid-flight. Placement is resolved here,
        in the submitting thread (deterministic decision order), before
        the ops fan out to the path channels.

        A write op that fails permanently (past the engine's transient
        retries) on a COMPLETE chunk of a multi-path store falls back to
        :meth:`_failover_write`; every other failure propagates after
        the remaining chunks settle."""
        nbytes = data_u8.nbytes
        if nbytes == 0:
            self._fd(name, 0)        # ensure the tensor exists on disk
            return
        byte_hi = byte_lo + nbytes
        eng = self.engine
        C = self.chunk
        # the sidecar must be loaded BEFORE the high-water mark is read:
        # a fresh backend over existing files (a reopen, or a chaos
        # harness swapped in mid-run) would otherwise see hw=0 and call
        # a partial chunk span "complete" — verifying a partial read
        # against a full-chunk CRC, or recording a partial-chunk CRC
        with self._map_lock:
            self._load_sidecar(name)
            if write:
                hw = max(self._hiwater.get(name, 0), byte_hi)
                self._hiwater[name] = hw
            else:
                hw = self._hiwater.get(name, 0)
        subs: List[tuple] = []
        mutated = False
        for c, lo, hi in self._chunk_spans(byte_lo, byte_hi):
            n = hi - lo
            # "complete": the buffer is authoritative for every byte the
            # chunk will hold — a full chunk, or a short LAST chunk that
            # starts on its boundary and reaches the tensor's known end
            complete = lo == c * C and (n == C or hi >= hw)
            if write:
                p, slot, changed = self._place_for_write(
                    name, c, full=(n == C), complete=complete)
                mutated = mutated or changed
            else:
                p, slot = self.placement(name, c)
            off = slot * C + (lo - c * C)
            mv = memoryview(data_u8[lo - byte_lo:hi - byte_lo])
            fut = eng.submit_chunk(
                p, self._chunk_op(name, p, off, mv, n, c, complete,
                                  write, route),
                priority, route=route, nbytes=n)
            subs.append((fut, c, lo, p, mv, n, complete))
        err = None
        for fut, c, lo, p, mv, n, complete in subs:
            try:
                fut.result()
            except BaseException as e:
                if write and complete and len(self.paths) > 1:
                    try:
                        self._failover_write(name, c, lo, mv, n, p,
                                             route, priority)
                        mutated = True
                        continue
                    except BaseException as e2:
                        err = err or e2
                else:
                    err = err or e
        if mutated or (write and self.integrity):
            # persist even on partial failure: the table describes where
            # the bytes were SENT, and surviving chunks did land there
            self._persist(name)
        if err is not None:
            raise err

    def write(self, name: str, data_u8: np.ndarray, byte_lo: int,
              priority: IOPriority):
        self._positioned(name, data_u8, byte_lo, write=True,
                         route="cpu->ssd", priority=priority)

    def readinto(self, name: str, out_u8: np.ndarray, byte_lo: int,
                 priority: IOPriority):
        self._positioned(name, out_u8, byte_lo, write=False,
                         route="ssd->cpu", priority=priority)

    def delete(self, name: str):
        for p in range(len(self.paths)):
            with self._fd_lock:
                fd = self._fds.pop((name, p), None)
            if fd is not None:
                os.close(fd)
            path = os.path.join(self.paths[p], _mangle(name) + f".s{p}.bin")
            try:
                os.unlink(path)
            except FileNotFoundError:
                pass
        with self._map_lock:
            self._tables.pop(name, None)
            self._claims.pop(name, None)
            self._cursors.pop(name, None)
            self._map_checked.discard(name)
            self._crcs.pop(name, None)
            self._hiwater.pop(name, None)
        try:
            os.unlink(self._map_path(name))
        except FileNotFoundError:
            pass

    def close(self):
        with self._fd_lock:
            fds, self._fds = list(self._fds.values()), {}
        for fd in fds:
            os.close(fd)
