"""The asynchronous transfer engine all offload traffic flows through.

Two scheduling levels, chosen so requests never deadlock on each other:

* **Request level** — `IOEngine.submit` enqueues a whole logical
  transfer (fetch layer-l params, spill a checkpoint tail, run one
  layer's optimizer segment) on a priority heap drained by a small
  worker pool. Priorities encode the critical path: a parameter fetch
  the GPU is about to block on always jumps ahead of a deferrable
  checkpoint spill.
* **Chunk level** — request bodies issue fixed-size chunk operations on
  the per-path channels (`submit_chunk`), one thread per SSD path, each
  with its own priority heap. Channels never wait on anything, so they
  always drain, so request workers always finish. Two request-on-request
  waits are permitted: a *gate* (α-delay ordering: a param fetch
  waiting on an optimizer flush — keep ``workers >= 2`` so the gating
  request can run while the gated one waits), and a *prefetch consume*
  (an optimizer flush using a ``PREFETCH_OPT`` hint's state reads) —
  legal because the consumer cancels a still-queued hint and only ever
  waits on a running-or-done request, whose body is itself wait-free.

Backpressure is a bounded in-flight byte budget charged at submit and
released at completion/cancellation. Cancellation is
best-effort-before-start (`IORequest.cancel`), which is exactly what a
schedule reset needs: queued prefetches die, a running one is drained.
:meth:`IOEngine.depth` exposes the live queue state (front heap,
per-route and per-path channel backlog, budget utilization) — the
signal the plan executor's backpressure-adaptive lookahead throttles
on.

Request/span lifecycle (what ``repro.obs`` observes)
====================================================

Every request and chunk op walks the same four edges, and the tracer
hooks exactly those edges — so a trace is a complete account of where
each byte's time went:

    submit ──(queue-wait)──> start ──(transfer)──> settle
       │                       │                      │
       │ `t_submit` stamped    │ worker pops the      │ exactly-once
       │ (only while tracing   │ heap, wins           │ `_settle_once`
       │ is enabled)           │ `set_running_…`      │ accounting
       └── budget wait (front  └───────────────────────── completion,
           requests only; charged                         failure or
           against `inflight_bytes`)                      cancellation

When the shared :class:`repro.obs.Tracer` is enabled, each worker
records TWO spans per executed request on its own track (= one Chrome
trace row per thread): a *queue-wait* span (``t_submit`` -> start; how
long the priority heap held it) and a *transfer* span (start ->
settle; how long the body ran), both tagged with route, priority
class, nbytes, and — on channel threads — the SSD path index. A
cancelled-while-queued request records nothing (no bytes moved). With
the tracer disabled the only cost is one flag test per submit/run,
measured by the bench-smoke gate.
"""
from __future__ import annotations

import enum
import errno as _errno
import heapq
import itertools
import os
import threading
import time
from concurrent.futures import Future
from typing import Callable, Dict, List, Optional, Sequence

from repro.io.bandwidth import BandwidthSimulator, PathBandwidthSimulator
from repro.io.config import PATH_POLICIES, IOConfig
from repro.io.integrity import IntegrityError
from repro.io.staging import StagingPool
from repro.obs.tracer import (CAT_FAULT, CAT_IO_CHUNK, CAT_IO_QUEUE,
                              CAT_IO_REQ, CAT_IO_REQ_QUEUE)


class IOPriority(enum.IntEnum):
    """Lower value = more urgent (GreedySnake's critical-path order).

    ``KV`` (serving-time KV-cache block stream, ``repro.serve``) sits
    right below the optimizer state: a late ``FETCH_KV`` stalls a whole
    request's next decode step — user-visible latency — but it must
    never starve the training-critical param/grad/opt streams when the
    two workloads share a device.

    ``ACT`` (SSDTrain-style activation spill/fetch) sits BELOW ckpt
    spills: the stream is opportunistic — it exists to soak up spare
    write bandwidth, and a late activation fetch only delays one
    micro-batch's backward, whereas a late checkpoint tail stalls the
    whole recompute pipeline."""
    PARAM_FETCH = 0
    INTER_LAYER_GRAD = 1
    OPTIMIZER_STATE = 2
    KV = 3
    CKPT_SPILL = 4
    ACT = 5


#: Consecutive chunk failures on one path before it is treated as
#: DRAINED: the "backlog"/"weighted" placement policies stop choosing
#: it for NEW chunks, and complete-chunk WRITES (whose authoritative
#: bytes the caller still holds) are rerouted to a survivor — both
#: pre-emptively in ``StripedFiles._place_for_write`` and reactively
#: via the per-chunk write-failover path. Reads of chunks already
#: placed there still run — and still fail loudly; their only copy is
#: on the dead device, so a silent reroute would return garbage.
#: One later success on the path zeroes the count (retry-recovered
#: transients therefore never accumulate toward the drain).
PATH_FAIL_DRAIN_THRESHOLD = 3

#: errno values classified as TRANSIENT: worth a bounded retry with
#: backoff, because the same op against the same device can legitimately
#: succeed a moment later. Everything else — EIO, ENOSPC, short reads,
#: injected dead-device faults — is permanent and propagates at once.
TRANSIENT_ERRNOS = frozenset(
    e for e in (_errno.EAGAIN, getattr(_errno, "EWOULDBLOCK", _errno.EAGAIN),
                _errno.EINTR, _errno.ETIMEDOUT, _errno.EBUSY,
                _errno.ENOBUFS))

#: Per-priority-class retry time budget (seconds of cumulative backoff
#: a chunk op may spend before its transient fault is escalated).
#: Critical-path classes give up fast — the executor blocks on them,
#: and a failed param fetch surfaces a loud, actionable error —
#: while the deferrable spill classes may ride out longer brownouts.
RETRY_TIMEOUT_S: Dict[int, float] = {
    IOPriority.PARAM_FETCH: 0.25,
    IOPriority.INTER_LAYER_GRAD: 0.25,
    IOPriority.OPTIMIZER_STATE: 0.5,
    IOPriority.KV: 0.25,
    IOPriority.CKPT_SPILL: 1.0,
    IOPriority.ACT: 1.0,
}


def is_transient(exc: BaseException) -> bool:
    """Transient-vs-permanent fault classification (see
    :data:`TRANSIENT_ERRNOS`). An explicit boolean ``transient``
    attribute on the exception overrides the errno heuristic — the
    chaos backend stamps it, and a real NVMe-oF transport could too.
    ``IntegrityError`` is transient for the retry round: a torn
    in-flight read heals on re-read, while bytes corrupted on the
    device keep mismatching until the budget is spent and the error
    propagates loudly."""
    t = getattr(exc, "transient", None)
    if t is not None:
        return bool(t)
    if isinstance(exc, IntegrityError):
        return True
    if isinstance(exc, OSError):
        return exc.errno in TRANSIENT_ERRNOS
    return False

#: Default priority for a given traffic-meter category.
CATEGORY_PRIORITY: Dict[str, IOPriority] = {
    "param": IOPriority.PARAM_FETCH,
    "inter_grad": IOPriority.INTER_LAYER_GRAD,
    "grad": IOPriority.INTER_LAYER_GRAD,
    "opt": IOPriority.OPTIMIZER_STATE,
    "kv": IOPriority.KV,
    "ckpt": IOPriority.CKPT_SPILL,
    "act": IOPriority.ACT,
}


class IORequest:
    """A scheduled transfer: callable + priority + accounting metadata.
    ``result()/cancel()/done()`` delegate to the underlying future."""

    __slots__ = ("priority", "seq", "category", "route", "nbytes", "fn",
                 "future", "_engine", "_accounted", "t_submit")

    def __init__(self, priority: int, seq: int, category: str, route: str,
                 nbytes: int, fn: Callable, engine: Optional["IOEngine"]):
        self.priority = int(priority)
        self.seq = seq
        self.category = category
        self.route = route
        self.nbytes = int(nbytes)
        self.fn = fn
        self.future: Future = Future()
        self._engine = engine
        self._accounted = False
        self.t_submit = 0.0     # stamped at submit ONLY while tracing

    def __lt__(self, other: "IORequest") -> bool:
        return (self.priority, self.seq) < (other.priority, other.seq)

    def result(self, timeout: Optional[float] = None):
        return self.future.result(timeout)

    def _settle_once(self) -> bool:
        """The budget/stat settlement must happen exactly once per
        request (cancel() on an already-cancelled Future returns True
        again, and completion follows a failed cancel)."""
        if self._accounted:
            return False
        self._accounted = True
        return True

    def cancel(self) -> bool:
        ok = self.future.cancel()
        if ok and self._engine is not None and self._settle_once():
            self._engine._on_cancelled(self)
        return ok

    def done(self) -> bool:
        return self.future.done()

    def running(self) -> bool:
        return self.future.running()

    def cancelled(self) -> bool:
        return self.future.cancelled()


class _PriorityWorkers:
    """N threads draining a priority heap of IORequests. When a tracer
    is attached each thread records queue-wait + execution spans on its
    own track (``path_index`` marks a single-thread path channel)."""

    def __init__(self, n: int, name: str, tracer=None,
                 path_index: Optional[int] = None):
        self._heap: List[IORequest] = []
        self._cv = threading.Condition()
        self._closed = False
        self._running = 0
        self._tracer = tracer
        self._path_index = path_index
        self._threads = [threading.Thread(target=self._run,
                                          name=f"{name}-{i}", daemon=True)
                         for i in range(n)]
        for t in self._threads:
            t.start()

    def submit(self, req: IORequest):
        with self._cv:
            if self._closed:
                raise RuntimeError("I/O engine is shut down")
            heapq.heappush(self._heap, req)
            self._cv.notify()

    def _run(self):
        while True:
            with self._cv:
                while not self._heap and not self._closed:
                    self._cv.wait()
                if not self._heap:
                    return                       # closed and drained
                req = heapq.heappop(self._heap)
            if not req.future.set_running_or_notify_cancel():
                continue                         # cancelled while queued
            tr = self._tracer
            rec = tr is not None and tr.enabled and req.t_submit > 0.0
            if rec:
                t_start = time.perf_counter()
            with self._cv:
                self._running += 1
            try:
                req.future.set_result(req.fn())
            except BaseException as e:           # propagate via the future
                req.future.set_exception(e)
            finally:
                with self._cv:
                    self._running -= 1
                if rec:
                    t_end = time.perf_counter()
                    self._record(tr, req, t_start, t_end)
                if req._engine is not None and req._settle_once():
                    req._engine._on_done(req)

    def _record(self, tr, req: IORequest, t_start: float, t_end: float):
        """Queue-wait + transfer spans for one executed request, on this
        worker thread's track."""
        track = threading.current_thread().name
        args = {"route": req.route, "nbytes": req.nbytes,
                "priority": IOPriority(req.priority).name}
        if self._path_index is None:             # front (request) pool
            name = req.category or "req"
            cat_q, cat_x = CAT_IO_REQ_QUEUE, CAT_IO_REQ
        else:                                    # path channel
            name = req.route or "chunk"
            cat_q, cat_x = CAT_IO_QUEUE, CAT_IO_CHUNK
            args["path"] = self._path_index
        tr.record(track, name + ":wait", cat_q, req.t_submit, t_start,
                  **args)
        tr.record(track, name, cat_x, t_start, t_end, **args)

    def shutdown(self, wait: bool = True):
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        if wait:
            for t in self._threads:
                t.join()


class IOEngine:
    """Priority-scheduled, budgeted, optionally bandwidth-paced transfers
    across one or more SSD paths. See the module docstring."""

    def __init__(self, config: Optional[IOConfig] = None, meter=None,
                 default_root: Optional[str] = None, tracer=None,
                 label: str = ""):
        # ``tracer``: a shared repro.obs.Tracer (or None); ``label``
        # prefixes the worker thread names — the DP engine passes
        # "rank<r>-" so each rank's channels get distinct trace tracks.
        # The default is built HERE, not in the signature: a default
        # argument is evaluated once at class-definition time, so
        # `config: IOConfig = IOConfig()` would hand every
        # default-constructed engine the same IOConfig instance (and the
        # same `bandwidth` dict from its default_factory).
        if config is None:
            config = IOConfig()
        paths = config.resolved_paths(default_root) if (
            config.paths or default_root) else None
        if not paths:
            raise ValueError("IOConfig.paths must name at least one "
                             "directory (or pass default_root)")
        for p in paths:
            os.makedirs(p, exist_ok=True)
        self.config = config
        self.paths: Sequence[str] = list(paths)
        self.meter = meter
        self.tracer = tracer
        self.chunk_bytes = int(config.chunk_bytes)
        self.simulator = BandwidthSimulator(config.bandwidth)
        self.path_simulator = PathBandwidthSimulator(config.path_bandwidth,
                                                    len(self.paths))
        # chunk->path placement policy: mutable at runtime (the
        # autotuner's `apply_plan_config(path_policy=...)` actuates
        # here); StripedFiles consults it per write
        self.path_policy = config.path_policy
        self.staging = StagingPool(config.staging_buffers,
                                   max(self.chunk_bytes, 1 << 20))
        self._seq = itertools.count()
        self._front = _PriorityWorkers(max(1, config.workers),
                                       f"{label}io-req", tracer)
        self._channels = [_PriorityWorkers(1, f"{label}io-path{i}", tracer,
                                           path_index=i)
                          for i in range(len(self.paths))]
        self._budget = int(config.inflight_bytes)
        self._inflight = 0
        self._bp_cv = threading.Condition()
        # per-route bytes of chunk ops submitted but not yet finished —
        # the O(1) backlog signal the adaptive lookahead polls per hint
        # (depth() reports the same numbers without scanning heaps) —
        # plus the per-path counterparts (chunk backlog, cumulative
        # bytes/ops) that depth()/stats() report for path-level pacing
        self._backlog_lock = threading.Lock()
        self._route_backlog: Dict[str, int] = {}
        self._path_backlog = [0] * len(self.paths)
        self._path_backlog_bytes = [0] * len(self.paths)
        self._path_bytes = [0] * len(self.paths)
        self._path_chunk_ops = [0] * len(self.paths)
        # cumulative chunk bytes per route and split per (route, path) —
        # the split must SUM to the total exactly (placement moves
        # bytes between paths, never between routes; obs.reconcile
        # checks it)
        self._route_bytes: Dict[str, int] = {}
        self._route_path_bytes: Dict[str, List[int]] = {}
        # placement state: bytes the dynamic policies have assigned per
        # path (the deterministic "weighted" criterion and the backlog
        # tie-break), and consecutive failures per path (fault drain)
        self._placed_bytes = [0] * len(self.paths)
        self._path_failures = [0] * len(self.paths)
        # fault-recovery accounting: transient retries, write failovers
        # and CRC mismatches, split per path (index = path)
        self._path_retries = [0] * len(self.paths)
        self._retries = int(config.retries)
        self._retry_backoff = float(config.retry_backoff_s)
        self._closed = False
        self._stats_lock = threading.Lock()
        self._stats = {
            "submitted": 0, "completed": 0, "cancelled": 0, "chunk_ops": 0,
            "max_inflight_bytes": 0,
            "chunk_retries": 0, "chunk_failovers": 0, "integrity_errors": 0,
            "bytes_by_priority": {p.name: 0 for p in IOPriority},
        }

    # ---------------- request level ----------------
    def submit(self, fn: Callable, *, priority: IOPriority,
               category: str = "", route: str = "", nbytes: int = 0
               ) -> IORequest:
        """Schedule ``fn()`` with the given priority. Blocks while the
        in-flight byte budget is exhausted (backpressure); a request
        larger than the whole budget is admitted once the engine drains.
        """
        nbytes = int(nbytes)
        with self._bp_cv:
            while (not self._closed and self._inflight > 0
                   and self._inflight + nbytes > self._budget):
                self._bp_cv.wait()
            if self._closed:
                raise RuntimeError("I/O engine is shut down")
            self._inflight += nbytes
            with self._stats_lock:
                self._stats["submitted"] += 1
                self._stats["max_inflight_bytes"] = max(
                    self._stats["max_inflight_bytes"], self._inflight)
        req = IORequest(priority, next(self._seq), category, route, nbytes,
                        fn, self)
        tr = self.tracer
        if tr is not None and tr.enabled:
            req.t_submit = time.perf_counter()
        try:
            self._front.submit(req)
        except RuntimeError:
            self._release_bytes(nbytes)
            raise
        return req

    def _release_bytes(self, nbytes: int):
        with self._bp_cv:
            self._inflight -= nbytes
            self._bp_cv.notify_all()

    def _on_done(self, req: IORequest):
        self._release_bytes(req.nbytes)
        with self._stats_lock:
            self._stats["completed"] += 1
            self._stats["bytes_by_priority"][IOPriority(req.priority).name] \
                += req.nbytes

    def _on_cancelled(self, req: IORequest):
        self._release_bytes(req.nbytes)
        with self._stats_lock:
            self._stats["cancelled"] += 1

    # ---------------- chunk level ----------------
    def _with_retry(self, fn: Callable, priority: IOPriority,
                    path_index: int, route: str) -> Callable:
        """Wrap a chunk op in the bounded transient-retry loop: each
        attempt after a :func:`is_transient` fault backs off
        exponentially from ``retry_backoff_s``, bounded by BOTH the
        ``retries`` attempt budget and the op's priority-class time
        budget (:data:`RETRY_TIMEOUT_S`). The sleep runs on the owning
        path's channel thread — only the faulting device's channel
        stalls, which is the point. Permanent faults raise through
        unchanged on the first attempt."""
        budget = RETRY_TIMEOUT_S.get(priority, 0.5)

        def run():
            delay = self._retry_backoff
            spent = 0.0
            for attempt in range(self._retries + 1):
                try:
                    return fn()
                except BaseException as e:
                    if (attempt >= self._retries or not is_transient(e)
                            or spent + delay > budget):
                        raise
                    with self._stats_lock:
                        self._stats["chunk_retries"] += 1
                    with self._backlog_lock:
                        self._path_retries[path_index] += 1
                    tr = self.tracer
                    if tr is not None and tr.enabled:
                        tr.instant(threading.current_thread().name,
                                   "retry", CAT_FAULT, path=path_index,
                                   route=route, attempt=attempt + 1,
                                   error=repr(e))
                    if delay > 0:
                        time.sleep(delay)
                    spent += delay
                    delay = delay * 2 if delay > 0 else 0.0
        return run

    def submit_chunk(self, path_index: int, fn: Callable,
                     priority: IOPriority, route: str = "",
                     nbytes: int = 0) -> Future:
        """Enqueue one chunk operation on a path channel. Channels are
        leaf workers: ``fn`` must not wait on other engine work (the
        transient-retry sleeps are the one sanctioned stall — they hold
        only the faulting path's own channel). ``route``/``nbytes`` are
        accounting only — they feed the per-route and per-path
        channel-backlog counters (:meth:`route_backlog`, ``depth()``)
        the adaptive lookahead throttles on."""
        if self._retries > 0:
            fn = self._with_retry(fn, priority, path_index, route)
        req = IORequest(priority, next(self._seq), "", route, nbytes, fn,
                        None)
        tr = self.tracer
        if tr is not None and tr.enabled:
            req.t_submit = time.perf_counter()
        with self._stats_lock:
            self._stats["chunk_ops"] += 1
        with self._backlog_lock:
            if route and nbytes:
                self._route_backlog[route] = \
                    self._route_backlog.get(route, 0) + nbytes
                self._route_bytes[route] = \
                    self._route_bytes.get(route, 0) + nbytes
                per_path = self._route_path_bytes.get(route)
                if per_path is None:
                    per_path = self._route_path_bytes[route] = \
                        [0] * len(self.paths)
                per_path[path_index] += nbytes
            self._path_backlog[path_index] += 1
            self._path_backlog_bytes[path_index] += nbytes
            self._path_bytes[path_index] += nbytes
            self._path_chunk_ops[path_index] += 1

        def _done(f, route=route, nbytes=nbytes, pi=path_index):
            # fires on completion, failure, AND cancellation
            with self._backlog_lock:
                if route and nbytes:
                    self._route_backlog[route] -= nbytes
                self._path_backlog[pi] -= 1
                self._path_backlog_bytes[pi] -= nbytes
                if not f.cancelled():
                    if f.exception() is not None:
                        self._path_failures[pi] += 1
                    else:
                        self._path_failures[pi] = 0

        req.future.add_done_callback(_done)
        self._channels[path_index].submit(req)
        return req.future

    def route_backlog(self, route: str) -> int:
        """Bytes of chunk work submitted on ``route`` and not yet
        finished — the O(1) saturation signal (one lock, no heap
        scans; cheap enough to poll per plan op)."""
        with self._backlog_lock:
            return self._route_backlog.get(route, 0)

    def least_loaded_path(self) -> int:
        """Index of the path channel with the smallest queued chunk-byte
        backlog — MLP-Offload's multi-path idle-level rule as a live
        feedback signal (O(P) under one lock). Under the dynamic
        ``path_policy`` values this is no longer advisory:
        :meth:`choose_path` consumes the same backlog (rate-normalized)
        to place each newly written chunk; committed chunks keep their
        recorded placement until a full overwrite."""
        with self._backlog_lock:
            return min(range(len(self._path_backlog_bytes)),
                       key=self._path_backlog_bytes.__getitem__)

    def path_imbalance(self) -> float:
        """``max/mean`` of the per-path chunk-byte backlogs (1.0 =
        perfectly balanced; 0.0 = all paths idle). The steering-signal
        scalar the autotuner logs alongside each decision: a sustained
        imbalance says the current layout is not using some path's idle
        capacity — the ``"backlog"`` placement policy is the actuator
        that reclaims it."""
        with self._backlog_lock:
            total = sum(self._path_backlog_bytes)
            if not total:
                return 0.0
            return (max(self._path_backlog_bytes) * len(
                self._path_backlog_bytes)) / total

    # ---------------- chunk placement ----------------
    def set_path_policy(self, policy: str):
        """Switch the chunk->path placement policy at runtime (the
        autotuner's actuation point). Placement decisions already
        recorded in chunk-location tables are untouched — the policy
        governs where the NEXT full-chunk writes land."""
        if policy not in PATH_POLICIES:
            raise ValueError(
                f"path_policy {policy!r} not in {PATH_POLICIES}")
        self.path_policy = str(policy)

    def choose_path(self, nbytes: int = 0) -> int:
        """Pick the path for one chunk about to be written under the
        active dynamic policy (``StripedFiles`` calls this per placed
        chunk; meaningless under "static", which computes its layout).

        * "weighted" — deterministic rate-proportional spreading:
          argmin of (bytes this policy has placed there + nbytes) /
          path weight, weights from the per-path caps (all-equal when
          unpaced).
        * "backlog" — MLP-Offload's idle-level feedback: argmin of the
          path's queued-but-unfinished chunk bytes normalized by its
          rate weight (the time until the path drains), with the
          weighted criterion as the tie-break so an idle engine
          degrades to rate-proportional spreading.

        Paths at :data:`PATH_FAIL_DRAIN_THRESHOLD` consecutive chunk
        failures are excluded (a dead path fails fast and would
        otherwise look idle) unless every path is failing."""
        backlog = self.path_policy == "backlog"
        w = self.path_simulator.weights()
        with self._backlog_lock:
            live = [p for p in range(len(self.paths))
                    if self._path_failures[p] < PATH_FAIL_DRAIN_THRESHOLD]
            if not live:
                live = list(range(len(self.paths)))

            def score(p):
                placed = (self._placed_bytes[p] + nbytes) / w[p]
                if backlog:
                    return ((self._path_backlog_bytes[p] + nbytes) / w[p],
                            placed, p)
                return (placed, p)

            p = min(live, key=score)
            self._placed_bytes[p] += nbytes
            return p

    # ---------------- fault drain / failover ----------------
    def path_drained(self, path_index: int) -> bool:
        """True once ``path_index`` has failed
        :data:`PATH_FAIL_DRAIN_THRESHOLD` consecutive chunk ops —
        the signal ``StripedFiles`` consults to stop sending NEW
        complete-chunk writes there (any placement policy, static
        included: a dead device is a fault condition, not a layout
        choice)."""
        with self._backlog_lock:
            return (self._path_failures[path_index]
                    >= PATH_FAIL_DRAIN_THRESHOLD)

    def failover_path(self, exclude, nbytes: int = 0) -> Optional[int]:
        """Pick a surviving path for a chunk whose write just failed
        permanently on every path in ``exclude`` (or whose target is
        drained). Prefers live paths by the weighted/backlog score of
        :meth:`choose_path`; falls back to ANY non-excluded path when
        every survivor is also drained (the bytes must land somewhere,
        and a loud failure there beats silent data loss). Returns
        ``None`` only when ``exclude`` covers every path — the
        genuinely-irrecoverable case the caller escalates."""
        exclude = set(exclude)
        cands = [p for p in range(len(self.paths)) if p not in exclude]
        if not cands:
            return None
        w = self.path_simulator.weights()
        with self._backlog_lock:
            live = [p for p in cands
                    if self._path_failures[p] < PATH_FAIL_DRAIN_THRESHOLD]
            pool = live or cands
            p = min(pool, key=lambda q: (
                (self._path_backlog_bytes[q] + nbytes) / w[q],
                (self._placed_bytes[q] + nbytes) / w[q], q))
            self._placed_bytes[p] += nbytes
            return p

    def note_failover(self, from_path: int, to_path: int, name: str,
                      chunk: int):
        """Account one chunk write rerouted off a failing path (counter
        + tracer instant); called by ``StripedFiles``."""
        with self._stats_lock:
            self._stats["chunk_failovers"] += 1
        tr = self.tracer
        if tr is not None and tr.enabled:
            tr.instant(threading.current_thread().name, "failover",
                       CAT_FAULT, from_path=from_path, to_path=to_path,
                       name=name, chunk=chunk)

    def note_integrity_error(self, path_index: int, name: str, chunk: int):
        """Account one CRC mismatch (counter + tracer instant); called
        by ``StripedFiles`` just before raising ``IntegrityError``."""
        with self._stats_lock:
            self._stats["integrity_errors"] += 1
        tr = self.tracer
        if tr is not None and tr.enabled:
            tr.instant(threading.current_thread().name, "crc_mismatch",
                       CAT_FAULT, path=path_index, name=name, chunk=chunk)

    @property
    def inflight_bytes(self) -> int:
        with self._bp_cv:
            return self._inflight

    @property
    def budget_bytes(self) -> int:
        return self._budget

    # ---------------- accounting ----------------
    def depth(self) -> dict:
        """Thread-safe live queue-depth snapshot (introspection /
        diagnostics; the executor's per-hint saturation check uses the
        O(1) ``inflight_bytes`` / :meth:`route_backlog` accessors that
        feed the same numbers).

        Keys: ``queued`` (requests waiting in the front heap),
        ``running`` (request bodies currently executing),
        ``queued_by_priority`` (name -> count),
        ``queued_bytes_by_route`` (route -> request bytes waiting),
        ``channel_queued`` / ``channel_queued_bytes_by_route`` (chunk
        ops on the path channels, submitted and unfinished),
        ``channel_backlog_per_path`` / ``channel_backlog_bytes_per_path``
        (the same backlog split per SSD path, index = path),
        ``inflight_bytes`` / ``budget_bytes`` (the backpressure
        budget), and ``utilization`` (inflight / budget)."""
        with self._front._cv:
            heap = list(self._front._heap)
            running = self._front._running
        qbp = {p.name: 0 for p in IOPriority}
        qbr: Dict[str, int] = {}
        for req in heap:
            if req.future.cancelled():
                continue
            qbp[IOPriority(req.priority).name] += 1
            if req.route:
                qbr[req.route] = qbr.get(req.route, 0) + req.nbytes
        ch_n = 0
        for ch in self._channels:
            with ch._cv:
                ch_n += len(ch._heap)
        with self._backlog_lock:
            ch_bytes = {r: n for r, n in self._route_backlog.items() if n}
            path_backlog = list(self._path_backlog)
            path_backlog_bytes = list(self._path_backlog_bytes)
        with self._bp_cv:
            inflight = self._inflight
        return {
            "queued": len(heap), "running": running,
            "queued_by_priority": qbp, "queued_bytes_by_route": qbr,
            "channel_queued": ch_n,
            "channel_queued_bytes_by_route": ch_bytes,
            "channel_backlog_per_path": path_backlog,
            "channel_backlog_bytes_per_path": path_backlog_bytes,
            "inflight_bytes": inflight, "budget_bytes": self._budget,
            "utilization": inflight / self._budget if self._budget else 0.0,
        }

    def throttle(self, route: str, nbytes: int):
        """Pace a transfer on a simulated-bandwidth route (no-op when the
        route has no configured cap)."""
        self.simulator.throttle(route, nbytes)

    def throttle_path(self, path_index: int, nbytes: int):
        """Pace a chunk against its SSD path's simulated device cap
        (no-op without ``IOConfig.path_bandwidth``). Applied in
        addition to the route cap — a chunk pays every cap it
        crosses."""
        self.path_simulator.throttle(path_index, nbytes)

    def _collect_stats(self) -> dict:
        """Cumulative counters (the aggregate keys are stable; the
        ``*_per_path`` lists — index = path — are the per-path
        bandwidth evidence the placement policies and the perf model's
        snapshot ingestion read). ``chunk_bytes_by_route_per_path``
        splits each route's cumulative chunk bytes across paths;
        placement only moves bytes BETWEEN paths, so each list must sum
        exactly to the route's total (``obs.reconcile`` checks this)."""
        with self._stats_lock:
            s = {k: (dict(v) if isinstance(v, dict) else v)
                 for k, v in self._stats.items()}
        with self._backlog_lock:
            s["chunk_bytes_per_path"] = list(self._path_bytes)
            s["chunk_ops_per_path"] = list(self._path_chunk_ops)
            s["chunk_bytes_by_route"] = dict(self._route_bytes)
            s["chunk_bytes_by_route_per_path"] = {
                r: list(v) for r, v in self._route_path_bytes.items()}
            s["path_failures"] = list(self._path_failures)
            s["chunk_retries_per_path"] = list(self._path_retries)
            s["paths_drained"] = [f >= PATH_FAIL_DRAIN_THRESHOLD
                                  for f in self._path_failures]
        s["path_policy"] = self.path_policy
        s["path_bandwidth"] = [self.path_simulator.cap(i)
                               for i in range(len(self.paths))]
        s["inflight_bytes"] = self._inflight
        s["num_paths"] = len(self.paths)
        s["staging_oversized_allocs"] = self.staging.oversized_allocs
        return s

    def metrics_snapshot(self) -> dict:
        """Versioned counter snapshot — the one supported metrics
        surface (same schema as :func:`_collect_stats` plus a
        ``version`` key tracking ``repro.obs.SNAPSHOT_VERSION``)."""
        from repro.obs.registry import SNAPSHOT_VERSION
        return {"version": SNAPSHOT_VERSION, **self._collect_stats()}

    def stats(self) -> dict:
        """Deprecated alias for :func:`metrics_snapshot` (without the
        ``version`` key). Will be removed after the deprecation window
        noted in CHANGES.md."""
        import warnings
        warnings.warn(
            "IOEngine.stats() is deprecated; use metrics_snapshot()",
            DeprecationWarning, stacklevel=2)
        return self._collect_stats()

    # ---------------- lifecycle ----------------
    def shutdown(self, wait: bool = True):
        with self._bp_cv:
            self._closed = True
            self._bp_cv.notify_all()
        self._front.shutdown(wait)
        for ch in self._channels:
            ch.shutdown(wait)
