"""Token-bucket bandwidth pacing, per transfer route and per SSD path.

The container's filesystem is far faster than the SSDs the paper models,
so byte counters alone cannot validate the perf model's *time*
predictions. Two independent simulators pace the chunk stream:

* :class:`BandwidthSimulator` — one bucket per ROUTE
  (``IOConfig.bandwidth``): models a shared link (the PCIe/NVMe fabric
  every path rides).
* :class:`PathBandwidthSimulator` — one bucket per PATH
  (``IOConfig.path_bandwidth``, index = path), shared by that path's
  reads and writes: models per-DEVICE speed, including heterogeneous
  path sets (a fast and a slow NVMe behind one stripe). This is the
  regime where chunk->path placement (``IOConfig.path_policy``)
  matters: static striping pins the aggregate at P x min(cap), while
  backlog-aware placement approaches sum(caps).

Both apply per chunk, before the syscall; a chunk pays each configured
cap it crosses. `bench_io` measures the achieved rates against the
caps, turning `repro.core.perfmodel` rooflines into wall-clock
observables.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Mapping, Optional, Sequence


class TokenBucket:
    """Classic token bucket: ``rate`` bytes/s refill, ``burst`` bytes
    capacity. ``consume(n)`` may overdraw the bucket and then sleeps off
    the deficit, so the *aggregate* rate across any number of threads
    converges to ``rate`` while short transfers keep sub-burst latency.
    """

    def __init__(self, rate: float, burst: Optional[float] = None):
        if rate <= 0:
            raise ValueError(f"TokenBucket rate must be > 0, got {rate}")
        self.rate = float(rate)
        self.burst = float(burst) if burst is not None else max(rate / 64.0,
                                                                1 << 16)
        self._tokens = self.burst
        self._t = time.perf_counter()
        self._lock = threading.Lock()

    def consume(self, nbytes: int):
        if nbytes <= 0:
            return
        with self._lock:
            now = time.perf_counter()
            self._tokens = min(self.burst,
                               self._tokens + (now - self._t) * self.rate)
            self._t = now
            self._tokens -= nbytes
            wait = -self._tokens / self.rate if self._tokens < 0 else 0.0
        if wait > 0:
            time.sleep(wait)


class BandwidthSimulator:
    """Per-route token buckets built from an ``IOConfig.bandwidth`` map.
    Unconfigured routes pass through untouched."""

    def __init__(self, caps: Mapping[str, float]):
        self._buckets: Dict[str, TokenBucket] = {
            route: TokenBucket(bw) for route, bw in caps.items() if bw}

    def throttle(self, route: str, nbytes: int):
        b = self._buckets.get(route)
        if b is not None:
            b.consume(nbytes)

    def cap(self, route: str) -> Optional[float]:
        b = self._buckets.get(route)
        return b.rate if b is not None else None

    def __bool__(self) -> bool:
        return bool(self._buckets)


class PathBandwidthSimulator:
    """Per-path token buckets built from an ``IOConfig.path_bandwidth``
    sequence (index = path; ``None`` = no per-path pacing). Each path's
    bucket is shared by its reads and writes — a device cap, not a
    route cap. Doubles as the rate-weight source for the
    "weighted"/"backlog" placement policies (:meth:`weights`)."""

    def __init__(self, caps: Optional[Sequence[float]], n_paths: int):
        caps = list(caps) if caps else []
        if caps and len(caps) != n_paths:
            raise ValueError(
                f"path_bandwidth has {len(caps)} cap(s) for "
                f"{n_paths} path(s)")
        self._caps = [float(c) for c in caps]
        self._buckets: List[Optional[TokenBucket]] = [
            TokenBucket(c) for c in self._caps] if caps else \
            [None] * n_paths

    def throttle(self, path_index: int, nbytes: int):
        b = self._buckets[path_index]
        if b is not None:
            b.consume(nbytes)

    def cap(self, path_index: int) -> Optional[float]:
        return self._caps[path_index] if self._caps else None

    def weights(self) -> List[float]:
        """Relative placement weights, one per path: the configured
        caps, or all-equal when no per-path pacing is set."""
        return list(self._caps) if self._caps \
            else [1.0] * len(self._buckets)

    def __bool__(self) -> bool:
        return bool(self._caps)
