"""Token-bucket bandwidth pacing per transfer route.

The container's filesystem is far faster than the SSDs the paper models,
so byte counters alone cannot validate the perf model's *time*
predictions. The simulator paces each configured route to a target
bytes/s, turning `repro.core.perfmodel` rooflines into wall-clock
observables (bench_io measures the achieved rate against the cap).
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Mapping, Optional


class TokenBucket:
    """Classic token bucket: ``rate`` bytes/s refill, ``burst`` bytes
    capacity. ``consume(n)`` may overdraw the bucket and then sleeps off
    the deficit, so the *aggregate* rate across any number of threads
    converges to ``rate`` while short transfers keep sub-burst latency.
    """

    def __init__(self, rate: float, burst: Optional[float] = None):
        if rate <= 0:
            raise ValueError(f"TokenBucket rate must be > 0, got {rate}")
        self.rate = float(rate)
        self.burst = float(burst) if burst is not None else max(rate / 64.0,
                                                                1 << 16)
        self._tokens = self.burst
        self._t = time.perf_counter()
        self._lock = threading.Lock()

    def consume(self, nbytes: int):
        if nbytes <= 0:
            return
        with self._lock:
            now = time.perf_counter()
            self._tokens = min(self.burst,
                               self._tokens + (now - self._t) * self.rate)
            self._t = now
            self._tokens -= nbytes
            wait = -self._tokens / self.rate if self._tokens < 0 else 0.0
        if wait > 0:
            time.sleep(wait)


class BandwidthSimulator:
    """Per-route token buckets built from an ``IOConfig.bandwidth`` map.
    Unconfigured routes pass through untouched."""

    def __init__(self, caps: Mapping[str, float]):
        self._buckets: Dict[str, TokenBucket] = {
            route: TokenBucket(bw) for route, bw in caps.items() if bw}

    def throttle(self, route: str, nbytes: int):
        b = self._buckets.get(route)
        if b is not None:
            b.consume(nbytes)

    def cap(self, route: str) -> Optional[float]:
        b = self._buckets.get(route)
        return b.rate if b is not None else None

    def __bool__(self) -> bool:
        return bool(self._buckets)
