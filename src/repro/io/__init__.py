"""`repro.io` — the async multi-path I/O engine under the offload stack.

Design note
===========

GreedySnake's speedups are storage-orchestration speedups: keeping the
SSD link saturated, fetching the next layer's parameters before the GPU
stalls, and hiding optimizer-state I/O under compute. The seed
implementation expressed that orchestration as ad-hoc
``ThreadPoolExecutor`` futures in the offload engine and coordinators —
no notion that a critical-path parameter fetch should preempt a
deferrable checkpoint spill, no chunking, one hard-coded SSD path, and
no way to model bandwidth. This package replaces that with a real
subsystem; everything in ``repro.offload`` now moves bytes through it.

Layering (arrows = "submits to"). Above the coordinators sits the
schedule IR: ``repro.core.plan`` compiles the vertical / horizontal /
wave schedule into a linear op stream ONCE, and the one plan executor
(``repro.offload.executor``) walks it — every op below the compute ops
is a coordinator call, and every coordinator call becomes engine
requests here:

    repro.core.plan (compile_* -> Plan)   repro.core.plan.plan_traffic
              |                                 (static byte prediction,
              v  repro.offload.executor          == the meters below)
    ParameterCoordinator / InterLayerTensorCoordinator /
    OptimizerStepCoordinator / ActivationCoordinator
                                      SSDStore / TieredVector
              |                                |
              v  IOEngine.submit (request)     v  chunk ops
        [priority heap -> worker pool]   [per-path channel threads]
              |                                ^
              +---- request bodies ------------+

How plan ops map to request priorities
(:data:`~repro.io.engine.CATEGORY_PRIORITY`):

* ``PREFETCH(l)`` hints — derived by the plan compiler's lookahead
  pass, one per ``FETCH_PARAM``/``ALLGATHER``, placed right after the
  previous fetch and never across a ``RESET_PARAMS`` — submit at
  ``PARAM_FETCH`` (top) priority: the GPU will block on them next.
* ``SPILL_GRAD``/``FETCH_GRAD`` traffic is ``INTER_LAYER_GRAD``; the
  wave schedule's cross-wave ``GRAD_SPILL``/``GRAD_FETCH_ACC`` buffer
  swaps pace at the same level (category ``grad``).
* ``OPT_LATE`` / ``WRITEBACK_GRAD`` optimizer segments run as
  ``OPTIMIZER_STATE`` requests whose tiered-vector chunk ops yield to
  parameter fetches on the same paths (the α-delay gate makes a fetch
  WAIT on a flush, which is why the engine keeps >= 3 workers).
* ``SPILL_CKPT`` tails are ``CKPT_SPILL``: deferrable until a
  ``FETCH_CKPT_BWD`` actually needs them.
* ``SPILL_ACT``/``FETCH_ACT`` — the SSDTrain-style activation stream
  (``OffloadConfig.activation_policy="spill"``) — run at ``ACT``, the
  bottom class: each layer's vjp residuals ride out after its forward
  and back in ahead of its backward INSTEAD of being recomputed from
  the boundary checkpoint, so the stream exists precisely to soak up
  write bandwidth nothing urgent wants. ``PREFETCH_ACT`` hints come
  from the same lookahead pass (one per fetch, never across a
  ``RESET_PARAMS``). Failure degrades softly: the checkpoint tier is
  untouched, so a failed spill or fetch falls back to recomputing that
  one micro-batch — with bitwise-identical results, because BOTH
  policies run backward from the same residuals (restored or
  recomputed). The byte closed forms are
  ``repro.core.traffic.act_spill_traffic`` and the ``act_spill=True``
  variants of the ckpt forms; ``plan_traffic`` predicts the meters
  exactly, and ``perfmodel``/``lp_search`` price spill-vs-recompute so
  ``"auto"`` can pick per machine (the ``act-battery`` CI suite pins
  all three legs).

* :class:`~repro.io.engine.IOEngine` — request-level scheduler. Each
  request carries a category/route (shared vocabulary with the
  ``TrafficMeter``), a byte count for the bounded in-flight budget
  (backpressure), a priority from
  :class:`~repro.io.engine.IOPriority` (param-fetch >
  inter-layer-grad > optimizer-state > ckpt-spill), and a completion
  future supporting cancellation
  (:meth:`~repro.io.engine.IORequest.cancel`).
* :class:`~repro.io.backend.StripedFiles` — chunk-level executor:
  tensors are cut into ``chunk_bytes`` chunks striped round-robin over
  N configured paths (MLP-Offload-style multi-path), one channel
  thread per path, positioned I/O on cached fds. On this container's
  2 cores, 2-path striping already beats single-path writes by ~1.5x
  (see ``benchmarks/bench_io.py``).
* :class:`~repro.io.bandwidth.BandwidthSimulator` — optional per-route
  token buckets (``gpu<->cpu``, ``cpu<->ssd``) so the roofline/LP
  predictions of :mod:`repro.core.perfmodel` can be checked in
  wall-clock on hardware much faster than the paper's SSDs
  (``repro.core.perfmodel.machine_from_bandwidth`` builds the matching
  ``MachineParams``).
* :class:`~repro.io.staging.StagingPool` — double-buffered host staging
  for asynchronous spills; ``acquire`` blocking when both buffers are
  in flight is the second backpressure layer.

Deadlock discipline: channel ops are leaves (never wait); request
bodies may wait only on channel ops and on α-delay *gates* (a param
fetch waiting on an optimizer flush), which is why the engine keeps at
least two request workers.

Per-rank engine layering (data parallelism)
===========================================

The data-parallel offload engine (``repro.offload.dp``) instantiates
the WHOLE stack above once per rank: rank r gets its own ``IOEngine``
over its own path subset (:meth:`~repro.io.config.IOConfig.
shard_for_rank`: paths ``r, r+R, ...``), its own meter/host/staging
state, and shard-length tiered vectors. Nothing above this package is
shared between ranks, so R rank engines drive R disjoint path sets
concurrently — that is the N-GPUs-×-N-SSD-paths aggregate-bandwidth
lever (``benchmarks/bench_dp.py``).

Rank-sharding invariants the test battery pins down
(``tests/test_dp_offload.py``, ``tests/test_property.py``):

* every tiered vector is split into CONTIGUOUS element ranges covering
  [0, P) (``repro.offload.dp.shard_bounds``) — elementwise ops (Adam,
  gradient accumulation) commute bitwise with the split;
* collectives fold contributions in GLOBAL micro-batch order, so an
  R-rank run is bit-identical (f32) to the single-rank engine;
* per-rank byte counters equal the ``dp_vertical_traffic`` closed
  forms exactly (shard storage I/O ``∝ 1/R``, ring collective traffic
  ``∝ (R-1)/R``);
* a rank's chunk ops never leave its own path set (stripe files land
  only under the owning rank's directories).

Fault discipline: a failed chunk op propagates through the request
future (``IORequest.result``), releases the in-flight byte budget and
its staging buffer, and never kills a worker thread — the
fault-injection suite (``tests/test_io_faults.py``) drives these paths
through an on-demand-failing backend (``StripedFiles._pread/_pwrite``
are the designated override points).

Follow-ons this unlocks are tracked in ROADMAP.md (NCCL-backed
collectives, uneven-rank sharding, an io_uring backend, NVMe-oF paths,
serving-time KV-cache reuse).
"""
from repro.io.backend import StripedFiles  # noqa: F401
from repro.io.bandwidth import BandwidthSimulator, TokenBucket  # noqa: F401
from repro.io.config import IOConfig  # noqa: F401
from repro.io.engine import (CATEGORY_PRIORITY, IOEngine,  # noqa: F401
                             IOPriority, IORequest)
from repro.io.staging import StagedBuffer, StagingPool  # noqa: F401
