"""`repro.io` — the async multi-path I/O engine under the offload stack.

Design note
===========

GreedySnake's speedups are storage-orchestration speedups: keeping the
SSD link saturated, fetching the next layer's parameters before the GPU
stalls, and hiding optimizer-state I/O under compute. The seed
implementation expressed that orchestration as ad-hoc
``ThreadPoolExecutor`` futures in the offload engine and coordinators —
no notion that a critical-path parameter fetch should preempt a
deferrable checkpoint spill, no chunking, one hard-coded SSD path, and
no way to model bandwidth. This package replaces that with a real
subsystem; everything in ``repro.offload`` now moves bytes through it.

Layering (arrows = "submits to"). Above the coordinators sits the
schedule IR: ``repro.core.plan`` compiles the vertical / horizontal /
wave schedule into a linear op stream ONCE, and the one plan executor
(``repro.offload.executor``) walks it — every op below the compute ops
is a coordinator call, and every coordinator call becomes engine
requests here:

    repro.core.plan (compile_* -> Plan)   repro.core.plan.plan_traffic
              |                                 (static byte prediction,
              v  repro.offload.executor          == the meters below)
    ParameterCoordinator / InterLayerTensorCoordinator /
    OptimizerStepCoordinator / ActivationCoordinator
                                      SSDStore / TieredVector
              |                                |
              v  IOEngine.submit (request)     v  chunk ops
        [priority heap -> worker pool]   [per-path channel threads]
              |                                ^
              +---- request bodies ------------+

The unified cross-stream lookahead + backpressure loop
======================================================

``repro.core.plan.insert_prefetch`` derives ONE hint op per fetch-class
op for every stream that can touch the SSD — ``PREFETCH`` per
``FETCH_PARAM``/``ALLGATHER``, ``PREFETCH_CKPT`` per backward
checkpoint-tail re-read, ``PREFETCH_ACT`` per activation-residual
fetch, and ``PREFETCH_OPT`` per α-tail ``OPT_LATE`` flush — each
placed ``prefetch_depth`` same-stream fetches ahead of its consumer
and never across a ``RESET_PARAMS``. The α-tail flushes themselves
ride the plan EPILOGUE (the cross-iteration seam): iteration i's
optimizer tail is submitted at the end of iteration i, so it is in
flight together with iteration i+1's first parameter fetches, gated
(not plan-ordered) for correctness.

Hints are pure scheduling: each submits the owning coordinator's
asynchronous read early and moves NO bytes of its own, so a hinted
plan's ``plan_traffic`` prediction — and every live meter — equals the
bare plan's exactly, and results stay bitwise-identical (f32) with the
lookahead on, off, or at any depth (``tests/test_lookahead.py`` pins
the whole grid).

The loop closes through :meth:`~repro.io.engine.IOEngine.depth`, the
thread-safe live queue snapshot (request heap by priority, per-route
channel-chunk backlog, in-flight bytes vs the backpressure budget).
Before issuing any hint the executor consults it and SKIPS the hint
when the link is saturated — MLP-Offload's idle-level rule: prefetch
only INTO idle bandwidth; a read issued against a standing backlog
cannot finish early, it just steals link time from whatever the GPU
blocks on next. Skipping is always legal (hints are byte-neutral), so
adaptivity costs nothing in determinism of results or counters. Under
``activation_policy="auto"`` the same signal gates each ``SPILL_ACT``
per (layer, micro-batch): a saturated write queue degrades that one
residual to the recompute path — still bitwise-identical, because both
policies run backward from the same vjp residuals.

How plan ops map to request priorities
(:data:`~repro.io.engine.CATEGORY_PRIORITY`):

* ``PREFETCH(l)`` hints submit at ``PARAM_FETCH`` (top) priority: the
  GPU will block on them next. The prefetch body performs only the
  SSD -> host stage; the host -> device copy stays on the consumer
  thread (an engine worker doing device copies would steal CPU from
  the compute the lookahead is protecting). A hint whose α gate is
  not READY — the gating flush still queued, so waiting on it would
  be unbounded — is refused by the coordinator (``set_gate``'s
  readiness probe): a burst of ``prefetch_depth`` gated fetch bodies
  outranking the queued flushes could otherwise occupy every request
  worker and leave none to run the flushes they wait on.
* ``SPILL_GRAD``/``FETCH_GRAD`` traffic is ``INTER_LAYER_GRAD``; the
  wave schedule's cross-wave ``GRAD_SPILL``/``GRAD_FETCH_ACC`` buffer
  swaps pace at the same level (category ``grad``).
* ``OPT_LATE`` / ``WRITEBACK_GRAD`` optimizer segments run as
  ``OPTIMIZER_STATE`` requests whose tiered-vector chunk ops yield to
  parameter fetches on the same paths (the α-delay gate makes a fetch
  WAIT on a flush, which is why the engine keeps >= 3 workers).
  ``PREFETCH_OPT`` state reads share the class; a flush consumes a
  landed prefetch's arrays, cancels a still-queued one (no bytes
  moved), and only ever waits on a running-or-done request — the
  bounded-wait discipline that keeps the worker pool deadlock-free.
* ``SPILL_CKPT`` tails are ``CKPT_SPILL``: deferrable until a
  ``FETCH_CKPT_BWD`` actually needs them — whose ``PREFETCH_CKPT``
  hint streams the tail back in behind the previous micro-batch's
  backward instead of blocking the executor at the fetch.
* ``SPILL_KV``/``FETCH_KV`` — the serving-time KV-block stream
  (``repro.serve``) — run at ``KV``, between the optimizer-state and
  ckpt-spill classes: a resumed request's next decode step blocks on
  its ``FETCH_KV`` (so KV outranks the deferrable spill tails), but a
  training-style param fetch sharing the paths must still win (mixed
  tenancy). KV payloads move as fixed ``kv_block_bytes`` blocks — a
  unit's cache padded to whole blocks, the warm ``round(x_host *
  blocks)`` head held in host DRAM and only the cold tail touching
  SSD (TieredVector's split at block granularity) — and
  ``PREFETCH_KV`` hints come from the SAME lookahead pass as training
  hints, with every ``SPILL_KV`` acting as a hint barrier so no read
  is queued across the eviction that makes the tiers authoritative.
  ``APPEND_KV`` is a device-HBM block-table write: zero offload
  bytes. The closed form is ``repro.core.traffic.kv_traffic``;
  ``plan_traffic`` and the serve meters must agree with it exactly
  (the ``tests/test_serve.py`` three-way sweep and the bench-smoke
  ``serve_ok`` gate pin this).
* ``SPILL_ACT``/``FETCH_ACT`` — the SSDTrain-style activation stream
  (``OffloadConfig.activation_policy="spill"``) — run at ``ACT``, the
  bottom class: each layer's vjp residuals ride out after its forward
  and back in ahead of its backward INSTEAD of being recomputed from
  the boundary checkpoint, so the stream exists precisely to soak up
  write bandwidth nothing urgent wants. Failure degrades softly: the
  checkpoint tier is untouched, so a failed spill or fetch falls back
  to recomputing that one micro-batch — with bitwise-identical
  results, because BOTH policies run backward from the same residuals
  (restored or recomputed). The byte closed forms are
  ``repro.core.traffic.act_spill_traffic`` and the ``act_spill=True``
  variants of the ckpt forms; ``plan_traffic`` predicts the meters
  exactly, and ``perfmodel``/``lp_search`` price spill-vs-recompute
  (now with ``lookahead=``-aware stall terms) so ``"auto"`` can pick
  per machine (the ``act-battery`` CI suite pins all three legs).

* :class:`~repro.io.engine.IOEngine` — request-level scheduler. Each
  request carries a category/route (shared vocabulary with the
  ``TrafficMeter``), a byte count for the bounded in-flight budget
  (backpressure), a priority from
  :class:`~repro.io.engine.IOPriority` (param-fetch >
  inter-layer-grad > optimizer-state > ckpt-spill), and a completion
  future supporting cancellation
  (:meth:`~repro.io.engine.IORequest.cancel`).
* :class:`~repro.io.backend.StripedFiles` — chunk-level executor:
  tensors are cut into ``chunk_bytes`` chunks over N configured paths
  (MLP-Offload-style multi-path), one channel thread per path,
  positioned I/O on cached fds. Chunk -> path assignment is a
  SCHEDULED decision, not a layout constant: under
  ``IOConfig.path_policy="static"`` chunk ``i`` lives at the classic
  ``i % P`` stripe (bit-for-bit the pre-policy layout, zero placement
  state); under ``"weighted"``/``"backlog"`` every full-chunk write
  asks :meth:`~repro.io.engine.IOEngine.choose_path` where to land —
  rate-proportional spreading, or MLP-Offload's idle-level feedback
  (least normalized backlog) — and records the decision in a
  per-tensor chunk-location table persisted as a JSON sidecar next to
  the stripe files. On the paced 4:1 two-path device in
  ``benchmarks/bench_io.py``, backlog placement writes at ~sum-of-caps
  where static pays 2x the slow cap; ``check_smoke.py`` gates the
  engine-level A/B at >= 1.3x tokens/s.
* :class:`~repro.io.bandwidth.BandwidthSimulator` — optional token
  buckets per route (``gpu<->cpu``, ``cpu<->ssd``) AND per path
  (``IOConfig.path_bandwidth``, heterogeneous device caps), so the
  roofline/LP predictions of :mod:`repro.core.perfmodel` can be
  checked in wall-clock on hardware much faster than the paper's SSDs
  (``repro.core.perfmodel.machine_from_bandwidth`` builds the matching
  ``MachineParams``; ``machine_from_snapshot`` ingests the tracer's
  per-path achieved rates, and ``machine_for_path_policy`` prices a
  heterogeneous device as P x min(rates) under static striping vs
  sum-of-rates under dynamic placement — the spread the autotuner's
  ``path_policy`` candidate axis steers by).
* :class:`~repro.io.staging.StagingPool` — double-buffered host staging
  for asynchronous spills; ``acquire`` blocking when both buffers are
  in flight is the second backpressure layer.

Deadlock discipline: channel ops are leaves (never wait); request
bodies may wait only on channel ops and on α-delay *gates* (a param
fetch waiting on an optimizer flush), which is why the engine keeps at
least two request workers.

Per-rank engine layering (data parallelism)
===========================================

The data-parallel offload engine (``repro.offload.dp``) instantiates
the WHOLE stack above once per rank: rank r gets its own ``IOEngine``
over its own path subset (:meth:`~repro.io.config.IOConfig.
shard_for_rank`: paths ``r, r+R, ...``, with the matching
``path_bandwidth`` caps sliced alongside so a rank's placement policy
sees its own devices' rates), its own meter/host/staging state, and
shard-length tiered vectors. Nothing above this package is
shared between ranks, so R rank engines drive R disjoint path sets
concurrently — that is the N-GPUs-×-N-SSD-paths aggregate-bandwidth
lever (``benchmarks/bench_dp.py``).

Rank-sharding invariants the test battery pins down
(``tests/test_dp_offload.py``, ``tests/test_property.py``):

* every tiered vector is split into CONTIGUOUS element ranges covering
  [0, P) (``repro.offload.dp.shard_bounds``) — elementwise ops (Adam,
  gradient accumulation) commute bitwise with the split;
* collectives fold contributions in GLOBAL micro-batch order, so an
  R-rank run is bit-identical (f32) to the single-rank engine;
* per-rank byte counters equal the ``dp_vertical_traffic`` closed
  forms exactly (shard storage I/O ``∝ 1/R``, ring collective traffic
  ``∝ (R-1)/R``);
* a rank's chunk ops never leave its own path set (stripe files land
  only under the owning rank's directories).

Fault discipline: integrity, retry, failover
============================================

A chunk op's fault walks a fixed escalation ladder; each rung acts only
when the rung below could not, and every rung is observable
(``chunk_retries`` / ``chunk_failovers`` / ``integrity_errors`` +
per-path splits in ``metrics_snapshot()``, ``io.fault`` tracer
instants):

1. **Classify** (:func:`~repro.io.engine.is_transient`): EAGAIN /
   EINTR / ETIMEDOUT-class errnos and first-round CRC mismatches are
   TRANSIENT — the same op against the same device can legitimately
   succeed a moment later. EIO, ENOSPC, short reads, and dead devices
   are PERMANENT. An explicit ``transient`` attribute on the exception
   overrides the heuristic (the chaos backend stamps it; a real
   NVMe-oF transport could too).
2. **Retry** (``IOConfig.retries``, on by default): a transient fault
   gets bounded re-attempts with exponential backoff from
   ``retry_backoff_s``, capped by BOTH the attempt budget and the op's
   priority-class time budget (:data:`~repro.io.engine.
   RETRY_TIMEOUT_S` — a critical-path param fetch gives up in 250 ms,
   a deferrable spill may ride out a 1 s brownout). The backoff sleeps
   on the faulting path's own channel thread, so only that device
   stalls. A retried op moves the same bytes to the same slot, and
   meters are recorded once at submit — retries are invisible to the
   byte accounting and to (f32) bitwise results.
3. **Fail over** (writes only): a PERMANENT write failure on a
   COMPLETE chunk — one whose caller-held buffer is authoritative for
   every byte — re-places the chunk on a surviving path
   (:meth:`~repro.io.engine.IOEngine.failover_path`) and re-writes it
   from that buffer, recording the move in the chunk-location table.
   A path at ``PATH_FAIL_DRAIN_THRESHOLD`` consecutive failures is
   also avoided PRE-emptively for new complete-chunk writes under
   every policy, static included, and the dynamic policies stop
   choosing it (a dead device fails fast, so its backlog alone would
   make it look attractively idle). Reads are NEVER rerouted: a
   chunk's only copy lives where the table says, so a dead-path read
   fails loudly rather than silently substituting garbage.
4. **Verify** (``IOConfig.integrity``): complete-chunk writes record a
   CRC32C of the intended bytes in the sidecar; complete-chunk reads
   verify and raise :class:`~repro.io.integrity.IntegrityError` on
   mismatch — torn writes and silent corruption surface at the read
   that would otherwise feed garbage to training, not steps later in
   a diverged loss.
5. **Propagate**: whatever survives the ladder fails loudly through
   the request future (``IORequest.result``), releasing the in-flight
   byte budget and any staging buffer, never killing a worker thread.
   Above the engine, the offload coordinators unwind to a clean state
   (the fault batteries pin budget/staging/tracking leaks at every
   priority class), and crash-consistent checkpoints
   (``OffloadEngine.save_checkpoint``: journaled manifest via atomic
   rename, per-tensor CRCs, torn/stale-manifest rejection) bound the
   blast radius of the genuinely irrecoverable case.

Fault injection is first-class: :class:`~repro.io.chaos.ChaosFiles`
(``repro.io.chaos``) subclasses the backend at its designated override
points (``StripedFiles._pread/_pwrite``) with deterministic countdown
fuses, name-targeted fuses, scripted path death, and a seeded
probabilistic :class:`~repro.io.chaos.ChaosSpec` (transient errors,
latency spikes, torn writes, bit flips) — the same injector drives the
fault batteries (``tests/test_io_faults.py``, ``tests/test_chaos.py``),
the degraded-mode benchmark cells, and ad-hoc chaos drills via
:func:`~repro.io.chaos.install_chaos`.

Follow-ons this unlocks are tracked in ROADMAP.md (NCCL-backed
collectives, uneven-rank sharding, an io_uring backend, NVMe-oF remote
path entries riding the per-path pacing/placement machinery — remote
transport faults now have a classification/retry/failover ladder to
plug into).
Serving-time KV-cache reuse landed as ``repro.serve`` (the ``KV``
priority class above).
"""
from repro.io.backend import StripedFiles  # noqa: F401
from repro.io.bandwidth import BandwidthSimulator, TokenBucket  # noqa: F401
from repro.io.chaos import ChaosFiles, ChaosSpec, install_chaos  # noqa: F401
from repro.io.config import IOConfig  # noqa: F401
from repro.io.engine import (CATEGORY_PRIORITY, IOEngine,  # noqa: F401
                             IOPriority, IORequest, is_transient)
from repro.io.integrity import IntegrityError, crc32c  # noqa: F401
from repro.io.staging import StagedBuffer, StagingPool  # noqa: F401
