"""`repro.io` — the async multi-path I/O engine under the offload stack.

Design note
===========

GreedySnake's speedups are storage-orchestration speedups: keeping the
SSD link saturated, fetching the next layer's parameters before the GPU
stalls, and hiding optimizer-state I/O under compute. The seed
implementation expressed that orchestration as ad-hoc
``ThreadPoolExecutor`` futures in the offload engine and coordinators —
no notion that a critical-path parameter fetch should preempt a
deferrable checkpoint spill, no chunking, one hard-coded SSD path, and
no way to model bandwidth. This package replaces that with a real
subsystem; everything in ``repro.offload`` now moves bytes through it.

Layering (arrows = "submits to"):

    ParameterCoordinator / InterLayerTensorCoordinator /
    OptimizerStepCoordinator          SSDStore / TieredVector
              |                                |
              v  IOEngine.submit (request)     v  chunk ops
        [priority heap -> worker pool]   [per-path channel threads]
              |                                ^
              +---- request bodies ------------+

* :class:`~repro.io.engine.IOEngine` — request-level scheduler. Each
  request carries a category/route (shared vocabulary with the
  ``TrafficMeter``), a byte count for the bounded in-flight budget
  (backpressure), a priority from
  :class:`~repro.io.engine.IOPriority` (param-fetch >
  inter-layer-grad > optimizer-state > ckpt-spill), and a completion
  future supporting cancellation
  (:meth:`~repro.io.engine.IORequest.cancel`).
* :class:`~repro.io.backend.StripedFiles` — chunk-level executor:
  tensors are cut into ``chunk_bytes`` chunks striped round-robin over
  N configured paths (MLP-Offload-style multi-path), one channel
  thread per path, positioned I/O on cached fds. On this container's
  2 cores, 2-path striping already beats single-path writes by ~1.5x
  (see ``benchmarks/bench_io.py``).
* :class:`~repro.io.bandwidth.BandwidthSimulator` — optional per-route
  token buckets (``gpu<->cpu``, ``cpu<->ssd``) so the roofline/LP
  predictions of :mod:`repro.core.perfmodel` can be checked in
  wall-clock on hardware much faster than the paper's SSDs
  (``repro.core.perfmodel.machine_from_bandwidth`` builds the matching
  ``MachineParams``).
* :class:`~repro.io.staging.StagingPool` — double-buffered host staging
  for asynchronous spills; ``acquire`` blocking when both buffers are
  in flight is the second backpressure layer.

Deadlock discipline: channel ops are leaves (never wait); request
bodies may wait only on channel ops and on α-delay *gates* (a param
fetch waiting on an optimizer flush), which is why the engine keeps at
least two request workers.

Follow-ons this unlocks are tracked in ROADMAP.md (multi-GPU striping,
an io_uring backend, NVMe-oF paths, serving-time KV-cache reuse).
"""
from repro.io.backend import StripedFiles  # noqa: F401
from repro.io.bandwidth import BandwidthSimulator, TokenBucket  # noqa: F401
from repro.io.config import IOConfig  # noqa: F401
from repro.io.engine import (CATEGORY_PRIORITY, IOEngine,  # noqa: F401
                             IOPriority, IORequest)
from repro.io.staging import StagedBuffer, StagingPool  # noqa: F401
