"""Bounded host staging pool for asynchronous spills.

An async offload (e.g. a checkpoint tail headed to SSD) must not keep the
producer's buffer alive until the write completes. The pool hands out a
fixed set of reusable host buffers: the caller memcpys into one, submits
the write, and the completion releases it. With ``nbuf=2`` this is the
classic double-buffer: one buffer drains to SSD while the next fills —
and ``acquire`` blocking when both are busy is the natural backpressure.
"""
from __future__ import annotations

import threading

import numpy as np


class StagedBuffer:
    """A leased staging buffer; ``view`` is the first ``nbytes`` of it.
    Call ``release()`` (idempotent) when the transfer completes."""

    def __init__(self, pool: "StagingPool", data: np.ndarray, nbytes: int,
                 pooled: bool):
        self._pool = pool
        self._data = data
        self._pooled = pooled
        self._released = False
        self.view = data[:nbytes]

    def release(self):
        if self._released:
            return
        self._released = True
        if self._pooled:
            self._pool._put_back(self._data)


class StagingPool:
    def __init__(self, nbuf: int = 2, buf_bytes: int = 1 << 20):
        self.buf_bytes = int(buf_bytes)
        self._free = [np.empty(self.buf_bytes, np.uint8) for _ in range(nbuf)]
        self._cv = threading.Condition()
        self.oversized_allocs = 0   # transfers too big for a pooled buffer

    def acquire(self, nbytes: int) -> StagedBuffer:
        """Lease a buffer of >= nbytes. Requests larger than the pool's
        buffer size get a one-off allocation (counted, not pooled)."""
        if nbytes > self.buf_bytes:
            with self._cv:
                self.oversized_allocs += 1
            return StagedBuffer(self, np.empty(nbytes, np.uint8), nbytes,
                                pooled=False)
        with self._cv:
            while not self._free:
                self._cv.wait()
            data = self._free.pop()
        return StagedBuffer(self, data, nbytes, pooled=True)

    def _put_back(self, data: np.ndarray):
        with self._cv:
            self._free.append(data)
            self._cv.notify()
