"""Configuration for the async I/O engine (`repro.io.IOEngine`).

Routes are named ``"src->dst"`` over the three tiers (``gpu``, ``cpu``,
``ssd``) — the same strings the :class:`~repro.offload.stores.TrafficMeter`
uses, so one config describes both the real transfer topology and the
optional simulated bandwidth caps.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Mapping, Optional, Sequence

#: The chunk->path placement policies (``IOConfig.path_policy``).
#: "static" is the layout constant (chunk i -> path i % P, bit-for-bit
#: the pre-placement-scheduling behavior); "weighted" spreads chunk
#: bytes proportionally to the per-path bandwidth caps; "backlog" is
#: MLP-Offload's idle-level rule — each chunk goes to the path whose
#: queued bytes drain soonest under its rate.
PATH_POLICIES = ("static", "weighted", "backlog")


@dataclasses.dataclass(frozen=True)
class IOConfig:
    """Knobs of the transfer engine.

    * ``paths`` — SSD mount points (directories). More than one enables
      MLP-Offload-style striping across per-path channel threads, so
      transfers proceed in parallel across paths. WHERE a chunk lands
      is the ``path_policy`` decision (default: chunk *i* on path
      ``i % len(paths)``).
    * ``chunk_bytes`` — stripe unit; also the staging-buffer size.
    * ``inflight_bytes`` — backpressure budget: ``IOEngine.submit``
      blocks while the bytes of queued+running requests would exceed it
      (a single oversized request is admitted when the engine is idle).
    * ``workers`` — request-level worker threads (chunk execution runs
      on the per-path channel threads, not these). Keep >= 2: a
      parameter-fetch request may *gate* on a lower-priority optimizer
      request (the α-delay ordering), so at least one worker must stay
      free to run the gating request.
    * ``bandwidth`` — optional simulated caps, route -> bytes/s
      (e.g. ``{"cpu->ssd": 2e9, "ssd->cpu": 4e9, "cpu->gpu": 24e9}``).
      Empty dict = no pacing. Used to validate
      :mod:`repro.core.perfmodel` rooflines in wall-clock.
    * ``staging_buffers`` — host staging pool depth for asynchronous
      spills (2 = classic double buffering).
    * ``path_policy`` — chunk->path placement (:data:`PATH_POLICIES`):
      ``"static"`` reproduces the round-robin layout constant
      bit-for-bit; ``"weighted"`` splits chunk bytes proportionally to
      the per-path caps; ``"backlog"`` places each chunk on the path
      whose queued bytes drain soonest (live feedback). Placement
      moves bytes BETWEEN paths only — per-(category, route) traffic
      is policy-invariant.
    * ``path_bandwidth`` — optional per-path simulated caps, bytes/s,
      index = path (e.g. ``(0.2e9, 0.05e9)`` models a 4:1 fast/slow
      pair). Each path gets its own token bucket, shared by its reads
      and writes — a per-DEVICE cap, where ``bandwidth`` caps a
      ROUTE across all paths. Also the rate weights of the
      "weighted"/"backlog" policies. Must match ``len(paths)`` when
      both are given.
    * ``retries`` — bounded retry budget per chunk op for TRANSIENT
      faults (EAGAIN/EINTR/ETIMEDOUT-class errors and first-round
      checksum mismatches): each attempt backs off exponentially from
      ``retry_backoff_s``, capped by the op's priority-class timeout
      (:data:`repro.io.engine.RETRY_TIMEOUT_S` — a critical-path param
      fetch gives up sooner than a deferrable spill). Permanent faults
      (EIO, short reads, dead devices) never retry — they propagate
      immediately so the per-path failure drain and the write-failover
      path can act. ``retries=0`` disables the loop entirely.
    * ``retry_backoff_s`` — initial backoff before the first retry;
      doubles per attempt.
    * ``integrity`` — record a CRC32C per complete chunk in the
      chunk-location sidecar at write time and verify it on every
      complete-chunk read (:mod:`repro.io.integrity`): silent
      corruption and torn writes raise ``IntegrityError`` instead of
      feeding garbage to training. Off by default (pure-Python CRC
      costs ~0.1 s/MB, and integrity-off runs must keep producing zero
      sidecars under the static layout pin).
    """

    paths: Optional[Sequence[str]] = None
    chunk_bytes: int = 1 << 20
    inflight_bytes: int = 1 << 30
    workers: int = 4
    bandwidth: Mapping[str, float] = dataclasses.field(default_factory=dict)
    staging_buffers: int = 2
    path_policy: str = "static"
    path_bandwidth: Optional[Sequence[float]] = None
    retries: int = 2
    retry_backoff_s: float = 0.002
    integrity: bool = False

    def __post_init__(self):
        if self.path_policy not in PATH_POLICIES:
            raise ValueError(
                f"path_policy {self.path_policy!r} not in {PATH_POLICIES}")
        if int(self.retries) < 0:
            raise ValueError(f"retries={self.retries} must be >= 0")
        if float(self.retry_backoff_s) < 0:
            raise ValueError(
                f"retry_backoff_s={self.retry_backoff_s} must be >= 0")
        if self.path_bandwidth is not None:
            caps = tuple(float(c) for c in self.path_bandwidth)
            if any(c <= 0 for c in caps):
                raise ValueError(
                    f"path_bandwidth caps must be > 0, got {caps}")
            if self.paths is not None and len(caps) != len(self.paths):
                raise ValueError(
                    f"path_bandwidth has {len(caps)} cap(s) for "
                    f"{len(self.paths)} path(s)")
            object.__setattr__(self, "path_bandwidth", caps)

    def resolved_paths(self, default_root: str) -> Sequence[str]:
        """The stripe directories, falling back to a single default."""
        return list(self.paths) if self.paths else [default_root]

    def shard_for_rank(self, rank: int, world: int) -> "IOConfig":
        """Per-rank view of a data-parallel path set (N ranks x N SSD
        paths): rank ``r`` drives paths ``r, r+world, ...`` with its own
        engine, so the ranks' channel threads saturate disjoint devices.
        With fewer paths than ranks, ranks share a device through
        per-rank subdirectories (disjoint stripe namespaces — correct,
        but those ranks contend for the device's bandwidth). With no
        paths configured the caller's per-rank ``default_root``
        applies. ``path_bandwidth`` caps follow their paths through
        the slice, so a rank's placement policy weighs exactly the
        devices it drives."""
        if not (0 <= rank < world):
            raise ValueError(f"rank {rank} outside world of {world}")
        if not self.paths:
            return self
        caps = self.path_bandwidth
        mine = list(self.paths)[rank::world]
        mine_caps = None if caps is None else tuple(caps[rank::world])
        if not mine:
            base_i = rank % len(self.paths)
            mine = [os.path.join(list(self.paths)[base_i], f"rank{rank}")]
            # the shared device's cap applies to the subdirectory too
            mine_caps = None if caps is None else (caps[base_i],)
        return dataclasses.replace(self, paths=mine,
                                   path_bandwidth=mine_caps)
