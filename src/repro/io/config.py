"""Configuration for the async I/O engine (`repro.io.IOEngine`).

Routes are named ``"src->dst"`` over the three tiers (``gpu``, ``cpu``,
``ssd``) — the same strings the :class:`~repro.offload.stores.TrafficMeter`
uses, so one config describes both the real transfer topology and the
optional simulated bandwidth caps.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Mapping, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class IOConfig:
    """Knobs of the transfer engine.

    * ``paths`` — SSD mount points (directories). More than one enables
      MLP-Offload-style striping: chunk *i* of every tensor lands on path
      ``i % len(paths)``, and each path has its own worker thread, so
      transfers proceed in parallel across paths.
    * ``chunk_bytes`` — stripe unit; also the staging-buffer size.
    * ``inflight_bytes`` — backpressure budget: ``IOEngine.submit``
      blocks while the bytes of queued+running requests would exceed it
      (a single oversized request is admitted when the engine is idle).
    * ``workers`` — request-level worker threads (chunk execution runs
      on the per-path channel threads, not these). Keep >= 2: a
      parameter-fetch request may *gate* on a lower-priority optimizer
      request (the α-delay ordering), so at least one worker must stay
      free to run the gating request.
    * ``bandwidth`` — optional simulated caps, route -> bytes/s
      (e.g. ``{"cpu->ssd": 2e9, "ssd->cpu": 4e9, "cpu->gpu": 24e9}``).
      Empty dict = no pacing. Used to validate
      :mod:`repro.core.perfmodel` rooflines in wall-clock.
    * ``staging_buffers`` — host staging pool depth for asynchronous
      spills (2 = classic double buffering).
    """

    paths: Optional[Sequence[str]] = None
    chunk_bytes: int = 1 << 20
    inflight_bytes: int = 1 << 30
    workers: int = 4
    bandwidth: Mapping[str, float] = dataclasses.field(default_factory=dict)
    staging_buffers: int = 2

    def resolved_paths(self, default_root: str) -> Sequence[str]:
        """The stripe directories, falling back to a single default."""
        return list(self.paths) if self.paths else [default_root]

    def shard_for_rank(self, rank: int, world: int) -> "IOConfig":
        """Per-rank view of a data-parallel path set (N ranks x N SSD
        paths): rank ``r`` drives paths ``r, r+world, ...`` with its own
        engine, so the ranks' channel threads saturate disjoint devices.
        With fewer paths than ranks, ranks share a device through
        per-rank subdirectories (disjoint stripe namespaces — correct,
        but those ranks contend for the device's bandwidth). With no
        paths configured the caller's per-rank ``default_root`` applies.
        """
        if not (0 <= rank < world):
            raise ValueError(f"rank {rank} outside world of {world}")
        if not self.paths:
            return self
        mine = list(self.paths)[rank::world]
        if not mine:
            base = list(self.paths)[rank % len(self.paths)]
            mine = [os.path.join(base, f"rank{rank}")]
        return dataclasses.replace(self, paths=mine)
