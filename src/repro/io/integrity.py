"""Chunk integrity: CRC32C (Castagnoli) checksums and the error type
verification raises.

The checksum is the storage-industry standard CRC32C (polynomial
0x1EDC6F41, reflected — the same function iSCSI, ext4 metadata, and
NVMe end-to-end protection use), implemented as a pure-Python
table-driven loop because this container bakes its dependency set (no
``crc32c``/``google-crc32c`` wheels). The loop costs ~0.1 s/MB, which
is irrelevant at the KB chunk sizes the fault batteries run and
acceptable for checkpoint manifests; integrity is therefore an OPT-IN
knob (``IOConfig.integrity``) rather than an always-on tax — see the
``repro.io`` design note for the lifecycle.

``IntegrityError`` subclasses ``IOError`` so every existing fault path
(request futures, coordinator cleanup, executor unwind) treats a
checksum mismatch exactly like a failed syscall: loudly.
"""
from __future__ import annotations

_POLY = 0x82F63B78          # 0x1EDC6F41 bit-reflected


def _build_table():
    table = []
    for n in range(256):
        crc = n
        for _ in range(8):
            crc = (crc >> 1) ^ _POLY if crc & 1 else crc >> 1
        table.append(crc)
    return tuple(table)


_TABLE = _build_table()


def crc32c(data, crc: int = 0) -> int:
    """CRC32C of ``data`` (bytes-like; memoryviews are read without
    copying). Pass a previous return value as ``crc`` to checksum a
    stream incrementally."""
    crc ^= 0xFFFFFFFF
    table = _TABLE
    for b in memoryview(data).cast("B"):
        crc = table[(crc ^ b) & 0xFF] ^ (crc >> 8)
    return crc ^ 0xFFFFFFFF


class IntegrityError(IOError):
    """Stored bytes do not match their recorded checksum (silent
    corruption, a torn write, or a stale sidecar). Raised on READ —
    the moment garbage would otherwise enter training — and classified
    as transient for one retry round (a torn in-flight read heals; bytes
    corrupted on the device keep mismatching and propagate loudly)."""
