"""First-class fault injection for the I/O fabric: ``ChaosFiles``, a
:class:`repro.io.backend.StripedFiles` whose raw chunk ops misbehave on
demand — deterministic countdown fuses for the fault batteries,
scripted path death for failover drills, and a seeded probabilistic
:class:`ChaosSpec` (transient errors, latency spikes, torn writes, bit
flips) for whole-training chaos sweeps and the degraded-mode benchmark
cells.

This promotes the injectors the fault tests grew locally
(``FaultyFiles`` / ``DeadPathFiles`` / ``ActFaultyFiles``) into the
library, with the same semantics the batteries pinned:

* **Countdown fuses** (``fail_writes`` / ``fail_reads``): each faulting
  op decrements its fuse and raises ``OSError(EIO, "injected
  write|read fault")`` until it reaches zero. EIO is deliberately
  PERMANENT under the engine's fault classification — one fused fault
  propagates to ``IORequest.result()`` on the first attempt, which is
  exactly what the leak/cleanup batteries assert.
* **Short reads** (``short_reads``): reads return half the requested
  bytes, exercising the short-read detection in the backend.
* **Name-targeted fuses** (``fail_name_writes`` / ``fail_name_reads``:
  name-prefix -> countdown; ``fail_prefix``: one-shot write fuse): aim
  a fault at one STREAM (``"act:"``, a ckpt boundary tensor) when
  chunk-level fuses can't tell an act tail from a ckpt tail. These
  fire in ``write``/``readinto`` — above chunking, one fault per call.
* **Dead paths** (``dead_paths`` / :meth:`kill_path`): every chunk op
  landing on a listed path raises permanent EIO — a persistently dead
  DEVICE, the input to the drain-and-failover machinery.
* **Probabilistic chaos** (:class:`ChaosSpec`): seeded, lock-guarded
  RNG; per-op transient errors (EAGAIN — the engine's retry loop
  absorbs them), latency spikes (sleep on the owning channel only),
  torn writes (only a prefix of the chunk lands) and bit flips (one
  flipped bit lands). The torn/flip corruptions land ON DISK while the
  caller's buffer — and therefore the recorded CRC — stays intact, so
  ``IOConfig.integrity`` verification catches them at the next read.

Transient chaos (``error_rate`` + ``latency_rate`` alone) composes
with retries into BITWISE-identical training: a retried chunk op moves
the same bytes to the same slot, and route/path meters are recorded at
submit time, once, above the retry loop.
"""
from __future__ import annotations

import dataclasses
import errno
import random
import threading
import time
from typing import Dict, Optional, Set

from repro.io.backend import StripedFiles


@dataclasses.dataclass
class ChaosSpec:
    """Probabilistic per-op fault rates (all default 0 = no chaos).

    * ``error_rate`` — probability a chunk op raises a TRANSIENT fault
      (``OSError(EAGAIN)``) before touching the device. The engine's
      bounded retry absorbs these; size ``IOConfig.retries`` so that
      ``error_rate ** (retries + 1)`` times the op count stays << 1.
    * ``latency_rate`` / ``latency_s`` — probability an op stalls for
      ``latency_s`` before running (a brownout, not a fault).
    * ``torn_write_rate`` — probability a write persists only the first
      half of its bytes (the caller's buffer is NOT modified, so the
      recorded CRC describes the intended bytes and the tear surfaces
      at the next verified read).
    * ``bit_flip_rate`` — probability a write lands with one bit
      flipped (same detection story as a tear).
    * ``seed`` — RNG seed; one seeded stream per ChaosFiles instance,
      lock-guarded because ops roll it from concurrent channel threads.
    """

    error_rate: float = 0.0
    latency_rate: float = 0.0
    latency_s: float = 0.001
    torn_write_rate: float = 0.0
    bit_flip_rate: float = 0.0
    seed: int = 0


class ChaosFiles(StripedFiles):
    """StripedFiles with every fault the batteries need (see the module
    docstring). All knobs default OFF — a fresh ChaosFiles is
    bit-for-bit a StripedFiles."""

    def __init__(self, engine, spec: Optional[ChaosSpec] = None):
        super().__init__(engine)
        self.spec = spec or ChaosSpec()
        self._rng = random.Random(self.spec.seed)
        self._rng_lock = threading.Lock()
        # deterministic countdown fuses (chunk level)
        self.fail_writes = 0
        self.fail_reads = 0
        self.short_reads = 0
        self.ops = 0
        # name-targeted fuses (call level)
        self.fail_name_writes: Dict[str, int] = {}
        self.fail_name_reads: Dict[str, int] = {}
        self.fail_prefix = ""        # one-shot arbitrary-name write fuse
        # scripted device death
        self.dead_paths: Set[int] = set()
        # chaos accounting (reads by tests/benches)
        self.injected = {"transient": 0, "latency": 0, "torn": 0,
                         "flip": 0, "fuse": 0, "dead": 0}

    # -------- compat with the historical DeadPathFiles single knob ----
    @property
    def dead_path(self) -> Optional[int]:
        return next(iter(self.dead_paths)) if self.dead_paths else None

    @dead_path.setter
    def dead_path(self, p: Optional[int]):
        self.dead_paths = set() if p is None else {p}

    def kill_path(self, p: int):
        """Script a device death: every later chunk op on path ``p``
        fails permanently."""
        self.dead_paths.add(p)

    def revive_path(self, p: int):
        self.dead_paths.discard(p)

    # ---------------- helpers ----------------
    def _fd_path(self, fd: int) -> Optional[int]:
        with self._fd_lock:
            for (_, p), f in self._fds.items():
                if f == fd:
                    return p
        return None

    def _roll(self, rate: float) -> bool:
        if rate <= 0.0:
            return False
        with self._rng_lock:
            return self._rng.random() < rate

    def _chaos_gate(self, write: bool):
        """The probabilistic pre-op effects shared by reads and writes:
        maybe stall, maybe raise a transient fault."""
        sp = self.spec
        if self._roll(sp.latency_rate):
            self.injected["latency"] += 1
            time.sleep(sp.latency_s)
        if self._roll(sp.error_rate):
            self.injected["transient"] += 1
            raise OSError(errno.EAGAIN,
                          "injected transient "
                          + ("write" if write else "read") + " fault")

    # ---------------- raw chunk ops ----------------
    def _pwrite(self, fd, mv, off):
        self.ops += 1
        p = self._fd_path(fd)
        if p is not None and p in self.dead_paths:
            self.injected["dead"] += 1
            raise OSError(errno.EIO, "injected dead-path write fault")
        if self.fail_writes > 0:
            self.fail_writes -= 1
            self.injected["fuse"] += 1
            raise OSError(errno.EIO, "injected write fault")
        self._chaos_gate(write=True)
        sp = self.spec
        if self._roll(sp.torn_write_rate) and len(mv) > 1:
            # persist only a prefix; the caller's buffer (and any CRC
            # computed from it) still describes the INTENDED bytes
            self.injected["torn"] += 1
            super()._pwrite(fd, mv[:len(mv) // 2], off)
            return
        if self._roll(sp.bit_flip_rate) and len(mv) > 0:
            self.injected["flip"] += 1
            buf = bytearray(mv)
            with self._rng_lock:
                i = self._rng.randrange(len(buf))
                b = self._rng.randrange(8)
            buf[i] ^= 1 << b
            super()._pwrite(fd, memoryview(buf), off)
            return
        super()._pwrite(fd, mv, off)

    def _pread(self, fd, mv, off):
        self.ops += 1
        p = self._fd_path(fd)
        if p is not None and p in self.dead_paths:
            self.injected["dead"] += 1
            raise OSError(errno.EIO, "injected dead-path read fault")
        if self.fail_reads > 0:
            self.fail_reads -= 1
            self.injected["fuse"] += 1
            raise OSError(errno.EIO, "injected read fault")
        if self.short_reads > 0:
            self.short_reads -= 1
            return max(0, super()._pread(fd, mv, off) // 2)
        self._chaos_gate(write=False)
        return super()._pread(fd, mv, off)

    # ---------------- name-targeted call-level fuses ----------------
    def _name_fuse(self, fuses: Dict[str, int], name: str) -> bool:
        for prefix, n in fuses.items():
            if n > 0 and name.startswith(prefix):
                fuses[prefix] = n - 1
                self.injected["fuse"] += 1
                return True
        return False

    def write(self, name, data_u8, byte_lo, priority):
        if self._name_fuse(self.fail_name_writes, name):
            raise OSError(errno.EIO, "injected write fault")
        if self.fail_prefix and name.startswith(self.fail_prefix):
            self.fail_prefix = ""
            self.injected["fuse"] += 1
            raise OSError(errno.EIO, "injected write fault")
        return super().write(name, data_u8, byte_lo, priority)

    def readinto(self, name, out_u8, byte_lo, priority):
        if self._name_fuse(self.fail_name_reads, name):
            raise OSError(errno.EIO, "injected read fault")
        return super().readinto(name, out_u8, byte_lo, priority)


def install_chaos(ssd, spec: Optional[ChaosSpec] = None) -> ChaosFiles:
    """Swap an :class:`repro.offload.stores.SSDStore`'s backend for a
    ``ChaosFiles`` (closing the clean one) and return it — the one-line
    hook tests, benches and the quickstart use:

        files = install_chaos(eng.ssd, ChaosSpec(error_rate=0.05))
    """
    ssd.files.close()
    files = ChaosFiles(ssd.engine, spec)
    ssd.files = files
    return files
