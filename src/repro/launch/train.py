"""Training launcher CLI.

Runs a REAL training loop on the available devices (this container: CPU),
or an SSD-offloaded run via the GreedySnake engine (--offload).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch gpt-tiny --steps 20
  PYTHONPATH=src python -m repro.launch.train --arch gpt-100m --steps 200 \
      --schedule vertical --offload --alpha 0.2 --microbatches 4
"""
from __future__ import annotations

import argparse
import tempfile

import jax

from repro.configs import get_config, get_smoke
from repro.core.perfmodel import StorageRatios
from repro.core.schedules import ScheduleConfig
from repro.optim import AdamConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt-tiny")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke variant of the arch")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--schedule", default="vertical",
                    choices=["vertical", "horizontal"])
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--alpha", type=float, default=0.0)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--offload", action="store_true",
                    help="run through the SSD-offload engine")
    ap.add_argument("--ssd-dir", default=None)
    ap.add_argument("--x-ckpt", type=float, default=0.5)
    ap.add_argument("--x-param", type=float, default=0.5)
    ap.add_argument("--x-opt", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)

    if args.offload:
        from repro.data import SyntheticLM
        from repro.offload import OffloadConfig, OffloadEngine
        workdir = args.ssd_dir or tempfile.mkdtemp(prefix="greedysnake_ssd_")
        print(f"SSD tier: {workdir}")
        ocfg = OffloadConfig(
            schedule=args.schedule, num_microbatches=args.microbatches,
            micro_batch=args.batch // args.microbatches, seq_len=args.seq,
            alpha=args.alpha, lr=args.lr,
            ratios=StorageRatios(args.x_ckpt, args.x_param, args.x_opt))
        eng = OffloadEngine(cfg, ocfg, jax.random.PRNGKey(0), workdir)
        data = SyntheticLM(cfg.vocab_size, seed=0)
        import time
        t0 = time.perf_counter()
        for i in range(args.steps):
            loss = eng.train_step(data.batch(args.batch, args.seq))
            print(f"step {i + 1:4d} loss {loss:8.4f}", flush=True)
        eng.finish()
        dt = time.perf_counter() - t0
        print(f"\n{args.steps} steps in {dt:.1f}s "
              f"({args.steps * args.batch * args.seq / dt:.0f} tokens/s)")
        print("traffic by category (GB):")
        for k, v in sorted(eng.meter.snapshot().items()):
            print(f"  {k:24s} {v / 1e9:10.3f}")
        print("phase seconds:", {k: round(v, 2)
                                 for k, v in eng.phase_time.items()})
        eng.close()
    else:
        from repro.train import Trainer
        sched = ScheduleConfig(schedule=args.schedule,
                               num_microbatches=args.microbatches,
                               alpha=args.alpha)
        tr = Trainer(cfg, sched, AdamConfig(lr=args.lr))
        rep = tr.run(args.steps, args.batch, args.seq)
        print(f"\nfinal loss {rep.losses[-1]:.4f}  "
              f"{rep.tokens_per_s:.0f} tokens/s")


if __name__ == "__main__":
    main()
