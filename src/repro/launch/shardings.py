"""PartitionSpec rules for every model/optimizer/batch/cache leaf.

Parameters are FSDP-sharded over the "model" axis (the paper integrates
ZeRO-style FSDP, §5): each weight leaf is sharded along its largest
mesh-divisible dimension, and XLA SPMD inserts the all-gather at use —
the ICI analogue of GreedySnake's parameter loads. Stacked period leaves
(leading n_periods dim from the layer scan) are never sharded on the
layer dim, so the gather happens once per layer per iteration under the
vertical schedule. Activations/batch shard over ("pod","data"); decode
caches shard the sequence dim over "model".
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import batch_axes, batch_axis_size, model_axis_size


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def param_pspec(path, leaf, mesh, *, expert_parallel: bool = False,
                fully_shard: bool = False) -> P:
    """FSDP rule: shard the largest divisible dim on "model".

    With ``expert_parallel`` MoE expert weights (…/moe/w_*: (E, d, f))
    shard the EXPERT dim instead — expert weights stay stationary and
    only the dispatched (E·C, d) tokens cross the mesh (all-to-all),
    which beats within-expert tensor parallelism for large E."""
    name = _path_str(path)
    msize = model_axis_size(mesh)
    shape = leaf.shape
    if leaf.ndim == 0 or msize == 1:
        return P()
    start = 1 if "periods" in name else 0  # skip stacked layer dim
    dims = list(range(start, len(shape)))
    if not dims:
        return P()
    spec: list = [None] * len(shape)
    if expert_parallel and "moe/w_" in name and len(dims) >= 3 \
            and shape[start] % msize == 0:
        spec[start] = "model"   # the expert dim
    else:
        # prefer the largest dimension divisible by the model axis
        cand = [d for d in dims if shape[d] % msize == 0]
        if not cand:
            return P()
        d = max(cand, key=lambda i: shape[i])
        spec[d] = "model"
    if fully_shard:
        # fully shard (2-D FSDP): spread a second dim over the data axes
        # so params + optimizer states occupy N·bytes/|devices|, not
        # N·bytes/|model|. XLA gathers at use either way; the resting
        # footprint is what must fit HBM (or host memory when offloaded).
        dax = tuple(a for a in mesh.axis_names if a != "model")
        dsize = int(np.prod([mesh.shape[a] for a in dax]))
        rest = [d for d in dims if spec[d] is None and shape[d] % dsize == 0]
        if rest and dsize > 1:
            d2 = max(rest, key=lambda i: shape[i])
            spec[d2] = dax if len(dax) > 1 else dax[0]
    return P(*spec)


def shard_params(tree, mesh, *, expert_parallel: bool = False,
                 fully_shard: bool = False):
    def rule(path, leaf):
        return NamedSharding(mesh, param_pspec(
            path, leaf, mesh, expert_parallel=expert_parallel,
            fully_shard=fully_shard))
    return jax.tree_util.tree_map_with_path(rule, tree)


def opt_state_shardings(params_shardings, mesh):
    """AdamState(master, m, v, step): states shard like params."""
    from repro.optim import AdamState
    rep = NamedSharding(mesh, P())
    return AdamState(master=params_shardings, m=params_shardings,
                     v=params_shardings, step=rep)


def batch_pspec(shape, mesh, *, batch_dim: int = 0,
                include_model: bool = False) -> P:
    """Shard dim0 over the batch axes; with ``include_model`` the batch
    also spreads over "model" (pure-FSDP mode: activations fully
    batch-sharded, parameters gathered at use — no tensor-parallel
    activation all-reduces). Falls back to progressively fewer axes when
    the batch is not divisible."""
    bax = batch_axes(mesh)                      # ("pod","data") or ("data",)
    candidates = []
    if include_model:
        candidates.append(tuple(bax) + ("model",))
    candidates.append(tuple(bax))
    if len(bax) > 1:
        candidates.append((bax[-1],))
    spec: list = [None] * len(shape)
    for axes in candidates:
        n = int(np.prod([mesh.shape[a] for a in axes]))
        if n > 1 and shape[batch_dim] % n == 0:
            spec[batch_dim] = axes if len(axes) > 1 else axes[0]
            break
    return P(*spec)


def shard_batch(tree, mesh, *, include_model: bool = False):
    def rule(path, leaf):
        return NamedSharding(mesh, batch_pspec(leaf.shape, mesh,
                                               include_model=include_model))
    return jax.tree_util.tree_map_with_path(rule, tree)


def cache_pspec(path, leaf, mesh, *, stacked: bool) -> P:
    """Decode caches: batch on ("pod","data"), sequence on "model".

    Layouts (see models/attention.py, models/mamba.py):
      KVCache.k/v:      (B, Hk, S, hd)    -> (bax, None, "model", None)
      KVCache.slot_pos: (S,)              -> replicated
      MLACache.latent:  (B, S, r)         -> (bax, "model", None)
      MLACache.k_rope:  (B, S, rope)      -> (bax, "model", None)
      MambaState.conv:  (B, K-1, di)      -> (bax, None, "model")
      MambaState.h:     (B, di, st)       -> (bax, "model", None)
    Stacked period caches carry a leading n_periods dim (skipped).
    """
    name = _path_str(path)
    msize = model_axis_size(mesh)
    bax = batch_axes(mesh)
    bsz = batch_axis_size(mesh)
    shape = list(leaf.shape)
    off = 1 if stacked and "periods" in name else 0
    spec: list = [None] * len(shape)
    if "slot_pos" in name:
        return P(*spec)
    ndim = len(shape) - off
    if ndim == 0:
        return P(*spec)
    # batch dim
    if bsz > 1 and shape[off] % bsz == 0:
        spec[off] = bax
    # sequence / feature dim on "model"
    if msize > 1:
        if "latent" in name or "k_rope" in name:
            if ndim >= 2 and shape[off + 1] % msize == 0:
                spec[off + 1] = "model"
        elif name.endswith("k") or name.endswith("v"):
            if ndim >= 3 and shape[off + 2] % msize == 0:
                spec[off + 2] = "model"
        elif "conv" in name:
            if ndim >= 3 and shape[off + 2] % msize == 0:
                spec[off + 2] = "model"
        elif "/h" in name or name.endswith("h"):
            if ndim >= 2 and shape[off + 1] % msize == 0:
                spec[off + 1] = "model"
    return P(*spec)


def shard_caches(tree, mesh):
    def rule(path, leaf):
        return NamedSharding(mesh, cache_pspec(path, leaf, mesh, stacked=True))
    return jax.tree_util.tree_map_with_path(rule, tree)


def replicated(mesh):
    return NamedSharding(mesh, P())
