"""Production mesh builders.

Defined as functions (not module constants) so importing this module never
touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 ("data","model") = 256 chips (TPU v5e pod slice).
    Multi-pod: 2x16x16 ("pod","data","model") = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh for CPU smoke tests of the distributed path."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


def batch_axes(mesh) -> tuple:
    """Mesh axes that shard the batch dimension."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def model_axis_size(mesh) -> int:
    return mesh.shape.get("model", 1)


def batch_axis_size(mesh) -> int:
    n = 1
    for a in batch_axes(mesh):
        n *= mesh.shape[a]
    return n
