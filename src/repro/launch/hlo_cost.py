"""Trip-count-aware cost analysis of compiled HLO.

``compiled.cost_analysis()`` counts each ``while`` body ONCE, regardless
of trip count — for scan-over-layers models this undercounts FLOPs,
bytes, and collective traffic by ~num_layers. This module re-derives the
three roofline inputs from the compiled HLO text, weighting every
instruction by the product of its enclosing loops' ``known_trip_count``:

* ``flops``      — 2 · prod(result dims) · prod(contracting dims) per
                   ``dot`` (matmuls dominate; elementwise is ignored).
* ``bytes``      — Σ (result + operand bytes) over top-level instructions
                   (fusion internals excluded: they never touch HBM).
* ``collectives``— per-kind counts and operand/result bytes.

Weights: ENTRY = 1; a while's body/condition computation inherits
weight × trip_count; fusion/call/to_apply callees inherit weight × 1.

Validated against ``cost_analysis()`` on scan-free modules (equal) and
against the analytic 6·N·D model on unrolled ones (see tests).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
                "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
                "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_TYPE_RE = re.compile(
    r"(pred|bf16|f16|f32|f64|s4|u4|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128)"
    r"\[([0-9,]*)\]")

COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
              "collective-permute")

# ops whose operands/results we count toward HBM traffic at top level
_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "after-all", "partition-id", "replica-id"}


def _shape_dims(dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n


def _types_bytes(s: str) -> int:
    return sum(_shape_dims(d) * _DTYPE_BYTES[t] for t, d in _TYPE_RE.findall(s))


@dataclasses.dataclass
class Instr:
    name: str
    result: str          # result type string (may be a tuple)
    op: str
    rest: str            # everything after the op name
    line: str


_INSTR_RE = re.compile(
    r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|\S+))\s+"
    r"([\w\-]+)(\(.*)$")


def parse_computations(hlo: str) -> Dict[str, List[Instr]]:
    """Split HLO text into computations: name -> instruction list."""
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if not line.startswith(" ") and line.rstrip().endswith("{"):
            m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
            if m:
                cur = "ENTRY" if m.group(1) else m.group(2)
                comps[cur] = []
            continue
        if line.strip() == "}":
            # keep cur until next header; nested braces don't occur at col>0
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            _, name, result, op, rest = m.groups()
            comps[cur].append(Instr(name, result, op, rest, line))
    return comps


def _callees(ins: Instr) -> List[Tuple[str, str]]:
    """(kind, computation-name) pairs referenced by this instruction."""
    out = []
    for attr in ("body", "condition", "calls", "to_apply"):
        for m in re.finditer(attr + r"=%?([\w.\-]+)", ins.rest):
            out.append((attr, m.group(1)))
        for m in re.finditer(attr + r"=\{([^}]*)\}", ins.rest):
            for nm in m.group(1).split(","):
                out.append((attr, nm.strip().lstrip("%")))
    return out


def _trip_count(ins: Instr) -> int:
    m = re.search(r'known_trip_count[^0-9]*(\d+)', ins.rest)
    return int(m.group(1)) if m else 1


def computation_weights(comps: Dict[str, List[Instr]]) -> Dict[str, float]:
    """Propagate execution counts from ENTRY through calls and loops."""
    weights: Dict[str, float] = defaultdict(float)
    root = "ENTRY" if "ENTRY" in comps else next(iter(comps))
    weights[root] = 1.0
    # topological-ish: repeated relaxation (call graph is a DAG; few passes)
    for _ in range(64):
        changed = False
        new = defaultdict(float)
        new[root] = 1.0
        for cname, instrs in comps.items():
            wc = weights.get(cname, 0.0)
            if wc == 0.0:
                continue
            for ins in instrs:
                mult = _trip_count(ins) if ins.op == "while" else 1
                for kind, callee in _callees(ins):
                    if callee in comps:
                        k = wc * (mult if ins.op == "while" else 1)
                        new[callee] += k
        new_w = {**{root: 1.0}, **dict(new)}
        if all(abs(new_w.get(k, 0) - weights.get(k, 0)) < 1e-9
               for k in set(new_w) | set(weights)):
            break
        weights = defaultdict(float, new_w)
        changed = True
    return dict(weights)


def _symbol_table(comps: Dict[str, List[Instr]], hlo: str
                  ) -> Dict[Tuple[str, str], str]:
    """(computation, symbol) -> type string; includes block parameters."""
    table: Dict[Tuple[str, str], str] = {}
    cur = None
    for line in hlo.splitlines():
        if not line.startswith(" ") and line.rstrip().endswith("{"):
            m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->", line)
            if m:
                cur = "ENTRY" if m.group(1) else m.group(2)
                for pm in re.finditer(r"%?([\w.\-]+):\s*([^,)]+)", m.group(3)):
                    table[(cur, pm.group(1))] = pm.group(2)
            continue
    for cname, instrs in comps.items():
        for ins in instrs:
            table[(cname, ins.name)] = ins.result
    return table


def _dot_flops(ins: Instr, cname: str, table) -> float:
    """2 * prod(result) * prod(lhs contracting dims)."""
    res_elems = sum(_shape_dims(d) for _, d in _TYPE_RE.findall(ins.result))
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
    if not m:
        return 0.0
    cdims = [int(x) for x in m.group(1).split(",") if x.strip()]
    ops = re.findall(r"%([\w.\-]+)", ins.rest.split(")", 1)[0])
    if not ops:
        return 0.0
    lhs_t = table.get((cname, ops[0]), "")
    tm = _TYPE_RE.search(lhs_t)
    if not tm:
        return 0.0
    dims = [int(x) for x in tm.group(2).split(",") if x.strip()]
    k = 1
    for c in cdims:
        if c < len(dims):
            k *= dims[c]
    return 2.0 * res_elems * k


def _operands(ins: Instr) -> List[str]:
    arglist = ins.rest[1:].split(")", 1)[0] if ins.rest.startswith("(") \
        else ins.rest
    return re.findall(r"%([\w.\-]+)", arglist)


def _param_utilization(callee: str, comps, table) -> Dict[int, float]:
    """For a fused computation: fraction of each positional parameter that
    is actually read (1.0 unless every use is a dynamic-slice/gather, in
    which case only the slices' bytes are touched — XLA-style operand
    utilization)."""
    instrs = comps.get(callee)
    if instrs is None:
        return {}
    # positional parameters: "%p = TYPE parameter(i)"
    param_syms: Dict[str, int] = {}
    for ins in instrs:
        if ins.op == "parameter":
            m = re.match(r"\((\d+)\)", ins.rest)
            if m:
                param_syms[ins.name] = int(m.group(1))
    util: Dict[int, float] = {}
    for sym, idx in param_syms.items():
        full = _types_bytes(table.get((callee, sym), ""))
        if full == 0:
            continue
        used = 0.0
        sliced_only = True
        for ins in instrs:
            if ins.op == "parameter" or sym not in _operands(ins):
                continue
            if ins.op in ("dynamic-slice", "gather", "slice"):
                used += _types_bytes(ins.result)
            elif ins.op == "dynamic-update-slice" and \
                    _operands(ins) and _operands(ins)[0] == sym:
                used += 0.0   # target is overwritten in place
            else:
                sliced_only = False
                break
        if sliced_only:
            util[idx] = min(1.0, used / full)
    return util


def _instr_bytes(ins: Instr, cname: str, table, comps=None) -> float:
    if ins.op in _SKIP_BYTES_OPS:
        return 0.0
    # slicing ops touch only the slice, not the full operand
    if ins.op in ("dynamic-slice", "gather", "slice"):
        return 2.0 * _types_bytes(ins.result)
    ops = _operands(ins)
    if ins.op == "dynamic-update-slice":
        upd = _types_bytes(table.get((cname, ops[1]), "")) if len(ops) > 1 \
            else 0
        return 2.0 * upd
    util: Dict[int, float] = {}
    result_bytes = _types_bytes(ins.result)
    if ins.op == "fusion" and comps is not None:
        for _, callee in _callees(ins):
            util = _param_utilization(callee, comps, table)
            # a fusion rooted at dynamic-update-slice writes only the
            # update slice in place, not the full carried buffer
            root = next((i for i in comps.get(callee, [])
                         if i.line.lstrip().startswith("ROOT")), None)
            if root is not None and root.op == "dynamic-update-slice":
                r_ops = _operands(root)
                upd = _types_bytes(table.get((callee, r_ops[1]), "")) \
                    if len(r_ops) > 1 else 0
                result_bytes = min(result_bytes, upd)
            break
    total = result_bytes
    for i, sym in enumerate(ops):
        t = table.get((cname, sym))
        if t:
            total += _types_bytes(t) * util.get(i, 1.0)
    return total


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes_accessed: float
    collectives: Dict[str, Dict[str, float]]

    @property
    def collective_bytes(self) -> float:
        return sum(v["result_bytes"] + v["operand_bytes"]
                   for v in self.collectives.values())


def _fusion_computations(comps: Dict[str, List[Instr]]) -> set:
    """Computations called by fusion ops (internals never touch HBM)."""
    out = set()
    for instrs in comps.values():
        for ins in instrs:
            if ins.op == "fusion":
                for _, callee in _callees(ins):
                    out.add(callee)
    return out


def analyze(hlo: str) -> HloCost:
    comps = parse_computations(hlo)
    weights = computation_weights(comps)
    table = _symbol_table(comps, hlo)
    fusion_comps = _fusion_computations(comps)

    flops = 0.0
    bytes_acc = 0.0
    colls = {k: {"count": 0.0, "result_bytes": 0.0, "operand_bytes": 0.0}
             for k in COLL_KINDS}
    for cname, instrs in comps.items():
        w = weights.get(cname, 0.0)
        if w == 0.0:
            continue
        for ins in instrs:
            if ins.op in ("dot", "convolution"):
                flops += w * _dot_flops(ins, cname, table)
            base = ins.op.replace("-start", "")
            if base in COLL_KINDS and not ins.op.endswith("-done"):
                c = colls[base]
                c["count"] += w
                c["result_bytes"] += w * _types_bytes(ins.result)
                arglist = ins.rest[1:].split(")", 1)[0]
                ob = sum(_types_bytes(table.get((cname, s), ""))
                         for s in re.findall(r"%([\w.\-]+)", arglist))
                c["operand_bytes"] += w * ob
            if cname not in fusion_comps:
                bytes_acc += w * _instr_bytes(ins, cname, table, comps)
    return HloCost(flops=flops, bytes_accessed=bytes_acc, collectives=colls)
