import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any other import (jax locks device count on first init).

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
from typing import Dict  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import (ASSIGNED_ARCHS, INPUT_SHAPES, get_config,  # noqa: E402
                           supports_shape)
from repro.core.schedules import ScheduleConfig, make_train_step  # noqa: E402
from repro.launch import shardings as sh  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import model as mdl  # noqa: E402
from repro.optim import AdamConfig, init_state  # noqa: E402

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) and dump
memory_analysis / cost_analysis / collective-byte parse for the roofline.

No arrays are allocated: parameters, optimizer state, caches, and batches
are ShapeDtypeStructs via jax.eval_shape.
"""


_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2,
                "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo: str) -> Dict[str, Dict[str, float]]:
    """Sum result + operand bytes of every collective op in the (per-device)
    compiled HLO."""
    out: Dict[str, Dict[str, float]] = {
        k: {"count": 0, "result_bytes": 0.0, "operand_bytes": 0.0}
        for k in _COLL_KINDS}
    # result = one type or tuple of types; op name; operand list in parens
    line_re = re.compile(
        r"=\s*(\(?[^)=]*?\)?)\s+(" + "|".join(_COLL_KINDS) + r")(?:-start)?\((.*)$")
    type_re = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128)\[([0-9,]*)\]")
    for line in hlo.splitlines():
        m = line_re.search(line)
        if not m:
            continue
        if "-done" in line.split("=")[1][:60]:
            continue
        result_part, kind, operand_part = m.groups()
        rbytes = sum(_shape_bytes(t, d) for t, d in type_re.findall(result_part))
        obytes = sum(_shape_bytes(t, d) for t, d in type_re.findall(operand_part))
        out[kind]["count"] += 1
        out[kind]["result_bytes"] += rbytes
        out[kind]["operand_bytes"] += obytes
    return out


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no device allocation)
# ---------------------------------------------------------------------------

def batch_specs(cfg, batch: int, seq: int) -> Dict[str, jax.ShapeDtypeStruct]:
    """Training/prefill batch for one architecture family."""
    sds = jax.ShapeDtypeStruct
    if cfg.family == "vlm":
        return {"tokens": sds((batch, seq - cfg.frontend_tokens), jnp.int32),
                "image_embeds": sds((batch, cfg.frontend_tokens, cfg.d_model),
                                    jnp.bfloat16)}
    out = {"tokens": sds((batch, seq), jnp.int32)}
    if cfg.family == "encdec":
        out["enc_embeds"] = sds((batch, cfg.encoder_seq, cfg.d_model),
                                jnp.bfloat16)
    return out


def input_specs(arch: str, shape_name: str):
    cfg = get_config(arch)
    shp = INPUT_SHAPES[shape_name]
    return batch_specs(cfg, shp.global_batch, shp.seq_len)


# ---------------------------------------------------------------------------
# Builders: lower the right step for the input shape
# ---------------------------------------------------------------------------

def _to_host(shardings_tree):
    """Move a sharding tree to host memory (the TPU analogue of the
    paper's CPU/SSD-resident optimizer states: resident in host DRAM,
    streamed to HBM by XLA at use)."""
    return jax.tree.map(
        lambda s: s.with_memory_kind("pinned_host"), shardings_tree)


def lower_train(cfg, mesh, shape, *, schedule: str, microbatches: int,
                remat: bool = True, fsdp_batch: bool = False,
                host_offload: bool = False):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_s = jax.eval_shape(
        lambda k: mdl.init_params(cfg, k), jax.random.PRNGKey(0))
    opt_s = jax.eval_shape(init_state, params_s)
    batch_s = batch_specs(cfg, shape.global_batch, shape.seq_len)

    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import schedules as sched_lib
    from repro.launch.mesh import batch_axes
    from repro.models import moe_ep

    has_moe_arch = any(cfg.is_moe_layer(i) for i in range(cfg.num_layers))
    full = tuple(batch_axes(mesh)) + ("model",)
    divides_all = shape.global_batch % int(
        np.prod([mesh.shape[a] for a in full])) == 0
    # Expert-dim param sharding is ONLY safe together with the explicit
    # EP shard_map — under auto-SPMD the sort-based dispatch's scatters
    # replicate and all-reduce at TB scale (EXPERIMENTS.md §Perf H5).
    use_ep = fsdp_batch and has_moe_arch and divides_all
    p_sh = sh.shard_params(params_s, mesh, expert_parallel=use_ep,
                           fully_shard=fsdp_batch)
    o_sh = sh.opt_state_shardings(p_sh, mesh)
    if host_offload:
        # optimizer states live in host DRAM (GreedySnake's CPU tier);
        # XLA streams them across the host<->HBM link per layer.
        o_sh = jax.tree.map(lambda s: s.with_memory_kind("pinned_host"),
                            o_sh)
    b_sh = sh.shard_batch(batch_s, mesh,
                          include_model=fsdp_batch and divides_all)
    rep = sh.replicated(mesh)
    if fsdp_batch:
        if divides_all:
            # pure FSDP: batch over ALL axes, params gathered at use.
            # MoE blocks additionally route through the expert-parallel
            # shard_map (all-to-all within model rows; expert weights
            # stationary on their shard).
            mdl.set_activation_spec(
                NamedSharding(mesh, P(full, None, None)))
            if use_ep:
                moe_ep.set_ep_mesh(mesh, axis="model", bax=full)
        sched_lib.set_grad_shardings(p_sh)
    else:
        mdl.set_activation_spec(None)
        sched_lib.set_grad_shardings(None)
        moe_ep.set_ep_mesh(None)
    step = make_train_step(
        cfg, ScheduleConfig(schedule=schedule, num_microbatches=microbatches,
                            remat=remat), AdamConfig())
    if host_offload:
        # Optimizer states are RESIDENT in host DRAM between steps (the
        # paper's CPU tier) and streamed to HBM for the update via
        # explicit transfers — the documented JAX host-offload pattern.
        # NOTE (recorded in DESIGN.md): inside one XLA program the
        # streaming granularity is the whole state tree, so peak HBM
        # still sees the f32 states transiently; per-LAYER streaming —
        # GreedySnake's actual pipeline — requires the external offload
        # engine. The dry-run proves the placement lowers and compiles.
        inner = step
        o_dev = jax.tree.map(lambda s: s.with_memory_kind("device"), o_sh)

        def step(params, opt, batch):
            opt_dev = jax.tree.map(jax.device_put, opt, o_dev)
            p2, o2, m = inner(params, opt_dev, batch)
            o2h = jax.tree.map(jax.device_put, o2, o_sh)
            return p2, o2h, m
    jitted = jax.jit(step,
                     in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh,
                                    {"loss": rep, "grad_norm": rep}),
                     donate_argnums=(0, 1))
    with mesh:
        return jitted.lower(params_s, opt_s, batch_s)


def lower_prefill(cfg, mesh, shape):
    params_s = jax.eval_shape(
        lambda k: mdl.init_params(cfg, k), jax.random.PRNGKey(0))
    caches_s = jax.eval_shape(
        lambda: mdl.init_caches(cfg, shape.global_batch, shape.seq_len))
    batch_s = batch_specs(cfg, shape.global_batch, shape.seq_len)
    p_sh = sh.shard_params(params_s, mesh)
    c_sh = sh.shard_caches(caches_s, mesh)
    b_sh = sh.shard_batch(batch_s, mesh)
    rep = sh.replicated(mesh)

    def step(params, batch, caches):
        return mdl.prefill(params, cfg, batch, caches)

    jitted = jax.jit(step, in_shardings=(p_sh, b_sh, c_sh),
                     out_shardings=(rep, c_sh), donate_argnums=(2,))
    with mesh:
        return jitted.lower(params_s, batch_s, caches_s)


def lower_decode(cfg, mesh, shape):
    params_s = jax.eval_shape(
        lambda k: mdl.init_params(cfg, k), jax.random.PRNGKey(0))
    caches_s = jax.eval_shape(
        lambda: mdl.init_caches(cfg, shape.global_batch, shape.seq_len))
    sds = jax.ShapeDtypeStruct
    tok_s = sds((shape.global_batch, 1), jnp.int32)
    pos_s = sds((), jnp.int32)
    p_sh = sh.shard_params(params_s, mesh)
    c_sh = sh.shard_caches(caches_s, mesh)
    t_sh = sh.shard_batch({"t": tok_s}, mesh)["t"]
    rep = sh.replicated(mesh)

    def step(params, token, pos, caches):
        logits, new_caches = mdl.decode_step(params, cfg, token, pos, caches)
        return logits, new_caches

    # logits (B, V): batch sharded, vocab on model
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import batch_axes, batch_axis_size
    bax = batch_axes(mesh)
    bspec = bax if shape.global_batch % max(1, batch_axis_size(mesh)) == 0 \
        and batch_axis_size(mesh) > 1 else None
    vspec = "model" if cfg.padded_vocab % mesh.shape.get("model", 1) == 0 else None
    l_sh = NamedSharding(mesh, P(bspec, vspec))
    jitted = jax.jit(step, in_shardings=(p_sh, t_sh, rep, c_sh),
                     out_shardings=(l_sh, c_sh), donate_argnums=(3,))
    with mesh:
        return jitted.lower(params_s, tok_s, pos_s, caches_s)


# ---------------------------------------------------------------------------
# Run one combination
# ---------------------------------------------------------------------------

def run_one(arch: str, shape_name: str, multi_pod: bool, *,
            schedule: str = "vertical", microbatches: int = 8,
            out_dir: str = "experiments/dryrun",
            fsdp_batch: bool = False, host_offload: bool = False) -> Dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    if shape.kind == "train":
        lowered = lower_train(cfg, mesh, shape, schedule=schedule,
                              microbatches=microbatches,
                              fsdp_batch=fsdp_batch,
                              host_offload=host_offload)
    elif shape.kind == "prefill":
        lowered = lower_prefill(cfg, mesh, shape)
    else:
        lowered = lower_decode(cfg, mesh, shape)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo_text = compiled.as_text()
    colls_raw = parse_collectives(hlo_text)
    # trip-count-aware reanalysis: XLA's cost_analysis counts while (scan)
    # bodies once; hlo_cost weights them by known_trip_count.
    from repro.launch import hlo_cost
    corrected = hlo_cost.analyze(hlo_text)

    n_chips = int(np.prod(list(mesh.shape.values())))
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_chips,
        "schedule": schedule if shape.kind == "train" else shape.kind,
        "sharding": "fsdp" if fsdp_batch else "tp",
        "host_offload": host_offload,
        "microbatches": microbatches if shape.kind == "train" else None,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops_per_device": corrected.flops,
        "bytes_accessed_per_device": corrected.bytes_accessed,
        "xla_flops_per_device": float(cost.get("flops", 0.0)),
        "xla_bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_bytes": mem.argument_size_in_bytes
            + mem.temp_size_in_bytes - mem.alias_size_in_bytes,
        },
        "collectives": corrected.collectives,
        "collectives_raw": colls_raw,
        "total_params": cfg.total_params(),
        "active_params": cfg.active_params(),
    }
    os.makedirs(out_dir, exist_ok=True)
    sfx = ("_fsdp" if fsdp_batch else "") + ("_host" if host_offload else "")
    fname = f"{arch}_{shape_name}_{rec['mesh']}_{schedule}{sfx}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all' (assigned pool)")
    ap.add_argument("--shape", default="all",
                    help="input shape name or 'all'")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--schedule", default="vertical",
                    choices=["vertical", "horizontal"])
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--fsdp", action="store_true",
                    help="batch over (data,model) + activation/grad "
                         "sharding constraints (beyond-paper optimized)")
    ap.add_argument("--host-offload", action="store_true",
                    help="place optimizer states in pinned_host memory "
                         "(the paper's CPU-resident states). NOTE: lowers "
                         "everywhere, but the CPU-backend SPMD partitioner "
                         "rejects placement annotations (XLA RET_CHECK "
                         "spmd_partitioner.cc:5669) — compiles on real TPU "
                         "backends only; see DESIGN.md §5.")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = ASSIGNED_ARCHS if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    for arch in archs:
        for shape in shapes:
            if not supports_shape(arch, shape):
                print(f"SKIP {arch} x {shape} (long-context ineligible, "
                      f"see DESIGN.md)", flush=True)
                continue
            for mp in meshes:
                tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
                try:
                    rec = run_one(arch, shape, mp, schedule=args.schedule,
                                  microbatches=args.microbatches,
                                  out_dir=args.out, fsdp_batch=args.fsdp,
                                  host_offload=args.host_offload)
                    peak = rec["memory"]["peak_estimate_bytes"] / 1e9
                    print(f"OK   {tag}: compile={rec['compile_s']}s "
                          f"flops/dev={rec['flops_per_device']:.3e} "
                          f"peak/dev={peak:.2f}GB", flush=True)
                except Exception as e:  # noqa: BLE001
                    print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)


if __name__ == "__main__":
    main()
