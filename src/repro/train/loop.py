"""Trainer: jit'd train loop over the configured schedule."""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.schedules import (ScheduleConfig, init_train_state,
                                  make_delayed_train_step, make_train_step)
from repro.data import SyntheticLM, make_batch
from repro.optim import AdamConfig


@dataclasses.dataclass
class TrainReport:
    losses: List[float]
    steps_per_s: float
    tokens_per_s: float


class Trainer:
    """End-to-end driver: synthetic data -> schedule -> Adam -> metrics."""

    def __init__(self, cfg, sched: ScheduleConfig, adam: Optional[AdamConfig] = None,
                 seed: int = 0):
        self.cfg = cfg
        self.sched = sched
        self.adam = adam or AdamConfig()
        self.key = jax.random.PRNGKey(seed)
        self.data = SyntheticLM(cfg.vocab_size, seed=seed)
        self.delayed = sched.alpha > 0.0
        self.params, self.state = init_train_state(cfg, self.key,
                                                   delayed=self.delayed)
        if self.delayed:
            step = make_delayed_train_step(cfg, sched, self.adam)
            self._step = jax.jit(step)
        else:
            step = make_train_step(cfg, sched, self.adam)
            self._step = jax.jit(step)
        self.step_num = 0

    def _next_batch(self, batch_size: int, seq_len: int) -> Dict[str, Any]:
        b = make_batch(self.cfg, batch_size, seq_len,
                       seed=self.step_num + 1, data=self.data)
        return {k: jnp.asarray(v) for k, v in b.items()}

    def run(self, steps: int, batch_size: int, seq_len: int,
            log_every: int = 10, log=print) -> TrainReport:
        losses = []
        t0 = None
        for i in range(steps):
            batch = self._next_batch(batch_size, seq_len)
            if self.delayed:
                self.params, self.state, metrics = self._step(self.state, batch)
            else:
                self.params, self.state, metrics = self._step(
                    self.params, self.state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            self.step_num += 1
            if i == 0:
                jax.block_until_ready(metrics["loss"])
                t0 = time.perf_counter()  # exclude compile
            if log_every and (i % log_every == 0 or i == steps - 1):
                log(f"step {self.step_num:5d} loss {loss:8.4f} "
                    f"gnorm {float(metrics['grad_norm']):8.3f}")
        jax.block_until_ready(self.params)
        dt = time.perf_counter() - (t0 or time.perf_counter())
        sps = (steps - 1) / dt if steps > 1 and dt > 0 else 0.0
        return TrainReport(losses, sps, sps * batch_size * seq_len)
