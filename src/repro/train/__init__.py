from repro.train.loop import Trainer, TrainReport  # noqa: F401
from repro.train import checkpoint  # noqa: F401
