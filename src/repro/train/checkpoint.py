"""Layerwise checkpoint save/restore (host .npz files).

Parameters and optimizer state are flattened with stable key paths and
written as one compressed npz per top-level group — the same layer-major
layout the offload engine uses, so a training run can be resumed either
in-memory or SSD-offloaded.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            # npz cannot round-trip ml_dtypes (bf16 loads back as raw
            # void); store as f32 — lossless upcast, restore() casts
            # back to the leaf dtype.
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save(path: str, params, opt_state=None, *, step: int = 0, meta: dict = None):
    os.makedirs(path, exist_ok=True)
    np.savez_compressed(os.path.join(path, "params.npz"), **_flatten(params))
    if opt_state is not None:
        np.savez_compressed(os.path.join(path, "opt.npz"), **_flatten(opt_state))
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump({"step": step, **(meta or {})}, f)


def _unflatten_like(tree, flat: Dict[str, np.ndarray]):
    leaves_p, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves_p:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = flat[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        out.append(jnp.asarray(arr, leaf.dtype))
    return jax.tree_util.tree_unflatten(jax.tree.structure(tree), out)


def restore(path: str, params_like, opt_like=None) -> Tuple[Any, Any, int]:
    with np.load(os.path.join(path, "params.npz")) as z:
        params = _unflatten_like(params_like, dict(z))
    opt = None
    if opt_like is not None:
        with np.load(os.path.join(path, "opt.npz")) as z:
            opt = _unflatten_like(opt_like, dict(z))
    with open(os.path.join(path, "meta.json")) as f:
        step = json.load(f)["step"]
    return params, opt, step
