"""Attention variants: GQA (+qk-norm, RoPE), sliding-window, MLA.

Memory discipline: the (Sq x Skv) score matrix is never materialised for
long sequences. ``flash_attention`` is a chunked online-softmax with a
custom VJP (backward recomputes scores chunk-wise), so it is safe to use
under per-layer remat for train_4k and for 32k prefill. Sliding-window
layers use an exact banded implementation with linear FLOPs.

This module is also the pure-jnp oracle for ``repro.kernels.flash_attention``.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, dense_init, init_rms_scale, rms_norm

_NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Chunked flash attention (pure jnp, custom VJP)
# ---------------------------------------------------------------------------

def _mask(q_pos, kv_pos, causal: bool, window: Optional[int]):
    """(q, k) -> bool allowed. q_pos: (..., Sq), kv_pos: (..., Skv)."""
    m = jnp.ones((q_pos.shape[-1], kv_pos.shape[-1]), bool)
    if causal:
        m &= kv_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= (q_pos[:, None] - kv_pos[None, :]) < window
    return m


def _choose_chunk(s: int, target: int) -> int:
    if s <= target:
        return s
    c = target
    while s % c != 0:
        c //= 2
    return max(c, 1)


def _flash_fwd_impl(q, k, v, q0: int, causal: bool, window: Optional[int],
                    q_chunk: int, kv_chunk: int, scale: float):
    """Returns (out, lse). q: (B,Hk,G,Sq,hd); k,v: (B,Hk,Skv,hd)."""
    B, Hk, G, Sq, hd = q.shape
    Skv = k.shape[2]
    hv = v.shape[-1]
    nq = Sq // q_chunk
    nk = Skv // kv_chunk

    def per_q(args):
        qi, qc = args  # qc: (B,Hk,G,qc,hd)
        q_pos = q0 + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, kj):
            acc, m, l = carry
            kc = jax.lax.dynamic_slice_in_dim(k, kj * kv_chunk, kv_chunk, 2)
            vc = jax.lax.dynamic_slice_in_dim(v, kj * kv_chunk, kv_chunk, 2)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qc.astype(jnp.float32),
                           kc.astype(jnp.float32)) * scale
            kv_pos = kj * kv_chunk + jnp.arange(kv_chunk)
            msk = _mask(q_pos, kv_pos, causal, window)
            s = jnp.where(msk, s, _NEG_INF)
            m2 = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m2[..., None])
            corr = jnp.exp(m - m2)
            l2 = corr * l + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bhkd->bhgqd", p, vc.astype(jnp.float32))
            acc2 = acc * corr[..., None] + pv
            return (acc2, m2, l2), None

        acc0 = jnp.zeros((B, Hk, G, q_chunk, hv), jnp.float32)
        m0 = jnp.full((B, Hk, G, q_chunk), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hk, G, q_chunk), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0), jnp.arange(nk))
        l = jnp.maximum(l, 1e-30)
        out = acc / l[..., None]
        lse = m + jnp.log(l)
        return out, lse

    qs = jnp.moveaxis(q.reshape(B, Hk, G, nq, q_chunk, hd), 3, 0)
    outs, lses = jax.lax.map(per_q, (jnp.arange(nq), qs))
    out = jnp.moveaxis(outs, 0, 3).reshape(B, Hk, G, Sq, hv)
    lse = jnp.moveaxis(lses, 0, 3).reshape(B, Hk, G, Sq)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, q0, causal, window, q_chunk, kv_chunk, scale):
    out, _ = _flash_fwd_impl(q, k, v, q0, causal, window, q_chunk, kv_chunk, scale)
    return out


def _flash_vjp_fwd(q, k, v, q0, causal, window, q_chunk, kv_chunk, scale):
    out, lse = _flash_fwd_impl(q, k, v, q0, causal, window, q_chunk, kv_chunk, scale)
    return out, (q, k, v, out, lse)


def _flash_vjp_bwd(q0, causal, window, q_chunk, kv_chunk, scale, res, dout):
    q, k, v, out, lse = res
    B, Hk, G, Sq, hd = q.shape
    Skv = k.shape[2]
    nq = Sq // q_chunk
    nk = Skv // kv_chunk
    do32 = dout.astype(jnp.float32)
    # D_i = rowsum(dO * O)
    delta = jnp.sum(do32 * out.astype(jnp.float32), axis=-1)  # (B,Hk,G,Sq)

    def per_kv(carry, kj):
        dq_acc = carry  # (B,Hk,G,Sq,hd) f32
        kc = jax.lax.dynamic_slice_in_dim(k, kj * kv_chunk, kv_chunk, 2).astype(jnp.float32)
        vc = jax.lax.dynamic_slice_in_dim(v, kj * kv_chunk, kv_chunk, 2).astype(jnp.float32)
        kv_pos = kj * kv_chunk + jnp.arange(kv_chunk)

        def per_q(qcarry, qi):
            dq_acc, dk_acc, dv_acc = qcarry
            qc = jax.lax.dynamic_slice_in_dim(q, qi * q_chunk, q_chunk, 3).astype(jnp.float32)
            doc = jax.lax.dynamic_slice_in_dim(do32, qi * q_chunk, q_chunk, 3)
            lsec = jax.lax.dynamic_slice_in_dim(lse, qi * q_chunk, q_chunk, 3)
            dc = jax.lax.dynamic_slice_in_dim(delta, qi * q_chunk, q_chunk, 3)
            q_pos = qi * q_chunk + jnp.arange(q_chunk)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qc, kc) * scale
            msk = _mask(q_pos, kv_pos, causal, window)
            s = jnp.where(msk, s, _NEG_INF)
            p = jnp.exp(s - lsec[..., None])
            dv_acc = dv_acc + jnp.einsum("bhgqk,bhgqd->bhkd", p, doc)
            dp = jnp.einsum("bhgqd,bhkd->bhgqk", doc, vc)
            ds = p * (dp - dc[..., None]) * scale
            dq_c = jnp.einsum("bhgqk,bhkd->bhgqd", ds, kc)
            dq_acc = jax.lax.dynamic_update_slice_in_dim(
                dq_acc,
                jax.lax.dynamic_slice_in_dim(dq_acc, qi * q_chunk, q_chunk, 3) + dq_c,
                qi * q_chunk, 3)
            dk_acc = dk_acc + jnp.einsum("bhgqk,bhgqd->bhkd", ds, qc)
            return (dq_acc, dk_acc, dv_acc), None

        dk0 = jnp.zeros((B, Hk, kv_chunk, hd), jnp.float32)
        dv0 = jnp.zeros((B, Hk, kv_chunk, v.shape[-1]), jnp.float32)
        (dq_acc, dk_c, dv_c), _ = jax.lax.scan(per_q, (dq_acc, dk0, dv0), jnp.arange(nq))
        return dq_acc, (dk_c, dv_c)

    # q0 is static 0 in training (only decode uses q0>0, and decode has no vjp)
    dq0 = jnp.zeros((B, Hk, G, Sq, hd), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(per_kv, dq0, jnp.arange(nk))
    dk = jnp.moveaxis(dks, 0, 2).reshape(B, Hk, Skv, hd)
    dv = jnp.moveaxis(dvs, 0, 2).reshape(B, Hk, Skv, v.shape[-1])
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def flash_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                    q0: int = 0, q_chunk: int = 512, kv_chunk: int = 1024,
                    scale: Optional[float] = None):
    """q: (B,Hq,Sq,hd); k,v: (B,Hk,Skv,hd[v]). Returns (B,Hq,Sq,hdv).

    GQA is handled by grouping Hq into Hk groups (no K/V repeat).
    """
    B, Hq, Sq, hd = q.shape
    Hk = k.shape[1]
    assert Hq % Hk == 0, (Hq, Hk)
    G = Hq // Hk
    qc = _choose_chunk(Sq, q_chunk)
    kc = _choose_chunk(k.shape[2], kv_chunk)
    sc = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Hk, G, Sq, hd)
    out = _flash(qg, k, v, q0, causal, window, qc, kc, sc)
    return out.reshape(B, Hq, Sq, v.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# Banded sliding-window attention (exact, linear FLOPs)
# ---------------------------------------------------------------------------

def banded_attention(q, k, v, *, window: int, scale: Optional[float] = None):
    """Causal sliding-window attention with block-banded compute.

    Requires Sq == Skv and Sq % window == 0 (callers fall back to
    flash_attention otherwise). Each query block of size w attends to
    [previous block, own block] with an exact mask.
    """
    B, Hq, S, hd = q.shape
    Hk = k.shape[1]
    G = Hq // Hk
    w = window
    nb = S // w
    sc = scale if scale is not None else 1.0 / math.sqrt(hd)

    qb = q.reshape(B, Hk, G, nb, w, hd).astype(jnp.float32)
    kb = k.reshape(B, Hk, nb, w, hd).astype(jnp.float32)
    vb = v.reshape(B, Hk, nb, w, v.shape[-1]).astype(jnp.float32)
    # previous block of k/v (block -1 is zeros, masked out)
    kprev = jnp.pad(kb[:, :, :-1], ((0, 0), (0, 0), (1, 0), (0, 0), (0, 0)))
    vprev = jnp.pad(vb[:, :, :-1], ((0, 0), (0, 0), (1, 0), (0, 0), (0, 0)))
    kctx = jnp.concatenate([kprev, kb], axis=3)   # (B,Hk,nb,2w,hd)
    vctx = jnp.concatenate([vprev, vb], axis=3)

    s = jnp.einsum("bhgnqd,bhnkd->bhgnqk", qb, kctx) * sc  # (B,Hk,G,nb,w,2w)
    qpos = jnp.arange(w)[:, None]
    kpos = jnp.arange(2 * w)[None, :] - w
    allowed = (kpos <= qpos) & (qpos - kpos < w)  # within-band mask
    first = jnp.arange(nb) == 0                   # block 0 has no prev block
    no_prev = jnp.concatenate([jnp.zeros((w,), bool), jnp.ones((w,), bool)])
    msk = allowed[None] | jnp.zeros((nb, 1, 1), bool)
    msk = msk & (no_prev[None, None, :] | ~first[:, None, None])
    s = jnp.where(msk[None, None, None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgnqk,bhnkd->bhgnqd", p, vctx)
    return o.reshape(B, Hq, S, v.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode attention (single query position)
# ---------------------------------------------------------------------------

def decode_attention(q, k, v, *, kv_pos, pos, window: Optional[int] = None,
                     scale: Optional[float] = None):
    """q: (B,Hq,1,hd); k,v: (B,Hk,S,hd); kv_pos: (S,) int32 slot positions
    (-big for empty). pos: scalar current position. Returns (B,Hq,1,hdv)."""
    B, Hq, _, hd = q.shape
    Hk = k.shape[1]
    G = Hq // Hk
    sc = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Hk, G, hd).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhsd->bhgs", qg, k.astype(jnp.float32)) * sc
    ok = kv_pos <= pos
    if window is not None:
        ok &= (pos - kv_pos) < window
    s = jnp.where(ok[None, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bhsd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, 1, v.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block (qk-norm, RoPE, sliding window, KV/ring cache)
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array        # (B, Hk, S_cache, hd)
    v: jax.Array        # (B, Hk, S_cache, hd)
    slot_pos: jax.Array  # (S_cache,) int32; -2**30 for empty slots


def gqa_init(key, cfg, dtype=jnp.bfloat16):
    d, Hq, Hk, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, Hq * hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, Hk * hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, Hk * hd), dtype=dtype),
        "wo": dense_init(ks[3], (Hq * hd, d), dtype=dtype),
    }
    if cfg.use_qk_norm:
        p["q_norm"] = init_rms_scale(hd)
        p["k_norm"] = init_rms_scale(hd)
    return p


def gqa_apply(params, x, *, cfg, window: Optional[int], theta: float,
              cache: Optional[KVCache] = None, pos=None,
              mode: str = "train", causal: bool = True
              ) -> Tuple[jax.Array, Optional[KVCache]]:
    """x: (B,S,d). mode: train | prefill | decode.

    decode: x is (B,1,d), ``pos`` is the scalar position, cache is updated.
    prefill: returns a filled cache (cache arg provides the allocated bufs).
    """
    B, S, d = x.shape
    Hq, Hk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(B, S, Hq, hd)
    k = (x @ params["wk"]).reshape(B, S, Hk, hd)
    v = (x @ params["wv"]).reshape(B, S, Hk, hd)
    if cfg.use_qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    if mode == "decode":
        positions = jnp.full((1,), pos, jnp.int32)
    else:
        positions = jnp.arange(S, dtype=jnp.int32)
    q = apply_rope(q, positions[None, :], theta)
    k = apply_rope(k, positions[None, :], theta)
    q = q.transpose(0, 2, 1, 3)  # (B,Hq,S,hd)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)

    new_cache = None
    if mode == "decode":
        assert cache is not None
        s_cache = cache.k.shape[2]
        slot = pos % s_cache if window is not None else pos
        ck = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), slot, 2)
        cv = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), slot, 2)
        spos = jax.lax.dynamic_update_slice_in_dim(
            cache.slot_pos, jnp.full((1,), pos, jnp.int32), slot, 0)
        new_cache = KVCache(ck, cv, spos)
        o = decode_attention(q, ck, cv, kv_pos=spos, pos=pos, window=window)
    else:
        if causal and window is not None and S % window == 0 and S >= window:
            o = banded_attention(q, k, v, window=window)
        else:
            o = flash_attention(q, k, v, causal=causal, window=window)
        if mode == "prefill":
            assert cache is not None
            s_cache = cache.k.shape[2]
            if window is not None:
                # keep only the trailing `window` positions in the ring
                keep = min(window, S)
                tail_k = k[:, :, S - keep:, :]
                tail_v = v[:, :, S - keep:, :]
                tail_pos = jnp.arange(S - keep, S, dtype=jnp.int32)
                start = (S - keep) % s_cache
                ck = jax.lax.dynamic_update_slice_in_dim(
                    cache.k, tail_k.astype(cache.k.dtype), start, 2)
                cv = jax.lax.dynamic_update_slice_in_dim(
                    cache.v, tail_v.astype(cache.v.dtype), start, 2)
                spos = jax.lax.dynamic_update_slice_in_dim(
                    cache.slot_pos, tail_pos, start, 0)
            else:
                ck = jax.lax.dynamic_update_slice_in_dim(
                    cache.k, k.astype(cache.k.dtype), 0, 2)
                cv = jax.lax.dynamic_update_slice_in_dim(
                    cache.v, v.astype(cache.v.dtype), 0, 2)
                spos = jax.lax.dynamic_update_slice_in_dim(
                    cache.slot_pos, jnp.arange(S, dtype=jnp.int32), 0, 0)
            new_cache = KVCache(ck, cv, spos)

    o = o.transpose(0, 2, 1, 3).reshape(B, S, Hq * hd)
    return (o @ params["wo"]).astype(x.dtype), new_cache


def gqa_cache_shape(cfg, batch: int, seq_len: int, window: Optional[int],
                    dtype=jnp.bfloat16) -> KVCache:
    """Allocate (or eval_shape) a KV cache. Sliding-window layers use a
    ring buffer of length `window` — the paper's memory frugality carried
    into serving."""
    s = min(window, seq_len) if window is not None else seq_len
    Hk, hd = cfg.num_kv_heads, cfg.head_dim
    return KVCache(
        k=jnp.zeros((batch, Hk, s, hd), dtype),
        v=jnp.zeros((batch, Hk, s, hd), dtype),
        slot_pos=jnp.full((s,), -(2 ** 30), jnp.int32),
    )


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------

class MLACache(NamedTuple):
    latent: jax.Array   # (B, S, kv_lora)
    k_rope: jax.Array   # (B, S, rope_dim)
    slot_pos: jax.Array  # (S,)


def mla_init(key, cfg, dtype=jnp.bfloat16):
    d, H = cfg.d_model, cfg.num_heads
    nope, rope, vhd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    ks = jax.random.split(key, 5)
    return {
        "wq": dense_init(ks[0], (d, H * (nope + rope)), dtype=dtype),
        "w_dkv": dense_init(ks[1], (d, r + rope), dtype=dtype),
        "kv_norm": init_rms_scale(r),
        "w_uk": dense_init(ks[2], (r, H * nope), dtype=dtype),
        "w_uv": dense_init(ks[3], (r, H * vhd), dtype=dtype),
        "wo": dense_init(ks[4], (H * vhd, d), dtype=dtype),
    }


def mla_apply(params, x, *, cfg, theta: float, cache: Optional[MLACache] = None,
              pos=None, mode: str = "train") -> Tuple[jax.Array, Optional[MLACache]]:
    B, S, d = x.shape
    H = cfg.num_heads
    nope, rope, vhd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    scale = 1.0 / math.sqrt(nope + rope)

    q = (x @ params["wq"]).reshape(B, S, H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    dkv = x @ params["w_dkv"]  # (B,S,r+rope)
    latent = rms_norm(dkv[..., :r], params["kv_norm"], cfg.norm_eps)
    k_rope = dkv[..., r:]

    if mode == "decode":
        positions = jnp.full((1,), pos, jnp.int32)
    else:
        positions = jnp.arange(S, dtype=jnp.int32)
    q_rope = apply_rope(q_rope, positions[None, :], theta)
    k_rope = apply_rope(k_rope[:, :, None, :], positions[None, :], theta)[:, :, 0, :]

    new_cache = None
    if mode == "decode":
        assert cache is not None
        cl = jax.lax.dynamic_update_slice_in_dim(
            cache.latent, latent.astype(cache.latent.dtype), pos, 1)
        cr = jax.lax.dynamic_update_slice_in_dim(
            cache.k_rope, k_rope.astype(cache.k_rope.dtype), pos, 1)
        spos = jax.lax.dynamic_update_slice_in_dim(
            cache.slot_pos, jnp.full((1,), pos, jnp.int32), pos, 0)
        new_cache = MLACache(cl, cr, spos)
        # absorbed decode: queries projected into latent space
        w_uk = params["w_uk"].reshape(r, H, nope)
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32))  # (B,1,H,r)
        s_lat = jnp.einsum("bshr,btr->bhst", q_lat, cl.astype(jnp.float32))
        s_rope = jnp.einsum("bshn,btn->bhst", q_rope.astype(jnp.float32),
                            cr.astype(jnp.float32))
        s = (s_lat + s_rope) * scale  # (B,H,1,T)
        ok = spos <= pos
        s = jnp.where(ok[None, None, None, :], s, _NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhst,btr->bshr", p, cl.astype(jnp.float32))
        w_uv = params["w_uv"].reshape(r, H, vhd)
        o = jnp.einsum("bshr,rhv->bshv", o_lat, w_uv.astype(jnp.float32))
    else:
        k_nope = (latent @ params["w_uk"]).reshape(B, S, H, nope)
        vfull = (latent @ params["w_uv"]).reshape(B, S, H, vhd)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, rope))], -1)
        qfull = jnp.concatenate([q_nope, q_rope], -1)
        o = flash_attention(qfull.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                            vfull.transpose(0, 2, 1, 3), causal=cfg.causal,
                            scale=scale).transpose(0, 2, 1, 3)
        if mode == "prefill":
            assert cache is not None
            cl = jax.lax.dynamic_update_slice_in_dim(
                cache.latent, latent.astype(cache.latent.dtype), 0, 1)
            cr = jax.lax.dynamic_update_slice_in_dim(
                cache.k_rope, k_rope.astype(cache.k_rope.dtype), 0, 1)
            spos = jax.lax.dynamic_update_slice_in_dim(
                cache.slot_pos, jnp.arange(S, dtype=jnp.int32), 0, 0)
            new_cache = MLACache(cl, cr, spos)

    o = o.reshape(B, S, H * vhd).astype(x.dtype)
    return o @ params["wo"], new_cache


def mla_cache_shape(cfg, batch: int, seq_len: int, dtype=jnp.bfloat16) -> MLACache:
    return MLACache(
        latent=jnp.zeros((batch, seq_len, cfg.kv_lora_rank), dtype),
        k_rope=jnp.zeros((batch, seq_len, cfg.qk_rope_head_dim), dtype),
        slot_pos=jnp.full((seq_len,), -(2 ** 30), jnp.int32),
    )
