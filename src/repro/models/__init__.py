"""Model zoo: all assigned architectures as composable JAX modules."""
from repro.models.model import (  # noqa: F401
    decode_step,
    forward_hidden,
    init_caches,
    init_params,
    loss_fn,
    prefill,
)
from repro.models.blocks import LayerKind, LayerPlan, build_plan  # noqa: F401
