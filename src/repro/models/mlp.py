"""Feed-forward blocks: SwiGLU and GELU MLPs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init


def mlp_init(key, d: int, f: int, act: str, dtype=jnp.bfloat16):
    if act == "swiglu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "w_gate": dense_init(k1, (d, f), dtype=dtype),
            "w_up": dense_init(k2, (d, f), dtype=dtype),
            "w_down": dense_init(k3, (f, d), dtype=dtype),
        }
    k1, k2 = jax.random.split(key, 2)
    return {
        "w_in": dense_init(k1, (d, f), dtype=dtype),
        "w_out": dense_init(k2, (f, d), dtype=dtype),
    }


def mlp_apply(params, x, act: str):
    if act == "swiglu":
        g = jax.nn.silu((x @ params["w_gate"]).astype(jnp.float32))
        u = (x @ params["w_up"]).astype(jnp.float32)
        return ((g * u).astype(x.dtype)) @ params["w_down"]
    h = jax.nn.gelu((x @ params["w_in"]).astype(jnp.float32), approximate=True)
    return h.astype(x.dtype) @ params["w_out"]
