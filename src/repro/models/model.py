"""Top-level models: causal LM (dense/MoE/SSM/hybrid/VLM) and enc-dec.

Layer execution is ``prefix -> lax.scan(period) -> suffix`` (see blocks.py),
with the period body rematerialised: that is exactly the paper's per-layer
activation checkpointing — the scan carry (one layer's output for the whole
batch) is the "inter-layer activation checkpoint" of GreedySnake §2.2.

Training-memory discipline mirrors GreedySnake: the quadratic attention
intermediates and FFN activations are recomputed in backward (remat), so
peak memory holds one layer's working set plus the per-layer checkpoints.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import blocks as blk
from repro.models.common import embed_init, init_rms_scale, rms_norm, sinusoidal_pos_emb

AUX_COEF = 0.01  # router load-balance coefficient

# Optional activation-sharding constraint (set by the launcher): a
# PartitionSpec pinned onto the layer-scan carry so XLA SPMD keeps
# activations fully batch-sharded (pure FSDP) instead of flip-flopping
# into tensor-parallel layouts with per-layer activation all-reduces.
_ACT_SPEC: Optional[Any] = None


def set_activation_spec(spec) -> None:
    global _ACT_SPEC
    _ACT_SPEC = spec


def _constrain(x):
    if _ACT_SPEC is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, _ACT_SPEC)
    except (ValueError, RuntimeError):  # no ambient mesh / spec mismatch
        return x


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _stack_periods(keys, cfg, period, dtype):
    def one(key):
        ks = jax.random.split(key, max(1, len(period)))
        return {f"sub{j}": blk.block_init(ks[j], cfg, kind, dtype)
                for j, kind in enumerate(period)}
    per = [one(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per)


def init_params(cfg, key, dtype=jnp.bfloat16) -> Dict[str, Any]:
    plan = blk.build_plan(cfg)
    n_keys = 4 + len(plan.prefix) + plan.n_periods + len(plan.suffix) + 1
    ks = list(jax.random.split(key, n_keys))
    params: Dict[str, Any] = {}
    params["embed"] = embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dtype)
    i = 1
    params["prefix"] = tuple(
        blk.block_init(ks[i + j], cfg, kind, dtype)
        for j, kind in enumerate(plan.prefix))
    i += len(plan.prefix)
    if plan.n_periods:
        params["periods"] = _stack_periods(ks[i:i + plan.n_periods], cfg,
                                           plan.period, dtype)
    i += plan.n_periods
    params["suffix"] = tuple(
        blk.block_init(ks[i + j], cfg, kind, dtype)
        for j, kind in enumerate(plan.suffix))
    i += len(plan.suffix)
    params["final_norm"] = init_rms_scale(cfg.d_model)
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(ks[i], cfg.padded_vocab, cfg.d_model, dtype).T
    i += 1
    if cfg.family == "encdec":
        eplan = blk.build_plan(cfg, decoder=False, num_layers=cfg.encoder_layers)
        eks = list(jax.random.split(ks[i], eplan.n_periods + len(eplan.prefix) + 2))
        enc: Dict[str, Any] = {}
        enc["prefix"] = tuple(
            blk.block_init(eks[j], cfg, kind, dtype)
            for j, kind in enumerate(eplan.prefix))
        if eplan.n_periods:
            enc["periods"] = _stack_periods(
                eks[len(eplan.prefix):len(eplan.prefix) + eplan.n_periods],
                cfg, eplan.period, dtype)
        enc["suffix"] = ()
        enc["final_norm"] = init_rms_scale(cfg.d_model)
        params["encoder"] = enc
    return params


def unembed_matrix(params, cfg):
    if cfg.tie_embeddings:
        return params["embed"].T  # (d, V)
    return params["unembed"]


# ---------------------------------------------------------------------------
# Stack execution
# ---------------------------------------------------------------------------

def _run_stack(params, x, cfg, plan, *, mode, caches=None, pos=None,
               enc_out=None, remat=True, scan_impl="jnp"):
    """Run prefix + scanned periods + suffix. Returns (x, new_caches, aux)."""
    aux = jnp.zeros((), jnp.float32)
    new_caches: Dict[str, Any] = {"prefix": [], "suffix": []}

    def apply_one(bp, x, kind, cache):
        return blk.block_apply(bp, x, cfg, kind, mode=mode, cache=cache,
                               pos=pos, enc_out=enc_out, scan_impl=scan_impl)

    for j, kind in enumerate(plan.prefix):
        cache = caches["prefix"][j] if caches else None
        fn = apply_one
        if mode == "train" and remat:
            fn = jax.checkpoint(apply_one, static_argnums=(2,), prevent_cse=False)
        x, nc, a = fn(params["prefix"][j], x, kind, cache)
        aux += a
        new_caches["prefix"].append(nc)

    if plan.n_periods:
        if mode == "train":
            def body(carry, pparams):
                x, aux = carry
                x = _constrain(x)
                for j, kind in enumerate(plan.period):
                    x, _, a = blk.block_apply(pparams[f"sub{j}"], x, cfg, kind,
                                              mode="train", enc_out=enc_out,
                                              scan_impl=scan_impl)
                    aux = aux + a
                return (_constrain(x), aux), None
            if remat:
                body = jax.checkpoint(body, prevent_cse=False)
            (x, aux), _ = jax.lax.scan(body, (x, aux), params["periods"])
        else:
            def body(x, xs):
                pparams, pcache = xs
                ncs = {}
                for j, kind in enumerate(plan.period):
                    x, nc, _ = blk.block_apply(pparams[f"sub{j}"], x, cfg, kind,
                                               mode=mode, cache=pcache[f"sub{j}"],
                                               pos=pos, enc_out=enc_out,
                                               scan_impl=scan_impl)
                    ncs[f"sub{j}"] = nc
                return x, ncs
            x, pcs = jax.lax.scan(body, x, (params["periods"], caches["periods"]))
            new_caches["periods"] = pcs

    for j, kind in enumerate(plan.suffix):
        cache = caches["suffix"][j] if caches else None
        fn = apply_one
        if mode == "train" and remat:
            fn = jax.checkpoint(apply_one, static_argnums=(2,), prevent_cse=False)
        x, nc, a = fn(params["suffix"][j], x, kind, cache)
        aux += a
        new_caches["suffix"].append(nc)

    new_caches["prefix"] = tuple(new_caches["prefix"])
    new_caches["suffix"] = tuple(new_caches["suffix"])
    return x, new_caches, aux


def _embed_inputs(params, cfg, batch, *, decode=False):
    """Returns decoder-input embeddings (B,S,d) from the batch dict."""
    tokens = batch["tokens"]
    x = params["embed"][jnp.clip(tokens, 0, cfg.padded_vocab - 1)]
    if cfg.scale_embed:
        x = (x.astype(jnp.float32) * jnp.sqrt(float(cfg.d_model))).astype(x.dtype)
    if cfg.family == "vlm" and not decode:
        img = batch["image_embeds"].astype(x.dtype)  # (B,P,d)
        x = jnp.concatenate([img, x], axis=1)
    return x


def _encode(params, cfg, enc_embeds, *, remat=True):
    """Whisper-style encoder over stubbed frame embeddings (B,F,d)."""
    B, F, d = enc_embeds.shape
    pos = sinusoidal_pos_emb(F, d).astype(enc_embeds.dtype)
    x = enc_embeds + pos[None]
    eplan = blk.build_plan(cfg, decoder=False, num_layers=cfg.encoder_layers)
    x, _, _ = _run_stack(params["encoder"], x, cfg, eplan, mode="train",
                         remat=remat)
    return rms_norm(x, params["encoder"]["final_norm"], cfg.norm_eps)


def forward_hidden(params, cfg, batch, *, remat=True, scan_impl="jnp"
                   ) -> Tuple[jax.Array, jax.Array]:
    """Full-batch forward to final hidden states. Returns (hidden, aux)."""
    plan = blk.build_plan(cfg)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encode(params, cfg, batch["enc_embeds"], remat=remat)
    x = _embed_inputs(params, cfg, batch)
    x, _, aux = _run_stack(params, x, cfg, plan, mode="train", enc_out=enc_out,
                           remat=remat, scan_impl=scan_impl)
    return rms_norm(x, params["final_norm"], cfg.norm_eps), aux


# ---------------------------------------------------------------------------
# Loss (chunked over sequence so (B,chunk,V) is the only logits buffer)
# ---------------------------------------------------------------------------

def _xent_chunk(h, unembed, labels, weights):
    logits = (h @ unembed).astype(jnp.float32)  # (B,c,V)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.sum((lse - ll) * weights), jnp.sum(weights)


def chunked_xent(hidden, unembed, labels, weights, chunk: int = 0):
    """Mean token cross-entropy; logits are formed ``chunk`` positions at a
    time (remat'd) so the (B,S,V) tensor never exists."""
    B, S, d = hidden.shape
    V = unembed.shape[-1]
    if chunk <= 0:
        # Two constraints: (a) the (B, chunk, V) logits buffer stays small
        # (the bytes bound uses the GLOBAL batch, conservative under batch
        # sharding); (b) at most ~32 chunks — every chunk's backward
        # all-reduces the partial d(unembed) across the batch axis, so
        # thousands of tiny chunks turn the loss into a collective storm
        # (796 GB/device for qwen3-4b train_4k before this bound).
        by_bytes = max(1, int((64 << 20) / max(B * V, 1)))
        chunk = min(S, max(S // 32, by_bytes))
    while S % chunk != 0:
        chunk -= 1
    nch = S // chunk

    def to_chunks(a):
        return jnp.moveaxis(a.reshape(B, nch, chunk, *a.shape[2:]), 0, 1)

    body = jax.checkpoint(
        lambda carry, args: (
            tuple(c + v for c, v in zip(carry, _xent_chunk(args[0], unembed,
                                                           args[1], args[2]))),
            None),
        prevent_cse=False)
    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (to_chunks(hidden), to_chunks(labels), to_chunks(weights)))
    return tot / jnp.maximum(cnt, 1.0)


def labels_and_weights(cfg, batch):
    """Next-token labels/weights over the FULL decoder sequence."""
    tokens = batch["tokens"]
    B, St = tokens.shape
    labels = jnp.concatenate([tokens[:, 1:], jnp.zeros((B, 1), tokens.dtype)], 1)
    weights = jnp.concatenate(
        [jnp.ones((B, St - 1), jnp.float32), jnp.zeros((B, 1), jnp.float32)], 1)
    if cfg.family == "vlm":
        P = cfg.frontend_tokens
        # sequence = [P image tokens; St text tokens]; positions P-1..P+St-2
        # predict text tokens 0..St-1; position P-1 predicts text token 0.
        S = P + St
        lab = jnp.zeros((B, S), tokens.dtype)
        lab = jax.lax.dynamic_update_slice(lab, tokens, (0, P - 1))
        w = jnp.zeros((B, S), jnp.float32)
        w = jax.lax.dynamic_update_slice(w, jnp.ones((B, St), jnp.float32), (0, P - 1))
        return lab, w
    return labels, weights


def loss_fn(params, cfg, batch, *, remat=True, scan_impl="jnp"):
    hidden, aux = forward_hidden(params, cfg, batch, remat=remat,
                                 scan_impl=scan_impl)
    labels, weights = labels_and_weights(cfg, batch)
    loss = chunked_xent(hidden, unembed_matrix(params, cfg), labels, weights)
    return loss + AUX_COEF * aux


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode
# ---------------------------------------------------------------------------

def init_caches(cfg, batch: int, seq_len: int, dtype=jnp.bfloat16):
    plan = blk.build_plan(cfg)

    def stack_cache():
        one = {f"sub{j}": blk.block_cache_shape(cfg, kind, batch, seq_len, dtype)
               for j, kind in enumerate(plan.period)}
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (plan.n_periods,) + x.shape), one)

    caches = {
        "prefix": tuple(blk.block_cache_shape(cfg, kind, batch, seq_len, dtype)
                        for kind in plan.prefix),
        "suffix": tuple(blk.block_cache_shape(cfg, kind, batch, seq_len, dtype)
                        for kind in plan.suffix),
    }
    if plan.n_periods:
        caches["periods"] = stack_cache()
    return caches


def cache_units(cfg) -> list:
    """The cache BLOCK LAYOUT seam ``repro.serve`` spills/fetches at:
    one unit per (prefix block | period-i sub-j | suffix block), in
    stack order. A unit is the smallest cache granule that round-trips
    through :func:`get_cache_unit`/:func:`set_cache_unit` bitwise — for
    a plain dense stack (no prefix/suffix, period length 1) this is
    exactly one unit per layer."""
    plan = blk.build_plan(cfg)
    units = [("prefix", j) for j in range(len(plan.prefix))]
    units += [("period", i, j) for i in range(plan.n_periods)
              for j in range(len(plan.period))]
    units += [("suffix", j) for j in range(len(plan.suffix))]
    return units


def cache_unit_nbytes(cfg, caches) -> list:
    """Per-unit payload bytes (shape metadata only — no device reads),
    aligned with :func:`cache_units` order. The serve engine's KV block
    tables and ``plan_traffic``'s ``kv_unit_nbytes`` both come from
    here, so the three-way byte invariant shares one source."""
    return [sum(int(l.size) * l.dtype.itemsize
                for l in jax.tree.leaves(get_cache_unit(caches, u)))
            for u in cache_units(cfg)]


def get_cache_unit(caches, unit):
    """One unit's cache pytree (period units slice the scan stack)."""
    if unit[0] == "prefix":
        return caches["prefix"][unit[1]]
    if unit[0] == "suffix":
        return caches["suffix"][unit[1]]
    _, i, j = unit
    return jax.tree.map(lambda a: a[i], caches["periods"][f"sub{j}"])


def set_cache_unit(caches, unit, value):
    """Functionally replace one unit; returns the new caches pytree."""
    new = dict(caches)
    if unit[0] == "prefix":
        t = list(new["prefix"])
        t[unit[1]] = value
        new["prefix"] = tuple(t)
        return new
    if unit[0] == "suffix":
        t = list(new["suffix"])
        t[unit[1]] = value
        new["suffix"] = tuple(t)
        return new
    _, i, j = unit
    periods = dict(new["periods"])
    periods[f"sub{j}"] = jax.tree.map(lambda a, x: a.at[i].set(x),
                                      periods[f"sub{j}"], value)
    new["periods"] = periods
    return new


def prefill(params, cfg, batch, caches, *, scan_impl="jnp"):
    """Process the prompt; fill caches; return (last_logits, caches)."""
    plan = blk.build_plan(cfg)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encode(params, cfg, batch["enc_embeds"], remat=False)
    x = _embed_inputs(params, cfg, batch)
    x, new_caches, _ = _run_stack(params, x, cfg, plan, mode="prefill",
                                  caches=caches, enc_out=enc_out, remat=False,
                                  scan_impl=scan_impl)
    h = rms_norm(x[:, -1:, :], params["final_norm"], cfg.norm_eps)
    logits = (h @ unembed_matrix(params, cfg))[:, 0, :]
    return logits.astype(jnp.float32), new_caches


def decode_step(params, cfg, token, pos, caches, *, scan_impl="jnp"):
    """One decode step. token: (B,1) int32; pos: scalar int32 position.

    Returns (logits (B,V) f32, updated caches)."""
    plan = blk.build_plan(cfg)
    x = _embed_inputs(params, cfg, {"tokens": token}, decode=True)
    x, new_caches, _ = _run_stack(params, x, cfg, plan, mode="decode",
                                  caches=caches, pos=pos, remat=False,
                                  scan_impl=scan_impl)
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (h @ unembed_matrix(params, cfg))[:, 0, :]
    return logits.astype(jnp.float32), new_caches
