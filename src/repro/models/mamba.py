"""Mamba-1 block (Falcon-Mamba / Jamba mixer): selective state-space scan.

The selective scan is a two-level chunked ``lax.scan`` (outer over chunks
with the SSM state as carry, inner sequential within a chunk) with the
outer body rematerialised, so backward memory is O(S/chunk * B*di*st)
checkpointed states + one chunk of residuals — the same
checkpoint/recompute structure GreedySnake applies at layer granularity,
applied here along time.

Also the pure-jnp oracle for ``repro.kernels.selective_scan``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init


class MambaState(NamedTuple):
    conv: jax.Array  # (B, conv-1, di) — trailing conv inputs
    h: jax.Array     # (B, di, st) f32 — SSM state


def mamba_init(key, cfg, dtype=jnp.bfloat16):
    d, di, st, rk = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    ks = jax.random.split(key, 6)
    # S4D-real initialisation for A; dt bias so softplus(dt) spans [1e-3, 1e-1]
    a = jnp.tile(jnp.arange(1, st + 1, dtype=jnp.float32)[None, :], (di, 1))
    dt_init = jnp.exp(jax.random.uniform(ks[0], (di,), jnp.float32)
                      * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))  # inverse softplus
    return {
        "in_proj": dense_init(ks[1], (d, 2 * di), dtype=dtype),
        "conv_w": dense_init(ks[2], (cfg.ssm_conv, di), in_axis=0, dtype=dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[3], (di, rk + 2 * st), dtype=dtype),
        "dt_proj": dense_init(ks[4], (rk, di), dtype=dtype),
        "dt_bias": dt_bias,
        "A_log": jnp.log(a),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], (di, d), dtype=dtype),
    }


def selective_scan(x, dt, A, Bc, Cc, D, *, h0=None, chunk: int = 64
                   ) -> Tuple[jax.Array, jax.Array]:
    """Selective SSM scan.

    x, dt: (B, S, di); Bc, Cc: (B, S, st); A: (di, st); D: (di,).
    Returns (y: (B,S,di), h_final: (B,di,st) f32).
    """
    B, S, di = x.shape
    st = A.shape[-1]
    c = chunk
    while S % c != 0:
        c //= 2
    nch = S // c
    if h0 is None:
        h0 = jnp.zeros((B, di, st), jnp.float32)

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = Bc.astype(jnp.float32)
    Cf = Cc.astype(jnp.float32)

    def inner_step(h, args):
        xt, dtt, bt, ct = args  # (B,di),(B,di),(B,st),(B,st)
        da = jnp.exp(dtt[..., None] * A)          # (B,di,st)
        h = da * h + (dtt * xt)[..., None] * bt[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, ct)
        return h, y

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def outer_body(h, args):
        xc, dtc, bc, cc = args  # (c, B, ...)
        h, ys = jax.lax.scan(inner_step, h, (xc, dtc, bc, cc))
        return h, ys

    def to_chunks(a):  # (B,S,F) -> (nch, c, B, F)
        return jnp.moveaxis(a.reshape(B, nch, c, -1), 0, 2)

    h, ys = jax.lax.scan(outer_body, h0,
                         (to_chunks(xf), to_chunks(dtf), to_chunks(Bf), to_chunks(Cf)))
    y = jnp.moveaxis(ys.reshape(S, B, di), 0, 1)  # wait-free reshape: (nch*c,B,di)
    y = y + xf * D
    return y.astype(x.dtype), h


def _causal_conv(x_in, conv_w, conv_b, tail: Optional[jax.Array] = None):
    """Depthwise causal conv along S. x_in: (B,S,di); conv_w: (K,di).

    tail: (B, K-1, di) previous inputs for streaming prefill (zeros if None).
    """
    B, S, di = x_in.shape
    K = conv_w.shape[0]
    if tail is None:
        tail = jnp.zeros((B, K - 1, di), x_in.dtype)
    xp = jnp.concatenate([tail, x_in], axis=1)  # (B, S+K-1, di)
    # sum_k w[k] * x[t - (K-1) + k]
    out = jnp.zeros((B, S, di), jnp.float32)
    for k in range(K):
        out = out + xp[:, k:k + S, :].astype(jnp.float32) * conv_w[k].astype(jnp.float32)
    return (out + conv_b.astype(jnp.float32)).astype(x_in.dtype)


def mamba_apply(params, x, cfg, *, state: Optional[MambaState] = None,
                mode: str = "train", scan_impl: str = "jnp"
                ) -> Tuple[jax.Array, Optional[MambaState]]:
    """x: (B,S,d). decode: S==1 with state; prefill returns final state."""
    B, S, d = x.shape
    di, st, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    rk = cfg.dt_rank
    xz = x @ params["in_proj"]
    x_in, z = xz[..., :di], xz[..., di:]
    A = -jnp.exp(params["A_log"])

    if mode == "decode":
        assert state is not None
        xp = jnp.concatenate([state.conv.astype(x_in.dtype), x_in], axis=1)  # (B,K,di)
        xc = jnp.einsum("bkd,kd->bd", xp.astype(jnp.float32),
                        params["conv_w"].astype(jnp.float32)) + params["conv_b"].astype(jnp.float32)
        xc = jax.nn.silu(xc).astype(x.dtype)[:, None, :]  # (B,1,di)
        new_conv = xp[:, 1:, :].astype(state.conv.dtype)
    else:
        xc = jax.nn.silu(_causal_conv(x_in, params["conv_w"], params["conv_b"]
                                      ).astype(jnp.float32)).astype(x.dtype)
        new_conv = None

    proj = xc @ params["x_proj"]  # (B,S,rk+2st)
    dt_raw, Bc, Cc = proj[..., :rk], proj[..., rk:rk + st], proj[..., rk + st:]
    dt = jax.nn.softplus((dt_raw @ params["dt_proj"]).astype(jnp.float32)
                         + params["dt_bias"])  # (B,S,di) f32

    if mode == "decode":
        h = state.h
        da = jnp.exp(dt[:, 0, :, None] * A)
        h = da * h + (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] \
            * Bc[:, 0].astype(jnp.float32)[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, Cc[:, 0].astype(jnp.float32))
        y = (y + xc[:, 0].astype(jnp.float32) * params["D"])[:, None, :]
        new_state = MambaState(conv=new_conv, h=h)
    else:
        if scan_impl == "pallas":
            from repro.kernels.ops import selective_scan_op
            y, h = selective_scan_op(xc, dt, A, Bc, Cc, params["D"])
        else:
            y, h = selective_scan(xc, dt, A, Bc, Cc, params["D"])
        new_state = None
        if mode == "prefill":
            tail = jnp.concatenate(
                [jnp.zeros((B, K - 1, di), x_in.dtype), x_in], axis=1)[:, S:, :] \
                if S < K - 1 else x_in[:, S - (K - 1):, :]
            new_state = MambaState(conv=tail.astype(jnp.bfloat16), h=h)

    y = (y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ params["out_proj"], new_state


def mamba_state_shape(cfg, batch: int, dtype=jnp.bfloat16) -> MambaState:
    return MambaState(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        h=jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    )
