"""Shared model building blocks: norms, RoPE, init helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with f32 accumulation, output in x.dtype."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def init_rms_scale(d: int) -> jax.Array:
    # stored as zero-centered ("1 + scale" applied in rms_norm, gemma-style)
    return jnp.zeros((d,), jnp.float32)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape (head_dim // 2,), f32."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding, "split halves" convention (Llama/NeoX).

    x: (B, S, H, hd); positions: (1, S) or (B, S) int32.
    """
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (B?, S, hd/2)
    ang = ang[:, :, None, :]  # broadcast over heads
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos_emb(seq: int, d: int, dtype=jnp.float32) -> jax.Array:
    """Classic transformer sinusoidal table (whisper-style), (seq, d)."""
    pos = np.arange(seq)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / (10_000.0 ** (2 * dim / d))
    tab = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(tab, dtype)


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.bfloat16) -> jax.Array:
    """Truncated-normal fan-in init (stddev = 1/sqrt(fan_in))."""
    fan_in = shape[in_axis]
    std = 1.0 / np.sqrt(fan_in)
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.bfloat16) -> jax.Array:
    std = 1.0 / np.sqrt(d)
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, (vocab, d), jnp.float32)).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


def count_params(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))
