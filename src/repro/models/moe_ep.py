"""Expert-parallel MoE with explicit all-to-all dispatch (shard_map).

XLA auto-SPMD cannot shard the sort-based dispatch of ``moe.moe_apply``:
its data-dependent scatters force replication + TB-scale all-reduces
(measured: 18.8 TB/dev for deepseek-v2-lite train_4k when expert weights
are E-sharded under jit). This module implements the Switch/Mixtral
expert-parallel pipeline by hand inside ``jax.shard_map``:

  local router top-k
    -> bucket assignments by owner shard (sort, capacity-bounded)
    -> all_to_all over the "model" axis              (tokens -> experts)
    -> local sort-based expert FFN over E/m experts
    -> all_to_all back                               (experts -> tokens)
    -> local weighted combine

Sharding contract (set by repro.launch.shardings "opt" mode):
  x            P(bax, "model", None)   batch over data axes, seq over model
  w_gate/up/.. P("model", None, None)  EXPERT dim sharded (stationary)
  router       replicated
  shared       replicated

The transpose of all_to_all is all_to_all, so the backward pass produces
the mirrored token return traffic and parameter gradients stay sharded on
the expert dim — no replicated expert weights at any point.

Enabled via ``set_ep_mesh(mesh)`` (None falls back to the dense-jit
``moe_apply``, which is the right choice on 1 device and for smokes).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models import moe as moe_lib
from repro.models.mlp import mlp_apply

_EP: Optional[dict] = None   # {"mesh": Mesh, "axis": str, "bax": tuple}


def set_ep_mesh(mesh, *, axis: str = "model",
                bax: Tuple[str, ...] = ("data", "model")) -> None:
    """Enable expert-parallel dispatch on ``mesh`` (None disables).

    ``bax`` are the axes the BATCH dim of x is sharded over (typically
    all mesh axes, so attention/dense parts stay pure-FSDP and the MoE
    all-to-all runs within model rows); ``axis`` is the expert axis."""
    global _EP
    _EP = None if mesh is None else {"mesh": mesh, "axis": axis,
                                     "bax": tuple(bax)}


def ep_enabled() -> bool:
    return _EP is not None


def _group_by(slot_ids, values, n_slots: int, fill):
    """Scatter values (N, d) into (n_slots+1, d) by slot id (last=trash)."""
    buf = jnp.full((n_slots + 1,) + values.shape[1:], fill, values.dtype)
    return buf.at[slot_ids].set(values)


def _sorted_dispatch(ids, n_buckets: int, capacity: int):
    """ids: (N,) bucket id per element. Returns (order, slot, keep):
    elements sorted by bucket; position within bucket < capacity kept;
    slot = bucket*capacity + pos (trash slot = n_buckets*capacity)."""
    N = ids.shape[0]
    order = jnp.argsort(ids, stable=True)
    sorted_ids = ids[order]
    counts = jnp.zeros((n_buckets,), jnp.int32).at[ids].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(N) - starts[sorted_ids]
    keep = (pos < capacity) & (sorted_ids >= 0) & (sorted_ids < n_buckets)
    slot = jnp.where(keep, sorted_ids * capacity + pos, n_buckets * capacity)
    return order, slot, keep


def _moe_ep_local(params, x, cfg, *, axis: str, all_axes,
                  capacity_factor: float) -> Tuple[jax.Array, jax.Array]:
    """Per-shard body (inside shard_map). x: (B_loc, S_loc, d)."""
    m = lax.axis_size(axis)
    my = lax.axis_index(axis)
    B, S, d = x.shape
    T = B * S
    E, K = cfg.num_experts, cfg.moe_top_k
    E_loc = E // m

    xf = x.reshape(T, d)
    logits = xf.astype(jnp.float32) @ params["router"]          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = lax.top_k(probs, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # global aux load-balance loss (Switch-style), averaged over the mesh
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[gate_idx.reshape(-1)].add(1.0) / (T * K)
    me = lax.pmean(me, all_axes)
    ce = lax.pmean(ce, all_axes)
    aux = E * jnp.sum(me * ce)

    # ---- stage 1: bucket assignments by OWNER shard ----
    flat_e = gate_idx.reshape(T * K)                 # global expert ids
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_g = gate_vals.reshape(T * K)
    owner = flat_e // E_loc
    C1 = max(1, int(math.ceil(T * K / m * capacity_factor)))
    order, slot, keep = _sorted_dispatch(owner, m, C1)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]

    send_x = _group_by(slot, xf[st], m * C1, 0)[:-1].reshape(m, C1, d)
    send_e = jnp.full((m * C1 + 1,), -1, jnp.int32).at[slot].set(se)
    send_e = send_e[:-1].reshape(m, C1)

    # ---- all-to-all: tokens -> expert shards ----
    recv_x = lax.all_to_all(send_x, axis, 0, 0, tiled=False)     # (m, C1, d)
    recv_e = lax.all_to_all(send_e, axis, 0, 0, tiled=False)     # (m, C1)

    # ---- stage 2: local expert FFN over E_loc experts ----
    rx = recv_x.reshape(m * C1, d)
    re = recv_e.reshape(m * C1) - my * E_loc          # local ids; pads < 0
    re = jnp.where((re >= 0) & (re < E_loc), re, -1)
    C2 = max(1, int(math.ceil(m * C1 / E_loc * capacity_factor)))
    order2, slot2, keep2 = _sorted_dispatch(re, E_loc, C2)
    xe = _group_by(slot2, rx[order2], E_loc * C2, 0)[:-1].reshape(E_loc, C2, d)
    ye = moe_lib._expert_ffn(params, xe, cfg.act).reshape(E_loc * C2, d)
    ye = jnp.concatenate([ye, jnp.zeros((1, d), ye.dtype)], axis=0)
    # un-sort back to received order (dropped slots contribute 0)
    out = jnp.zeros((m * C1, d), ye.dtype).at[order2].set(
        ye[slot2] * keep2[:, None].astype(ye.dtype))

    # ---- all-to-all back: expert outputs -> token owners ----
    back = lax.all_to_all(out.reshape(m, C1, d), axis, 0, 0, tiled=False)
    back = jnp.concatenate([back.reshape(m * C1, d),
                            jnp.zeros((1, d), back.dtype)], axis=0)

    # ---- local combine ----
    contrib = back[slot] * (sg * keep.astype(jnp.float32))[:, None].astype(back.dtype)
    y = jnp.zeros((T, d), jnp.float32).at[st].add(contrib.astype(jnp.float32))
    y = y.astype(x.dtype).reshape(B, S, d)

    if cfg.num_shared_experts:
        y = y + mlp_apply(params["shared"], x, cfg.act)
    return y, aux


def moe_apply_ep(params, x, cfg, *, capacity_factor: float = 1.25
                 ) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE via shard_map (requires set_ep_mesh)."""
    ep = _EP
    assert ep is not None
    mesh, axis, bax = ep["mesh"], ep["axis"], ep["bax"]
    all_axes = tuple(mesh.axis_names)
    x_spec = P(bax if len(bax) > 1 else bax[0], None, None)

    def pspec(path, leaf):
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        if name.startswith("w_"):
            return P(axis, None, None)       # expert dim
        return P()                            # router / shared: replicated

    param_specs = jax.tree_util.tree_map_with_path(pspec, params)
    fn = jax.shard_map(
        partial(_moe_ep_local, cfg=cfg, axis=axis, all_axes=all_axes,
                capacity_factor=capacity_factor),
        mesh=mesh,
        in_specs=(param_specs, x_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )
    return fn(params, x)


def moe_dispatch(params, x, cfg) -> Tuple[jax.Array, jax.Array]:
    """EP when enabled and shapes divide; dense-jit fallback otherwise."""
    ep = _EP
    if ep is not None:
        m = ep["mesh"].shape[ep["axis"]]
        bsz = math.prod(ep["mesh"].shape[a] for a in ep["bax"])
        if cfg.num_experts % m == 0 and x.shape[0] % bsz == 0:
            return moe_apply_ep(params, x, cfg)
    return moe_lib.moe_apply(params, x, cfg)
