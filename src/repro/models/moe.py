"""Mixture-of-Experts layer: top-k routing with sort-based capacity dispatch.

Dispatch is sort-based (Mixtral/MegaBlocks style) rather than GShard
one-hot: a (T, E, C) dispatch tensor at assigned scales (T=65k, E=128,
C=5k) would be ~4e13 elements. Sorting T*k assignments keeps memory
O(T*k + E*C*d) and the expert einsum FLOPs equal to *active* FLOPs
(top_k/E of the dense-all-experts cost), which matters for the roofline:
compiled HLO_FLOPs stay proportional to N_active.

Experts are sharded over the "model" mesh axis (expert parallelism); the
scatter/gather across the token<->expert resharding is where the
all-to-all shows up in the dry-run collective parse.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init
from repro.models.mlp import mlp_apply, mlp_init


def moe_init(key, cfg, dtype=jnp.bfloat16):
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    mult = 3 if cfg.act == "swiglu" else 2
    p = {"router": dense_init(ks[0], (d, E), dtype=jnp.float32)}
    if cfg.act == "swiglu":
        p["w_gate"] = dense_init(ks[1], (E, d, f), in_axis=1, dtype=dtype)
        p["w_up"] = dense_init(ks[2], (E, d, f), in_axis=1, dtype=dtype)
        p["w_down"] = dense_init(ks[3], (E, f, d), in_axis=1, dtype=dtype)
    else:
        p["w_in"] = dense_init(ks[1], (E, d, f), in_axis=1, dtype=dtype)
        p["w_out"] = dense_init(ks[2], (E, f, d), in_axis=1, dtype=dtype)
    if cfg.num_shared_experts:
        p["shared"] = mlp_init(ks[4], d, cfg.moe_d_ff * cfg.num_shared_experts,
                               cfg.act, dtype)
    return p


def _expert_ffn(params, xe, act: str):
    """xe: (E, C, d) -> (E, C, d)."""
    if act == "swiglu":
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, params["w_gate"]).astype(jnp.float32))
        u = jnp.einsum("ecd,edf->ecf", xe, params["w_up"]).astype(jnp.float32)
        h = (g * u).astype(xe.dtype)
        return jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, params["w_in"]).astype(jnp.float32),
                    approximate=True).astype(xe.dtype)
    return jnp.einsum("ecf,efd->ecd", h, params["w_out"])


def moe_apply(params, x, cfg, *, capacity_factor: float = 1.25
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,d). Returns (y, aux_loss). Tokens over capacity are dropped
    (their contribution is the shared-expert/residual path only)."""
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.moe_top_k
    T = B * S
    C = max(1, int(math.ceil(T * K / E * capacity_factor)))

    xf = x.reshape(T, d)
    logits = (xf.astype(jnp.float32) @ params["router"])  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)  # (T,K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- aux load-balance loss (Switch-style over all K slots) ----
    me = jnp.mean(probs, axis=0)  # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[gate_idx.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce)

    # ---- sort-based dispatch ----
    flat_e = gate_idx.reshape(T * K)
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_g = gate_vals.reshape(T * K)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    # position within expert = rank - (first rank of that expert)
    counts = jnp.zeros((E,), jnp.int32).at[se].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(T * K) - starts[se]
    keep = pos_in_e < C
    slot = jnp.where(keep, se * C + pos_in_e, E * C)  # E*C = trash slot

    xe = jnp.zeros((E * C + 1, d), x.dtype).at[slot].set(xf[st])
    ye = _expert_ffn(params, xe[:-1].reshape(E, C, d), cfg.act)
    ye = jnp.concatenate([ye.reshape(E * C, d),
                          jnp.zeros((1, d), ye.dtype)], axis=0)
    contrib = ye[slot] * (sg * keep)[:, None].astype(ye.dtype)
    y = jnp.zeros((T, d), jnp.float32).at[st].add(contrib.astype(jnp.float32))
    y = y.astype(x.dtype).reshape(B, S, d)

    if cfg.num_shared_experts:
        y = y + mlp_apply(params["shared"], x, cfg.act)
    return y, aux
