"""Layer kinds, the periodic layer plan, and the generic block.

Heterogeneous stacks (Jamba's 1:7 mamba:attn, Gemma3's 5:1 local:global,
DeepSeek's leading dense layer) are decomposed into
``prefix + period x n + suffix`` so that the periodic part runs under a
single ``lax.scan`` with stacked parameters — keeping the lowered HLO
small for the 512-device dry-run while preserving exact layer order.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import mamba as mamba_lib
from repro.models import moe as moe_lib
from repro.models.common import init_rms_scale, rms_norm
from repro.models.mlp import mlp_apply, mlp_init


@dataclasses.dataclass(frozen=True)
class LayerKind:
    mixer: str                 # "attn" | "mla" | "mamba"
    moe: bool = False
    window: Optional[int] = None   # sliding window (None = global)
    causal: bool = True
    cross: bool = False        # enc-dec decoder cross-attention
    theta: float = 10_000.0


def layer_kind(cfg, i: int, *, decoder: bool = True) -> LayerKind:
    if not cfg.is_attn_layer(i):
        return LayerKind(mixer="mamba", moe=cfg.is_moe_layer(i))
    mixer = "mla" if cfg.use_mla else "attn"
    is_global = cfg.is_global_attn_layer(i)
    window = None if is_global else cfg.sliding_window
    theta = cfg.rope_theta if is_global else cfg.local_rope_theta
    return LayerKind(
        mixer=mixer,
        moe=cfg.is_moe_layer(i),
        window=window,
        causal=cfg.causal if decoder else False,
        cross=(cfg.family == "encdec" and decoder),
        theta=theta,
    )


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    prefix: Tuple[LayerKind, ...]
    period: Tuple[LayerKind, ...]
    n_periods: int
    suffix: Tuple[LayerKind, ...]

    @property
    def num_layers(self) -> int:
        return len(self.prefix) + len(self.period) * self.n_periods + len(self.suffix)

    def all_kinds(self) -> List[LayerKind]:
        return (list(self.prefix) + list(self.period) * self.n_periods
                + list(self.suffix))


def build_plan(cfg, *, decoder: bool = True,
               num_layers: Optional[int] = None) -> LayerPlan:
    L = num_layers if num_layers is not None else cfg.num_layers
    kinds = [layer_kind(cfg, i, decoder=decoder) for i in range(L)]
    best = None
    for pre in range(0, L + 1):
        for p in range(1, L - pre + 1):
            # kinds[pre:] must follow period p
            ok = all(kinds[pre + j] == kinds[pre + (j % p)] for j in range(L - pre))
            if not ok:
                continue
            n = (L - pre) // p
            suf = L - pre - n * p
            cost = pre + p + suf  # unrolled layers in the HLO
            if best is None or cost < best[0]:
                best = (cost, pre, p, n, suf)
    _, pre, p, n, suf = best
    if n <= 1:  # no point scanning a single period; unroll into prefix
        return LayerPlan(tuple(kinds), (), 0, ())
    return LayerPlan(tuple(kinds[:pre]), tuple(kinds[pre:pre + p]), n,
                     tuple(kinds[pre + n * p:]))


# ---------------------------------------------------------------------------
# Generic block
# ---------------------------------------------------------------------------

def block_init(key, cfg, kind: LayerKind, dtype=jnp.bfloat16) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": init_rms_scale(cfg.d_model)}
    if kind.mixer == "attn":
        p["attn"] = attn_lib.gqa_init(ks[0], cfg, dtype)
    elif kind.mixer == "mla":
        p["attn"] = attn_lib.mla_init(ks[0], cfg, dtype)
    else:
        p["mamba"] = mamba_lib.mamba_init(ks[0], cfg, dtype)
    if kind.cross:
        p["norm_cross"] = init_rms_scale(cfg.d_model)
        p["cross"] = attn_lib.gqa_init(ks[1], cfg, dtype)
    if cfg.family == "ssm":
        return p  # pure-mamba block: no separate FFN
    p["norm2"] = init_rms_scale(cfg.d_model)
    if kind.moe:
        p["moe"] = moe_lib.moe_init(ks[2], cfg, dtype)
    else:
        p["mlp"] = mlp_init(ks[3], cfg.d_model, cfg.d_ff, cfg.act, dtype)
    return p


def block_cache_shape(cfg, kind: LayerKind, batch: int, seq_len: int,
                      dtype=jnp.bfloat16):
    """Decode-cache structure for one block (None-free, scan-stackable)."""
    cache: Dict[str, Any] = {}
    if kind.mixer == "attn":
        cache["kv"] = attn_lib.gqa_cache_shape(cfg, batch, seq_len, kind.window, dtype)
    elif kind.mixer == "mla":
        cache["kv"] = attn_lib.mla_cache_shape(cfg, batch, seq_len, dtype)
    else:
        cache["ssm"] = mamba_lib.mamba_state_shape(cfg, batch, dtype)
    if kind.cross:
        cache["cross_kv"] = attn_lib.gqa_cache_shape(cfg, batch, cfg.encoder_seq,
                                                     None, dtype)
    return cache


def _cross_attend(params, x, cache_kv: attn_lib.KVCache, cfg):
    """Decoder cross-attention against cached encoder K/V."""
    B, S, d = x.shape
    Hq, Hk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(B, S, Hq, hd).transpose(0, 2, 1, 3)
    o = attn_lib.flash_attention(q, cache_kv.k, cache_kv.v, causal=False)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, Hq * hd)
    return o @ params["wo"]


def _build_cross_kv(params, enc_out, cfg) -> attn_lib.KVCache:
    B, F, d = enc_out.shape
    Hk, hd = cfg.num_kv_heads, cfg.head_dim
    k = (enc_out @ params["wk"]).reshape(B, F, Hk, hd).transpose(0, 2, 1, 3)
    v = (enc_out @ params["wv"]).reshape(B, F, Hk, hd).transpose(0, 2, 1, 3)
    return attn_lib.KVCache(k=k, v=v, slot_pos=jnp.arange(F, dtype=jnp.int32))


def block_apply(params, x, cfg, kind: LayerKind, *, mode: str = "train",
                cache=None, pos=None, enc_out=None, scan_impl: str = "jnp"):
    """Pre-norm residual block. Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}
    h = rms_norm(x, params["norm1"], cfg.norm_eps)
    if kind.mixer == "attn":
        kv = cache.get("kv") if cache else None
        y, nkv = attn_lib.gqa_apply(params["attn"], h, cfg=cfg, window=kind.window,
                                    theta=kind.theta, cache=kv, pos=pos, mode=mode,
                                    causal=kind.causal)
        if nkv is not None:
            new_cache["kv"] = nkv
    elif kind.mixer == "mla":
        kv = cache.get("kv") if cache else None
        y, nkv = attn_lib.mla_apply(params["attn"], h, cfg=cfg, theta=kind.theta,
                                    cache=kv, pos=pos, mode=mode)
        if nkv is not None:
            new_cache["kv"] = nkv
    else:
        ssm = cache.get("ssm") if cache else None
        y, nssm = mamba_lib.mamba_apply(params["mamba"], h, cfg, state=ssm,
                                        mode=mode, scan_impl=scan_impl)
        if nssm is not None:
            new_cache["ssm"] = nssm
    x = x + y

    if kind.cross:
        hc = rms_norm(x, params["norm_cross"], cfg.norm_eps)
        if mode == "decode":
            ckv = cache["cross_kv"]
        else:
            ckv = _build_cross_kv(params["cross"], enc_out, cfg)
        x = x + _cross_attend(params["cross"], hc, ckv, cfg)
        if mode in ("prefill", "decode"):
            new_cache["cross_kv"] = ckv

    if cfg.family != "ssm":
        h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
        if kind.moe:
            from repro.models import moe_ep
            y2, aux = moe_ep.moe_dispatch(params["moe"], h2, cfg)
        else:
            y2 = mlp_apply(params["mlp"], h2, cfg.act)
        x = x + y2

    if mode == "train":
        return x, None, aux
    return x, new_cache, aux
