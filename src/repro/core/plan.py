"""Schedule IR: compile vertical / horizontal / wave plans once, execute
them everywhere (GreedySnake §3/§4 made first-class).

The paper's contribution is a *schedule* — a total order over parameter
fetches, micro-batch forward/backward work, checkpoint spills, gradient
movement and (α-delayed) optimizer segments. The repo used to encode
that order three times as imperative control flow (the single-rank
vertical and horizontal step bodies, plus a re-derivation inside the
data-parallel engine) while ``repro.core.traffic`` maintained the
matching byte closed-forms by hand. This module makes the schedule a
data structure:

* :class:`PlanOp` / :class:`Op` — one storage-or-compute action at the
  coordinator-call granularity (the op table below).
* :func:`compile_wave` — the ONE schedule compiler. A *wave* runs ``W``
  micro-batches vertically (alternating §4.2 order inside the wave,
  boundary micro-batch kept on device), then the next wave; the f32
  gradient-accumulation buffer is swapped through CPU between waves
  (the horizontal tax). ``W = M`` is GreedySnake's vertical schedule,
  ``W = 1`` the ZeRO-Infinity-style horizontal baseline, and
  ``1 < W < M`` a tunable ckpt-traffic / param-reuse trade-off:
  parameters are (re)loaded ``2·M/W`` times while forward checkpoint
  re-reads and inter-layer gradient round-trips shrink by one
  micro-batch per wave (closed forms:
  :func:`repro.core.traffic.wave_ckpt_traffic`).
* :func:`compile_vertical` / :func:`compile_horizontal` — the two paper
  schedules as wave specializations (``W=M`` / ``W=1``).
* :func:`insert_prefetch` — THE unified cross-stream lookahead pass:
  one hint per fetch-class op, for every stream that can touch the SSD
  (``PREFETCH`` for param fetches / all-gathers, ``PREFETCH_CKPT`` for
  backward checkpoint-tail re-reads, ``PREFETCH_ACT`` for the
  activation stream, ``PREFETCH_OPT`` for the α-tail optimizer state
  reads). Hints are placed ``depth`` same-stream fetches ahead (or at
  the segment anchor), never across a ``RESET_PARAMS`` — cancelled
  prefetches would otherwise change measured traffic. Hints move
  *when* bytes flow, never *how many*: a plan with hints predicts (and
  measures) byte-for-byte the same traffic as the same plan without.
* :func:`plan_traffic` — a static analyzer: an abstract interpreter
  over the op stream (tracking device-kept slots and CPU-cached
  checkpoint tails, §4.2 eviction included) that predicts every
  ``(category, route)`` byte counter of the real engines EXACTLY —
  the third leg of the plan / closed-form / measured-counter
  cross-check in the test battery.

Op table (executor semantics live in ``repro.offload.executor``):

====================  =====================================================
op                    meaning (bytes it moves)
====================  =====================================================
PHASE(tag)            wall-clock phase marker (fwd / bwd / opt_wait)
OPT_LATE(l)           flush layer l's α-tail optimizer segment and gate
                      l's NEXT param fetch on it (opt state r/w for the
                      [k_early, P) segment). Emitted in the plan
                      EPILOGUE: the flush of iteration i's tail is
                      submitted at the end of iteration i, so it is in
                      flight together with iteration i+1's first param
                      fetches — the §4.4 optimizer/forward overlap as a
                      plan-level seam rather than executor ordering
PREFETCH(l)           hint: start layer l's param fetch now (maps to
                      IOPriority.PARAM_FETCH; bytes accounted at FETCH)
PREFETCH_OPT(l)       hint: start the α-tail optimizer-state reads of
                      layer l now (tag="late"; bytes accounted at the
                      OPT_LATE flush that consumes them)
PREFETCH_CKPT(l, m)   hint: start the backward checkpoint tail's SSD
                      re-read now (bytes accounted at FETCH_CKPT_BWD)
FETCH_PARAM(l)        await layer l's params on device
                      (param ssd->cpu tail + cpu->gpu full)
ALLGATHER(l)          DP: all ranks' shard fetches + ring all-gather
                      (per rank: shard ssd->cpu/cpu->gpu + (R-1)/R ring)
RELEASE_PARAM(l)      drop the device param slot
RESET_PARAMS          schedule boundary: cancel outstanding prefetches
EMBED_FWD(m)          token embedding for micro-batch m (device only)
SPILL_CKPT(l, m)      offload boundary-l ckpt of m (gpu->cpu + ssd tail;
                      ``keep`` pins the §4.2 boundary copy on device)
FETCH_CKPT(l, m)      next-layer forward input (device-kept: free;
                      else cpu->gpu, consuming the CPU tail cache)
FETCH_CKPT_BWD(l, m)  backward recompute input (cpu->gpu + ssd tail
                      re-read unless the tail is still CPU-cached or
                      already prefetched by a PREFETCH_CKPT hint)
FWD(l, m)             layer forward (compute only; under the spill
                      policy it also materialises the vjp residuals)
SPILL_ACT(l, m)       spill policy: stream layer l's vjp residuals for
                      micro-batch m out (act gpu->cpu + ssd tail at the
                      opportunistic IOPriority.ACT; the CPU tail copy
                      is dropped once the spill lands)
PREFETCH_ACT(l, m)    hint: start the residual tail's SSD read now
                      (bytes accounted at FETCH_ACT)
FETCH_ACT(l, m)       await the residuals on device ahead of BWD
                      (act ssd->cpu tail + cpu->gpu full); replaces
                      FETCH_CKPT_BWD — backward applies the saved vjp
                      instead of recomputing from the checkpoint
HEAD_BWD(m)           loss + head backward for m (compute only)
BWD(l, m)             layer backward; ``acc`` accumulates dW into the
                      layer gradient register (else stashed for DP)
SPILL_GRAD(l, m)      inter-layer activation grad to CPU (``keep``
                      pins it; kept grads never touch CPU — the saving)
FETCH_GRAD(l, m)      inter-layer grad back to device (kept: free)
DROP_CKPT(l, m)       release boundary-l ckpt of m (CPU + pending spill)
GRAD_INIT(l)          zero the layer-gradient register
GRAD_SPILL(l)         wave boundary: park the partial f32 layer gradient
                      in CPU (grad gpu->cpu)
GRAD_FETCH_ACC(l)     wave boundary: fetch + add the parked partial sum
                      (grad cpu->gpu)
WRITEBACK_GRAD(l)     hand the accumulated f32 layer gradient to the
                      optimizer coordinator: grad gpu->cpu + the early
                      (1-α) optimizer segment's state r/w + low-precision
                      param write-back
REDUCE_SCATTER(l)     DP: ordered fold of the stashed per-micro-batch
                      gradients (global §4.2 order), ring cost, then each
                      rank's shard WRITEBACK
EMBED_BWD(m)          embedding backward for m (compute only)
FOLD_HEAD(ms)         DP: fold stashed head grads/losses in global order
FOLD_EMBED(ms)        DP: fold stashed embedding grads in global order
ALLREDUCE_HEAD        DP: ring all-reduce cost of the replicated head
HEAD_ADAM             device Adam on embedding / unembed / final norm
WAIT_OPT              α=0: drain the overlapped optimizer requests
BARRIER               jax.effects_barrier() at the fwd/bwd boundary
PREFETCH_KV(l, m)     hint: start request m's unit-l KV tail SSD read now
                      (maps to IOPriority.KV; bytes accounted at FETCH_KV)
FETCH_KV(l, m)        serving: await request m's unit-l KV blocks on
                      device (kv ssd->cpu cold blocks + cpu->gpu all,
                      block-padded)
SPILL_KV(l, m)        serving: evict request m's unit-l KV blocks to the
                      warm/cold tiers (kv gpu->cpu all + cpu->ssd cold
                      blocks, block-padded); also the eviction barrier
                      KV hints never cross
APPEND_KV(l, m)       serving: record the tokens request m appended to
                      its unit-l device-resident block table (HBM write
                      — moves no offload bytes; occupancy accounting)
====================  =====================================================

Serving plans (``repro.serve``) are compiled per engine step directly
into this IR with ``schedule="serve"``: per-unit ``FETCH_PARAM`` ops
(the same lookahead pass places their ``PREFETCH`` hints), the KV ops
above, and ``PHASE`` markers tagged ``prefill``/``decode`` carrying the
request id in ``m`` for the compute. :func:`plan_traffic` prices them
through the same abstract interpreter (see the ``kv_*`` /
``param_unit_nbytes`` fields of :class:`PlanCosts`).

Plans are compiled ONCE per engine (the schedule depends only on
(L, M, W, R, α) and the micro-batch order function) and executed every
step; step-dependent behavior (the α gate's "step > 1" guard) is the
executor's, not the plan's.
"""
from __future__ import annotations

import dataclasses
import enum
from collections import defaultdict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.perfmodel import StorageRatios


# ---------------------------------------------------------------------------
# canonical micro-batch order + rank/wave sharding helpers
# ---------------------------------------------------------------------------

def mb_order(M: int, l: int) -> List[int]:
    """THE §4.2 alternating micro-batch order for layer ``l`` — the one
    canonical implementation (the engines and every plan compiler import
    it from here). Every producer emits a boundary's tensors in the
    REVERSE of its consumer's order and keeps the last-produced one on
    device, so the consumer's FIRST access hits the device slot and
    frees it immediately."""
    return list(range(M)) if l % 2 == 0 else list(range(M - 1, -1, -1))


def shard_bounds(n: int, world: int) -> List[Tuple[int, int]]:
    """Contiguous 1/R element ranges covering [0, n) (sizes differ by at
    most one when R does not divide n)."""
    cuts = [(n * r) // world for r in range(world + 1)]
    return [(cuts[r], cuts[r + 1]) for r in range(world)]


# ---------------------------------------------------------------------------
# the IR
# ---------------------------------------------------------------------------

class Op(enum.Enum):
    PHASE = "phase"
    OPT_LATE = "opt_late"
    PREFETCH = "prefetch"
    PREFETCH_OPT = "prefetch_opt"
    PREFETCH_CKPT = "prefetch_ckpt"
    FETCH_PARAM = "fetch_param"
    ALLGATHER = "allgather"
    RELEASE_PARAM = "release_param"
    RESET_PARAMS = "reset_params"
    EMBED_FWD = "embed_fwd"
    SPILL_CKPT = "spill_ckpt"
    FETCH_CKPT = "fetch_ckpt"
    FETCH_CKPT_BWD = "fetch_ckpt_bwd"
    FWD = "fwd"
    SPILL_ACT = "spill_act"
    PREFETCH_ACT = "prefetch_act"
    FETCH_ACT = "fetch_act"
    HEAD_BWD = "head_bwd"
    BWD = "bwd"
    SPILL_GRAD = "spill_grad"
    FETCH_GRAD = "fetch_grad"
    DROP_CKPT = "drop_ckpt"
    GRAD_INIT = "grad_init"
    GRAD_SPILL = "grad_spill"
    GRAD_FETCH_ACC = "grad_fetch_acc"
    WRITEBACK_GRAD = "writeback_grad"
    REDUCE_SCATTER = "reduce_scatter"
    EMBED_BWD = "embed_bwd"
    FOLD_HEAD = "fold_head"
    FOLD_EMBED = "fold_embed"
    ALLREDUCE_HEAD = "allreduce_head"
    HEAD_ADAM = "head_adam"
    WAIT_OPT = "wait_opt"
    BARRIER = "barrier"
    PREFETCH_KV = "prefetch_kv"
    FETCH_KV = "fetch_kv"
    SPILL_KV = "spill_kv"
    APPEND_KV = "append_kv"


@dataclasses.dataclass(frozen=True)
class PlanOp:
    op: Op
    l: int = -1                 # layer / boundary index
    m: int = -1                 # micro-batch index
    keep: bool = False          # §4.2 keep-on-device flag
    acc: bool = False           # accumulate eagerly (single-rank fold)
    ms: Tuple[int, ...] = ()    # fold order for FOLD_* / REDUCE_SCATTER
    tag: str = ""               # PHASE name

    def __repr__(self):  # compact: FWD(l=2, m=1)
        parts = []
        if self.l >= 0:
            parts.append(f"l={self.l}")
        if self.m >= 0:
            parts.append(f"m={self.m}")
        if self.keep:
            parts.append("keep")
        if self.tag:
            parts.append(self.tag)
        return f"{self.op.name}({', '.join(parts)})"


@dataclasses.dataclass(frozen=True)
class PlanSpec:
    """The schedule-shaping knobs a compiler needs."""
    L: int                      # pipelined transformer layers
    M: int                      # micro-batches per iteration
    alpha: float = 0.0          # §4.4 delayed-optimizer ratio
    ranks: int = 1              # data-parallel ranks (vertical only)
    act_spill: bool = False     # SSDTrain-style activation streaming:
                                # SPILL_ACT/FETCH_ACT replace backward
                                # recompute (resolved policy — "auto"
                                # is decided before compilation)


@dataclasses.dataclass(frozen=True)
class Plan:
    schedule: str               # "vertical" | "horizontal" | "wave"
    spec: PlanSpec
    W: int                      # micro-batches per wave
    ops: Tuple[PlanOp, ...]

    @property
    def num_waves(self) -> int:
        return self.spec.M // self.W

    def count(self, kind: Op) -> int:
        return sum(1 for o in self.ops if o.op is kind)

    def __len__(self) -> int:
        return len(self.ops)


OrderFn = Callable[[int], List[int]]


# ---------------------------------------------------------------------------
# compilers
# ---------------------------------------------------------------------------

def _restrict(order: Sequence[int], lo: int, hi: int) -> List[int]:
    """A block's consumption order = the global order restricted to the
    block (keeps the per-block §4.2 alternation, so each block's boundary
    micro-batch stays on device)."""
    return [m for m in order if lo <= m < hi]


def compile_wave(spec: PlanSpec, W: int,
                 order: Optional[OrderFn] = None,
                 opt_epilogue: bool = True) -> Plan:
    """Compile the W-micro-batches-per-wave schedule for ``spec``.

    ``order(l)`` must return the global micro-batch order of layer l
    (default: the canonical :func:`mb_order`); compilers consume blocks
    of it, so a perturbed order compiles to a plan whose executor pays
    the §4.2 eviction penalty — and :func:`plan_traffic` predicts it.

    ``opt_epilogue`` places the α-tail ``OPT_LATE`` flushes: ``True``
    (the cross-iteration seam, default) emits them in the plan
    EPILOGUE — iteration i's tail is submitted at the end of iteration
    i and overlaps iteration i+1's first fetches; ``False`` emits them
    in the PROLOGUE (tag ``"pro"``) — the pre-lookahead executor
    ordering, where the flush of the previous step's tail serializes
    against this step's empty pipeline. Both orderings flush the same
    (gradient, Adam-step) pairs, so results are bitwise-identical; the
    prologue variant exists as the lookahead-off baseline.
    """
    L, M, R, alpha = spec.L, spec.M, spec.ranks, spec.alpha
    if W < 1 or M % W:
        raise ValueError(f"wave size W={W} must divide M={M}")
    if R > 1:
        if W != M:
            raise ValueError("data-parallel plans are vertical (W == M)")
        if M % R:
            raise ValueError(f"M={M} must divide across R={R} ranks")
    if order is None:
        order = lambda l: mb_order(M, l)  # noqa: E731
    nw = M // W
    dp = R > 1
    Mr = M // R

    ops: List[PlanOp] = []
    emit = ops.append

    def groups(l: int, w: int) -> List[List[int]]:
        """Emission groups at layer l for wave w: the wave's block, or
        (DP: single wave) one rank-major group per rank — each group
        keeps ITS boundary micro-batch on device."""
        if dp:
            return [_restrict(order(l), r * Mr, (r + 1) * Mr)
                    for r in range(R)]
        return [_restrict(order(l), w * W, (w + 1) * W)]

    emit(PlanOp(Op.PHASE, tag="fwd"))
    if alpha > 0 and not opt_epilogue:
        for l in range(L):
            emit(PlanOp(Op.OPT_LATE, l=l, tag="pro"))

    for w in range(nw):
        if w > 0:
            emit(PlanOp(Op.PHASE, tag="fwd"))
        # ---- forward ----
        # The embedding produces boundary 0 in the REVERSE of layer 0's
        # consumption order so the kept micro-batch is consumed first.
        for grp in groups(0, w):
            for m in reversed(grp):
                emit(PlanOp(Op.EMBED_FWD, m=m))
                emit(PlanOp(Op.SPILL_CKPT, l=0, m=m, keep=(m == grp[0])))
        for l in range(L):
            emit(PlanOp(Op.ALLGATHER if dp else Op.FETCH_PARAM, l=l))
            for grp in groups(l, w):
                for m in grp:
                    emit(PlanOp(Op.FETCH_CKPT, l=l, m=m))
                    emit(PlanOp(Op.FWD, l=l, m=m))
                    if spec.act_spill:
                        emit(PlanOp(Op.SPILL_ACT, l=l, m=m))
                    emit(PlanOp(Op.SPILL_CKPT, l=l + 1, m=m,
                                keep=(m == grp[-1])))
            emit(PlanOp(Op.RELEASE_PARAM, l=l))
        emit(PlanOp(Op.BARRIER))

        # ---- backward ----
        emit(PlanOp(Op.PHASE, tag="bwd"))
        for grp in groups(L, w):
            for m in grp:
                emit(PlanOp(Op.FETCH_CKPT, l=L, m=m))
                emit(PlanOp(Op.HEAD_BWD, m=m, acc=not dp))
                emit(PlanOp(Op.SPILL_GRAD, l=L, m=m, keep=(m == grp[-1])))
                emit(PlanOp(Op.DROP_CKPT, l=L, m=m))
        if dp:
            emit(PlanOp(Op.FOLD_HEAD, ms=tuple(order(L))))
        emit(PlanOp(Op.RESET_PARAMS))
        for l in range(L - 1, -1, -1):
            emit(PlanOp(Op.ALLGATHER if dp else Op.FETCH_PARAM, l=l))
            if not dp:
                emit(PlanOp(Op.GRAD_INIT, l=l))
            for grp in groups(l, w):
                for m in grp:
                    # spill policy: backward consumes the streamed vjp
                    # residuals; recompute re-reads the checkpoint
                    emit(PlanOp(Op.FETCH_ACT if spec.act_spill
                                else Op.FETCH_CKPT_BWD, l=l, m=m))
                    emit(PlanOp(Op.FETCH_GRAD, l=l + 1, m=m))
                    emit(PlanOp(Op.BWD, l=l, m=m, acc=not dp))
                    emit(PlanOp(Op.SPILL_GRAD, l=l, m=m, keep=(m == grp[-1])))
                    emit(PlanOp(Op.DROP_CKPT, l=l, m=m))
            if dp:
                emit(PlanOp(Op.REDUCE_SCATTER, l=l, ms=tuple(order(l))))
            elif nw == 1:
                emit(PlanOp(Op.WRITEBACK_GRAD, l=l))
            else:
                # cross-wave f32 accumulation buffer swap (the
                # horizontal tax): first wave parks, middle waves
                # fetch+add+park, the last wave fetches and writes back
                # => (2·nw - 1) buffer movements per layer.
                if w > 0:
                    emit(PlanOp(Op.GRAD_FETCH_ACC, l=l))
                if w < nw - 1:
                    emit(PlanOp(Op.GRAD_SPILL, l=l))
                else:
                    emit(PlanOp(Op.WRITEBACK_GRAD, l=l))
            emit(PlanOp(Op.RELEASE_PARAM, l=l))
        # embedding backward: layer 0 produced grad(0) in order(0), so
        # consume in reverse — the kept micro-batch comes first.
        for grp in groups(0, w):
            for m in reversed(grp):
                emit(PlanOp(Op.FETCH_GRAD, l=0, m=m))
                emit(PlanOp(Op.EMBED_BWD, m=m, acc=not dp))

    if dp:
        emit(PlanOp(Op.FOLD_EMBED, ms=tuple(reversed(order(0)))))
        emit(PlanOp(Op.ALLREDUCE_HEAD))
    emit(PlanOp(Op.PHASE, tag="opt_wait"))
    # The cross-iteration seam (§4.4 realized at plan level): THIS
    # iteration's α-tail optimizer segments are flushed in the EPILOGUE
    # — each OPT_LATE(l) submits the tail update and re-arms layer l's
    # fetch gate — so by the time the next interpretation of this same
    # plan issues its first PREFETCH/FETCH_PARAM ops, the tail flushes
    # (and, via PREFETCH_OPT hints, their state reads) are already in
    # flight: iteration i's optimizer tail overlaps iteration i+1's
    # layer-0/1 parameter fetches. The gate (not plan order) is what
    # keeps a fetch from reading a half-updated parameter vector.
    if alpha > 0 and opt_epilogue:
        for l in range(L):
            emit(PlanOp(Op.OPT_LATE, l=l))
    emit(PlanOp(Op.HEAD_ADAM))
    if alpha == 0:
        emit(PlanOp(Op.WAIT_OPT))

    name = "vertical" if W == M else ("horizontal" if W == 1 else "wave")
    return Plan(schedule=name, spec=spec, W=W, ops=tuple(ops))


def compile_vertical(spec: PlanSpec,
                     order: Optional[OrderFn] = None,
                     opt_epilogue: bool = True) -> Plan:
    """GreedySnake's vertical schedule: one wave of all M micro-batches
    (§3.4: params loaded twice per ITERATION, grads accumulated on
    device and moved once)."""
    return compile_wave(spec, spec.M, order=order,
                        opt_epilogue=opt_epilogue)


def compile_horizontal(spec: PlanSpec,
                       order: Optional[OrderFn] = None,
                       opt_epilogue: bool = True) -> Plan:
    """ZeRO-Infinity-style baseline: waves of one micro-batch (params
    loaded twice per MICRO-BATCH, the f32 grad buffer swapped through
    CPU (2M-1) times)."""
    return compile_wave(spec, 1, order=order, opt_epilogue=opt_epilogue)


# ---------------------------------------------------------------------------
# the unified cross-stream lookahead pass
# ---------------------------------------------------------------------------

_FETCH_KINDS = (Op.FETCH_PARAM, Op.ALLGATHER)

#: fetch-class op -> the hint op the lookahead pass derives for it.
#: FETCH_CKPT and FETCH_GRAD are absent on purpose: their payloads are
#: provably device-kept or CPU-resident (the forward consumes the ckpt
#: CPU cache, inter-layer gradients never touch SSD), so there is
#: nothing to look ahead for.
HINT_FOR_FETCH: Dict[Op, Op] = {
    Op.FETCH_PARAM: Op.PREFETCH,
    Op.ALLGATHER: Op.PREFETCH,
    Op.FETCH_CKPT_BWD: Op.PREFETCH_CKPT,
    Op.FETCH_ACT: Op.PREFETCH_ACT,
    Op.OPT_LATE: Op.PREFETCH_OPT,
    Op.FETCH_KV: Op.PREFETCH_KV,
}

#: every hint op kind (executor: submit the fetch early; moves no bytes)
HINT_KINDS = (Op.PREFETCH, Op.PREFETCH_OPT, Op.PREFETCH_CKPT,
              Op.PREFETCH_ACT, Op.PREFETCH_KV)


def _hint_pass(ops: List[PlanOp], fetch_kinds, hint_kind: Op,
               depth: int, barrier_kinds=(None,)) -> List[PlanOp]:
    """One stream's lookahead pass: every op whose kind is in
    ``fetch_kinds`` gets exactly one ``hint_kind`` hint, placed right
    after the ``depth``-th previous same-stream fetch in the same
    schedule segment (``depth=1`` is the classic two-stage §4.2
    pipeline; larger depths hint further ahead), or after the segment
    anchor — plan start (or, in a prologue-ordered plan, after the
    leading ``OPT_LATE`` prefix: a hint before the α gates are armed
    would fetch parameters the late optimizer segment is still
    writing), or the segment's ``RESET_PARAMS``. Hints never cross a
    ``RESET_PARAMS`` — nor any extra ``barrier_kinds`` the stream
    declares (the KV stream's ``SPILL_KV`` evictions: a hint hoisted
    above an eviction would fetch blocks the eviction is still
    writing)."""
    lead = -1
    for i, op in enumerate(ops):
        if op.op is Op.PHASE:
            continue
        if op.op is Op.OPT_LATE:
            lead = i
            continue
        break
    inserts: Dict[int, List[PlanOp]] = defaultdict(list)
    anchor = lead
    recent: List[int] = []           # last <= depth same-stream fetches
    for i, op in enumerate(ops):
        if op.op is Op.RESET_PARAMS or op.op in barrier_kinds:
            anchor = i
            recent = []
        elif op.op in fetch_kinds:
            pos = recent[0] if len(recent) == depth else anchor
            inserts[pos].append(PlanOp(hint_kind, l=op.l, m=op.m,
                                       tag=op.tag))
            recent.append(i)
            if len(recent) > depth:
                recent.pop(0)
    out: List[PlanOp] = list(inserts.get(-1, []))
    for i, op in enumerate(ops):
        out.append(op)
        out.extend(inserts.get(i, []))
    return out


def _opt_hint_pass(ops: List[PlanOp]) -> List[PlanOp]:
    """PREFETCH_OPT hints for the epilogue ``OPT_LATE`` flushes: layer
    l's α-tail state reads start right after its ``WRITEBACK_GRAD`` /
    ``REDUCE_SCATTER`` (the op that retires layer l in backward), so
    they overlap the remaining backward compute. The [k_early, P) tail
    is stable from the previous flush (gate-ordered before this
    iteration's forward fetch) until this epilogue's flush consumes the
    prefetch, and the early segment's concurrent [0, k_early) writes
    are range-disjoint — so the hint is value-safe anywhere after the
    previous fetch of layer l; this placement maximises overlap."""
    idx_late = {op.l: i for i, op in enumerate(ops)
                if op.op is Op.OPT_LATE}
    if not idx_late:
        return ops
    inserts: Dict[int, List[PlanOp]] = defaultdict(list)
    before: set = set()
    for l, li in idx_late.items():
        wb = next((i for i, op in enumerate(ops)
                   if op.op in (Op.WRITEBACK_GRAD, Op.REDUCE_SCATTER)
                   and op.l == l and i < li), None)
        if wb is not None:
            inserts[wb].append(PlanOp(Op.PREFETCH_OPT, l=l, tag="late"))
        else:
            # no retiring op ahead of the flush (prologue-ordered
            # plans): hint just before the flush so the 1:1 pairing
            # holds — the prefetch reads the exact pre-flush state the
            # flush consumes
            before.add(li)
    out: List[PlanOp] = []
    for i, op in enumerate(ops):
        if i in before:
            out.append(PlanOp(Op.PREFETCH_OPT, l=op.l, tag="late"))
        out.append(op)
        out.extend(inserts.get(i, []))
    return out


def insert_prefetch(plan: Plan, depth: int = 1) -> Plan:
    """THE unified cross-stream lookahead pass: derive exactly one hint
    per fetch-class op, for every stream that can touch the SSD —

    * ``PREFETCH`` per ``FETCH_PARAM``/``ALLGATHER``, placed ``depth``
      param fetches ahead (``depth=1``: right after the previous fetch
      — the two-stage §4.2 pipeline: layer l on device while l+1
      streams in; a segment's first fetches anchor at plan start or
      the segment's ``RESET_PARAMS``);
    * ``PREFETCH_CKPT`` per ``FETCH_CKPT_BWD`` (recompute plans): the
      checkpoint tail's SSD re-read streams in while the previous
      micro-batch's backward runs, instead of blocking the executor;
    * ``PREFETCH_ACT`` per ``FETCH_ACT`` (spill plans), at the
      opportunistic ``IOPriority.ACT``;
    * ``PREFETCH_OPT`` per epilogue ``OPT_LATE``: the α-tail optimizer
      state reads start as soon as the layer retires in backward
      (see :func:`_opt_hint_pass` for the value-safety argument).

    ``depth=0`` disables the pass entirely (the plan is returned
    unchanged — every fetch degrades to a synchronous gate-ordered
    read, which is the "lookahead off" baseline the byte-parity and
    bitwise batteries compare against).

    Hints never cross a ``RESET_PARAMS``: the reset cancels queued
    prefetches, but one already running would have moved (and metered)
    bytes a hint-free plan never moved. For the same reason hints move
    *when* bytes flow, never *how many*: ``plan_traffic`` of a hinted
    plan equals ``plan_traffic`` of the bare plan exactly, and the
    executor may legally SKIP any hint (backpressure-adaptive
    throttling) without changing a single byte counter.
    """
    if depth < 0:
        raise ValueError(f"prefetch depth must be >= 0, got {depth}")
    if depth == 0:
        return plan
    ops = _hint_pass(list(plan.ops), _FETCH_KINDS, Op.PREFETCH, depth)
    if plan.spec.act_spill:
        ops = _hint_pass(ops, (Op.FETCH_ACT,), Op.PREFETCH_ACT, depth)
    else:
        ops = _hint_pass(ops, (Op.FETCH_CKPT_BWD,), Op.PREFETCH_CKPT,
                         depth)
    if any(o.op is Op.FETCH_KV for o in ops):
        # the KV stream (serving plans): one PREFETCH_KV per FETCH_KV,
        # never hoisted across a SPILL_KV — an eviction is the barrier
        # that makes the tiers the source of truth for those blocks
        ops = _hint_pass(ops, (Op.FETCH_KV,), Op.PREFETCH_KV, depth,
                         barrier_kinds=(Op.SPILL_KV,))
    ops = _opt_hint_pass(ops)
    return dataclasses.replace(plan, ops=tuple(ops))


# ---------------------------------------------------------------------------
# static traffic analyzer
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlanCosts:
    """The byte-sizing facts :func:`plan_traffic` needs (everything else
    is in the plan)."""
    P: int                      # per-layer flat param elements
    param_itemsize: int         # low-precision param bytes per element
    ckpt_elems: int             # one boundary tensor: mb * seq * d_model
    act_itemsize: int           # activation / inter-grad bytes per element
    ratios: StorageRatios = dataclasses.field(default_factory=StorageRatios)
    alpha: float = 0.0
    ranks: int = 1
    head_nbytes: int = 0        # f32 embed+unembed+norm grads (DP ring)
    act_res_bytes: int = 0      # one (layer, micro-batch) vjp-residual
                                # payload — what SPILL_ACT/FETCH_ACT move
                                # (engines size it via jax.eval_shape)
    # ---- serving (schedule="serve" plans; repro.serve) ----
    kv_block_bytes: int = 0     # fixed KV block size (0 = no KV stream)
    kv_x_host: float = 0.0      # fraction of evicted KV blocks kept
                                # host-warm (rest go cold to SSD)
    kv_unit_nbytes: Tuple[int, ...] = ()    # per cache-unit KV payload
                                # bytes for ONE request (index = the
                                # FETCH_KV/SPILL_KV op's ``l``); block
                                # padding is applied by the analyzer
    param_unit_nbytes: Tuple[int, ...] = ()  # serve per-unit param blob
                                # bytes — when non-empty, FETCH_PARAM(l)
                                # is priced per unit instead of by ``P``
    param_x_host: float = 0.0   # serve param tier split (byte fraction
                                # host-resident, TieredVector rounding)

    @staticmethod
    def from_engine(eng) -> "PlanCosts":
        """Sizing facts read off a live (single-rank or DP) engine."""
        ocfg = eng.ocfg
        item = eng.dtype.itemsize
        head_nbytes = 4 * (eng.embed.size + eng.unembed.size
                           + eng.final_norm.size)
        return PlanCosts(
            P=eng.P, param_itemsize=item,
            ckpt_elems=ocfg.micro_batch * ocfg.seq_len * eng.cfg.d_model,
            act_itemsize=item, ratios=ocfg.ratios, alpha=ocfg.alpha,
            ranks=getattr(eng, "R", 1), head_nbytes=head_nbytes,
            act_res_bytes=getattr(eng, "act_nbytes", 0))


def _khost(x: float, n: int) -> int:
    """TieredVector's CPU-resident element count (same rounding)."""
    return int(round(x * n))


def _seg_ssd(n: int, x_host: float, lo: int, hi: int) -> int:
    """SSD-touching elements of a [lo, hi) segment read/write of an
    n-element tiered vector (mirrors TieredVector.read_range/write_seg)."""
    return max(0, hi - max(lo, _khost(x_host, n)))


def plan_traffic(plan: Plan, costs: PlanCosts):
    """Predicted per-iteration ``(category, route) -> bytes`` counters,
    computed directly from the IR by abstract interpretation.

    The analyzer tracks exactly the state the coordinators do —
    device-kept checkpoint/gradient slots and CPU-cached checkpoint
    tails — including the §4.2 eviction discipline, so a plan compiled
    from a PERTURBED micro-batch order predicts the eviction penalty
    too. α-delayed optimizer segments are counted at the epilogue
    ``OPT_LATE`` ops (each iteration flushes its own tail at plan end),
    which is what an engine run followed by ``finish()`` measures.
    ``PREFETCH*`` hint ops move no bytes — a hinted plan's prediction
    equals the bare plan's exactly (hints change *when* bytes flow,
    never *how many*).

    Returns one dict for single-rank plans, a per-rank list for DP.
    """
    R = plan.spec.ranks
    x = costs.ratios
    E = costs.ckpt_elems
    a = costs.act_itemsize
    u = E * a                                   # one boundary tensor
    ps = costs.param_itemsize
    P = costs.P
    kc = _khost(x.ckpt, E)
    Mr = plan.spec.M // R
    bounds = shard_bounds(P, R)
    out = [defaultdict(int) for _ in range(R)]

    def owner(m: int) -> int:
        return m // Mr if R > 1 else 0

    def add(r: int, cat: str, route: str, n: int):
        if n:
            out[r][(cat, route)] += int(n)

    def opt_segment(r: int, n: int, lo: int, hi: int):
        """Early/late optimizer segment [lo, hi) of an n-element shard:
        master+m+v f32 reads and writes, low-precision param writeback."""
        o = _seg_ssd(n, x.opt, lo, hi) * 4
        add(r, "opt", "ssd->cpu", 3 * o)
        add(r, "opt", "cpu->ssd", 3 * o)
        add(r, "param", "cpu->ssd", _seg_ssd(n, x.param, lo, hi) * ps)

    kept: set = set()            # device-kept ckpt (l, m)
    kept_grad: set = set()       # device-kept inter-layer grad (l, m)
    tail_cached: set = set()     # ckpt tail still in CPU cache (l, m)

    for op in plan.ops:
        k = op.op
        if k is Op.FETCH_PARAM:
            if costs.param_unit_nbytes:
                # serving: per-unit param blob, tiered by byte fraction
                nb = costs.param_unit_nbytes[op.l]
                add(0, "param", "ssd->cpu",
                    nb - _khost(costs.param_x_host, nb))
                add(0, "param", "cpu->gpu", nb)
                continue
            add(0, "param", "ssd->cpu", (P - _khost(x.param, P)) * ps)
            add(0, "param", "cpu->gpu", P * ps)
        elif k is Op.ALLGATHER:
            for r, (lo, hi) in enumerate(bounds):
                n_r = hi - lo
                add(r, "param", "ssd->cpu",
                    (n_r - _khost(x.param, n_r)) * ps)
                add(r, "param", "cpu->gpu", n_r * ps)
                add(r, "param", "gpu->net", (R - 1) * n_r * ps)
                add(r, "param", "net->gpu", (P - n_r) * ps)
        elif k is Op.SPILL_CKPT:
            r = owner(op.m)
            add(r, "ckpt", "gpu->cpu", u)
            tail_cached.add((op.l, op.m))
            if kc < E:
                add(r, "ckpt", "cpu->ssd", (E - kc) * a)
            if op.keep:
                kept.add((op.l, op.m))
        elif k is Op.FETCH_CKPT:
            r = owner(op.m)
            if (op.l, op.m) in kept:
                kept.discard((op.l, op.m))
            else:
                # §4.2 eviction: an out-of-order consumer costs this
                # rank's kept boundary slot (its CPU cache already
                # exists, so eviction itself moves no bytes)
                for key in [key for key in kept
                            if key[0] == op.l and owner(key[1]) == r]:
                    kept.discard(key)
                add(r, "ckpt", "cpu->gpu", u)
                tail_cached.discard((op.l, op.m))
        elif k is Op.FETCH_CKPT_BWD:
            r = owner(op.m)
            kept.discard((op.l, op.m))
            if kc < E and (op.l, op.m) not in tail_cached:
                add(r, "ckpt", "ssd->cpu", (E - kc) * a)
            add(r, "ckpt", "cpu->gpu", u)
        elif k is Op.SPILL_ACT:
            r = owner(op.m)
            A = costs.act_res_bytes
            add(r, "act", "gpu->cpu", A)
            ka = _khost(x.act, A)            # coordinator rounding (bytes)
            if ka < A:
                add(r, "act", "cpu->ssd", A - ka)
        elif k is Op.FETCH_ACT:
            r = owner(op.m)
            A = costs.act_res_bytes
            ka = _khost(x.act, A)
            if ka < A:
                # unlike ckpt tails, the CPU copy is dropped as soon as
                # the spill lands (reclaiming DRAM is the point), so
                # every fetch re-reads the tail from SSD
                add(r, "act", "ssd->cpu", A - ka)
            add(r, "act", "cpu->gpu", A)
        elif k is Op.SPILL_GRAD:
            if op.keep:
                kept_grad.add((op.l, op.m))
            else:
                add(owner(op.m), "inter_grad", "gpu->cpu", u)
        elif k is Op.FETCH_GRAD:
            r = owner(op.m)
            if (op.l, op.m) in kept_grad:
                kept_grad.discard((op.l, op.m))
            else:
                # out-of-order: the rank's kept grads were never written
                # to CPU, so losing the slot forces the spill §4.2 avoids
                for key in [key for key in kept_grad
                            if key[0] == op.l and owner(key[1]) == r]:
                    kept_grad.discard(key)
                    add(r, "inter_grad", "gpu->cpu", u)
                add(r, "inter_grad", "cpu->gpu", u)
        elif k is Op.DROP_CKPT:
            kept.discard((op.l, op.m))
            tail_cached.discard((op.l, op.m))
        elif k is Op.GRAD_SPILL:
            add(0, "grad", "gpu->cpu", P * 4)
        elif k is Op.GRAD_FETCH_ACC:
            add(0, "grad", "cpu->gpu", P * 4)
        elif k is Op.WRITEBACK_GRAD:
            add(0, "grad", "gpu->cpu", P * 4)
            opt_segment(0, P, 0, int(round((1.0 - costs.alpha) * P)))
        elif k is Op.OPT_LATE:
            # epilogue seam: each iteration flushes its OWN α-tail at
            # plan end (the byte count is what an engine run followed
            # by finish() measures; PREFETCH_OPT hints only move the
            # state reads earlier, never change them)
            for r, (lo, hi) in enumerate(bounds):
                n_r = hi - lo
                opt_segment(r, n_r, int(round((1.0 - costs.alpha) * n_r)),
                            n_r)
        elif k is Op.REDUCE_SCATTER:
            ring = (R - 1) * (P * 4) // R
            for r, (lo, hi) in enumerate(bounds):
                n_r = hi - lo
                add(r, "grad", "gpu->net", ring)
                add(r, "grad", "net->gpu", ring)
                add(r, "grad", "gpu->cpu", n_r * 4)
                opt_segment(r, n_r, 0,
                            int(round((1.0 - costs.alpha) * n_r)))
        elif k is Op.ALLREDUCE_HEAD:
            ring = 2 * (R - 1) * costs.head_nbytes // R
            for r in range(R):
                add(r, "head_grad", "gpu->net", ring)
                add(r, "head_grad", "net->gpu", ring)
        elif k is Op.SPILL_KV:
            # eviction: ALL of the unit's blocks leave the device
            # (block-padded), the host-warm head stays in DRAM, the
            # cold tail goes to SSD — the TieredVector split applied
            # at BLOCK granularity (repro.core.traffic.kv_blocks)
            from repro.core.traffic import kv_blocks
            bb = costs.kv_block_bytes
            nbk = kv_blocks(costs.kv_unit_nbytes[op.l], bb)
            kb = _khost(costs.kv_x_host, nbk)
            add(0, "kv", "gpu->cpu", nbk * bb)
            add(0, "kv", "cpu->ssd", (nbk - kb) * bb)
        elif k is Op.FETCH_KV:
            # resume: the cold tail re-reads from SSD, then every block
            # (warm head + tail) lands back on device
            from repro.core.traffic import kv_blocks
            bb = costs.kv_block_bytes
            nbk = kv_blocks(costs.kv_unit_nbytes[op.l], bb)
            kb = _khost(costs.kv_x_host, nbk)
            add(0, "kv", "ssd->cpu", (nbk - kb) * bb)
            add(0, "kv", "cpu->gpu", nbk * bb)
        # every other op moves no bytes (APPEND_KV is a device-HBM
        # block-table write — occupancy accounting, no offload traffic)

    dicts = [dict(d) for d in out]
    return dicts[0] if R == 1 else dicts
