"""Closed-form data-movement model (GreedySnake §1/§3.3/§3.4).

Notation (paper §1): N layers, total model size ``ms`` bytes (low-precision
parameters), per-micro-batch aggregated checkpoint size ``cs`` bytes, and
M micro-batches per iteration. Gradient-accumulation buffers are kept in
full precision, hence the factor 2·ms for a full set of f32 gradients.

These formulas drive the Fig. 5 reproduction and the perf model; the
offload engine's measured byte counters are validated against them.
"""
from __future__ import annotations

import dataclasses


BYTES_LOW = 2   # bf16/fp16 parameters and checkpoints
BYTES_F32 = 4


@dataclasses.dataclass(frozen=True)
class TrafficBreakdown:
    """GPU<->lower-hierarchy traffic, bytes per iteration."""
    param_load: float
    grad_swap: float          # full-precision grad-accum buffer movement
    ckpt_write: float
    ckpt_read: float
    inter_grad: float         # vertical: inter-layer activation grads via CPU

    @property
    def load(self) -> float:
        return self.param_load + self.grad_swap / 2 + self.ckpt_read + self.inter_grad / 2

    @property
    def offload(self) -> float:
        return self.grad_swap / 2 + self.ckpt_write + self.inter_grad / 2

    @property
    def total(self) -> float:
        return self.param_load + self.grad_swap + self.ckpt_write \
            + self.ckpt_read + self.inter_grad


def model_bytes(cfg) -> int:
    """ms: low-precision parameter bytes."""
    return cfg.total_params() * BYTES_LOW


def checkpoint_bytes(cfg, micro_batch: int, seq_len: int) -> int:
    """cs: aggregated inter-layer checkpoint bytes for ONE micro-batch
    (one (mb, S, d) tensor per layer boundary)."""
    n_ckpt = cfg.num_layers
    return n_ckpt * micro_batch * seq_len * cfg.d_model * BYTES_LOW


def horizontal_traffic(ms: float, cs: float, M: int) -> TrafficBreakdown:
    """ZeRO-Infinity-style schedule (paper §1):
    params loaded 2x per micro-batch (fwd + bwd recompute) = 2·M·ms;
    checkpoints written once and read once per micro-batch = 2·M·cs;
    the f32 grad buffer: first mb offloads only, the rest fetch+offload
    = (2(M-1)+1)·2ms = (2M-1)·2ms."""
    return TrafficBreakdown(
        param_load=2 * M * ms,
        grad_swap=(2 * M - 1) * 2 * ms,
        ckpt_write=M * cs,
        ckpt_read=M * cs,
        inter_grad=0.0,
    )


def vertical_traffic(ms: float, cs: float, M: int) -> TrafficBreakdown:
    """GreedySnake vertical schedule (§3.4):
    params loaded once for fwd and once for bwd-recompute = 2·ms;
    grads accumulated in GPU memory, transferred once = 2·ms (f32);
    checkpoints: written once per micro-batch per layer (M·cs), read
    twice (next-layer forward input + backward recompute) minus the
    boundary micro-batch kept on-GPU (alternating order, §4.2);
    inter-layer activation gradients pass through CPU memory in the
    backward pass (2·M·cs·(1/N-th each way ≈ cs per mb per boundary))."""
    keep = cs / max(M, 1)   # one micro-batch's worth stays on-GPU per layer
    return TrafficBreakdown(
        param_load=2 * ms,
        grad_swap=2 * ms,
        ckpt_write=M * cs,
        ckpt_read=2 * M * cs - 2 * keep,
        inter_grad=2 * M * cs - 2 * keep,
    )


def optimizer_state_bytes(cfg) -> int:
    """Master + momentum + variance, f32 each (§2.2: master params are
    treated as optimizer state)."""
    return cfg.total_params() * 3 * BYTES_F32


def accum_grad_bytes(cfg) -> int:
    return cfg.total_params() * BYTES_F32
