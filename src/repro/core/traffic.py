"""Closed-form data-movement model (GreedySnake §1/§3.3/§3.4).

Notation (paper §1): N layers, total model size ``ms`` bytes (low-precision
parameters), per-micro-batch aggregated checkpoint size ``cs`` bytes, and
M micro-batches per iteration. Gradient-accumulation buffers are kept in
full precision, hence the factor 2·ms for a full set of f32 gradients.

These formulas drive the Fig. 5 reproduction and the perf model; the
offload engine's measured byte counters are validated against them.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


BYTES_LOW = 2   # bf16/fp16 parameters and checkpoints
BYTES_F32 = 4


@dataclasses.dataclass(frozen=True)
class TrafficBreakdown:
    """GPU<->lower-hierarchy traffic, bytes per iteration."""
    param_load: float
    grad_swap: float          # full-precision grad-accum buffer movement
    ckpt_write: float
    ckpt_read: float
    inter_grad: float         # vertical: inter-layer activation grads via CPU

    @property
    def load(self) -> float:
        return self.param_load + self.grad_swap / 2 + self.ckpt_read + self.inter_grad / 2

    @property
    def offload(self) -> float:
        return self.grad_swap / 2 + self.ckpt_write + self.inter_grad / 2

    @property
    def total(self) -> float:
        return self.param_load + self.grad_swap + self.ckpt_write \
            + self.ckpt_read + self.inter_grad


def model_bytes(cfg) -> int:
    """ms: low-precision parameter bytes."""
    return cfg.total_params() * BYTES_LOW


def checkpoint_bytes(cfg, micro_batch: int, seq_len: int) -> int:
    """cs: aggregated inter-layer checkpoint bytes for ONE micro-batch
    (one (mb, S, d) tensor per layer boundary)."""
    n_ckpt = cfg.num_layers
    return n_ckpt * micro_batch * seq_len * cfg.d_model * BYTES_LOW


def horizontal_traffic(ms: float, cs: float, M: int) -> TrafficBreakdown:
    """ZeRO-Infinity-style schedule (paper §1):
    params loaded 2x per micro-batch (fwd + bwd recompute) = 2·M·ms;
    checkpoints written once and read once per micro-batch = 2·M·cs;
    the f32 grad buffer: first mb offloads only, the rest fetch+offload
    = (2(M-1)+1)·2ms = (2M-1)·2ms."""
    return TrafficBreakdown(
        param_load=2 * M * ms,
        grad_swap=(2 * M - 1) * 2 * ms,
        ckpt_write=M * cs,
        ckpt_read=M * cs,
        inter_grad=0.0,
    )


def wave_traffic(ms: float, cs: float, M: int, W: int) -> TrafficBreakdown:
    """The wave hybrid schedule (``repro.core.plan.compile_wave``):
    ``nw = M/W`` waves of W micro-batches, each run vertically, with the
    f32 grad-accumulation buffer swapped through CPU between waves.

    Params are (re)loaded twice per wave (2·nw·ms) and the grad buffer
    moves (2·nw-1)·2·ms — the two horizontal taxes, each scaled down by
    W. Each wave's kept micro-batch saves its FORWARD re-read and its
    inter-layer-gradient round trip; backward recompute always re-reads
    every micro-batch (M·cs), so ckpt_read = (2M - nw)·cs. The
    endpoints are the two paper schedules: W=M returns
    :func:`vertical_traffic` (its §3.4 keep convention) and W=1 equals
    :func:`horizontal_traffic` exactly; the exact per-boundary
    engine-level counters are :func:`wave_ckpt_traffic`."""
    if W < 1 or M % W:
        raise ValueError(f"wave size W={W} must divide M={M}")
    if W == M:
        return vertical_traffic(ms, cs, M)
    nw = M // W
    return TrafficBreakdown(
        param_load=2 * nw * ms,
        grad_swap=(2 * nw - 1) * 2 * ms,
        ckpt_write=M * cs,
        ckpt_read=(2 * M - nw) * cs,
        inter_grad=2 * (M - nw) * cs,
    )


def vertical_traffic(ms: float, cs: float, M: int) -> TrafficBreakdown:
    """GreedySnake vertical schedule (§3.4):
    params loaded once for fwd and once for bwd-recompute = 2·ms;
    grads accumulated in GPU memory, transferred once = 2·ms (f32);
    checkpoints: written once per micro-batch per layer (M·cs), read
    twice (next-layer forward input + backward recompute) minus the
    boundary micro-batch kept on-GPU (alternating order, §4.2);
    inter-layer activation gradients pass through CPU memory in the
    backward pass (2·M·cs·(1/N-th each way ≈ cs per mb per boundary))."""
    keep = cs / max(M, 1)   # one micro-batch's worth stays on-GPU per layer
    return TrafficBreakdown(
        param_load=2 * ms,
        grad_swap=2 * ms,
        ckpt_write=M * cs,
        ckpt_read=2 * M * cs - 2 * keep,
        inter_grad=2 * M * cs - 2 * keep,
    )


@dataclasses.dataclass(frozen=True)
class CkptTraffic:
    """EXACT engine-level checkpoint / inter-layer-gradient counters for
    the vertical schedule (unlike :func:`vertical_traffic`'s smooth
    approximation, these count the L+1 actual layer boundaries the
    engine materialises — embedding output plus each layer output).

    Unit: ``u = cs / L`` — one micro-batch's single-boundary tensor.
    The §4.2 alternating micro-batch order keeps exactly one micro-batch
    per boundary on device, saving its forward re-read and both
    directions of its inter-layer gradient transfer; backward recompute
    re-reads every micro-batch.
    """
    write: float        # ckpt gpu->cpu: every boundary, every micro-batch
    read_fwd: float     # next-layer forward inputs: boundary mb on device
    read_bwd: float     # backward recompute inputs: no device saving
    inter_grad: float   # activation-grad round trips through CPU
    ssd_spill: float    # async tail spills at x_ckpt=0 (== write)
    ssd_reread: float   # bwd tail re-reads at x_ckpt=0: the boundary
                        # micro-batch's tail stays CPU-cached, so only
                        # M-1 per interior boundary touch the SSD

    @property
    def read(self) -> float:
        return self.read_fwd + self.read_bwd


@dataclasses.dataclass(frozen=True)
class ActTraffic:
    """EXACT engine-level counters of the SSDTrain-style activation
    stream (``activation_policy="spill"``): per pipelined layer and
    micro-batch, the layer's vjp residuals — ``A`` bytes, the
    non-boundary activations backward needs — are streamed out after
    the forward (``SPILL_ACT``) and streamed back just before the
    backward (``FETCH_ACT``), instead of being recomputed from the
    boundary checkpoint. ``x_act`` is the CPU-resident head fraction
    (``StorageRatios.act``); the tail beyond it rides the SSD at
    ``IOPriority.ACT`` (below ckpt spills — opportunistic)."""
    spill: float        # act gpu->cpu: every layer, every micro-batch
    fetch: float        # act cpu->gpu: same count, ahead of each BWD
    ssd_spill: float    # act cpu->ssd: the (1 - x_act) tails
    ssd_reread: float   # act ssd->cpu: every tail re-read at backward
                        # (the CPU copy is dropped once the spill lands
                        # — freeing DRAM is the point of the stream)

    @property
    def total(self) -> float:
        return self.spill + self.fetch + self.ssd_spill + self.ssd_reread


def act_spill_traffic(A: float, M: int, L: int,
                      x_act: float = 0.0) -> ActTraffic:
    """Closed-form per-iteration activation-stream counters: ``L·M``
    spills and fetches of ``A`` bytes each (one per (layer,
    micro-batch)), with the ``(A - k)`` tail touching the SSD both ways
    (``k = round(x_act · A)`` — the same rounding the coordinator and
    :func:`repro.core.plan.plan_traffic` apply). Wave size does not
    enter: activations are written and read within one wave, with no
    §4.2 keep discipline (the stream is strictly FIFO per micro-batch).
    """
    tail = A - int(round(x_act * A))
    return ActTraffic(
        spill=L * M * A,
        fetch=L * M * A,
        ssd_spill=L * M * tail,
        ssd_reread=L * M * tail,
    )


def kv_blocks(nbytes: int, block_bytes: int) -> int:
    """Number of fixed-size KV blocks one payload occupies (ceil) — the
    ONE rounding the serve block tables, :func:`kv_traffic`, and
    ``repro.core.plan.plan_traffic`` all share."""
    if block_bytes <= 0:
        raise ValueError(f"block_bytes must be > 0, got {block_bytes}")
    return -(-int(nbytes) // int(block_bytes))


@dataclasses.dataclass(frozen=True)
class KVTraffic:
    """EXACT engine-level counters of the serving KV-block stream
    (``repro.serve``): evictions (``SPILL_KV``) move ALL of a cache
    unit's blocks off device and write the cold tail to SSD; resumes
    (``FETCH_KV``) re-read the cold tail and restore every block.
    ``x_host`` is the warm (host-resident) BLOCK fraction — the
    TieredVector split applied at block granularity, so all four
    counters are multiples of the block size. ``APPEND_KV`` ops move no
    offload bytes (device-HBM block-table writes)."""
    spill: int          # kv gpu->cpu: all blocks of every evicted unit
    ssd_spill: int      # kv cpu->ssd: the cold (1 - x_host) block tails
    fetch: int          # kv cpu->gpu: all blocks of every resumed unit
    ssd_fetch: int      # kv ssd->cpu: the cold tails re-read on resume

    @property
    def total(self) -> int:
        return self.spill + self.ssd_spill + self.fetch + self.ssd_fetch


def kv_traffic(unit_nbytes, block_bytes: int, x_host: float,
               spills, fetches) -> KVTraffic:
    """Closed-form KV-stream counters: ``spills[i]`` / ``fetches[i]``
    are how many times cache unit ``i`` (payload ``unit_nbytes[i]``)
    was evicted / resumed this window. Each event moves the unit's full
    block-padded payload across the device boundary and its cold block
    tail across the SSD boundary, with ``k = round(x_host · blocks)``
    warm blocks held in host DRAM (the same rounding the coordinator
    and ``plan_traffic`` apply) — the third leg of the serve three-way
    byte invariant."""
    spill = ssd_spill = fetch = ssd_fetch = 0
    for nb, ns, nf in zip(unit_nbytes, spills, fetches):
        blocks = kv_blocks(nb, block_bytes)
        cold = (blocks - int(round(x_host * blocks))) * block_bytes
        padded = blocks * block_bytes
        spill += ns * padded
        ssd_spill += ns * cold
        fetch += nf * padded
        ssd_fetch += nf * cold
    return KVTraffic(spill=spill, ssd_spill=ssd_spill, fetch=fetch,
                     ssd_fetch=ssd_fetch)


def wave_ckpt_traffic(cs: float, M: int, W: int, L: int,
                      act_spill: bool = False) -> CkptTraffic:
    """Exact per-iteration checkpoint / inter-layer-gradient counters of
    the plan-driven engine for the W-wave schedule (``nw = M/W`` waves,
    each behaving vertically over its W micro-batches): every boundary
    is written for every micro-batch, and each wave keeps ONE
    micro-batch per boundary on device — saving its forward re-read and
    both directions of its inter-layer gradient, ``nw`` times per
    boundary per iteration. Backward recompute re-reads every
    micro-batch; the kept micro-batches' tails stay CPU-cached, so only
    ``M - nw`` per interior boundary touch the SSD.

    ``W=M`` is the vertical engine (:func:`vertical_ckpt_traffic`);
    ``W=1`` is the horizontal engine, whose forward re-reads,
    inter-layer gradients, and SSD tail re-reads all collapse to zero
    (the single in-flight micro-batch never leaves the device) — the
    interpolation the wave knob trades against its ``2·nw·ms``
    parameter reloads.

    With ``act_spill=True`` (``activation_policy="spill"``) the
    backward pass consumes the activation stream
    (:func:`act_spill_traffic`) instead of recomputing from
    checkpoints, so the two backward re-read terms vanish: no
    ``FETCH_CKPT_BWD`` reads (``read_bwd = 0``) and no SSD tail
    re-reads (``ssd_reread = 0``). Checkpoint WRITES are unchanged —
    the next layer's forward still consumes the CPU cache, and the SSD
    tails stay on disk as the recompute fallback a failed activation
    fetch degrades to."""
    if W < 1 or M % W:
        raise ValueError(f"wave size W={W} must divide M={M}")
    nw = M // W
    u = cs / max(L, 1)
    nb = L + 1                       # boundaries 0..L
    return CkptTraffic(
        write=nb * M * u,
        read_fwd=nb * (M - nw) * u,
        read_bwd=0.0 if act_spill else L * M * u,
        inter_grad=2 * nb * (M - nw) * u,
        ssd_spill=nb * M * u,
        ssd_reread=0.0 if act_spill else L * (M - nw) * u,
    )


def vertical_ckpt_traffic(cs: float, M: int, L: int,
                          act_spill: bool = False) -> CkptTraffic:
    """Exact per-iteration checkpoint byte counters of the vertical
    engine: "read twice minus the on-device boundary micro-batch"
    (§4.2), per boundary — the single-wave (W=M) case of
    :func:`wave_ckpt_traffic`. Perturbing the alternating order costs
    ``(L)·u`` extra checkpoint reads and ``2·L·u`` extra inter-layer
    gradient bytes (only the embedding-side boundary stays aligned).
    ``ssd_*`` fields are the fully-offloaded (x_ckpt=0) values."""
    return wave_ckpt_traffic(cs, M, M, L, act_spill=act_spill)


@dataclasses.dataclass(frozen=True)
class DPRankTraffic:
    """Per-rank, per-iteration bytes for the R-way data-parallel
    vertical schedule (ZeRO-style partitioned state, ring collectives).
    All quantities are for ONE rank; ``ssd_*`` properties give the
    fully-offloaded (x = 0) storage traffic each rank's own SSD path
    set carries — aggregate storage traffic is R× those, which is the
    multi-path bandwidth lever of the Fig. 10 scaling."""
    param_fetch: float         # own shard, fwd+bwd (cpu->gpu): 2·ms/R
    param_allgather: float     # ring recv (net->gpu): 2·ms·(R-1)/R
    param_writeback: float     # updated low-precision shard: ms/R
    grad_offload: float        # reduce-scattered f32 shard (gpu->cpu)
    grad_reducescatter: float  # ring send == recv: grad_bytes·(R-1)/R
    opt_read: float            # master+m+v shard reads: os_bytes/R
    opt_write: float           # master+m+v shard writes: os_bytes/R
    ckpt: Optional[CkptTraffic]  # boundary traffic over M/R micro-batches
    act: Optional[ActTraffic] = None  # activation stream over M/R
                                      # micro-batches (spill policy)

    @property
    def interconnect(self) -> float:
        """Bytes received per rank per iteration over the DP fabric
        (all-gather + reduce-scatter; the head all-reduce is excluded
        like the paper excludes the head from the pipeline, §4.5)."""
        return self.param_allgather + self.grad_reducescatter

    @property
    def ssd_read(self) -> float:
        r = self.param_fetch + self.opt_read
        r += self.ckpt.ssd_reread if self.ckpt else 0.0
        return r + (self.act.ssd_reread if self.act else 0.0)

    @property
    def ssd_write(self) -> float:
        w = self.param_writeback + self.opt_write
        w += self.ckpt.ssd_spill if self.ckpt else 0.0
        return w + (self.act.ssd_spill if self.act else 0.0)


def dp_vertical_traffic(ms: float, cs: float, M: int, R: int, *,
                        grad_bytes: Optional[float] = None,
                        os_bytes: Optional[float] = None,
                        n_layers: Optional[int] = None,
                        act_bytes: Optional[float] = None) -> DPRankTraffic:
    """Closed-form per-rank traffic for R data-parallel ranks running
    the vertical schedule over M global micro-batches.

    Defaults follow the paper's conventions (f32 grads = ``2·ms``,
    optimizer state = ``6·ms``); pass explicit byte counts to match an
    engine running at a different precision (the f32 test engine passes
    ``grad_bytes=ms`` and ``os_bytes=3·ms``). With ``n_layers`` the
    checkpoint terms are the exact per-boundary counters
    (:func:`vertical_ckpt_traffic` over the rank's ``M/R``
    micro-batches); without it they are omitted. With ``act_bytes=A``
    (per-(layer, micro-batch) residual bytes) the rank additionally
    carries the activation stream of its M/R micro-batches
    (:func:`act_spill_traffic`) and its checkpoint backward re-reads
    vanish — activations are sharded by micro-batch ownership, so each
    rank spills and fetches on its OWN path set."""
    if M % R:
        raise ValueError(f"M={M} must divide across R={R} ranks")
    grad_bytes = 2.0 * ms if grad_bytes is None else grad_bytes
    os_bytes = 6.0 * ms if os_bytes is None else os_bytes
    shard = ms / R
    spill = act_bytes is not None
    return DPRankTraffic(
        param_fetch=2 * shard,
        param_allgather=2 * (ms - shard),
        param_writeback=shard,
        grad_offload=grad_bytes / R,
        grad_reducescatter=grad_bytes * (R - 1) / R,
        opt_read=os_bytes / R,
        opt_write=os_bytes / R,
        ckpt=(vertical_ckpt_traffic(cs, M // R, n_layers, act_spill=spill)
              if n_layers else None),
        act=(act_spill_traffic(act_bytes, M // R, n_layers)
             if spill and n_layers else None),
    )


def optimizer_state_bytes(cfg) -> int:
    """Master + momentum + variance, f32 each (§2.2: master params are
    treated as optimizer state)."""
    return cfg.total_params() * 3 * BYTES_F32


def accum_grad_bytes(cfg) -> int:
    return cfg.total_params() * BYTES_F32


def act_residual_bytes(cfg, micro_batch: int, seq_len: int) -> int:
    """``as``: aggregated non-boundary activation (vjp residual) bytes
    for ONE micro-batch across all pipelined layers — the workload term
    the spill policy streams instead of recomputing (SSDTrain's lever).

    This is a closed-form ESTIMATE for the perf model / Algorithm 1
    (per token per layer: qkv + attention output + the two MLP
    intermediates + the normalised inputs, plus the attention
    probabilities); the engines size the stream EXACTLY from
    ``jax.eval_shape`` of their residual-returning forward."""
    t = micro_batch * seq_len
    per_layer = t * (6 * cfg.d_model + 2 * cfg.d_ff) * BYTES_LOW
    if not cfg.is_attention_free:
        per_layer += cfg.num_heads * micro_batch * seq_len * seq_len \
            * BYTES_LOW
    return cfg.num_layers * per_layer
