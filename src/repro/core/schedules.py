"""Train-step builders: horizontal vs vertical gradient accumulation.

GreedySnake's key identity (§3.4): vertical scheduling — running each
layer over ALL micro-batches before the next layer — computes exactly the
same gradients as horizontal micro-batch accumulation (linearity of the
summed gradient). In XLA terms:

* ``horizontal``: ``lax.scan`` over M micro-batches; each iteration runs
  the full model fwd+bwd (per-layer remat) and accumulates f32 gradients
  in the scan carry. This is the ZeRO-Infinity baseline: the full-model
  f32 gradient buffer is carried through all M iterations (its repeated
  traffic shows up in `cost_analysis` bytes, the HBM analogue of the
  paper's `(2M-1)·2ms` grad swapping), and sharded params are re-gathered
  per micro-batch.

* ``vertical``: the concatenated global batch runs layer-by-layer (the
  scan over layers inside the model) with per-layer remat — parameters
  are gathered ONCE per layer per iteration and gradients produced once.
  The inter-layer activation checkpoint (the scan carry, now M× larger)
  is the extra traffic the paper trades for parameter reuse.

Optimizer-step overlap (§4.3/4.4) is expressed through the α-delayed
partial Adam: ``alpha`` of every layer's update is deferred into the next
iteration's forward. On TPU the XLA latency-hiding scheduler overlaps the
host-offloaded state movement; on the CPU offload engine the overlap is
real threads (see repro.offload.engine).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import model as model_lib
from repro.optim import (AdamConfig, DelayedAdamState, apply_early,
                         apply_update, clip_by_global_norm, flush_late,
                         global_norm, init_state)


# Optional sharding tree (matching the params pytree) pinned onto the
# gradients. With model-sharded optimizer states this turns the per-layer
# data-axis grad all-reduce into a cheaper reduce-scatter (ZeRO-2-style),
# matching how GreedySnake transfers each layer's fully-accumulated grads
# exactly once. Set by the launcher; None = let SPMD decide.
_GRAD_SHARDINGS = None


def set_grad_shardings(tree) -> None:
    global _GRAD_SHARDINGS
    _GRAD_SHARDINGS = tree


def _constrain_grads(grads):
    if _GRAD_SHARDINGS is None:
        return grads
    try:
        return jax.tree.map(jax.lax.with_sharding_constraint, grads,
                            _GRAD_SHARDINGS)
    except (ValueError, RuntimeError):
        return grads


@dataclasses.dataclass(frozen=True)
class ScheduleConfig:
    schedule: str = "vertical"       # "vertical" | "horizontal"
    num_microbatches: int = 1        # M (horizontal splits the batch; for
                                     # vertical, M only documents the batch
                                     # composition — execution is layerwise)
    alpha: float = 0.0               # delayed-optimizer ratio (§4.4)
    clip_norm: Optional[float] = None
    remat: bool = True
    scan_impl: str = "jnp"           # attention/ssm kernel impl


def _split(batch, m: int):
    def sp(x):
        return x.reshape(m, x.shape[0] // m, *x.shape[1:])
    return jax.tree.map(sp, batch)


def grads_fn(cfg, sched: ScheduleConfig) -> Callable:
    """Returns grads(params, batch) -> (loss, grads) under the schedule."""
    def loss_fn(params, batch):
        return model_lib.loss_fn(params, cfg, batch, remat=sched.remat,
                                 scan_impl=sched.scan_impl)

    if sched.schedule == "vertical" or sched.num_microbatches == 1:
        def vertical(params, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            return loss, _constrain_grads(grads)
        return vertical

    m = sched.num_microbatches

    def horizontal(params, batch):
        mb = _split(batch, m)

        def body(carry, mbatch):
            loss_acc, gacc = carry
            l, g = jax.value_and_grad(loss_fn)(params, mbatch)
            gacc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gacc, g)
            return (loss_acc + l, gacc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, gsum), _ = jax.lax.scan(body, (jnp.zeros(()), g0), mb)
        grads = jax.tree.map(lambda g: g / m, gsum)
        return loss_sum / m, _constrain_grads(grads)

    return horizontal


def make_train_step(cfg, sched: ScheduleConfig, adam: AdamConfig):
    """Standard (α=0) train step: params, opt_state, batch -> ...

    Works for both schedules; the returned metrics include grad norm.
    """
    gfn = grads_fn(cfg, sched)

    def step(params, opt_state, batch):
        loss, grads = gfn(params, batch)
        gn = global_norm(grads)
        if sched.clip_norm is not None:
            grads, coef, _ = clip_by_global_norm(grads, sched.clip_norm)
        params, opt_state = apply_update(opt_state, grads, adam)
        return params, opt_state, {"loss": loss, "grad_norm": gn}

    return step


def make_delayed_train_step(cfg, sched: ScheduleConfig, adam: AdamConfig):
    """GreedySnake train step with the α-delayed optimizer (§4.4).

    State is DelayedAdamState. Semantics per iteration:
      1. flush the pending α fraction of the previous step's update
         (the "optimizer step overlapped with forward" — every layer is
         fully updated before it is used);
      2. fwd+bwd under the configured schedule;
      3. apply the (1-α) early fraction immediately (overlapped with
         backward in the real pipeline); retain grads as pending.
    With the same inputs, N iterations followed by a final flush are
    bit-identical (f32) to N standard Adam steps.
    """
    gfn = grads_fn(cfg, sched)
    alpha = sched.alpha

    def step(state: DelayedAdamState, batch):
        params, state = flush_late(state, adam, alpha)
        loss, grads = gfn(params, batch)
        gn = global_norm(grads)
        if sched.clip_norm is not None:
            grads, _, _ = clip_by_global_norm(grads, sched.clip_norm)
        params, state = apply_early(state, grads, adam, alpha)
        return params, state, {"loss": loss, "grad_norm": gn}

    return step


def init_train_state(cfg, key, *, delayed: bool = False):
    params = model_lib.init_params(cfg, key)
    opt = init_state(params)
    if not delayed:
        return params, opt
    from repro.optim import init_delayed
    grads_like = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return params, init_delayed(opt, grads_like)
