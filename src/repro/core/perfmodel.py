"""Iteration-time & roofline model for SSD-offloaded training (§3.1, §4.5).

Predicts per-iteration time for horizontal vs vertical schedules from
machine parameters (GPU compute rate, PCIe bw, SSD bw, CPU-Adam rate) and
workload sizes (model bytes ms, checkpoint bytes cs, optimizer-state
bytes os). This is the "simple yet accurate performance model" that
Algorithm 1 builds its LP around, and it draws the roofline of Fig. 3:

    throughput(M) = tokens(M) / T_iter(M)
    I/O-access roofline:   T_iter >= os_ssd_traffic / ssd_bw
    computation roofline:  T_iter >= total_compute / gpu_flops
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core import traffic as tr


@dataclasses.dataclass(frozen=True)
class MachineParams:
    """Benchmark results packed as system parameters (Alg. 1's  M).

    ``ssd_path_read_bw`` / ``ssd_path_write_bw`` optionally record the
    PER-PATH achieved rates of a multi-path SSD tier (index = path).
    The aggregate ``ssd_read_bw`` / ``ssd_write_bw`` stay the rates the
    time model divides by; how a heterogeneous path set folds into that
    aggregate depends on the chunk-placement policy — apply
    :func:`machine_for_path_policy` before pricing a plan."""
    name: str = "a100-cloud"
    gpu_flops: float = 140e12          # sustained matmul FLOP/s (bf16)
    pcie_bw: float = 24e9              # GPU<->CPU, bytes/s
    ssd_read_bw: float = 6.0e9
    ssd_write_bw: float = 3.0e9
    cpu_adam_bw: float = 8.0e9         # optimizer-state bytes processed /s
    cpu_mem: float = 400e9             # usable DRAM for offload (per rank)
    gpu_mem: float = 40e9
    num_gpus: int = 1
    interconnect_bw: float = 16e9      # DP fabric, bytes/s per rank
                                       # (ring all-gather/reduce-scatter)
    ssd_path_read_bw: Optional[Tuple[float, ...]] = None
    ssd_path_write_bw: Optional[Tuple[float, ...]] = None


def machine_for_path_policy(m: MachineParams, path_policy: str = "static"
                            ) -> MachineParams:
    """Fold the per-path SSD rates into the aggregate ``ssd_read_bw`` /
    ``ssd_write_bw`` under a chunk-placement policy:

    * ``"static"`` — round-robin striping moves every tensor through
      every path in equal byte shares, so the slowest device paces the
      whole stripe: aggregate = ``P x min(path_rates)``.
    * ``"weighted"`` / ``"backlog"`` — placement splits bytes in
      proportion to what each path absorbs, so the devices drain
      together: aggregate = ``sum(path_rates)``.

    A machine without per-path rates is returned unchanged — the
    aggregate numbers already are the measurement."""
    def eff(per_path, fallback: float) -> float:
        rates = [float(r) for r in (per_path or ()) if r and r > 0]
        if not rates:
            return fallback
        if path_policy == "static":
            return len(rates) * min(rates)
        return sum(rates)

    rd = eff(m.ssd_path_read_bw, m.ssd_read_bw)
    wr = eff(m.ssd_path_write_bw, m.ssd_write_bw)
    if rd == m.ssd_read_bw and wr == m.ssd_write_bw:
        return m
    return dataclasses.replace(m, ssd_read_bw=rd, ssd_write_bw=wr)


def machine_from_bandwidth(bandwidth, base: Optional[MachineParams] = None
                           ) -> MachineParams:
    """MachineParams whose link rates mirror a simulated-bandwidth map
    (``repro.io.IOConfig.bandwidth``: route -> bytes/s). This is the
    plumbing that lets the roofline/LP predictions be validated in
    wall-clock against the I/O engine's token-bucket pacing: configure
    caps, run the real engine, and compare measured times with this
    machine's predictions (see ``benchmarks/bench_io.py``).

    Takes a plain mapping (not an IOConfig) so ``repro.core`` stays
    independent of ``repro.io``."""
    base = base or MachineParams()
    pcie = bandwidth.get("cpu->gpu", bandwidth.get("gpu->cpu", base.pcie_bw))
    return dataclasses.replace(
        base, name=f"{base.name}-simulated",
        pcie_bw=float(pcie),
        ssd_read_bw=float(bandwidth.get("ssd->cpu", base.ssd_read_bw)),
        ssd_write_bw=float(bandwidth.get("cpu->ssd", base.ssd_write_bw)))


def machine_from_bench(source, base: Optional[MachineParams] = None
                       ) -> MachineParams:
    """MachineParams whose SSD link rates come from a MEASURED
    ``benchmarks/bench_io.py --json`` run on this container (the ROADMAP
    item: Algorithm 1 solving against real link speeds rather than
    datasheet A100-node numbers).

    ``source`` is the path to the dumped JSON (or an already-parsed
    dict). Recognised keys: explicit ``ssd_read_bw`` / ``ssd_write_bw``
    / ``pcie_bw`` (bytes/s), else the best rate across the per-path-count
    measurements under ``"paths": {"<P>": {"read_bps", "write_bps"}}``
    (multi-path striping IS the device's aggregate rate here)."""
    if isinstance(source, (str, bytes)):
        import json
        with open(source) as f:
            data = json.load(f)
    else:
        data = dict(source)
    base = base or MachineParams()
    paths = data.get("paths", {})
    best_rd = max((float(v["read_bps"]) for v in paths.values()),
                  default=base.ssd_read_bw)
    best_wr = max((float(v["write_bps"]) for v in paths.values()),
                  default=base.ssd_write_bw)
    return dataclasses.replace(
        base, name=f"{base.name}-bench",
        ssd_read_bw=float(data.get("ssd_read_bw", best_rd)),
        ssd_write_bw=float(data.get("ssd_write_bw", best_wr)),
        pcie_bw=float(data.get("pcie_bw", base.pcie_bw)))


def machine_from_snapshot(snapshot, base: Optional[MachineParams] = None
                          ) -> MachineParams:
    """MachineParams whose SSD link rates come from a LIVE
    ``metrics_snapshot()`` — the ``repro.obs`` registry dict both
    engines export. The snapshot's ``trace.routes`` aggregates hold the
    measured chunk-span bytes and busy seconds per route (recorded by
    the I/O channel threads while the tracer was enabled) — the
    ROADMAP item-3 feed: ``machine_from_bench`` ingesting live meters
    instead of a separate ``bench_io.py`` pass. Routes with no measured
    spans (tracing off, or no traffic on that link) keep ``base``'s
    rates.

    Measured-rate semantics: a route's rate is ``rate_bps = bytes /
    busy_wall_s`` where ``busy_wall_s`` is the UNION of the chunk-span
    intervals across all P concurrent path-channel threads (see
    ``Tracer.summary``). Dividing by the plain per-channel ``busy_s``
    sum instead would read ~1/P of the striped device's aggregate
    bandwidth and make every consumer (the LP solver, the autotuner)
    systematically under-provision the plan. Old snapshots without
    ``rate_bps`` fall back to ``bytes / busy_s`` — correct only for
    single-path engines.

    Per-path rates: when the trace carries a route's ``per_path`` split
    (one single-threaded channel per SSD path, so each path's ``bytes /
    busy_s`` is that DEVICE's achieved rate), the result also fills
    ``ssd_path_read_bw`` / ``ssd_path_write_bw`` — the evidence
    :func:`machine_for_path_policy` folds into policy-dependent
    aggregates so the LP can price "static" vs "backlog" placement on a
    heterogeneous path set.

    Takes a plain dict, so ``repro.core`` stays independent of
    ``repro.obs``."""
    base = base or MachineParams()
    routes = (snapshot.get("trace") or {}).get("routes") or {}

    def rate(route: str, default: float) -> float:
        d = routes.get(route)
        if not d or not d.get("bytes"):
            return default
        if d.get("rate_bps"):
            return float(d["rate_bps"])
        if not d.get("busy_s"):
            return default
        return float(d["bytes"]) / float(d["busy_s"])

    def path_rates(route: str):
        pp = (routes.get(route) or {}).get("per_path") or {}
        rates = []
        for k in sorted(pp, key=int):
            v = pp[k] or {}
            r = v.get("rate_bps") or (
                float(v["bytes"]) / float(v["busy_s"])
                if v.get("bytes") and v.get("busy_s") else 0.0)
            rates.append(float(r))
        return tuple(rates) if any(rates) else None

    return dataclasses.replace(
        base, name=f"{base.name}-live",
        ssd_read_bw=rate("ssd->cpu", base.ssd_read_bw),
        ssd_write_bw=rate("cpu->ssd", base.ssd_write_bw),
        ssd_path_read_bw=path_rates("ssd->cpu"),
        ssd_path_write_bw=path_rates("cpu->ssd"))


def transfer_seconds(m: MachineParams, route: str, nbytes: float) -> float:
    """Predicted wall-clock for moving ``nbytes`` over one route."""
    bw = {"cpu->gpu": m.pcie_bw, "gpu->cpu": m.pcie_bw,
          "ssd->cpu": m.ssd_read_bw, "cpu->ssd": m.ssd_write_bw}[route]
    return nbytes / bw


def route_seconds(m: MachineParams, routes) -> dict:
    """Per-route predicted seconds for a ``(category, route) -> bytes``
    counter map — the shape :func:`repro.core.plan.plan_traffic` emits
    and the engines' ``TrafficMeter`` measures. This is the bridge from
    the schedule IR's static byte prediction to this time model: each
    link's lower bound is the sum of its categories' bytes over its
    bandwidth (``net`` routes use the DP interconnect)."""
    bw = {"cpu->gpu": m.pcie_bw, "gpu->cpu": m.pcie_bw,
          "ssd->cpu": m.ssd_read_bw, "cpu->ssd": m.ssd_write_bw,
          "gpu->net": m.interconnect_bw, "net->gpu": m.interconnect_bw}
    out: dict = {}
    for (_, route), nbytes in routes.items():
        out[route] = out.get(route, 0.0) + nbytes / bw[route]
    return out


@dataclasses.dataclass(frozen=True)
class Workload:
    """Per-GPU per-iteration quantities for one (model, mb, seq)."""
    ms: float        # low-precision param bytes (per GPU shard)
    cs: float        # aggregated ckpt bytes per micro-batch
    os_bytes: float  # optimizer state bytes (3 x f32 per element)
    grad_bytes: float  # f32 grad buffer bytes
    flops_per_mb: float  # fwd-only model FLOPs for one micro-batch
    tokens_per_mb: int
    n_layers: int = 1
    as_bytes: float = 0.0  # aggregated activation-residual bytes per
                           # micro-batch (the spill policy's stream)

    @staticmethod
    def from_config(cfg, micro_batch: int, seq_len: int, num_gpus: int = 1
                    ) -> "Workload":
        p = cfg.total_params()
        tokens = micro_batch * seq_len
        # fwd ~ 2*P*T; attention adds 2*S per token per layer pair
        attn = 4 * cfg.num_layers * cfg.d_model * seq_len * tokens \
            if not cfg.is_attention_free else 0
        return Workload(
            ms=tr.model_bytes(cfg) / num_gpus,
            cs=tr.checkpoint_bytes(cfg, micro_batch, seq_len),
            os_bytes=tr.optimizer_state_bytes(cfg) / num_gpus,
            grad_bytes=tr.accum_grad_bytes(cfg) / num_gpus,
            flops_per_mb=2 * cfg.active_params() * tokens + attn,
            tokens_per_mb=tokens,
            n_layers=cfg.num_layers,
            as_bytes=tr.act_residual_bytes(cfg, micro_batch, seq_len),
        )

    @property
    def grad_transient(self) -> float:
        """CPU bytes for in-flight layer gradients under the VERTICAL
        schedule: grads are produced per layer, consumed by the optimizer
        a couple of pipeline stages later, then freed — only ~3 layers'
        worth is ever alive (§4.3). The horizontal schedule instead keeps
        the FULL f32 buffer alive across all micro-batches."""
        return self.grad_bytes * min(1.0, 3.0 / max(1, self.n_layers))


@dataclasses.dataclass(frozen=True)
class StorageRatios:
    """Fraction of each data type resident in CPU memory (rest on SSD).
    ``act`` is the activation-stream head fraction — only consulted
    when ``activation_policy="spill"`` routes non-boundary activations
    through storage instead of recomputing them."""
    ckpt: float = 0.0
    param: float = 0.0
    opt: float = 1.0
    act: float = 0.0


def _ssd_time(read_bytes, write_bytes, m: MachineParams) -> float:
    return read_bytes / m.ssd_read_bw + write_bytes / m.ssd_write_bw


def cpu_mem_vertical(w: Workload, n: int, x: "StorageRatios",
                     alpha: float) -> float:
    """CPU bytes the vertical schedule needs resident: the CPU-cached
    fractions of ckpts/params/opt-states plus the transient per-layer
    gradient pipeline. The α-delayed gradients REUSE the reclaimed
    CPU-resident param/ckpt memory (§4.4) — see delayed_grads_fit."""
    return n * w.cs * x.ckpt + w.ms * x.param + w.os_bytes * x.opt \
        + w.grad_transient


def delayed_grads_fit(w: Workload, n: int, x: "StorageRatios",
                      alpha: float) -> bool:
    """§4.4 memory-reuse requirement: the α-retained gradients must fit
    in the CPU memory reclaimed from obsolete params + checkpoints."""
    return alpha * w.grad_bytes <= w.ms * x.param + n * w.cs * x.ckpt + 1e-6


def cpu_mem_horizontal(w: Workload, x: "StorageRatios") -> float:
    """Horizontal keeps the FULL f32 grad-accumulation buffer alive for
    the whole iteration (only one micro-batch's ckpt is alive at once).
    Gradients that do not fit spill to SSD (handled in the time model)."""
    return w.ms * x.param + w.os_bytes * x.opt + w.cs * x.ckpt


def compute_times(w: Workload, m: MachineParams):
    """(t_fwd, t_bwd) GPU seconds for ONE micro-batch.
    Backward includes recomputation: ~3x fwd FLOPs (2x bwd + 1x recompute)."""
    t_f = w.flops_per_mb / m.gpu_flops
    return t_f, 3.0 * t_f


def _lookahead_stalls(w: Workload, m: MachineParams, M: int, alpha: float,
                      x: StorageRatios, spill: bool) -> tuple:
    """(fwd, bwd) seconds of SSD reads the schedule SERIALIZES with
    compute when the cross-stream lookahead hints are disabled:

    * fwd — the α-tail optimizer state reads block the first layers'
      gate-ordered parameter fetches instead of riding ahead of them
      (``PREFETCH_OPT``);
    * bwd — each checkpoint tail's re-read (recompute) or residual
      tail's read (spill) blocks the executor at the fetch instead of
      streaming in behind the previous micro-batch's backward
      (``PREFETCH_CKPT`` / ``PREFETCH_ACT``).

    With lookahead ON these reads overlap compute, so the stage bounds
    stay pure maxes — the model the pre-lookahead formulas already
    assumed optimistically; ``lookahead=False`` makes the lost overlap
    explicit, which is the reduced-stall term Algorithm 1 prices."""
    fwd = alpha * w.os_bytes * (1 - x.opt) / m.ssd_read_bw
    if spill:
        bwd = M * w.as_bytes * (1 - x.act) / m.ssd_read_bw
    else:
        bwd = M * w.cs * (1 - x.ckpt) / m.ssd_read_bw
    return fwd, bwd


def iteration_time_vertical(w: Workload, m: MachineParams, M: int,
                            alpha: float, x: StorageRatios,
                            act: str = "recompute",
                            lookahead: bool = True) -> float:
    """GreedySnake §4: fwd and bwd stages each bounded by the max of GPU
    compute, PCIe traffic, SSD traffic, and (overlapped) CPU-Adam time.

    ``act="spill"`` prices the SSDTrain-style activation stream:
    backward drops its recompute third (``t_b1 = 2·t_f1``) and its
    checkpoint re-reads, and instead the ``M·as`` residual bytes ride
    out after forward and back in before backward (``StorageRatios.act``
    CPU-resident, the tail over SSD at the opportunistic priority).

    ``lookahead=False`` prices the hint-free executor: the reads the
    cross-stream lookahead overlaps (:func:`_lookahead_stalls`) are
    added to the stage compute terms instead of hiding under the max."""
    spill = act == "spill"
    t_f1, t_b1 = compute_times(w, m)
    if spill:
        t_b1 = 2.0 * t_f1                  # vjp only; no recompute pass
    pcie = tr.vertical_traffic(w.ms, w.cs, M)
    # PCIe split: fwd moves params (1x) + ckpt writes/reads; bwd the rest.
    pcie_fwd = w.ms + M * w.cs + (M - 1) * w.cs
    pcie_bwd = pcie.total - pcie_fwd
    if spill:
        pcie_fwd += M * w.as_bytes         # residual spill after each FWD
        pcie_bwd += M * (w.as_bytes - w.cs)  # fetch replaces ckpt re-read
    act_tail = M * w.as_bytes * (1 - x.act) if spill else 0.0
    bwd_ckpt_rd = 0.0 if spill else M * w.cs * (1 - x.ckpt)
    fwd_ssd = _ssd_time(w.ms * (1 - x.param) + alpha * w.os_bytes * (1 - x.opt),
                        M * w.cs * (1 - x.ckpt) + act_tail
                        + alpha * w.os_bytes * (1 - x.opt), m)
    bwd_ssd = _ssd_time(w.ms * (1 - x.param) + bwd_ckpt_rd + act_tail
                        + (1 - alpha) * w.os_bytes * (1 - x.opt),
                        (1 - alpha) * w.os_bytes * (1 - x.opt), m)
    adam_t = (w.os_bytes + w.grad_bytes) / m.cpu_adam_bw
    st_f, st_b = (0.0, 0.0) if lookahead else \
        _lookahead_stalls(w, m, M, alpha, x, spill)
    t_fwd = max(M * t_f1 + st_f, pcie_fwd / m.pcie_bw, fwd_ssd,
                alpha * adam_t)
    t_bwd = max(M * t_b1 + st_b, pcie_bwd / m.pcie_bw, bwd_ssd,
                (1 - alpha) * adam_t)
    return t_fwd + t_bwd


def iteration_time_wave(w: Workload, m: MachineParams, M: int, W: int,
                        alpha: float, x: StorageRatios,
                        act: str = "recompute",
                        lookahead: bool = True) -> float:
    """The wave hybrid (``repro.core.plan.compile_wave``): ``nw = M/W``
    waves, each stage bounded like the vertical model but with the
    parameter (re)loads scaled by ``nw`` and the cross-wave f32
    grad-buffer swap riding the PCIe terms (it is CPU-resident, like
    the horizontal engine's accumulation buffer). ``W=M`` reduces to
    :func:`iteration_time_vertical` exactly. ``act="spill"`` prices the
    activation stream the same way (wave size does not change its byte
    count — spills and fetches stay within one wave)."""
    if W < 1 or M % W:
        return float("inf")
    if W == M:
        return iteration_time_vertical(w, m, M, alpha, x, act=act,
                                       lookahead=lookahead)
    spill = act == "spill"
    nw = M // W
    t_f1, t_b1 = compute_times(w, m)
    if spill:
        t_b1 = 2.0 * t_f1
    pcie = tr.wave_traffic(w.ms, w.cs, M, W)
    pcie_fwd = nw * w.ms + M * w.cs + (M - nw) * w.cs
    pcie_bwd = pcie.total - pcie_fwd
    if spill:
        pcie_fwd += M * w.as_bytes
        pcie_bwd += M * (w.as_bytes - w.cs)
    act_tail = M * w.as_bytes * (1 - x.act) if spill else 0.0
    bwd_ckpt_rd = 0.0 if spill else M * w.cs * (1 - x.ckpt)
    fwd_ssd = _ssd_time(
        nw * w.ms * (1 - x.param) + alpha * w.os_bytes * (1 - x.opt),
        M * w.cs * (1 - x.ckpt) + act_tail
        + alpha * w.os_bytes * (1 - x.opt), m)
    bwd_ssd = _ssd_time(
        nw * w.ms * (1 - x.param) + bwd_ckpt_rd + act_tail
        + (1 - alpha) * w.os_bytes * (1 - x.opt),
        (1 - alpha) * w.os_bytes * (1 - x.opt), m)
    adam_t = (w.os_bytes + w.grad_bytes) / m.cpu_adam_bw
    st_f, st_b = (0.0, 0.0) if lookahead else \
        _lookahead_stalls(w, m, M, alpha, x, spill)
    t_fwd = max(M * t_f1 + st_f, pcie_fwd / m.pcie_bw, fwd_ssd,
                alpha * adam_t)
    t_bwd = max(M * t_b1 + st_b, pcie_bwd / m.pcie_bw, bwd_ssd,
                (1 - alpha) * adam_t)
    return t_fwd + t_bwd


def pick_activation_policy(w: Workload, m: MachineParams, M: int, W: int,
                           alpha: float, x: StorageRatios,
                           lookahead: bool = True) -> str:
    """Resolve ``activation_policy="auto"``: "spill" exactly when the
    roofline says streaming the residuals beats recomputing them —
    i.e. the spill-priced iteration is faster. Spilling wins when the
    backward recompute third is the binding term (slow compute, fast
    SSDs with spare write bandwidth); recompute wins when storage is
    the bottleneck and the extra ``2·M·as`` bytes would lengthen the
    critical path. ``lookahead`` must match the executor that will run
    the plan (``prefetch_depth > 0``): the hint-free executor pays the
    serialized tail-read stalls, which shift the break-even point."""
    t_re = iteration_time_wave(w, m, M, W, alpha, x, act="recompute",
                               lookahead=lookahead)
    t_sp = iteration_time_wave(w, m, M, W, alpha, x, act="spill",
                               lookahead=lookahead)
    return "spill" if t_sp < t_re else "recompute"


def iteration_time_vertical_dp(w: Workload, m: MachineParams, M: int,
                               alpha: float, x: StorageRatios,
                               R: Optional[int] = None,
                               act: str = "recompute",
                               lookahead: bool = True) -> float:
    """R-GPU data-parallel vertical schedule (the Fig. 10 scaling
    model). ``w`` is the FULL-model workload; each rank owns 1/R of
    every storage shard (ZeRO-style) and M/R of the micro-batches, and
    drives its OWN SSD path set — so per-rank storage time shrinks R×
    (R× aggregate bandwidth) while two collective terms appear on the
    critical path: the per-layer-boundary param all-gather
    (fwd and bwd: ``ms·(R-1)/R`` per rank each) and the gradient
    reduce-scatter (bwd: ``grad_bytes·(R-1)/R`` per rank), paced by
    ``m.interconnect_bw``. ``m.cpu_mem`` is per rank."""
    R = int(R or m.num_gpus)
    if R <= 1:
        return iteration_time_vertical(w, m, M, alpha, x, act=act,
                                       lookahead=lookahead)
    if M % R:
        return float("inf")
    spill = act == "spill"
    Mr = M // R
    wr = dataclasses.replace(w, ms=w.ms / R, os_bytes=w.os_bytes / R,
                             grad_bytes=w.grad_bytes / R)
    t_f1, t_b1 = compute_times(w, m)
    if spill:
        t_b1 = 2.0 * t_f1
    # per-rank PCIe: own shard + this rank's micro-batches' ckpt traffic
    pcie = tr.vertical_traffic(wr.ms, w.cs, Mr)
    pcie_fwd = wr.ms + Mr * w.cs + (Mr - 1) * w.cs
    pcie_bwd = pcie.total - pcie_fwd
    if spill:
        pcie_fwd += Mr * w.as_bytes
        pcie_bwd += Mr * (w.as_bytes - w.cs)
    act_tail = Mr * w.as_bytes * (1 - x.act) if spill else 0.0
    bwd_ckpt_rd = 0.0 if spill else Mr * w.cs * (1 - x.ckpt)
    fwd_ssd = _ssd_time(
        wr.ms * (1 - x.param) + alpha * wr.os_bytes * (1 - x.opt),
        Mr * w.cs * (1 - x.ckpt) + act_tail
        + alpha * wr.os_bytes * (1 - x.opt), m)
    bwd_ssd = _ssd_time(
        wr.ms * (1 - x.param) + bwd_ckpt_rd + act_tail
        + (1 - alpha) * wr.os_bytes * (1 - x.opt),
        (1 - alpha) * wr.os_bytes * (1 - x.opt), m)
    adam_t = (wr.os_bytes + wr.grad_bytes) / m.cpu_adam_bw
    frac = (R - 1) / R
    ic_fwd = frac * w.ms / m.interconnect_bw                  # all-gather
    ic_bwd = frac * (w.ms + w.grad_bytes) / m.interconnect_bw  # + red-scat
    st_f, st_b = (0.0, 0.0) if lookahead else \
        _lookahead_stalls(wr, m, Mr, alpha, x, spill)
    t_fwd = max(Mr * t_f1 + st_f, pcie_fwd / m.pcie_bw, fwd_ssd, ic_fwd,
                alpha * adam_t)
    t_bwd = max(Mr * t_b1 + st_b, pcie_bwd / m.pcie_bw, bwd_ssd, ic_bwd,
                (1 - alpha) * adam_t)
    return t_fwd + t_bwd


def rooflines_dp(w: Workload, m: MachineParams, x: StorageRatios, R: int):
    """R-rank extension of :func:`rooflines` (Fig. 3 / Fig. 10): the
    optimizer-state I/O bound shrinks R× (each rank's path set carries
    only its shard), compute scales R×, and the interconnect adds a
    third ceiling from the per-iteration collective bytes."""
    opt_io, comp = rooflines(w, m, x)
    frac = (R - 1) / R if R > 1 else 0.0
    ic = frac * (2 * w.ms + w.grad_bytes) / m.interconnect_bw
    return opt_io / R, comp * R, ic


def iteration_time_horizontal(w: Workload, m: MachineParams, M: int,
                              x: StorageRatios,
                              overlap_last_bwd: bool = False) -> float:
    """ZeRO-Infinity-style: per-micro-batch param reload + grad-buffer
    swapping (§3.3).

    Two documented ZeRO-Infinity behaviors are modeled:
    * the grad-accumulation buffer is fetched ON DEMAND when a bucket's
      backward fires (§2.2 Fig. 2(b) step 4), so its movement is
      SERIALIZED with backward compute rather than hidden under it;
    * the optimizer step is NOT overlapped with the backward pass
      (§6.2: "Ratel ... overlaps the backward pass with the optimizer
      step ... whereas ZeRO-Infinity does not"). Pass
      ``overlap_last_bwd=True`` for the paper's generous §1 framing
      (overlap with the last micro-batch's backward).

    The full f32 gradient-accumulation buffer must persist across all
    micro-batches; the fraction that does not fit in the CPU-memory
    leftover (after the x-configured param/opt/ckpt residency) spills to
    SSD and is re-read + re-written per micro-batch — the dominant cost
    for models whose grads exceed DRAM (e.g. GPT-175B: 700 GB f32)."""
    t_f1, t_b1 = compute_times(w, m)
    leftover = 0.95 * m.cpu_mem - cpu_mem_horizontal(w, x)
    if leftover < 0:
        return float("inf")
    x_g = min(1.0, max(0.0, leftover / w.grad_bytes))
    # per-micro-batch PCIe: fwd = params + ckpt write; bwd = params + ckpt
    pcie_f1 = w.ms + w.cs
    pcie_b1 = w.ms + w.cs
    fwd_ssd1 = _ssd_time(w.ms * (1 - x.param), w.cs * (1 - x.ckpt), m)
    bwd_ssd1 = _ssd_time(w.ms * (1 - x.param) + w.cs * (1 - x.ckpt), 0, m)
    # grad fetch + offload ((2M-1)*2ms total ~= 2/mb): serialized
    grad_t1 = 2 * w.grad_bytes * x_g / m.pcie_bw \
        + _ssd_time(w.grad_bytes * (1 - x_g), w.grad_bytes * (1 - x_g), m)
    t_f = max(t_f1, pcie_f1 / m.pcie_bw, fwd_ssd1)
    t_b = max(t_b1, pcie_b1 / m.pcie_bw, bwd_ssd1) + grad_t1
    opt_ssd = _ssd_time(w.os_bytes * (1 - x.opt), w.os_bytes * (1 - x.opt), m)
    adam_t = (w.os_bytes + w.grad_bytes) / m.cpu_adam_bw
    opt_time = max(opt_ssd, adam_t)
    hidden = t_b if overlap_last_bwd else 0.0
    return M * (t_f + t_b) + max(0.0, opt_time - hidden)


def throughput_tokens_per_s(w: Workload, t_iter: float, M: int) -> float:
    return M * w.tokens_per_mb / t_iter


def rooflines(w: Workload, m: MachineParams, x: StorageRatios):
    """(io_roofline_tokens_per_iter_per_s_slope, compute_roofline) — Fig. 3.

    IO-access roofline: iteration time >= optimizer-state SSD traffic time,
    so throughput <= (M*tokens) / t_opt_io  (linear in batch).
    Compute roofline: throughput <= gpu_flops / flops_per_token."""
    opt_io = _ssd_time(w.os_bytes * (1 - x.opt), w.os_bytes * (1 - x.opt), m)
    flops_per_token = 4 * w.flops_per_mb / w.tokens_per_mb  # fwd+bwd+recompute
    comp = m.gpu_flops / flops_per_token
    return opt_io, comp


def mfu(w: Workload, m: MachineParams, t_iter: float, M: int,
        peak_flops: Optional[float] = None) -> float:
    total_flops = 4 * w.flops_per_mb * M
    return total_flops / (t_iter * (peak_flops or m.gpu_flops))
