"""LP-based configuration search (GreedySnake Algorithm 1).

For each (micro-batch count n, delay ratio α), a small linear program
finds the storage split x = (ckpt, param, opt) between CPU memory and SSD
that minimises effective iteration time t_f + t_b under the CPU-memory
constraint; the outer loop increases n until throughput saturates
(< 1% improvement) and records the smallest such n with its α* and x*.

Variables: x_c, x_p, x_o in [0,1] (CPU-resident fractions), t_f, t_b.
Each "t >= max(...)" from Alg. 1 becomes one linear row per term:
    t >= const - Σ coef_i x_i   <=>   -Σ coef_i x_i - t <= -const
Active constraints at the decision boundary (paper §4.5): CPU memory
capacity, GPU computation time, SSD bandwidth. Gradients are 100%
CPU-resident, as in the paper.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np
from scipy.optimize import linprog

from repro.core import traffic as tr
from repro.core.perfmodel import (MachineParams, StorageRatios, Workload,
                                  compute_times, machine_for_path_policy)

#: chunk->path placement policies the LP can price (must mirror
#: ``repro.io.config.PATH_POLICIES``; duplicated so ``repro.core``
#: stays independent of ``repro.io``)
PATH_POLICIES = ("static", "weighted", "backlog")

REG = 1e-12  # SSD-traffic regulariser (s/byte): Alg. 1's "minimise SSD
             # traffic when possible" tie-breaker


@dataclasses.dataclass(frozen=True)
class LPSolution:
    x: StorageRatios
    t_f: float
    t_b: float
    act_policy: str = "recompute"
    path_policy: str = "static"

    @property
    def iteration_time(self) -> float:
        return self.t_f + self.t_b


def solve_config(m: MachineParams, w: Workload, n: int, alpha: float,
                 num_gpus: int = 1,
                 wave: Optional[int] = None,
                 act_policy: str = "recompute",
                 lookahead: bool = True,
                 path_policy: str = "static") -> Optional[LPSolution]:
    """One LP solve for fixed (n, α).

    Return contract (the autotuner distinguishes the two): ``None``
    means STRICTLY "the LP is infeasible under these machine/workload
    constraints" — a legitimate answer a controller should score as
    "candidate unusable". Invalid ARGUMENTS (``n`` not divisible by
    ``num_gpus``, a ``wave`` under DP, ``wave`` not a divisor of
    ``n``, an unknown ``act_policy``) raise ``ValueError`` — a caller
    bug, never to be silently conflated with infeasibility.

    With ``num_gpus=R > 1`` the LP models the R-way data-parallel
    vertical schedule: ``w`` is the FULL-model workload, each rank owns
    1/R of the params / optimizer state / gradient shards and n/R of
    the micro-batches (``n`` must divide by R), ``m.cpu_mem`` is
    per-rank DRAM, and two constant interconnect rows join the stage
    lower bounds (per-layer-boundary all-gathers, f32 reduce-scatter)
    paced by ``m.interconnect_bw``.

    With ``wave=W`` (single-GPU only) the LP models the wave hybrid of
    ``repro.core.plan.compile_wave``: the parameter-load terms scale by
    ``nw = n/W``, the cross-wave f32 grad-buffer swap joins the PCIe
    rows, and — unlike vertical's ~3-layer transient — the FULL f32
    accumulation buffer stays CPU-resident across waves, tightening the
    memory row. ``wave=None`` (or ``wave == n``) is vertical.

    ``act_policy`` adds the activation-policy row: "spill" prices the
    SSDTrain-style residual stream — the backward compute bound drops
    its recompute third (``t_b1 = 2·t_f1``), the checkpoint backward
    re-read rows vanish, and the ``n·as`` residual bytes join the SSD
    write (forward) and read (backward) constants and both PCIe rows
    (the stream is fully offloaded in the LP: its priority class is the
    lowest, so it only soaks spare bandwidth — letting it compete for
    the LP's CPU budget would understate checkpoint residency).
    "auto" solves both rows and returns the faster solution, tagged in
    ``LPSolution.act_policy``.

    ``lookahead=False`` prices the hint-free executor (the default
    models the cross-stream lookahead pass): the SSD reads the hints
    overlap — the α-tail optimizer state ahead of the forward gates,
    the per-micro-batch checkpoint/residual tails ahead of each
    backward fetch — join the GPU-compute rows as serialized stall
    terms (with their x coefficients) instead of hiding under the
    stage max, mirroring ``perfmodel._lookahead_stalls``.

    ``path_policy`` prices the SSD tier's chunk-placement policy when
    ``m`` carries per-path rates (``ssd_path_read_bw`` /
    ``ssd_path_write_bw``): "static" striping runs the stripe at
    ``P x min(path_rate)``; "weighted"/"backlog" placement reaches
    ``sum(path_rates)`` (:func:`machine_for_path_policy`). Without
    per-path evidence every policy prices identically."""
    if path_policy not in PATH_POLICIES:
        raise ValueError(f"unknown path_policy {path_policy!r}")
    m = machine_for_path_policy(m, path_policy)
    if act_policy == "auto":
        sols = [solve_config(m, w, n, alpha, num_gpus=num_gpus, wave=wave,
                             act_policy=p, lookahead=lookahead,
                             path_policy=path_policy)
                for p in ("recompute", "spill")]
        sols = [s for s in sols if s is not None]
        return min(sols, key=lambda s: s.iteration_time, default=None)
    if act_policy not in ("recompute", "spill"):
        raise ValueError(f"unknown act_policy {act_policy!r}")
    spill = act_policy == "spill"
    R = int(num_gpus)
    ms_full, grad_full = w.ms, w.grad_bytes
    if R > 1:
        if n % R:
            raise ValueError(
                f"solve_config: n={n} must be divisible by num_gpus={R}")
        if wave not in (None, n):
            # DP plans are vertical (W == n)
            raise ValueError(
                f"solve_config: wave={wave} is invalid under "
                f"num_gpus={R} (DP plans are vertical; pass wave=None "
                f"or wave=n)")
        wave = None              # normalize before n is divided by R
        w = dataclasses.replace(w, ms=w.ms / R, os_bytes=w.os_bytes / R,
                                grad_bytes=w.grad_bytes / R)
        n = n // R
    W = n if wave is None else int(wave)
    if W < 1 or n % W:
        raise ValueError(
            f"solve_config: wave={W} must be a positive divisor of "
            f"n={n}")
    nw = n // W
    t_f1, t_b1 = compute_times(w, m)
    if spill:
        t_b1 = 2.0 * t_f1           # vjp only — no recompute pass
    act_b = n * w.as_bytes if spill else 0.0
    rd, wr = m.ssd_read_bw, m.ssd_write_bw
    A_ub: List[List[float]] = []
    b_ub: List[float] = []

    def add(row, b):
        A_ub.append(row)
        b_ub.append(b)

    def add_time_lb(t_idx: int, const: float, coefs=(0.0, 0.0, 0.0)):
        """t_{t_idx} >= const - coefs · x."""
        row = [-coefs[0], -coefs[1], -coefs[2], 0.0, 0.0]
        row[t_idx] = -1.0
        add(row, -const)

    # objective: minimise t_f + t_b - REG * (CPU-resident bytes)
    c = np.array([-REG * 2 * n * w.cs, -REG * 2 * w.ms,
                  -REG * 2 * w.os_bytes, 1.0, 1.0])

    # CPU memory: n*cs*x_c + ms*x_p + os*x_o + resident grads <= DRAM.
    # Vertical (nw=1) keeps only ~3 layers of gradients in flight (§4.3);
    # a multi-wave schedule parks the FULL f32 accumulation buffer in CPU
    # between waves. The α-delayed fraction reuses reclaimed param/ckpt
    # memory (§4.4), so it adds no net footprint but must FIT in that
    # reclaimed memory:  α·grad_bytes <= ms·x_p + n·cs·x_c
    grad_resident = w.grad_transient if nw == 1 else w.grad_bytes
    add([n * w.cs, w.ms, w.os_bytes, 0, 0],
        m.cpu_mem * 0.95 - grad_resident)
    add([-n * w.cs, -w.ms, 0, 0, 0], -alpha * w.grad_bytes)

    # --- forward stage lower bounds ---
    if lookahead:
        add_time_lb(3, n * t_f1)                               # GPU compute
    else:
        # hint-free: the α-tail optimizer reads serialize with compute
        # at the forward gates (PREFETCH_OPT is what overlaps them)
        add_time_lb(3, n * t_f1 + alpha * w.os_bytes / rd,
                    (0.0, 0.0, alpha * w.os_bytes / rd))
    #   SSD: reads  nw·ms(1-x_p)/rd + α·os(1-x_o)/rd
    #        writes n·cs(1-x_c)/wr + n·as/wr (spill) + α·os(1-x_o)/wr
    const_f = nw * w.ms / rd + n * w.cs / wr + act_b / wr \
        + alpha * w.os_bytes * (1 / rd + 1 / wr)
    add_time_lb(3, const_f, (n * w.cs / wr, nw * w.ms / rd,
                             alpha * w.os_bytes * (1 / rd + 1 / wr)))
    adam_t = (w.os_bytes + w.grad_bytes) / m.cpu_adam_bw
    add_time_lb(3, alpha * adam_t)                             # CPU Adam (α part)
    pc = tr.wave_traffic(w.ms, w.cs, n, W)
    pcie_fwd = nw * w.ms + (2 * n - nw) * w.cs + act_b
    add_time_lb(3, pcie_fwd / m.pcie_bw)                       # PCIe

    # --- backward stage lower bounds ---
    if lookahead:
        add_time_lb(4, n * t_b1)
    elif spill:
        # residual-tail reads serialize with backward (PREFETCH_ACT)
        add_time_lb(4, n * t_b1 + act_b / rd)
    else:
        # ckpt-tail re-reads serialize with backward (PREFETCH_CKPT)
        add_time_lb(4, n * t_b1 + n * w.cs / rd,
                    (n * w.cs / rd, 0.0, 0.0))
    #   spill: the n·cs checkpoint re-read row is replaced by the n·as
    #   residual fetch (constant — the stream is fully offloaded)
    bwd_ckpt_rd = 0.0 if spill else n * w.cs
    const_b = nw * w.ms / rd + bwd_ckpt_rd / rd + act_b / rd \
        + (1 - alpha) * w.os_bytes * (1 / rd + 1 / wr)
    add_time_lb(4, const_b, (bwd_ckpt_rd / rd, nw * w.ms / rd,
                             (1 - alpha) * w.os_bytes * (1 / rd + 1 / wr)))
    add_time_lb(4, (1 - alpha) * adam_t)
    pcie_bwd = pc.total - (nw * w.ms + (2 * n - nw) * w.cs)
    if spill:
        pcie_bwd += act_b - n * w.cs   # residual fetch replaces re-read
    add_time_lb(4, max(0.0, pcie_bwd) / m.pcie_bw)

    # --- data-parallel interconnect lower bounds (constant rows) ---
    if R > 1:
        frac = (R - 1) / R
        add_time_lb(3, frac * ms_full / m.interconnect_bw)  # fwd all-gather
        add_time_lb(4, frac * (ms_full + grad_full)         # bwd all-gather
                    / m.interconnect_bw)                    # + reduce-scatter

    bounds = [(0, 1), (0, 1), (0, 1), (0, None), (0, None)]
    res = linprog(c, A_ub=np.array(A_ub), b_ub=np.array(b_ub), bounds=bounds,
                  method="highs")
    if not res.success:
        return None
    x_c, x_p, x_o, t_f, t_b = res.x
    return LPSolution(StorageRatios(ckpt=float(x_c), param=float(x_p),
                                    opt=float(x_o)), float(t_f), float(t_b),
                      act_policy=act_policy, path_policy=path_policy)


@dataclasses.dataclass(frozen=True)
class SearchResult:
    n: int
    alpha: float
    x: StorageRatios
    iteration_time: float
    throughput_tokens_per_s: float


def find_optimal_config(m: MachineParams, w: Workload,
                        alphas=None, max_n: int = 256,
                        improve_thresh: float = 1.01,
                        num_gpus: int = 1) -> Optional[SearchResult]:
    """Algorithm 1: increase n until throughput saturates; per n pick the
    best α by grid argmax; per (n, α) solve the storage-ratio LP. With
    ``num_gpus=R`` the search steps n by R (global micro-batch counts
    that shard evenly) and solves the data-parallel LP."""
    alphas = alphas if alphas is not None else [i / 100 for i in range(0, 51)]
    best = None
    max_tp = 0.0
    n = 0
    while n < max_n:
        n += max(1, int(num_gpus))
        sols = [(a, solve_config(m, w, n, a, num_gpus=num_gpus))
                for a in alphas]
        sols = [(a, s) for a, s in sols if s is not None]
        if not sols:
            continue
        a_star, s_star = min(sols, key=lambda t: t[1].iteration_time)
        tp = n * w.tokens_per_mb / s_star.iteration_time
        if tp >= improve_thresh * max_tp:
            max_tp = tp
            best = SearchResult(n, a_star, s_star.x, s_star.iteration_time, tp)
        else:
            break
    return best
