"""The paper's primary contribution: vertical-schedule gradient
accumulation, α-delayed optimizer overlap, traffic/roofline models, and
the Algorithm-1 LP configuration search."""
from repro.core.schedules import (  # noqa: F401
    ScheduleConfig,
    grads_fn,
    init_train_state,
    make_delayed_train_step,
    make_train_step,
)
from repro.core.traffic import (  # noqa: F401
    TrafficBreakdown,
    checkpoint_bytes,
    horizontal_traffic,
    model_bytes,
    optimizer_state_bytes,
    vertical_traffic,
)
from repro.core.perfmodel import (  # noqa: F401
    MachineParams,
    StorageRatios,
    Workload,
    cpu_mem_horizontal,
    cpu_mem_vertical,
    delayed_grads_fit,
    iteration_time_horizontal,
    iteration_time_vertical,
    rooflines,
    throughput_tokens_per_s,
)
from repro.core.lp_search import (  # noqa: F401
    LPSolution,
    SearchResult,
    find_optimal_config,
    solve_config,
)
