"""Crash-consistent training checkpoints for the offload engines.

The checkpoint is the engine's FULL trainable state — per layer the
low-precision params and the f32 master/m/v optimizer vectors, plus the
device-resident embedding/head tensors, their Adam state, and
``step_num`` — exactly the state whose round-trip the plan-swap
bitwise pin established (``tests/test_autotune.py`` grew it ad hoc;
this module is its promotion). Vectors are stored ASSEMBLED (full
``P``-element vectors, not rank shards), so a checkpoint written by the
single-rank engine restores into the DP engine and vice versa: DP
sharding is contiguous (``shard_bounds``), so assembly is
concatenation and restore is slicing — both bitwise.

Crash consistency is manifest-journaled:

* every tensor is written to its own generation-stamped file
  (``<name>.g<step>.bin``, fsynced) with its CRC32C recorded;
* the manifest (``manifest.json`` — version, engine meta, per-tensor
  file/nbytes/dtype/shape/crc) is written LAST via temp + rename +
  fsync: the checkpoint EXISTS only once the manifest commits, and a
  crash mid-save leaves the previous manifest pointing at the previous
  generation's files, which are garbage-collected only AFTER the new
  manifest is durable;
* restore reads and CRC-verifies every tensor BEFORE mutating any
  engine state (all-or-nothing): a torn manifest, a missing/short/
  corrupt tensor file, or meta that doesn't match the engine (L, P,
  param dtype) raises :class:`CheckpointError` and leaves the engine
  exactly as it was.

Restore quiesces first (``finish()`` + the same coordinator
clear as the plan-swap seam) so no in-flight spill or armed α gate can
interleave with the state writes, then writes through
``TieredVector.write_full`` — unmetered, like initialization, so a
restore perturbs no traffic accounting.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Iterator, Tuple

import numpy as np

from repro.io.integrity import crc32c

CKPT_VERSION = 1
MANIFEST = "manifest.json"


class CheckpointError(IOError):
    """The checkpoint is unusable — torn/missing manifest, corrupt or
    missing tensor bytes, or meta that doesn't match the engine. Raised
    BEFORE any engine state is mutated."""


def _fname(name: str, gen: int) -> str:
    return name.replace(":", "_").replace("/", "_") + f".g{gen}.bin"


def _is_dp(eng) -> bool:
    return hasattr(eng, "ranks")


def _assemble(eng, attr: str, l: int, dtype) -> np.ndarray:
    """Layer ``l``'s full vector from ``attr`` (``p_vecs``/``m_master``/
    ``m_m``/``m_v``), concatenating rank shards on the DP engine."""
    if _is_dp(eng):
        out = np.empty(eng.P, dtype)
        for rk, (lo, hi) in zip(eng.ranks, eng.bounds):
            out[lo:hi] = getattr(rk, attr)[l].read()
        return out
    return np.asarray(getattr(eng, attr)[l].read(), dtype).copy()


_VEC_ATTRS = (("p", "p_vecs"), ("master", "m_master"),
              ("m", "m_m"), ("v", "m_v"))
_HEAD_TENSORS = ("embed", "unembed", "final_norm")


def _state_items(eng) -> Iterator[Tuple[str, np.ndarray]]:
    pdt = np.dtype(eng.ocfg.param_dtype)
    for l in range(eng.L):
        for key, attr in _VEC_ATTRS:
            dt = pdt if key == "p" else np.float32
            yield f"{key}:{l}", _assemble(eng, attr, l, dt)
    for t in _HEAD_TENSORS:
        yield t, np.asarray(eng.__dict__[t])
        for k in ("m", "v"):
            yield f"head:{t}:{k}", np.asarray(eng.head_state[t][k])


def _expected_names(L: int):
    names = {f"{key}:{l}" for key, _ in _VEC_ATTRS for l in range(L)}
    for t in _HEAD_TENSORS:
        names.add(t)
        names.update({f"head:{t}:m", f"head:{t}:v"})
    return names


def _quiesce(eng):
    """Drain every stream and drop per-plan residue — the plan-swap
    seam's contract, so restored state can't race in-flight I/O.
    ``finish()`` is best-effort: when restoring after a FAILED step its
    flushes may re-raise that step's fault, but the restore is about to
    overwrite all state anyway — the coordinator clears below make the
    engine quiet regardless."""
    try:
        eng.finish()
    except Exception:
        pass
    stacks = eng.ranks if _is_dp(eng) else (eng,)
    for s in stacks:
        s.params_c.reset()
        s.params_c.clear_gates()
        s.ckpt_c.clear()
        s.act_c.clear()
        s.opt_c.clear()


def save_checkpoint(eng, directory: str) -> str:
    """Write a crash-consistent checkpoint of ``eng`` into ``directory``
    and return the committed manifest path. Non-destructive: training
    can continue on the same engine afterwards."""
    eng.finish()            # α tails flushed => vectors are authoritative
    os.makedirs(directory, exist_ok=True)
    gen = int(eng.step_num)
    tensors: Dict[str, dict] = {}
    for name, arr in _state_items(eng):
        arr = np.ascontiguousarray(arr)
        data = arr.tobytes()
        fn = _fname(name, gen)
        with open(os.path.join(directory, fn), "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        tensors[name] = {"file": fn, "nbytes": len(data),
                         "dtype": str(arr.dtype),
                         "shape": list(arr.shape),
                         "crc32c": crc32c(data)}
    doc = {"version": CKPT_VERSION,
           "meta": {"L": int(eng.L), "P": int(eng.P), "step_num": gen,
                    "param_dtype": str(np.dtype(eng.ocfg.param_dtype)),
                    "arch": getattr(eng.cfg, "name", ""),
                    "ranks": int(getattr(eng, "R", 1))},
           "tensors": tensors}
    target = os.path.join(directory, MANIFEST)
    tmp = target + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, target)
    # only now — with the new manifest durable — drop files the
    # previous generation's manifest referenced
    keep = {spec["file"] for spec in tensors.values()}
    for fn in os.listdir(directory):
        if fn.endswith(".bin") and fn not in keep:
            try:
                os.unlink(os.path.join(directory, fn))
            except FileNotFoundError:
                pass
    return target


def load_manifest(directory: str) -> dict:
    """Parse and structurally validate the committed manifest (no
    tensor reads). Raises :class:`CheckpointError` on a missing, torn,
    or wrong-version manifest."""
    mp = os.path.join(directory, MANIFEST)
    try:
        with open(mp) as f:
            doc = json.load(f)
    except FileNotFoundError:
        raise CheckpointError(f"no checkpoint manifest at {mp}")
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise CheckpointError(
            f"torn or corrupt checkpoint manifest at {mp}: {e}")
    if doc.get("version") != CKPT_VERSION:
        raise CheckpointError(
            f"checkpoint manifest version {doc.get('version')!r} != "
            f"{CKPT_VERSION}")
    if not isinstance(doc.get("tensors"), dict) \
            or not isinstance(doc.get("meta"), dict):
        raise CheckpointError(
            f"checkpoint manifest at {mp} is structurally invalid")
    return doc


def restore_checkpoint(eng, directory: str) -> int:
    """Restore ``eng`` from the checkpoint in ``directory`` and return
    the restored ``step_num``. All tensor bytes are read and
    CRC-verified before any engine state is touched; the restored
    trajectory is bitwise (f32) — the plan-swap pin, now through disk.
    """
    doc = load_manifest(directory)
    meta = doc["meta"]
    pdt = str(np.dtype(eng.ocfg.param_dtype))
    for key, have in (("L", int(eng.L)), ("P", int(eng.P)),
                      ("param_dtype", pdt)):
        if meta.get(key) != have:
            raise CheckpointError(
                f"checkpoint meta mismatch: {key}={meta.get(key)!r} "
                f"but this engine has {key}={have!r}")
    missing = _expected_names(eng.L) - set(doc["tensors"])
    if missing:
        raise CheckpointError(
            f"checkpoint is missing tensors: {sorted(missing)[:4]}...")
    arrays: Dict[str, np.ndarray] = {}
    for name, spec in doc["tensors"].items():
        fp = os.path.join(directory, spec["file"])
        try:
            with open(fp, "rb") as f:
                data = f.read()
        except FileNotFoundError:
            raise CheckpointError(
                f"checkpoint tensor file missing: {fp}")
        if len(data) != int(spec["nbytes"]):
            raise CheckpointError(
                f"torn checkpoint tensor {name!r}: {len(data)}/"
                f"{spec['nbytes']} bytes")
        if crc32c(data) != int(spec["crc32c"]):
            raise CheckpointError(
                f"corrupt checkpoint tensor {name!r}: CRC32C mismatch")
        arrays[name] = np.frombuffer(
            data, dtype=np.dtype(spec["dtype"])).reshape(
                spec["shape"]).copy()
    # everything verified — now (and only now) mutate the engine
    import jax.numpy as jnp
    _quiesce(eng)
    dp = _is_dp(eng)
    for l in range(eng.L):
        for key, attr in _VEC_ATTRS:
            arr = arrays[f"{key}:{l}"]
            if dp:
                for rk, (lo, hi) in zip(eng.ranks, eng.bounds):
                    getattr(rk, attr)[l].write_full(arr[lo:hi])
            else:
                getattr(eng, attr)[l].write_full(arr)
    for t in _HEAD_TENSORS:
        setattr(eng, t, jnp.asarray(arrays[t]))
    eng.head_state = {t: {k: jnp.asarray(arrays[f"head:{t}:{k}"])
                          for k in ("m", "v")}
                      for t in _HEAD_TENSORS}
    eng.step_num = int(meta["step_num"])
    return eng.step_num
