"""Three-tier tensor storage: device (jax) / host (numpy) / SSD (files).

On this container the "GPU" tier is the jax CPU device and the SSD tier is
the filesystem — the data movement, byte counters, and thread-overlap
structure are real; only the device arithmetic rate differs from the
paper's A100s. All traffic is metered by category so the engine's counters
can be validated against the closed-form model in repro.core.traffic.

All SSD bytes move through :class:`repro.io.IOEngine`: chunked,
priority-scheduled, striped across the engine's configured paths, and
optionally bandwidth-paced. ``SSDStore`` is the tensor-naming layer on
top (shapes/dtypes, metering, async spills via the staging pool).
"""
from __future__ import annotations

import threading
from collections import defaultdict
from concurrent.futures import CancelledError
from typing import Dict, Optional, Tuple

import numpy as np

from repro.io import (CATEGORY_PRIORITY, IOConfig, IOEngine, IOPriority,
                      IORequest, StripedFiles)


class TrafficMeter:
    """Byte counters keyed by (category, route)."""

    def __init__(self):
        self._lock = threading.Lock()
        self.bytes: Dict[Tuple[str, str], int] = defaultdict(int)

    def add(self, category: str, route: str, n: int):
        with self._lock:
            self.bytes[(category, route)] += int(n)

    def total(self, route_prefix: str = "") -> int:
        return sum(v for (c, r), v in self.bytes.items()
                   if r.startswith(route_prefix))

    def by_category(self) -> Dict[str, int]:
        out: Dict[str, int] = defaultdict(int)
        for (c, r), v in self.bytes.items():
            out[c] += v
        return dict(out)

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return {f"{c}:{r}": v for (c, r), v in sorted(self.bytes.items())}

    def reset(self):
        with self._lock:
            self.bytes.clear()


def _u8(arr: np.ndarray) -> np.ndarray:
    """Flat uint8 view of a contiguous array (no copy)."""
    return arr.reshape(-1).view(np.uint8)


def _priority(category: str) -> IOPriority:
    return CATEGORY_PRIORITY.get(category, IOPriority.CKPT_SPILL)


class SSDStore:
    """Named flat tensors on SSD, striped across the I/O engine's paths.

    Overwrites must keep a tensor's byte size (partial updates go through
    ``write_range``); the offload engine's tensors are all fixed-size.
    """

    def __init__(self, root: str, meter: TrafficMeter,
                 engine: Optional[IOEngine] = None,
                 chunk_bytes: Optional[int] = None):
        self.root = root
        self.meter = meter
        if engine is None:
            cfg = IOConfig(paths=[root]) if chunk_bytes is None else \
                IOConfig(paths=[root], chunk_bytes=chunk_bytes)
            engine = IOEngine(cfg, meter=meter)
            self._owns_engine = True
        else:
            self._owns_engine = False
        self.engine = engine
        self.files = StripedFiles(engine)
        self._shapes: Dict[str, Tuple[tuple, np.dtype]] = {}
        self._async_reqs: set = set()
        self._async_lock = threading.Lock()

    def _meta(self, name: str) -> Tuple[tuple, np.dtype]:
        try:
            return self._shapes[name]
        except KeyError:
            raise KeyError(
                f"SSDStore: no tensor named {name!r} is registered "
                f"({len(self._shapes)} known names)") from None

    def write(self, name: str, arr: np.ndarray, category: str,
              metered: bool = True):
        arr = np.ascontiguousarray(arr)
        self.files.write(name, _u8(arr), 0, _priority(category))
        self._shapes[name] = (arr.shape, arr.dtype)
        if metered:
            self.meter.add(category, "cpu->ssd", arr.nbytes)

    def write_async(self, name: str, arr: np.ndarray, category: str
                    ) -> IORequest:
        """Stage ``arr`` into the double-buffered host pool and schedule
        the (chunked, striped) write; the caller's buffer is free as soon
        as this returns. Wait on the returned request before reading."""
        arr = np.ascontiguousarray(arr)
        staged = self.engine.staging.acquire(arr.nbytes)
        np.copyto(staged.view, _u8(arr))
        self._shapes[name] = (arr.shape, arr.dtype)
        pri = _priority(category)
        nbytes = arr.nbytes

        def work():
            try:
                self.files.write(name, staged.view, 0, pri)
                self.meter.add(category, "cpu->ssd", nbytes)
            finally:
                staged.release()

        req = self.engine.submit(work, priority=pri, category=category,
                                 route="cpu->ssd", nbytes=nbytes)
        with self._async_lock:
            self._async_reqs.add(req)

        def _done(f):
            # a cancelled spill never runs `work`; don't leak the slot
            if f.cancelled():
                staged.release()
            with self._async_lock:
                self._async_reqs.discard(req)

        req.future.add_done_callback(_done)
        return req

    def read(self, name: str, category: str, out: Optional[np.ndarray] = None
             ) -> np.ndarray:
        shape, dtype = self._meta(name)
        nbytes = int(np.prod(shape)) * dtype.itemsize
        pri = _priority(category)
        if out is not None and out.flags.c_contiguous and out.nbytes == nbytes:
            self.files.readinto(name, _u8(out), 0, pri)
            self.meter.add(category, "ssd->cpu", nbytes)
            return out
        arr = np.empty(shape, dtype)
        self.files.readinto(name, _u8(arr), 0, pri)
        self.meter.add(category, "ssd->cpu", nbytes)
        if out is not None:
            np.copyto(out, arr)
            return out
        return arr

    def read_range(self, name: str, lo: int, hi: int, category: str,
                   out: Optional[np.ndarray] = None) -> np.ndarray:
        """Partial read of elements [lo, hi) — only the needed fraction
        touches the SSD paths (the paper's chunked optimizer I/O; the
        data-parallel engine's rank-shard fetches). With a contiguous
        ``out`` of the right size the chunk ops land directly in the
        caller's buffer (no intermediate allocation)."""
        _, dtype = self._meta(name)
        n = hi - lo
        if out is not None and out.flags.c_contiguous \
                and out.size == n and out.dtype == dtype:
            arr = out
        else:
            arr = np.empty(n, dtype)
        self.files.readinto(name, _u8(arr), lo * dtype.itemsize,
                            _priority(category))
        self.meter.add(category, "ssd->cpu", arr.nbytes)
        if out is not None and arr is not out:
            np.copyto(out, arr)
            return out
        return arr

    def write_range(self, name: str, arr: np.ndarray, lo: int,
                    category: str):
        """Partial in-place write of elements [lo, lo+len)."""
        _, dtype = self._meta(name)
        arr = np.ascontiguousarray(arr, dtype=dtype)
        self.files.write(name, _u8(arr), lo * dtype.itemsize,
                         _priority(category))
        self.meter.add(category, "cpu->ssd", arr.nbytes)

    def delete(self, name: str):
        """Remove a tensor's stripe files and registration."""
        self._meta(name)
        self.files.delete(name)
        del self._shapes[name]

    def clear(self):
        """Delete every registered tensor's files (workdir cleanup)."""
        for name in list(self._shapes):
            self.delete(name)

    def exists(self, name: str) -> bool:
        return name in self._shapes

    def nbytes(self) -> int:
        return sum(int(np.prod(s)) * d.itemsize
                   for s, d in self._shapes.values())

    def close(self):
        # Drain async spills first: a spill still queued when clear()
        # unlinks the stripe files would recreate them via O_CREAT.
        with self._async_lock:
            pending = list(self._async_reqs)
        for req in pending:
            try:
                req.result()
            except CancelledError:
                pass
        self.clear()
        self.files.close()
        if self._owns_engine:
            self.engine.shutdown(wait=True)


class HostStore:
    """Host ("pinned") buffers. Tracks resident bytes — the CPU-memory
    budget the LP of Algorithm 1 constrains — and the peak residency
    (``peak_nbytes``), updated on every put, for validating the vertical
    schedule's footprint against the LP solution."""

    def __init__(self, meter: TrafficMeter):
        self.meter = meter
        self._bufs: Dict[str, np.ndarray] = {}
        self._lock = threading.Lock()
        self._nbytes = 0
        self.peak_nbytes = 0

    def put(self, name: str, arr: np.ndarray):
        with self._lock:
            old = self._bufs.get(name)
            if old is not None:
                self._nbytes -= old.nbytes
            self._bufs[name] = arr
            self._nbytes += arr.nbytes
            if self._nbytes > self.peak_nbytes:
                self.peak_nbytes = self._nbytes

    def get(self, name: str) -> np.ndarray:
        return self._bufs[name]

    def pop(self, name: str) -> np.ndarray:
        with self._lock:
            arr = self._bufs.pop(name)
            self._nbytes -= arr.nbytes
        return arr

    def __contains__(self, name: str) -> bool:
        return name in self._bufs

    def nbytes(self) -> int:
        return self._nbytes


class TieredVector:
    """A flat 1-D tensor split between host memory and SSD by a ratio
    x in [0,1] (fraction host-resident): elements [0, k) live in host,
    [k, n) on SSD — the paper's per-data-type storage ratio. SSD bytes
    move as chunked engine requests at the priority of ``category``."""

    def __init__(self, name: str, n: int, dtype, x_host: float,
                 host: HostStore, ssd: SSDStore, category: str):
        self.name = name
        self.n = n
        self.dtype = np.dtype(dtype)
        self.k = int(round(x_host * n))
        self.host = host
        self.ssd = ssd
        self.category = category

    def write_full(self, arr: np.ndarray):
        """Initial population (not counted as training traffic)."""
        assert arr.shape == (self.n,) and arr.dtype == self.dtype
        if self.k:
            self.host.put(self.name + ":h", arr[:self.k].copy())
        if self.k < self.n:
            self.ssd.write(self.name + ":s", arr[self.k:], self.category,
                           metered=False)

    def read(self, out: Optional[np.ndarray] = None) -> np.ndarray:
        """Assemble the full vector; SSD portion is metered."""
        if out is None:
            out = np.empty((self.n,), self.dtype)
        if self.k:
            np.copyto(out[:self.k], self.host.get(self.name + ":h"))
        if self.k < self.n:
            self.ssd.read(self.name + ":s", self.category, out=out[self.k:])
        return out

    def write(self, arr: np.ndarray, lo: int = 0, hi: Optional[int] = None):
        """Write back elements [lo, hi); SSD portion is metered."""
        hi = self.n if hi is None else hi
        if lo < self.k:
            h = min(hi, self.k)
            np.copyto(self.host.get(self.name + ":h")[lo:h], arr[lo:h])
        if hi > self.k:
            lo_s = max(lo, self.k)
            if lo_s == self.k and hi == self.n:
                self.ssd.write(self.name + ":s", arr[self.k:], self.category)
            else:
                # partial SSD write: only [lo_s, hi) touches disk
                self.ssd.write_range(self.name + ":s",
                                     arr[lo_s:hi], lo_s - self.k,
                                     self.category)

    def write_seg(self, data: np.ndarray, lo: int):
        """Write back the segment [lo, lo+len(data)) given only the
        segment's data (no full-size staging buffer needed)."""
        hi = lo + data.size
        if lo < self.k:
            h = min(hi, self.k)
            np.copyto(self.host.get(self.name + ":h")[lo:h], data[:h - lo])
        if hi > self.k:
            lo_s = max(lo, self.k)
            self.ssd.write_range(self.name + ":s", data[lo_s - lo:],
                                 lo_s - self.k, self.category)

    def read_range(self, lo: int, hi: int, out: Optional[np.ndarray] = None
                   ) -> np.ndarray:
        if out is None:
            out = np.empty((hi - lo,), self.dtype)
        if lo < self.k:
            h = min(hi, self.k)
            np.copyto(out[:h - lo], self.host.get(self.name + ":h")[lo:h])
        if hi > self.k:
            lo_s = max(lo, self.k)
            self.ssd.read_range(self.name + ":s", lo_s - self.k,
                                hi - self.k, self.category,
                                out=out[lo_s - lo:])
        return out
