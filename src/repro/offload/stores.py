"""Three-tier tensor storage: device (jax) / host (numpy) / SSD (files).

On this container the "GPU" tier is the jax CPU device and the SSD tier is
the filesystem — the data movement, byte counters, and thread-overlap
structure are real; only the device arithmetic rate differs from the
paper's A100s. All traffic is metered by category so the engine's counters
can be validated against the closed-form model in repro.core.traffic.
"""
from __future__ import annotations

import os
import threading
from collections import defaultdict
from typing import Dict, Optional, Tuple

import numpy as np


class TrafficMeter:
    """Byte counters keyed by (category, route)."""

    def __init__(self):
        self._lock = threading.Lock()
        self.bytes: Dict[Tuple[str, str], int] = defaultdict(int)

    def add(self, category: str, route: str, n: int):
        with self._lock:
            self.bytes[(category, route)] += int(n)

    def total(self, route_prefix: str = "") -> int:
        return sum(v for (c, r), v in self.bytes.items()
                   if r.startswith(route_prefix))

    def by_category(self) -> Dict[str, int]:
        out: Dict[str, int] = defaultdict(int)
        for (c, r), v in self.bytes.items():
            out[c] += v
        return dict(out)

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return {f"{c}:{r}": v for (c, r), v in sorted(self.bytes.items())}

    def reset(self):
        with self._lock:
            self.bytes.clear()


class SSDStore:
    """Flat binary files, one per tensor name."""

    def __init__(self, root: str, meter: TrafficMeter):
        self.root = root
        self.meter = meter
        os.makedirs(root, exist_ok=True)
        self._shapes: Dict[str, Tuple[tuple, np.dtype]] = {}

    def _path(self, name: str) -> str:
        return os.path.join(self.root, name.replace("/", "_") + ".bin")

    def write(self, name: str, arr: np.ndarray, category: str):
        arr = np.ascontiguousarray(arr)
        arr.tofile(self._path(name))
        self._shapes[name] = (arr.shape, arr.dtype)
        self.meter.add(category, "cpu->ssd", arr.nbytes)

    def read(self, name: str, category: str, out: Optional[np.ndarray] = None
             ) -> np.ndarray:
        shape, dtype = self._shapes[name]
        arr = np.fromfile(self._path(name), dtype=dtype).reshape(shape)
        self.meter.add(category, "ssd->cpu", arr.nbytes)
        if out is not None:
            np.copyto(out, arr)
            return out
        return arr

    def read_range(self, name: str, lo: int, hi: int, category: str
                   ) -> np.ndarray:
        """Partial read of elements [lo, hi) via seek — only the needed
        fraction touches the device (the paper's chunked optimizer I/O)."""
        _, dtype = self._shapes[name]
        with open(self._path(name), "rb") as f:
            f.seek(lo * dtype.itemsize)
            arr = np.fromfile(f, dtype=dtype, count=hi - lo)
        self.meter.add(category, "ssd->cpu", arr.nbytes)
        return arr

    def write_range(self, name: str, arr: np.ndarray, lo: int,
                    category: str):
        """Partial in-place write of elements [lo, lo+len) via seek."""
        _, dtype = self._shapes[name]
        arr = np.ascontiguousarray(arr, dtype=dtype)
        with open(self._path(name), "r+b") as f:
            f.seek(lo * dtype.itemsize)
            f.write(arr.tobytes())
        self.meter.add(category, "cpu->ssd", arr.nbytes)

    def exists(self, name: str) -> bool:
        return name in self._shapes

    def nbytes(self) -> int:
        return sum(int(np.prod(s)) * d.itemsize
                   for s, d in self._shapes.values())


class HostStore:
    """Host ("pinned") buffers. Tracks resident bytes — the CPU-memory
    budget the LP of Algorithm 1 constrains."""

    def __init__(self, meter: TrafficMeter):
        self.meter = meter
        self._bufs: Dict[str, np.ndarray] = {}

    def put(self, name: str, arr: np.ndarray):
        self._bufs[name] = arr

    def get(self, name: str) -> np.ndarray:
        return self._bufs[name]

    def pop(self, name: str) -> np.ndarray:
        return self._bufs.pop(name)

    def __contains__(self, name: str) -> bool:
        return name in self._bufs

    def nbytes(self) -> int:
        return sum(a.nbytes for a in self._bufs.values())


class TieredVector:
    """A flat 1-D tensor split between host memory and SSD by a ratio
    x in [0,1] (fraction host-resident): elements [0, k) live in host,
    [k, n) on SSD — the paper's per-data-type storage ratio."""

    def __init__(self, name: str, n: int, dtype, x_host: float,
                 host: HostStore, ssd: SSDStore, category: str):
        self.name = name
        self.n = n
        self.dtype = np.dtype(dtype)
        self.k = int(round(x_host * n))
        self.host = host
        self.ssd = ssd
        self.category = category

    def write_full(self, arr: np.ndarray):
        """Initial population (not counted as training traffic)."""
        assert arr.shape == (self.n,) and arr.dtype == self.dtype
        if self.k:
            self.host.put(self.name + ":h", arr[:self.k].copy())
        if self.k < self.n:
            sub = arr[self.k:]
            sub.tofile(self.ssd._path(self.name + ":s"))
            self.ssd._shapes[self.name + ":s"] = (sub.shape, sub.dtype)

    def read(self, out: Optional[np.ndarray] = None) -> np.ndarray:
        """Assemble the full vector; SSD portion is metered."""
        if out is None:
            out = np.empty((self.n,), self.dtype)
        if self.k:
            np.copyto(out[:self.k], self.host.get(self.name + ":h"))
        if self.k < self.n:
            self.ssd.read(self.name + ":s", self.category, out=out[self.k:])
        return out

    def write(self, arr: np.ndarray, lo: int = 0, hi: Optional[int] = None):
        """Write back elements [lo, hi); SSD portion is metered."""
        hi = self.n if hi is None else hi
        if lo < self.k:
            h = min(hi, self.k)
            np.copyto(self.host.get(self.name + ":h")[lo:h], arr[lo:h])
        if hi > self.k:
            lo_s = max(lo, self.k)
            if lo_s == self.k and hi == self.n:
                sub = np.ascontiguousarray(arr[self.k:])
                sub.tofile(self.ssd._path(self.name + ":s"))
                self.meter_write(sub.nbytes)
            else:
                # partial SSD write: seek-based, only [lo_s, hi) touches disk
                self.ssd.write_range(self.name + ":s",
                                     arr[lo_s:hi], lo_s - self.k,
                                     self.category)

    def write_seg(self, data: np.ndarray, lo: int):
        """Write back the segment [lo, lo+len(data)) given only the
        segment's data (no full-size staging buffer needed)."""
        hi = lo + data.size
        if lo < self.k:
            h = min(hi, self.k)
            np.copyto(self.host.get(self.name + ":h")[lo:h], data[:h - lo])
        if hi > self.k:
            lo_s = max(lo, self.k)
            self.ssd.write_range(self.name + ":s", data[lo_s - lo:],
                                 lo_s - self.k, self.category)

    def read_range(self, lo: int, hi: int, out: Optional[np.ndarray] = None
                   ) -> np.ndarray:
        if out is None:
            out = np.empty((hi - lo,), self.dtype)
        if lo < self.k:
            h = min(hi, self.k)
            np.copyto(out[:h - lo], self.host.get(self.name + ":h")[lo:h])
        if hi > self.k:
            lo_s = max(lo, self.k)
            seg = self.ssd.read_range(self.name + ":s", lo_s - self.k,
                                      hi - self.k, self.category)
            np.copyto(out[lo_s - lo:], seg)
        return out

    def meter_write(self, n: int):
        self.ssd.meter.add(self.category, "cpu->ssd", n)
