"""Data-parallel sharded offload: R rank workers × R SSD path sets.

ZeRO-style partitioned offload (the layout GreedySnake's multi-GPU
baseline uses, and the one its 4-GPU result beats by scheduling): every
tiered vector — low-precision params, master, momentum, variance — is
split into R contiguous element ranges. Rank ``r`` owns range
``[lo_r, hi_r)`` of every layer's vectors, keeps it on its OWN
``IOEngine`` + SSD path set (``IOConfig.shard_for_rank``), and runs the
α-delayed partial Adam on only that shard, so R ranks drive R× the
aggregate storage bandwidth.

The schedule itself is not re-derived here: ``repro.core.plan``
compiles ONE data-parallel vertical plan (``ALLGATHER`` /
``REDUCE_SCATTER`` ops in place of the single-rank ``FETCH_PARAM`` /
``WRITEBACK_GRAD``; per-micro-batch ops emitted rank-major, each rank's
block consuming the global §4.2 alternating order restricted to it, so
every rank's boundary micro-batch keeps its device slot), and the same
``repro.offload.executor`` that drives the single-rank engine walks it
against this engine's per-rank coordinator stacks. Per iteration:

* rank ``r`` runs micro-batches ``[r·M/R, (r+1)·M/R)``;
* **ALLGATHER(l)**: the low-precision param shards at each layer
  boundary (each rank reads ``1/R`` of the layer from its own SSD
  paths — the per-rank reads are prefetched on all R engines before
  any is awaited, which is where the aggregate-bandwidth win comes
  from);
* **REDUCE_SCATTER(l)**: each fully-accumulated f32 layer gradient is
  folded in GLOBAL micro-batch order and every rank updates only its
  optimizer-state shard.

Determinism (§6.5, extended across the data-parallel axis): the
simulated collectives fold contributions in GLOBAL micro-batch order —
the exact fold the single-rank engine performs — and element-range
slicing commutes bitwise with every elementwise op involved (gradient
accumulation, Adam). An R-rank run is therefore **bit-identical (f32)**
to the single-rank ``OffloadEngine``; a real deployment gets the same
property from deterministic (rank-ordered ring) NCCL reductions.

Metering: each rank has its own ``TrafficMeter``. Collective traffic
uses routes ``"gpu->net"`` / ``"net->gpu"`` with ring costs — per rank
and direction, ``(R-1)/R`` of the buffer (categories ``"param"`` for
the all-gather, ``"grad"`` for the reduce-scatter, ``"head_grad"`` for
the replicated embedding/head all-reduce, which the paper's per-layer
pipeline excludes, §4.5). Closed forms:
:func:`repro.core.traffic.dp_vertical_traffic`; the per-rank counters
are validated against them exactly in the DP test battery.
"""
from __future__ import annotations

import dataclasses
import os
from collections import defaultdict
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import (PlanSpec, compile_vertical, insert_prefetch,
                             mb_order, shard_bounds)
from repro.io import IOConfig, IOEngine
from repro.io.config import PATH_POLICIES
from repro.models import blocks as blk
from repro.offload.coordinators import (ActivationCoordinator,
                                        InterLayerTensorCoordinator,
                                        OptimizerStepCoordinator,
                                        ParameterCoordinator)
from repro.offload.engine import (OffloadConfig, _flatten_tree,
                                  _make_unflatten, act_residual_nbytes,
                                  bind_block_fns, build_block_fns,
                                  lookahead_stats,
                                  reset_lookahead_stats,
                                  resolve_activation_policy,
                                  shifted_labels, split_microbatches)
from repro.offload.executor import execute_plan
from repro.offload.stores import (HostStore, SSDStore, TieredVector,
                                  TrafficMeter)
from repro.optim.cpu_adam import CpuAdam

__all__ = ["DataParallelOffloadEngine", "shard_bounds"]


class _Rank:
    """One data-parallel rank: its own meter/host/engine/SSD stack, its
    contiguous shard of every tiered vector, and the three coordinators
    rebound to that shard-local storage."""

    def __init__(self, index: int, world: int, root: str,
                 iocfg: IOConfig, ocfg: OffloadConfig, tracer=None):
        self.index = index
        self.world = world
        self.root = root
        self.meter = TrafficMeter()
        self.host = HostStore(self.meter)
        # same worker floor as the single-rank engine: a gated param
        # fetch may wait on an optimizer request (α-delay ordering)
        if iocfg.workers < 3:
            iocfg = dataclasses.replace(iocfg, workers=3)
        # the tracer is SHARED across ranks (one timeline); the label
        # keeps each rank's worker threads on distinct trace tracks
        self.ioe = IOEngine(iocfg, meter=self.meter, default_root=root,
                            tracer=tracer, label=f"rank{index}-")
        self.ssd = SSDStore(root, self.meter, engine=self.ioe)
        self.p_vecs: List[TieredVector] = []
        self.m_master: List[TieredVector] = []
        self.m_m: List[TieredVector] = []
        self.m_v: List[TieredVector] = []
        # coordinators are attached by the engine once shards exist
        self.params_c: Optional[ParameterCoordinator] = None
        self.ckpt_c: Optional[InterLayerTensorCoordinator] = None
        self.opt_c: Optional[OptimizerStepCoordinator] = None
        self.act_c: Optional[ActivationCoordinator] = None

    def close(self):
        self.params_c.reset()
        self.ckpt_c.wait_pending()
        self.act_c.wait_pending()
        self.opt_c.wait_all()
        self.ssd.close()
        self.ioe.shutdown(wait=True)


class DataParallelOffloadEngine:
    """R-rank data-parallel version of :class:`OffloadEngine` (vertical
    schedule only). Same constructor contract plus ``ranks``; per-rank
    SSD paths come from ``ocfg.io`` partitioned by
    ``IOConfig.shard_for_rank`` (default: ``<workdir>/rank<r>``)."""

    def __init__(self, cfg, ocfg: OffloadConfig, key, workdir: str,
                 ranks: int = 2):
        assert cfg.family in ("dense",), "engine drives homogeneous GPT stacks"
        assert ocfg.schedule == "vertical", \
            "data-parallel offload implements the vertical schedule"
        plan = blk.build_plan(cfg)
        assert len(plan.period) == 1 and not plan.prefix and not plan.suffix
        M = ocfg.num_microbatches
        if M % ranks:
            raise ValueError(
                f"num_microbatches={M} must divide evenly across "
                f"{ranks} ranks (uneven sharding is a ROADMAP follow-on)")
        self.cfg = cfg
        self.ocfg = ocfg
        self.kind = plan.period[0]
        self.L = cfg.num_layers
        self.R = ranks
        self.Mr = M // ranks
        self.dtype = jnp.dtype(ocfg.param_dtype)
        self.step_num = 0
        self._closed = False
        self.phase_time: Dict[str, float] = {"fwd": 0.0, "bwd": 0.0,
                                             "opt_wait": 0.0}

        base_io = ocfg.io if ocfg.io is not None else \
            IOConfig(workers=ocfg.io_workers)
        from repro.obs import Tracer
        self.tracer = Tracer()
        if ocfg.trace:
            self.tracer.enable()
        self.ranks: List[_Rank] = [
            _Rank(r, ranks, os.path.join(workdir, f"rank{r}"),
                  base_io.shard_for_rank(r, ranks), ocfg,
                  tracer=self.tracer)
            for r in range(ranks)]

        # ---- init params layerwise, identical key-split to the
        # single-rank engine, each rank persisting only its shard ----
        keys = jax.random.split(key, self.L + 1)
        x = ocfg.ratios
        tmpl = None
        for l in range(self.L):
            lp = blk.block_init(keys[l], cfg, self.kind, dtype=self.dtype)
            flat, treedef, shapes = _flatten_tree(lp)
            flat = flat.astype(ocfg.param_dtype)
            if tmpl is None:
                tmpl = (treedef, shapes)
                self.P = flat.size
                self.bounds = shard_bounds(self.P, ranks)
            f32 = flat.astype(np.float32)
            for rk, (lo, hi) in zip(self.ranks, self.bounds):
                n_r = hi - lo
                pv = TieredVector(f"param:{l}", n_r, ocfg.param_dtype,
                                  x.param, rk.host, rk.ssd, "param")
                pv.write_full(flat[lo:hi])
                rk.p_vecs.append(pv)
                for name, lst, init in (
                        ("master", rk.m_master, f32[lo:hi]),
                        ("m", rk.m_m, np.zeros(n_r, np.float32)),
                        ("v", rk.m_v, np.zeros(n_r, np.float32))):
                    tv = TieredVector(f"{name}:{l}", n_r, np.float32,
                                      x.opt, rk.host, rk.ssd, "opt")
                    tv.write_full(init)
                    lst.append(tv)
        self._unflatten = _make_unflatten(tmpl[0], tmpl[1], self.dtype)

        # embedding / head replicated on every (simulated) device; one
        # copy suffices because all ranks apply identical reduced grads
        from repro.models.common import embed_init, init_rms_scale
        ek = jax.random.split(keys[self.L], 2)
        self.embed = embed_init(ek[0], cfg.padded_vocab, cfg.d_model,
                                self.dtype)
        self.unembed = embed_init(ek[1], cfg.padded_vocab, cfg.d_model,
                                  self.dtype).T
        self.final_norm = init_rms_scale(cfg.d_model)
        self.head_state = {
            t: {"m": jnp.zeros_like(getattr(self, t), dtype=jnp.float32),
                "v": jnp.zeros_like(getattr(self, t), dtype=jnp.float32)}
            for t in ("embed", "unembed", "final_norm")}

        for rk in self.ranks:
            rk.params_c = ParameterCoordinator(rk.p_vecs, rk.meter, rk.ioe)
            rk.ckpt_c = InterLayerTensorCoordinator(
                x.ckpt, rk.host, rk.ssd, rk.meter, rk.ioe)
            rk.opt_c = OptimizerStepCoordinator(
                rk.m_master, rk.m_m, rk.m_v, rk.p_vecs, rk.host, rk.meter,
                rk.ioe, CpuAdam(lr=ocfg.lr), ocfg.alpha,
                param_dtype=np.dtype(ocfg.param_dtype))
            # activation shards are per micro-batch OWNER: each rank's
            # residual payloads ride its own IOEngine + SSD path set
            rk.act_c = ActivationCoordinator(x.act, rk.host, rk.ssd,
                                             rk.meter, rk.ioe)
        for c in self._coordinators():
            c.tracer = self.tracer

        bind_block_fns(self, build_block_fns(cfg, self.kind,
                                             self._unflatten))
        self.act_nbytes = act_residual_nbytes(
            self.j_layer_fwd_res, self.P, self.dtype, ocfg.micro_batch,
            ocfg.seq_len, cfg.d_model)
        self.act_policy = resolve_activation_policy(
            ocfg, cfg, self.P, self.dtype.itemsize, self.act_nbytes)
        self.act_fallbacks = 0
        self.op_seconds: Dict[str, float] = defaultdict(float)
        self.hint_skips = 0
        self.act_skips = 0
        self.backpressure = ocfg.backpressure
        self.act_adaptive = (ocfg.activation_policy == "auto"
                             and self.act_policy == "spill")
        self._plan = self._compile_plan()

    # ------------------------------------------------------------------
    # micro-batch ownership and ordering
    # ------------------------------------------------------------------
    def _mb_order(self, l: int) -> List[int]:
        """Global §4.2 alternating order — THE canonical
        ``repro.core.plan.mb_order``; sharing it with the single-rank
        engine is part of the bit-parity guarantee."""
        return mb_order(self.ocfg.num_microbatches, l)

    def _compile_plan(self):
        """Compile the R-rank vertical plan once (ALLGATHER /
        REDUCE_SCATTER ops; rank-major micro-batch blocks); every
        train_step interprets it with the shared executor."""
        depth = self.ocfg.resolved_prefetch_depth()
        spec = PlanSpec(L=self.L, M=self.ocfg.num_microbatches,
                        alpha=self.ocfg.alpha, ranks=self.R,
                        act_spill=(self.act_policy == "spill"))
        return insert_prefetch(
            compile_vertical(spec, order=self._mb_order,
                             opt_epilogue=depth > 0), depth=depth)

    # ------------------------------------------------------------------
    # simulated deterministic collectives
    # ------------------------------------------------------------------
    def _collective(self, category: str, send: int, recv: int):
        """Charge one collective's ring cost to every rank's meter (and
        pace it when a ``net`` route cap is configured)."""
        for rk in self.ranks:
            rk.meter.add(category, "gpu->net", send)
            rk.meter.add(category, "net->gpu", recv)
            rk.ioe.throttle("gpu->net", send)
            rk.ioe.throttle("net->gpu", recv)

    def _allgather_params(self, l: int) -> jax.Array:
        """Each rank's shard fetch (already prefetched on its own engine)
        concatenated into the full layer vector. Ring all-gather cost:
        each rank sends its shard R-1 times and receives the R-1 other
        shards."""
        shards = [rk.params_c.get(l) for rk in self.ranks]
        full = jnp.concatenate(shards)
        item = self.dtype.itemsize
        for rk, sh in zip(self.ranks, shards):
            mine = sh.size * item
            rk.meter.add("param", "gpu->net", (self.R - 1) * mine)
            rk.meter.add("param", "net->gpu", self.P * item - mine)
            rk.ioe.throttle("gpu->net", (self.R - 1) * mine)
            rk.ioe.throttle("net->gpu", self.P * item - mine)
        return full

    def _reduce_scatter_update(self, l: int, per_mb: Dict[int, jax.Array],
                               step: int):
        """Deterministic reduce-scatter + per-rank partial Adam: fold the
        per-micro-batch layer grads in GLOBAL micro-batch order (the
        single-rank engine's exact accumulation), slice each rank's
        element range, and hand it to that rank's optimizer coordinator.
        Ring cost: (R-1)/R of the f32 buffer per rank, each direction."""
        gacc = self._allreduce_fold(jnp.zeros((self.P,), jnp.float32),
                                    per_mb, self._mb_order(l))
        ring = (self.R - 1) * gacc.nbytes // self.R
        self._collective("grad", ring, ring)
        for rk, (lo, hi) in zip(self.ranks, self.bounds):
            rk.opt_c.submit_early(l, gacc[lo:hi], step)

    def _allreduce_fold(self, zeros: jax.Array, per_mb: Dict[int, jax.Array],
                        order: Sequence[int]) -> jax.Array:
        out = zeros
        for m in order:
            out = out + per_mb[m]
        return out

    # ------------------------------------------------------------------
    def _split_tokens(self, tokens):
        return split_microbatches(tokens, self.ocfg.num_microbatches,
                                  self.ocfg.micro_batch)

    def _labels(self, tok_mb):
        return shifted_labels(tok_mb)

    def train_step(self, tokens: np.ndarray) -> float:
        return execute_plan(self, self._plan, tokens)

    # ------------------------------------------------------------------
    def finish(self):
        """Flush α-pending optimizer shards and drain spills on every
        rank; afterwards all meters are complete and deterministic."""
        for rk in self.ranks:
            for l in range(self.L):
                rk.opt_c.flush_late(l, self.step_num)
                rk.opt_c.wait_late(l)
            rk.opt_c.wait_all()
            rk.ckpt_c.wait_pending()
            rk.act_c.wait_pending()

    # ------------------------------------------------------------------
    def apply_plan_config(self, prefetch_depth: Optional[int] = None,
                          activation_policy: Optional[str] = None,
                          path_policy: Optional[str] = None):
        """Between-iteration plan hot-swap (the autotuner seam), DP
        variant: same quiesce-and-clear contract as
        :meth:`OffloadEngine.apply_plan_config` applied to EVERY rank
        stack (``path_policy`` actuates every rank's I/O engine — each
        rank places chunks over its own path shard). DP plans are
        vertical by construction, so there is no ``wave_size`` knob
        here — ``lp_search.solve_config`` rejects one under
        ``num_gpus>1`` for the same reason."""
        changes = {}
        if prefetch_depth is not None:
            changes["prefetch_depth"] = int(prefetch_depth)
        if activation_policy is not None:
            changes["activation_policy"] = str(activation_policy)
        trial = dataclasses.replace(self.ocfg, **changes)
        trial.resolved_prefetch_depth()
        if trial.activation_policy not in ("recompute", "spill", "auto"):
            raise ValueError(
                f"unknown activation_policy "
                f"{trial.activation_policy!r}")
        if path_policy is not None and path_policy not in PATH_POLICIES:
            raise ValueError(
                f"path_policy {path_policy!r} not in {PATH_POLICIES}")
        self.finish()
        if path_policy is not None:
            for rk in self.ranks:
                rk.ioe.set_path_policy(path_policy)
        for rk in self.ranks:
            rk.params_c.reset()
            rk.params_c.clear_gates()
            rk.ckpt_c.clear()
            rk.act_c.clear()
        for k, v in changes.items():
            setattr(self.ocfg, k, v)
        if activation_policy is not None:
            self.act_policy = resolve_activation_policy(
                self.ocfg, self.cfg, self.P, self.dtype.itemsize,
                self.act_nbytes)
            self.act_adaptive = (self.ocfg.activation_policy == "auto"
                                 and self.act_policy == "spill")
        self._plan = self._compile_plan()
        return self._plan

    def read_params(self, l: int) -> np.ndarray:
        """The full low-precision param vector of layer l, assembled from
        the rank shards (validation/checkpointing)."""
        out = np.empty(self.P, np.dtype(self.ocfg.param_dtype))
        for rk, (lo, hi) in zip(self.ranks, self.bounds):
            out[lo:hi] = rk.p_vecs[l].read()
        return out

    def save_checkpoint(self, directory: str) -> str:
        """Crash-consistent checkpoint, ASSEMBLED format (full vectors,
        not rank shards) — interchangeable with the single-rank
        engine's; see :mod:`repro.offload.checkpoint`."""
        from repro.offload.checkpoint import save_checkpoint
        return save_checkpoint(self, directory)

    def restore_checkpoint(self, directory: str) -> int:
        """Restore from :meth:`save_checkpoint` output (any rank
        count's), re-sharding by ``bounds``. All-or-nothing."""
        from repro.offload.checkpoint import restore_checkpoint
        return restore_checkpoint(self, directory)

    def traffic(self) -> List[Dict[str, int]]:
        """Per-rank meter snapshots (index = rank)."""
        return [rk.meter.snapshot() for rk in self.ranks]

    def _coordinators(self):
        return [c for rk in self.ranks
                for c in (rk.params_c, rk.ckpt_c, rk.act_c, rk.opt_c)]

    def _lookahead_stats(self) -> Dict[str, object]:
        """Cross-rank aggregate, same shape as the single-rank engine's."""
        return lookahead_stats(self, self._coordinators())

    def reset_stats(self):
        reset_lookahead_stats(self, self._coordinators())

    @property
    def plan(self):
        """The compiled DP plan this engine interprets each step
        (what ``obs.reconcile`` joins a snapshot against)."""
        return self._plan

    def metrics_snapshot(self) -> Dict[str, object]:
        """The versioned flat metrics registry snapshot — same schema
        as the single-rank engine's, per-rank fields as lists; see
        :func:`repro.obs.build_snapshot`."""
        from repro.obs import build_snapshot
        return build_snapshot(self)

    def stats(self) -> Dict[str, object]:
        """Deprecated: use :meth:`metrics_snapshot` (versioned, and a
        strict superset of this shape — see CHANGES.md for the
        deprecation window)."""
        import warnings
        warnings.warn(
            "DataParallelOffloadEngine.stats() is deprecated; use "
            "metrics_snapshot()", DeprecationWarning, stacklevel=2)
        return {
            "ranks": self.R,
            "bounds": list(self.bounds),
            "io": [rk.ioe._collect_stats() for rk in self.ranks],
            "host_peak_nbytes": [rk.host.peak_nbytes for rk in self.ranks],
            "act_policy": self.act_policy,
            "act_fallbacks": self.act_fallbacks,
            "lookahead": self._lookahead_stats(),
        }

    def close(self):
        if self._closed:
            return
        self._closed = True
        for rk in self.ranks:
            rk.close()
