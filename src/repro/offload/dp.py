"""Data-parallel sharded offload: R rank workers × R SSD path sets.

ZeRO-style partitioned offload (the layout GreedySnake's multi-GPU
baseline uses, and the one its 4-GPU result beats by scheduling): every
tiered vector — low-precision params, master, momentum, variance — is
split into R contiguous element ranges. Rank ``r`` owns range
``[lo_r, hi_r)`` of every layer's vectors, keeps it on its OWN
``IOEngine`` + SSD path set (``IOConfig.shard_for_rank``), and runs the
α-delayed partial Adam on only that shard, so R ranks drive R× the
aggregate storage bandwidth. Per iteration the ranks:

* split the global batch: rank ``r`` runs micro-batches
  ``[r·M/R, (r+1)·M/R)`` through the same vertical schedule (its local
  micro-batch order is the global §4.2 alternating order restricted to
  its block, which preserves the boundary-micro-batch device slot);
* **all-gather** the low-precision param shards at each layer boundary
  (each rank reads ``1/R`` of the layer from its own SSD paths — the
  per-rank reads are submitted to all R engines before any is awaited,
  which is where the aggregate-bandwidth win comes from);
* **reduce-scatter** each fully-accumulated f32 layer gradient so every
  rank updates only its optimizer-state shard.

Determinism (§6.5, extended across the data-parallel axis): the
simulated collectives fold contributions in GLOBAL micro-batch order —
the exact fold the single-rank engine performs — and element-range
slicing commutes bitwise with every elementwise op involved (gradient
accumulation, Adam). An R-rank run is therefore **bit-identical (f32)**
to the single-rank ``OffloadEngine``; a real deployment gets the same
property from deterministic (rank-ordered ring) NCCL reductions.

Metering: each rank has its own ``TrafficMeter``. Collective traffic
uses routes ``"gpu->net"`` / ``"net->gpu"`` with ring costs — per rank
and direction, ``(R-1)/R`` of the buffer (categories ``"param"`` for
the all-gather, ``"grad"`` for the reduce-scatter, ``"head_grad"`` for
the replicated embedding/head all-reduce, which the paper's per-layer
pipeline excludes, §4.5). Closed forms:
:func:`repro.core.traffic.dp_vertical_traffic`; the per-rank counters
are validated against them exactly in the DP test battery.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.io import IOConfig, IOEngine
from repro.models import blocks as blk
from repro.offload.coordinators import (InterLayerTensorCoordinator,
                                        OptimizerStepCoordinator,
                                        ParameterCoordinator)
from repro.offload.engine import (OffloadConfig, _flatten_tree,
                                  _make_unflatten, bind_block_fns,
                                  build_block_fns, mb_order, shifted_labels,
                                  split_microbatches)
from repro.offload.stores import (HostStore, SSDStore, TieredVector,
                                  TrafficMeter)
from repro.optim.cpu_adam import CpuAdam


def shard_bounds(n: int, world: int) -> List[Tuple[int, int]]:
    """Contiguous 1/R element ranges covering [0, n) (sizes differ by at
    most one when R does not divide n)."""
    cuts = [(n * r) // world for r in range(world + 1)]
    return [(cuts[r], cuts[r + 1]) for r in range(world)]


class _Rank:
    """One data-parallel rank: its own meter/host/engine/SSD stack, its
    contiguous shard of every tiered vector, and the three coordinators
    rebound to that shard-local storage."""

    def __init__(self, index: int, world: int, root: str,
                 iocfg: IOConfig, ocfg: OffloadConfig):
        self.index = index
        self.world = world
        self.root = root
        self.meter = TrafficMeter()
        self.host = HostStore(self.meter)
        # same worker floor as the single-rank engine: a gated param
        # fetch may wait on an optimizer request (α-delay ordering)
        if iocfg.workers < 3:
            iocfg = dataclasses.replace(iocfg, workers=3)
        self.ioe = IOEngine(iocfg, meter=self.meter, default_root=root)
        self.ssd = SSDStore(root, self.meter, engine=self.ioe)
        self.p_vecs: List[TieredVector] = []
        self.m_master: List[TieredVector] = []
        self.m_m: List[TieredVector] = []
        self.m_v: List[TieredVector] = []
        # coordinators are attached by the engine once shards exist
        self.params_c: Optional[ParameterCoordinator] = None
        self.ckpt_c: Optional[InterLayerTensorCoordinator] = None
        self.opt_c: Optional[OptimizerStepCoordinator] = None

    def close(self):
        self.params_c.reset()
        self.ckpt_c.wait_pending()
        self.opt_c.wait_all()
        self.ssd.close()
        self.ioe.shutdown(wait=True)


class DataParallelOffloadEngine:
    """R-rank data-parallel version of :class:`OffloadEngine` (vertical
    schedule only). Same constructor contract plus ``ranks``; per-rank
    SSD paths come from ``ocfg.io`` partitioned by
    ``IOConfig.shard_for_rank`` (default: ``<workdir>/rank<r>``)."""

    def __init__(self, cfg, ocfg: OffloadConfig, key, workdir: str,
                 ranks: int = 2):
        assert cfg.family in ("dense",), "engine drives homogeneous GPT stacks"
        assert ocfg.schedule == "vertical", \
            "data-parallel offload implements the vertical schedule"
        plan = blk.build_plan(cfg)
        assert len(plan.period) == 1 and not plan.prefix and not plan.suffix
        M = ocfg.num_microbatches
        if M % ranks:
            raise ValueError(
                f"num_microbatches={M} must divide evenly across "
                f"{ranks} ranks (uneven sharding is a ROADMAP follow-on)")
        self.cfg = cfg
        self.ocfg = ocfg
        self.kind = plan.period[0]
        self.L = cfg.num_layers
        self.R = ranks
        self.Mr = M // ranks
        self.dtype = jnp.dtype(ocfg.param_dtype)
        self.step_num = 0
        self._closed = False

        base_io = ocfg.io if ocfg.io is not None else \
            IOConfig(workers=ocfg.io_workers)
        self.ranks: List[_Rank] = [
            _Rank(r, ranks, os.path.join(workdir, f"rank{r}"),
                  base_io.shard_for_rank(r, ranks), ocfg)
            for r in range(ranks)]

        # ---- init params layerwise, identical key-split to the
        # single-rank engine, each rank persisting only its shard ----
        keys = jax.random.split(key, self.L + 1)
        x = ocfg.ratios
        tmpl = None
        for l in range(self.L):
            lp = blk.block_init(keys[l], cfg, self.kind, dtype=self.dtype)
            flat, treedef, shapes = _flatten_tree(lp)
            flat = flat.astype(ocfg.param_dtype)
            if tmpl is None:
                tmpl = (treedef, shapes)
                self.P = flat.size
                self.bounds = shard_bounds(self.P, ranks)
            f32 = flat.astype(np.float32)
            for rk, (lo, hi) in zip(self.ranks, self.bounds):
                n_r = hi - lo
                pv = TieredVector(f"param:{l}", n_r, ocfg.param_dtype,
                                  x.param, rk.host, rk.ssd, "param")
                pv.write_full(flat[lo:hi])
                rk.p_vecs.append(pv)
                for name, lst, init in (
                        ("master", rk.m_master, f32[lo:hi]),
                        ("m", rk.m_m, np.zeros(n_r, np.float32)),
                        ("v", rk.m_v, np.zeros(n_r, np.float32))):
                    tv = TieredVector(f"{name}:{l}", n_r, np.float32,
                                      x.opt, rk.host, rk.ssd, "opt")
                    tv.write_full(init)
                    lst.append(tv)
        self._unflatten = _make_unflatten(tmpl[0], tmpl[1], self.dtype)

        # embedding / head replicated on every (simulated) device; one
        # copy suffices because all ranks apply identical reduced grads
        from repro.models.common import embed_init, init_rms_scale
        ek = jax.random.split(keys[self.L], 2)
        self.embed = embed_init(ek[0], cfg.padded_vocab, cfg.d_model,
                                self.dtype)
        self.unembed = embed_init(ek[1], cfg.padded_vocab, cfg.d_model,
                                  self.dtype).T
        self.final_norm = init_rms_scale(cfg.d_model)
        self.head_state = {
            t: {"m": jnp.zeros_like(getattr(self, t), dtype=jnp.float32),
                "v": jnp.zeros_like(getattr(self, t), dtype=jnp.float32)}
            for t in ("embed", "unembed", "final_norm")}

        for rk in self.ranks:
            rk.params_c = ParameterCoordinator(rk.p_vecs, rk.meter, rk.ioe)
            rk.ckpt_c = InterLayerTensorCoordinator(
                x.ckpt, rk.host, rk.ssd, rk.meter, rk.ioe)
            rk.opt_c = OptimizerStepCoordinator(
                rk.m_master, rk.m_m, rk.m_v, rk.p_vecs, rk.host, rk.meter,
                rk.ioe, CpuAdam(lr=ocfg.lr), ocfg.alpha,
                param_dtype=np.dtype(ocfg.param_dtype))

        bind_block_fns(self, build_block_fns(cfg, self.kind,
                                             self._unflatten))

    # ------------------------------------------------------------------
    # micro-batch ownership and ordering
    # ------------------------------------------------------------------
    def _mb_order(self, l: int) -> List[int]:
        """Global §4.2 alternating order — THE single-rank engine's
        ``mb_order``; sharing it is part of the bit-parity guarantee."""
        return mb_order(self.ocfg.num_microbatches, l)

    def _rank_mbs(self, r: int) -> range:
        return range(r * self.Mr, (r + 1) * self.Mr)

    def _rank_order(self, r: int, l: int) -> List[int]:
        """Rank r's local order = the global order restricted to its
        contiguous micro-batch block (keeps the per-rank alternation, so
        every rank's boundary micro-batch stays on device)."""
        own = set(self._rank_mbs(r))
        return [m for m in self._mb_order(l) if m in own]

    # ------------------------------------------------------------------
    # simulated deterministic collectives
    # ------------------------------------------------------------------
    def _collective(self, category: str, send: int, recv: int):
        """Charge one collective's ring cost to every rank's meter (and
        pace it when a ``net`` route cap is configured)."""
        for rk in self.ranks:
            rk.meter.add(category, "gpu->net", send)
            rk.meter.add(category, "net->gpu", recv)
            rk.ioe.throttle("gpu->net", send)
            rk.ioe.throttle("net->gpu", recv)

    def _allgather_params(self, l: int) -> jax.Array:
        """Each rank's shard fetch (already prefetched on its own engine)
        concatenated into the full layer vector. Ring all-gather cost:
        each rank sends its shard R-1 times and receives the R-1 other
        shards."""
        shards = [rk.params_c.get(l) for rk in self.ranks]
        full = jnp.concatenate(shards)
        item = self.dtype.itemsize
        for rk, sh in zip(self.ranks, shards):
            mine = sh.size * item
            rk.meter.add("param", "gpu->net", (self.R - 1) * mine)
            rk.meter.add("param", "net->gpu", self.P * item - mine)
            rk.ioe.throttle("gpu->net", (self.R - 1) * mine)
            rk.ioe.throttle("net->gpu", self.P * item - mine)
        return full

    def _reduce_scatter_update(self, l: int, per_mb: Dict[int, jax.Array],
                               step: int):
        """Deterministic reduce-scatter + per-rank partial Adam: fold the
        per-micro-batch layer grads in GLOBAL micro-batch order (the
        single-rank engine's exact accumulation), slice each rank's
        element range, and hand it to that rank's optimizer coordinator.
        Ring cost: (R-1)/R of the f32 buffer per rank, each direction."""
        gacc = self._allreduce_fold(jnp.zeros((self.P,), jnp.float32),
                                    per_mb, self._mb_order(l))
        ring = (self.R - 1) * gacc.nbytes // self.R
        self._collective("grad", ring, ring)
        for rk, (lo, hi) in zip(self.ranks, self.bounds):
            rk.opt_c.submit_early(l, gacc[lo:hi], step)

    def _allreduce_fold(self, zeros: jax.Array, per_mb: Dict[int, jax.Array],
                        order: Sequence[int]) -> jax.Array:
        out = zeros
        for m in order:
            out = out + per_mb[m]
        return out

    # ------------------------------------------------------------------
    def _split_tokens(self, tokens):
        return split_microbatches(tokens, self.ocfg.num_microbatches,
                                  self.ocfg.micro_batch)

    def _labels(self, tok_mb):
        return shifted_labels(tok_mb)

    def train_step(self, tokens: np.ndarray) -> float:
        ocfg = self.ocfg
        mbs = self._split_tokens(tokens)
        self.step_num += 1
        step = self.step_num
        denom = jnp.asarray(float(np.prod(tokens.shape) - tokens.shape[0]),
                            jnp.float32)

        # ---------- forward ----------
        if ocfg.alpha > 0 and step > 1:
            for rk in self.ranks:
                for l in range(self.L):
                    rk.opt_c.flush_late(l, step - 1)
                    rk.params_c.set_gate(
                        l, (lambda c, ll: lambda: c.wait_late(ll))(
                            rk.opt_c, l))
        for rk in self.ranks:
            order0 = self._rank_order(rk.index, 0)
            for m in reversed(order0):
                x = self.j_embed(self.embed, jnp.asarray(mbs[m]))
                rk.ckpt_c.put_ckpt(0, m, x, keep_on_device=(m == order0[0]))
        # submit ALL ranks' shard fetches before any is awaited — this is
        # the aggregate-bandwidth lever (R engines × R path sets busy)
        for rk in self.ranks:
            rk.params_c.prefetch(0)
        for l in range(self.L):
            p_dev = self._allgather_params(l)
            for rk in self.ranks:
                rk.params_c.prefetch(l + 1)
            for rk in self.ranks:
                order = self._rank_order(rk.index, l)
                for m in order:
                    x = rk.ckpt_c.get_ckpt_fwd(l, m)
                    y = self.j_layer_fwd(p_dev, x)
                    rk.ckpt_c.put_ckpt(l + 1, m, y,
                                       keep_on_device=(m == order[-1]))
            del p_dev
        jax.effects_barrier()

        # ---------- backward (+ overlapped sharded optimizer) ----------
        loss_total = 0.0
        per_mb_head: Dict[int, tuple] = {}
        for rk in self.ranks:
            order = self._rank_order(rk.index, self.L)
            for m in order:
                x = rk.ckpt_c.get_ckpt_fwd(self.L, m)
                lab, w = self._labels(mbs[m])
                loss, du, dn, dx = self.j_head_bwd(
                    self.unembed, self.final_norm, x, lab, w, denom)
                per_mb_head[m] = (loss, du, dn)
                rk.ckpt_c.put_grad(self.L, m, dx,
                                   keep_on_device=(m == order[-1]))
                rk.ckpt_c.drop_ckpt(self.L, m)
        # fold losses and head grads in the single-rank engine's order
        d_un = jnp.zeros_like(self.unembed, dtype=jnp.float32)
        d_nm = jnp.zeros_like(self.final_norm, dtype=jnp.float32)
        for m in self._mb_order(self.L):
            loss, du, dn = per_mb_head[m]
            loss_total += float(loss)
            d_un = d_un + du
            d_nm = d_nm + dn

        for rk in self.ranks:
            rk.params_c.reset()        # fwd->bwd boundary
            rk.params_c.prefetch(self.L - 1)
        for l in range(self.L - 1, -1, -1):
            p_dev = self._allgather_params(l)
            for rk in self.ranks:
                rk.params_c.prefetch(l - 1)
            per_mb_dp: Dict[int, jax.Array] = {}
            for rk in self.ranks:
                order = self._rank_order(rk.index, l)
                for m in order:
                    x = rk.ckpt_c.get_ckpt_bwd(l, m)
                    dy = rk.ckpt_c.get_grad(l + 1, m)
                    dx, dp, _ = self.j_layer_bwd(p_dev, x, dy)
                    per_mb_dp[m] = dp
                    rk.ckpt_c.put_grad(l, m, dx,
                                       keep_on_device=(m == order[-1]))
                    rk.ckpt_c.drop_ckpt(l, m)
            self._reduce_scatter_update(l, per_mb_dp, step)
            del p_dev

        # embedding backward (replicated): per-rank compute, ordered fold
        per_mb_de: Dict[int, jax.Array] = {}
        for rk in self.ranks:
            for m in reversed(self._rank_order(rk.index, 0)):
                dx0 = rk.ckpt_c.get_grad(0, m)
                per_mb_de[m] = self.j_embed_bwd(self.embed,
                                                jnp.asarray(mbs[m]), dx0)
        d_embed = self._allreduce_fold(
            jnp.zeros_like(self.embed, dtype=jnp.float32), per_mb_de,
            list(reversed(self._mb_order(0))))

        # replicated head params: all-reduce the grads (ring: 2·(R-1)/R
        # each way per rank) and apply the identical update everywhere
        head_bytes = int(d_embed.nbytes + d_un.nbytes + d_nm.nbytes)
        ring = 2 * (self.R - 1) * head_bytes // self.R
        self._collective("head_grad", ring, ring)
        for name, g in (("embed", d_embed), ("unembed", d_un),
                        ("final_norm", d_nm)):
            st = self.head_state[name]
            p2, st["m"], st["v"] = self.j_adam_dev(
                getattr(self, name), st["m"], st["v"], g,
                jnp.asarray(step, jnp.int32), jnp.asarray(self.ocfg.lr))
            setattr(self, name, p2)
        if ocfg.alpha == 0:
            for rk in self.ranks:
                rk.opt_c.wait_all()
        return loss_total

    # ------------------------------------------------------------------
    def finish(self):
        """Flush α-pending optimizer shards and drain spills on every
        rank; afterwards all meters are complete and deterministic."""
        for rk in self.ranks:
            for l in range(self.L):
                rk.opt_c.flush_late(l, self.step_num)
                rk.opt_c.wait_late(l)
            rk.opt_c.wait_all()
            rk.ckpt_c.wait_pending()

    def read_params(self, l: int) -> np.ndarray:
        """The full low-precision param vector of layer l, assembled from
        the rank shards (validation/checkpointing)."""
        out = np.empty(self.P, np.dtype(self.ocfg.param_dtype))
        for rk, (lo, hi) in zip(self.ranks, self.bounds):
            out[lo:hi] = rk.p_vecs[l].read()
        return out

    def traffic(self) -> List[Dict[str, int]]:
        """Per-rank meter snapshots (index = rank)."""
        return [rk.meter.snapshot() for rk in self.ranks]

    def stats(self) -> Dict[str, object]:
        return {
            "ranks": self.R,
            "bounds": list(self.bounds),
            "io": [rk.ioe.stats() for rk in self.ranks],
            "host_peak_nbytes": [rk.host.peak_nbytes for rk in self.ranks],
        }

    def close(self):
        if self._closed:
            return
        self._closed = True
        for rk in self.ranks:
            rk.close()
