"""OffloadEngine: GreedySnake's schedules executed against REAL
three-tier storage, by compiling a schedule plan once and interpreting
it every step.

Design note (the schedule IR)
=============================

This engine no longer hard-codes any schedule as control flow. Instead:

* ``repro.core.plan`` compiles the schedule — vertical, horizontal, or
  the wave hybrid — into a linear op stream (``FETCH_PARAM``, ``FWD``,
  ``SPILL_CKPT``/``FETCH_CKPT``, ``BWD``, ``WRITEBACK_GRAD``,
  ``OPT_LATE``, ... — see the op table in that module), with
  ``PREFETCH`` hints derived by a lookahead pass;
* ``repro.offload.executor.execute_plan`` — the ONE executor, shared
  with the data-parallel engine — walks the plan against the three
  coordinators and the ``repro.io`` engine;
* ``repro.core.plan.plan_traffic`` predicts every byte counter of a
  run statically from the same IR, cross-checked exactly against the
  closed forms in ``repro.core.traffic`` AND the engine's measured
  meters (``tests/test_plan_executor.py``).

Schedules (per-iteration traffic, validated in tests; ms = low-precision
model bytes, cs = per-micro-batch aggregated ckpt bytes, M micro-batches,
W = wave size, nw = M/W waves):

  vertical   (W=M): params 2·ms, grads 2·ms (f32 once), ckpt M·cs
             written, read twice minus the on-device boundary
             micro-batch (§3.4 + §4.2)
  horizontal (W=1): params 2·M·ms, grad buffer (2M-1)·2·ms, one
             micro-batch resident on device at a time
  wave       (1<W<M): params 2·nw·ms, grad buffer (2·nw-1)·2·ms, and
             the wave interior behaves vertically — the knob trades
             checkpoint traffic against parameter reuse
             (``repro.core.traffic.wave_ckpt_traffic``)

and the (1-α) optimizer fraction overlaps backward, the α fraction the
next forward, via ``OPT_LATE`` gates (§4.4).

Orthogonal to the schedule, ``activation_policy`` picks how backward
gets its inputs: ``"recompute"`` (the paper) re-reads each boundary
checkpoint and recomputes the layer inside the vjp; ``"spill"``
(SSDTrain-style) streams each layer's vjp residuals out after its
forward (``SPILL_ACT``) and back ahead of its backward (``FETCH_ACT``)
at the opportunistic ``IOPriority.ACT``, trading ``2·L·M·A`` stream
bytes for the recompute third of backward and the checkpoint
re-reads; ``"auto"`` prices both with ``repro.core.perfmodel`` against
``OffloadConfig.machine`` (or the configured bandwidth caps). Both
policies apply the SAME saved-residual backward, so they are
bitwise-identical (f32) in losses and parameters; the closed forms are
``repro.core.traffic.act_spill_traffic`` + the ``act_spill=True`` ckpt
variants, and ``A`` is sized exactly by :func:`act_residual_nbytes`.

The embedding and LM head stay device-resident (the paper excludes them
from the per-layer pipeline and adds their time separately, §4.5).
"""
from __future__ import annotations

import dataclasses
import warnings
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.perfmodel import MachineParams, StorageRatios
from repro.core.plan import (PlanSpec, compile_wave, insert_prefetch,
                             mb_order)
from repro.io import IOConfig, IOEngine
from repro.io.config import PATH_POLICIES
from repro.models import blocks as blk
from repro.models.common import rms_norm
from repro.models.model import _xent_chunk
from repro.offload.coordinators import (ActivationCoordinator,
                                        InterLayerTensorCoordinator,
                                        OptimizerStepCoordinator,
                                        ParameterCoordinator)
from repro.offload.executor import execute_plan
from repro.offload.stores import HostStore, SSDStore, TieredVector, TrafficMeter
from repro.optim.cpu_adam import CpuAdam

__all__ = ["OffloadConfig", "OffloadEngine", "build_block_fns",
           "bind_block_fns", "mb_order", "split_microbatches",
           "shifted_labels", "act_residual_nbytes",
           "resolve_activation_policy", "engine_workload"]


@dataclasses.dataclass
class OffloadConfig:
    schedule: str = "vertical"          # "vertical" | "horizontal" | "wave"
    num_microbatches: int = 4
    micro_batch: int = 2
    seq_len: int = 128
    alpha: float = 0.0                  # delayed optimizer ratio (§4.4)
    wave_size: int = 0                  # W for schedule="wave" (must
                                        # divide num_microbatches;
                                        # W=M <=> vertical, W=1 <=> horizontal)
    ratios: StorageRatios = dataclasses.field(default_factory=StorageRatios)
    lr: float = 1e-3
    io_workers: int = 4
    param_dtype: str = "float32"        # f32 => bit-exact vs in-memory ref
    io: Optional[IOConfig] = None       # paths/chunking/budget/bandwidth
                                        # (None: single path = the workdir)
    activation_policy: str = "recompute"  # "recompute" | "spill" | "auto":
                                        # spill streams each layer's vjp
                                        # residuals (SPILL_ACT/FETCH_ACT)
                                        # instead of recomputing backward
                                        # from the boundary checkpoint;
                                        # auto asks the perf model AND
                                        # adapts per (layer, micro-batch)
                                        # at runtime: a spill is skipped
                                        # (recompute fallback, bitwise-
                                        # identical) when the live write
                                        # queue depth says the SSD is
                                        # saturated
    machine: Optional[MachineParams] = None  # link rates for the "auto"
                                        # decision (None: bandwidth caps
                                        # in `io` if set, else defaults)
    prefetch_depth: int = 1             # cross-stream lookahead depth:
                                        # how many same-stream fetches
                                        # ahead each PREFETCH* hint is
                                        # placed (0 disables the hints
                                        # entirely — every fetch becomes
                                        # a synchronous gate-ordered
                                        # read; byte counters and
                                        # results are identical)
    trace: bool = False                 # start with the repro.obs span
                                        # tracer recording (it can also
                                        # be toggled later via
                                        # eng.tracer.enable/disable;
                                        # off = one flag test per site)
    backpressure: float = 0.5           # adaptive-lookahead threshold:
                                        # skip hints / degrade "auto"
                                        # spills once the I/O engine's
                                        # live depth exceeds this
                                        # fraction of its in-flight
                                        # byte budget

    #: guard against typo'd or absurd lookahead depths — a hint placed
    #: hundreds of fetches ahead would just pin host memory
    MAX_PREFETCH_DEPTH = 16

    #: The schedules / activation policies a config may name. Anything
    #: else is rejected at CONSTRUCTION — same eager ``ValueError``
    #: contract as ``IOConfig`` (path_policy) and ``solve_config``.
    SCHEDULES = ("vertical", "horizontal", "wave")
    ACTIVATION_POLICIES = ("recompute", "spill", "auto")

    def __post_init__(self):
        """Reject malformed knobs at CONSTRUCTION (a typo'd schedule or
        depth should fail where it was written, not when a plan first
        compiles)."""
        if self.schedule not in self.SCHEDULES:
            raise ValueError(
                f"unknown schedule {self.schedule!r}; "
                f"choose one of {self.SCHEDULES}")
        if self.activation_policy not in self.ACTIVATION_POLICIES:
            raise ValueError(
                f"unknown activation_policy {self.activation_policy!r}; "
                f"choose one of {self.ACTIVATION_POLICIES}")
        d = int(self.prefetch_depth)
        if not 0 <= d <= self.MAX_PREFETCH_DEPTH:
            raise ValueError(
                f"prefetch_depth={self.prefetch_depth} is outside "
                f"[0, {self.MAX_PREFETCH_DEPTH}]; 0 disables the "
                "lookahead hints, 1 is the classic two-stage pipeline, "
                "larger values hint further ahead")
        if not 0.0 < float(self.backpressure) <= 1.0:
            raise ValueError(
                f"backpressure={self.backpressure} must be in (0, 1] "
                "(fraction of the I/O in-flight budget beyond which "
                "lookahead hints are skipped)")

    def resolved_prefetch_depth(self) -> int:
        """The validated lookahead depth (0 = hints off)."""
        self.__post_init__()     # mutable dataclass: re-check at use
        return int(self.prefetch_depth)

    def resolved_wave_size(self) -> int:
        """The W this config's schedule compiles to."""
        M = self.num_microbatches
        if self.schedule == "vertical":
            return M
        if self.schedule == "horizontal":
            return 1
        if self.schedule == "wave":
            W = self.wave_size
            if W < 1 or M % W:
                raise ValueError(
                    f"wave_size={W} must be in [1, M] and divide "
                    f"num_microbatches={M}")
            return W
        raise ValueError(f"unknown schedule {self.schedule!r}")


def _flatten_tree(tree) -> Tuple[np.ndarray, list, list]:
    leaves, treedef = jax.tree.flatten(tree)
    shapes = [l.shape for l in leaves]
    flat = np.concatenate([np.asarray(l).reshape(-1) for l in leaves])
    return flat, treedef, shapes


def _make_unflatten(treedef, shapes, dtype):
    sizes = [int(np.prod(s)) for s in shapes]
    offs = np.cumsum([0] + sizes)

    def unflatten(flat):
        leaves = [jax.lax.dynamic_slice_in_dim(flat, int(offs[i]), sizes[i], 0)
                  .reshape(shapes[i]).astype(dtype)
                  for i in range(len(sizes))]
        return jax.tree.unflatten(treedef, leaves)
    return unflatten


def _adam_device(p, m, v, g, step, lr):
    """Plain Adam for the device-resident embedding/head params."""
    b1, b2, eps = 0.9, 0.95, 1e-8
    g = g.astype(jnp.float32)
    m2 = b1 * m + (1 - b1) * g
    v2 = b2 * v + (1 - b2) * g * g
    t = step.astype(jnp.float32)
    up = (m2 / (1 - b1 ** t)) / (jnp.sqrt(v2 / (1 - b2 ** t)) + eps)
    return (p.astype(jnp.float32) - lr * up).astype(p.dtype), m2, v2


def build_block_fns(cfg, kind, unflatten) -> Dict[str, object]:
    """Jitted per-layer / embedding / head functions, shared by the
    single-rank and data-parallel engines. Both engines driving the SAME
    compiled computations is what makes an R-rank run bit-identical
    (f32) to a single-rank run — any per-engine recompilation could
    legally re-fuse and break that."""

    def layer_fwd(p_flat, x):
        lp = unflatten(p_flat)
        y, _, _ = blk.block_apply(lp, x, cfg, kind, mode="train")
        return y

    def layer_fwd_res(p_flat, x):
        """Forward that ALSO returns the vjp residuals (a Partial
        pytree of arrays). Both activation policies run backward from
        these residuals — spill restores them from storage, recompute
        re-runs this function at backward time — so the two policies'
        gradients are bitwise-identical by construction."""
        return jax.vjp(lambda p, xx: layer_fwd(p, xx), p_flat, x)

    def layer_bwd_res(vjp, dy):
        """Backward from saved/recomputed residuals (no forward pass)."""
        dp, dx = vjp(dy)
        return dx, dp.astype(jnp.float32)

    def embed_fwd(embed, tokens):
        return embed[tokens]

    def head_loss(unembed, norm, x, labels, weights, denom):
        h = rms_norm(x, norm, cfg.norm_eps)
        tot, _ = _xent_chunk(h, unembed, labels, weights)
        return tot / denom

    def head_bwd(unembed, norm, x, labels, weights, denom):
        (loss), vjp = jax.vjp(
            lambda u, nm, xx: head_loss(u, nm, xx, labels, weights, denom),
            unembed, norm, x)
        du, dn, dx = vjp(jnp.ones((), jnp.float32))
        return loss, du, dn, dx

    def embed_bwd(embed, tokens, dx):
        f = lambda e: e[tokens]
        _, vjp = jax.vjp(f, embed)
        return vjp(dx)[0]

    return {
        "layer_fwd": jax.jit(layer_fwd),
        "layer_fwd_res": jax.jit(layer_fwd_res),
        "layer_bwd_res": jax.jit(layer_bwd_res),
        "embed": jax.jit(embed_fwd),
        "head_bwd": jax.jit(head_bwd),
        "embed_bwd": jax.jit(embed_bwd),
        "adam_dev": jax.jit(_adam_device),
    }


def bind_block_fns(obj, fns: Dict[str, object]) -> None:
    """Attach :func:`build_block_fns` results as the ``j_*`` attributes
    both engines use."""
    obj.j_layer_fwd = fns["layer_fwd"]
    obj.j_layer_fwd_res = fns["layer_fwd_res"]
    obj.j_layer_bwd_res = fns["layer_bwd_res"]
    obj.j_embed = fns["embed"]
    obj.j_head_bwd = fns["head_bwd"]
    obj.j_embed_bwd = fns["embed_bwd"]
    obj.j_adam_dev = fns["adam_dev"]


def act_residual_nbytes(j_layer_fwd_res, P: int, dtype, micro_batch: int,
                        seq_len: int, d_model: int) -> int:
    """EXACT byte size of one (layer, micro-batch) residual payload —
    what each ``SPILL_ACT``/``FETCH_ACT`` moves — via ``jax.eval_shape``
    (no compute, no allocation). Shared by both engines and by
    ``PlanCosts.from_engine`` through the ``act_nbytes`` attribute."""
    _, res = jax.eval_shape(
        j_layer_fwd_res,
        jax.ShapeDtypeStruct((P,), dtype),
        jax.ShapeDtypeStruct((micro_batch, seq_len, d_model), dtype))
    return sum(int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
               for leaf in jax.tree.leaves(res))


def resolve_activation_policy(ocfg: OffloadConfig, cfg, P: int,
                              itemsize: int, act_nbytes: int) -> str:
    """Resolve the ``activation_policy`` knob to "recompute"|"spill".
    "auto" prices both policies with the perf model
    (:func:`repro.core.perfmodel.pick_activation_policy`) using
    ENGINE-accurate workload bytes (this engine's dtype and measured
    residual size, not the bf16 paper defaults) and the machine from
    ``ocfg.machine``, the configured bandwidth caps, or the defaults.
    """
    pol = ocfg.activation_policy
    if pol in ("recompute", "spill"):
        return pol
    if pol != "auto":
        raise ValueError(f"unknown activation_policy {pol!r}")
    from repro.core.perfmodel import (machine_from_bandwidth,
                                      pick_activation_policy)
    m = ocfg.machine
    if m is None:
        bw = ocfg.io.bandwidth if ocfg.io is not None else None
        m = machine_from_bandwidth(bw) if bw else MachineParams()
    w = engine_workload(ocfg, cfg, P, itemsize, act_nbytes)
    M = ocfg.num_microbatches
    return pick_activation_policy(w, m, M, ocfg.resolved_wave_size(),
                                  ocfg.alpha, ocfg.ratios,
                                  lookahead=ocfg.resolved_prefetch_depth()
                                  > 0)


def engine_workload(ocfg: OffloadConfig, cfg, P: int, itemsize: int,
                    act_nbytes: int):
    """The ENGINE-accurate :class:`repro.core.perfmodel.Workload`: the
    FLOP model comes from the one place it is maintained
    (``Workload.from_config``); only the byte fields are overridden
    with this engine's actual sizes — its dtype, its flat layer
    vector, its measured residual payload. The one workload both the
    "auto" activation-policy pricing and the online autotuner solve
    against (an autotuner solving the paper's bf16 defaults would
    retune the wrong machine)."""
    from repro.core.perfmodel import Workload
    L = cfg.num_layers
    tokens = ocfg.micro_batch * ocfg.seq_len
    return dataclasses.replace(
        Workload.from_config(cfg, ocfg.micro_batch, ocfg.seq_len),
        ms=L * P * itemsize,
        cs=L * tokens * cfg.d_model * itemsize,
        os_bytes=3 * L * P * 4,
        grad_bytes=L * P * 4,
        as_bytes=L * act_nbytes,
    )


def lookahead_stats(eng, coordinators) -> Dict[str, object]:
    """Prefetch hit/miss counters aggregated over ``coordinators`` plus
    the engine's adaptive-skip counters and per-op stall meters — the
    ONE ``stats()["lookahead"]`` shape for both engines (the DP engine
    passes every rank's coordinator stack)."""
    from repro.offload.executor import stall_seconds
    hits = sum(c.la_hits for c in coordinators)
    misses = sum(c.la_misses for c in coordinators)
    total = hits + misses
    return {"hits": hits, "misses": misses,
            "hit_rate": hits / total if total else 1.0,
            "hint_skips": eng.hint_skips,
            "act_skips": eng.act_skips,
            "stall_s": stall_seconds(eng.op_seconds),
            "op_seconds": dict(eng.op_seconds)}


def reset_lookahead_stats(eng, coordinators) -> None:
    """Zero EVERY measured-iteration meter — stall/phase timers,
    adaptive-skip and fallback counters, lookahead hit/miss counts —
    so a second measured iteration after reset reports exactly like the
    first (bench warm-up boundary; traffic meters have their own
    ``reset``, and the I/O engines' cumulative stats are lifetime
    counters by design)."""
    eng.op_seconds.clear()
    eng.hint_skips = eng.act_skips = 0
    eng.act_fallbacks = 0
    for k in eng.phase_time:
        eng.phase_time[k] = 0.0
    for c in coordinators:
        c.la_hits = c.la_misses = 0


def split_microbatches(tokens: np.ndarray, M: int, micro_batch: int
                       ) -> np.ndarray:
    assert tokens.shape[0] == M * micro_batch
    return tokens.reshape(M, micro_batch, -1)


def shifted_labels(tok_mb: np.ndarray):
    """Next-token labels/weights for one micro-batch (last position
    masked), identical across engines."""
    lab = np.concatenate([tok_mb[:, 1:], np.zeros((tok_mb.shape[0], 1),
                                                  tok_mb.dtype)], 1)
    w = np.ones(tok_mb.shape, np.float32)
    w[:, -1] = 0.0
    return jnp.asarray(lab), jnp.asarray(w)


class OffloadEngine:
    def __init__(self, cfg, ocfg: OffloadConfig, key, workdir: str):
        assert cfg.family in ("dense",), "engine drives homogeneous GPT stacks"
        plan = blk.build_plan(cfg)
        assert len(plan.period) == 1 and not plan.prefix and not plan.suffix
        self.cfg = cfg
        self.ocfg = ocfg
        self.kind = plan.period[0]
        self.L = cfg.num_layers
        self.dtype = jnp.dtype(ocfg.param_dtype)
        self.meter = TrafficMeter()
        self.host = HostStore(self.meter)
        # All offload traffic flows through one IOEngine. A gated param
        # fetch may wait on an optimizer request, and two fetches can be
        # gated at once, so the engine needs at least 3 request workers
        # or the α-delay gate discipline can deadlock.
        iocfg = ocfg.io if ocfg.io is not None else \
            IOConfig(workers=ocfg.io_workers)
        if iocfg.workers < 3:
            iocfg = dataclasses.replace(iocfg, workers=3)
        # one shared span tracer for every layer that touches bytes
        # (executor, IOEngine threads, coordinators); off by default
        from repro.obs import Tracer
        self.tracer = Tracer()
        if ocfg.trace:
            self.tracer.enable()
        self.ioe = IOEngine(iocfg, meter=self.meter, default_root=workdir,
                            tracer=self.tracer)
        self.ssd = SSDStore(workdir, self.meter, engine=self.ioe)
        self.step_num = 0
        self._closed = False
        self.phase_time: Dict[str, float] = {"fwd": 0.0, "bwd": 0.0, "opt_wait": 0.0}

        # ---- init params layerwise straight into tiered storage ----
        keys = jax.random.split(key, self.L + 1)
        x = ocfg.ratios
        self.p_vecs: List[TieredVector] = []
        self.m_master: List[TieredVector] = []
        self.m_m: List[TieredVector] = []
        self.m_v: List[TieredVector] = []
        tmpl = None
        for l in range(self.L):
            lp = blk.block_init(keys[l], cfg, self.kind, dtype=self.dtype)
            flat, treedef, shapes = _flatten_tree(lp)
            flat = flat.astype(ocfg.param_dtype)
            if tmpl is None:
                tmpl = (treedef, shapes)
                self.P = flat.size
            pv = TieredVector(f"param:{l}", self.P, ocfg.param_dtype,
                              x.param, self.host, self.ssd, "param")
            pv.write_full(flat)
            self.p_vecs.append(pv)
            for name, lst, init in (("master", self.m_master, flat.astype(np.float32)),
                                    ("m", self.m_m, np.zeros(self.P, np.float32)),
                                    ("v", self.m_v, np.zeros(self.P, np.float32))):
                tv = TieredVector(f"{name}:{l}", self.P, np.float32,
                                  x.opt, self.host, self.ssd, "opt")
                tv.write_full(init)
                lst.append(tv)
        self._unflatten = _make_unflatten(tmpl[0], tmpl[1], self.dtype)

        # embedding / head resident on device (+ their own device Adam)
        from repro.models.common import embed_init, init_rms_scale
        ek = jax.random.split(keys[self.L], 2)
        self.embed = embed_init(ek[0], cfg.padded_vocab, cfg.d_model, self.dtype)
        self.unembed = embed_init(ek[1], cfg.padded_vocab, cfg.d_model, self.dtype).T
        self.final_norm = init_rms_scale(cfg.d_model)
        self.head_state = {
            t: {"m": jnp.zeros_like(getattr(self, t), dtype=jnp.float32),
                "v": jnp.zeros_like(getattr(self, t), dtype=jnp.float32)}
            for t in ("embed", "unembed", "final_norm")}

        # coordinators (all submit through the shared IOEngine)
        self.params_c = ParameterCoordinator(self.p_vecs, self.meter,
                                             self.ioe)
        self.ckpt_c = InterLayerTensorCoordinator(x.ckpt, self.host, self.ssd,
                                                  self.meter, self.ioe)
        self.opt_c = OptimizerStepCoordinator(
            self.m_master, self.m_m, self.m_v, self.p_vecs, self.host,
            self.meter, self.ioe, CpuAdam(lr=ocfg.lr), ocfg.alpha,
            param_dtype=np.dtype(ocfg.param_dtype))
        self.act_c = ActivationCoordinator(x.act, self.host, self.ssd,
                                           self.meter, self.ioe)
        for c in self._coordinators():
            c.tracer = self.tracer

        self._build_jit_fns()
        # size the activation stream exactly (one (layer, mb) residual
        # payload) and resolve the recompute/spill/auto policy knob
        self.act_nbytes = act_residual_nbytes(
            self.j_layer_fwd_res, self.P, self.dtype, ocfg.micro_batch,
            ocfg.seq_len, cfg.d_model)
        self.act_policy = resolve_activation_policy(
            ocfg, cfg, self.P, self.dtype.itemsize, self.act_nbytes)
        self.act_fallbacks = 0      # micro-batches degraded to recompute
        # cross-stream lookahead state: per-op stall meters, adaptive
        # skip counters, and the backpressure knob the executor reads
        self.op_seconds: Dict[str, float] = defaultdict(float)
        self.hint_skips = 0         # hints skipped under backpressure
        self.act_skips = 0          # "auto" spills degraded per (l, m)
        self.backpressure = ocfg.backpressure
        self.act_adaptive = (ocfg.activation_policy == "auto"
                             and self.act_policy == "spill")
        self._plan = self._compile_plan()

    # ------------------------------------------------------------------
    def _build_jit_fns(self):
        bind_block_fns(self, build_block_fns(self.cfg, self.kind,
                                             self._unflatten))

    # ------------------------------------------------------------------
    def _mb_order(self, l: int) -> List[int]:
        """The canonical §4.2 alternating micro-batch order
        (:func:`repro.core.plan.mb_order`) for this config's M. The plan
        compiler consults THIS method, so tests can perturb the order
        and watch the executor pay the eviction penalty."""
        return mb_order(self.ocfg.num_microbatches, l)

    def _compile_plan(self):
        """Compile the configured schedule once; every train_step
        interprets the same plan (with the cross-stream lookahead
        hints at the configured depth)."""
        depth = self.ocfg.resolved_prefetch_depth()
        spec = PlanSpec(L=self.L, M=self.ocfg.num_microbatches,
                        alpha=self.ocfg.alpha, ranks=1,
                        act_spill=(self.act_policy == "spill"))
        # depth 0 = the full lookahead-off baseline: no hints AND the
        # pre-lookahead prologue OPT_LATE ordering
        plan = compile_wave(spec, self.ocfg.resolved_wave_size(),
                            order=self._mb_order,
                            opt_epilogue=depth > 0)
        return insert_prefetch(plan, depth=depth)

    def train_step(self, tokens: np.ndarray) -> float:
        return execute_plan(self, self._plan, tokens)

    # ------------------------------------------------------------------
    def _split_tokens(self, tokens):
        return split_microbatches(tokens, self.ocfg.num_microbatches,
                                  self.ocfg.micro_batch)

    def _labels(self, tok_mb):
        return shifted_labels(tok_mb)

    # ------------------------------------------------------------------
    def finish(self):
        """Flush any α-pending optimizer work and drain outstanding
        checkpoint spills (end of training): afterwards the meter
        snapshot is complete and deterministic."""
        for l in range(self.L):
            self.opt_c.flush_late(l, self.step_num)
            self.opt_c.wait_late(l)
        self.opt_c.wait_all()
        self.ckpt_c.wait_pending()
        self.act_c.wait_pending()

    # ------------------------------------------------------------------
    def apply_plan_config(self, wave_size: Optional[int] = None,
                          prefetch_depth: Optional[int] = None,
                          activation_policy: Optional[str] = None,
                          path_policy: Optional[str] = None):
        """Hot-swap the compiled plan BETWEEN iterations — the
        autotuner's retune seam. Changes any subset of the tunable
        knobs (``wave_size`` retargets the schedule to the wave hybrid
        with that W; ``prefetch_depth``; ``activation_policy``;
        ``path_policy`` actuates the I/O engine's chunk->path
        placement — no plan-shape change, so no recompile needed for
        it alone, but the same quiesce applies so the policy flips at
        an iteration boundary) and recompiles; the next ``train_step``
        interprets the new plan.

        The seam must not leak per-plan state, in either direction:

        * α tails are flushed and waited (``finish()`` semantics —
          identical to what a prologue plan would apply at the next
          step's start, so the flush is trajectory-neutral for both
          the epilogue and prologue OPT_LATE placements);
        * outstanding param prefetches are cancelled and the armed α
          gates dropped (:meth:`ParameterCoordinator.clear_gates` —
          the tails just settled, so a surviving gate could only
          deadlock the new plan's first fetch);
        * checkpoint device-kept slots / pending spills / bwd-tail
          prefetches and activation residue are cleared — the new plan
          re-derives its own working set.

        Knobs are validated on a throwaway config copy BEFORE anything
        mutates, so a bad value raises ``ValueError`` and leaves the
        engine running its current plan. ``prefetch_depth`` and
        ``activation_policy`` swaps are bitwise trajectory-neutral by
        the PR-4/5 invariants; a ``wave_size`` swap is exact w.r.t. an
        engine compiled with the new plan from the same checkpointed
        state (the satellite pin), though the W axis itself regroups
        the f32 gradient fold across waves."""
        changes = {}
        if wave_size is not None:
            changes.update(schedule="wave", wave_size=int(wave_size))
        if prefetch_depth is not None:
            changes["prefetch_depth"] = int(prefetch_depth)
        if activation_policy is not None:
            changes["activation_policy"] = str(activation_policy)
        trial = dataclasses.replace(self.ocfg, **changes)
        trial.resolved_wave_size()          # raises on a bad W
        trial.resolved_prefetch_depth()     # raises on a bad depth
        if trial.activation_policy not in ("recompute", "spill", "auto"):
            raise ValueError(
                f"unknown activation_policy "
                f"{trial.activation_policy!r}")
        if path_policy is not None and path_policy not in PATH_POLICIES:
            raise ValueError(
                f"path_policy {path_policy!r} not in {PATH_POLICIES}")
        # quiesce: flush + wait the α tails, drain ckpt/act streams
        self.finish()
        if path_policy is not None:
            self.ioe.set_path_policy(path_policy)
        # drop per-plan residue on every coordinator
        self.params_c.reset()
        self.params_c.clear_gates()
        self.ckpt_c.clear()
        self.act_c.clear()
        # commit the knobs and recompile
        for k, v in changes.items():
            setattr(self.ocfg, k, v)
        if activation_policy is not None:
            self.act_policy = resolve_activation_policy(
                self.ocfg, self.cfg, self.P, self.dtype.itemsize,
                self.act_nbytes)
            self.act_adaptive = (self.ocfg.activation_policy == "auto"
                                 and self.act_policy == "spill")
        self._plan = self._compile_plan()
        return self._plan

    # ------------------------------------------------------------------
    def save_checkpoint(self, directory: str) -> str:
        """Crash-consistent checkpoint of the full trainable state
        (journaled manifest + CRC-verified tensors; see
        :mod:`repro.offload.checkpoint`). Returns the manifest path."""
        from repro.offload.checkpoint import save_checkpoint
        return save_checkpoint(self, directory)

    def restore_checkpoint(self, directory: str) -> int:
        """Restore from :meth:`save_checkpoint` output (all-or-nothing,
        verified before any state mutates). Returns the restored
        ``step_num``; the continued trajectory is bitwise (f32)."""
        from repro.offload.checkpoint import restore_checkpoint
        return restore_checkpoint(self, directory)

    def traffic(self) -> Dict[str, int]:
        out = self.meter.snapshot()
        out["host:peak_nbytes"] = self.host.peak_nbytes
        return out

    def _coordinators(self):
        return (self.params_c, self.ckpt_c, self.act_c, self.opt_c)

    def _lookahead_stats(self) -> Dict[str, object]:
        return lookahead_stats(self, self._coordinators())

    def reset_stats(self):
        """Zero every measured-iteration meter (warm-up boundary; the
        traffic meter has its own ``reset``)."""
        reset_lookahead_stats(self, self._coordinators())

    @property
    def plan(self):
        """The compiled schedule plan this engine interprets each step
        (what ``obs.reconcile`` joins a snapshot against)."""
        return self._plan

    def metrics_snapshot(self) -> Dict[str, object]:
        """The versioned flat metrics registry snapshot — subsumes
        :meth:`stats`, JSON-serializable; see
        :func:`repro.obs.build_snapshot` for the schema."""
        from repro.obs import build_snapshot
        return build_snapshot(self)

    def stats(self) -> Dict[str, object]:
        """Deprecated: use :meth:`metrics_snapshot` (versioned, and a
        strict superset of this shape — see CHANGES.md for the
        deprecation window)."""
        warnings.warn(
            "OffloadEngine.stats() is deprecated; use metrics_snapshot()",
            DeprecationWarning, stacklevel=2)
        return {"io": self.ioe._collect_stats(),
                "host_peak_nbytes": self.host.peak_nbytes,
                "host_nbytes": self.host.nbytes(),
                "act_policy": self.act_policy,
                "act_fallbacks": self.act_fallbacks,
                "lookahead": self._lookahead_stats(),
                "phase_time": dict(self.phase_time)}

    def close(self):
        """Drain outstanding I/O, delete the workdir's tensor files, and
        shut the transfer engine down. Idempotent."""
        if self._closed:
            return
        self._closed = True
        self.params_c.reset()
        self.ckpt_c.wait_pending()
        self.act_c.wait_pending()
        self.opt_c.wait_all()
        self.ssd.close()              # removes stripe files from the paths
        self.ioe.shutdown(wait=True)
