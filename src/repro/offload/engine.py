"""OffloadEngine: GreedySnake's pipelined vertical (and baseline
horizontal) schedule executed against REAL three-tier storage.

This is the runnable counterpart of the paper's system on this container:
* "GPU"  = the jax device (compute + per-layer working set),
* "CPU"  = numpy host buffers,
* "SSD"  = binary files under a work directory.

Per iteration, the engine moves exactly the bytes the paper's §1/§3.4
analysis predicts (validated in tests against repro.core.traffic):

  vertical:    params 2·ms, grads 2·ms (f32 once), ckpt M·cs written,
               read twice minus the on-device boundary micro-batch
  horizontal:  params 2·M·ms, grad buffer (2M-1)·2·ms, ckpt 2·M·cs

and overlaps the (1-α) optimizer fraction with backward and the α
fraction with the next forward via worker threads.

The embedding and LM head stay device-resident (the paper excludes them
from the per-layer pipeline and adds their time separately, §4.5).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.perfmodel import StorageRatios
from repro.io import IOConfig, IOEngine
from repro.models import blocks as blk
from repro.models.common import rms_norm
from repro.models.model import _xent_chunk, labels_and_weights
from repro.offload.coordinators import (InterLayerTensorCoordinator,
                                        OptimizerStepCoordinator,
                                        ParameterCoordinator, _xfer)
from repro.offload.stores import HostStore, SSDStore, TieredVector, TrafficMeter
from repro.optim.cpu_adam import CpuAdam


@dataclasses.dataclass
class OffloadConfig:
    schedule: str = "vertical"          # "vertical" | "horizontal"
    num_microbatches: int = 4
    micro_batch: int = 2
    seq_len: int = 128
    alpha: float = 0.0                  # delayed optimizer ratio (§4.4)
    ratios: StorageRatios = dataclasses.field(default_factory=StorageRatios)
    lr: float = 1e-3
    io_workers: int = 4
    param_dtype: str = "float32"        # f32 => bit-exact vs in-memory ref
    io: Optional[IOConfig] = None       # paths/chunking/budget/bandwidth
                                        # (None: single path = the workdir)


def _flatten_tree(tree) -> Tuple[np.ndarray, list, list]:
    leaves, treedef = jax.tree.flatten(tree)
    shapes = [l.shape for l in leaves]
    flat = np.concatenate([np.asarray(l).reshape(-1) for l in leaves])
    return flat, treedef, shapes


def _make_unflatten(treedef, shapes, dtype):
    sizes = [int(np.prod(s)) for s in shapes]
    offs = np.cumsum([0] + sizes)

    def unflatten(flat):
        leaves = [jax.lax.dynamic_slice_in_dim(flat, int(offs[i]), sizes[i], 0)
                  .reshape(shapes[i]).astype(dtype)
                  for i in range(len(sizes))]
        return jax.tree.unflatten(treedef, leaves)
    return unflatten


def _adam_device(p, m, v, g, step, lr):
    """Plain Adam for the device-resident embedding/head params."""
    b1, b2, eps = 0.9, 0.95, 1e-8
    g = g.astype(jnp.float32)
    m2 = b1 * m + (1 - b1) * g
    v2 = b2 * v + (1 - b2) * g * g
    t = step.astype(jnp.float32)
    up = (m2 / (1 - b1 ** t)) / (jnp.sqrt(v2 / (1 - b2 ** t)) + eps)
    return (p.astype(jnp.float32) - lr * up).astype(p.dtype), m2, v2


def build_block_fns(cfg, kind, unflatten) -> Dict[str, object]:
    """Jitted per-layer / embedding / head functions, shared by the
    single-rank and data-parallel engines. Both engines driving the SAME
    compiled computations is what makes an R-rank run bit-identical
    (f32) to a single-rank run — any per-engine recompilation could
    legally re-fuse and break that."""

    def layer_fwd(p_flat, x):
        lp = unflatten(p_flat)
        y, _, _ = blk.block_apply(lp, x, cfg, kind, mode="train")
        return y

    def layer_bwd(p_flat, x, dy):
        y, vjp = jax.vjp(lambda p, xx: layer_fwd(p, xx), p_flat, x)
        dp, dx = vjp(dy)
        return dx, dp.astype(jnp.float32), y

    def embed_fwd(embed, tokens):
        return embed[tokens]

    def head_loss(unembed, norm, x, labels, weights, denom):
        h = rms_norm(x, norm, cfg.norm_eps)
        tot, _ = _xent_chunk(h, unembed, labels, weights)
        return tot / denom

    def head_bwd(unembed, norm, x, labels, weights, denom):
        (loss), vjp = jax.vjp(
            lambda u, nm, xx: head_loss(u, nm, xx, labels, weights, denom),
            unembed, norm, x)
        du, dn, dx = vjp(jnp.ones((), jnp.float32))
        return loss, du, dn, dx

    def embed_bwd(embed, tokens, dx):
        f = lambda e: e[tokens]
        _, vjp = jax.vjp(f, embed)
        return vjp(dx)[0]

    return {
        "layer_fwd": jax.jit(layer_fwd),
        "layer_bwd": jax.jit(layer_bwd),
        "embed": jax.jit(embed_fwd),
        "head_bwd": jax.jit(head_bwd),
        "embed_bwd": jax.jit(embed_bwd),
        "adam_dev": jax.jit(_adam_device),
    }


def bind_block_fns(obj, fns: Dict[str, object]) -> None:
    """Attach :func:`build_block_fns` results as the ``j_*`` attributes
    both engines use."""
    obj.j_layer_fwd = fns["layer_fwd"]
    obj.j_layer_bwd = fns["layer_bwd"]
    obj.j_embed = fns["embed"]
    obj.j_head_bwd = fns["head_bwd"]
    obj.j_embed_bwd = fns["embed_bwd"]
    obj.j_adam_dev = fns["adam_dev"]


def mb_order(M: int, l: int) -> List[int]:
    """The §4.2 alternating micro-batch order for layer ``l`` — shared
    by the single-rank and data-parallel engines; the R-rank
    bit-parity guarantee depends on both using THIS function."""
    return list(range(M)) if l % 2 == 0 else list(range(M - 1, -1, -1))


def split_microbatches(tokens: np.ndarray, M: int, micro_batch: int
                       ) -> np.ndarray:
    assert tokens.shape[0] == M * micro_batch
    return tokens.reshape(M, micro_batch, -1)


def shifted_labels(tok_mb: np.ndarray):
    """Next-token labels/weights for one micro-batch (last position
    masked), identical across engines."""
    lab = np.concatenate([tok_mb[:, 1:], np.zeros((tok_mb.shape[0], 1),
                                                  tok_mb.dtype)], 1)
    w = np.ones(tok_mb.shape, np.float32)
    w[:, -1] = 0.0
    return jnp.asarray(lab), jnp.asarray(w)


class OffloadEngine:
    def __init__(self, cfg, ocfg: OffloadConfig, key, workdir: str):
        assert cfg.family in ("dense",), "engine drives homogeneous GPT stacks"
        plan = blk.build_plan(cfg)
        assert len(plan.period) == 1 and not plan.prefix and not plan.suffix
        self.cfg = cfg
        self.ocfg = ocfg
        self.kind = plan.period[0]
        self.L = cfg.num_layers
        self.dtype = jnp.dtype(ocfg.param_dtype)
        self.meter = TrafficMeter()
        self.host = HostStore(self.meter)
        # All offload traffic flows through one IOEngine. A gated param
        # fetch may wait on an optimizer request, and two fetches can be
        # gated at once, so the engine needs at least 3 request workers
        # or the α-delay gate discipline can deadlock.
        iocfg = ocfg.io if ocfg.io is not None else \
            IOConfig(workers=ocfg.io_workers)
        if iocfg.workers < 3:
            iocfg = dataclasses.replace(iocfg, workers=3)
        self.ioe = IOEngine(iocfg, meter=self.meter, default_root=workdir)
        self.ssd = SSDStore(workdir, self.meter, engine=self.ioe)
        self.step_num = 0
        self._closed = False
        self.phase_time: Dict[str, float] = {"fwd": 0.0, "bwd": 0.0, "opt_wait": 0.0}

        # ---- init params layerwise straight into tiered storage ----
        keys = jax.random.split(key, self.L + 1)
        x = ocfg.ratios
        self.p_vecs: List[TieredVector] = []
        self.m_master: List[TieredVector] = []
        self.m_m: List[TieredVector] = []
        self.m_v: List[TieredVector] = []
        tmpl = None
        for l in range(self.L):
            lp = blk.block_init(keys[l], cfg, self.kind, dtype=self.dtype)
            flat, treedef, shapes = _flatten_tree(lp)
            flat = flat.astype(ocfg.param_dtype)
            if tmpl is None:
                tmpl = (treedef, shapes)
                self.P = flat.size
            pv = TieredVector(f"param:{l}", self.P, ocfg.param_dtype,
                              x.param, self.host, self.ssd, "param")
            pv.write_full(flat)
            self.p_vecs.append(pv)
            for name, lst, init in (("master", self.m_master, flat.astype(np.float32)),
                                    ("m", self.m_m, np.zeros(self.P, np.float32)),
                                    ("v", self.m_v, np.zeros(self.P, np.float32))):
                tv = TieredVector(f"{name}:{l}", self.P, np.float32,
                                  x.opt, self.host, self.ssd, "opt")
                tv.write_full(init)
                lst.append(tv)
        self._unflatten = _make_unflatten(tmpl[0], tmpl[1], self.dtype)

        # embedding / head resident on device (+ their own device Adam)
        from repro.models.common import embed_init, init_rms_scale
        ek = jax.random.split(keys[self.L], 2)
        self.embed = embed_init(ek[0], cfg.padded_vocab, cfg.d_model, self.dtype)
        self.unembed = embed_init(ek[1], cfg.padded_vocab, cfg.d_model, self.dtype).T
        self.final_norm = init_rms_scale(cfg.d_model)
        self.head_state = {
            t: {"m": jnp.zeros_like(getattr(self, t), dtype=jnp.float32),
                "v": jnp.zeros_like(getattr(self, t), dtype=jnp.float32)}
            for t in ("embed", "unembed", "final_norm")}

        # coordinators (all submit through the shared IOEngine)
        self.params_c = ParameterCoordinator(self.p_vecs, self.meter,
                                             self.ioe)
        self.ckpt_c = InterLayerTensorCoordinator(x.ckpt, self.host, self.ssd,
                                                  self.meter, self.ioe)
        self.opt_c = OptimizerStepCoordinator(
            self.m_master, self.m_m, self.m_v, self.p_vecs, self.host,
            self.meter, self.ioe, CpuAdam(lr=ocfg.lr), ocfg.alpha,
            param_dtype=np.dtype(ocfg.param_dtype))

        self._build_jit_fns()

    # ------------------------------------------------------------------
    def _build_jit_fns(self):
        bind_block_fns(self, build_block_fns(self.cfg, self.kind,
                                             self._unflatten))

    # ------------------------------------------------------------------
    def _mb_order(self, l: int) -> List[int]:
        """Alternating micro-batch order between consecutive layers (§4.2)
        so the boundary micro-batch's activations stay on device.

        Discipline (validated by the boundary-micro-batch test): every
        producer emits a boundary's tensors in the REVERSE of its
        consumer's order and keeps the last-produced one on device, so
        the consumer's FIRST access hits the device slot and frees it
        immediately. The coordinators enforce this strictly — a kept
        tensor consumed out of order is evicted (checkpoint) or spilled
        (inter-layer gradient), exactly what a memory-bound GPU would do.
        """
        return mb_order(self.ocfg.num_microbatches, l)

    def train_step(self, tokens: np.ndarray) -> float:
        if self.ocfg.schedule == "vertical":
            return self._step_vertical(tokens)
        return self._step_horizontal(tokens)

    # ------------------------------------------------------------------
    def _split_tokens(self, tokens):
        return split_microbatches(tokens, self.ocfg.num_microbatches,
                                  self.ocfg.micro_batch)

    def _labels(self, tok_mb):
        return shifted_labels(tok_mb)

    def _step_vertical(self, tokens: np.ndarray) -> float:
        ocfg = self.ocfg
        M = ocfg.num_microbatches
        mbs = self._split_tokens(tokens)
        self.step_num += 1
        step = self.step_num
        denom = jnp.asarray(float(np.prod(tokens.shape) - tokens.shape[0]),
                            jnp.float32)

        # ---------- forward ----------
        t0 = time.perf_counter()
        # α-delayed flush must complete before each layer's params are read:
        # submit the late-fraction updates and gate the prefetches on them.
        if ocfg.alpha > 0 and step > 1:
            for l in range(self.L):
                self.opt_c.flush_late(l, step - 1)
                self.params_c.set_gate(
                    l, (lambda ll: lambda: self.opt_c.wait_late(ll))(l))
        # Embedding produces boundary 0 in the REVERSE of layer 0's
        # consumption order so the kept micro-batch is the first one layer
        # 0 consumes (§4.2 alternating-order discipline, see _mb_order).
        order0 = self._mb_order(0)
        for m in reversed(order0):
            x = self.j_embed(self.embed, jnp.asarray(mbs[m]))
            self.ckpt_c.put_ckpt(0, m, x, keep_on_device=(m == order0[0]))
        self.params_c.prefetch(0)
        for l in range(self.L):
            p_dev = self.params_c.get(l)
            self.params_c.prefetch(l + 1)
            order = self._mb_order(l)
            for m in order:
                x = self.ckpt_c.get_ckpt_fwd(l, m)
                y = self.j_layer_fwd(p_dev, x)
                self.ckpt_c.put_ckpt(l + 1, m, y,
                                     keep_on_device=(m == order[-1]))
            del p_dev
        jax.effects_barrier()
        self.phase_time["fwd"] += time.perf_counter() - t0

        # ---------- backward (+ overlapped optimizer) ----------
        t0 = time.perf_counter()
        loss_total = 0.0
        # head: produce inter-layer grads dL/dx_L per micro-batch
        order = self._mb_order(self.L)
        d_un = jnp.zeros_like(self.unembed, dtype=jnp.float32)
        d_nm = jnp.zeros_like(self.final_norm, dtype=jnp.float32)
        for m in order:
            x = self.ckpt_c.get_ckpt_fwd(self.L, m)   # head input
            lab, w = self._labels(mbs[m])
            loss, du, dn, dx = self.j_head_bwd(self.unembed, self.final_norm,
                                               x, lab, w, denom)
            loss_total += float(loss)
            d_un += du
            d_nm += dn
            self.ckpt_c.put_grad(self.L, m, dx,
                                 keep_on_device=(m == order[-1]))
            self.ckpt_c.drop_ckpt(self.L, m)
        self.params_c.reset()          # fwd->bwd boundary: cancel prefetches
        self.params_c.prefetch(self.L - 1)
        d_embed = jnp.zeros_like(self.embed, dtype=jnp.float32)
        for l in range(self.L - 1, -1, -1):
            p_dev = self.params_c.get(l)
            self.params_c.prefetch(l - 1)
            gacc = jnp.zeros((self.P,), jnp.float32)
            # Alternate between consecutive backward layers too: layer l+1
            # produced grad(l+1) in _mb_order(l+1); consuming in
            # _mb_order(l) (its reverse) makes the device-kept gradient
            # this layer's FIRST input, so the slot frees immediately.
            order = self._mb_order(l)
            for m in order:
                x = self.ckpt_c.get_ckpt_bwd(l, m)
                dy = self.ckpt_c.get_grad(l + 1, m)
                dx, dp, _ = self.j_layer_bwd(p_dev, x, dy)
                gacc = gacc + dp
                self.ckpt_c.put_grad(l, m, dx,
                                     keep_on_device=(m == order[-1]))
                self.ckpt_c.drop_ckpt(l, m)
            # fully-accumulated layer grads -> CPU, optimizer overlapped
            self.opt_c.submit_early(l, gacc, step)
            del p_dev
        # embedding backward: layer 0 produced grad(0) in _mb_order(0),
        # so consume in reverse — the kept micro-batch comes first.
        for m in reversed(self._mb_order(0)):
            dx0 = self.ckpt_c.get_grad(0, m)
            d_embed += self.j_embed_bwd(self.embed, jnp.asarray(mbs[m]), dx0)
        self.phase_time["bwd"] += time.perf_counter() - t0

        # head params update (device adam)
        t0 = time.perf_counter()
        for name, g in (("embed", d_embed), ("unembed", d_un),
                        ("final_norm", d_nm)):
            st = self.head_state[name]
            p2, st["m"], st["v"] = self.j_adam_dev(
                getattr(self, name), st["m"], st["v"], g,
                jnp.asarray(step, jnp.int32), jnp.asarray(self.ocfg.lr))
            setattr(self, name, p2)
        if ocfg.alpha == 0:
            self.opt_c.wait_all()
        self.phase_time["opt_wait"] += time.perf_counter() - t0
        return loss_total

    # ------------------------------------------------------------------
    def _step_horizontal(self, tokens: np.ndarray) -> float:
        """ZeRO-Infinity-style baseline: per micro-batch full fwd+bwd with
        the f32 accumulation buffer swapped through device memory."""
        ocfg = self.ocfg
        M = ocfg.num_microbatches
        mbs = self._split_tokens(tokens)
        self.step_num += 1
        step = self.step_num
        denom = jnp.asarray(float(np.prod(tokens.shape) - tokens.shape[0]),
                            jnp.float32)
        loss_total = 0.0
        d_un = jnp.zeros_like(self.unembed, dtype=jnp.float32)
        d_nm = jnp.zeros_like(self.final_norm, dtype=jnp.float32)
        d_embed = jnp.zeros_like(self.embed, dtype=jnp.float32)

        for m in range(M):
            # -------- forward (activations stay on device within the mb) ----
            t0 = time.perf_counter()
            if ocfg.alpha > 0 and step > 1 and m == 0:
                for l in range(self.L):
                    self.opt_c.flush_late(l, step - 1)
                    self.params_c.set_gate(
                        l, (lambda ll: lambda: self.opt_c.wait_late(ll))(l))
            x = self.j_embed(self.embed, jnp.asarray(mbs[m]))
            self.params_c.prefetch(0)
            for l in range(self.L):
                p_dev = self.params_c.get(l)
                self.params_c.prefetch(l + 1)
                self.ckpt_c.put_ckpt(l, m, x)   # save layer INPUT for bwd
                x = self.j_layer_fwd(p_dev, x)
                del p_dev
            self.phase_time["fwd"] += time.perf_counter() - t0

            # -------- backward --------
            t0 = time.perf_counter()
            lab, w = self._labels(mbs[m])
            loss, du, dn, dy = self.j_head_bwd(self.unembed, self.final_norm,
                                               x, lab, w, denom)
            loss_total += float(loss)
            d_un += du
            d_nm += dn
            self.params_c.reset()      # fwd->bwd boundary: cancel prefetches
            self.params_c.prefetch(self.L - 1)
            dy_dev = dy
            for l in range(self.L - 1, -1, -1):
                p_dev = self.params_c.get(l)
                self.params_c.prefetch(l - 1)
                xin = self.ckpt_c.get_ckpt_bwd(l, m)
                dx, dp, _ = self.j_layer_bwd(p_dev, xin, dy_dev)
                self.ckpt_c.drop_ckpt(l, m)
                dy_dev = dx
                # f32 grad-accum buffer swapped via CPU (the horizontal tax):
                # mb 0 offloads; mb 1..M-2 fetch+offload; the last mb fetches
                # and hands the sum to the optimizer => (2M-1) x 2ms total.
                if m == 0:
                    g = np.asarray(dp)
                    _xfer(self.meter, self.ioe, "grad", "gpu->cpu", g.nbytes)
                    self.host.put(f"gacc:{l}", g)
                elif m < M - 1:
                    g_host = self.host.get(f"gacc:{l}")
                    _xfer(self.meter, self.ioe, "grad", "cpu->gpu",
                          g_host.nbytes)
                    g = np.asarray(dp + jnp.asarray(g_host))
                    _xfer(self.meter, self.ioe, "grad", "gpu->cpu", g.nbytes)
                    self.host.put(f"gacc:{l}", g)
                else:
                    g_host = self.host.pop(f"gacc:{l}")
                    _xfer(self.meter, self.ioe, "grad", "cpu->gpu",
                          g_host.nbytes)
                    g_dev = dp + jnp.asarray(g_host)
                    # optimizer overlaps only with this LAST micro-batch (§3.3)
                    self.opt_c.submit_early(l, g_dev, step)
                del p_dev
            d_embed += self.j_embed_bwd(self.embed, jnp.asarray(mbs[m]), dy_dev)
            self.phase_time["bwd"] += time.perf_counter() - t0

        t0 = time.perf_counter()
        for name, g in (("embed", d_embed), ("unembed", d_un),
                        ("final_norm", d_nm)):
            st = self.head_state[name]
            p2, st["m"], st["v"] = self.j_adam_dev(
                getattr(self, name), st["m"], st["v"], g,
                jnp.asarray(step, jnp.int32), jnp.asarray(self.ocfg.lr))
            setattr(self, name, p2)
        if ocfg.alpha == 0:
            self.opt_c.wait_all()
        self.phase_time["opt_wait"] += time.perf_counter() - t0
        return loss_total

    # ------------------------------------------------------------------
    def finish(self):
        """Flush any α-pending optimizer work and drain outstanding
        checkpoint spills (end of training): afterwards the meter
        snapshot is complete and deterministic."""
        for l in range(self.L):
            self.opt_c.flush_late(l, self.step_num)
            self.opt_c.wait_late(l)
        self.opt_c.wait_all()
        self.ckpt_c.wait_pending()

    def traffic(self) -> Dict[str, int]:
        out = self.meter.snapshot()
        out["host:peak_nbytes"] = self.host.peak_nbytes
        return out

    def stats(self) -> Dict[str, object]:
        """I/O-engine counters + host residency + phase wall-times."""
        return {"io": self.ioe.stats(),
                "host_peak_nbytes": self.host.peak_nbytes,
                "host_nbytes": self.host.nbytes(),
                "phase_time": dict(self.phase_time)}

    def close(self):
        """Drain outstanding I/O, delete the workdir's tensor files, and
        shut the transfer engine down. Idempotent."""
        if self._closed:
            return
        self._closed = True
        self.params_c.reset()
        self.ckpt_c.wait_pending()
        self.opt_c.wait_all()
        self.ssd.close()              # removes stripe files from the paths
        self.ioe.shutdown(wait=True)
