"""Pinned-buffer packing (GreedySnake §5).

PyTorch pads each pinned allocation to a power-of-two size, wasting up to
half the allocation. GreedySnake instead allocates a small set of
power-of-two blocks, each holding multiple same-size buffers, chosen by
dynamic programming to minimise waste. We reproduce that DP exactly.

``pack(n, size, max_block_log2)`` returns the list of block sizes (bytes,
powers of two) that hold ``n`` buffers of ``size`` bytes with minimum
total allocated memory (ties: fewer blocks).
"""
from __future__ import annotations

from typing import List, Tuple


def pack(n: int, size: int, max_block_log2: int = 34) -> Tuple[int, List[int]]:
    """Minimise total allocated power-of-two bytes to hold n buffers of
    ``size`` bytes (buffers must not span blocks).

    Returns (total_allocated_bytes, block_sizes)."""
    assert n >= 0 and size > 0
    if n == 0:
        return 0, []
    # candidate blocks: powers of two that hold >= 1 buffer
    blocks = []
    b = 1
    while b < size:
        b <<= 1
    while b <= (1 << max_block_log2):
        blocks.append(b)
        if b // size >= n:   # one block already holds everything
            break
        b <<= 1
    INF = float("inf")
    # dp[j] = (min total bytes to hold >= j buffers, blocks used)
    dp: List[Tuple[float, List[int]]] = [(INF, [])] * (n + 1)
    dp[0] = (0, [])
    for j in range(1, n + 1):
        best = (INF, [])
        for blk in blocks:
            cap = blk // size
            prev = dp[max(0, j - cap)]
            cand = prev[0] + blk
            if cand < best[0] or (cand == best[0]
                                  and len(prev[1]) + 1 < len(best[1])):
                best = (cand, prev[1] + [blk])
        dp[j] = best
    total, blks = dp[n]
    return int(total), sorted(blks, reverse=True)


def naive_padded(n: int, size: int) -> int:
    """PyTorch-style: each buffer padded to its own power of two."""
    b = 1
    while b < size:
        b <<= 1
    return n * b


def waste_ratio(n: int, size: int) -> Tuple[float, float]:
    """(DP waste, naive waste) as fractions of the useful bytes."""
    useful = n * size
    dp_total, _ = pack(n, size)
    return dp_total / useful - 1.0, naive_padded(n, size) / useful - 1.0
