"""The coordinators of GreedySnake §5 (+ the SSDTrain activation stream).

* ParameterCoordinator — per-layer low-precision params in tiered storage;
  two-stage prefetch (§4.2): SSD->CPU staged two pipeline stages ahead,
  CPU->device one stage ahead (async engine request), device copy dropped
  after use. ``reset()`` cancels in-flight fetches via the I/O engine's
  cancellation API at a schedule boundary.
* InterLayerTensorCoordinator — activation checkpoints (forward) and
  inter-layer gradients (backward). Checkpoints are written to CPU and the
  (1-x_c) tail streamed to SSD; the forward-pass consumer reads the CPU
  cache (paper: "written to SSD but at the same time cached in CPU"), after
  which the tail is dropped from CPU; the backward-pass recompute re-reads
  the tail from SSD. Inter-layer gradients stay in CPU (never SSD).
* OptimizerStepCoordinator — master/momentum/variance in tiered f32
  vectors; the (1-α) fraction updates right after a layer's backward
  (async, overlapped), the α fraction is flushed just before the layer's
  next forward (§4.4). Gradients for the α fraction are retained in CPU
  memory (the paper reuses reclaimed param/ckpt buffers; we meter the
  bytes the same way).
* ActivationCoordinator — the SSDTrain-style activation stream
  (``activation_policy="spill"``): each layer's vjp residuals — the
  non-boundary activations backward needs — are flattened to one byte
  payload after the forward, the ``StorageRatios.act`` head kept in
  CPU and the tail streamed to SSD at ``IOPriority.ACT`` (below ckpt
  spills: strictly opportunistic). The CPU tail copy is dropped as
  soon as the spill is staged (reclaiming DRAM is the point), so every
  backward fetch re-reads the tail. A failed spill or fetch surfaces
  at ``get`` and the executor degrades that one micro-batch to the
  recompute path — the checkpoint tier it needs is still intact.

All three submit their asynchronous work to :class:`repro.io.IOEngine`
rather than raw executors, so a parameter fetch the GPU is about to
block on is scheduled ahead of a deferrable checkpoint spill, and every
transfer is budgeted, cancellable, and (optionally) bandwidth-paced.
"""
from __future__ import annotations

from concurrent.futures import CancelledError
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.io import IOEngine, IOPriority, IORequest
from repro.offload.stores import HostStore, SSDStore, TieredVector, TrafficMeter
from repro.optim.cpu_adam import CpuAdam


def _xfer(meter: TrafficMeter, engine: IOEngine, category: str, route: str,
          nbytes: int):
    """Meter + (optionally) pace one device-side copy — the single place
    the meter.add/throttle pair lives for non-chunked transfers."""
    meter.add(category, route, nbytes)
    engine.throttle(route, nbytes)


class ParameterCoordinator:
    def __init__(self, vectors: List[TieredVector], meter: TrafficMeter,
                 engine: IOEngine, dtype=np.float16):
        self.vectors = vectors
        self.meter = meter
        self.engine = engine
        self._futures: Dict[int, IORequest] = {}
        self._gate: Dict[int, Callable[[], None]] = {}

    def set_gate(self, l: int, fn: Callable[[], None]):
        """Barrier that must complete before layer l's params are read
        (used to order the α-delayed optimizer flush before the fetch)."""
        self._gate[l] = fn

    def _fetch(self, l: int):
        gate = self._gate.pop(l, None)
        if gate is not None:
            gate()
        host_arr = self.vectors[l].read()          # meters ssd->cpu
        dev = jnp.asarray(host_arr)                 # "PCIe" copy
        _xfer(self.meter, self.engine, "param", "cpu->gpu", host_arr.nbytes)
        return dev

    def prefetch(self, l: int):
        if 0 <= l < len(self.vectors) and l not in self._futures:
            v = self.vectors[l]
            self._futures[l] = self.engine.submit(
                lambda l=l: self._fetch(l),
                priority=IOPriority.PARAM_FETCH, category="param",
                route="ssd->cpu", nbytes=v.n * v.dtype.itemsize)

    def get(self, l: int) -> jax.Array:
        if l not in self._futures:
            self.prefetch(l)
        return self._futures.pop(l).result()

    def reset(self):
        """Drop all outstanding prefetches at a schedule boundary:
        queued requests are cancelled before they touch storage; a
        running one is drained so its buffers settle."""
        for req in self._futures.values():
            if not req.cancel():
                try:
                    req.result()
                except CancelledError:
                    pass
        self._futures.clear()


class InterLayerTensorCoordinator:
    """Checkpoints: dict (layer, mb) -> (host_head, ssd_name or None).
    x_c = CPU-resident fraction; the tail beyond k goes to SSD."""

    def __init__(self, x_cpu: float, host: HostStore, ssd: SSDStore,
                 meter: TrafficMeter, engine: IOEngine):
        self.x = x_cpu
        self.host = host
        self.ssd = ssd
        self.meter = meter
        self.engine = engine
        self._pending: Dict[Tuple[str, int, int], IORequest] = {}
        self._shapes: Dict[Tuple[str, int, int], tuple] = {}
        self._device_kept: Dict[Tuple[int, int], jax.Array] = {}

    def _key(self, kind: str, l: int, m: int) -> str:
        return f"{kind}:{l}:{m}"

    # ---- forward checkpoints ----
    def put_ckpt(self, l: int, m: int, y_dev: jax.Array,
                 keep_on_device: bool = False):
        """Offload layer-l input checkpoint for micro-batch m."""
        if keep_on_device:
            self._device_kept[(l, m)] = y_dev
        arr = np.asarray(y_dev).reshape(-1)
        _xfer(self.meter, self.engine, "ckpt", "gpu->cpu", arr.nbytes)
        self._shapes[("c", l, m)] = y_dev.shape
        k = int(round(self.x * arr.size))
        name = self._key("c", l, m)
        self.host.put(name + ":h", arr[:k].copy())
        self.host.put(name + ":tail", arr[k:].copy())  # CPU cache until consumed
        if k < arr.size:
            old = self._pending.pop(("c", l, m), None)
            if old is not None:
                old.result()    # never two in-flight spills of one name
            # spill via the staging pool: lowest priority, cancellable
            self._pending[("c", l, m)] = self.ssd.write_async(
                name + ":s", arr[k:], "ckpt")

    def get_ckpt_fwd(self, l: int, m: int) -> jax.Array:
        """Next-layer forward input: device-kept or CPU cache (no SSD read).
        Drops the CPU tail afterwards (reclaimed, §4.4)."""
        if (l, m) in self._device_kept:
            return self._device_kept.pop((l, m))
        # §4.2 device-slot discipline: a kept boundary checkpoint is only
        # useful if it is the boundary's FIRST consumer (alternating
        # micro-batch order). A consumer for a different micro-batch means
        # the order was perturbed — the device cannot hold the slot across
        # the whole layer pass, so the kept copy is evicted (its CPU cache
        # already exists) and is re-read like any other micro-batch.
        for k in [k for k in self._device_kept if k[0] == l]:
            del self._device_kept[k]
        name = self._key("c", l, m)
        head = self.host.get(name + ":h")
        tail = self.host.pop(name + ":tail")   # consume CPU cache
        arr = np.concatenate([head, tail])
        _xfer(self.meter, self.engine, "ckpt", "cpu->gpu", arr.nbytes)
        return jnp.asarray(arr).reshape(self._shapes[("c", l, m)])

    def get_ckpt_bwd(self, l: int, m: int) -> jax.Array:
        """Backward recompute input: CPU head + SSD tail."""
        self._device_kept.pop((l, m), None)
        name = self._key("c", l, m)
        req = self._pending.pop(("c", l, m), None)
        if req is not None:
            req.result()
        head = self.host.get(name + ":h")
        shape = self._shapes[("c", l, m)]
        n = int(np.prod(shape))
        if head.size < n:
            if name + ":tail" in self.host:      # never trimmed (x=1 case)
                tail = self.host.get(name + ":tail")
            else:
                tail = self.ssd.read(name + ":s", "ckpt")
            arr = np.concatenate([head, tail])
        else:
            arr = head
        _xfer(self.meter, self.engine, "ckpt", "cpu->gpu", arr.nbytes)
        return jnp.asarray(arr).reshape(shape)

    def wait_pending(self):
        """Drain all outstanding checkpoint spills (engine teardown)."""
        for req in list(self._pending.values()):
            try:
                req.result()
            except CancelledError:
                pass
        self._pending.clear()

    def clear(self):
        """Abandon every checkpoint / inter-layer gradient this
        coordinator tracks: release device-kept boundary tensors, cancel
        or drain in-flight spills (swallowing their errors — the caller
        is already unwinding), and drop the CPU-resident pieces. Used by
        the plan executor's mid-step failure path so a failed micro-batch
        cannot leak device slots into the next step."""
        self._device_kept.clear()
        for req in list(self._pending.values()):
            if not req.cancel():
                try:
                    req.result()
                except Exception:
                    pass
        self._pending.clear()
        for kind, l, m in list(self._shapes):
            name = self._key(kind, l, m)
            keys = ([name + ":h", name + ":tail"] if kind == "c"
                    else [name])
            for key in keys:
                if key in self.host:
                    self.host.pop(key)
        self._shapes.clear()

    def drop_ckpt(self, l: int, m: int):
        # A ckpt consumed only via get_ckpt_fwd (the head layer) still has
        # its SSD spill in flight: drain it so no orphan write can race a
        # next-step spill of the same name and counters stay deterministic.
        self._device_kept.pop((l, m), None)
        req = self._pending.pop(("c", l, m), None)
        if req is not None:
            req.result()
        name = self._key("c", l, m)
        self.host.pop(name + ":h") if name + ":h" in self.host else None
        if name + ":tail" in self.host:
            self.host.pop(name + ":tail")

    # ---- inter-layer gradients (backward; CPU only, §4.3) ----
    def put_grad(self, l: int, m: int, dx_dev: jax.Array,
                 keep_on_device: bool = False):
        if keep_on_device:
            self._device_kept[(-l - 1, m)] = dx_dev
            return
        arr = np.asarray(dx_dev)
        _xfer(self.meter, self.engine, "inter_grad", "gpu->cpu", arr.nbytes)
        self._shapes[("g", l, m)] = dx_dev.shape
        self.host.put(self._key("g", l, m), arr)

    def get_grad(self, l: int, m: int) -> jax.Array:
        if (-l - 1, m) in self._device_kept:
            return self._device_kept.pop((-l - 1, m))
        # Out-of-order consumer: a kept inter-layer gradient was never
        # written to CPU (that is the whole saving), so losing the device
        # slot forces the spill the alternating order §4.2 avoids — pay
        # the gpu->cpu transfer now, and the cpu->gpu read later.
        for k in [k for k in self._device_kept if k[0] == -l - 1]:
            dx = self._device_kept.pop(k)
            arr = np.asarray(dx)
            _xfer(self.meter, self.engine, "inter_grad", "gpu->cpu",
                  arr.nbytes)
            self._shapes[("g", l, k[1])] = dx.shape
            self.host.put(self._key("g", l, k[1]), arr)
        arr = self.host.pop(self._key("g", l, m))
        _xfer(self.meter, self.engine, "inter_grad", "cpu->gpu", arr.nbytes)
        return jnp.asarray(arr).reshape(self._shapes[("g", l, m)])


class ActivationCoordinator:
    """Activation (vjp-residual) spill/fetch stream, keyed (layer, mb).

    Layout per key: the flattened residual payload's ``x_act`` head
    lives in the host store (``act:l:m:h``); the tail is written to SSD
    asynchronously (``act:l:m:s``, category ``"act"`` =>
    ``IOPriority.ACT``) and NOT cached — ``get`` re-reads it. The vjp
    treedef and leaf dtypes/shapes stay in coordinator memory (they are
    structure, not data; identical every iteration)."""

    def __init__(self, x_act: float, host: HostStore, ssd: SSDStore,
                 meter: TrafficMeter, engine: IOEngine):
        self.x = x_act
        self.host = host
        self.ssd = ssd
        self.meter = meter
        self.engine = engine
        self._tree: Dict[Tuple[int, int], object] = {}
        self._meta: Dict[Tuple[int, int], list] = {}
        self._k: Dict[Tuple[int, int], int] = {}
        self._n: Dict[Tuple[int, int], int] = {}
        self._pending: Dict[Tuple[int, int], IORequest] = {}     # spills
        self._prefetched: Dict[Tuple[int, int], IORequest] = {}  # reads

    def _name(self, l: int, m: int) -> str:
        return f"act:{l}:{m}"

    def put(self, l: int, m: int, vjp):
        """Stream micro-batch m's layer-l residuals out (async tail)."""
        leaves, treedef = jax.tree.flatten(vjp)
        metas, chunks = [], []
        for leaf in leaves:
            arr = np.asarray(leaf)
            # record the TRUE shape first: ascontiguousarray promotes
            # 0-d scalars (slice indices etc.) to (1,), and a scalar
            # restored 1-d would break the vjp's transpose rules
            metas.append((arr.dtype, arr.shape))
            chunks.append(np.ascontiguousarray(arr).reshape(-1)
                          .view(np.uint8))
        buf = np.concatenate(chunks) if chunks else np.zeros(0, np.uint8)
        _xfer(self.meter, self.engine, "act", "gpu->cpu", buf.nbytes)
        key = (l, m)
        k = int(round(self.x * buf.size))
        self._tree[key] = treedef
        self._meta[key] = metas
        self._k[key] = k
        self._n[key] = buf.size
        if k:
            self.host.put(self._name(l, m) + ":h", buf[:k].copy())
        if k < buf.size:
            old = self._pending.pop(key, None)
            if old is not None:
                old.result()    # never two in-flight spills of one name
            self._pending[key] = self.ssd.write_async(
                self._name(l, m) + ":s", buf[k:], "act")

    def prefetch(self, l: int, m: int):
        """Hint: start the tail's SSD read now (ACT priority). No-op if
        there is nothing spilled, or the spill itself is still in
        flight (a request body must never wait on another request)."""
        key = (l, m)
        if key in self._prefetched or key not in self._n:
            return
        k, n = self._k[key], self._n[key]
        if k >= n:
            return
        wr = self._pending.get(key)
        if wr is not None and not wr.done():
            return
        name = self._name(l, m) + ":s"
        self._prefetched[key] = self.engine.submit(
            lambda: self.ssd.read(name, "act"),
            priority=IOPriority.ACT, category="act", route="ssd->cpu",
            nbytes=n - k)

    def get(self, l: int, m: int):
        """Residuals back on device: host head + SSD tail, rebuilt into
        the vjp pytree. A failed spill surfaces HERE — the executor's
        fallback point for degrading to recompute."""
        key = (l, m)
        name = self._name(l, m)
        req = self._prefetched.pop(key, None)
        wr = self._pending.pop(key, None)
        try:
            if wr is not None:
                wr.result()
        except BaseException:
            if req is not None and not req.cancel():
                try:
                    req.result()
                except Exception:
                    pass        # the spill's error is what propagates
            raise
        k, n = self._k[key], self._n[key]
        if req is not None:
            tail = req.result()
        else:
            tail = self.ssd.read(name + ":s", "act") if k < n else None
        head = self.host.pop(name + ":h") if k else np.zeros(0, np.uint8)
        if tail is None:
            buf = head
        elif head.size:
            buf = np.concatenate([head, tail])
        else:
            buf = tail
        _xfer(self.meter, self.engine, "act", "cpu->gpu", buf.nbytes)
        leaves, off = [], 0
        for dt, shp in self._meta[key]:
            nb = int(np.prod(shp)) * dt.itemsize
            leaves.append(jnp.asarray(
                np.frombuffer(buf[off:off + nb].tobytes(),
                              dtype=dt).reshape(shp)))
            off += nb
        vjp = jax.tree.unflatten(self._tree[key], leaves)
        self._forget(key)
        return vjp

    def _forget(self, key):
        for d in (self._tree, self._meta, self._k, self._n):
            d.pop(key, None)

    def drop(self, l: int, m: int):
        """Abandon one key: cancel/drain its in-flight requests
        (swallowing their errors — the caller is falling back) and free
        the host head."""
        key = (l, m)
        for d in (self._prefetched, self._pending):
            req = d.pop(key, None)
            if req is not None and not req.cancel():
                try:
                    req.result()
                except Exception:
                    pass
        name = self._name(l, m)
        if name + ":h" in self.host:
            self.host.pop(name + ":h")
        self._forget(key)

    def clear(self):
        """Abandon everything (mid-plan fault cleanup)."""
        keys = set(self._n) | set(self._pending) | set(self._prefetched)
        for l, m in keys:
            self.drop(l, m)

    def wait_pending(self):
        """Drain outstanding spills/reads (finish/teardown)."""
        for d in (self._pending, self._prefetched):
            for req in list(d.values()):
                try:
                    req.result()
                except (CancelledError, OSError):
                    pass
            d.clear()


class OptimizerStepCoordinator:
    """Per-layer Adam over tiered f32 state vectors with α-delay.
    Each layer's update runs as an OPTIMIZER_STATE-priority engine
    request: its tiered-vector reads/writes become chunked channel ops
    that yield to parameter fetches on the same SSD paths."""

    def __init__(self, masters: List[TieredVector], ms: List[TieredVector],
                 vs: List[TieredVector], params: List[TieredVector],
                 host: HostStore, meter: TrafficMeter,
                 engine: IOEngine, adam: CpuAdam, alpha: float,
                 param_dtype=np.dtype("bfloat16")):
        self.masters, self.ms, self.vs = masters, ms, vs
        self.params = params
        self.host = host
        self.meter = meter
        self.engine = engine
        self.adam = adam
        self.alpha = alpha
        self.param_dtype = param_dtype
        self._early_futs: Dict[int, IORequest] = {}
        self._late_futs: Dict[int, IORequest] = {}

    def _k_early(self, l: int) -> int:
        return int(round((1.0 - self.alpha) * self.masters[l].n))

    def submit_early(self, l: int, g_dev: jax.Array, step: int):
        """After layer l's backward: transfer grads, update the (1-α)
        fraction, retain grads for the α fraction (CPU-resident)."""
        g = np.asarray(g_dev).astype(np.float32)
        _xfer(self.meter, self.engine, "grad", "gpu->cpu", g.nbytes)

        def work():
            n = self.masters[l].n
            k = self._k_early(l)
            if k > 0:
                mast = self.masters[l].read_range(0, k)
                m_ = self.ms[l].read_range(0, k)
                v_ = self.vs[l].read_range(0, k)
                self.adam.update(mast, m_, v_, g[:k], step)
                self._write_range(self.masters[l], mast, 0, k)
                self._write_range(self.ms[l], m_, 0, k)
                self._write_range(self.vs[l], v_, 0, k)
                lowp = mast.astype(self.param_dtype)
                self._write_range(self.params[l], lowp, 0, k)
            if k < n:
                self.host.put(f"pending_grad:{l}", g[k:].copy())

        self._early_futs[l] = self.engine.submit(
            work, priority=IOPriority.OPTIMIZER_STATE, category="opt",
            route="cpu->ssd", nbytes=g.nbytes)

    def _write_range(self, vec: TieredVector, data: np.ndarray, lo: int, hi: int):
        vec.write_seg(data, lo)

    def flush_late(self, l: int, step: int):
        """Before layer l's next forward: update the remaining α fraction."""
        f = self._early_futs.pop(l, None)
        if f is not None:
            f.result()
        n = self.masters[l].n
        k = self._k_early(l)
        if k >= n:
            return
        key = f"pending_grad:{l}"
        if key not in self.host:
            return
        g_tail = self.host.pop(key)

        def work():
            mast = self.masters[l].read_range(k, n)
            m_ = self.ms[l].read_range(k, n)
            v_ = self.vs[l].read_range(k, n)
            self.adam.update(mast, m_, v_, g_tail, step)
            self._write_range(self.masters[l], mast, k, n)
            self._write_range(self.ms[l], m_, k, n)
            self._write_range(self.vs[l], v_, k, n)
            self._write_range(self.params[l], mast.astype(self.params[l].dtype), k, n)

        self._late_futs[l] = self.engine.submit(
            work, priority=IOPriority.OPTIMIZER_STATE, category="opt",
            route="cpu->ssd", nbytes=g_tail.nbytes)

    def wait_late(self, l: int):
        f = self._late_futs.pop(l, None)
        if f is not None:
            f.result()

    def wait_all(self):
        for d in (self._early_futs, self._late_futs):
            for f in list(d.values()):
                f.result()
            d.clear()
