"""The coordinators of GreedySnake §5 (+ the SSDTrain activation stream).

* ParameterCoordinator — per-layer low-precision params in tiered
  storage; two-stage prefetch (§4.2): the async engine request performs
  the SSD->CPU stage (scheduled by the plan's ``PREFETCH`` hints, up to
  ``prefetch_depth`` fetches ahead), the CPU->device copy happens at
  consumption on the caller's thread, and the device copy is dropped
  after use. ``reset()`` cancels in-flight fetches via the I/O engine's
  cancellation API at a schedule boundary.
* InterLayerTensorCoordinator — activation checkpoints (forward) and
  inter-layer gradients (backward). Checkpoints are written to CPU and
  the (1-x_c) tail streamed to SSD; the forward-pass consumer reads the
  CPU cache (paper: "written to SSD but at the same time cached in
  CPU"), after which the tail is dropped from CPU; the backward-pass
  recompute re-reads the tail from SSD — asynchronously ahead of the
  consumer when a ``PREFETCH_CKPT`` hint fired (``prefetch_bwd``).
  Inter-layer gradients stay in CPU (never SSD).
* OptimizerStepCoordinator — master/momentum/variance in tiered f32
  vectors; the (1-α) fraction updates right after a layer's backward
  (async, overlapped), the α fraction is flushed at the plan EPILOGUE
  and gates the layer's next forward fetch (§4.4 as a cross-iteration
  seam). ``prefetch_late`` (the ``PREFETCH_OPT`` hint) starts the
  α-tail state reads while backward still runs; ``flush_late``
  consumes a landed prefetch, cancels a queued one, and reads the tail
  itself otherwise — byte counters are hint-invariant either way.
  Gradients for the α fraction are retained in CPU memory (the paper
  reuses reclaimed param/ckpt buffers; we meter the bytes the same
  way).

* ActivationCoordinator — the SSDTrain-style activation stream
  (``activation_policy="spill"``): each layer's vjp residuals — the
  non-boundary activations backward needs — are flattened to one byte
  payload after the forward, the ``StorageRatios.act`` head kept in
  CPU and the tail streamed to SSD at ``IOPriority.ACT`` (below ckpt
  spills: strictly opportunistic). The CPU tail copy is dropped as
  soon as the spill is staged (reclaiming DRAM is the point), so every
  backward fetch re-reads the tail. A failed spill or fetch surfaces
  at ``get`` and the executor degrades that one micro-batch to the
  recompute path — the checkpoint tier it needs is still intact.

* KVBlockCoordinator — the serving-time KV-cache block stream
  (``repro.serve``): an evicted request's per-layer cache pytree is
  flattened to one byte payload, padded to a whole number of
  fixed-size blocks (``kv_blocks``), the ``x_host`` head blocks kept
  in CPU and the cold tail streamed to SSD at ``IOPriority.KV``
  (above ckpt spills — a late ``FETCH_KV`` is user-visible decode
  latency). Resume restores every block bitwise: the true payload
  length is kept in coordinator memory, so padding never leaks into
  the rebuilt pytree.

Every coordinator counts lookahead hits/misses (``la_hits`` /
``la_misses``: did the consumer find a completed prefetch?) — the
hit-rate column of the bench-smoke artifact. When the engine attaches
its shared ``repro.obs.Tracer`` (the ``tracer`` attribute, None by
default), each HINTED prefetch additionally records one lifecycle span
from issue to settlement, named ``<stream>:<outcome>`` with outcome
``hit`` (consumer found it landed), ``late`` (consumer waited on it),
``cancelled`` (reset/teardown/queued-cancel before use) or ``unused``
(landed but the consumer had a cheaper source) — co-located with the
``la_hits``/``la_misses`` increments so the trace and the counters can
never disagree.

All three submit their asynchronous work to :class:`repro.io.IOEngine`
rather than raw executors, so a parameter fetch the GPU is about to
block on is scheduled ahead of a deferrable checkpoint spill, and every
transfer is budgeted, cancellable, and (optionally) bandwidth-paced.
"""
from __future__ import annotations

import time
from concurrent.futures import CancelledError
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.io import IOEngine, IOPriority, IORequest
from repro.obs.tracer import CAT_HINT
from repro.offload.stores import HostStore, SSDStore, TieredVector, TrafficMeter
from repro.optim.cpu_adam import CpuAdam


def _hint_issue(coord, key):
    """Open a hint-lifecycle span: remember the issue time (only while
    the engine's tracer is recording — one flag test otherwise)."""
    tr = getattr(coord, "tracer", None)
    if tr is not None and tr.enabled:
        coord._hint_t[key] = time.perf_counter()


def _hint_settle(coord, stream: str, key, outcome: str):
    """Close a hint-lifecycle span with its outcome (hit / late /
    cancelled / unused). No-op for keys never opened (consumer-driven
    fetches, tracing off)."""
    t0 = coord._hint_t.pop(key, None)
    if t0 is None:
        return
    tr = getattr(coord, "tracer", None)
    if tr is None or not tr.enabled:
        return
    l, m = key if isinstance(key, tuple) else (key, -1)
    tr.record(f"hints/{stream}", f"{stream}:{outcome}", CAT_HINT,
              t0, time.perf_counter(), l=int(l), m=int(m), outcome=outcome)


def _xfer(meter: TrafficMeter, engine: IOEngine, category: str, route: str,
          nbytes: int):
    """Meter + (optionally) pace one device-side copy — the single place
    the meter.add/throttle pair lives for non-chunked transfers."""
    meter.add(category, route, nbytes)
    engine.throttle(route, nbytes)


def _cancel_or_drain(req: IORequest):
    """Dispose of a request whose result nobody wants: cancel it if
    still queued (no bytes moved), else drain it, swallowing its error
    — the caller has its own data path (fallback, unwind, teardown)."""
    if not req.cancel():
        try:
            req.result()
        except Exception:
            pass


class ParameterCoordinator:
    def __init__(self, vectors: List[TieredVector], meter: TrafficMeter,
                 engine: IOEngine, dtype=np.float16):
        self.vectors = vectors
        self.meter = meter
        self.engine = engine
        self._futures: Dict[int, IORequest] = {}
        self._gate: Dict[int, Callable[[], None]] = {}
        self._gate_ready: Dict[int, Callable[[], bool]] = {}
        self.la_hits = 0        # get() found a completed prefetch
        self.la_misses = 0      # get() had to wait (or submit) the fetch
        self.tracer = None      # engine-attached repro.obs.Tracer
        self._hint_t: Dict[int, float] = {}

    def set_gate(self, l: int, fn: Callable[[], None],
                 ready: Optional[Callable[[], bool]] = None):
        """Barrier that must complete before layer l's params are read
        (used to order the α-delayed optimizer flush before the fetch).

        ``ready`` is the deadlock guard for HINTED fetches: it must
        return True only when waiting on the gate is BOUNDED (the
        gating work is running or done, not still queued). A prefetch
        hint whose gate is not ready is skipped — otherwise a burst of
        ``prefetch_depth`` gated fetch bodies, all outranking the
        queued flushes in the priority heap, could occupy every
        request worker and leave none to run the very flushes they
        wait on. A consumer-driven ``get`` ignores ``ready``: the
        executor blocks instead of a worker, so workers stay free to
        drain the flush."""
        self._gate[l] = fn
        if ready is not None:
            self._gate_ready[l] = ready

    def _fetch(self, l: int) -> np.ndarray:
        """SSD -> host stage only (the two-stage §4.2 pipeline's first
        stage, and everything a prefetch worker should do): wait the α
        gate, then assemble the host vector. The host -> device copy
        stays in :meth:`get` on the consumer thread — doing it on an
        engine worker would steal CPU from the overlapped compute the
        lookahead exists to protect."""
        gate = self._gate.pop(l, None)
        self._gate_ready.pop(l, None)
        if gate is not None:
            gate()
        return self.vectors[l].read()              # meters ssd->cpu

    def prefetch(self, l: int, consumer: bool = False):
        """Submit layer l's async host fetch. A HINT (``consumer=False``)
        is refused while l's gate is not ready (see :meth:`set_gate`);
        the consumer path always submits — its wait is the executor's,
        not a worker's."""
        if not (0 <= l < len(self.vectors)) or l in self._futures:
            return
        if not consumer:
            ready = self._gate_ready.get(l)
            if l in self._gate and ready is not None and not ready():
                return
        v = self.vectors[l]
        self._futures[l] = self.engine.submit(
            lambda l=l: self._fetch(l),
            priority=IOPriority.PARAM_FETCH, category="param",
            route="ssd->cpu", nbytes=v.n * v.dtype.itemsize)
        if not consumer:
            _hint_issue(self, l)

    def get(self, l: int) -> jax.Array:
        if l not in self._futures:
            self.prefetch(l, consumer=True)
            self.la_misses += 1
        elif self._futures[l].done():
            self.la_hits += 1
            _hint_settle(self, "param", l, "hit")
        else:
            self.la_misses += 1
            _hint_settle(self, "param", l, "late")
        host_arr = self._futures.pop(l).result()
        dev = jnp.asarray(host_arr)                 # "PCIe" copy
        _xfer(self.meter, self.engine, "param", "cpu->gpu", host_arr.nbytes)
        return dev

    def reset(self):
        """Drop all outstanding prefetches at a schedule boundary:
        queued requests are cancelled before they touch storage; a
        running one is drained so its buffers settle. A drained
        request's ERROR is swallowed (``_cancel_or_drain``): nobody
        will consume these futures, and a failed prefetch left in
        ``_futures`` would re-raise a dead step's fault into the next
        step's ``get``."""
        for l, req in self._futures.items():
            _hint_settle(self, "param", l, "cancelled")
            _cancel_or_drain(req)
        self._futures.clear()

    def clear_gates(self):
        """Drop every armed α gate. NOT part of :meth:`reset`: the
        RESET_PARAMS plan op calls ``reset()`` mid-step between waves,
        where the armed gates must survive to order the next wave's
        fetches after their optimizer tails. Only the between-iteration
        plan-swap seam (``apply_plan_config``) and the executor's
        mid-step failure unwind may clear them — at the seam the α
        tails have been flushed and waited; on a failed step the tails
        are abandoned with the step. Either way a stale gate would only
        re-raise a dead step's fault (or deadlock) on the next plan's
        first fetch."""
        self._gate.clear()
        self._gate_ready.clear()


class InterLayerTensorCoordinator:
    """Checkpoints: dict (layer, mb) -> (host_head, ssd_name or None).
    x_c = CPU-resident fraction; the tail beyond k goes to SSD."""

    def __init__(self, x_cpu: float, host: HostStore, ssd: SSDStore,
                 meter: TrafficMeter, engine: IOEngine):
        self.x = x_cpu
        self.host = host
        self.ssd = ssd
        self.meter = meter
        self.engine = engine
        self._pending: Dict[Tuple[str, int, int], IORequest] = {}
        self._shapes: Dict[Tuple[str, int, int], tuple] = {}
        self._device_kept: Dict[Tuple[int, int], jax.Array] = {}
        self._prefetched: Dict[Tuple[int, int], IORequest] = {}  # bwd tails
        self.la_hits = 0        # bwd tail was prefetched and had landed
        self.la_misses = 0      # bwd tail came off the SSD synchronously
        self.tracer = None      # engine-attached repro.obs.Tracer
        self._hint_t: Dict[Tuple[int, int], float] = {}

    def _key(self, kind: str, l: int, m: int) -> str:
        return f"{kind}:{l}:{m}"

    # ---- forward checkpoints ----
    def put_ckpt(self, l: int, m: int, y_dev: jax.Array,
                 keep_on_device: bool = False):
        """Offload layer-l input checkpoint for micro-batch m."""
        if keep_on_device:
            self._device_kept[(l, m)] = y_dev
        arr = np.asarray(y_dev).reshape(-1)
        _xfer(self.meter, self.engine, "ckpt", "gpu->cpu", arr.nbytes)
        self._shapes[("c", l, m)] = y_dev.shape
        k = int(round(self.x * arr.size))
        name = self._key("c", l, m)
        self.host.put(name + ":h", arr[:k].copy())
        self.host.put(name + ":tail", arr[k:].copy())  # CPU cache until consumed
        if k < arr.size:
            old = self._pending.pop(("c", l, m), None)
            if old is not None:
                old.result()    # never two in-flight spills of one name
            # spill via the staging pool: lowest priority, cancellable
            self._pending[("c", l, m)] = self.ssd.write_async(
                name + ":s", arr[k:], "ckpt")

    def get_ckpt_fwd(self, l: int, m: int) -> jax.Array:
        """Next-layer forward input: device-kept or CPU cache (no SSD read).
        Drops the CPU tail afterwards (reclaimed, §4.4)."""
        if (l, m) in self._device_kept:
            return self._device_kept.pop((l, m))
        # §4.2 device-slot discipline: a kept boundary checkpoint is only
        # useful if it is the boundary's FIRST consumer (alternating
        # micro-batch order). A consumer for a different micro-batch means
        # the order was perturbed — the device cannot hold the slot across
        # the whole layer pass, so the kept copy is evicted (its CPU cache
        # already exists) and is re-read like any other micro-batch.
        for k in [k for k in self._device_kept if k[0] == l]:
            del self._device_kept[k]
        name = self._key("c", l, m)
        head = self.host.get(name + ":h")
        tail = self.host.pop(name + ":tail")   # consume CPU cache
        arr = np.concatenate([head, tail])
        _xfer(self.meter, self.engine, "ckpt", "cpu->gpu", arr.nbytes)
        return jnp.asarray(arr).reshape(self._shapes[("c", l, m)])

    def prefetch_bwd(self, l: int, m: int):
        """``PREFETCH_CKPT`` hint: start the backward tail's SSD re-read
        now (ckpt priority) instead of blocking the executor at
        ``get_ckpt_bwd``. No-op when the payload cannot need an SSD
        read — unknown key, CPU-cached tail, fully host-resident head —
        or when the spill itself is still in flight (a request body
        must never wait on another request). Moves the read's bytes
        earlier, never changes them."""
        key = (l, m)
        if key in self._prefetched or ("c", l, m) not in self._shapes:
            return
        name = self._key("c", l, m)
        if name + ":tail" in self.host or name + ":h" not in self.host:
            return
        head = self.host.get(name + ":h")
        n = int(np.prod(self._shapes[("c", l, m)]))
        if head.size >= n:
            return
        wr = self._pending.get(("c", l, m))
        if wr is not None and not wr.done():
            return
        self._prefetched[key] = self.engine.submit(
            lambda: self.ssd.read(name + ":s", "ckpt"),
            priority=IOPriority.CKPT_SPILL, category="ckpt",
            route="ssd->cpu",
            nbytes=(n - head.size) * head.dtype.itemsize)
        _hint_issue(self, key)

    def get_ckpt_bwd(self, l: int, m: int) -> jax.Array:
        """Backward recompute input: CPU head + SSD tail (prefetched by
        a ``PREFETCH_CKPT`` hint when the lookahead pass placed one)."""
        self._device_kept.pop((l, m), None)
        name = self._key("c", l, m)
        req = self._pending.pop(("c", l, m), None)
        if req is not None:
            req.result()
        pre = self._prefetched.pop((l, m), None)
        head = self.host.get(name + ":h")
        shape = self._shapes[("c", l, m)]
        n = int(np.prod(shape))
        if head.size < n:
            if name + ":tail" in self.host:      # never trimmed (x=1 case)
                tail = self.host.get(name + ":tail")
            elif pre is not None:
                hit = pre.done()     # evaluate once: it can flip mid-read
                self.la_hits += hit
                self.la_misses += not hit
                _hint_settle(self, "ckpt", (l, m), "hit" if hit else "late")
                tail = pre.result()
                pre = None
            else:
                self.la_misses += 1
                tail = self.ssd.read(name + ":s", "ckpt")
            arr = np.concatenate([head, tail])
        else:
            arr = head
        if pre is not None:          # prefetched but unused (CPU-cached)
            _hint_settle(self, "ckpt", (l, m), "unused")
            _cancel_or_drain(pre)
        _xfer(self.meter, self.engine, "ckpt", "cpu->gpu", arr.nbytes)
        return jnp.asarray(arr).reshape(shape)

    def wait_pending(self):
        """Drain all outstanding checkpoint spills (engine teardown)."""
        for req in list(self._pending.values()):
            try:
                req.result()
            except CancelledError:
                pass
        self._pending.clear()

    def clear(self):
        """Abandon every checkpoint / inter-layer gradient this
        coordinator tracks: release device-kept boundary tensors, cancel
        or drain in-flight spills (swallowing their errors — the caller
        is already unwinding), and drop the CPU-resident pieces. Used by
        the plan executor's mid-step failure path so a failed micro-batch
        cannot leak device slots into the next step."""
        self._device_kept.clear()
        for req in list(self._pending.values()):
            if not req.cancel():
                try:
                    req.result()
                except Exception:
                    pass
        self._pending.clear()
        for key, req in list(self._prefetched.items()):
            _hint_settle(self, "ckpt", key, "cancelled")
            _cancel_or_drain(req)
        self._prefetched.clear()
        for kind, l, m in list(self._shapes):
            name = self._key(kind, l, m)
            keys = ([name + ":h", name + ":tail"] if kind == "c"
                    else [name])
            for key in keys:
                if key in self.host:
                    self.host.pop(key)
        self._shapes.clear()

    def drop_ckpt(self, l: int, m: int):
        # A ckpt consumed only via get_ckpt_fwd (the head layer) still has
        # its SSD spill in flight: drain it so no orphan write can race a
        # next-step spill of the same name and counters stay deterministic.
        self._device_kept.pop((l, m), None)
        pre = self._prefetched.pop((l, m), None)
        if pre is not None:
            _hint_settle(self, "ckpt", (l, m), "cancelled")
            _cancel_or_drain(pre)
        req = self._pending.pop(("c", l, m), None)
        if req is not None:
            req.result()
        name = self._key("c", l, m)
        self.host.pop(name + ":h") if name + ":h" in self.host else None
        if name + ":tail" in self.host:
            self.host.pop(name + ":tail")

    # ---- inter-layer gradients (backward; CPU only, §4.3) ----
    def put_grad(self, l: int, m: int, dx_dev: jax.Array,
                 keep_on_device: bool = False):
        if keep_on_device:
            self._device_kept[(-l - 1, m)] = dx_dev
            return
        arr = np.asarray(dx_dev)
        _xfer(self.meter, self.engine, "inter_grad", "gpu->cpu", arr.nbytes)
        self._shapes[("g", l, m)] = dx_dev.shape
        self.host.put(self._key("g", l, m), arr)

    def get_grad(self, l: int, m: int) -> jax.Array:
        if (-l - 1, m) in self._device_kept:
            return self._device_kept.pop((-l - 1, m))
        # Out-of-order consumer: a kept inter-layer gradient was never
        # written to CPU (that is the whole saving), so losing the device
        # slot forces the spill the alternating order §4.2 avoids — pay
        # the gpu->cpu transfer now, and the cpu->gpu read later.
        for k in [k for k in self._device_kept if k[0] == -l - 1]:
            dx = self._device_kept.pop(k)
            arr = np.asarray(dx)
            _xfer(self.meter, self.engine, "inter_grad", "gpu->cpu",
                  arr.nbytes)
            self._shapes[("g", l, k[1])] = dx.shape
            self.host.put(self._key("g", l, k[1]), arr)
        arr = self.host.pop(self._key("g", l, m))
        _xfer(self.meter, self.engine, "inter_grad", "cpu->gpu", arr.nbytes)
        return jnp.asarray(arr).reshape(self._shapes[("g", l, m)])


class ActivationCoordinator:
    """Activation (vjp-residual) spill/fetch stream, keyed (layer, mb).

    Layout per key: the flattened residual payload's ``x_act`` head
    lives in the host store (``act:l:m:h``); the tail is written to SSD
    asynchronously (``act:l:m:s``, category ``"act"`` =>
    ``IOPriority.ACT``) and NOT cached — ``get`` re-reads it. The vjp
    treedef and leaf dtypes/shapes stay in coordinator memory (they are
    structure, not data; identical every iteration)."""

    def __init__(self, x_act: float, host: HostStore, ssd: SSDStore,
                 meter: TrafficMeter, engine: IOEngine):
        self.x = x_act
        self.host = host
        self.ssd = ssd
        self.meter = meter
        self.engine = engine
        self._tree: Dict[Tuple[int, int], object] = {}
        self._meta: Dict[Tuple[int, int], list] = {}
        self._k: Dict[Tuple[int, int], int] = {}
        self._n: Dict[Tuple[int, int], int] = {}
        self._pending: Dict[Tuple[int, int], IORequest] = {}     # spills
        self._prefetched: Dict[Tuple[int, int], IORequest] = {}  # reads
        self.la_hits = 0        # get() found a landed tail prefetch
        self.la_misses = 0      # get() read the tail synchronously
        self.tracer = None      # engine-attached repro.obs.Tracer
        self._hint_t: Dict[Tuple[int, int], float] = {}

    def _name(self, l: int, m: int) -> str:
        return f"act:{l}:{m}"

    def put(self, l: int, m: int, vjp):
        """Stream micro-batch m's layer-l residuals out (async tail)."""
        leaves, treedef = jax.tree.flatten(vjp)
        metas, chunks = [], []
        for leaf in leaves:
            arr = np.asarray(leaf)
            # record the TRUE shape first: ascontiguousarray promotes
            # 0-d scalars (slice indices etc.) to (1,), and a scalar
            # restored 1-d would break the vjp's transpose rules
            metas.append((arr.dtype, arr.shape))
            chunks.append(np.ascontiguousarray(arr).reshape(-1)
                          .view(np.uint8))
        buf = np.concatenate(chunks) if chunks else np.zeros(0, np.uint8)
        _xfer(self.meter, self.engine, "act", "gpu->cpu", buf.nbytes)
        key = (l, m)
        k = int(round(self.x * buf.size))
        self._tree[key] = treedef
        self._meta[key] = metas
        self._k[key] = k
        self._n[key] = buf.size
        if k:
            self.host.put(self._name(l, m) + ":h", buf[:k].copy())
        if k < buf.size:
            old = self._pending.pop(key, None)
            if old is not None:
                old.result()    # never two in-flight spills of one name
            self._pending[key] = self.ssd.write_async(
                self._name(l, m) + ":s", buf[k:], "act")

    def prefetch(self, l: int, m: int):
        """Hint: start the tail's SSD read now (ACT priority). No-op if
        there is nothing spilled, or the spill itself is still in
        flight (a request body must never wait on another request)."""
        key = (l, m)
        if key in self._prefetched or key not in self._n:
            return
        k, n = self._k[key], self._n[key]
        if k >= n:
            return
        wr = self._pending.get(key)
        if wr is not None and not wr.done():
            return
        name = self._name(l, m) + ":s"
        self._prefetched[key] = self.engine.submit(
            lambda: self.ssd.read(name, "act"),
            priority=IOPriority.ACT, category="act", route="ssd->cpu",
            nbytes=n - k)
        _hint_issue(self, key)

    def get(self, l: int, m: int):
        """Residuals back on device: host head + SSD tail, rebuilt into
        the vjp pytree. A failed spill surfaces HERE — the executor's
        fallback point for degrading to recompute."""
        key = (l, m)
        name = self._name(l, m)
        req = self._prefetched.pop(key, None)
        wr = self._pending.pop(key, None)
        try:
            if wr is not None:
                wr.result()
        except BaseException:
            if req is not None and not req.cancel():
                try:
                    req.result()
                except Exception:
                    pass        # the spill's error is what propagates
            raise
        k, n = self._k[key], self._n[key]
        if req is not None:
            hit = req.done()         # evaluate once: it can flip mid-read
            self.la_hits += hit
            self.la_misses += not hit
            _hint_settle(self, "act", key, "hit" if hit else "late")
            tail = req.result()
        elif k < n:
            self.la_misses += 1
            tail = self.ssd.read(name + ":s", "act")
        else:
            tail = None
        head = self.host.pop(name + ":h") if k else np.zeros(0, np.uint8)
        if tail is None:
            buf = head
        elif head.size:
            buf = np.concatenate([head, tail])
        else:
            buf = tail
        _xfer(self.meter, self.engine, "act", "cpu->gpu", buf.nbytes)
        leaves, off = [], 0
        for dt, shp in self._meta[key]:
            nb = int(np.prod(shp)) * dt.itemsize
            leaves.append(jnp.asarray(
                np.frombuffer(buf[off:off + nb].tobytes(),
                              dtype=dt).reshape(shp)))
            off += nb
        vjp = jax.tree.unflatten(self._tree[key], leaves)
        self._forget(key)
        return vjp

    def _forget(self, key):
        for d in (self._tree, self._meta, self._k, self._n):
            d.pop(key, None)

    def drop(self, l: int, m: int):
        """Abandon one key: cancel/drain its in-flight requests
        (swallowing their errors — the caller is falling back) and free
        the host head."""
        key = (l, m)
        _hint_settle(self, "act", key, "cancelled")
        for d in (self._prefetched, self._pending):
            req = d.pop(key, None)
            if req is not None:
                _cancel_or_drain(req)
        name = self._name(l, m)
        if name + ":h" in self.host:
            self.host.pop(name + ":h")
        self._forget(key)

    def clear(self):
        """Abandon everything (mid-plan fault cleanup)."""
        keys = set(self._n) | set(self._pending) | set(self._prefetched)
        for l, m in keys:
            self.drop(l, m)

    def wait_pending(self):
        """Drain outstanding spills/reads (finish/teardown)."""
        for d in (self._pending, self._prefetched):
            for req in list(d.values()):
                try:
                    req.result()
                except (CancelledError, OSError):
                    pass
            d.clear()


class KVBlockCoordinator:
    """Tiered KV-cache block stream, keyed (request, layer-unit).

    Layout per key: the flattened cache payload is padded up to
    ``n_blocks * block_bytes`` (``kv_blocks`` — the SAME ceil the plan
    interpreter and ``traffic.kv_traffic`` price), the
    ``round(x_host * n_blocks)`` head blocks live in the host store
    (``kv:r:l:h``), and the cold tail blocks are written to SSD
    asynchronously (``kv:r:l:s``, category ``"kv"`` =>
    ``IOPriority.KV``). Cache treedef and leaf dtypes/shapes stay in
    coordinator memory — structure, not data. ``get`` rebuilds the
    pytree bitwise from the true (un-padded) payload length."""

    def __init__(self, block_bytes: int, x_host: float, host: HostStore,
                 ssd: SSDStore, meter: TrafficMeter, engine: IOEngine):
        if block_bytes <= 0:
            raise ValueError(f"block_bytes must be > 0, got {block_bytes}")
        self.block_bytes = int(block_bytes)
        self.x = float(x_host)
        self.host = host
        self.ssd = ssd
        self.meter = meter
        self.engine = engine
        self._tree: Dict[Tuple[int, int], object] = {}
        self._meta: Dict[Tuple[int, int], list] = {}
        self._k: Dict[Tuple[int, int], int] = {}       # host head blocks
        self._blocks: Dict[Tuple[int, int], int] = {}  # total blocks
        self._n: Dict[Tuple[int, int], int] = {}       # true payload bytes
        self._pending: Dict[Tuple[int, int], IORequest] = {}     # spills
        self._prefetched: Dict[Tuple[int, int], IORequest] = {}  # reads
        self.la_hits = 0        # get() found a landed tail prefetch
        self.la_misses = 0      # get() read the cold tail synchronously
        self.tracer = None      # engine-attached repro.obs.Tracer
        self._hint_t: Dict[Tuple[int, int], float] = {}

    def _name(self, r: int, l: int) -> str:
        return f"kv:{r}:{l}"

    def blocks_of(self, nbytes: int) -> int:
        from repro.core.traffic import kv_blocks
        return kv_blocks(nbytes, self.block_bytes)

    def put(self, r: int, l: int, caches):
        """SPILL_KV: evict request r's layer-unit-l cache pytree to the
        tiers (all blocks off device; cold tail to SSD, async)."""
        leaves, treedef = jax.tree.flatten(caches)
        metas, chunks = [], []
        for leaf in leaves:
            arr = np.asarray(leaf)
            metas.append((arr.dtype, arr.shape))
            chunks.append(np.ascontiguousarray(arr).reshape(-1)
                          .view(np.uint8))
        buf = np.concatenate(chunks) if chunks else np.zeros(0, np.uint8)
        bb = self.block_bytes
        nbk = self.blocks_of(buf.size)
        pad = np.zeros(nbk * bb, np.uint8)
        pad[:buf.size] = buf
        _xfer(self.meter, self.engine, "kv", "gpu->cpu", pad.nbytes)
        key = (r, l)
        kb = int(round(self.x * nbk))
        self._tree[key] = treedef
        self._meta[key] = metas
        self._k[key] = kb
        self._blocks[key] = nbk
        self._n[key] = buf.size
        if kb:
            self.host.put(self._name(r, l) + ":h", pad[:kb * bb].copy())
        if kb < nbk:
            old = self._pending.pop(key, None)
            if old is not None:
                old.result()    # never two in-flight spills of one name
            self._pending[key] = self.ssd.write_async(
                self._name(r, l) + ":s", pad[kb * bb:], "kv")

    def prefetch(self, r: int, l: int):
        """``PREFETCH_KV`` hint: start the cold tail's SSD read now (KV
        priority). No-op if nothing is spilled or the spill itself is
        still in flight (a request body must never wait on another
        request)."""
        key = (r, l)
        if key in self._prefetched or key not in self._blocks:
            return
        kb, nbk = self._k[key], self._blocks[key]
        if kb >= nbk:
            return
        wr = self._pending.get(key)
        if wr is not None and not wr.done():
            return
        name = self._name(r, l) + ":s"
        self._prefetched[key] = self.engine.submit(
            lambda: self.ssd.read(name, "kv"),
            priority=IOPriority.KV, category="kv", route="ssd->cpu",
            nbytes=(nbk - kb) * self.block_bytes)
        _hint_issue(self, key)

    def get(self, r: int, l: int):
        """FETCH_KV: restore the cache pytree bitwise — host head
        blocks + SSD cold tail, truncated back to the true payload."""
        key = (r, l)
        name = self._name(r, l)
        req = self._prefetched.pop(key, None)
        wr = self._pending.pop(key, None)
        try:
            if wr is not None:
                wr.result()
        except BaseException:
            if req is not None and not req.cancel():
                try:
                    req.result()
                except Exception:
                    pass        # the spill's error is what propagates
            raise
        kb, nbk = self._k[key], self._blocks[key]
        if req is not None:
            hit = req.done()         # evaluate once: it can flip mid-read
            self.la_hits += hit
            self.la_misses += not hit
            _hint_settle(self, "kv", key, "hit" if hit else "late")
            tail = req.result()
        elif kb < nbk:
            self.la_misses += 1
            tail = self.ssd.read(name + ":s", "kv")
        else:
            tail = None
        head = (self.host.pop(name + ":h") if kb
                else np.zeros(0, np.uint8))
        if tail is None:
            pad = head
        elif head.size:
            pad = np.concatenate([head, tail])
        else:
            pad = tail
        _xfer(self.meter, self.engine, "kv", "cpu->gpu", pad.nbytes)
        buf = pad[:self._n[key]]
        leaves, off = [], 0
        for dt, shp in self._meta[key]:
            nb = int(np.prod(shp)) * dt.itemsize
            leaves.append(jnp.asarray(
                np.frombuffer(buf[off:off + nb].tobytes(),
                              dtype=dt).reshape(shp)))
            off += nb
        caches = jax.tree.unflatten(self._tree[key], leaves)
        self._forget(key)
        return caches

    def _forget(self, key):
        for d in (self._tree, self._meta, self._k, self._blocks, self._n):
            d.pop(key, None)

    def drop(self, r: int, l: int):
        """Abandon one key (finished request whose blocks are freed
        without a resume): cancel/drain in-flight requests, free the
        host head, delete the SSD tail."""
        key = (r, l)
        _hint_settle(self, "kv", key, "cancelled")
        pre = self._prefetched.pop(key, None)
        if pre is not None:
            _cancel_or_drain(pre)
        wr = self._pending.pop(key, None)
        if wr is not None:
            try:
                wr.result()   # let the write land, then delete the name
            except Exception:
                pass
        name = self._name(r, l)
        if name + ":h" in self.host:
            self.host.pop(name + ":h")
        kb = self._k.get(key)
        nbk = self._blocks.get(key)
        if kb is not None and nbk is not None and kb < nbk:
            try:
                self.ssd.delete(name + ":s")
            except KeyError:
                pass
        self._forget(key)

    def clear(self):
        """Abandon everything (engine teardown / fault cleanup)."""
        keys = set(self._n) | set(self._pending) | set(self._prefetched)
        for r, l in keys:
            self.drop(r, l)

    def wait_pending(self):
        """Drain outstanding spills/reads (finish/teardown)."""
        for d in (self._pending, self._prefetched):
            for req in list(d.values()):
                try:
                    req.result()
                except (CancelledError, OSError):
                    pass
            d.clear()


class OptimizerStepCoordinator:
    """Per-layer Adam over tiered f32 state vectors with α-delay.
    Each layer's update runs as an OPTIMIZER_STATE-priority engine
    request: its tiered-vector reads/writes become chunked channel ops
    that yield to parameter fetches on the same SSD paths."""

    def __init__(self, masters: List[TieredVector], ms: List[TieredVector],
                 vs: List[TieredVector], params: List[TieredVector],
                 host: HostStore, meter: TrafficMeter,
                 engine: IOEngine, adam: CpuAdam, alpha: float,
                 param_dtype=np.dtype("bfloat16")):
        self.masters, self.ms, self.vs = masters, ms, vs
        self.params = params
        self.host = host
        self.meter = meter
        self.engine = engine
        self.adam = adam
        self.alpha = alpha
        self.param_dtype = param_dtype
        self._early_futs: Dict[int, IORequest] = {}
        self._late_futs: Dict[int, IORequest] = {}
        self._late_pre: Dict[int, IORequest] = {}   # PREFETCH_OPT reads
        self.la_hits = 0        # flush_late consumed a landed prefetch
        self.la_misses = 0      # flush_late read the α-tail itself
        self.tracer = None      # engine-attached repro.obs.Tracer
        self._hint_t: Dict[int, float] = {}

    def _k_early(self, l: int) -> int:
        return int(round((1.0 - self.alpha) * self.masters[l].n))

    def prefetch_late(self, l: int):
        """``PREFETCH_OPT`` hint: start layer l's α-tail state reads
        (master/m/v of [k_early, n)) now, so the next ``flush_late``
        only has to run the Adam segment and the writes. Value-safe
        whenever the previous flush of l has completed (the α gate
        orders it before l's forward fetch) — the concurrent EARLY
        segment only writes the disjoint [0, k_early) ranges. No-op if
        there is no α tail or a hint is already in flight; moves the
        reads earlier, never changes them."""
        if l in self._late_pre:
            return
        n = self.masters[l].n
        k = self._k_early(l)
        if k >= n:
            return

        def work():
            return (self.masters[l].read_range(k, n),
                    self.ms[l].read_range(k, n),
                    self.vs[l].read_range(k, n))

        self._late_pre[l] = self.engine.submit(
            work, priority=IOPriority.OPTIMIZER_STATE, category="opt",
            route="ssd->cpu", nbytes=3 * (n - k) * 4)
        _hint_issue(self, l)

    def submit_early(self, l: int, g_dev: jax.Array, step: int):
        """After layer l's backward: transfer grads, update the (1-α)
        fraction, retain grads for the α fraction (CPU-resident)."""
        g = np.asarray(g_dev).astype(np.float32)
        _xfer(self.meter, self.engine, "grad", "gpu->cpu", g.nbytes)

        def work():
            n = self.masters[l].n
            k = self._k_early(l)
            if k > 0:
                mast = self.masters[l].read_range(0, k)
                m_ = self.ms[l].read_range(0, k)
                v_ = self.vs[l].read_range(0, k)
                self.adam.update(mast, m_, v_, g[:k], step)
                self._write_range(self.masters[l], mast, 0, k)
                self._write_range(self.ms[l], m_, 0, k)
                self._write_range(self.vs[l], v_, 0, k)
                lowp = mast.astype(self.param_dtype)
                self._write_range(self.params[l], lowp, 0, k)
            if k < n:
                self.host.put(f"pending_grad:{l}", g[k:].copy())

        self._early_futs[l] = self.engine.submit(
            work, priority=IOPriority.OPTIMIZER_STATE, category="opt",
            route="cpu->ssd", nbytes=g.nbytes)

    def _write_range(self, vec: TieredVector, data: np.ndarray, lo: int, hi: int):
        vec.write_seg(data, lo)

    def flush_late(self, l: int, step: int):
        """Flush the remaining α fraction (gate-ordered before layer
        l's next forward fetch). Consumes a ``prefetch_late`` hint's
        state reads when one landed; a still-queued hint is cancelled
        (no bytes moved) and the flush reads the tail itself, so the
        byte counters are hint-invariant either way."""
        f = self._early_futs.pop(l, None)
        if f is not None:
            f.result()
        pre = self._late_pre.pop(l, None)
        n = self.masters[l].n
        k = self._k_early(l)
        key = f"pending_grad:{l}"
        if k >= n or key not in self.host:
            if pre is not None:
                _hint_settle(self, "opt", l, "unused")
                _cancel_or_drain(pre)
            return
        g_tail = self.host.pop(key)
        if pre is not None:
            if pre.done():
                self.la_hits += 1
                _hint_settle(self, "opt", l, "hit")
            elif pre.cancel():
                pre = None           # never started: read synchronously
                self.la_misses += 1
                _hint_settle(self, "opt", l, "cancelled")
            else:
                self.la_misses += 1  # running: its bytes are in flight
                _hint_settle(self, "opt", l, "late")
        else:
            self.la_misses += 1

        def work():
            if pre is not None:
                # running-or-done by construction (a queued hint was
                # cancelled above), so this wait is bounded and cannot
                # deadlock the request workers
                mast, m_, v_ = pre.result()
            else:
                mast = self.masters[l].read_range(k, n)
                m_ = self.ms[l].read_range(k, n)
                v_ = self.vs[l].read_range(k, n)
            self.adam.update(mast, m_, v_, g_tail, step)
            self._write_range(self.masters[l], mast, k, n)
            self._write_range(self.ms[l], m_, k, n)
            self._write_range(self.vs[l], v_, k, n)
            self._write_range(self.params[l], mast.astype(self.params[l].dtype), k, n)

        self._late_futs[l] = self.engine.submit(
            work, priority=IOPriority.OPTIMIZER_STATE, category="opt",
            route="cpu->ssd", nbytes=g_tail.nbytes)

    def wait_late(self, l: int):
        f = self._late_futs.pop(l, None)
        if f is not None:
            f.result()

    def late_settled(self, l: int) -> bool:
        """Is waiting on layer l's late flush BOUNDED right now — no
        flush outstanding, or its request already running/done (never
        still queued)? The α-gate readiness probe for hinted fetches."""
        f = self._late_futs.get(l)
        return f is None or f.done() or f.running()

    def wait_all(self):
        for l, f in list(self._late_pre.items()):
            _hint_settle(self, "opt", l, "cancelled")
            _cancel_or_drain(f)     # an orphaned hint's error is moot
        self._late_pre.clear()
        for d in (self._early_futs, self._late_futs):
            for f in list(d.values()):
                f.result()
            d.clear()

    def clear(self):
        """Abandon every outstanding flush after a failed step:
        cancel-or-drain all futures (their errors either already
        propagated to the caller or belong to a step being thrown
        away) and drop retained α-tail gradients, so the next step
        cannot consume a stale ``pending_grad`` or trip over a failed
        flush via the α gate. Unlike :meth:`wait_all` this never
        raises. The completed prefix of the in-place Adam update stays
        applied — a failed step is re-run from a checkpoint, not
        resumed."""
        for d in (self._late_pre, self._early_futs, self._late_futs):
            for f in list(d.values()):
                _cancel_or_drain(f)
            d.clear()
        self._hint_t.clear()
        for l in range(len(self.masters)):
            key = f"pending_grad:{l}"
            if key in self.host:
                self.host.pop(key)
